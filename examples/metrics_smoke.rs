//! End-to-end metrics smoke: serve a dwork hub with live counters and a
//! Prometheus exposition endpoint (the library form of `threesched dhub
//! serve --metrics-addr`), drive a small campaign through it with a
//! worker pool, read the hub's snapshot off the `RunOutcome`, scrape
//! the endpoint over raw TCP the way Prometheus would, and print the
//! exposition body to stdout.
//!
//! Run: `cargo run --example metrics_smoke`

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use anyhow::Result;
use threesched::coordinator::dwork::{self, SchedState, ServerConfig};
use threesched::metrics::{self, Registry};
use threesched::workflow::{Backend, BackendDetail, Session, TaskSpec, WorkerPool, WorkflowGraph};

fn main() -> Result<()> {
    // a hub with live counters and a scrape endpoint
    let reg = Registry::enabled();
    let (scrape_addr, _responder) = metrics::serve_exposition(reg.clone(), "127.0.0.1:0")?;
    let cfg = ServerConfig { metrics: reg, ..ServerConfig::default() };
    let (addr, _guard, _hub) = dwork::spawn_tcp(SchedState::new(), cfg, "127.0.0.1:0")?;
    eprintln!("hub on {addr}, exposition on {scrape_addr}");

    // a small diamond campaign, submitted fire-and-forget
    let mut g = WorkflowGraph::new("metrics-smoke");
    g.add_task(TaskSpec::new("fetch").est(0.001))?;
    g.add_task(TaskSpec::new("left").after(&["fetch"]).est(0.001))?;
    g.add_task(TaskSpec::new("right").after(&["fetch"]).est(0.001))?;
    g.add_task(TaskSpec::new("join").after(&["left", "right"]).est(0.001))?;
    let submission = Session::new(&g)
        .backend(Backend::Dwork { remote: Some(addr.to_string().into()) })
        .submit()?;

    // a two-thread pool drains the hub while wait() polls
    let dir =
        std::env::temp_dir().join(format!("threesched-metrics-smoke-{}", std::process::id()));
    std::fs::create_dir_all(&dir)?;
    let pool_addr = addr.to_string();
    let pool_dir = dir.clone();
    let pool =
        std::thread::spawn(move || WorkerPool::new(&pool_addr).threads(2).dir(pool_dir).run());
    let outcome = submission.wait()?;
    let stats = pool.join().expect("pool thread")?;
    eprintln!(
        "campaign done: {} tasks via {} pool threads",
        outcome.summary.tasks_run, stats.threads
    );

    // the hub's snapshot rode along with wait()
    let BackendDetail::DworkRemote { metrics: Some(m), .. } = &outcome.detail else {
        anyhow::bail!("hub did not answer the Metrics request");
    };
    assert_eq!(m.counter("tasks_completed"), 4, "all four diamond tasks complete");

    // raw-TCP scrape, the way a Prometheus scrape config would
    let mut s = TcpStream::connect(scrape_addr)?;
    s.set_read_timeout(Some(Duration::from_secs(5)))?;
    s.write_all(b"GET /metrics HTTP/1.1\r\nHost: localhost\r\nConnection: close\r\n\r\n")?;
    let mut resp = String::new();
    s.read_to_string(&mut resp)?;
    assert!(resp.starts_with("HTTP/1.1 200"), "scrape failed: {resp}");
    let body = resp.split("\r\n\r\n").nth(1).unwrap_or("");
    assert!(
        body.contains("threesched_tasks_completed_total 4"),
        "exposition missing the completed-task counter:\n{body}"
    );
    assert!(
        body.contains("threesched_service_steal_seconds_bucket"),
        "exposition missing the steal service histogram"
    );
    println!("{body}");
    eprintln!("ok: scraped {} bytes of exposition from {scrape_addr}", body.len());
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
