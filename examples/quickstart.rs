//! Quickstart: the three schedulers in ~60 lines each of use.
//!
//! Run: `cargo run --release --example quickstart`

use threesched::coordinator::dwork::{self, TaskMsg};
use threesched::coordinator::mpilist::Context;
use threesched::coordinator::pmake;
use threesched::substrate::cluster::Machine;

fn demo_dwork() -> anyhow::Result<()> {
    println!("--- dwork: bag of tasks with dependencies ---");
    // build a small DAG: prep -> {dock-0, dock-1} -> report
    let mut state = dwork::SchedState::new();
    state.create(TaskMsg::new("prep", vec![]), &[])?;
    state.create(TaskMsg::new("dock-0", vec![]), &["prep".into()])?;
    state.create(TaskMsg::new("dock-1", vec![]), &["prep".into()])?;
    state.create(TaskMsg::new("report", vec![]), &["dock-0".into(), "dock-1".into()])?;
    let (connector, server) = dwork::spawn_inproc(state, dwork::ServerConfig::default());
    // two workers pull until the server says Exit
    std::thread::scope(|s| {
        for w in 0..2 {
            let conn = connector.connect();
            s.spawn(move || {
                let mut c = dwork::Client::new(Box::new(conn), format!("worker-{w}"));
                dwork::run_worker(&mut c, 1, |t| {
                    println!("  worker-{w} ran {}", t.name);
                    Ok(())
                })
                .unwrap();
            });
        }
    });
    drop(connector);
    let final_state = server.join().unwrap();
    println!("  all done: {}\n", final_state.all_done());
    Ok(())
}

fn demo_mpilist() {
    println!("--- mpi-list: bulk-synchronous map-reduce ---");
    let sums = Context::run(4, |ctx| {
        // distribute 0..1000, square locally, reduce globally
        let dfm = ctx.iterates(1000).map(|x| x * x);
        dfm.reduce(ctx, 0u64, |a, b| a + b)
    });
    println!("  sum of squares 0..1000 on every rank: {:?}\n", sums[0]);
    assert!(sums.iter().all(|&s| s == 332_833_500));
}

fn demo_pmake() -> anyhow::Result<()> {
    println!("--- pmake: file-directed rules ---");
    let dir = std::env::temp_dir().join(format!("threesched-quickstart-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    std::fs::write(dir.join("input.txt"), "42\n")?;
    // NOTE the paper's escaping rule: literal braces (awk's) are doubled,
    // template substitutions ({inp[x]}) are single.
    let rules = pmake::parse_rules(
        r#"
double:
  inp:
    x: "input.txt"
  out:
    y: "doubled.txt"
  script: |
    awk '{{print $1 * 2}}' {inp[x]} > {out[y]}
report:
  inp:
    y: "doubled.txt"
  out:
    r: "report.txt"
  script: |
    echo "result: $(cat {inp[y]})" > {out[r]}
"#,
    )?;
    let targets = pmake::parse_targets(&format!(
        "demo:\n  dirname: {}\n  out:\n    r: report.txt\n",
        dir.display()
    ))?;
    let dag = pmake::Dag::build(
        &rules,
        &targets[0],
        &|p: &std::path::Path| p.exists(),
        &|rs| pmake::default_mpirun(rs),
    )?;
    println!("  task graph: {} tasks", dag.tasks.len());
    let cfg = pmake::SchedConfig { nodes: 2, machine: Machine::summit(2), fifo: false };
    let report = pmake::run(&dag, &pmake::ShellExecutor::default(), &cfg)?;
    println!(
        "  succeeded: {}, report.txt = {:?}\n",
        report.succeeded.len(),
        std::fs::read_to_string(dir.join("report.txt"))?.trim()
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("threesched quickstart: three schedulers, three sync mechanisms\n");
    demo_dwork()?;
    demo_mpilist();
    demo_pmake()?;
    println!("quickstart OK");
    Ok(())
}
