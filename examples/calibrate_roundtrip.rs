//! The calibration loop end to end: simulate the three standard
//! calibration workloads under a *perturbed* cost model (standing in
//! for "your cluster", whose constants differ from the paper's Table
//! 4), write the lifecycle traces to disk, fit a profile back from the
//! trace files alone, check the injected constants are recovered,
//! cross-validate fitted-vs-default prediction error, and persist the
//! profile — exactly what `threesched calibrate <traces...> --out
//! profile.toml --report` automates.
//!
//! Run: `cargo run --release --example calibrate_roundtrip`
//!
//! Set `THREESCHED_CALIBRATE_DIR` to keep the traces and profile on
//! disk (CI does, and uploads them as workflow artifacts).

use std::path::PathBuf;

use threesched::calibrate::{self, workloads, CalibrationProfile};
use threesched::substrate::cluster::costs::CostModel;
use threesched::trace;

fn main() -> anyhow::Result<()> {
    let keep = std::env::var_os("THREESCHED_CALIBRATE_DIR").map(PathBuf::from);
    let dir = keep.clone().unwrap_or_else(|| {
        std::env::temp_dir().join(format!("threesched-calibrate-rt-{}", std::process::id()))
    });
    std::fs::create_dir_all(&dir)?;

    // the "real cluster": Table 4, deliberately warped (the same ground
    // truth the CI golden-model regression asserts against)
    let inj = workloads::perturbed_model();

    println!("=== 1. simulate the calibration workloads (known constants) ===\n");
    let mut files = Vec::new();
    for run in workloads::standard() {
        let (source, events) = workloads::simulate(&run, &inj, 42)?;
        let path = dir.join(format!("{}.jsonl", run.tool.name()));
        trace::write_trace(&path, &source, &events)?;
        println!(
            "  {:>8}: {} tasks at {} ranks -> {}",
            run.tool.name(),
            run.graph.len(),
            run.ranks,
            path.display()
        );
        files.push(path);
    }

    println!("\n=== 2. fit a profile from the trace files alone ===\n");
    let base = CostModel::paper();
    let mut traces = Vec::new();
    for f in &files {
        let (source, events) = trace::read_trace(f)?;
        traces.push(calibrate::classify_trace(&source, events, None)?);
    }
    let cal = calibrate::fit_traces(&traces, &base)?;
    print!("{}", calibrate::render_calibration(&cal));

    // the whole point: the loop must close on the injected constants
    let fitted = cal.profile.model();
    let rel = |a: f64, b: f64| (a - b).abs() / b.abs();
    anyhow::ensure!(
        rel(fitted.steal_rtt, inj.steal_rtt) < 0.10,
        "steal_rtt recovery: fitted {} vs injected {}",
        fitted.steal_rtt,
        inj.steal_rtt
    );
    anyhow::ensure!(
        rel(fitted.gumbel_beta_per_task, inj.gumbel_beta_per_task) < 0.10,
        "gumbel beta recovery: fitted {} vs injected {}",
        fitted.gumbel_beta_per_task,
        inj.gumbel_beta_per_task
    );
    anyhow::ensure!(
        rel(fitted.metg_pmake(1), inj.metg_pmake(1)) < 0.10,
        "pmake launch-law recovery: fitted {} vs injected {}",
        fitted.metg_pmake(1),
        inj.metg_pmake(1)
    );
    println!("recovery: every fitted parameter within 10% of the injected value");

    println!("\n=== 3. cross-validate: fitted model vs Table-4 defaults ===\n");
    let v = calibrate::validate_profile(&traces, &base, &cal.profile, 7)?;
    print!("{}", calibrate::render_validation(&v));
    anyhow::ensure!(
        v.improved(),
        "fitted profile must predict the measured traces strictly better"
    );

    let out = dir.join("profile.toml");
    cal.profile.save(&out)?;
    let loaded = CalibrationProfile::load(&out)?;
    anyhow::ensure!(loaded == cal.profile, "profile TOML round-trip must be identity");
    println!(
        "\nwrote {} (use with `threesched workflow plan --calibration ...`)",
        out.display()
    );
    if keep.is_none() {
        let _ = std::fs::remove_dir_all(&dir);
    }
    Ok(())
}
