//! Cross-validation end to end: for each canonically shaped example
//! workflow, lay the adaptive selector's *predicted* makespan next to
//! the DES-*simulated* makespan for every back-end (with relative
//! error), then run one small pipeline for real with tracing on, print
//! its Fig-5-style breakdown, and put the *measured* makespan in the
//! same table — the loop that lets the cost model be trusted (or
//! recalibrated).
//!
//! Run: `cargo run --release --example trace_compare`

use threesched::substrate::cluster::costs::CostModel;
use threesched::trace::{self, Tracer};
use threesched::workflow::{Backend, Session, TaskSpec, WorkflowGraph};

fn deep_file_chain() -> WorkflowGraph {
    let mut g = WorkflowGraph::new("md-restart-chain");
    for i in 0..24 {
        let mut t = TaskSpec::command(format!("seg{i}"), format!("simulate > seg{i}.chk"))
            .outputs(&[&format!("seg{i}.chk")])
            .est(3600.0);
        if i > 0 {
            t = t.after(&[&format!("seg{}", i - 1)]);
        }
        g.add_task(t).unwrap();
    }
    g
}

fn wide_irregular_fan() -> WorkflowGraph {
    let mut g = WorkflowGraph::new("docking-fan");
    g.add_task(TaskSpec::new("receptor-prep").est(10.0)).unwrap();
    for i in 0..300 {
        let est = 0.5 + (i % 13) as f64;
        g.add_task(
            TaskSpec::kernel(format!("dock{i}"), "atb_128", i as u64)
                .after(&["receptor-prep"])
                .est(est),
        )
        .unwrap();
    }
    g
}

fn flat_uniform_map() -> WorkflowGraph {
    let mut g = WorkflowGraph::new("frame-analysis");
    for i in 0..4096 {
        g.add_task(TaskSpec::kernel(format!("frame{i}"), "atb_256", i as u64).est(0.05))
            .unwrap();
    }
    g
}

fn main() -> anyhow::Result<()> {
    let m = CostModel::paper();

    println!("=== predicted (selector) vs simulated (DES), 864 ranks ===\n");
    for g in [deep_file_chain(), wide_irregular_fan(), flat_uniform_map()] {
        let rows = trace::compare_backends(&g, &m, 864, 42, &[])?;
        println!("{}", trace::render_comparison(&g.name, 864, &rows));
        // the whole point: on the backend the selector picks, its
        // closed-form estimate must be in the same ballpark as the DES
        let selected = rows.iter().find(|r| r.selected).expect("one selected");
        anyhow::ensure!(
            selected.rel_err_pred_vs_sim() < 1.0,
            "{}: selector predicts {:.2}s but the DES says {:.2}s on {}",
            g.name,
            selected.predicted_s,
            selected.simulated_s,
            selected.tool.name()
        );
    }

    println!("=== measured cross-validation (real traced run) ===\n");
    let mut g = WorkflowGraph::new("mini-pipeline");
    g.add_task(
        TaskSpec::command("gen", "seq 1 50 > input.txt").outputs(&["input.txt"]).est(0.01),
    )?;
    for i in 0..6 {
        g.add_task(
            TaskSpec::kernel(format!("crunch{i}"), "atb_64", i).after(&["gen"]).est(0.01),
        )?;
    }
    let dir = std::env::temp_dir()
        .join(format!("threesched-trace-compare-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let tracer = Tracer::memory();
    let summary = Session::new(&g)
        .backend(Backend::Dwork { remote: None })
        .parallelism(2)
        .dir(&dir)
        .tracer(tracer.clone())
        .run()?
        .summary;
    anyhow::ensure!(summary.all_ok(), "mini-pipeline failed: {summary:?}");
    let events = tracer.drain();
    trace::validate(&events)?;
    print!("{}", trace::TraceReport::from_events(&events).render("dwork"));

    let measured = vec![("dwork".to_string(), trace::makespan(&events))];
    let rows = trace::compare_backends(&g, &m, 2, 42, &measured)?;
    println!("\n{}", trace::render_comparison(&g.name, 2, &rows));
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
