//! Ensemble pipeline: the paper's Fig 1 workflow, end to end, with real
//! compute.
//!
//! The paper's motivating pmake use case (Ref [3]) is an ensemble docking
//! campaign: `simulate -> analyze` over many systems.  Here each
//! `simulate` runs a *real* iterated AᵀB task through the PJRT runtime
//! (via the `threesched task` CLI, i.e. a genuine subprocess launch like
//! jsrun would do), and each `analyze` summarizes the simulation output —
//! exercising rules parsing, template substitution, file-directed DAG
//! construction, node-hours priority, and the shell executor.
//!
//! Run: `cargo run --release --example ensemble_pipeline`

use threesched::coordinator::pmake::{self, Dag, SchedConfig, ShellExecutor};
use threesched::substrate::cluster::Machine;

fn main() -> anyhow::Result<()> {
    let dir = std::env::temp_dir().join(format!("threesched-ensemble-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;

    // locate our own binary to use as the task program (the paper's
    // `simulate` executable); cargo puts examples next to the main bin
    let me = std::env::current_exe()?;
    let bin = me
        .parent()
        .and_then(|p| p.parent())
        .map(|p| p.join("threesched"))
        .filter(|p| p.exists())
        .ok_or_else(|| anyhow::anyhow!("threesched binary not built (cargo build --release)"))?;
    let artifacts = threesched::runtime::default_artifacts_dir();

    // seed the campaign: one .param file per system (the paper's inputs)
    let systems = 3;
    for n in 1..=systems {
        std::fs::write(dir.join(format!("{n}.param")), format!("seed={n}\n"))?;
    }

    // Fig 1a, adapted: simulate runs the iterated-matmul artifact through
    // PJRT; analyze computes a checksum "average" of the trajectory
    let rules = pmake::parse_rules(&format!(
        r#"
simulate:
  resources: {{time: 120, nrs: 1, cpu: 42, gpu: 6}}
  inp:
    param: "{{n}}.param"
  out:
    trj: "{{n}}.trj"
  script: |
    {{mpirun}} {bin} task --artifact atb_chain_64_i16 --seed {{n}} --artifacts-dir {artifacts} --out {{out[trj]}}
analyze:
  resources: {{time: 10, nrs: 1, cpu: 1}}
  inp:
    trj: "{{n}}.trj"
  out:
    npy: "an_{{n}}.npy"
  script: |
    {{mpirun}} awk '{{{{ s += $1; c += 1 }}}} END {{{{ printf "%.6f\n", s / c }}}}' {{inp[trj]}} > {{out[npy]}}
"#,
        bin = bin.display(),
        artifacts = artifacts.display(),
    ))?;
    let targets = pmake::parse_targets(&format!(
        r#"
campaign:
  dirname: {}
  loop:
    n: "range(1,{})"
  tgt:
    npy: "an_{{n}}.npy"
"#,
        dir.display(),
        systems + 1
    ))?;

    let dag = Dag::build(
        &rules,
        &targets[0],
        &|p: &std::path::Path| p.exists(),
        &|rs| pmake::default_mpirun(rs),
    )?;
    println!(
        "ensemble campaign: {} tasks ({} simulate + {} analyze)",
        dag.tasks.len(),
        systems,
        systems
    );
    for t in &dag.tasks {
        println!(
            "  {:14} priority {:7.3} node-hours, deps {:?}",
            t.stem(),
            t.priority,
            t.deps
        );
    }

    let cfg = SchedConfig { nodes: 2, machine: Machine::summit(2), fifo: false };
    let t0 = std::time::Instant::now();
    let report = pmake::run(&dag, &ShellExecutor::default(), &cfg)?;
    println!(
        "campaign finished in {:.2}s: {} succeeded, {} failed, launch overhead {:.3}s",
        t0.elapsed().as_secs_f64(),
        report.succeeded.len(),
        report.failed.len(),
        report.total_launch_s
    );
    anyhow::ensure!(report.all_ok(), "campaign had failures");

    for n in 1..=systems {
        let avg = std::fs::read_to_string(dir.join(format!("an_{n}.npy")))?;
        println!("  system {n}: mean(|trajectory|) = {}", avg.trim());
    }

    // idempotence: a second run finds every file present -> zero tasks
    let dag2 = Dag::build(
        &rules,
        &targets[0],
        &|p: &std::path::Path| p.exists(),
        &|rs| pmake::default_mpirun(rs),
    )?;
    println!("re-run DAG size (everything up to date): {}", dag2.tasks.len());
    anyhow::ensure!(dag2.tasks.is_empty(), "rebuild should be a no-op");

    let _ = std::fs::remove_dir_all(&dir);
    println!("ensemble_pipeline OK");
    Ok(())
}
