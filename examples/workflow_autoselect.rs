//! Workflow IR + adaptive selection end to end: build three canonically
//! shaped campaigns, show what the METG-based selector says about each,
//! then execute one small pipeline on ALL three coordinators to show a
//! single graph really is portable across synchronization mechanisms.
//!
//! Run: `cargo run --release --example workflow_autoselect`

use threesched::metg::simmodels::Tool;
use threesched::substrate::cluster::costs::CostModel;
use threesched::workflow::{Backend, Session, TaskSpec, WorkflowGraph};

fn deep_file_chain() -> WorkflowGraph {
    let mut g = WorkflowGraph::new("md-restart-chain");
    for i in 0..24 {
        let mut t = TaskSpec::command(format!("seg{i}"), format!("simulate > seg{i}.chk"))
            .outputs(&[&format!("seg{i}.chk")])
            .est(3600.0); // hour-long segments: launch cost is invisible
        if i > 0 {
            t = t.after(&[&format!("seg{}", i - 1)]);
        }
        g.add_task(t).unwrap();
    }
    g
}

fn wide_irregular_fan() -> WorkflowGraph {
    let mut g = WorkflowGraph::new("docking-fan");
    g.add_task(TaskSpec::new("receptor-prep").est(10.0)).unwrap();
    for i in 0..300 {
        let est = 0.5 + (i % 13) as f64; // ligands vary wildly in cost
        g.add_task(
            TaskSpec::kernel(format!("dock{i}"), "atb_128", i as u64)
                .after(&["receptor-prep"])
                .est(est),
        )
        .unwrap();
    }
    g
}

fn flat_uniform_map() -> WorkflowGraph {
    let mut g = WorkflowGraph::new("frame-analysis");
    for i in 0..4096 {
        g.add_task(TaskSpec::kernel(format!("frame{i}"), "atb_256", i as u64).est(0.05))
            .unwrap();
    }
    g
}

fn main() -> anyhow::Result<()> {
    let m = CostModel::paper();
    println!("=== adaptive selection at the paper's 864-rank scale ===\n");
    for g in [deep_file_chain(), wide_irregular_fan(), flat_uniform_map()] {
        let plan = Session::new(&g).cost_model(m.clone()).parallelism(864).plan()?;
        println!("--- {} ---\n{}", g.name, plan.render());
    }

    println!("=== one pipeline, three executions ===\n");
    let mut g = WorkflowGraph::new("mini-pipeline");
    g.add_task(TaskSpec::command("gen", "seq 1 100 > input.txt").outputs(&["input.txt"]))?;
    g.add_task(TaskSpec::kernel("crunch", "atb_32", 1).after(&["gen"]))?;
    g.add_task(
        TaskSpec::command("wc", "wc -l < input.txt > count.txt")
            .outputs(&["count.txt"])
            .after(&["gen", "crunch"]),
    )?;
    for tool in Tool::ALL {
        let dir = std::env::temp_dir().join(format!(
            "threesched-autoselect-{}-{}",
            tool.name().replace('-', ""),
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let summary = Session::new(&g)
            .backend(Backend::from_tool(tool))
            .parallelism(2)
            .dir(&dir)
            .run()?
            .summary;
        let count = std::fs::read_to_string(dir.join("count.txt"))?;
        println!(
            "{:<8} ran {} tasks ({} failed) in {:.3}s; count.txt = {}",
            tool.name(),
            summary.tasks_run,
            summary.tasks_failed,
            summary.makespan_s,
            count.trim()
        );
        let _ = std::fs::remove_dir_all(&dir);
    }
    Ok(())
}
