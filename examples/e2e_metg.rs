//! End-to-end METG driver: the repository's headline validation run.
//!
//! Proves all layers compose on a real workload, then reproduces the
//! paper's headline numbers:
//!
//!  1. single-device baseline — measure t_kernel for the Pallas AᵀB
//!     artifacts on this host's PJRT device (the paper's 1-GPU runs);
//!  2. real weak-scaling runs — all three coordinators execute the same
//!     kernel workload at host scale (4 in-process ranks), with measured
//!     per-component breakdowns;
//!  3. measured micro-costs — our steal/complete RTT feeds the DES;
//!  4. paper-scale METG — the DES reruns the sec. 4 sweep at 6..6912
//!     ranks with both the paper's 23 us RTT and our measured RTT.
//!
//! Output is the paper-vs-measured table recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example e2e_metg`

use std::time::Instant;

use threesched::coordinator::dwork::{self, Client, TaskMsg};
use threesched::coordinator::mpilist::Context;
use threesched::coordinator::pmake;
use threesched::metg::harness::{
    measure_t_kernel, metg_sweep, render_metg, render_table4, TextTable, PAPER_RANKS,
};
use threesched::metg::Workload;
use threesched::runtime::service::RuntimeService;
use threesched::runtime::{default_artifacts_dir, fill_f32, HostBuf};
use threesched::substrate::cluster::costs::CostModel;
use threesched::substrate::cluster::Machine;

const RANKS: usize = 4;
const KERNELS_PER_RANK: u64 = 16;
const TILE: usize = 128;

fn real_dwork(h: &threesched::runtime::service::RuntimeHandle) -> anyhow::Result<(f64, f64, f64)> {
    let mut state = dwork::SchedState::new();
    for i in 0..(RANKS as u64 * KERNELS_PER_RANK) {
        state.create(TaskMsg::new(format!("k{i}"), vec![]), &[])?;
    }
    let (connector, server) = dwork::spawn_inproc(state, dwork::ServerConfig::default());
    let t0 = Instant::now();
    let stats: Vec<dwork::WorkerStats> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..RANKS)
            .map(|w| {
                let conn = connector.connect();
                let h = h.clone();
                s.spawn(move || {
                    let mut c = Client::new(Box::new(conn), format!("w{w}"));
                    let a = fill_f32(TILE * TILE, 1);
                    let b = fill_f32(TILE * TILE, 2);
                    dwork::run_worker(&mut c, 1, |_t| {
                        h.execute(
                            &format!("atb_{TILE}"),
                            vec![HostBuf::F32(a.clone()), HostBuf::F32(b.clone())],
                        )?;
                        Ok(())
                    })
                    .unwrap()
                })
            })
            .collect();
        handles.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let makespan = t0.elapsed().as_secs_f64();
    drop(connector);
    server.join().unwrap();
    let compute: f64 = stats.iter().map(|s| s.compute_s).sum();
    let comm: f64 = stats.iter().map(|s| s.comm_s).sum();
    Ok((makespan, compute, comm))
}

fn real_mpilist(h: &threesched::runtime::service::RuntimeHandle) -> anyhow::Result<(f64, f64)> {
    let h2 = h.clone();
    let t0 = Instant::now();
    let per_rank: Vec<f64> = Context::run(RANKS, move |ctx| {
        let a = fill_f32(TILE * TILE, 1);
        let b = fill_f32(TILE * TILE, 2);
        let t0 = Instant::now();
        let dfm = ctx.iterates(RANKS as u64 * KERNELS_PER_RANK).map(|_| {
            h2.execute(
                &format!("atb_{TILE}"),
                vec![HostBuf::F32(a.clone()), HostBuf::F32(b.clone())],
            )
            .map(|_| 1u64)
            .unwrap_or(0)
        });
        let done = dfm.reduce(ctx, 0u64, |x, y| x + y);
        assert_eq!(done, RANKS as u64 * KERNELS_PER_RANK);
        t0.elapsed().as_secs_f64()
    });
    let makespan = t0.elapsed().as_secs_f64();
    let spread = per_rank.iter().cloned().fold(f64::MIN, f64::max)
        - per_rank.iter().cloned().fold(f64::MAX, f64::min);
    Ok((makespan, spread))
}

fn real_pmake(bin: &std::path::Path, artifacts: &std::path::Path) -> anyhow::Result<(f64, f64)> {
    let dir = std::env::temp_dir().join(format!("threesched-e2e-pmake-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir)?;
    let rules = pmake::parse_rules(&format!(
        r#"
step:
  resources: {{time: 1, nrs: 1, cpu: 42}}
  out:
    f: "step_{{n}}.out"
  script: |
    {bin} task --artifact atb_chain_{TILE}_i16 --seed {{n}} --artifacts-dir {art} --out {{out[f]}}
"#,
        bin = bin.display(),
        art = artifacts.display(),
    ))?;
    let targets = pmake::parse_targets(&format!(
        "t:\n  dirname: {}\n  loop:\n    n: \"range(0,{RANKS})\"\n  tgt:\n    f: \"step_{{n}}.out\"\n",
        dir.display()
    ))?;
    let dag = pmake::Dag::build(
        &rules,
        &targets[0],
        &|p: &std::path::Path| p.exists(),
        &|rs| pmake::default_mpirun(rs),
    )?;
    let cfg = pmake::SchedConfig { nodes: RANKS, machine: Machine::summit(RANKS), fifo: false };
    let t0 = Instant::now();
    let report = pmake::run(&dag, &pmake::ShellExecutor::default(), &cfg)?;
    let makespan = t0.elapsed().as_secs_f64();
    anyhow::ensure!(report.all_ok(), "pmake campaign failed");
    let _ = std::fs::remove_dir_all(&dir);
    Ok((makespan, report.total_launch_s))
}

fn measure_rtt() -> anyhow::Result<f64> {
    let n = 20_000usize;
    let mut state = dwork::SchedState::new();
    for i in 0..n {
        state.create(TaskMsg::new(format!("t{i}"), vec![]), &[])?;
    }
    let (connector, server) = dwork::spawn_inproc(state, dwork::ServerConfig::default());
    let mut c = Client::new(Box::new(connector.connect()), "rtt");
    let t0 = Instant::now();
    // strict acquire(1)→report(1) keeps the two-RTT-per-task shape the
    // divisor assumes; a dependency-free farm never answers an empty
    // batch mid-drain, so AllDone is the only exit
    loop {
        let ts = match c.acquire(1)? {
            dwork::StealBatch::Tasks(ts) => ts,
            dwork::StealBatch::AllDone => break,
        };
        for t in &ts {
            c.report(&[dwork::Completion::ok(&t.name)])?;
        }
    }
    let rtt = t0.elapsed().as_secs_f64() / (2.0 * n as f64);
    drop(c);
    drop(connector);
    server.join().unwrap();
    Ok(rtt)
}

fn main() -> anyhow::Result<()> {
    println!("=== e2e_metg: end-to-end validation run ===\n");
    let artifacts = default_artifacts_dir();
    let svc = RuntimeService::start(&artifacts)?;
    let h = svc.handle();

    // 1. single-device baseline (the paper's 1-GPU runs)
    println!("[1] single-device kernel baselines (PJRT CPU, Pallas interpret-lowered):");
    let mut baselines = TextTable::new(&["artifact", "t_kernel", "GFLOP/s"]);
    let mut t128 = 0.0;
    for ts in [64usize, 128, 256] {
        let name = format!("atb_{ts}");
        let t = measure_t_kernel(&h, &name, 5)?;
        if ts == TILE {
            t128 = t;
        }
        baselines.row(vec![
            name.clone(),
            format!("{:.3}ms", t * 1e3),
            format!("{:.2}", 2.0 * (ts as f64).powi(3) / t / 1e9),
        ]);
    }
    println!("{}", baselines.render());

    // 2. real weak-scaling runs, all three coordinators, same workload
    println!(
        "[2] real coordinator runs: {RANKS} in-process ranks x {KERNELS_PER_RANK} kernels \
         (tile {TILE}, one shared PJRT device => ideal = serialized compute):"
    );
    let ideal = RANKS as f64 * KERNELS_PER_RANK as f64 * t128;
    let (dw_mk, dw_compute, dw_comm) = real_dwork(&h)?;
    let (ml_mk, ml_spread) = real_mpilist(&h)?;
    let me = std::env::current_exe()?;
    let bin = me
        .parent()
        .and_then(|p| p.parent())
        .map(|p| p.join("threesched"))
        .filter(|p| p.exists());
    let mut table = TextTable::new(&["tool", "makespan", "efficiency", "dominant overhead"]);
    table.row(vec![
        "dwork".into(),
        format!("{dw_mk:.2}s"),
        format!("{:.3}", ideal / dw_mk),
        format!("comm {:.3}s vs compute {:.3}s (aggregate)", dw_comm, dw_compute),
    ]);
    table.row(vec![
        "mpi-list".into(),
        format!("{ml_mk:.2}s"),
        format!("{:.3}", ideal / ml_mk),
        format!("rank spread {:.3}s", ml_spread),
    ]);
    match bin {
        Some(bin) => {
            let (pm_mk, pm_launch) = real_pmake(&bin, &artifacts)?;
            table.row(vec![
                "pmake".into(),
                format!("{pm_mk:.2}s"),
                format!("{:.3}", ideal / pm_mk),
                format!("process launches {pm_launch:.3}s + fresh PJRT init per step"),
            ]);
        }
        None => {
            table.row(vec![
                "pmake".into(),
                "-".into(),
                "-".into(),
                "skipped: build the threesched binary first (cargo build --release)".into(),
            ]);
        }
    }
    println!("{}", table.render());

    // 3. measured micro-costs
    let rtt = measure_rtt()?;
    println!(
        "[3] measured steal/complete RTT (in-proc): {:.1} us — paper measured 23 us\n",
        rtt * 1e6
    );

    // 4. paper-scale METG via DES, with paper RTT and with measured RTT
    println!("[4] paper-scale METG (DES at the paper's rank counts):");
    let w = Workload::paper();
    let m_paper = CostModel::paper();
    println!("{}", render_metg(&metg_sweep(&m_paper, &w, &PAPER_RANKS)));
    let m_ours = CostModel::paper().with_measured_rtt(rtt);
    println!("--- same sweep with OUR measured RTT ---");
    println!("{}", render_metg(&metg_sweep(&m_ours, &w, &PAPER_RANKS)));
    println!("{}", render_table4(&m_paper, Some(rtt)));
    println!("e2e_metg OK");
    Ok(())
}
