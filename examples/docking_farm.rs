//! Docking farm over TCP: the paper's dwork production pattern (Ref [4] —
//! "running docking and AI-based rescoring").
//!
//! A dhub server runs over real TCP with a persistent task database;
//! workers connect over sockets, pull docking tasks, execute *real*
//! matmul scoring kernels through PJRT, and dynamically insert rescoring
//! tasks for promising hits (the paper's task-insertion loop).  One
//! worker dies mid-run to exercise Exit-based fault tolerance, and the
//! run finishes with a queue Status report — the dquery view.
//!
//! Run: `cargo run --release --example docking_farm`

use threesched::coordinator::dwork::{self, Client, ServerConfig, TaskMsg};
use threesched::runtime::service::RuntimeService;
use threesched::runtime::{default_artifacts_dir, fill_f32, HostBuf};
use threesched::substrate::kvstore::KvStore;
use threesched::substrate::transport::tcp::TcpClient;

fn main() -> anyhow::Result<()> {
    let dbdir = std::env::temp_dir().join(format!("threesched-farm-db-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dbdir);

    // persistent task DB: the campaign survives a server restart
    let state = dwork::SchedState::with_store(KvStore::open(&dbdir)?);
    let (addr, _guard, server) = dwork::spawn_tcp(state, ServerConfig::default(), "127.0.0.1:0")?;
    println!("dhub listening on {addr} (db at {})", dbdir.display());

    // user client seeds the campaign: 24 docking tasks
    let ligands = 24usize;
    {
        let mut user = Client::new(Box::new(TcpClient::connect(&addr.to_string())?), "user");
        for i in 0..ligands {
            user.create(TaskMsg::new(format!("dock-{i:03}"), vec![i as u8]), &[])?;
        }
        let st = user.status()?;
        println!("seeded {} docking tasks", st.total);
    }

    let svc = RuntimeService::start(&default_artifacts_dir())?;
    let h = svc.handle();
    h.warm(&["atb_64"])?;

    let t0 = std::time::Instant::now();
    let stats: Vec<(String, u64, u64)> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for w in 0..3usize {
            let addr = addr.to_string();
            let h = h.clone();
            handles.push(s.spawn(move || {
                let name = format!("worker-{w}");
                let conn = TcpClient::connect(&addr).unwrap();
                let mut c = Client::new(Box::new(conn), name.clone());
                // second connection for dynamic task creation from inside
                // the execution callback
                let mut creator =
                    Client::new(Box::new(TcpClient::connect(&addr).unwrap()), format!("{name}-ins"));
                let mut ran = 0u64;
                let mut inserted = 0u64;
                let stats = dwork::run_worker(&mut c, 1, |t| {
                    // "dock": score the ligand with a real AᵀB kernel
                    let seed = *t.body.first().unwrap_or(&0) as u64;
                    let a = fill_f32(64 * 64, seed * 2 + 1);
                    let b = fill_f32(64 * 64, seed * 2 + 2);
                    let (outs, _) =
                        h.execute("atb_64", vec![HostBuf::F32(a), HostBuf::F32(b)])?;
                    let score = outs[0].as_f32()?[0];
                    ran += 1;
                    // promising docks get an AI-rescoring pass (dynamic
                    // insertion, the paper's "append" pattern)
                    if t.name.starts_with("dock-") && score > 0.0 {
                        let rescore = format!("rescore-{}", &t.name[5..]);
                        if creator.create(TaskMsg::new(rescore, t.body.clone()), &[]).is_ok() {
                            inserted += 1;
                        }
                    }
                    // worker-2 "crashes" early to exercise fault tolerance
                    if w == 2 && ran == 3 {
                        anyhow::bail!("injected node failure")
                    }
                    Ok(())
                });
                match stats {
                    Ok(st) => (name, st.tasks_run, inserted),
                    Err(_) => {
                        // tell the server we're gone so our tasks requeue
                        let _ = c.exit();
                        (name, ran, inserted)
                    }
                }
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let mut total_ran = 0;
    let mut total_inserted = 0;
    for (name, ran, inserted) in &stats {
        println!("  {name}: ran {ran} tasks, inserted {inserted} rescoring tasks");
        total_ran += ran;
        total_inserted += inserted;
    }
    println!(
        "farm drained in {:.2}s: {} executed ({} docking + {} dynamically inserted)",
        t0.elapsed().as_secs_f64(),
        total_ran,
        ligands,
        total_inserted
    );

    // dquery-style final status
    {
        let mut q = Client::new(Box::new(TcpClient::connect(&addr.to_string())?), "dquery");
        let st = q.status()?;
        println!(
            "final status: total={} completed={} errored={} ready={} waiting={}",
            st.total, st.completed, st.errored, st.ready, st.waiting
        );
        q.save()?; // snapshot the campaign database
        anyhow::ensure!(st.completed + st.errored == st.total, "queue must be drained");
        // one task errored (the injected crash marks its task failed only
        // if it was mid-completion; our injected failure reports the task
        // as errored via Complete(success=false))
    }
    drop(server);
    let _ = std::fs::remove_dir_all(&dbdir);
    println!("docking_farm OK");
    Ok(())
}
