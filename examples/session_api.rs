//! The unified execution API end to end: ONE builder (`workflow::Session`)
//! plans, lowers, and runs the same graph on every back-end, and returns
//! ONE typed outcome (`RunOutcome`) carrying the plan that chose the
//! coordinator plus per-backend detail the old `RunSummary`-only entry
//! points threw away.
//!
//! Run: `cargo run --release --example session_api`

use threesched::workflow::{
    Backend, BackendDetail, Lowered, Session, TaskSpec, WorkflowGraph,
};

fn pipeline() -> anyhow::Result<WorkflowGraph> {
    let mut g = WorkflowGraph::new("session-demo");
    g.add_task(TaskSpec::command("gen", "seq 1 100 > input.txt").outputs(&["input.txt"]))?;
    for i in 0..4 {
        g.add_task(
            TaskSpec::kernel(format!("crunch{i}"), "atb_32", i).after(&["gen"]).est(0.01),
        )?;
    }
    g.add_task(
        TaskSpec::command("wc", "wc -l < input.txt > count.txt")
            .outputs(&["count.txt"])
            .after(&["gen", "crunch0", "crunch1", "crunch2", "crunch3"]),
    )?;
    Ok(g)
}

fn main() -> anyhow::Result<()> {
    let g = pipeline()?;

    println!("=== 1. plan: the decision, without executing ===\n");
    let plan = Session::new(&g).parallelism(4).plan()?;
    print!("{}", plan.render());
    println!();

    println!("=== 2. lower: the planned coordinator's input format ===\n");
    match Session::new(&g).backend(Backend::Dwork { remote: None }).lower()? {
        Lowered::Dwork(tasks) => {
            println!("dwork task list: {} creates in topological order", tasks.len())
        }
        other => anyhow::bail!("expected the dwork lowering, got {other:?}"),
    }
    println!();

    println!("=== 3. run: same builder, every backend, typed detail ===\n");
    for backend in [
        Backend::Pmake,
        Backend::Dwork { remote: None },
        Backend::MpiList,
    ] {
        let dir = std::env::temp_dir()
            .join(format!("threesched-session-demo-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let outcome = Session::new(&g).backend(backend).parallelism(2).dir(&dir).run()?;
        anyhow::ensure!(outcome.all_ok(), "{:?}", outcome.summary);
        let detail = match &outcome.detail {
            BackendDetail::Pmake { reports } => {
                format!("{} target report(s)", reports.len())
            }
            BackendDetail::Dwork { server } => format!(
                "hub drained: {} completed / {} errored",
                server.completed, server.errored
            ),
            BackendDetail::DworkRemote { server, .. } => {
                format!("remote hub: {} completed", server.completed)
            }
            BackendDetail::MpiList { ranks } => format!("{} rank(s) reported", ranks.len()),
        };
        println!(
            "{:<8} ran {} tasks in {:.3}s — {}",
            outcome.summary.coordinator.name(),
            outcome.summary.tasks_run,
            outcome.summary.makespan_s,
            detail
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    println!("\n=== 4. auto: selection verdict travels with the outcome ===\n");
    let dir = std::env::temp_dir()
        .join(format!("threesched-session-demo-auto-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let outcome = Session::new(&g).backend(Backend::Auto).parallelism(2).dir(&dir).run()?;
    let rec = outcome.plan.recommendation.as_ref().expect("auto carries the verdict");
    println!(
        "selector picked {} ({} assessed); run confirmed with {} tasks",
        rec.choice.name(),
        rec.assessments.len(),
        outcome.summary.tasks_run
    );
    anyhow::ensure!(outcome.all_ok(), "{:?}", outcome.summary);
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
