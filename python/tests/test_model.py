"""L2 shape/semantics tests for the task registry and aot lowering."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


def test_registry_contents():
    reg = model.registry()
    for ts in (64, 128, 256, 512):
        assert f"atb_{ts}" in reg
        assert f"atb_chain_{ts}_i256" in reg
    assert "colstats_4096x8" in reg
    assert "hist2d_4096" in reg


def test_registry_flops():
    reg = model.registry()
    _, _, flops = reg["atb_256"]
    assert flops == 2.0 * 256**3
    _, _, cflops = reg["atb_chain_256_i256"]
    assert cflops == 256 * flops


def test_atb_task_matches_ref():
    a, b = rand((128, 128), 0), rand((128, 128), 1)
    (got,) = model.atb_task(a, b)
    np.testing.assert_allclose(got, ref.atb(a, b), rtol=1e-4, atol=1e-4)


def test_atb_chain_task_matches_ref():
    a, x0 = rand((64, 64), 2), rand((64, 64), 3)
    (got,) = model.atb_chain_task(a, x0, iters=8)
    want = ref.atb_chain(a, x0, 8)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_colstats_task():
    x = rand((4096, 8), 4)
    (got,) = model.colstats_task(x)
    assert got.shape == (4, 8)
    np.testing.assert_allclose(got[0], np.min(np.asarray(x), 0), rtol=1e-5)
    np.testing.assert_allclose(got[2], np.mean(np.asarray(x), 0), rtol=1e-4, atol=1e-5)


def test_hist2d_task_mass():
    xy = rand((4096, 2), 5)
    lo = jnp.asarray(np.array([-6.0, -6.0], np.float32))
    hi = jnp.asarray(np.array([6.0, 6.0], np.float32))
    (h,) = model.hist2d_task(xy, lo, hi, bins_x=301, bins_y=201)
    assert h.shape == (301, 201)
    assert float(jnp.sum(h)) == 4096.0


def test_score_gen_deterministic():
    (x1,) = model.score_gen_task(jnp.asarray([7], jnp.int32), n=64, d=4)
    (x2,) = model.score_gen_task(jnp.asarray([7], jnp.int32), n=64, d=4)
    (x3,) = model.score_gen_task(jnp.asarray([8], jnp.int32), n=64, d=4)
    np.testing.assert_array_equal(x1, x2)
    assert np.any(np.asarray(x1) != np.asarray(x3))


def test_spell():
    assert aot.spell(model.f32(256, 256)) == "f32[256,256]"
    assert aot.spell(model.i32(1)) == "i32[1]"


def test_lowering_one_artifact(tmp_path):
    """End-to-end lowering of one small artifact produces parseable HLO."""
    import functools

    fn = model.atb_task
    lowered = jax.jit(fn).lower(model.f32(64, 64), model.f32(64, 64))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[64,64]" in text


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.tsv")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_consistent():
    root = os.path.join(os.path.dirname(__file__), "../../artifacts")
    with open(os.path.join(root, "manifest.tsv")) as f:
        rows = [line.strip().split("\t") for line in f if line.strip()]
    names = {r[0] for r in rows}
    assert names == set(model.registry().keys())
    for name, fname, ins, outs, flops in rows:
        assert os.path.exists(os.path.join(root, fname)), fname
        assert float(flops) >= 0.0
