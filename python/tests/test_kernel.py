"""L1 correctness: Pallas AᵀB kernel vs the pure-jnp oracle.

This is the core correctness signal for the compute layer — everything the
Rust coordinators execute goes through this kernel.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, ref

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32))


# ---------------------------------------------------------------- unit tests


@pytest.mark.parametrize("m,n,k", [(8, 8, 8), (64, 64, 64), (128, 128, 128), (256, 256, 256)])
def test_atb_square(m, n, k):
    a, b = rand((k, m), 1), rand((k, n), 2)
    np.testing.assert_allclose(matmul.atb(a, b), ref.atb(a, b), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize(
    "m,n,k",
    [(64, 128, 256), (128, 64, 32), (256, 8, 64), (8, 256, 128), (512, 128, 64)],
)
def test_atb_rect(m, n, k):
    a, b = rand((k, m), 3), rand((k, n), 4)
    np.testing.assert_allclose(matmul.atb(a, b), ref.atb(a, b), rtol=1e-4, atol=1e-4)


def test_atb_multiblock_accumulation():
    """Contraction split across >1 k-blocks must accumulate, not overwrite."""
    a, b = rand((512, 64), 5), rand((512, 64), 6)
    got = matmul.atb(a, b, bm=64, bn=64, bk=128)  # 4 k-steps
    np.testing.assert_allclose(got, ref.atb(a, b), rtol=1e-4, atol=1e-4)


def test_atb_explicit_blocks_equal_auto():
    a, b = rand((128, 128), 7), rand((128, 128), 8)
    auto = matmul.atb(a, b)
    man = matmul.atb(a, b, bm=32, bn=64, bk=16)
    np.testing.assert_allclose(auto, man, rtol=1e-4, atol=1e-4)


def test_atb_identity():
    eye = jnp.eye(64, dtype=jnp.float32)
    b = rand((64, 64), 9)
    np.testing.assert_allclose(matmul.atb(eye, b), b, rtol=1e-5, atol=1e-5)


def test_atb_zeros():
    a = jnp.zeros((64, 32), jnp.float32)
    b = rand((64, 16), 10)
    assert not np.any(np.asarray(matmul.atb(a, b)))


def test_pick_block():
    assert matmul.pick_block(256) == 128
    assert matmul.pick_block(64) == 64
    assert matmul.pick_block(300) == 100  # largest divisor <= 128
    assert matmul.pick_block(7) == 7
    assert matmul.pick_block(130) == 65


def test_vmem_budget_default_blocks():
    """Default 128-blocks must fit comfortably in a 16 MiB VMEM."""
    assert matmul.vmem_bytes(128, 128, 128) == 3 * 128 * 128 * 4  # 192 KiB
    assert matmul.vmem_bytes(128, 128, 128) < 16 * 2**20 / 8


def test_chain_matches_ref():
    a, x0 = rand((64, 64), 11), rand((64, 64), 12)
    got = ref.atb_chain(a, x0, 16)
    # explicit python loop oracle
    x = x0
    for _ in range(16):
        y = np.asarray(ref.atb(a, x))
        x = jnp.asarray(y / max(np.max(np.abs(y)), 1e-30))
    np.testing.assert_allclose(got, x, rtol=1e-4, atol=1e-4)


def test_chain_is_bounded():
    a, x0 = rand((64, 64), 13), rand((64, 64), 14)
    out = ref.atb_chain(a, x0, 64)
    assert np.max(np.abs(np.asarray(out))) <= 1.0 + 1e-5


# ----------------------------------------------------------- hypothesis sweep


@settings(max_examples=25, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32, 64, 128]),
    n=st.sampled_from([8, 16, 32, 64, 128]),
    k=st.sampled_from([8, 16, 32, 64, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_atb_hypothesis_shapes(m, n, k, seed):
    a, b = rand((k, m), seed), rand((k, n), seed + 1)
    np.testing.assert_allclose(matmul.atb(a, b), ref.atb(a, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    bm=st.sampled_from([8, 16, 32]),
    bn=st.sampled_from([8, 16, 32]),
    bk=st.sampled_from([8, 16, 32, 64]),
    seed=st.integers(0, 2**16),
)
def test_atb_hypothesis_blocks(bm, bn, bk, seed):
    """Any dividing block choice yields the same numbers."""
    m = n = 64
    k = 128
    a, b = rand((k, m), seed), rand((k, n), seed + 1)
    got = matmul.atb(a, b, bm=bm, bn=bn, bk=bk)
    np.testing.assert_allclose(got, ref.atb(a, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    vals=st.lists(
        st.tuples(st.floats(-10, 10), st.floats(-10, 10)), min_size=1, max_size=100
    )
)
def test_hist2d_conserves_mass(vals):
    xy = jnp.asarray(np.array(vals, dtype=np.float32))
    lo = jnp.asarray(np.array([-10.0, -10.0], np.float32))
    hi = jnp.asarray(np.array([10.0, 10.0], np.float32))
    h = ref.hist2d(xy, lo, hi, 31, 21)
    assert h.shape == (31, 21)
    assert float(jnp.sum(h)) == len(vals)  # every sample lands in a bin
