"""AOT bridge: lower every L2 task variant to HLO text in artifacts/.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the Rust ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids, so text
round-trips cleanly.  See /opt/xla-example/README.md.

Besides the ``.hlo.txt`` files this writes ``artifacts/manifest.tsv``:

    name <TAB> file <TAB> in0;in1;... <TAB> out0;... <TAB> flops

with shapes spelled ``f32[256,256]``.  The Rust runtime
(``rust/src/runtime/registry.rs``) discovers artifacts through this
manifest, so Python and Rust never need to agree on shapes in code.

Run via ``make artifacts`` (no-op when inputs are unchanged).
"""

import argparse
import hashlib
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spell(s) -> str:
    """ShapeDtypeStruct -> manifest spelling, e.g. f32[256,256]."""
    names = {"float32": "f32", "int32": "i32", "uint32": "u32"}
    d = names.get(s.dtype.name, s.dtype.name)
    return f"{d}[{','.join(str(x) for x in s.shape)}]"


def lower_all(out_dir: str, verbose: bool = True) -> None:
    os.makedirs(out_dir, exist_ok=True)
    reg = model.registry()
    rows = []
    for name, (fn, example_args, flops) in sorted(reg.items()):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(fn, *example_args)
        ins = ";".join(spell(a) for a in example_args)
        outs = ";".join(spell(o) for o in out_shapes)
        rows.append(f"{name}\t{fname}\t{ins}\t{outs}\t{flops:.0f}")
        if verbose:
            digest = hashlib.sha256(text.encode()).hexdigest()[:8]
            print(f"  {name:24s} {len(text):>9d}B sha={digest} in={ins} out={outs}")
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write("\n".join(rows) + "\n")
    if verbose:
        print(f"wrote {len(rows)} artifacts + manifest.tsv to {out_dir}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="../artifacts", help="output directory")
    p.add_argument("-q", "--quiet", action="store_true")
    args = p.parse_args()
    lower_all(args.out, verbose=not args.quiet)


if __name__ == "__main__":
    sys.exit(main())
