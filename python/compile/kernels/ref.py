"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the ground truth that pytest + hypothesis check the Pallas
implementations against. They are also what the paper's kernel *is*:
a single-precision ``AᵀB`` multiplication (cublas-sgemm in the paper,
sec. 3), iterated 256x per task for pmake/dwork.
"""

import jax.numpy as jnp
from jax import lax


def atb(a, b):
    """Reference AᵀB: ``a`` is (K, M), ``b`` is (K, N) -> (M, N) f32.

    Matches the paper's wavefunction-overlap building block S = psi^dag psi.
    """
    return jnp.dot(a.T, b, preferred_element_type=jnp.float32)


def atb_chain(a, x0, iters):
    """Reference iterated task: ``iters`` dependent AᵀB multiplications.

    The paper defines one pmake/dwork task as 256 iterations of the matmul
    kernel (sec. 3).  A data-dependent chain (x_{i+1} = normalize(Aᵀ x_i))
    keeps XLA from hoisting the work out of the loop; the normalization
    prevents overflow so the chain is numerically stable for any length.
    """

    def body(_, x):
        y = jnp.dot(a.T, x, preferred_element_type=jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(y)), 1e-30)
        return y / scale

    return lax.fori_loop(0, iters, body, x0)


def colstats(x):
    """Reference per-column statistics: stack of [min, max, mean, var].

    This is the mpi-list production snippet's ``stat`` step (paper Fig 3):
    each rank computes summary statistics of its local dataframe shard.
    """
    return jnp.stack(
        [
            jnp.min(x, axis=0),
            jnp.max(x, axis=0),
            jnp.mean(x, axis=0),
            jnp.var(x, axis=0),
        ]
    )


def hist2d(xy, lo, hi, bins_x, bins_y):
    """Reference 2-D histogram with fixed bounds.

    The mpi-list production snippet (paper Fig 3) histograms 'score' vs
    'r3' columns into a 301x201 grid; each rank histograms its local shard
    and the grids are summed with an MPI reduce.  ``xy`` is (n, 2); ``lo``
    and ``hi`` are (2,) bounds.  Returns (bins_x, bins_y) f32 counts.
    """
    span = jnp.maximum(hi - lo, 1e-30)
    ix = jnp.clip(((xy[:, 0] - lo[0]) / span[0] * bins_x).astype(jnp.int32), 0, bins_x - 1)
    iy = jnp.clip(((xy[:, 1] - lo[1]) / span[1] * bins_y).astype(jnp.int32), 0, bins_y - 1)
    flat = ix * bins_y + iy
    counts = jnp.zeros((bins_x * bins_y,), jnp.float32).at[flat].add(1.0)
    return counts.reshape(bins_x, bins_y)
