"""L1 Pallas kernel: block-tiled AᵀB single-precision matmul.

TPU-minded adaptation of the paper's cublas-sgemm kernel (see DESIGN.md
§Hardware-Adaptation): instead of CUDA threadblocks + shared memory, the
HBM<->VMEM schedule is expressed with a (M/bm, N/bn, K/bk) grid and
BlockSpecs.  The contraction dimension k is the *last* grid axis, so it is
the innermost loop: the (bm, bn) output block stays resident in VMEM as an
accumulator while (bk, bm) / (bk, bn) input tiles stream through.

AᵀB is computed without materializing Aᵀ: the A BlockSpec indexes A by
(k, i), i.e. A is read in its natural (K, M) layout and only the small
VMEM-resident tile is transposed when it is fed to the MXU.

``interpret=True`` is mandatory here: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and interpret mode traces the grid into plain HLO
(while-loop + dynamic-slice) that compiles and runs anywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _atb_kernel(a_ref, b_ref, o_ref):
    """One grid step: o[i,j] += a[k,i]ᵀ @ b[k,j] (init at k == 0)."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a_tile = a_ref[...]  # (bk, bm) — natural layout, transpose in-VMEM
    b_tile = b_ref[...]  # (bk, bn)
    o_ref[...] += jax.lax.dot_general(
        a_tile,
        b_tile,
        # contract the k (axis 0) of both tiles: (bk,bm) x (bk,bn) -> (bm,bn)
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def pick_block(dim, target=128):
    """Largest divisor of ``dim`` that is <= target (MXU-friendly 128).

    The MXU is a 128x128 systolic array; blocks of 128 give full occupancy
    for f32 (8 sublane passes).  For small or odd sizes we fall back to the
    largest divisor so the grid always tiles the array exactly.
    """
    if dim <= target:
        return dim
    for cand in range(target, 0, -1):
        if dim % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def atb(a, b, bm=None, bn=None, bk=None):
    """Pallas AᵀB: ``a`` (K, M) f32, ``b`` (K, N) f32 -> (M, N) f32."""
    k_dim, m = a.shape
    k2, n = b.shape
    assert k_dim == k2, f"contraction mismatch: {a.shape} vs {b.shape}"
    bm = bm or pick_block(m)
    bn = bn or pick_block(n)
    bk = bk or pick_block(k_dim)
    assert m % bm == 0 and n % bn == 0 and k_dim % bk == 0, (
        f"blocks ({bm},{bn},{bk}) must divide dims ({m},{n},{k_dim})"
    )
    grid = (m // bm, n // bn, k_dim // bk)
    return pl.pallas_call(
        _atb_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bm), lambda i, j, k: (k, i)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


def vmem_bytes(bm, bn, bk, dtype_bytes=4):
    """VMEM footprint of one grid step (A tile + B tile + accumulator).

    Used by the perf notes in DESIGN.md: must stay well under the ~16 MiB
    of VMEM per TPU core for the double-buffered pipeline to fit.
    """
    return (bk * bm + bk * bn + bm * bn) * dtype_bytes
