"""L2: the task workloads of the paper's evaluation, as jitted jax fns.

Each function here is one *task body* that the Rust coordinators execute
through PJRT.  They call the L1 Pallas kernel (kernels/matmul.py) so that
the kernel lowers into the same HLO module; ``aot.py`` lowers each variant
once to HLO text in ``artifacts/``.

Paper mapping (sec. 3, Evaluation Method):
  * ``atb_task``       — one cublas-sgemm-equivalent kernel execution
                         (the mpi-list workload runs 1024 of these per rank
                         inside a map; Rust loops over the executable).
  * ``atb_chain_task`` — one pmake/dwork task = ``iters`` dependent kernel
                         executions (paper: 256 iterations per task).
  * ``colstats_task``  — the Fig 3 'stat' step for mpi-list.
  * ``hist2d_task``    — the Fig 3 2-D histogram step for mpi-list.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import matmul, ref


def atb_task(a, b):
    """One AᵀB kernel execution via the Pallas kernel."""
    return (matmul.atb(a, b),)


def atb_chain_task(a, x0, *, iters):
    """``iters`` dependent AᵀB kernel executions (one scheduler task).

    A single fused executable: the loop is a lax.fori_loop in HLO, so the
    Rust hot path dispatches the whole 256-iteration task with ONE PJRT
    execute call — no Python, no per-iteration dispatch (DESIGN.md §Perf L2).
    """

    def body(_, x):
        y = matmul.atb(a, x)
        scale = jnp.maximum(jnp.max(jnp.abs(y)), 1e-30)
        return y / scale

    return (lax.fori_loop(0, iters, body, x0),)


def colstats_task(x):
    """Per-column [min, max, mean, var] for one mpi-list shard."""
    return (ref.colstats(x),)


def hist2d_task(xy, lo, hi, *, bins_x, bins_y):
    """Fixed-bounds 2-D histogram of one mpi-list shard."""
    return (ref.hist2d(xy, lo, hi, bins_x, bins_y),)


def score_gen_task(seed_arr, *, n, d):
    """Synthetic 'docking score' generator for the examples.

    Stands in for reading the paper's parquet dataset (repro band: data is
    unavailable): deterministic pseudo-random (n, d) score table derived
    from a scalar seed.  Column 0 plays 'score', column 1 plays 'r3'.
    """
    key = jax.random.PRNGKey(0)
    key = jax.random.fold_in(key, seed_arr[0])
    x = jax.random.normal(key, (n, d), jnp.float32)
    # give columns distinct, correlated scales so the 2-D histogram has shape
    x = x.at[:, 1].set(0.5 * x[:, 0] + 0.5 * x[:, 1])
    return (x,)


# ---------------------------------------------------------------------------
# Artifact registry: name -> (fn, example args).  aot.py lowers each entry.
# ---------------------------------------------------------------------------


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def registry(tile_sizes=(64, 128, 256, 512), chain_iters=(16, 256)):
    """Build the artifact registry.

    Keyed by artifact name; value is (jittable fn, example_args, flops).
    flops is the useful-work count per execution, used by the Fig 4
    efficiency harness (2*M*N*K per AᵀB).
    """
    reg = {}
    for ts in tile_sizes:
        reg[f"atb_{ts}"] = (
            atb_task,
            (f32(ts, ts), f32(ts, ts)),
            2.0 * ts * ts * ts,
        )
    for ts in tile_sizes:
        for it in chain_iters:
            reg[f"atb_chain_{ts}_i{it}"] = (
                functools.partial(atb_chain_task, iters=it),
                (f32(ts, ts), f32(ts, ts)),
                2.0 * ts * ts * ts * it,
            )
    reg["colstats_4096x8"] = (colstats_task, (f32(4096, 8),), 4.0 * 4096 * 8)
    reg["hist2d_4096"] = (
        functools.partial(hist2d_task, bins_x=301, bins_y=201),
        (f32(4096, 2), f32(2), f32(2)),
        10.0 * 4096,
    )
    reg["score_gen_4096x8"] = (
        functools.partial(score_gen_task, n=4096, d=8),
        (i32(1),),
        0.0,
    )
    return reg
