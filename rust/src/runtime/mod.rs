//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The request path is pure Rust: `python -m compile.aot` ran once at
//! build time and wrote `artifacts/*.hlo.txt` + `manifest.tsv`; here we
//! compile each module on the PJRT CPU client the first time it is used
//! and cache the executable.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based (not `Send`),
//! so [`service::RuntimeService`] runs the client on a dedicated thread
//! and hands out cheap clonable [`service::RuntimeHandle`]s — the same
//! shape as the paper's "1 MPI rank per GPU" device queue, with the
//! service thread playing the device.
//!
//! Feature gating: the `xla` crate (and the native XLA toolchain behind
//! it) is only required with `--features pjrt`.  The default build uses a
//! pure-Rust interpreter for the `atb_*` matmul artifacts (same manifest,
//! same [`HostBuf`] contract, same numerics as [`host_atb`]), so every
//! coordinator, test and example works in a hermetic offline build.

pub mod registry;
pub mod service;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use registry::{ArtifactSpec, Dtype, Manifest};

/// Typed host buffer crossing the runtime boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum HostBuf {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl HostBuf {
    pub fn len(&self) -> usize {
        match self {
            HostBuf::F32(v) => v.len(),
            HostBuf::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostBuf::F32(v) => Ok(v),
            _ => bail!("expected f32 buffer"),
        }
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            HostBuf::F32(_) => Dtype::F32,
            HostBuf::I32(_) => Dtype::I32,
        }
    }
}

/// The single-threaded runtime: PJRT client + compiled-executable cache.
#[cfg(feature = "pjrt")]
pub struct Runtime {
    client: xla::PjRtClient,
    manifest: Manifest,
    root: PathBuf,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// executions per artifact (perf accounting)
    pub exec_counts: HashMap<String, u64>,
}

#[cfg(feature = "pjrt")]
impl Runtime {
    /// Open the artifact directory (must contain manifest.tsv).
    pub fn open(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.tsv"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        Ok(Runtime {
            client,
            manifest,
            root: artifacts_dir.to_path_buf(),
            cache: HashMap::new(),
            exec_counts: HashMap::new(),
        })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Runtime::open(&default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (run `make artifacts`?)"))
    }

    /// Compile (once) and return the cached executable.
    pub fn load(&mut self, name: &str) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(name) {
            let spec = self.spec(name)?.clone();
            let path = self.root.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {path:?}: {e:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
            self.cache.insert(name.to_string(), exe);
        }
        Ok(&self.cache[name])
    }

    /// Execute `name` on host buffers; returns the output buffers.
    ///
    /// Input buffers are validated against the manifest (arity, dtype,
    /// element count) before they touch PJRT, so shape bugs surface as
    /// clean errors rather than C++ aborts.
    pub fn execute(&mut self, name: &str, inputs: &[HostBuf]) -> Result<Vec<HostBuf>> {
        let spec = self.spec(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (buf, shape)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if buf.dtype() != shape.dtype {
                bail!("{name}: input {i} dtype mismatch ({:?} vs {:?})", buf.dtype(), shape.dtype);
            }
            if buf.len() != shape.elems() {
                bail!(
                    "{name}: input {i} has {} elements, shape {} wants {}",
                    buf.len(),
                    shape,
                    shape.elems()
                );
            }
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, shape) in inputs.iter().zip(&spec.inputs) {
            let dims: Vec<i64> = shape.dims.iter().map(|&d| d as i64).collect();
            let lit = match buf {
                HostBuf::F32(v) => xla::Literal::vec1(v),
                HostBuf::I32(v) => xla::Literal::vec1(v),
            };
            let lit = lit
                .reshape(&dims)
                .map_err(|e| anyhow!("reshaping input for {name}: {e:?}"))?;
            literals.push(lit);
        }
        let exe = self.load(name)?;
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let mut out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unpack the result tuple
        let n_outs = spec.outputs.len();
        let mut elements = out_lit
            .decompose_tuple()
            .map_err(|e| anyhow!("decomposing result tuple of {name}: {e:?}"))?;
        if elements.len() != n_outs {
            bail!("{name}: manifest promises {} outputs, tuple has {}", n_outs, elements.len());
        }
        let mut outs = Vec::with_capacity(n_outs);
        for (lit, shape) in elements.iter_mut().zip(&spec.outputs) {
            let buf = match shape.dtype {
                Dtype::F32 => HostBuf::F32(
                    lit.to_vec::<f32>()
                        .map_err(|e| anyhow!("reading f32 output of {name}: {e:?}"))?,
                ),
                Dtype::I32 => HostBuf::I32(
                    lit.to_vec::<i32>()
                        .map_err(|e| anyhow!("reading i32 output of {name}: {e:?}"))?,
                ),
            };
            outs.push(buf);
        }
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        Ok(outs)
    }
}

/// Pure-Rust fallback runtime (no `pjrt` feature): interprets the `atb_N`
/// artifacts with [`host_atb`] so the full scheduler stack runs offline.
/// Same manifest contract, same validation, same output shapes.
#[cfg(not(feature = "pjrt"))]
pub struct Runtime {
    manifest: Manifest,
    /// executions per artifact (perf accounting)
    pub exec_counts: HashMap<String, u64>,
}

#[cfg(not(feature = "pjrt"))]
impl Runtime {
    /// Open the artifact directory (must contain manifest.tsv).
    pub fn open(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.tsv"))?;
        Ok(Runtime { manifest, exec_counts: HashMap::new() })
    }

    /// Default artifact location relative to the repo root.
    pub fn open_default() -> Result<Self> {
        Runtime::open(&default_artifacts_dir())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn spec(&self, name: &str) -> Result<&ArtifactSpec> {
        self.manifest
            .get(name)
            .ok_or_else(|| anyhow!("unknown artifact {name:?} (run `make artifacts`?)"))
    }

    /// No compilation step in interpreter mode; this only checks the
    /// artifact is known and interpretable (parity with the PJRT `load`).
    pub fn load(&mut self, name: &str) -> Result<()> {
        self.spec(name)?;
        atb_tile(name)?;
        Ok(())
    }

    /// Execute `name` on host buffers; returns the output buffers.
    pub fn execute(&mut self, name: &str, inputs: &[HostBuf]) -> Result<Vec<HostBuf>> {
        let spec = self.spec(name)?.clone();
        if inputs.len() != spec.inputs.len() {
            bail!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            );
        }
        for (i, (buf, shape)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if buf.dtype() != shape.dtype {
                bail!("{name}: input {i} dtype mismatch ({:?} vs {:?})", buf.dtype(), shape.dtype);
            }
            if buf.len() != shape.elems() {
                bail!(
                    "{name}: input {i} has {} elements, shape {} wants {}",
                    buf.len(),
                    shape,
                    shape.elems()
                );
            }
        }
        let ts = atb_tile(name)?;
        // the manifest is the shape authority: refuse to compute if it
        // disagrees with the name the interpreter dispatches on
        if spec.inputs.len() != 2
            || spec.inputs.iter().any(|s| s.elems() != ts * ts)
            || spec.outputs.len() != 1
            || spec.outputs[0].elems() != ts * ts
        {
            bail!(
                "{name}: manifest shapes do not match an atb_{ts} kernel \
                 (interpreter mode cannot run it)"
            );
        }
        let a = inputs[0].as_f32()?;
        let b = inputs[1].as_f32()?;
        let out = host_atb(a, b, ts, ts, ts);
        *self.exec_counts.entry(name.to_string()).or_insert(0) += 1;
        Ok(vec![HostBuf::F32(out)])
    }
}

/// Largest tile the in-process interpreters accept: 8192² f32 is 256 MB
/// per operand, already generous for one host task.
pub const MAX_ATB_TILE: usize = 8192;

/// Tile size of a plain `atb_{N}` artifact; errors for artifacts the
/// pure-Rust interpreters cannot emulate (chained/fused variants need
/// real PJRT) and for tile sizes whose buffers would not fit a sane
/// host task.  Shared by the interpreter-mode [`Runtime`] and the
/// workflow kernel driver.
pub fn atb_tile(name: &str) -> Result<usize> {
    let ts = name
        .strip_prefix("atb_")
        .and_then(|s| s.parse::<usize>().ok())
        .ok_or_else(|| {
            anyhow!("artifact {name:?} is not a plain atb_N kernel (interpreter only runs atb_N)")
        })?;
    if ts == 0 || ts > MAX_ATB_TILE {
        bail!("artifact {name:?}: interpreter supports tile sizes 1..={MAX_ATB_TILE}");
    }
    Ok(ts)
}

/// Locate `artifacts/` by walking up from the current directory (so tests,
/// benches and examples work from any workspace subdirectory).
pub fn default_artifacts_dir() -> PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.tsv").exists() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

/// Deterministic pseudo-random f32 test data in [-1, 1) — the workload
/// generator's matrix filler (cheap, reproducible across runs).
pub fn fill_f32(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = crate::substrate::rng::Rng::new(seed);
    (0..n).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect()
}

/// Reference AᵀB on the host — the Rust-side oracle used by the runtime
/// integration tests (independent of the Python oracle).
pub fn host_atb(a: &[f32], b: &[f32], k: usize, m: usize, n: usize) -> Vec<f32> {
    assert_eq!(a.len(), k * m);
    assert_eq!(b.len(), k * n);
    let mut out = vec![0f32; m * n];
    for kk in 0..k {
        for i in 0..m {
            let av = a[kk * m + i];
            if av == 0.0 {
                continue;
            }
            for j in 0..n {
                out[i * n + j] += av * b[kk * n + j];
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_deterministic() {
        assert_eq!(fill_f32(16, 7), fill_f32(16, 7));
        assert_ne!(fill_f32(16, 7), fill_f32(16, 8));
        assert!(fill_f32(1000, 1).iter().all(|x| (-1.0..1.0).contains(x)));
    }

    #[test]
    fn host_atb_identity() {
        // a = I(2), b arbitrary: aᵀb = b
        let a = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![5.0, 6.0, 7.0, 8.0];
        assert_eq!(host_atb(&a, &b, 2, 2, 2), b);
    }

    #[test]
    fn host_atb_known() {
        // a (k=2, m=2) = [[1,2],[3,4]], b (k=2,n=1) = [[10],[20]]
        // aᵀb = [[1*10+3*20],[2*10+4*20]] = [[70],[100]]
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let b = vec![10.0, 20.0];
        assert_eq!(host_atb(&a, &b, 2, 2, 1), vec![70.0, 100.0]);
    }

    #[test]
    fn hostbuf_validation() {
        let b = HostBuf::F32(vec![1.0, 2.0]);
        assert_eq!(b.len(), 2);
        assert!(b.as_f32().is_ok());
        assert_eq!(b.dtype(), Dtype::F32);
        let i = HostBuf::I32(vec![1]);
        assert!(i.as_f32().is_err());
    }

    /// Interpreter-mode coverage (mirrors tests/runtime_artifacts.rs for
    /// the offline build): synthesize a manifest, run atb_64, check the
    /// numerics against the host oracle and the validation paths.
    #[cfg(not(feature = "pjrt"))]
    mod interpreter {
        use super::super::*;

        fn manifest_dir() -> PathBuf {
            let d = std::env::temp_dir()
                .join(format!("threesched-interp-{}-{:?}", std::process::id(), std::thread::current().id()));
            let _ = std::fs::remove_dir_all(&d);
            std::fs::create_dir_all(&d).unwrap();
            std::fs::write(
                d.join("manifest.tsv"),
                "atb_64\tatb_64.hlo.txt\tf32[64,64];f32[64,64]\tf32[64,64]\t524288\n\
                 atb_chain_64_i16\tc.hlo.txt\tf32[64,64];f32[64,64]\tf32[64,64]\t1\n",
            )
            .unwrap();
            d
        }

        #[test]
        fn atb_matches_host_oracle() {
            let dir = manifest_dir();
            let mut rt = Runtime::open(&dir).unwrap();
            let a = fill_f32(64 * 64, 1);
            let b = fill_f32(64 * 64, 2);
            let outs = rt
                .execute("atb_64", &[HostBuf::F32(a.clone()), HostBuf::F32(b.clone())])
                .unwrap();
            assert_eq!(outs[0].as_f32().unwrap(), &host_atb(&a, &b, 64, 64, 64)[..]);
            assert_eq!(rt.exec_counts["atb_64"], 1);
            let _ = std::fs::remove_dir_all(&dir);
        }

        #[test]
        fn validation_and_unsupported_artifacts() {
            let dir = manifest_dir();
            let mut rt = Runtime::open(&dir).unwrap();
            // wrong arity
            assert!(rt.execute("atb_64", &[]).is_err());
            // wrong element count
            assert!(rt
                .execute("atb_64", &[HostBuf::F32(vec![0.0; 3]), HostBuf::F32(vec![0.0; 3])])
                .is_err());
            // unknown artifact
            assert!(rt.execute("nope", &[]).is_err());
            // chain artifacts need real PJRT
            assert!(rt.load("atb_chain_64_i16").is_err());
            assert!(rt.load("atb_64").is_ok());
            let _ = std::fs::remove_dir_all(&dir);
        }

        #[test]
        fn service_thread_works_in_interpreter_mode() {
            let dir = manifest_dir();
            let svc = crate::runtime::service::RuntimeService::start(&dir).unwrap();
            let h = svc.handle();
            let a = fill_f32(64 * 64, 3);
            let b = fill_f32(64 * 64, 4);
            let (outs, dt) = h
                .execute("atb_64", vec![HostBuf::F32(a), HostBuf::F32(b)])
                .unwrap();
            assert_eq!(outs[0].len(), 64 * 64);
            assert!(dt >= 0.0);
            assert_eq!(h.flops("atb_64").unwrap(), 524288.0);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }
}
