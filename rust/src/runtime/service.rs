//! Runtime service: a dedicated thread owning the (non-`Send`) PJRT
//! client, exposing a clonable, thread-safe [`RuntimeHandle`].
//!
//! This mirrors the paper's deployment: every Summit rank owns one GPU and
//! queues kernels onto it; here every process owns one PJRT CPU device
//! behind a service thread, and workers (dwork clients, pmake job scripts,
//! mpi-list ranks) enqueue executions through handles.
//!
//! The handle also reports per-execution wall time so the METG harness can
//! separate compute from coordination overhead exactly as Fig 5 does.

use std::path::Path;
use std::sync::mpsc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{HostBuf, Runtime};

enum Req {
    Execute {
        name: String,
        inputs: Vec<HostBuf>,
        reply: mpsc::Sender<Result<(Vec<HostBuf>, f64)>>,
    },
    Warm {
        names: Vec<String>,
        reply: mpsc::Sender<Result<f64>>,
    },
    Flops {
        name: String,
        reply: mpsc::Sender<Result<f64>>,
    },
    Shutdown,
}

/// Handle to the runtime service.  Clone freely; all clones funnel into
/// the single device thread (executions are serialized, like one GPU).
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Req>,
}

impl RuntimeHandle {
    /// Execute an artifact; returns (outputs, device_seconds).
    pub fn execute(&self, name: &str, inputs: Vec<HostBuf>) -> Result<(Vec<HostBuf>, f64)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Execute { name: name.to_string(), inputs, reply })
            .map_err(|_| anyhow!("runtime service is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped request"))?
    }

    /// Compile a set of artifacts ahead of time; returns compile seconds.
    /// (The paper's 'alloc' phase: startup cost paid once, not per task.)
    pub fn warm(&self, names: &[&str]) -> Result<f64> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Warm { names: names.iter().map(|s| s.to_string()).collect(), reply })
            .map_err(|_| anyhow!("runtime service is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped request"))?
    }

    /// Useful FLOPs per execution of `name` (from the manifest).
    pub fn flops(&self, name: &str) -> Result<f64> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Req::Flops { name: name.to_string(), reply })
            .map_err(|_| anyhow!("runtime service is gone"))?;
        rx.recv().map_err(|_| anyhow!("runtime service dropped request"))?
    }
}

/// The running service.  Dropping it shuts the device thread down.
pub struct RuntimeService {
    tx: mpsc::Sender<Req>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl RuntimeService {
    /// Start a service over the given artifact directory.
    pub fn start(artifacts_dir: &Path) -> Result<RuntimeService> {
        let dir = artifacts_dir.to_path_buf();
        let (tx, rx) = mpsc::channel::<Req>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let thread = std::thread::Builder::new()
            .name("pjrt-device".into())
            .spawn(move || {
                let mut rt = match Runtime::open(&dir) {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                for req in rx {
                    match req {
                        Req::Execute { name, inputs, reply } => {
                            let t0 = Instant::now();
                            let out = rt.execute(&name, &inputs);
                            let dt = t0.elapsed().as_secs_f64();
                            let _ = reply.send(out.map(|o| (o, dt)));
                        }
                        Req::Warm { names, reply } => {
                            let t0 = Instant::now();
                            let mut err = None;
                            for n in &names {
                                if let Err(e) = rt.load(n) {
                                    err = Some(e);
                                    break;
                                }
                            }
                            let dt = t0.elapsed().as_secs_f64();
                            let _ = reply.send(match err {
                                None => Ok(dt),
                                Some(e) => Err(e),
                            });
                        }
                        Req::Flops { name, reply } => {
                            let _ = reply.send(rt.spec(&name).map(|s| s.flops));
                        }
                        Req::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("runtime thread died during startup"))??;
        Ok(RuntimeService { tx, thread: Some(thread) })
    }

    /// Start over the default artifact directory.
    pub fn start_default() -> Result<RuntimeService> {
        RuntimeService::start(&super::default_artifacts_dir())
    }

    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: self.tx.clone() }
    }
}

impl Drop for RuntimeService {
    fn drop(&mut self) {
        let _ = self.tx.send(Req::Shutdown);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    // Exercised end-to-end in rust/tests/runtime_artifacts.rs (needs the
    // artifacts directory).  Here: only failure-path checks.
    use super::*;

    #[test]
    fn start_on_missing_dir_errors() {
        let r = RuntimeService::start(Path::new("/nonexistent/artifacts"));
        assert!(r.is_err());
    }
}
