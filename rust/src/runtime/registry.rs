//! Artifact registry: parse `artifacts/manifest.tsv` written by aot.py.
//!
//! Format (one artifact per line):
//!
//! ```text
//! name <TAB> file <TAB> in0;in1;... <TAB> out0;... <TAB> flops
//! ```
//!
//! with shapes spelled like `f32[256,256]`.  Python is the single source
//! of truth for shapes; Rust discovers them here.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Element types our artifacts use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        match s {
            "f32" => Ok(Dtype::F32),
            "i32" => Ok(Dtype::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::I32 => "i32",
        }
    }
}

/// A typed shape, e.g. f32[256,256].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Shape {
    pub dtype: Dtype,
    pub dims: Vec<usize>,
}

impl Shape {
    pub fn parse(s: &str) -> Result<Shape> {
        let open = s.find('[').ok_or_else(|| anyhow!("shape {s:?} missing '['"))?;
        if !s.ends_with(']') {
            bail!("shape {s:?} missing ']'");
        }
        let dtype = Dtype::parse(&s[..open])?;
        let body = &s[open + 1..s.len() - 1];
        let dims = if body.is_empty() {
            vec![]
        } else {
            body.split(',')
                .map(|d| d.trim().parse::<usize>().context("bad dim"))
                .collect::<Result<Vec<_>>>()?
        };
        Ok(Shape { dtype, dims })
    }

    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }
}

impl fmt::Display for Shape {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}[", self.dtype.name())?;
        for (i, d) in self.dims.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{d}")?;
        }
        write!(f, "]")
    }
}

/// One artifact's metadata.
#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<Shape>,
    pub outputs: Vec<Shape>,
    /// useful FLOPs per execution (Fig 4's efficiency numerator)
    pub flops: f64,
}

/// The parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Manifest {
    specs: BTreeMap<String, ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut specs = BTreeMap::new();
        for (lineno, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let cols: Vec<&str> = line.split('\t').collect();
            if cols.len() != 5 {
                bail!("manifest line {}: expected 5 columns, got {}", lineno + 1, cols.len());
            }
            let parse_shapes = |s: &str| -> Result<Vec<Shape>> {
                if s.is_empty() {
                    return Ok(vec![]);
                }
                s.split(';').map(Shape::parse).collect()
            };
            let spec = ArtifactSpec {
                name: cols[0].to_string(),
                file: cols[1].to_string(),
                inputs: parse_shapes(cols[2])?,
                outputs: parse_shapes(cols[3])?,
                flops: cols[4].parse().context("bad flops column")?,
            };
            specs.insert(spec.name.clone(), spec);
        }
        Ok(Manifest { specs })
    }

    pub fn get(&self, name: &str) -> Option<&ArtifactSpec> {
        self.specs.get(name)
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.specs.keys().map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.specs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.specs.is_empty()
    }

    /// The atb tile sizes present (for sweeps), ascending.
    pub fn atb_tile_sizes(&self) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .specs
            .keys()
            .filter_map(|k| k.strip_prefix("atb_")?.parse::<usize>().ok())
            .collect();
        out.sort_unstable();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_parse_roundtrip() {
        let s = Shape::parse("f32[256,256]").unwrap();
        assert_eq!(s.dtype, Dtype::F32);
        assert_eq!(s.dims, vec![256, 256]);
        assert_eq!(s.elems(), 65536);
        assert_eq!(s.to_string(), "f32[256,256]");
        let s = Shape::parse("i32[1]").unwrap();
        assert_eq!(s.dtype, Dtype::I32);
        let s = Shape::parse("f32[]").unwrap();
        assert_eq!(s.elems(), 1); // scalar: empty product = 1
    }

    #[test]
    fn shape_parse_errors() {
        assert!(Shape::parse("f32").is_err());
        assert!(Shape::parse("f64[2]").is_err());
        assert!(Shape::parse("f32[a]").is_err());
        assert!(Shape::parse("f32[2").is_err());
    }

    #[test]
    fn manifest_parse() {
        let text = "atb_64\tatb_64.hlo.txt\tf32[64,64];f32[64,64]\tf32[64,64]\t524288\n\
                    atb_128\tatb_128.hlo.txt\tf32[128,128];f32[128,128]\tf32[128,128]\t4194304\n";
        let m = Manifest::parse(text).unwrap();
        assert_eq!(m.len(), 2);
        let s = m.get("atb_64").unwrap();
        assert_eq!(s.inputs.len(), 2);
        assert_eq!(s.outputs.len(), 1);
        assert_eq!(s.flops, 2.0 * 64.0 * 64.0 * 64.0);
        assert_eq!(m.atb_tile_sizes(), vec![64, 128]);
    }

    #[test]
    fn manifest_bad_columns() {
        assert!(Manifest::parse("only\tthree\tcolumns\n").is_err());
    }

    #[test]
    fn real_manifest_if_built() {
        let dir = crate::runtime::default_artifacts_dir();
        let path = dir.join("manifest.tsv");
        if !path.exists() {
            return; // artifacts not built in this environment
        }
        let m = Manifest::load(&path).unwrap();
        assert!(m.get("atb_256").is_some());
        assert!(m.atb_tile_sizes().contains(&512));
        for name in m.names() {
            let s = m.get(name).unwrap();
            assert!(dir.join(&s.file).exists(), "missing {}", s.file);
        }
    }
}
