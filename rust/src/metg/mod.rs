//! METG evaluation: the paper's minimum-effective-task-granularity
//! methodology (sec. 3), at two fidelities:
//!
//! * **real mode** — the actual coordinators run real PJRT matmul tasks
//!   in-process at small rank counts (what this host can hold);
//! * **simulated mode** ([`simmodels`]) — the same scheduler state
//!   machines driven by the discrete-event simulator against the
//!   Table-4-calibrated cost models, at the paper's 6–6912 rank scales.
//!
//! METG definition: the task duration at which scheduling overhead equals
//! useful work — equivalently, the smallest task size whose computational
//! efficiency (ideal / actual time) reaches 50%.

pub mod harness;
pub mod simmodels;

/// The paper's weak-scaling workload (sec. 3): 1024 kernel executions per
/// rank; for pmake and dwork a task bundles 256 kernel iterations, so 4
/// tasks reach each rank per run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Workload {
    pub kernels_per_rank: u64,
    pub iters_per_task: u64,
}

impl Workload {
    pub fn paper() -> Workload {
        Workload { kernels_per_rank: 1024, iters_per_task: 256 }
    }

    /// Scaled-down variant for real-mode runs on this host.
    pub fn small() -> Workload {
        Workload { kernels_per_rank: 64, iters_per_task: 16 }
    }

    pub fn tasks_per_rank(&self) -> u64 {
        self.kernels_per_rank / self.iters_per_task
    }

    /// Ideal (zero-overhead) makespan for a per-kernel time.
    pub fn ideal_makespan(&self, t_kernel: f64) -> f64 {
        self.kernels_per_rank as f64 * t_kernel
    }
}

/// One efficiency measurement point (a Fig 4 sample).
#[derive(Clone, Copy, Debug)]
pub struct EffPoint {
    /// ideal single-device time per kernel (the Fig 4 x-axis)
    pub t_kernel: f64,
    /// ideal / actual
    pub efficiency: f64,
    pub makespan: f64,
}

/// Extract the METG from an efficiency curve: the smallest task size with
/// efficiency >= 0.5 (linear interpolation between samples).  The curve
/// must be sampled in ascending `t_kernel`.  Reported in *task* seconds
/// (kernel time × iterations), matching the paper's statement of task
/// granularity.
pub fn metg_from_curve(points: &[EffPoint], iters_per_task: u64) -> Option<f64> {
    let mut prev: Option<&EffPoint> = None;
    for p in points {
        if p.efficiency >= 0.5 {
            let t = match prev {
                Some(q) if q.efficiency < 0.5 && p.efficiency > q.efficiency => {
                    // log-linear interpolation in t
                    let f = (0.5 - q.efficiency) / (p.efficiency - q.efficiency);
                    (q.t_kernel.ln() + f * (p.t_kernel.ln() - q.t_kernel.ln())).exp()
                }
                _ => p.t_kernel,
            };
            return Some(t * iters_per_task as f64);
        }
        prev = Some(p);
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_workload_shape() {
        let w = Workload::paper();
        assert_eq!(w.tasks_per_rank(), 4);
        assert_eq!(w.ideal_makespan(0.001), 1.024);
    }

    #[test]
    fn metg_extraction() {
        let pts = vec![
            EffPoint { t_kernel: 1e-4, efficiency: 0.01, makespan: 1.0 },
            EffPoint { t_kernel: 1e-3, efficiency: 0.1, makespan: 1.0 },
            EffPoint { t_kernel: 1e-2, efficiency: 0.9, makespan: 1.0 },
        ];
        let metg = metg_from_curve(&pts, 256).unwrap();
        // crossover between 1e-3 and 1e-2, times 256 iters
        assert!(metg > 0.256 && metg < 2.56, "metg={metg}");
    }

    #[test]
    fn metg_none_when_never_efficient() {
        let pts = vec![EffPoint { t_kernel: 1.0, efficiency: 0.3, makespan: 1.0 }];
        assert!(metg_from_curve(&pts, 1).is_none());
    }

    #[test]
    fn metg_first_point_already_efficient() {
        let pts = vec![EffPoint { t_kernel: 1e-5, efficiency: 0.8, makespan: 1.0 }];
        assert_eq!(metg_from_curve(&pts, 1), Some(1e-5));
    }
}
