//! DES models of the three schedulers at paper scale.
//!
//! Each model executes the *scheduling logic* (queues, launches, barriers)
//! in virtual time against the calibrated [`CostModel`], with Gumbel task
//! noise, and reports a per-component time breakdown — the machinery
//! behind Fig 4 (scaled efficiency), Fig 5 (breakdown pies) and the METG
//! sweep at 6–6912 ranks.

use crate::substrate::cluster::costs::CostModel;
use crate::substrate::des::{key, Sim};
use crate::substrate::rng::Rng;
use crate::trace::{EventKind, Tracer};

use super::{EffPoint, Workload};

/// Per-component time accounting, in seconds of *aggregate rank time*
/// (divide by ranks × makespan for Fig 5's fractions).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Breakdown {
    pub compute: f64,
    /// job-step launch (pmake only)
    pub jsrun: f64,
    /// per-step allocation / GPU init (pmake only)
    pub alloc: f64,
    /// task database round-trips (dwork only)
    pub communication: f64,
    /// end-of-phase straggler wait (mpi-list; pmake at full-machine tasks)
    pub sync: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.jsrun + self.alloc + self.communication + self.sync
    }

    /// Fraction of total time that is useful compute.
    pub fn compute_fraction(&self) -> f64 {
        let t = self.total();
        if t <= 0.0 {
            0.0
        } else {
            self.compute / t
        }
    }
}

/// Result of one simulated run.
#[derive(Clone, Copy, Debug)]
pub struct SimRun {
    pub makespan: f64,
    pub breakdown: Breakdown,
}

impl SimRun {
    pub fn efficiency(&self, w: &Workload, t_kernel: f64) -> f64 {
        w.ideal_makespan(t_kernel) / self.makespan
    }

    pub fn eff_point(&self, w: &Workload, t_kernel: f64) -> EffPoint {
        EffPoint {
            t_kernel,
            efficiency: self.efficiency(w, t_kernel),
            makespan: self.makespan,
        }
    }
}

/// Sample one rank's compute time for `kernels` kernel executions with
/// the calibrated *absolute* extreme-value jitter (Table 4 sync column).
/// This drives mpi-list's METG (static assignment exposes stragglers) and
/// pmake's sync slice (each job step barriers the whole allocation).
fn rank_compute_abs(rng: &mut Rng, m: &CostModel, t_kernel: f64, kernels: u64) -> f64 {
    let ideal = t_kernel * kernels as f64;
    let noise = rng.gumbel(0.0, m.gumbel_beta_per_task * kernels as f64);
    (ideal + noise).max(ideal * 0.5).max(0.0)
}

/// dwork's dynamic pulling absorbs stragglers (the point of a task list),
/// so only a small execution-proportional jitter remains on each task.
fn rank_compute_prop(rng: &mut Rng, t_kernel: f64, kernels: u64) -> f64 {
    let ideal = t_kernel * kernels as f64;
    let noise = rng.gumbel(0.0, 0.02 * ideal);
    (ideal + noise).max(ideal * 0.5)
}

// ---------------------------------------------------------------- mpi-list

/// mpi-list: one launch, static assignment, barrier at the end.
/// Overheads: python startup (once) + straggler sync per run.
pub fn sim_mpilist(m: &CostModel, w: &Workload, ranks: usize, t_kernel: f64, seed: u64) -> SimRun {
    sim_mpilist_traced(m, w, ranks, t_kernel, seed, &Tracer::default())
}

/// [`sim_mpilist`] emitting the standard lifecycle trace (virtual time):
/// one traced "task" per rank — the rank's whole kernel batch, which is
/// mpi-list's unit of work between barriers.
pub fn sim_mpilist_traced(
    m: &CostModel,
    w: &Workload,
    ranks: usize,
    t_kernel: f64,
    seed: u64,
    tracer: &Tracer,
) -> SimRun {
    let mut rng = Rng::new(seed);
    let mut fastest = f64::MAX;
    let mut slowest = 0.0f64;
    let mut total_compute = 0.0;
    for r in 0..ranks {
        let mut rr = rng.split(r as u64);
        let t = rank_compute_abs(&mut rr, m, t_kernel, w.kernels_per_rank);
        if tracer.enabled() {
            let name = format!("mpilist-r{r}");
            let who = format!("rank{r}");
            tracer.record_at(0.0, &name, EventKind::Created, "");
            tracer.record_at(0.0, &name, EventKind::Ready, "");
            tracer.record_at(0.0, &name, EventKind::Launched, &who);
            tracer.record_at(0.0, &name, EventKind::Started, &who);
            tracer.record_at(t, &name, EventKind::Finished, &who);
        }
        fastest = fastest.min(t);
        slowest = slowest.max(t);
        total_compute += t;
    }
    // startup is once-per-run, reported separately in Table 4 (not part of
    // the per-task METG accounting, matching the paper's treatment)
    let makespan = slowest;
    let sync = slowest * ranks as f64 - total_compute; // aggregate idle at barrier
    SimRun {
        makespan,
        breakdown: Breakdown { compute: total_compute, sync, ..Default::default() },
    }
}

// ------------------------------------------------------------------ dwork

/// dwork: central server serializes task dispatch; workers overlap
/// communication with compute (paper's client).  DES with a FIFO server
/// queue: each Steal/Complete pair occupies the server for `steal_rtt`.
pub fn sim_dwork(m: &CostModel, w: &Workload, ranks: usize, t_kernel: f64, seed: u64) -> SimRun {
    sim_dwork_traced(m, w, ranks, t_kernel, seed, &Tracer::default())
}

/// [`sim_dwork`] emitting the standard lifecycle trace (virtual time);
/// task `dwork-r<r>-t<k>` is rank r's k-th pulled task.
pub fn sim_dwork_traced(
    m: &CostModel,
    w: &Workload,
    ranks: usize,
    t_kernel: f64,
    seed: u64,
    tracer: &Tracer,
) -> SimRun {
    // event kinds
    const REQ: u16 = 1; // worker asks for a task (joins server queue)
    const GRANT: u16 = 2; // server finished serving the head request
    const DONE: u16 = 3; // worker finished computing a task

    let mut rng = Rng::new(seed);
    let tasks_per_rank = w.tasks_per_rank().max(1);
    let kernels_per_task = w.kernels_per_rank / tasks_per_rank;
    let mut remaining: Vec<u64> = vec![tasks_per_rank; ranks];
    let mut queue: std::collections::VecDeque<usize> = Default::default();
    let mut server_busy = false;
    let mut compute = vec![0.0f64; ranks];
    let mut wait = vec![0.0f64; ranks];
    let mut req_at = vec![0.0f64; ranks];
    let mut finished_at = vec![0.0f64; ranks];
    let task_name = |r: usize, remaining_r: u64| {
        format!("dwork-r{r}-t{}", tasks_per_rank - remaining_r)
    };

    let mut sim = Sim::new();
    for r in 0..ranks {
        if tracer.enabled() {
            for k in 0..tasks_per_rank {
                let name = format!("dwork-r{r}-t{k}");
                tracer.record_at(0.0, &name, EventKind::Created, "");
                tracer.record_at(0.0, &name, EventKind::Ready, "");
            }
        }
        sim.at(0.0, key::pack(REQ, r as u64));
    }
    while let Some(ev) = sim.next() {
        let now = sim.now();
        match key::kind(ev.key) {
            REQ => {
                let r = key::index(ev.key) as usize;
                req_at[r] = now;
                queue.push_back(r);
                if !server_busy {
                    server_busy = true;
                    sim.after(m.steal_rtt, key::pack(GRANT, 0));
                }
            }
            GRANT => {
                let r = queue.pop_front().expect("grant with empty queue");
                wait[r] += now - req_at[r];
                // worker starts computing one task
                let mut rr = rng.split((r as u64) << 32 | remaining[r]);
                let t = rank_compute_prop(&mut rr, t_kernel, kernels_per_task);
                if tracer.enabled() {
                    let name = task_name(r, remaining[r]);
                    let who = format!("w{r}");
                    tracer.record_at(now, &name, EventKind::Launched, &who);
                    tracer.record_at(now, &name, EventKind::Started, &who);
                }
                compute[r] += t;
                sim.after(t, key::pack(DONE, r as u64));
                if queue.is_empty() {
                    server_busy = false;
                } else {
                    sim.after(m.steal_rtt, key::pack(GRANT, 0));
                }
            }
            DONE => {
                let r = key::index(ev.key) as usize;
                if tracer.enabled() {
                    tracer.record_at(
                        now,
                        &task_name(r, remaining[r]),
                        EventKind::Finished,
                        &format!("w{r}"),
                    );
                }
                remaining[r] -= 1;
                if remaining[r] > 0 {
                    sim.at(now, key::pack(REQ, r as u64));
                } else {
                    finished_at[r] = now;
                }
            }
            _ => unreachable!(),
        }
    }
    let makespan = sim.now();
    let total_compute: f64 = compute.iter().sum();
    let total_wait: f64 = wait.iter().sum();
    // residual idle: ranks that finished early wait for the last completion
    let tail: f64 = finished_at.iter().map(|&f| makespan - f).sum();
    SimRun {
        makespan,
        breakdown: Breakdown {
            compute: total_compute,
            communication: total_wait,
            sync: tail,
            ..Default::default()
        },
    }
}

// ------------------------------------------------------------------ pmake

/// pmake: each task is a separate job step launched onto the allocation;
/// the benchmark's tasks each occupy all ranks, so a run is
/// `tasks_per_rank` sequential steps of jsrun + alloc + max-rank-compute
/// (paper Fig 5: jsrun, alloc, compute, sync slices).
pub fn sim_pmake(m: &CostModel, w: &Workload, ranks: usize, t_kernel: f64, seed: u64) -> SimRun {
    sim_pmake_traced(m, w, ranks, t_kernel, seed, &Tracer::default())
}

/// [`sim_pmake`] emitting the standard lifecycle trace (virtual time);
/// each job step `pmake-s<k>` occupies the whole allocation, so
/// Launched→Started is exactly the jsrun+alloc window.
pub fn sim_pmake_traced(
    m: &CostModel,
    w: &Workload,
    ranks: usize,
    t_kernel: f64,
    seed: u64,
    tracer: &Tracer,
) -> SimRun {
    let mut rng = Rng::new(seed);
    let steps = w.tasks_per_rank().max(1);
    let kernels_per_task = w.kernels_per_rank / steps;
    let mut bd = Breakdown::default();
    let mut makespan = 0.0;
    if tracer.enabled() {
        for s in 0..steps {
            tracer.record_at(0.0, &format!("pmake-s{s}"), EventKind::Created, "");
        }
    }
    for s in 0..steps {
        let jsrun = m.jsrun(ranks);
        let alloc = m.alloc;
        let mut slowest = 0.0f64;
        let mut total = 0.0;
        for r in 0..ranks {
            let mut rr = rng.split(s << 32 | r as u64);
            let t = rank_compute_abs(&mut rr, m, t_kernel, kernels_per_task);
            slowest = slowest.max(t);
            total += t;
        }
        if tracer.enabled() {
            let name = format!("pmake-s{s}");
            tracer.record_at(makespan, &name, EventKind::Ready, "");
            tracer.record_at(makespan, &name, EventKind::Launched, "alloc");
            tracer.record_at(makespan + jsrun + alloc, &name, EventKind::Started, "alloc");
            tracer.record_at(
                makespan + jsrun + alloc + slowest,
                &name,
                EventKind::Finished,
                "alloc",
            );
        }
        makespan += jsrun + alloc + slowest;
        // jsrun+alloc stall the entire allocation (cannot overlap; paper)
        bd.jsrun += jsrun * ranks as f64;
        bd.alloc += alloc * ranks as f64;
        bd.compute += total;
        bd.sync += slowest * ranks as f64 - total;
    }
    SimRun { makespan, breakdown: bd }
}

/// Which scheduler a sim run models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tool {
    Pmake,
    Dwork,
    MpiList,
}

impl Tool {
    pub const ALL: [Tool; 3] = [Tool::Pmake, Tool::Dwork, Tool::MpiList];

    pub fn name(&self) -> &'static str {
        match self {
            Tool::Pmake => "pmake",
            Tool::Dwork => "dwork",
            Tool::MpiList => "mpi-list",
        }
    }

    pub fn simulate(
        &self,
        m: &CostModel,
        w: &Workload,
        ranks: usize,
        t_kernel: f64,
        seed: u64,
    ) -> SimRun {
        self.simulate_traced(m, w, ranks, t_kernel, seed, &Tracer::default())
    }

    /// [`Tool::simulate`] with a lifecycle tracer (virtual timestamps,
    /// identical schema to real-run traces).
    pub fn simulate_traced(
        &self,
        m: &CostModel,
        w: &Workload,
        ranks: usize,
        t_kernel: f64,
        seed: u64,
        tracer: &Tracer,
    ) -> SimRun {
        match self {
            Tool::Pmake => sim_pmake_traced(m, w, ranks, t_kernel, seed, tracer),
            Tool::Dwork => sim_dwork_traced(m, w, ranks, t_kernel, seed, tracer),
            Tool::MpiList => sim_mpilist_traced(m, w, ranks, t_kernel, seed, tracer),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metg::metg_from_curve;

    fn model() -> CostModel {
        CostModel::paper()
    }

    #[test]
    fn mpilist_efficiency_approaches_one_for_big_tasks() {
        let m = model();
        let w = Workload::paper();
        let run = sim_mpilist(&m, &w, 864, 1.0, 1);
        let eff = run.efficiency(&w, 1.0);
        assert!(eff > 0.9, "eff={eff}");
        let run = sim_mpilist(&m, &w, 864, 1e-6, 1);
        let eff = run.efficiency(&w, 1e-6);
        assert!(eff < 0.5, "eff={eff} should be sync-dominated");
    }

    #[test]
    fn dwork_server_serializes_at_tiny_tasks() {
        let m = model();
        let w = Workload::paper();
        // zero-work kernels: makespan ~= total tasks * rtt (paper: "the
        // server is the bottleneck, and the time equals the total number
        // of tasks assigned times the round-trip time")
        let ranks = 64;
        let run = sim_dwork(&m, &w, ranks, 0.0, 1);
        let total_tasks = (w.tasks_per_rank() * ranks as u64) as f64;
        let expect = total_tasks * m.steal_rtt;
        assert!(
            (run.makespan - expect).abs() / expect < 0.1,
            "makespan={} expect={}",
            run.makespan,
            expect
        );
    }

    #[test]
    fn dwork_overlap_hides_rtt_for_big_tasks() {
        let m = model();
        let w = Workload::paper();
        let run = sim_dwork(&m, &w, 864, 0.01, 1);
        let eff = run.efficiency(&w, 0.01);
        assert!(eff > 0.8, "eff={eff}");
    }

    #[test]
    fn pmake_dominated_by_launch_for_small_tasks() {
        let m = model();
        let w = Workload::paper();
        let run = sim_pmake(&m, &w, 864, 1e-4, 1);
        let bd = run.breakdown;
        assert!(bd.jsrun + bd.alloc > bd.compute, "launch must dominate: {bd:?}");
        // 4 steps of (jsrun + alloc) ~= 4 * (2.34 + 1.81) ~= 16.6s floor
        assert!(run.makespan > 16.0, "makespan={}", run.makespan);
    }

    #[test]
    fn headline_metg_ordering_at_864() {
        // paper sec. 4: METG at 864 ranks = 0.3ms / 25ms / 4500ms
        let m = model();
        let w = Workload::paper();
        let grid: Vec<f64> = (-7..=2)
            .flat_map(|e| [1.0, 2.0, 5.0].map(|m| m * 10f64.powi(e)))
            .collect();
        let mut metgs = Vec::new();
        for tool in Tool::ALL {
            let pts: Vec<EffPoint> = grid
                .iter()
                .map(|&t| tool.simulate(&m, &w, 864, t, 42).eff_point(&w, t))
                .collect();
            let iters = match tool {
                Tool::MpiList => 1, // per-kernel tasks
                _ => w.iters_per_task,
            };
            metgs.push((tool, metg_from_curve(&pts, iters).expect("curve must cross 0.5")));
        }
        let get = |t: Tool| metgs.iter().find(|(tt, _)| *tt == t).unwrap().1;
        let (ml, dw, pm) = (get(Tool::MpiList), get(Tool::Dwork), get(Tool::Pmake));
        // orders of magnitude must match the paper
        assert!(ml < 2e-3, "mpi-list METG {ml}s vs paper 0.3ms");
        assert!((5e-3..0.2).contains(&dw), "dwork METG {dw}s vs paper 25ms");
        assert!((1.0..20.0).contains(&pm), "pmake METG {pm}s vs paper 4.5s");
        assert!(ml < dw && dw < pm);
    }

    #[test]
    fn dwork_metg_scales_linearly_with_ranks() {
        let m = model();
        let w = Workload::paper();
        // at fixed small t_kernel, efficiency degrades ~linearly in ranks
        let e1 = sim_dwork(&m, &w, 100, 1e-5, 7).efficiency(&w, 1e-5);
        let e2 = sim_dwork(&m, &w, 800, 1e-5, 7).efficiency(&w, 1e-5);
        assert!(e1 > e2 * 2.0, "e1={e1} e2={e2}");
    }

    #[test]
    fn breakdowns_account_for_total_time() {
        let m = model();
        let w = Workload::paper();
        for tool in Tool::ALL {
            let run = tool.simulate(&m, &w, 60, 0.001, 3);
            let bd = run.breakdown;
            let aggregate = 60.0 * run.makespan;
            // breakdown components must not exceed aggregate rank-time and
            // must cover most of it (pmake's jsrun/alloc stall all ranks)
            assert!(
                bd.total() <= aggregate * 1.01,
                "{}: breakdown {} > aggregate {}",
                tool.name(),
                bd.total(),
                aggregate
            );
            assert!(
                bd.total() >= aggregate * 0.5,
                "{}: breakdown {} misses most of aggregate {}",
                tool.name(),
                bd.total(),
                aggregate
            );
        }
    }

    #[test]
    fn traced_sim_runs_emit_wellformed_traces() {
        let m = model();
        let w = Workload::small();
        for tool in Tool::ALL {
            let tracer = Tracer::memory();
            let run = tool.simulate_traced(&m, &w, 6, 0.001, 3, &tracer);
            let evs = tracer.drain();
            assert!(!evs.is_empty(), "{}", tool.name());
            crate::trace::validate(&evs).unwrap_or_else(|e| panic!("{}: {e}", tool.name()));
            // trace horizon matches the reported makespan
            let last = evs.iter().map(|e| e.t).fold(0.0f64, f64::max);
            assert!(
                (last - run.makespan).abs() <= 1e-9 * run.makespan.max(1.0),
                "{}: trace ends {last}, makespan {}",
                tool.name(),
                run.makespan
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let m = model();
        let w = Workload::paper();
        for tool in Tool::ALL {
            let a = tool.simulate(&m, &w, 60, 0.01, 9);
            let b = tool.simulate(&m, &w, 60, 0.01, 9);
            assert_eq!(a.makespan, b.makespan, "{}", tool.name());
        }
    }
}
