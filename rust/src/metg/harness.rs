//! Evaluation harness: generates the paper's tables and figures.
//!
//! Fig 4 (efficiency vs tile size), Fig 5 (per-component breakdowns),
//! Table 4 (overhead scaling) and the METG-vs-ranks sweep, each as plain
//! text tables printed by the corresponding bench target.  Real-mode
//! helpers measure the actual coordinators over PJRT at host scale.

use anyhow::Result;

use crate::runtime::service::RuntimeHandle;
use crate::runtime::{fill_f32, HostBuf};
use crate::substrate::cluster::costs::{
    CostModel, TABLE4_ALLOC, TABLE4_DWORK_CONN, TABLE4_JSRUN, TABLE4_PY_ALLOC,
    TABLE4_PY_IMPORTS, TABLE4_RANKS, TABLE4_STEAL_RTT, TABLE4_SYNC_1024,
};

use super::simmodels::Tool;
use super::{metg_from_curve, EffPoint, Workload};

/// The paper's rank scales.
pub const PAPER_RANKS: [usize; 4] = [6, 60, 864, 6912];

/// Log-spaced kernel-time grid for METG sweeps (seconds).
pub fn t_kernel_grid() -> Vec<f64> {
    (-7..=2)
        .flat_map(|e| [1.0, 2.0, 5.0].map(|m| m * 10f64.powi(e)))
        .collect()
}

/// Simple fixed-width text table builder (no external crates).
pub struct TextTable {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl TextTable {
    pub fn new(header: &[&str]) -> TextTable {
        TextTable { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(String::len).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$}  ", c, w = widths[i]));
            }
            line.trim_end().to_string() + "\n"
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&format!("{}\n", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len())));
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

fn fmt_t(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.2}s")
    } else if t >= 1e-3 {
        format!("{:.2}ms", t * 1e3)
    } else {
        format!("{:.1}us", t * 1e6)
    }
}

// ------------------------------------------------------------------- Fig 4

/// One Fig 4 sample: tool × tile size at fixed ranks.
pub struct Fig4Row {
    pub tool: Tool,
    pub tile: usize,
    pub t_kernel: f64,
    pub gflops_per_rank: f64,
    pub rel_efficiency: f64,
}

/// Simulated Fig 4: per-GPU GFLOP/s (upper) + relative efficiency (lower)
/// across tile sizes, at the given rank count.  `t_kernel_of_tile` maps a
/// tile size to its ideal single-device kernel time (measured in real
/// mode; V100-modelled in paper mode).
pub fn fig4(
    m: &CostModel,
    w: &Workload,
    ranks: usize,
    tiles: &[(usize, f64)],
    seed: u64,
) -> Vec<Fig4Row> {
    let mut out = Vec::new();
    for &(tile, t_kernel) in tiles {
        let flops = 2.0 * (tile as f64).powi(3);
        for tool in Tool::ALL {
            let run = tool.simulate(m, w, ranks, t_kernel, seed);
            let eff = run.efficiency(w, t_kernel);
            out.push(Fig4Row {
                tool,
                tile,
                t_kernel,
                // actual per-rank throughput = eff * ideal throughput
                gflops_per_rank: eff * flops / t_kernel / 1e9,
                rel_efficiency: eff,
            });
        }
    }
    out
}

/// Ideal V100 kernel time for a tile size (paper hardware model): ramps
/// from call-overhead-bound small tiles to 14 TF/s peak at 4096+.
pub fn v100_t_kernel(tile: usize) -> f64 {
    let flops = 2.0 * (tile as f64).powi(3);
    let peak = 14e12;
    // efficiency ramp: tiny tiles can't fill the GPU (paper Fig 4 upper)
    let util = (tile as f64 / 4096.0).min(1.0).powf(0.6).max(0.02);
    let launch = 10e-6; // kernel launch + blas call path
    flops / (peak * util) + launch
}

pub fn render_fig4(rows: &[Fig4Row], ranks: usize) -> String {
    let mut upper = TextTable::new(&["tile", "t_kernel", "pmake GF/s", "dwork GF/s", "mpi-list GF/s"]);
    let mut lower = TextTable::new(&["tile", "t_kernel", "pmake eff", "dwork eff", "mpi-list eff"]);
    let tiles: Vec<usize> = {
        let mut t: Vec<usize> = rows.iter().map(|r| r.tile).collect();
        t.sort_unstable();
        t.dedup();
        t
    };
    for tile in tiles {
        let get = |tool: Tool| {
            rows.iter()
                .find(|r| r.tile == tile && r.tool == tool)
                .expect("complete grid")
        };
        let (p, d, l) = (get(Tool::Pmake), get(Tool::Dwork), get(Tool::MpiList));
        upper.row(vec![
            tile.to_string(),
            fmt_t(p.t_kernel),
            format!("{:.1}", p.gflops_per_rank),
            format!("{:.1}", d.gflops_per_rank),
            format!("{:.1}", l.gflops_per_rank),
        ]);
        lower.row(vec![
            tile.to_string(),
            fmt_t(p.t_kernel),
            format!("{:.4}", p.rel_efficiency),
            format!("{:.4}", d.rel_efficiency),
            format!("{:.4}", l.rel_efficiency),
        ]);
    }
    format!(
        "Fig 4 (upper): absolute GFLOP/s per rank, {ranks} ranks\n{}\n\
         Fig 4 (lower): efficiency relative to single-device compute, {ranks} ranks\n{}",
        upper.render(),
        lower.render()
    )
}

// ------------------------------------------------------------------- Fig 5

/// Fig 5: per-component time fractions for one tool/tile/ranks cell.
pub fn fig5_row(m: &CostModel, w: &Workload, tool: Tool, ranks: usize, t_kernel: f64) -> [f64; 5] {
    let run = tool.simulate(m, w, ranks, t_kernel, 5);
    let bd = run.breakdown;
    let total = (ranks as f64 * run.makespan).max(1e-30);
    [
        bd.compute / total,
        bd.jsrun / total,
        bd.alloc / total,
        bd.communication / total,
        bd.sync / total,
    ]
}

pub fn render_fig5(m: &CostModel, w: &Workload, ranks: usize, tiles: &[(usize, f64)]) -> String {
    let mut t = TextTable::new(&["tool", "tile", "compute", "jsrun", "alloc", "comm", "sync"]);
    for tool in Tool::ALL {
        for &(tile, tk) in tiles {
            let f = fig5_row(m, w, tool, ranks, tk);
            t.row(vec![
                tool.name().into(),
                tile.to_string(),
                format!("{:.3}", f[0]),
                format!("{:.3}", f[1]),
                format!("{:.3}", f[2]),
                format!("{:.3}", f[3]),
                format!("{:.3}", f[4]),
            ]);
        }
    }
    format!("Fig 5: time-breakdown fractions at {ranks} ranks (rows sum to ~1)\n{}", t.render())
}

// ----------------------------------------------------------------- Table 4

/// Table 4, model vs paper: per-rank-count overhead components.
pub fn render_table4(m: &CostModel, measured_rtt: Option<f64>) -> String {
    let mut t = TextTable::new(&[
        "ranks",
        "jsrun model",
        "jsrun paper",
        "alloc",
        "steal RTT",
        "sync/1024 model",
        "sync/1024 paper",
        "py alloc",
        "py imports model",
        "py imports paper",
        "dwork conn model",
    ]);
    for (i, &r) in TABLE4_RANKS.iter().enumerate() {
        let conn_paper: Option<f64> =
            TABLE4_DWORK_CONN.iter().find(|&&(cr, _)| cr == r).map(|&(_, v)| v);
        t.row(vec![
            r.to_string(),
            format!("{:.3}", m.jsrun(r)),
            format!("{:.3}", TABLE4_JSRUN[i]),
            format!("{TABLE4_ALLOC:.2}"),
            format!(
                "{} (paper {})",
                fmt_t(measured_rtt.unwrap_or(m.steal_rtt)),
                fmt_t(TABLE4_STEAL_RTT)
            ),
            format!("{:.3}", m.sync_spread(r, 1024)),
            format!("{:.2}", TABLE4_SYNC_1024[i]),
            format!("{TABLE4_PY_ALLOC:.2}"),
            format!("{:.2}", m.py_imports(r)),
            format!("{:.2}", TABLE4_PY_IMPORTS[i]),
            match conn_paper {
                Some(p) => format!("{:.2} (paper {p:.2})", m.dwork_conn(r)),
                None => format!("{:.2} (paper -)", m.dwork_conn(r)),
            },
        ]);
    }
    format!("Table 4: overhead components vs ranks (seconds)\n{}", t.render())
}

// ------------------------------------------------------------- METG sweep

/// METG per tool per rank count (the sec. 4 headline + Ref [2] Fig 9
/// comparison).  Returns (tool, ranks, metg_seconds).
pub fn metg_sweep(m: &CostModel, w: &Workload, ranks_list: &[usize]) -> Vec<(Tool, usize, f64)> {
    let grid = t_kernel_grid();
    let mut out = Vec::new();
    for &ranks in ranks_list {
        for tool in Tool::ALL {
            let pts: Vec<EffPoint> = grid
                .iter()
                .map(|&t| tool.simulate(m, w, ranks, t, 42).eff_point(w, t))
                .collect();
            let iters = match tool {
                Tool::MpiList => 1,
                _ => w.iters_per_task,
            };
            if let Some(metg) = metg_from_curve(&pts, iters) {
                out.push((tool, ranks, metg));
            }
        }
    }
    out
}

pub fn render_metg(rows: &[(Tool, usize, f64)]) -> String {
    let mut t = TextTable::new(&["ranks", "pmake METG", "dwork METG", "mpi-list METG"]);
    let mut ranks: Vec<usize> = rows.iter().map(|(_, r, _)| *r).collect();
    ranks.sort_unstable();
    ranks.dedup();
    for r in ranks {
        let get = |tool: Tool| {
            rows.iter()
                .find(|(tt, rr, _)| *tt == tool && *rr == r)
                .map(|(_, _, m)| fmt_t(*m))
                .unwrap_or_else(|| "-".into())
        };
        t.row(vec![r.to_string(), get(Tool::Pmake), get(Tool::Dwork), get(Tool::MpiList)]);
    }
    format!(
        "METG vs ranks (task granularity where overhead = compute)\n\
         paper headline at 864 ranks: pmake 4500ms, dwork 25ms, mpi-list 0.3ms\n{}",
        t.render()
    )
}

// --------------------------------------------------------------- real mode

/// Measure the ideal per-kernel time of an artifact on this host's PJRT
/// device (the paper's single-GPU baseline run).
pub fn measure_t_kernel(h: &RuntimeHandle, artifact: &str, reps: u32) -> Result<f64> {
    let spec_elems = {
        // probe input sizes via flops name convention atb_{ts}
        let ts: usize = artifact
            .strip_prefix("atb_")
            .and_then(|s| s.split('_').next())
            .and_then(|s| s.parse().ok())
            .ok_or_else(|| anyhow::anyhow!("not an atb artifact: {artifact}"))?;
        ts * ts
    };
    let a = fill_f32(spec_elems, 1);
    let b = fill_f32(spec_elems, 2);
    h.warm(&[artifact])?;
    let mut best = f64::MAX;
    for _ in 0..reps.max(1) {
        let (_, dt) = h.execute(artifact, vec![HostBuf::F32(a.clone()), HostBuf::F32(b.clone())])?;
        best = best.min(dt);
    }
    Ok(best)
}

/// Real-mode efficiency sample: actual coordinator, actual PJRT kernels.
pub struct RealRun {
    pub makespan: f64,
    pub kernels: u64,
    pub t_kernel_baseline: f64,
}

impl RealRun {
    pub fn efficiency(&self, ranks: usize) -> f64 {
        let ideal = self.kernels as f64 / ranks as f64 * self.t_kernel_baseline;
        ideal / self.makespan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_ascending() {
        let g = t_kernel_grid();
        assert!(g.windows(2).all(|w| w[0] < w[1]));
        assert!(g[0] <= 1e-7 && *g.last().unwrap() >= 100.0);
    }

    #[test]
    fn text_table_renders() {
        let mut t = TextTable::new(&["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("a"));
        assert!(s.contains("bb"));
        assert!(s.lines().count() >= 3);
    }

    #[test]
    fn v100_model_sane() {
        // large tiles approach peak: 8192^3*2 / 14e12 ≈ 78.6ms
        let t = v100_t_kernel(8192);
        assert!((0.07..0.09).contains(&t), "t={t}");
        // small tiles are launch-bound, not at peak
        let t64 = v100_t_kernel(64);
        let gflops = 2.0 * 64f64.powi(3) / t64 / 1e9;
        assert!(gflops < 1000.0, "64-tile at {gflops} GF/s should be far from 14000");
    }

    #[test]
    fn fig4_rows_complete() {
        let m = CostModel::paper();
        let w = Workload::paper();
        let tiles: Vec<(usize, f64)> =
            [256, 1024, 4096].iter().map(|&t| (t, v100_t_kernel(t))).collect();
        let rows = fig4(&m, &w, 60, &tiles, 1);
        assert_eq!(rows.len(), 9);
        let txt = render_fig4(&rows, 60);
        assert!(txt.contains("Fig 4"));
        assert!(txt.contains("4096"));
        // efficiency grows with tile size for every tool
        for tool in Tool::ALL {
            let effs: Vec<f64> = [256, 1024, 4096]
                .iter()
                .map(|&t| {
                    rows.iter()
                        .find(|r| r.tile == t && r.tool == tool)
                        .unwrap()
                        .rel_efficiency
                })
                .collect();
            assert!(effs[0] <= effs[2], "{}: {effs:?}", tool.name());
        }
    }

    #[test]
    fn fig5_fractions_sum_to_one() {
        let m = CostModel::paper();
        let w = Workload::paper();
        for tool in Tool::ALL {
            let f = fig5_row(&m, &w, tool, 60, 0.001);
            let sum: f64 = f.iter().sum();
            assert!((0.5..=1.01).contains(&sum), "{}: {f:?} sums to {sum}", tool.name());
        }
    }

    #[test]
    fn table4_renders_all_ranks() {
        let txt = render_table4(&CostModel::paper(), Some(12e-6));
        for r in TABLE4_RANKS {
            assert!(txt.contains(&r.to_string()));
        }
        assert!(txt.contains("paper"));
    }

    #[test]
    fn metg_sweep_produces_ordering() {
        let m = CostModel::paper();
        let w = Workload::paper();
        let rows = metg_sweep(&m, &w, &[60, 864]);
        assert_eq!(rows.len(), 6);
        let txt = render_metg(&rows);
        assert!(txt.contains("864"));
        for &ranks in &[60usize, 864] {
            let get = |tool: Tool| {
                rows.iter()
                    .find(|(t, r, _)| *t == tool && *r == ranks)
                    .unwrap()
                    .2
            };
            assert!(get(Tool::MpiList) < get(Tool::Dwork));
            assert!(get(Tool::Dwork) < get(Tool::Pmake));
        }
    }
}
