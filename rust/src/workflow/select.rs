//! Adaptive scheduler selection: graph shape × METG cost model.
//!
//! The paper's central practical question — *which of the three tools do
//! I point at my workload?* — answered mechanically.  The rule combines:
//!
//! 1. **Granularity** (the METG test): a coordinator is only efficient
//!    when mean task duration t̄ clears its minimum effective task
//!    granularity; estimated efficiency is t̄ / (t̄ + METG), the METG
//!    definition inverted (overhead = work at exactly 50%).
//! 2. **Shape** (the synchronization-mechanism test):
//!    * pmake wants *file-synchronized* graphs — tasks that already
//!      declare file outputs get restartability and `make -k` robustness
//!      for free, but pay a job-step launch per task;
//!    * mpi-list wants *flat bulk-synchronous maps* — one level of
//!      uniform tasks needs no synchronization at all;
//!    * dwork takes everything else: irregular widths, heterogeneous
//!      durations, fine granularity down to its server RTT.
//!
//! Preference among the eligible (paper §7, simplicity argument): the
//! simplest mechanism whose overhead is invisible at the workload's
//! granularity — files, then static lists, then the task server.

use anyhow::Result;

use crate::metg::simmodels::Tool;
use crate::substrate::cluster::costs::CostModel;

use super::graph::{GraphStats, WorkflowGraph};

/// Flat-map levels tolerate this much duration spread before the static
/// assignment's stragglers argue for dynamic pulling instead.  Shared
/// with the analyzer's W102 lint (`crate::analyze::granularity`).
pub(crate) const UNIFORM_CV: f64 = 0.25;

/// Minimum estimated efficiency for a coordinator to be "eligible".
/// Shared with the analyzer's W101 lint.
pub(crate) const EFF_FLOOR: f64 = 0.5;

/// Per-coordinator verdict.
#[derive(Clone, Debug)]
pub struct Assessment {
    pub tool: Tool,
    pub eligible: bool,
    /// t̄ / (t̄ + METG): estimated computational efficiency at this
    /// workload's mean granularity
    pub efficiency: f64,
    /// the coordinator's METG at the target scale (seconds)
    pub metg_s: f64,
    /// rough makespan estimate (seconds) for display/ordering
    pub est_makespan_s: f64,
    pub reason: String,
}

/// The selector's full answer.
#[derive(Clone, Debug)]
pub struct Recommendation {
    pub choice: Tool,
    pub ranks: usize,
    pub stats: GraphStats,
    /// all three assessments, in [`Tool::ALL`] order
    pub assessments: Vec<Assessment>,
}

impl Recommendation {
    pub fn assessment(&self, tool: Tool) -> &Assessment {
        self.assessments.iter().find(|a| a.tool == tool).expect("all tools assessed")
    }

    /// Human-facing report (the `workflow plan` body).
    pub fn render(&self) -> String {
        let s = &self.stats;
        let mut out = format!(
            "graph: {} tasks, {} edges, depth {}, width {}, \
             work {:.1}s, critical path {:.1}s, parallelism {:.1}x\n\
             mean task {:.3}s (cv {:.2}), file-sync: {}, uniform: {}\n\
             at {} ranks:\n",
            s.tasks,
            s.edges,
            s.depth,
            s.width,
            s.total_work_s,
            s.critical_path_s,
            s.max_parallelism,
            s.mean_task_s,
            s.cv_task_s,
            s.file_sync,
            s.uniform_payload,
            self.ranks
        );
        for a in &self.assessments {
            out.push_str(&format!(
                "  {:<8} METG {:>9} eff {:>5.1}% est makespan {:>9} {} — {}\n",
                a.tool.name(),
                fmt_t(a.metg_s),
                a.efficiency * 100.0,
                fmt_t(a.est_makespan_s),
                if a.eligible { "[ok]" } else { "[  ]" },
                a.reason
            ));
        }
        out.push_str(&format!("recommendation: {}\n", self.choice.name()));
        out
    }
}

pub(crate) fn fmt_t(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.2}s")
    } else if t >= 1e-3 {
        format!("{:.2}ms", t * 1e3)
    } else {
        format!("{:.1}us", t * 1e6)
    }
}

/// Is the graph a flat bulk-synchronous map: a single level of uniform
/// independent tasks (mpi-list's home turf)?
fn is_flat_map(s: &GraphStats) -> bool {
    s.depth == 1 && s.uniform_payload && s.cv_task_s <= UNIFORM_CV
}

/// Recommend a coordinator for `g` at a target scale of `ranks` workers.
pub fn select(g: &WorkflowGraph, m: &CostModel, ranks: usize) -> Result<Recommendation> {
    let (stats, levels) = g.analyze()?;
    let ranks = ranks.max(1);
    let t_mean = stats.mean_task_s;
    let n = stats.tasks.max(1);
    let tasks_per_rank = n.div_ceil(ranks).max(1) as u64;

    let eff = |metg: f64| {
        if t_mean <= 0.0 {
            0.0
        } else {
            t_mean / (t_mean + metg)
        }
    };

    // ---- per-tool METG + rough makespan estimates -------------------
    let metg_pmake = m.metg_pmake(ranks);
    let metg_dwork = m.metg_dwork(ranks);
    let metg_mpilist = m.metg_mpilist(ranks, tasks_per_rank);

    // pmake: the critical path pays one job-step launch per hop; off-path
    // work spreads over the allocation.
    let est_pmake = stats.critical_path_s
        + stats.depth as f64 * metg_pmake
        + (stats.total_work_s - stats.critical_path_s) / ranks as f64;
    // dwork: one connection storm, then the binding constraint is either
    // the dependency chain, the aggregate work, or the serialized server.
    let est_dwork = m.dwork_conn(ranks).max(0.0)
        + (stats.critical_path_s + stats.depth as f64 * m.steal_rtt)
            .max(stats.total_work_s / ranks as f64)
            .max(n as f64 * m.steal_rtt);
    // mpi-list: per level, the largest per-rank block of the slowest
    // task, plus a straggler sync per phase.
    let est_mpilist = {
        let mut total = 0.0;
        for level in &levels {
            let max_est = level
                .iter()
                .map(|&i| g.tasks()[i].est_s)
                .fold(0f64, f64::max);
            let per_rank = level.len().div_ceil(ranks);
            total += per_rank as f64 * max_est + m.sync_spread(ranks, per_rank.max(1) as u64);
        }
        total
    };

    // ---- eligibility gates ------------------------------------------
    let eff_pmake = eff(metg_pmake);
    let eff_dwork = eff(metg_dwork);
    let eff_mpilist = eff(metg_mpilist);

    let pmake_eligible = stats.file_sync && eff_pmake >= EFF_FLOOR;
    let mpilist_eligible = is_flat_map(&stats) && eff_mpilist >= EFF_FLOOR;

    let pmake_reason = if !stats.file_sync {
        "tasks declare no file outputs; nothing for file-based sync to watch".to_string()
    } else if eff_pmake < EFF_FLOOR {
        format!("tasks of {} are below the {} launch cost", fmt_t(t_mean), fmt_t(metg_pmake))
    } else {
        "file-synchronized graph, tasks dwarf the job-step launch cost".to_string()
    };
    let mpilist_reason = if !is_flat_map(&stats) {
        format!(
            "not a flat uniform map (depth {}, cv {:.2}); static assignment would idle ranks",
            stats.depth, stats.cv_task_s
        )
    } else if eff_mpilist < EFF_FLOOR {
        format!("straggler spread {} per task overwhelms {}", fmt_t(metg_mpilist), fmt_t(t_mean))
    } else {
        "flat uniform map: static assignment needs no synchronization at all".to_string()
    };
    let dwork_reason = if eff_dwork >= EFF_FLOOR {
        "dependency-aware pulling absorbs irregular shape and granularity".to_string()
    } else {
        format!(
            "WARNING: mean task {} is under dwork's METG {}; expect <50% efficiency",
            fmt_t(t_mean),
            fmt_t(metg_dwork)
        )
    };

    // ---- preference among the eligible ------------------------------
    let choice = if pmake_eligible {
        Tool::Pmake
    } else if mpilist_eligible {
        Tool::MpiList
    } else {
        Tool::Dwork
    };

    let assessments = vec![
        Assessment {
            tool: Tool::Pmake,
            eligible: pmake_eligible,
            efficiency: eff_pmake,
            metg_s: metg_pmake,
            est_makespan_s: est_pmake,
            reason: pmake_reason,
        },
        Assessment {
            tool: Tool::Dwork,
            eligible: true,
            efficiency: eff_dwork,
            metg_s: metg_dwork,
            est_makespan_s: est_dwork,
            reason: dwork_reason,
        },
        Assessment {
            tool: Tool::MpiList,
            eligible: mpilist_eligible,
            efficiency: eff_mpilist,
            metg_s: metg_mpilist,
            est_makespan_s: est_mpilist,
            reason: mpilist_reason,
        },
    ];

    Ok(Recommendation { choice, ranks, stats, assessments })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::graph::TaskSpec;

    fn model() -> CostModel {
        CostModel::paper()
    }

    /// Deep file-dependency chain of coarse simulate steps -> pmake.
    fn deep_file_chain(n: usize) -> WorkflowGraph {
        let mut g = WorkflowGraph::new("chain");
        for i in 0..n {
            let mut t = TaskSpec::command(
                format!("step{i}"),
                format!("simulate > s{i}.trj"),
            )
            .outputs(&[&format!("s{i}.trj")])
            .est(600.0);
            if i > 0 {
                t = t.after(&[&format!("step{}", i - 1)]);
            }
            g.add_task(t).unwrap();
        }
        g
    }

    /// Wide shallow fan of heterogeneous in-memory tasks -> dwork.
    fn wide_shallow(n: usize) -> WorkflowGraph {
        let mut g = WorkflowGraph::new("fan");
        g.add_task(TaskSpec::new("root").est(1.0)).unwrap();
        for i in 0..n {
            // heterogeneous durations: stragglers under static assignment
            let est = 0.2 + 3.0 * (i % 7) as f64;
            g.add_task(
                TaskSpec::kernel(format!("leaf{i}"), "atb_128", i as u64)
                    .after(&["root"])
                    .est(est),
            )
            .unwrap();
        }
        g
    }

    /// Flat uniform bulk-synchronous map -> mpi-list.
    fn flat_map(n: usize) -> WorkflowGraph {
        let mut g = WorkflowGraph::new("map");
        for i in 0..n {
            g.add_task(TaskSpec::kernel(format!("k{i}"), "atb_256", i as u64).est(0.05))
                .unwrap();
        }
        g
    }

    #[test]
    fn picks_pmake_for_deep_file_chain() {
        let rec = select(&deep_file_chain(20), &model(), 864).unwrap();
        assert_eq!(rec.choice, Tool::Pmake, "{}", rec.render());
        assert!(rec.assessment(Tool::Pmake).eligible);
        assert!(rec.assessment(Tool::Pmake).efficiency > 0.9);
    }

    #[test]
    fn picks_dwork_for_wide_shallow_graph() {
        let rec = select(&wide_shallow(200), &model(), 864).unwrap();
        assert_eq!(rec.choice, Tool::Dwork, "{}", rec.render());
        // pmake is out (no files), mpi-list is out (depth 2, heterogeneous)
        assert!(!rec.assessment(Tool::Pmake).eligible);
        assert!(!rec.assessment(Tool::MpiList).eligible);
    }

    #[test]
    fn picks_mpilist_for_flat_bulk_synchronous_map() {
        let rec = select(&flat_map(4096), &model(), 864).unwrap();
        assert_eq!(rec.choice, Tool::MpiList, "{}", rec.render());
        assert!(rec.assessment(Tool::MpiList).eligible);
    }

    #[test]
    fn fine_grained_file_chain_falls_back_to_dwork() {
        // file outputs but millisecond tasks: pmake's launch cost fails
        // the METG test, dwork absorbs it
        let mut g = WorkflowGraph::new("tiny");
        for i in 0..10 {
            let mut t = TaskSpec::command(format!("t{i}"), "true")
                .outputs(&[&format!("t{i}.out")])
                .est(0.005);
            if i > 0 {
                t = t.after(&[&format!("t{}", i - 1)]);
            }
            g.add_task(t).unwrap();
        }
        let rec = select(&g, &model(), 864).unwrap();
        assert_eq!(rec.choice, Tool::Dwork, "{}", rec.render());
        assert!(rec.assessment(Tool::Pmake).efficiency < 0.5);
    }

    #[test]
    fn render_mentions_all_tools() {
        let rec = select(&flat_map(64), &model(), 60).unwrap();
        let txt = rec.render();
        for t in Tool::ALL {
            assert!(txt.contains(t.name()), "missing {} in:\n{txt}", t.name());
        }
        assert!(txt.contains("recommendation"));
    }

    #[test]
    fn efficiency_matches_metg_definition() {
        // at t̄ == METG the estimated efficiency is exactly 50%
        let m = model();
        let mut g = WorkflowGraph::new("edge");
        let metg = m.metg_dwork(864);
        for i in 0..864 {
            g.add_task(TaskSpec::kernel(format!("k{i}"), "atb_64", i).est(metg)).unwrap();
        }
        let rec = select(&g, &m, 864).unwrap();
        let eff = rec.assessment(Tool::Dwork).efficiency;
        assert!((eff - 0.5).abs() < 1e-9, "eff={eff}");
    }
}
