//! Execution drivers behind [`super::session::Session`]: run one
//! [`WorkflowGraph`] to completion on any of the three coordinators.
//!
//! Payload execution is shared: `Command` scripts run under `/bin/sh` in
//! the campaign directory, `Kernel` payloads run the pure-Rust `atb_N`
//! interpreter in-process (no PJRT required), `Noop` is free.  Under
//! pmake, kernels travel as a `#kernel artifact seed` marker line that
//! [`WorkflowExecutor`] intercepts before handing the rest of the script
//! to the shell — a comment to any plain `/bin/sh`, so lowered rules
//! files stay valid standalone pmake inputs.
//!
//! The pre-`Session` free functions (`run_pmake`, `run_dwork_traced`,
//! `dispatch`, the remote triplet, …) finished their one-release
//! deprecation window and are gone; every entry point is a
//! [`super::session::Session`] builder call now.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use crate::coordinator::dwork::{
    self, Client, CreateItem, RefusalCode, StatusInfo, SubmitOutcome,
};
use crate::coordinator::mpilist::{block_range, Context};
use crate::coordinator::pmake::{self, Executor, LaunchReport, ShellExecutor, TaskInstance};
use crate::metg::simmodels::Tool;
use crate::metrics::{Counter, Gauge, MetricsSnapshot, Registry};
use crate::runtime::{atb_tile, fill_f32, host_atb};
use crate::substrate::cluster::Machine;
use crate::trace::{EventKind, Tracer};

use super::graph::{Payload, TaskSpec, WorkflowGraph};
use super::lower;
use super::session::{PollCfg, RankStats};

/// Outcome of one workflow execution.  Semantics are identical across
/// back-ends: `tasks_run` were attempted (success or failure),
/// `tasks_failed` of those failed, `tasks_skipped` never ran because a
/// transitive dependency failed (pmake's poisoned set, dwork's errored
/// successors).
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub coordinator: Tool,
    pub tasks_run: usize,
    pub tasks_failed: usize,
    pub tasks_skipped: usize,
    pub makespan_s: f64,
}

impl RunSummary {
    pub fn all_ok(&self) -> bool {
        self.tasks_failed == 0 && self.tasks_skipped == 0
    }
}

/// Execute a kernel payload in-process: plain `atb_N` runs the host
/// matmul on deterministic seeded inputs (the same numerics the PJRT
/// path produces for these artifacts).  Name parsing and the tile-size
/// bound are shared with the interpreter runtime ([`atb_tile`]).
pub fn exec_kernel(artifact: &str, seed: u64) -> Result<()> {
    let ts = atb_tile(artifact)?;
    let a = fill_f32(ts * ts, seed.wrapping_mul(31).wrapping_add(1));
    let b = fill_f32(ts * ts, seed.wrapping_mul(31).wrapping_add(2));
    let out = host_atb(&a, &b, ts, ts, ts);
    std::hint::black_box(&out);
    Ok(())
}

/// Run a shell payload in `dir`; non-zero exit is an error.
fn exec_command(script: &str, dir: &Path) -> Result<()> {
    let out = std::process::Command::new("/bin/sh")
        .arg("-c")
        .arg(script)
        .current_dir(dir)
        .output()
        .with_context(|| format!("spawning /bin/sh in {dir:?}"))?;
    if !out.status.success() {
        bail!(
            "script exited {}: {}",
            out.status,
            String::from_utf8_lossy(&out.stderr).trim()
        );
    }
    Ok(())
}

/// Execute one payload (shared by the dwork and mpi-list drivers).
pub fn exec_payload(p: &Payload, dir: &Path) -> Result<()> {
    match p {
        Payload::Command { script } => exec_command(script, dir),
        Payload::Kernel { artifact, seed } => exec_kernel(artifact, *seed),
        Payload::Noop => Ok(()),
    }
}

/// Execute one full task: run its payload, then materialize declared
/// outputs the payload itself cannot write (kernel results and no-op
/// markers are not files).  This mirrors the `touch` lines the pmake
/// lowering emits, so a file-consuming successor sees the same world on
/// every coordinator.  Command scripts are responsible for their own
/// declared outputs, exactly as under pmake.
pub fn exec_task(t: &TaskSpec, dir: &Path) -> Result<()> {
    exec_payload(&t.payload, dir)?;
    if !matches!(t.payload, Payload::Command { .. }) {
        for f in &t.outputs {
            let path = dir.join(f);
            if let Some(parent) = path.parent() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating {parent:?}"))?;
            }
            std::fs::File::create(&path).with_context(|| format!("touching {path:?}"))?;
        }
    }
    Ok(())
}

// ------------------------------------------------------------------ pmake

/// pmake executor that understands the `#kernel` marker the lowering
/// emits: the kernel runs in-process, everything else (including the
/// output-file touches) goes through the regular [`ShellExecutor`].
#[derive(Default)]
pub struct WorkflowExecutor {
    pub shell: ShellExecutor,
}

impl Executor for WorkflowExecutor {
    fn launch(&self, task: &TaskInstance) -> LaunchReport {
        if let Some(rest) = task.script.lines().next().and_then(|l| l.strip_prefix("#kernel ")) {
            let parsed = rest
                .split_once(' ')
                .and_then(|(a, s)| s.trim().parse::<u64>().ok().map(|s| (a.to_string(), s)));
            // an unparseable "#kernel ..." line is a user-authored shell
            // comment, not our marker: fall through to the plain shell
            if let Some((artifact, seed)) = parsed {
                let t0 = Instant::now();
                if exec_kernel(&artifact, seed).is_err() {
                    return LaunchReport { success: false, ..Default::default() };
                }
                let kernel_s = t0.elapsed().as_secs_f64();
                let mut report = self.shell.launch(task);
                report.run_s += kernel_s;
                return report;
            }
        }
        self.shell.launch(task)
    }
}

/// Run the workflow under pmake in `dir` (created if missing): lower to
/// rules/targets text, write both files, parse them back (the round-trip
/// is part of the contract), build the file DAG and push it onto the
/// allocation.  Returns the per-target reports next to the summary.
pub(crate) fn pmake_driver(
    g: &WorkflowGraph,
    dir: &Path,
    nodes: usize,
    tracer: &Tracer,
    metrics: &Registry,
) -> Result<(Vec<pmake::RunReport>, RunSummary)> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let dir_str = dir.to_string_lossy().to_string();
    let lowered = lower::to_pmake(g, &dir_str)?;
    // never clobber hand-authored campaign files: the default --dir is
    // the current directory, which may already hold a real rules.yaml
    for (name, text) in [
        ("rules.yaml", lowered.rules_yaml.as_str()),
        ("targets.yaml", lowered.targets_yaml.as_str()),
    ] {
        let path = dir.join(name);
        let foreign = path.exists()
            && std::fs::read_to_string(&path).map(|cur| cur != text).unwrap_or(true);
        if foreign {
            bail!(
                "refusing to overwrite existing {name} in {dir:?} (not produced by this \
                 workflow) — move it or pick another --dir"
            );
        }
        std::fs::write(&path, text).with_context(|| format!("writing {path:?}"))?;
    }
    // parse the text we just wrote (same round-trip pmake::from_workflow
    // performs, without lowering the graph a second time)
    let rules = pmake::parse_rules(&lowered.rules_yaml)?;
    let targets = pmake::parse_targets(&lowered.targets_yaml)?;
    let nodes = nodes.max(1);
    let cfg = pmake::SchedConfig { nodes, machine: Machine::summit(nodes), fifo: false };
    let exec = WorkflowExecutor::default();
    let t0 = Instant::now();
    let mut outcomes = Vec::new();
    for target in &targets {
        let dag = pmake::Dag::build(
            &rules,
            target,
            &|p: &Path| p.exists(),
            &|rs| pmake::default_mpirun(rs),
        )?;
        let report = pmake::run_traced(&dag, &exec, &cfg, tracer)?;
        outcomes.push((dag, report));
    }
    let (run, failed, skipped) = summarize_pmake(&outcomes);
    // driver-level series: pmake pushes jobs itself, so the per-task
    // counts come from the aggregated reports rather than a worker loop
    metrics.add(Counter::DriverTasksLaunched, run as u64);
    metrics.add(Counter::DriverTasksCompleted, (run - failed) as u64);
    metrics.add(Counter::DriverTasksFailed, failed as u64);
    let summary = RunSummary {
        coordinator: Tool::Pmake,
        tasks_run: run,
        tasks_failed: failed,
        tasks_skipped: skipped,
        makespan_s: t0.elapsed().as_secs_f64(),
    };
    Ok((outcomes.into_iter().map(|(_, r)| r).collect(), summary))
}

/// Aggregate per-target reports into workflow-level counts.  Task
/// identity is the instance stem (rule + binding): a shared ancestor
/// reachable from several targets is counted once, not once per target,
/// and once it ran anywhere it leaves the skipped set.
fn summarize_pmake(outcomes: &[(pmake::Dag, pmake::RunReport)]) -> (usize, usize, usize) {
    use std::collections::HashSet;
    let mut ran: HashSet<String> = HashSet::new();
    let mut failed: HashSet<String> = HashSet::new();
    let mut poisoned: HashSet<String> = HashSet::new();
    for (dag, report) in outcomes {
        for &id in &report.succeeded {
            ran.insert(dag.tasks[id].stem());
        }
        for &id in &report.failed {
            let stem = dag.tasks[id].stem();
            ran.insert(stem.clone());
            failed.insert(stem);
        }
        for &id in &report.poisoned {
            poisoned.insert(dag.tasks[id].stem());
        }
    }
    let skipped = poisoned.iter().filter(|s| !ran.contains(*s)).count();
    (ran.len(), failed.len(), skipped)
}

// ------------------------------------------------------------------ dwork

/// Run the workflow under in-proc dwork: seed a dhub from the graph and
/// drain it with `workers` pulling threads.  Returns the hub's final
/// counters and the run's [`MetricsSnapshot`] next to the summary.
///
/// The hub, its state machine, and every worker thread share one
/// registry: the caller's when enabled, otherwise a locally enabled one
/// — so the outcome always carries real counters even when the session
/// never asked for live metrics.
pub(crate) fn dwork_driver(
    g: &WorkflowGraph,
    dir: &Path,
    workers: usize,
    prefetch: u32,
    tracer: &Tracer,
    metrics: &Registry,
) -> Result<(StatusInfo, MetricsSnapshot, RunSummary)> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let registry =
        if metrics.is_enabled() { metrics.clone() } else { Registry::enabled() };
    if g.is_empty() {
        // workers would park forever on a hub that never receives a task
        let summary = RunSummary {
            coordinator: Tool::Dwork,
            tasks_run: 0,
            tasks_failed: 0,
            tasks_skipped: 0,
            makespan_s: 0.0,
        };
        return Ok((StatusInfo::default(), registry.snapshot(), summary));
    }
    // the tracer must be in place BEFORE ingestion so Created events land
    let mut state = dwork::SchedState::new();
    state.set_tracer(tracer.clone());
    state.ingest_workflow(g)?;
    let cfg = dwork::ServerConfig { metrics: registry.clone(), ..dwork::ServerConfig::default() };
    let (connector, handle) = dwork::spawn_inproc(state, cfg);
    // a traced run periodically folds registry deltas into the JSONL
    // stream (schema /3 metric lines), so `trace report` can plot queue
    // depth and inflight over the campaign's lifetime
    let sampler = if tracer.enabled() {
        let stop = Arc::new(AtomicBool::new(false));
        let (reg, tr, stop2) = (registry.clone(), tracer.clone(), stop.clone());
        let h = std::thread::Builder::new()
            .name("metrics-fold".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    tr.record_metric("queue_depth", reg.gauge(Gauge::QueueDepth) as f64);
                    tr.record_metric("tasks_inflight", reg.gauge(Gauge::Inflight) as f64);
                    std::thread::sleep(Duration::from_millis(20));
                }
            })
            .expect("spawn metrics-fold");
        Some((stop, h))
    } else {
        None
    };
    let workers = workers.max(1);
    let t0 = Instant::now();
    let totals: Result<Vec<(u64, u64)>> = std::thread::scope(|s| {
        (0..workers)
            .map(|w| {
                let conn = connector.connect();
                let dir = dir.to_path_buf();
                // server owns the terminal events; workers add Started
                let opts = dwork::WorkerOpts {
                    prefetch,
                    tracer: tracer.clone(),
                    metrics: registry.clone(),
                    ..dwork::WorkerOpts::default()
                };
                s.spawn(move || {
                    // exit-on-drop balances the hub's attach/exit pair, so
                    // the final snapshot shows zero connected workers
                    let mut c =
                        Client::new(Box::new(conn), format!("wf-w{w}")).exit_on_drop(true);
                    let stats = dwork::run_worker_opts(&mut c, &opts, |t| match g.get(&t.name) {
                        // known task: full semantics incl. declared-output
                        // materialization for kernel/noop payloads
                        Some(spec) => exec_task(spec, &dir),
                        // foreign task (shared dhub): body-only execution
                        None => exec_payload(&Payload::decode_body(&t.body)?, &dir),
                    })?;
                    Ok::<(u64, u64), anyhow::Error>((stats.tasks_run, stats.tasks_failed))
                })
            })
            .collect::<Vec<_>>()
            .into_iter()
            .map(|h| h.join().expect("worker panicked"))
            .collect::<Result<Vec<_>>>()
    });
    if let Some((stop, h)) = sampler {
        stop.store(true, Ordering::Relaxed);
        let _ = h.join();
    }
    let totals = totals?;
    let makespan = t0.elapsed().as_secs_f64();
    drop(connector);
    let state = handle.join().expect("dhub panicked");
    if !state.all_done() {
        bail!("dwork run ended with unfinished tasks");
    }
    let tasks_run: usize = totals.iter().map(|&(r, _)| r as usize).sum();
    let summary = RunSummary {
        coordinator: Tool::Dwork,
        tasks_run,
        tasks_failed: totals.iter().map(|&(_, f)| f as usize).sum(),
        // errored successors are finished server-side without ever
        // reaching a worker: they are the skipped set
        tasks_skipped: g.len().saturating_sub(tasks_run),
        makespan_s: makespan,
    };
    Ok((state.status(), registry.snapshot(), summary))
}

// --------------------------------------------------------- dwork (remote)

fn remote_client(addr: &str, role: &str, cfg: &PollCfg) -> Client {
    let conn = crate::substrate::transport::tcp::ReconnectConn::new(addr)
        .with_limits(3, cfg.connect_timeout);
    Client::new(Box::new(conn), format!("wf-{role}-{}", std::process::id()))
}

/// What a remote submission handed the hub: the accounting the await
/// loop needs to turn server-side counters into a [`RunSummary`].
/// Carried by [`super::session::Submission`].
#[derive(Clone, Debug)]
pub struct RemoteSubmission {
    /// tasks the hub accepted (successful Create round-trips, duplicate
    /// acks included)
    pub submitted: usize,
    /// Creates acked as "duplicate".  Either a replay of our own Create
    /// after a reconnect, or a task a previous campaign left on the hub
    /// — and in the latter case it may have finished *before* the
    /// baseline, so the await loop must not demand its completion show
    /// up in the post-baseline deltas (it would hang forever on a
    /// shared hub).
    pub duplicate_acks: usize,
    /// tasks never created because an upstream dependency had already
    /// failed by the time they reached the hub — remote workers race the
    /// submitter, so a fast-failing task can poison dependents that are
    /// still in flight; they join the summary's skipped set
    pub skipped_at_submit: usize,
    /// hub status sampled *before* submission, so a long-lived hub's
    /// previous campaigns don't pollute this run's counts
    pub baseline: StatusInfo,
    /// the hub session the creates landed in.  `None` both for an
    /// anonymous submission and when a pre-session hub degraded the
    /// session to anonymous — the await loop then falls back to the
    /// global counters instead of the per-session row
    pub session: Option<String>,
}

/// Per-item outcome bookkeeping shared by every submission chunk.
/// Items inside one frame are applied by the hub in order, so a refusal
/// of an early item is visible (through `doomed`) when a later item of
/// the *same* frame is classified — that is how a dependent riding in
/// the same chunk as its doomed dependency is recognized: its refusal
/// arrives as `DepMissing` (the dependency was never created), and the
/// doomed set disambiguates that from a genuinely malformed graph.
fn apply_chunk(
    c: &mut Client,
    session: Option<&str>,
    chunk: &mut Vec<CreateItem>,
    doomed: &mut std::collections::HashSet<String>,
    submitted: &mut usize,
    duplicate_acks: &mut usize,
    addr: &str,
) -> Result<()> {
    if chunk.is_empty() {
        return Ok(());
    }
    // a session-scoped chunk travels as a create-only SubmitDelta frame
    // (same per-item outcome contract as CreateBatch)
    let outcomes = match session {
        Some(s) => c.submit_delta(s, &[], chunk),
        None => c.submit(chunk),
    }
    .with_context(|| format!("submitting workflow to {addr}"))?;
    for (item, outcome) in chunk.drain(..).zip(outcomes) {
        match outcome {
            SubmitOutcome::Created => *submitted += 1,
            SubmitOutcome::Refused(e) => match e.code {
                // a reconnect mid-submit can replay a Create the server
                // had already applied; the duplicate refusal IS the ack
                Some(RefusalCode::Duplicate) => {
                    *submitted += 1;
                    *duplicate_acks += 1;
                }
                // a remote worker already ran and failed a dependency
                // while this submission was in flight: the server
                // (correctly) refuses the Create — the task is skipped,
                // like any other dependent of a failure
                Some(RefusalCode::DepErrored) => {
                    doomed.insert(item.task.name);
                }
                // the dependency was doomed earlier (possibly earlier in
                // this very frame) and thus never created: same skip
                Some(RefusalCode::DepMissing)
                    if item.deps.iter().any(|d| doomed.contains(d)) =>
                {
                    doomed.insert(item.task.name);
                }
                _ => {
                    let name = item.task.name;
                    return Err(anyhow::Error::new(e)
                        .context(format!("submitting task {name:?} to {addr}")));
                }
            },
        }
    }
    Ok(())
}

/// Ingest `g` into the remote dhub at `addr`: Create messages in
/// topological order (exactly what the server's Create API requires),
/// chunked `cfg.transport.batch` tasks per wire frame so a 10k-task
/// campaign costs tens of round-trips instead of 10k.  Against a
/// pre-batch hub the client transparently degrades to per-task Creates;
/// the accounting below is identical either way.
pub(crate) fn remote_submit(
    g: &WorkflowGraph,
    addr: &str,
    session: Option<&str>,
    incremental: bool,
    cfg: &PollCfg,
) -> Result<RemoteSubmission> {
    let mut c = remote_client(addr, "submit", cfg);
    let baseline = c.status().with_context(|| format!("querying dhub at {addr}"))?;
    // probe the session up front: a pre-session hub answers the unknown
    // kind, the client pins the degrade, and the whole submission falls
    // back to the anonymous namespace (recorded as session: None so the
    // await loop reads the right counters)
    let session = match session {
        Some(name) => {
            if c.open_session(name).with_context(|| format!("opening session on {addr}"))? {
                Some(name.to_string())
            } else {
                None
            }
        }
        None => None,
    };
    let tasks = if incremental { lower::to_dwork_delta(g)? } else { lower::to_dwork(g)? };
    let batch = cfg.transport.batch.max(1);
    let mut doomed: std::collections::HashSet<String> = std::collections::HashSet::new();
    let mut submitted = 0usize;
    let mut duplicate_acks = 0usize;
    let mut chunk: Vec<CreateItem> = Vec::with_capacity(batch);
    let s = session.as_deref();
    for t in tasks {
        if t.deps.iter().any(|d| doomed.contains(d)) {
            doomed.insert(t.msg.name.clone());
            continue;
        }
        chunk.push(CreateItem::new(t.msg, t.deps));
        if chunk.len() >= batch {
            apply_chunk(&mut c, s, &mut chunk, &mut doomed, &mut submitted, &mut duplicate_acks, addr)?;
        }
    }
    apply_chunk(&mut c, s, &mut chunk, &mut doomed, &mut submitted, &mut duplicate_acks, addr)?;
    Ok(RemoteSubmission {
        submitted,
        duplicate_acks,
        skipped_at_submit: doomed.len(),
        baseline,
        session,
    })
}

/// Block until the submission has drained out of the hub at `addr`, then
/// reconstruct the run summary from the server-side counters:
/// `tasks_run` = completed + failed, `tasks_skipped` = (errored − failed)
/// + skipped-at-submit.  Also returns the final hub counters (the
/// remote [`super::session::BackendDetail`]).
///
/// Termination, in order of preference: the hub reports fully drained,
/// or the post-baseline finish count covers every Create including the
/// duplicate-acked ones (both exact), or — only when duplicate acks make
/// the full count potentially unsatisfiable (the duplicate may have
/// finished *before* the baseline, e.g. leftover state from a previous
/// campaign) — the surely-new count is covered and the hub has shown no
/// further progress for a full stall window.  Counts are exact when this
/// campaign is the only traffic between baseline and drain and the
/// stall fallback did not fire; the fallback can attribute a replayed
/// still-running task's eventual finish to nobody (it returns before
/// that task completes), which is the price of not hanging forever on a
/// shared hub.
/// The campaign-visible (completed, errored, failed) triple: the
/// per-session row when the submission was session-scoped — so other
/// clients' traffic on a shared hub never perturbs the drain math —
/// otherwise the hub-global counters (the historical behavior, and the
/// degrade path against a pre-session hub).
fn campaign_counts(st: &StatusInfo, session: Option<&str>) -> (u64, u64, u64) {
    match session {
        Some(name) => st
            .sessions
            .iter()
            .find(|r| r.name == name)
            .map(|r| (r.completed, r.errored, r.failed))
            .unwrap_or((0, 0, 0)),
        None => (st.completed, st.errored, st.failed),
    }
}

pub(crate) fn remote_await(
    addr: &str,
    submission: &RemoteSubmission,
    cfg: &PollCfg,
) -> Result<(StatusInfo, RunSummary)> {
    let mut c = remote_client(addr, "await", cfg);
    let session = submission.session.as_deref();
    let (base_completed, base_errored, base_failed) =
        campaign_counts(&submission.baseline, session);
    let all = submission.submitted as u64;
    let surely_new = submission.submitted.saturating_sub(submission.duplicate_acks) as u64;
    // "no progress for this many polls" concludes that missing finishes
    // pre-date the baseline and will never appear in the deltas
    const STALL_POLLS: u32 = 10;
    let mut last_finished = u64::MAX;
    let mut stalled = 0u32;
    let t0 = Instant::now();
    loop {
        let st = c.status().with_context(|| format!("polling dhub at {addr}"))?;
        let (now_completed, now_errored, now_failed) = campaign_counts(&st, session);
        let finished =
            (now_completed + now_errored).saturating_sub(base_completed + base_errored);
        if finished == last_finished {
            stalled += 1;
        } else {
            stalled = 0;
            last_finished = finished;
        }
        let done = st.is_drained()
            || finished >= all
            || (finished >= surely_new && stalled >= STALL_POLLS);
        if done {
            let completed = now_completed.saturating_sub(base_completed) as usize;
            let failed = now_failed.saturating_sub(base_failed) as usize;
            let errored = now_errored.saturating_sub(base_errored) as usize;
            let summary = RunSummary {
                coordinator: Tool::Dwork,
                tasks_run: completed + failed,
                tasks_failed: failed,
                tasks_skipped: errored.saturating_sub(failed) + submission.skipped_at_submit,
                makespan_s: t0.elapsed().as_secs_f64(),
            };
            return Ok((st, summary));
        }
        std::thread::sleep(cfg.poll);
    }
}

/// Best-effort fetch of a remote hub's live metrics: `None` when the
/// hub predates the Metrics request (it answers Err for the unknown
/// kind) or runs with its registry disabled (version-0 snapshot).
pub(crate) fn remote_metrics(addr: &str, cfg: &PollCfg) -> Option<MetricsSnapshot> {
    let mut c = remote_client(addr, "metrics", cfg);
    c.metrics().ok().filter(|m| m.version != 0)
}

// --------------------------------------------------------------- mpi-list

/// Run the workflow under mpi-list: `procs` in-process SPMD ranks execute
/// the static plan phase by phase, with a barrier after each phase and no
/// other synchronization.  Returns per-rank stats next to the summary.
pub(crate) fn mpilist_driver(
    g: &WorkflowGraph,
    dir: &Path,
    procs: usize,
    tracer: &Tracer,
    metrics: &Registry,
) -> Result<(Vec<RankStats>, RunSummary)> {
    std::fs::create_dir_all(dir).with_context(|| format!("creating {dir:?}"))?;
    let procs = procs.max(1);
    let plan = lower::to_mpilist(g, procs)?;
    for t in g.tasks() {
        tracer.record(&t.name, EventKind::Created, "");
    }
    let t0 = Instant::now();
    let per_rank: Vec<(usize, usize)> = Context::run(procs, |ctx| {
        let mut run = 0usize;
        let mut failed = 0usize;
        let who = format!("rank{}", ctx.rank());
        for level in &plan.levels {
            let (start, count) = block_range(ctx.rank(), procs, level.len() as u64);
            // every task of the block is Ready the moment its rank enters
            // the phase; Launched−Ready is then the rank-serialization
            // wait (earlier block elements), matching the DES model and
            // the report's queue-wait semantics
            for k in start..start + count {
                tracer.record(&g.tasks()[level[k as usize]].name, EventKind::Ready, "");
            }
            for k in start..start + count {
                let t = &g.tasks()[level[k as usize]];
                tracer.record(&t.name, EventKind::Launched, &who);
                tracer.record(&t.name, EventKind::Started, &who);
                metrics.inc(Counter::DriverTasksLaunched);
                run += 1;
                let ok = exec_task(t, dir).is_ok();
                if ok {
                    metrics.inc(Counter::DriverTasksCompleted);
                } else {
                    metrics.inc(Counter::DriverTasksFailed);
                    failed += 1;
                }
                tracer.record(
                    &t.name,
                    if ok { EventKind::Finished } else { EventKind::Failed },
                    &who,
                );
            }
            // the phase barrier IS the synchronization mechanism
            ctx.comm.barrier();
        }
        (run, failed)
    });
    let ranks: Vec<RankStats> = per_rank
        .iter()
        .enumerate()
        .map(|(rank, &(tasks_run, tasks_failed))| RankStats { rank, tasks_run, tasks_failed })
        .collect();
    let summary = RunSummary {
        coordinator: Tool::MpiList,
        tasks_run: per_rank.iter().map(|&(r, _)| r).sum(),
        tasks_failed: per_rank.iter().map(|&(_, f)| f).sum(),
        // the static plan runs every task regardless of upstream failures
        tasks_skipped: 0,
        makespan_s: t0.elapsed().as_secs_f64(),
    };
    Ok((ranks, summary))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::graph::TaskSpec;
    use crate::workflow::session::{Backend, Session};
    use std::path::PathBuf;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!(
            "threesched-wfrun-{name}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn pmake_session<'g>(g: &'g WorkflowGraph, dir: &Path, nodes: usize) -> Session<'g> {
        Session::new(g).backend(Backend::Pmake).parallelism(nodes).dir(dir)
    }

    fn file_pipeline() -> WorkflowGraph {
        let mut g = WorkflowGraph::new("pipe");
        g.add_task(TaskSpec::command("gen", "echo 7 > data.txt").outputs(&["data.txt"]))
            .unwrap();
        g.add_task(TaskSpec::kernel("crunch", "atb_32", 5).after(&["gen"])).unwrap();
        g.add_task(
            TaskSpec::command("sum", "cp data.txt sum.txt")
                .outputs(&["sum.txt"])
                .after(&["gen", "crunch"]),
        )
        .unwrap();
        g
    }

    #[test]
    fn kernel_exec_runs_atb_only() {
        assert!(exec_kernel("atb_16", 3).is_ok());
        assert!(exec_kernel("mystery", 3).is_err());
    }

    #[test]
    fn apply_chunk_classifies_per_item_refusals() {
        use crate::coordinator::dwork::{
            spawn_inproc, Completion, SchedState, ServerConfig, TaskMsg,
        };
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "wf-submit-test");
        // seed server-side state: "dup" already exists, "boom" has failed
        // (so a dependent's Create is refused DepErrored)
        assert!(c.submit(&[
            CreateItem::new(TaskMsg::new("dup", vec![]), vec![]),
            CreateItem::new(TaskMsg::new("boom", vec![]), vec![]),
        ])
        .unwrap()
        .iter()
        .all(SubmitOutcome::is_created));
        let got = c.acquire(2).unwrap();
        let got = match got {
            crate::coordinator::dwork::StealBatch::Tasks(t) => t,
            other => panic!("expected tasks, got {other:?}"),
        };
        assert_eq!(got.len(), 2);
        c.report(&[Completion::ok("dup"), Completion::failed("boom")]).unwrap();

        // one mixed chunk: a fresh create, a duplicate ack, a dependent
        // of an errored task, and a dependent of a task doomed upstream
        // (its dep is only in `doomed`, never created — DepMissing)
        let mut doomed: std::collections::HashSet<String> =
            ["gone".to_string()].into_iter().collect();
        let mut submitted = 0usize;
        let mut duplicate_acks = 0usize;
        let mut chunk = vec![
            CreateItem::new(TaskMsg::new("fresh", vec![]), vec![]),
            CreateItem::new(TaskMsg::new("dup", vec![]), vec![]),
            CreateItem::new(TaskMsg::new("kid-of-boom", vec![]), vec!["boom".into()]),
            CreateItem::new(TaskMsg::new("kid-of-gone", vec![]), vec!["gone".into()]),
        ];
        apply_chunk(
            &mut c, None, &mut chunk, &mut doomed, &mut submitted, &mut duplicate_acks, "inproc",
        )
        .unwrap();
        assert!(chunk.is_empty(), "chunk drains on success");
        assert_eq!(submitted, 2, "fresh + duplicate-ack");
        assert_eq!(duplicate_acks, 1);
        assert!(doomed.contains("kid-of-boom"), "DepErrored dooms the dependent");
        assert!(doomed.contains("kid-of-gone"), "DepMissing with doomed dep dooms too");

        // a DepMissing refusal whose dep was never doomed is a real
        // error (malformed graph / foreign hub state), not a skip
        let mut chunk =
            vec![CreateItem::new(TaskMsg::new("orphan", vec![]), vec!["ghost".into()])];
        let err = apply_chunk(
            &mut c, None, &mut chunk, &mut doomed, &mut submitted, &mut duplicate_acks, "inproc",
        )
        .unwrap_err();
        assert!(err.to_string().contains("orphan"), "{err}");
        drop(c);
        drop(connector);
        let _ = handle.join();
    }

    #[test]
    fn kernel_declared_outputs_materialize_on_every_backend() {
        // a command consumes a file that only exists because the kernel
        // task DECLARED it — under pmake the lowering touches it, under
        // dwork/mpilist exec_task must do the same
        let mut g = WorkflowGraph::new("kout");
        g.add_task(TaskSpec::kernel("k", "atb_16", 1).outputs(&["k.out"])).unwrap();
        g.add_task(
            TaskSpec::command("c", "test -f k.out && touch c.ok")
                .outputs(&["c.ok"])
                .after(&["k"]),
        )
        .unwrap();
        for tool in Tool::ALL {
            let dir = tmp(&format!("kout-{}", tool.name().replace('-', "")));
            let outcome = Session::new(&g)
                .backend(Backend::from_tool(tool))
                .parallelism(2)
                .dir(&dir)
                .run()
                .unwrap();
            assert!(outcome.all_ok(), "{}: {:?}", tool.name(), outcome.summary);
            assert!(dir.join("c.ok").exists(), "{}", tool.name());
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn nested_declared_outputs_work_on_every_backend() {
        let mut g = WorkflowGraph::new("nested");
        g.add_task(TaskSpec::kernel("k", "atb_16", 2).outputs(&["results/k.out"])).unwrap();
        g.add_task(
            TaskSpec::command("c", "test -f results/k.out && touch ok.txt")
                .outputs(&["ok.txt"])
                .after(&["k"]),
        )
        .unwrap();
        for tool in Tool::ALL {
            let dir = tmp(&format!("nested-{}", tool.name().replace('-', "")));
            let outcome = Session::new(&g)
                .backend(Backend::from_tool(tool))
                .parallelism(2)
                .dir(&dir)
                .run()
                .unwrap();
            assert!(outcome.all_ok(), "{}: {:?}", tool.name(), outcome.summary);
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn run_pmake_refuses_to_clobber_foreign_rules() {
        let g = file_pipeline();
        let dir = tmp("clobber");
        std::fs::write(dir.join("rules.yaml"), "hand: made\n").unwrap();
        let err = pmake_session(&g, &dir, 1).run().unwrap_err();
        assert!(err.to_string().contains("refusing to overwrite"), "{err}");
        assert_eq!(
            std::fs::read_to_string(dir.join("rules.yaml")).unwrap(),
            "hand: made\n",
            "foreign file untouched"
        );
        // rerunning over our OWN previous output is fine
        let _ = std::fs::remove_file(dir.join("rules.yaml"));
        pmake_session(&g, &dir, 1).run().unwrap();
        let outcome = pmake_session(&g, &dir, 1).run().unwrap();
        assert!(outcome.all_ok(), "{:?}", outcome.summary);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn user_comment_starting_with_kernel_marker_falls_through_to_shell() {
        let mut g = WorkflowGraph::new("marker");
        g.add_task(TaskSpec::command("c", "#kernel warmup notes\ntouch ran.txt")
            .outputs(&["ran.txt"]))
            .unwrap();
        let dir = tmp("marker");
        let outcome = pmake_session(&g, &dir, 1).run().unwrap();
        assert!(outcome.all_ok(), "{:?}", outcome.summary);
        assert!(dir.join("ran.txt").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mpilist_counts_failures_but_finishes() {
        let mut g = WorkflowGraph::new("mixed");
        for i in 0..6 {
            let script = if i == 2 { "false" } else { "true" };
            g.add_task(TaskSpec::command(format!("t{i}"), script)).unwrap();
        }
        let dir = tmp("mpilist-fail");
        let outcome =
            Session::new(&g).backend(Backend::MpiList).parallelism(3).dir(&dir).run().unwrap();
        assert_eq!(outcome.summary.tasks_run, 6);
        assert_eq!(outcome.summary.tasks_failed, 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn pmake_shared_ancestor_counts_once_across_targets() {
        // regression: tasks_run/failed/poisoned were summed per target, so
        // an ancestor reachable from two targets counted twice.  Shared
        // failing ancestor: both target DAGs instantiate it (its output
        // never appears), both reports blame it, the summary must not.
        let rules_text = "\
gen:
  resources: {time: 0.01, nrs: 1, cpu: 1, gpu: 0, ranks: 1}
  out:
    o0: \"gen.txt\"
  script: |
    false
a:
  resources: {time: 0.01, nrs: 1, cpu: 1, gpu: 0, ranks: 1}
  inp:
    d0: \"gen.txt\"
  out:
    o0: \"a.txt\"
  script: |
    touch a.txt
b:
  resources: {time: 0.01, nrs: 1, cpu: 1, gpu: 0, ranks: 1}
  inp:
    d0: \"gen.txt\"
  out:
    o0: \"b.txt\"
  script: |
    touch b.txt
";
        let targets_text = "\
ta:
  dirname: \"/tmp/unused\"
  out:
    s0: \"a.txt\"
tb:
  dirname: \"/tmp/unused\"
  out:
    s0: \"b.txt\"
";
        struct FailGen;
        impl Executor for FailGen {
            fn launch(&self, task: &TaskInstance) -> LaunchReport {
                LaunchReport { success: task.rule != "gen", ..Default::default() }
            }
        }
        let rules = pmake::parse_rules(rules_text).unwrap();
        let targets = pmake::parse_targets(targets_text).unwrap();
        assert_eq!(targets.len(), 2);
        let cfg = pmake::SchedConfig::default();
        let mut outcomes = Vec::new();
        for target in &targets {
            let dag = pmake::Dag::build(
                &rules,
                target,
                &|_: &Path| false, // no outputs ever appear: gen fails
                &|rs| pmake::default_mpirun(rs),
            )
            .unwrap();
            let report = pmake::run(&dag, &FailGen, &cfg).unwrap();
            outcomes.push((dag, report));
        }
        // naive per-target summing sees gen twice
        let naive_run: usize = outcomes
            .iter()
            .map(|(_, r)| r.succeeded.len() + r.failed.len())
            .sum();
        assert_eq!(naive_run, 2, "precondition: both targets ran the shared ancestor");
        let (run, failed, skipped) = summarize_pmake(&outcomes);
        assert_eq!(run, 1, "shared ancestor must count once");
        assert_eq!(failed, 1);
        assert_eq!(skipped, 2, "a and b are distinct skipped tasks");
    }

    #[test]
    fn empty_workflow_zero_summary_under_dwork() {
        let g = WorkflowGraph::new("void");
        let dir = tmp("dwork-empty");
        let outcome = Session::new(&g)
            .backend(Backend::Dwork { remote: None, session: None })
            .parallelism(2)
            .dir(&dir)
            .run()
            .unwrap();
        assert_eq!(outcome.summary.tasks_run, 0);
        assert!(outcome.all_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    // NOTE: the TCP remote-driver equivalence path (remote Session vs
    // in-proc over real sockets, failure propagation, worker death) is
    // covered end-to-end in rust/tests/dwork_remote.rs — not duplicated
    // here.  Session-vs-legacy-shim equivalence on random DAGs lives in
    // rust/tests/session_api.rs.
}
