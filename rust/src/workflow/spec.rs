//! YAML front-end for the workflow IR (reuses [`crate::substrate::yaml`]).
//!
//! Format (one document per workflow):
//!
//! ```yaml
//! name: docking-campaign
//! tasks:
//!   - name: prep
//!     script: |
//!       echo ready > prep.out
//!     outputs: [prep.out]
//!     est: 30
//!     resources: {time: 1, nrs: 1, cpu: 1}
//!   - name: dock-0
//!     kernel: atb_128
//!     seed: 7
//!     after: [prep]
//!     est: 0.5
//! ```
//!
//! Fields per task: `name` (required); exactly one of `script` / `kernel`
//! (otherwise the task is a no-op barrier); `seed` (kernel only); `after`,
//! `inputs`, `outputs` (lists or comma strings); `est` (seconds);
//! `resources` (pmake-style flow map).

use anyhow::{anyhow, bail, Context, Result};

use crate::substrate::cluster::ResourceSet;
use crate::substrate::yaml::{self, Yaml};

use super::graph::{Payload, TaskSpec, WorkflowGraph};

/// Parse a workflow document.  The graph is validated (acyclic, closed,
/// race-free) — use [`parse_workflow_loose`] to get a possibly-broken
/// graph for the analyzer to report on.
pub fn parse_workflow(src: &str) -> Result<WorkflowGraph> {
    let g = parse_workflow_loose(src)?;
    g.validate()?;
    Ok(g)
}

/// Parse without validating: syntax and per-task field errors still
/// fail (with source line numbers, e.g. `line 17: tasks[2]: task
/// "prep": outputs must be a list …`), but graph-level defects (cycles,
/// races, dangling deps) are admitted so `workflow lint` can report all
/// of them at once instead of dying on the first.
pub fn parse_workflow_loose(src: &str) -> Result<WorkflowGraph> {
    let doc = yaml::parse(src)?;
    let name = doc
        .get("name")
        .and_then(|y| y.as_text())
        .unwrap_or_else(|| "workflow".to_string());
    let mut g = WorkflowGraph::new(name);
    let Some(tasks) = doc.get("tasks").and_then(Yaml::as_list) else {
        bail!("workflow document needs a `tasks:` list");
    };
    let item_lines = yaml::list_item_lines(src, "tasks");
    for (i, entry) in tasks.iter().enumerate() {
        let task = parse_task(entry).with_context(|| match item_lines.get(i) {
            Some(line) => format!("line {line}: tasks[{i}]"),
            None => format!("tasks[{i}]"),
        })?;
        g.add_task(task)?;
    }
    Ok(g)
}

pub fn parse_workflow_file(path: &std::path::Path) -> Result<WorkflowGraph> {
    let src =
        std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    parse_workflow(&src).with_context(|| format!("parsing {path:?}"))
}

/// File form of [`parse_workflow_loose`] (the `workflow lint` entry
/// point: parse errors are fatal, graph defects become diagnostics).
pub fn parse_workflow_file_loose(path: &std::path::Path) -> Result<WorkflowGraph> {
    let src =
        std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
    parse_workflow_loose(&src).with_context(|| format!("parsing {path:?}"))
}

fn string_list(y: &Yaml, what: &str) -> Result<Vec<String>> {
    match y {
        Yaml::List(items) => items
            .iter()
            .map(|v| v.as_text().ok_or_else(|| anyhow!("{what}: list items must be scalars")))
            .collect(),
        // "a, b, c" convenience form
        _ => match y.as_text() {
            Some(t) if t.trim().is_empty() => Ok(vec![]),
            Some(t) => Ok(t.split(',').map(|s| s.trim().to_string()).collect()),
            None => bail!("{what} must be a list or a comma-separated string"),
        },
    }
}

fn parse_resources(y: &Yaml, what: &str) -> Result<ResourceSet> {
    let mut rs = ResourceSet::default();
    let Some(m) = y.as_map() else {
        bail!("{what} must be a mapping like {{time: 10, nrs: 1, cpu: 1}}")
    };
    for (k, v) in m {
        let num = v
            .as_f64()
            .ok_or_else(|| anyhow!("{what}.{k} must be numeric"))?;
        match k.as_str() {
            "time" => rs.time_min = num,
            "nrs" => rs.nrs = num as usize,
            "cpu" => rs.cpu = num as usize,
            "gpu" => rs.gpu = num as usize,
            "ranks" => rs.ranks_per_rs = (num as usize).max(1),
            other => bail!("{what}: unknown resource key {other:?}"),
        }
    }
    Ok(rs)
}

fn parse_task(y: &Yaml) -> Result<TaskSpec> {
    let Some(members) = y.as_map() else {
        bail!("each task must be a mapping");
    };
    let name = y
        .get("name")
        .and_then(|v| v.as_text())
        .ok_or_else(|| anyhow!("task needs a name"))?;
    let mut t = TaskSpec::new(name.clone());
    let mut script: Option<String> = None;
    let mut kernel: Option<String> = None;
    let mut seed: Option<u64> = None;
    for (k, v) in members {
        match k.as_str() {
            "name" => {}
            "script" => {
                script = Some(
                    v.as_text()
                        .ok_or_else(|| anyhow!("task {name}: script must be text"))?,
                )
            }
            "kernel" => {
                kernel = Some(
                    v.as_text()
                        .ok_or_else(|| anyhow!("task {name}: kernel must be a name"))?,
                )
            }
            "seed" => {
                seed = Some(
                    v.as_i64()
                        .and_then(|i| u64::try_from(i).ok())
                        .ok_or_else(|| anyhow!("task {name}: seed must be a non-negative int"))?,
                )
            }
            "after" => t.after = string_list(v, &format!("task {name}: after"))?,
            "inputs" => t.inputs = string_list(v, &format!("task {name}: inputs"))?,
            "outputs" => t.outputs = string_list(v, &format!("task {name}: outputs"))?,
            "est" => {
                t.est_s = v
                    .as_f64()
                    .ok_or_else(|| anyhow!("task {name}: est must be numeric (seconds)"))?
            }
            "resources" => t.resources = parse_resources(v, &format!("task {name}: resources"))?,
            other => bail!("task {name}: unknown field {other:?}"),
        }
    }
    if seed.is_some() && kernel.is_none() {
        bail!("task {name}: seed only applies to kernel tasks");
    }
    t.payload = match (script, kernel) {
        (Some(_), Some(_)) => bail!("task {name}: script and kernel are mutually exclusive"),
        (Some(s), None) => Payload::Command { script: s.trim_end().to_string() },
        (None, Some(a)) => Payload::Kernel { artifact: a, seed: seed.unwrap_or(0) },
        (None, None) => Payload::Noop,
    };
    Ok(t)
}

/// Serialize a graph back to the YAML front-end format (round-trip aid +
/// `workflow lower` output for humans).
pub fn to_yaml(g: &WorkflowGraph) -> String {
    let mut out = String::new();
    out.push_str(&format!("name: {}\ntasks:\n", g.name));
    for t in g.tasks() {
        out.push_str(&format!("  - name: {}\n", t.name));
        match &t.payload {
            Payload::Command { script } => {
                out.push_str("    script: |\n");
                for line in script.lines() {
                    out.push_str(&format!("      {line}\n"));
                }
            }
            Payload::Kernel { artifact, seed } => {
                out.push_str(&format!("    kernel: {artifact}\n    seed: {seed}\n"));
            }
            Payload::Noop => {}
        }
        let list = |items: &[String]| {
            items.iter().map(|s| format!("\"{s}\"")).collect::<Vec<_>>().join(", ")
        };
        if !t.after.is_empty() {
            out.push_str(&format!("    after: [{}]\n", list(&t.after)));
        }
        if !t.inputs.is_empty() {
            out.push_str(&format!("    inputs: [{}]\n", list(&t.inputs)));
        }
        if !t.outputs.is_empty() {
            out.push_str(&format!("    outputs: [{}]\n", list(&t.outputs)));
        }
        out.push_str(&format!("    est: {}\n", t.est_s));
        let r = &t.resources;
        out.push_str(&format!(
            "    resources: {{time: {}, nrs: {}, cpu: {}, gpu: {}, ranks: {}}}\n",
            r.time_min, r.nrs, r.cpu, r.gpu, r.ranks_per_rs
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const WF: &str = r#"
name: demo
tasks:
  - name: prep
    script: |
      echo ready > prep.out
    outputs: [prep.out]
    est: 30
    resources: {time: 1, nrs: 1, cpu: 1}
  - name: dock-0
    kernel: atb_128
    seed: 7
    after: [prep]
    est: 0.5
  - name: dock-1
    kernel: atb_128
    seed: 8
    after: [prep]
    est: 0.5
  - name: report
    script: "echo done > report.txt"
    outputs: [report.txt]
    after: "dock-0, dock-1"
    est: 2
"#;

    #[test]
    fn parses_demo() {
        let g = parse_workflow(WF).unwrap();
        assert_eq!(g.name, "demo");
        assert_eq!(g.len(), 4);
        let prep = g.get("prep").unwrap();
        assert!(matches!(&prep.payload, Payload::Command { script } if script.contains("prep.out")));
        assert_eq!(prep.outputs, vec!["prep.out"]);
        assert!((prep.est_s - 30.0).abs() < 1e-12);
        assert!((prep.resources.time_min - 1.0).abs() < 1e-12);
        let d0 = g.get("dock-0").unwrap();
        assert_eq!(d0.payload, Payload::Kernel { artifact: "atb_128".into(), seed: 7 });
        assert_eq!(d0.after, vec!["prep"]);
        // comma-string form of after
        let rep = g.get("report").unwrap();
        assert_eq!(rep.after, vec!["dock-0", "dock-1"]);
    }

    #[test]
    fn yaml_roundtrip_preserves_graph() {
        let g = parse_workflow(WF).unwrap();
        let g2 = parse_workflow(&to_yaml(&g)).unwrap();
        assert_eq!(g.len(), g2.len());
        for t in g.tasks() {
            let t2 = g2.get(&t.name).expect("task survives roundtrip");
            assert_eq!(t.payload, t2.payload, "{}", t.name);
            assert_eq!(t.after, t2.after);
            assert_eq!(t.outputs, t2.outputs);
            assert!((t.est_s - t2.est_s).abs() < 1e-12);
        }
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_workflow("name: x\n").is_err(), "no tasks list");
        assert!(parse_workflow("tasks:\n  - script: echo\n").is_err(), "no name");
        assert!(
            parse_workflow("tasks:\n  - name: a\n    script: x\n    kernel: y\n").is_err(),
            "script+kernel"
        );
        assert!(
            parse_workflow("tasks:\n  - name: a\n    bogus: 1\n").is_err(),
            "unknown field"
        );
        assert!(
            parse_workflow("tasks:\n  - name: a\n    script: x\n    seed: 4\n").is_err(),
            "seed without kernel"
        );
        assert!(
            parse_workflow("tasks:\n  - name: a\n    after: [ghost]\n").is_err(),
            "dangling dep"
        );
        assert!(
            parse_workflow("tasks:\n  - name: a\n    after: [b]\n  - name: b\n    after: [a]\n")
                .is_err(),
            "cycle"
        );
    }

    #[test]
    fn parse_errors_carry_line_numbers() {
        // the bad entry (`outputs` as a flow map) starts on source line 5
        let src = "name: x\ntasks:\n  - name: ok\n    est: 1\n  - name: bad\n    outputs: {a: 1}\n";
        let err = parse_workflow(src).unwrap_err();
        let chain = format!("{err:#}");
        assert!(chain.contains("line 5: tasks[1]"), "{chain}");
        assert!(chain.contains("outputs must be a list"), "{chain}");
        // loose parse admits graph-level defects for the analyzer…
        let racy = "tasks:\n  - name: a\n    after: [ghost]\n";
        let g = parse_workflow_loose(racy).unwrap();
        assert_eq!(g.len(), 1);
        // …which strict parsing still refuses
        assert!(parse_workflow(racy).is_err());
    }

    #[test]
    fn defaults() {
        let g = parse_workflow("tasks:\n  - name: only\n").unwrap();
        let t = g.get("only").unwrap();
        assert_eq!(t.payload, Payload::Noop);
        assert!((t.est_s - 1.0).abs() < 1e-12);
        assert_eq!(g.name, "workflow");
    }
}
