//! The unified execution API: one builder, one outcome type, every
//! back-end.
//!
//! Before this module, every cross-cutting execution feature doubled the
//! driver surface: `run_pmake`/`run_pmake_traced`, `run_dwork` plus a
//! remote triplet, per-call `RemoteOpts`, a calibration side-channel on
//! some entry points and not others.  [`Session`] collapses all of it
//! into one context object that owns the graph reference, the execution
//! target, and the telemetry/calibration hooks — the shape task-server
//! systems like Rain and Balsam converged on — so new scenarios (new
//! back-ends, remote fan-out, elastic pools) are additive data on
//! [`Backend`], not new function families.
//!
//! ```no_run
//! use threesched::workflow::{Backend, BackendDetail, Session, TaskSpec, WorkflowGraph};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut g = WorkflowGraph::new("demo");
//! g.add_task(TaskSpec::command("gen", "echo hi > out.txt").outputs(&["out.txt"]))?;
//! g.add_task(TaskSpec::kernel("crunch", "atb_32", 7).after(&["gen"]))?;
//!
//! // inspect what would run, without running it
//! let plan = Session::new(&g).parallelism(4).plan()?;
//! println!("{}", plan.render());
//!
//! // run it (Backend::Auto is the default: the selector picks)
//! let outcome = Session::new(&g)
//!     .backend(Backend::Auto)
//!     .parallelism(4)
//!     .dir("/tmp/demo")
//!     .run()?;
//! println!(
//!     "{}: {} tasks run, {} failed",
//!     outcome.summary.coordinator.name(),
//!     outcome.summary.tasks_run,
//!     outcome.summary.tasks_failed
//! );
//!
//! // a dwork run always carries the hub's final live-metrics snapshot
//! if let BackendDetail::Dwork { metrics, .. } = &outcome.detail {
//!     println!(
//!         "steals served: {} (p99 steal service {:.1} µs)",
//!         metrics.counter("steals_served"),
//!         metrics.hist("service_steal").map_or(0.0, |h| h.quantile(0.99) * 1e6),
//!     );
//! }
//! # Ok(()) }
//! ```

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context as _, Result};

use crate::calibrate::CalibrationProfile;
use crate::coordinator::dwork::{self, Client, StatusInfo};
use crate::coordinator::pmake;
use crate::metg::simmodels::Tool;
use crate::metrics::{MetricsSnapshot, Registry};
use crate::substrate::cluster::costs::CostModel;
use crate::substrate::transport::tcp::TcpClient;
use crate::substrate::transport::TransportCfg;
use crate::trace::Tracer;

use super::graph::{Payload, WorkflowGraph};
use super::lower::{self, DworkTask, LoweredPmake, MpiListPlan};
use super::run::{self, RemoteSubmission, RunSummary};
use super::select::{select, Recommendation};

// ----------------------------------------------------------------- config

/// Where a [`Session`] executes.  Execution modes are *data*: the remote
/// dwork deployment is a field on [`Backend::Dwork`], not a separate
/// function family.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum Backend {
    /// Let the METG + shape selector pick (the default).
    #[default]
    Auto,
    /// File-synchronized parallel make.
    Pmake,
    /// The task-list server; `remote: Some(..)` feeds a long-lived TCP
    /// dhub instead of spawning an in-proc hub + worker threads, and
    /// `session: Some(..)` scopes every submitted task to a named hub
    /// session (per-client namespace on a shared hub; see
    /// [`Session::submit_incremental`]).  Against a pre-session hub the
    /// session name degrades to today's anonymous behavior.
    Dwork { remote: Option<RemoteTarget>, session: Option<String> },
    /// Static bulk-synchronous rank lists.
    MpiList,
}

impl Backend {
    /// The explicit backend for a coordinator the caller already chose.
    pub fn from_tool(tool: Tool) -> Backend {
        match tool {
            Tool::Pmake => Backend::Pmake,
            Tool::Dwork => Backend::Dwork { remote: None, session: None },
            Tool::MpiList => Backend::MpiList,
        }
    }

    /// Parse a CLI-style name: `auto | pmake | dwork | mpilist | mpi-list`.
    pub fn from_name(name: &str) -> Option<Backend> {
        match name {
            "auto" => Some(Backend::Auto),
            "pmake" => Some(Backend::Pmake),
            "dwork" => Some(Backend::Dwork { remote: None, session: None }),
            "mpilist" | "mpi-list" => Some(Backend::MpiList),
            _ => None,
        }
    }
}

/// A remote dhub to feed over TCP (`threesched dhub serve`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RemoteTarget {
    pub addr: String,
}

impl RemoteTarget {
    pub fn new(addr: impl Into<String>) -> RemoteTarget {
        RemoteTarget { addr: addr.into() }
    }
}

impl From<&str> for RemoteTarget {
    fn from(addr: &str) -> RemoteTarget {
        RemoteTarget::new(addr)
    }
}

impl From<String> for RemoteTarget {
    fn from(addr: String) -> RemoteTarget {
        RemoteTarget::new(addr)
    }
}

/// Polling knobs for the remote paths (the successor of the old
/// `RemoteOpts`): how often to poll a hub for completion, how long to
/// keep dialing one that is not up yet, and the wire-level transport
/// knobs (socket timeout, redial backoff, batch size) that used to be
/// hard-coded constants.
#[derive(Clone, Debug)]
pub struct PollCfg {
    /// status-poll interval while awaiting completion
    pub poll: Duration,
    /// how long to keep dialing a hub that is not up yet
    pub connect_timeout: Duration,
    /// socket timeout / redial backoff / batched-wire chunk size
    pub transport: TransportCfg,
}

impl Default for PollCfg {
    fn default() -> Self {
        PollCfg {
            poll: Duration::from_millis(50),
            connect_timeout: Duration::from_secs(10),
            transport: TransportCfg::default(),
        }
    }
}

fn default_parallelism() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2)
}

// ------------------------------------------------------------------- plan

/// The resolved execution decision: which coordinator, at what scale,
/// against which target — plus the selector's full reasoning when the
/// backend was [`Backend::Auto`].  Produced by [`Session::plan`] without
/// executing anything.
#[derive(Clone, Debug)]
pub struct Plan {
    /// the coordinator that will run the graph
    pub tool: Tool,
    /// nodes (pmake) / workers (dwork) / ranks (mpi-list); 0 for remote
    /// deployments, where execution parallelism is whatever worker
    /// pools joined the hub
    pub parallelism: usize,
    /// remote dhub target, when the dwork deployment is distributed
    pub remote: Option<RemoteTarget>,
    /// hub session the campaign is scoped to (dwork only; `None` =
    /// the anonymous namespace)
    pub session: Option<String>,
    /// the selector's assessments; `Some` iff the backend was `Auto`
    pub recommendation: Option<Recommendation>,
}

impl Plan {
    /// Human-facing report: the selector's full table for `Auto`, a
    /// one-liner for an explicitly forced backend.
    pub fn render(&self) -> String {
        match (&self.recommendation, &self.remote) {
            (Some(rec), _) => rec.render(),
            (None, Some(t)) => format!(
                "backend: {} (remote dhub at {}; parallelism = whatever worker pools \
                 joined the hub)\n",
                self.tool.name(),
                t.addr
            ),
            (None, None) => format!(
                "backend: {} (explicit, selector bypassed) at parallelism {}\n",
                self.tool.name(),
                self.parallelism
            ),
        }
    }
}

/// A lowered (but not executed) workflow, from [`Session::lower`].
#[derive(Clone, Debug)]
pub enum Lowered {
    /// pmake `rules.yaml` / `targets.yaml` text
    Pmake(LoweredPmake),
    /// dwork task list in topological creation order
    Dwork(Vec<DworkTask>),
    /// mpi-list static bulk-synchronous rank plan
    MpiList(MpiListPlan),
}

// ---------------------------------------------------------------- outcome

/// Per-rank accounting from an mpi-list run.
#[derive(Clone, Copy, Debug, Default)]
pub struct RankStats {
    pub rank: usize,
    pub tasks_run: usize,
    pub tasks_failed: usize,
}

/// What each back-end knows beyond the common [`RunSummary`] view.
#[derive(Clone, Debug)]
pub enum BackendDetail {
    /// one [`pmake::RunReport`] per target (launch overhead, launch
    /// order, per-target makespan)
    Pmake { reports: Vec<pmake::RunReport> },
    /// final hub counters after the in-proc run drained, plus the final
    /// [`MetricsSnapshot`] — always populated (the driver enables a
    /// local registry when the session's is disabled)
    Dwork { server: StatusInfo, metrics: MetricsSnapshot },
    /// what was handed to the remote hub, and its counters at drain;
    /// `metrics` is best-effort — `None` when the hub predates the
    /// Metrics request or runs with its registry disabled
    DworkRemote {
        submission: RemoteSubmission,
        server: StatusInfo,
        metrics: Option<MetricsSnapshot>,
    },
    /// per-rank run/failed counts from the static plan
    MpiList { ranks: Vec<RankStats> },
}

/// The typed result of [`Session::run`]: the common summary every
/// back-end can produce, the [`Plan`] that chose the back-end, and the
/// per-backend detail the old `RunSummary`-only API threw away.
#[derive(Clone, Debug)]
pub struct RunOutcome {
    pub plan: Plan,
    pub summary: RunSummary,
    pub detail: BackendDetail,
}

impl RunOutcome {
    /// No failures and nothing skipped.
    pub fn all_ok(&self) -> bool {
        self.summary.all_ok()
    }
}

// ---------------------------------------------------------------- session

/// One workflow execution context: graph + backend + every cross-cutting
/// knob (parallelism, campaign dir, tracer, calibration, polling) in a
/// single builder, carried through all three lowerings.
///
/// Defaults reproduce the historical free-function behavior exactly:
/// `Backend::Auto`, the machine's available parallelism, the current
/// directory, a disabled tracer, the Table-4 cost model, prefetch 1.
/// See the [module docs](crate::workflow::session) for a worked example.
#[derive(Clone, Debug)]
pub struct Session<'g> {
    graph: &'g WorkflowGraph,
    backend: Backend,
    parallelism: Option<usize>,
    dir: PathBuf,
    tracer: Tracer,
    metrics: Registry,
    model: CostModel,
    poll: PollCfg,
    prefetch: u32,
    allow_lint_errors: bool,
}

impl<'g> Session<'g> {
    pub fn new(graph: &'g WorkflowGraph) -> Session<'g> {
        Session {
            graph,
            backend: Backend::Auto,
            parallelism: None,
            dir: PathBuf::from("."),
            tracer: Tracer::default(),
            metrics: Registry::default(),
            model: CostModel::paper(),
            poll: PollCfg::default(),
            prefetch: 1,
            allow_lint_errors: false,
        }
    }

    /// Where to execute (default [`Backend::Auto`]).
    pub fn backend(mut self, backend: Backend) -> Self {
        self.backend = backend;
        self
    }

    /// Target scale: nodes for pmake, workers for dwork, ranks for
    /// mpi-list — and the selector's scale under `Auto`.  Defaults to
    /// the machine's available parallelism.
    pub fn parallelism(mut self, n: usize) -> Self {
        self.parallelism = Some(n);
        self
    }

    /// Campaign working directory (created if missing; default `.`).
    /// Local back-ends only: under a remote dwork target, payloads
    /// execute wherever the worker pools run (`dhub worker --dir`).
    pub fn dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.dir = dir.into();
        self
    }

    /// Lifecycle tracer threaded into whichever back-end runs (default:
    /// disabled, a true no-op in the hot paths).  Local back-ends record
    /// directly.  A remote dwork target attaches a live event
    /// subscription to the hub (`Request::Subscribe`) *before* the graph
    /// is submitted and feeds the tracer from that stream while
    /// [`Submission::wait`] polls for the drain — server-side timestamps,
    /// so the resulting trace profiles/compares like a hub-side one.
    /// (Worker-local `Started` events still only appear in worker traces:
    /// `dhub worker --trace`.)
    pub fn tracer(mut self, tracer: Tracer) -> Self {
        self.tracer = tracer;
        self
    }

    /// Live-metrics registry threaded into whichever local back-end
    /// runs (default: disabled).  Share one enabled
    /// [`Registry`](crate::metrics::Registry) with a concurrently
    /// scraped exposition endpoint to watch a session live; the final
    /// snapshot lands on [`BackendDetail::Dwork`] either way.
    pub fn metrics(mut self, metrics: Registry) -> Self {
        self.metrics = metrics;
        self
    }

    /// Price backends with a fitted calibration profile instead of the
    /// Table-4 defaults (affects [`Backend::Auto`] selection only).
    pub fn calibration(mut self, profile: &CalibrationProfile) -> Self {
        self.model = profile.model();
        self
    }

    /// Price backends with an explicit cost model (the lower-level form
    /// of [`Session::calibration`]).
    pub fn cost_model(mut self, model: CostModel) -> Self {
        self.model = model;
        self
    }

    /// Remote-path polling knobs (ignored for local backends).
    pub fn polling(mut self, poll: PollCfg) -> Self {
        self.poll = poll;
        self
    }

    /// dwork worker prefetch depth for the in-proc driver (default 1;
    /// ignored elsewhere — remote pools set their own prefetch via
    /// [`WorkerPool::prefetch`] / `dhub worker --prefetch`).
    pub fn prefetch(mut self, n: u32) -> Self {
        self.prefetch = n;
        self
    }

    /// Escape hatch for the pre-flight lint gate: run the graph even
    /// though the analyzer found Error-severity diagnostics (duplicate
    /// outputs, write-write races, read-write hazards).  First-declared
    /// producer wins every `by_output` lookup, deterministically.
    /// Referential integrity (unknown deps, cycles, stamp collisions)
    /// still fails inside the lowerings — there is no graph to run.
    pub fn allow_lint_errors(mut self, allow: bool) -> Self {
        self.allow_lint_errors = allow;
        self
    }

    fn resolved_parallelism(&self) -> usize {
        self.parallelism.unwrap_or_else(default_parallelism).max(1)
    }

    /// Run the full static analyzer over the session's graph at the
    /// session's scale, cost model, and backend: the library form of
    /// `threesched workflow lint`.  Infallible — a broken graph *is*
    /// the report (see [`crate::analyze`]).
    pub fn analyze(&self) -> crate::analyze::AnalysisReport {
        let target = match &self.backend {
            Backend::Auto => None,
            Backend::Pmake => Some(Tool::Pmake),
            Backend::Dwork { .. } => Some(Tool::Dwork),
            Backend::MpiList => Some(Tool::MpiList),
        };
        let opts = crate::analyze::AnalyzeOpts {
            ranks: self.resolved_parallelism(),
            model: self.model.clone(),
            target,
        };
        crate::analyze::analyze_graph(self.graph, &opts)
    }

    /// The pre-flight gate behind [`Session::plan`]: refuse
    /// Error-severity diagnostics unless the escape hatch is open.
    fn lint_gate(&self) -> Result<()> {
        if self.allow_lint_errors {
            return Ok(());
        }
        let errors: Vec<crate::analyze::Diagnostic> =
            crate::analyze::error_diagnostics(self.graph)
                .into_iter()
                .filter(|d| d.severity == crate::analyze::Severity::Error)
                .collect();
        if errors.is_empty() {
            return Ok(());
        }
        let mut list = String::new();
        for d in &errors {
            list.push_str("  ");
            list.push_str(&d.headline());
            list.push('\n');
        }
        bail!(
            "workflow {:?} fails lint with {} error(s):\n{list}  \
             (inspect with `threesched workflow lint`; bypass with \
             Session::allow_lint_errors(true))",
            self.graph.name,
            errors.len()
        );
    }

    /// Resolve the execution decision without executing: the selector
    /// runs for [`Backend::Auto`], explicit backends pass through.
    /// Refuses graphs with Error-severity lint diagnostics (see
    /// [`Session::allow_lint_errors`]).  Touches neither the filesystem
    /// nor the network.
    pub fn plan(&self) -> Result<Plan> {
        self.lint_gate()?;
        let parallelism = self.resolved_parallelism();
        let (tool, remote, session, recommendation) = match &self.backend {
            Backend::Auto => {
                let rec = select(self.graph, &self.model, parallelism)?;
                (rec.choice, None, None, Some(rec))
            }
            Backend::Pmake => (Tool::Pmake, None, None, None),
            Backend::Dwork { remote, session } => {
                (Tool::Dwork, remote.clone(), session.clone(), None)
            }
            Backend::MpiList => (Tool::MpiList, None, None, None),
        };
        // remote execution happens wherever the worker pools run: the
        // submitter's core count would be a lie, so the plan says 0
        // ("unknown/remote") — the same convention Submission::resume uses
        let parallelism = if remote.is_some() { 0 } else { parallelism };
        Ok(Plan { tool, parallelism, remote, session, recommendation })
    }

    /// Lower the graph for the planned coordinator without executing.
    /// The pmake lowering embeds the session's campaign dir as the
    /// target dirname; the mpi-list plan uses the session's parallelism.
    pub fn lower(&self) -> Result<Lowered> {
        let plan = self.plan()?;
        Ok(match plan.tool {
            Tool::Pmake => {
                Lowered::Pmake(lower::to_pmake(self.graph, &self.dir.to_string_lossy())?)
            }
            Tool::Dwork => Lowered::Dwork(lower::to_dwork(self.graph)?),
            Tool::MpiList => Lowered::MpiList(lower::to_mpilist(self.graph, plan.parallelism)?),
        })
    }

    /// Execute the graph to completion on the planned back-end.
    pub fn run(&self) -> Result<RunOutcome> {
        let plan = self.plan()?;
        // a remote target only ever appears on the dwork plan: submit,
        // then block for the server-side drain
        if plan.remote.is_some() {
            return self.submit_with_plan(plan)?.wait();
        }
        let (summary, detail) = match plan.tool {
            Tool::Pmake => {
                let (reports, summary) = run::pmake_driver(
                    self.graph,
                    &self.dir,
                    plan.parallelism,
                    &self.tracer,
                    &self.metrics,
                )?;
                (summary, BackendDetail::Pmake { reports })
            }
            Tool::Dwork => {
                let (server, metrics, summary) = run::dwork_driver(
                    self.graph,
                    &self.dir,
                    plan.parallelism,
                    self.prefetch,
                    &self.tracer,
                    &self.metrics,
                )?;
                (summary, BackendDetail::Dwork { server, metrics })
            }
            Tool::MpiList => {
                let (ranks, summary) = run::mpilist_driver(
                    self.graph,
                    &self.dir,
                    plan.parallelism,
                    &self.tracer,
                    &self.metrics,
                )?;
                (summary, BackendDetail::MpiList { ranks })
            }
        };
        Ok(RunOutcome { plan, summary, detail })
    }

    /// Ingest the graph into the remote hub and detach (the remote
    /// analogue of firing off a campaign and walking away).  Requires
    /// `Backend::Dwork { remote: Some(..) }`; block later with
    /// [`Submission::wait`].
    pub fn submit(&self) -> Result<Submission> {
        let plan = self.plan()?;
        self.submit_with_plan(plan)
    }

    /// Submit this graph as an *incremental delta* into the backend's
    /// hub session: unlike [`Session::submit`], `after` edges may name
    /// tasks that are not in this graph — the hub resolves them against
    /// work already submitted to the session, whether finished or still
    /// in flight.  This is the client half of the task-spawns-task
    /// path: a campaign driver can keep calling it to grow a running
    /// graph.  Requires `Backend::Dwork { remote: Some(..), session:
    /// Some(..) }`; block later with [`Submission::wait`], which scopes
    /// its drain detection to the session's own counters.
    pub fn submit_incremental(&self) -> Result<Submission> {
        // the regular lint gate would refuse the external edges that
        // make a delta a delta (deps unknown locally, resolved by the
        // hub); cycles among the delta's own tasks are still refused
        // inside the delta lowering
        let (remote, session) = match &self.backend {
            Backend::Dwork { remote: Some(r), session: Some(s) } => (r.clone(), s.clone()),
            _ => bail!(
                "submit_incremental() needs a remote session: use Backend::Dwork {{ \
                 remote: Some(..), session: Some(..) }}"
            ),
        };
        let plan = Plan {
            tool: Tool::Dwork,
            parallelism: 0, // remote: whatever pools joined the hub
            remote: Some(remote),
            session: Some(session),
            recommendation: None,
        };
        self.submit_lowered(plan, true)
    }

    fn submit_with_plan(&self, plan: Plan) -> Result<Submission> {
        self.submit_lowered(plan, false)
    }

    fn submit_lowered(&self, plan: Plan, incremental: bool) -> Result<Submission> {
        let Some(target) = plan.remote.clone() else {
            bail!(
                "submit() needs a remote target: use Backend::Dwork {{ remote: Some(..) }} \
                 (a local run has nothing to detach from)"
            );
        };
        // a session tracer rides the hub's live event stream: the
        // subscription MUST register before the first Create lands, so
        // the trace covers the campaign from its first lifecycle event
        let tail = if self.tracer.enabled() {
            TailHandle::spawn(&target.addr, self.tracer.clone(), &self.poll)
                .context("attaching trace subscription to the remote hub")?
        } else {
            TailHandle::default()
        };
        let accounting = run::remote_submit(
            self.graph,
            &target.addr,
            plan.session.as_deref(),
            incremental,
            &self.poll,
        )?;
        Ok(Submission { plan, accounting, poll: self.poll.clone(), tail })
    }
}

/// A background subscriber thread feeding a local [`Tracer`] from a
/// remote hub's live event stream.  Arc-shared so [`Submission`] stays
/// `Clone`; the first [`TailHandle::finish`] joins the thread, later
/// calls are no-ops.
#[derive(Clone, Default)]
struct TailHandle(Option<Arc<TailInner>>);

struct TailInner {
    stop: AtomicBool,
    thread: Mutex<Option<std::thread::JoinHandle<u64>>>,
}

impl std::fmt::Debug for TailHandle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match &self.0 {
            None => write!(f, "TailHandle(detached)"),
            Some(_) => write!(f, "TailHandle(subscribed)"),
        }
    }
}

impl TailHandle {
    /// Dial the hub, register the subscription synchronously (events
    /// only accumulate server-side from this moment), then start the
    /// polling thread.
    fn spawn(addr: &str, tracer: Tracer, poll: &PollCfg) -> Result<TailHandle> {
        let conn = TcpClient::connect_retry_cfg(addr, poll.connect_timeout, &poll.transport)?;
        let name = format!("wf-tail-{}", std::process::id());
        // exit_on_drop: leaving detaches the subscription server-side
        let mut c = Client::new(Box::new(conn), name).exit_on_drop(true);
        c.subscribe("", 0)?;
        let inner = Arc::new(TailInner {
            stop: AtomicBool::new(false),
            thread: Mutex::new(None),
        });
        let inner2 = inner.clone();
        let interval = poll.poll;
        let handle = std::thread::Builder::new()
            .name("wf-tail".into())
            .spawn(move || {
                let mut dropped = 0u64;
                loop {
                    let b = match c.subscribe("", 0) {
                        Ok(b) => b,
                        Err(_) => break, // hub gone: the trace ends here
                    };
                    dropped += b.dropped;
                    for ev in &b.events {
                        tracer.record_at_in_session(ev.t, &ev.session, &ev.task, ev.kind, &ev.who);
                    }
                    if b.events.is_empty() {
                        // drain fully before honoring done/stop: events
                        // emitted before the drain signal are still queued
                        if b.done || inner2.stop.load(Ordering::Relaxed) {
                            break;
                        }
                        std::thread::sleep(interval);
                    }
                }
                dropped
            })
            .context("spawning the trace-subscription thread")?;
        *inner.thread.lock().expect("tail thread slot poisoned") = Some(handle);
        Ok(TailHandle(Some(inner)))
    }

    /// Signal the subscriber to stop once its queue is drained, then
    /// join it.  Safe to call from any [`Submission`] clone; only the
    /// first call joins.
    fn finish(&self) {
        let Some(inner) = &self.0 else { return };
        inner.stop.store(true, Ordering::Relaxed);
        let handle = inner.thread.lock().expect("tail thread slot poisoned").take();
        if let Some(h) = handle {
            if let Ok(dropped) = h.join() {
                if dropped > 0 {
                    eprintln!(
                        "warning: {dropped} trace events dropped by the hub \
                         (subscriber polled too slowly); the local trace is incomplete"
                    );
                }
            }
        }
    }
}

/// A detached remote submission: what the hub accepted, plus everything
/// needed to poll it to completion.
#[derive(Clone, Debug)]
pub struct Submission {
    /// the plan the session resolved at submit time
    pub plan: Plan,
    /// per-Create accounting ([`Submission::wait`] needs it to turn
    /// server-side counters into a [`RunSummary`])
    pub accounting: RemoteSubmission,
    poll: PollCfg,
    /// live trace subscription, when the session had an enabled tracer
    tail: TailHandle,
}

impl Submission {
    /// Rebuild a submission handle from its parts — the cross-process
    /// detach workflow: submit in one process (persisting
    /// [`Submission::accounting`]), then resume and [`Submission::wait`]
    /// from another.
    pub fn resume(addr: &str, accounting: RemoteSubmission, poll: PollCfg) -> Submission {
        Submission {
            plan: Plan {
                tool: Tool::Dwork,
                parallelism: 0, // remote: whatever pools joined the hub
                remote: Some(RemoteTarget::new(addr)),
                session: accounting.session.clone(),
                recommendation: None,
            },
            accounting,
            poll,
            tail: TailHandle::default(),
        }
    }

    /// The hub this submission went to.
    pub fn addr(&self) -> &str {
        &self.plan.remote.as_ref().expect("submission always has a remote target").addr
    }

    /// Block until the submission has drained out of the hub, then
    /// reconstruct the outcome from the server-side counters.  The hub's
    /// live metrics ride along when it exposes them (best-effort: an old
    /// or metrics-disabled hub yields `None`).
    pub fn wait(&self) -> Result<RunOutcome> {
        let (server, summary) = run::remote_await(self.addr(), &self.accounting, &self.poll)?;
        // the drain is server-side fact now: let the subscriber empty
        // its queue and stop, so the local trace is complete on return
        self.tail.finish();
        let metrics = run::remote_metrics(self.addr(), &self.poll);
        Ok(RunOutcome {
            plan: self.plan.clone(),
            summary,
            detail: BackendDetail::DworkRemote {
                submission: self.accounting.clone(),
                server,
                metrics,
            },
        })
    }
}

// ------------------------------------------------------------ worker pool

/// Aggregate accounting from a [`WorkerPool`] run.
#[derive(Clone, Debug, Default)]
pub struct PoolStats {
    /// the pool's base worker name (thread `i` is `"{name}.{i}"`)
    pub name: String,
    pub threads: usize,
    pub tasks_run: u64,
    pub tasks_failed: u64,
    pub compute_s: f64,
    pub comm_s: f64,
    pub idle_s: f64,
}

/// A pool of workflow-aware worker threads joined to a remote dhub —
/// the library form of `threesched dhub worker`.  Each thread runs the
/// standard pull loop on task-body payloads (`Payload::decode_body`),
/// parks with exponential backoff on an empty hub, and (with
/// [`WorkerPool::linger`]) survives campaign boundaries and hub
/// restarts instead of exiting at drain.
#[derive(Clone, Debug)]
pub struct WorkerPool {
    addr: String,
    threads: usize,
    prefetch: u32,
    report_batch: usize,
    dir: PathBuf,
    base_name: Option<String>,
    linger: bool,
    idle_floor: Duration,
    idle_ceiling: Duration,
    connect_timeout: Duration,
    tracer: Tracer,
    metrics: Registry,
}

impl WorkerPool {
    pub fn new(addr: impl Into<String>) -> WorkerPool {
        WorkerPool {
            addr: addr.into(),
            threads: 1,
            prefetch: 1,
            report_batch: 1,
            dir: PathBuf::from("."),
            base_name: None,
            linger: false,
            idle_floor: Duration::from_micros(200),
            idle_ceiling: Duration::from_millis(100),
            connect_timeout: Duration::from_secs(10),
            tracer: Tracer::default(),
            metrics: Registry::default(),
        }
    }

    /// Pulling threads in this process (default 1).
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = n.max(1);
        self
    }

    /// Tasks to buffer per thread (default 1).
    pub fn prefetch(mut self, n: u32) -> Self {
        self.prefetch = n;
        self
    }

    /// Completions to buffer per thread before reporting them to the
    /// hub in one wire frame (default 1 = report each immediately).
    /// Raising this amortizes the report RTT across a burst — the
    /// worker-side counterpart of Steal-n — at the cost of delaying
    /// successor release until the buffer flushes; the worker loop
    /// always flushes before parking, so chains never deadlock.
    pub fn batch(mut self, n: usize) -> Self {
        self.report_batch = n.max(1);
        self
    }

    /// Campaign working directory payloads execute in (default `.`).
    pub fn dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.dir = dir.into();
        self
    }

    /// Worker name prefix.  The default is unique across hosts — the
    /// hub keys assignment state by worker name, and PIDs are only
    /// per-host, so two pools on different nodes could otherwise
    /// collide and corrupt each other's requeue accounting.
    pub fn name(mut self, base: impl Into<String>) -> Self {
        self.base_name = Some(base.into());
        self
    }

    /// Survive campaign boundaries: rejoin after the hub drains (the
    /// hub still sends the paper-faithful Exit at drain).
    pub fn linger(mut self, yes: bool) -> Self {
        self.linger = yes;
        self
    }

    /// Idle-backoff bounds while the hub has nothing ready.
    pub fn idle_backoff(mut self, floor: Duration, ceiling: Duration) -> Self {
        self.idle_floor = floor;
        self.idle_ceiling = ceiling;
        self
    }

    /// How long to keep dialing a hub that is not up yet (default 10s).
    pub fn connect_timeout(mut self, t: Duration) -> Self {
        self.connect_timeout = t;
        self
    }

    /// Worker-side lifecycle recorder.  This pool owns its stream (the
    /// hub's trace lives in another process), so it records `Connected`
    /// on every attach plus `Started` and the terminals.
    pub fn tracer(mut self, tracer: Tracer) -> Self {
        self.tracer = tracer;
        self
    }

    /// Worker-side live counters (poll/backoff/park transitions,
    /// steal-RTT and compute histograms), aggregated across all pool
    /// threads.  Snapshot the registry you pass in to read them.
    pub fn metrics(mut self, metrics: Registry) -> Self {
        self.metrics = metrics;
        self
    }

    fn default_base_name() -> String {
        let nonce = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.subsec_nanos())
            .unwrap_or(0);
        let host = std::env::var("HOSTNAME").unwrap_or_default();
        format!("dhub-{host}-{}-{nonce:08x}", std::process::id())
    }

    /// Join the hub and pull until dismissed (or forever, with
    /// [`WorkerPool::linger`]).  Blocks the calling thread.
    pub fn run(&self) -> Result<PoolStats> {
        let base = self.base_name.clone().unwrap_or_else(Self::default_base_name);
        std::fs::create_dir_all(&self.dir)
            .with_context(|| format!("creating {:?}", self.dir))?;
        let totals: Vec<dwork::WorkerStats> = std::thread::scope(|s| {
            (0..self.threads)
                .map(|i| {
                    let name = format!("{base}.{i}");
                    s.spawn(move || self.run_thread(name))
                })
                .collect::<Vec<_>>()
                .into_iter()
                .map(|h| h.join().expect("worker thread panicked"))
                .collect::<Result<Vec<_>>>()
        })?;
        let mut out = PoolStats { name: base, threads: self.threads, ..PoolStats::default() };
        for t in &totals {
            out.tasks_run += t.tasks_run;
            out.tasks_failed += t.tasks_failed;
            out.compute_s += t.compute_s;
            out.comm_s += t.comm_s;
            out.idle_s += t.idle_s;
        }
        Ok(out)
    }

    /// One pulling thread: dial, drain, and — when lingering — rejoin
    /// across campaign boundaries, hub outages, and hub restarts.
    fn run_thread(&self, name: String) -> Result<dwork::WorkerStats> {
        let opts = dwork::WorkerOpts {
            prefetch: self.prefetch,
            report_batch: self.report_batch,
            idle_floor: self.idle_floor,
            idle_ceiling: self.idle_ceiling,
            tracer: self.tracer.clone(),
            trace_terminals: true,
            metrics: self.metrics.clone(),
        };
        let mut total = dwork::WorkerStats::default();
        // rejoin backoff between campaigns: a drained hub dismisses
        // workers instantly, so a lingering pool must not
        // reconnect-cycle at full speed for the whole inter-campaign gap
        let rejoin_floor = Duration::from_millis(250);
        let rejoin_ceiling = Duration::from_secs(10);
        let mut rejoin = rejoin_floor;
        loop {
            let dial = TcpClient::connect_retry(&self.addr, self.connect_timeout);
            let conn = match dial {
                Ok(conn) => conn,
                // a lingering pool must outlive hub outages of any
                // length, not just the one dial window
                Err(e) if self.linger => {
                    eprintln!("{name}: hub unreachable ({e:#}); retrying");
                    std::thread::sleep(rejoin);
                    rejoin = (rejoin * 2).min(rejoin_ceiling);
                    continue;
                }
                Err(e) => return Err(e),
            };
            // exit_on_drop: a dying thread hands its assigned tasks
            // back to the hub
            let mut c = Client::new(Box::new(conn), name.clone()).exit_on_drop(true);
            let dir = self.dir.clone();
            let worked = dwork::run_worker_opts(&mut c, &opts, |t| {
                // empty body: a bare synchronization task (e.g. via
                // `dwork create`)
                if t.body.is_empty() {
                    return Ok(());
                }
                run::exec_payload(&Payload::decode_body(&t.body)?, &dir)
            });
            let stats = match worked {
                Ok(stats) => stats,
                // a lingering pool outlives hub restarts too:
                // reconnect, don't die
                Err(e) if self.linger => {
                    eprintln!("{name}: hub connection lost ({e:#}); rejoining");
                    std::thread::sleep(rejoin);
                    rejoin = (rejoin * 2).min(rejoin_ceiling);
                    continue;
                }
                Err(e) => return Err(e),
            };
            total.tasks_run += stats.tasks_run;
            total.tasks_failed += stats.tasks_failed;
            total.compute_s += stats.compute_s;
            total.comm_s += stats.comm_s;
            total.idle_s += stats.idle_s;
            // the hub dismisses workers when a campaign drains (paper
            // Exit); a lingering pool serves successive campaigns on a
            // long-lived hub instead of exiting
            if !self.linger {
                return Ok(total);
            }
            if stats.tasks_run > 0 {
                rejoin = rejoin_floor; // productive campaign
            }
            std::thread::sleep(rejoin);
            rejoin = (rejoin * 2).min(rejoin_ceiling);
        }
    }
}

// ------------------------------------------------------------------ tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::graph::TaskSpec;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir()
            .join(format!("threesched-session-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn file_pipeline() -> WorkflowGraph {
        let mut g = WorkflowGraph::new("pipe");
        g.add_task(TaskSpec::command("gen", "echo 7 > data.txt").outputs(&["data.txt"]))
            .unwrap();
        g.add_task(TaskSpec::kernel("crunch", "atb_32", 5).after(&["gen"])).unwrap();
        g.add_task(
            TaskSpec::command("sum", "cp data.txt sum.txt")
                .outputs(&["sum.txt"])
                .after(&["gen", "crunch"]),
        )
        .unwrap();
        g
    }

    #[test]
    fn same_graph_completes_on_all_three_backends() {
        let g = file_pipeline();
        for tool in Tool::ALL {
            let dir = tmp(&format!("all3-{}", tool.name().replace('-', "")));
            let outcome = Session::new(&g)
                .backend(Backend::from_tool(tool))
                .parallelism(2)
                .dir(&dir)
                .run()
                .unwrap();
            assert_eq!(outcome.summary.coordinator, tool);
            assert_eq!(outcome.summary.tasks_run, 3, "{}", tool.name());
            assert!(outcome.all_ok(), "{}", tool.name());
            assert!(dir.join("sum.txt").exists(), "{}: sink output missing", tool.name());
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn auto_plan_carries_the_recommendation_and_run_honors_it() {
        let g = file_pipeline();
        let dir = tmp("auto");
        let session = Session::new(&g).parallelism(2).dir(&dir);
        let plan = session.plan().unwrap();
        let rec = plan.recommendation.as_ref().expect("auto plan has a recommendation");
        assert_eq!(rec.choice, plan.tool);
        let outcome = session.run().unwrap();
        assert_eq!(outcome.plan.tool, plan.tool);
        assert_eq!(outcome.summary.coordinator, plan.tool);
        assert!(outcome.all_ok());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn explicit_plan_skips_the_selector_and_nothing_executes() {
        let g = file_pipeline();
        let dir = std::env::temp_dir().join(format!(
            "threesched-session-noexec-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let plan = Session::new(&g)
            .backend(Backend::Pmake)
            .parallelism(3)
            .dir(&dir)
            .plan()
            .unwrap();
        assert_eq!(plan.tool, Tool::Pmake);
        assert_eq!(plan.parallelism, 3);
        assert!(plan.recommendation.is_none());
        assert!(plan.render().contains("pmake"), "{}", plan.render());
        assert!(!dir.exists(), "plan() must not touch the campaign dir");
    }

    #[test]
    fn outcome_detail_matches_backend() {
        let g = file_pipeline();
        let dir = tmp("detail-pmake");
        let outcome =
            Session::new(&g).backend(Backend::Pmake).parallelism(2).dir(&dir).run().unwrap();
        match &outcome.detail {
            BackendDetail::Pmake { reports } => {
                assert!(!reports.is_empty());
                assert!(reports.iter().all(|r| r.all_ok()));
            }
            other => panic!("expected pmake detail, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);

        let dir = tmp("detail-dwork");
        let outcome = Session::new(&g)
            .backend(Backend::Dwork { remote: None, session: None })
            .parallelism(2)
            .dir(&dir)
            .run()
            .unwrap();
        match &outcome.detail {
            BackendDetail::Dwork { server, metrics } => {
                assert!(server.is_drained());
                assert_eq!(server.completed, 3);
                assert_eq!(server.failed, 0);
                // the driver always runs an enabled registry, so the
                // outcome carries a live snapshot without opting in
                assert_eq!(metrics.version, crate::metrics::MetricsSnapshot::VERSION);
                assert_eq!(metrics.counter("tasks_completed"), 3);
                assert_eq!(metrics.gauge("queue_depth"), 0);
            }
            other => panic!("expected dwork detail, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);

        let dir = tmp("detail-mpilist");
        let outcome =
            Session::new(&g).backend(Backend::MpiList).parallelism(3).dir(&dir).run().unwrap();
        match &outcome.detail {
            BackendDetail::MpiList { ranks } => {
                assert_eq!(ranks.len(), 3);
                let run: usize = ranks.iter().map(|r| r.tasks_run).sum();
                assert_eq!(run, outcome.summary.tasks_run);
            }
            other => panic!("expected mpi-list detail, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn dwork_server_counters_expose_the_failed_skipped_split() {
        let mut g = WorkflowGraph::new("fail");
        g.add_task(TaskSpec::command("boom", "exit 3")).unwrap();
        g.add_task(TaskSpec::command("child", "true").after(&["boom"])).unwrap();
        let dir = tmp("dwork-fail");
        let outcome = Session::new(&g)
            .backend(Backend::Dwork { remote: None, session: None })
            .parallelism(1)
            .prefetch(0)
            .dir(&dir)
            .run()
            .unwrap();
        assert_eq!(outcome.summary.tasks_run, 1, "child never served");
        assert_eq!(outcome.summary.tasks_failed, 1);
        assert_eq!(outcome.summary.tasks_skipped, 1);
        match &outcome.detail {
            BackendDetail::Dwork { server, metrics } => {
                assert_eq!(server.failed, 1);
                assert_eq!(server.skipped(), 1);
                assert!(server.is_drained());
                assert_eq!(metrics.counter("tasks_failed"), 1);
                assert_eq!(metrics.counter("tasks_skipped"), 1);
            }
            other => panic!("expected dwork detail, got {other:?}"),
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn lower_resolves_through_the_plan() {
        let g = file_pipeline();
        match Session::new(&g).backend(Backend::Pmake).lower().unwrap() {
            Lowered::Pmake(low) => assert!(low.rules_yaml.contains("gen")),
            other => panic!("expected pmake lowering, got {other:?}"),
        }
        match Session::new(&g).backend(Backend::Dwork { remote: None, session: None }).lower().unwrap() {
            Lowered::Dwork(tasks) => assert_eq!(tasks.len(), 3),
            other => panic!("expected dwork lowering, got {other:?}"),
        }
        match Session::new(&g).backend(Backend::MpiList).parallelism(2).lower().unwrap() {
            Lowered::MpiList(plan) => assert_eq!(plan.total_tasks(), 3),
            other => panic!("expected mpi-list lowering, got {other:?}"),
        }
    }

    #[test]
    fn submit_refuses_without_a_remote_target() {
        let g = file_pipeline();
        let err = Session::new(&g).backend(Backend::Dwork { remote: None, session: None }).submit();
        assert!(err.is_err());
        let err = Session::new(&g).backend(Backend::Pmake).submit();
        assert!(err.is_err());
    }

    #[test]
    fn remote_tracer_attaches_a_subscription_or_fails_fast() {
        // a session tracer on a remote target attaches a live hub
        // subscription (it used to be refused outright); with no hub
        // listening, the attach fails at dial time, bounded by the
        // connect timeout, and names the subscription in the error
        let g = file_pipeline();
        let err = Session::new(&g)
            .backend(Backend::Dwork { remote: Some("127.0.0.1:1".into()), session: None })
            .polling(PollCfg {
                connect_timeout: Duration::from_millis(50),
                ..PollCfg::default()
            })
            .tracer(Tracer::memory())
            .submit()
            .unwrap_err();
        assert!(err.to_string().contains("trace subscription"), "{err}");
    }

    #[test]
    fn backend_names_roundtrip() {
        assert_eq!(Backend::from_name("auto"), Some(Backend::Auto));
        assert_eq!(Backend::from_name("pmake"), Some(Backend::Pmake));
        assert_eq!(Backend::from_name("dwork"), Some(Backend::Dwork { remote: None, session: None }));
        assert_eq!(Backend::from_name("mpilist"), Some(Backend::MpiList));
        assert_eq!(Backend::from_name("mpi-list"), Some(Backend::MpiList));
        assert_eq!(Backend::from_name("warp"), None);
        for tool in Tool::ALL {
            let b = Backend::from_tool(tool);
            assert_eq!(Backend::from_name(tool.name()), Some(b));
        }
    }
}
