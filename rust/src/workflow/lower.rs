//! Lowering: compile one [`WorkflowGraph`] into each coordinator's input.
//!
//! * **pmake** — `rules.yaml` + `targets.yaml` text, parseable by
//!   [`crate::coordinator::pmake::parse_rules`]: one rule per task, file
//!   presence as the dependency mechanism (declared outputs, or a
//!   synthesized `<name>.done` stamp for tasks without file outputs).
//! * **dwork** — a task list with explicit dependency edges, in an order
//!   the dhub server accepts (dependencies created first).
//! * **mpi-list** — a static bulk-synchronous plan: topological levels,
//!   each level's tasks block-distributed over the ranks with the same
//!   arithmetic as [`crate::coordinator::mpilist::block_range`].

use anyhow::{bail, Result};

use crate::coordinator::dwork::TaskMsg;
use crate::coordinator::mpilist::block_range;
use crate::substrate::cluster::ResourceSet;

use super::graph::{Payload, WorkflowGraph};

/// pmake lowering result: the two YAML documents pmake consumes.
#[derive(Clone, Debug)]
pub struct LoweredPmake {
    pub rules_yaml: String,
    pub targets_yaml: String,
}

/// Escape `{`/`}` so pmake's `format()`-style substitution reproduces the
/// original script text verbatim.
fn escape_braces(s: &str) -> String {
    s.replace('{', "{{").replace('}', "}}")
}

fn sanitize(name: &str) -> String {
    let s: String = name
        .chars()
        .map(|c| if c.is_ascii_alphanumeric() || "_-.".contains(c) { c } else { '_' })
        .collect();
    if s.is_empty() || s.starts_with('-') {
        format!("wf{s}")
    } else {
        s
    }
}

/// Lower to pmake rule/target documents rooted at `dirname` (the campaign
/// working directory tasks run in).
pub fn to_pmake(g: &WorkflowGraph, dirname: &str) -> Result<LoweredPmake> {
    g.check_integrity()?;
    if g.is_empty() {
        bail!("cannot lower an empty workflow");
    }
    // dirname is the one string that arrives unvalidated (straight from
    // the CLI) and gets interpolated into quoted YAML: the emitted
    // subset has no escape sequences, so reject only what a quoted
    // scalar genuinely cannot carry ('#', spaces, braces are all fine
    // inside double quotes)
    if dirname.is_empty() || dirname.contains(['"', '\n']) {
        bail!("campaign dirname {dirname:?} cannot contain double quotes or newlines");
    }
    // one adjacency build threads through ordering, rule emission and
    // sink discovery alike
    let preds = g.preds_vec();
    let order = g.topo_order_from(&preds)?;
    let mut rules = String::new();
    for &i in &order {
        let t = &g.tasks()[i];
        rules.push_str(&format!("{}:\n", t.name));
        let r: &ResourceSet = &t.resources;
        // a task that kept the default resource hints gets its priority
        // weight from the duration estimate instead
        let time_min = if *r == ResourceSet::default() {
            (t.est_s / 60.0).max(0.01)
        } else {
            r.time_min
        };
        rules.push_str(&format!(
            "  resources: {{time: {time_min}, nrs: {}, cpu: {}, gpu: {}, ranks: {}}}\n",
            r.nrs, r.cpu, r.gpu, r.ranks_per_rs
        ));
        // explicit + file-implied dependencies, same edge set the other
        // lowerings use (deps_of), then any remaining source files.
        // Self-produced inputs (in-place updates) are dropped: listing
        // them would make the rule depend on its own output and trip
        // pmake's cycle detector.
        let mut inp: Vec<String> = Vec::new();
        for &d in &preds[i] {
            inp.extend(g.tasks()[d].sync_files());
        }
        inp.extend(t.inputs.iter().filter(|f| !t.outputs.contains(f)).cloned());
        let mut seen = std::collections::BTreeSet::new();
        inp.retain(|f| seen.insert(f.clone()));
        if !inp.is_empty() {
            rules.push_str("  inp:\n");
            for (k, f) in inp.iter().enumerate() {
                rules.push_str(&format!("    d{k}: \"{f}\"\n"));
            }
        }
        rules.push_str("  out:\n");
        for (k, f) in t.sync_files().iter().enumerate() {
            rules.push_str(&format!("    o{k}: \"{f}\"\n"));
        }
        // script: the payload, then whatever file-touching makes the
        // outputs (= synchronization tokens) true
        let mut lines: Vec<String> = match &t.payload {
            Payload::Command { script } => {
                script.lines().map(escape_braces).collect()
            }
            Payload::Kernel { artifact, seed } => {
                // marker line interpreted by WorkflowExecutor (in-process
                // kernel); a comment to any plain /bin/sh
                vec![format!("#kernel {artifact} {seed}")]
            }
            Payload::Noop => vec![":".to_string()],
        };
        if lines.is_empty() {
            lines.push(":".to_string());
        }
        let touch: Vec<String> = match &t.payload {
            // commands are expected to create their declared outputs
            // themselves; only the synthesized stamp needs help
            Payload::Command { .. } if !t.outputs.is_empty() => Vec::new(),
            _ => t.sync_files(),
        };
        if !touch.is_empty() {
            // nested outputs need their directories first (exec_task does
            // the same create_dir_all on the other back-ends)
            let mut parents: Vec<&str> = touch
                .iter()
                .filter_map(|f| f.rsplit_once('/').map(|(d, _)| d))
                .collect();
            parents.sort_unstable();
            parents.dedup();
            if !parents.is_empty() {
                lines.push(format!("mkdir -p {}", parents.join(" ")));
            }
            lines.push(format!("touch {}", touch.join(" ")));
        }
        rules.push_str("  script: |\n");
        for l in &lines {
            rules.push_str(&format!("    {l}\n"));
        }
    }

    let target_name = sanitize(&g.name);
    let mut targets = format!("{target_name}:\n  dirname: \"{dirname}\"\n  out:\n");
    let mut has_succ = vec![false; g.len()];
    for ps in &preds {
        for &p in ps {
            has_succ[p] = true;
        }
    }
    let mut k = 0usize;
    for i in (0..g.len()).filter(|&i| !has_succ[i]) {
        for f in g.tasks()[i].sync_files() {
            targets.push_str(&format!("    s{k}: \"{f}\"\n"));
            k += 1;
        }
    }
    Ok(LoweredPmake { rules_yaml: rules, targets_yaml: targets })
}

/// One dwork task ready for `SchedState::create` (or `dwork create`).
#[derive(Clone, Debug)]
pub struct DworkTask {
    pub msg: TaskMsg,
    pub deps: Vec<String>,
}

/// Lower to a dwork task list.  Topological order: every task appears
/// after all of its dependencies, exactly what the dhub Create API
/// requires.
pub fn to_dwork(g: &WorkflowGraph) -> Result<Vec<DworkTask>> {
    g.check_integrity()?;
    let preds = g.preds_vec();
    let order = g.topo_order_from(&preds)?;
    Ok(order
        .into_iter()
        .map(|i| {
            let t = &g.tasks()[i];
            DworkTask {
                msg: TaskMsg::new(t.name.clone(), t.payload.encode_body()),
                // explicit + file-implied edges, matching pmake's
                // file-walk semantics
                deps: preds[i].iter().map(|&d| g.tasks()[d].name.clone()).collect(),
            }
        })
        .collect())
}

/// Lower to a dwork *delta*: like [`to_dwork`], but `after` edges that
/// name tasks outside this graph ride through verbatim as external
/// dependencies for the hub's incremental resolver — they resolve
/// against work already submitted to the target session (finished or
/// in-flight) instead of failing referential integrity.  Cycles among
/// the graph's own tasks are still refused; that is the only integrity
/// a delta can check locally.
pub fn to_dwork_delta(g: &WorkflowGraph) -> Result<Vec<DworkTask>> {
    let preds = g.preds_vec();
    let order = g.topo_order_from(&preds)?;
    Ok(order
        .into_iter()
        .map(|i| {
            let t = &g.tasks()[i];
            let mut deps: Vec<String> =
                preds[i].iter().map(|&d| g.tasks()[d].name.clone()).collect();
            for d in &t.after {
                if g.index_of(d).is_none() {
                    deps.push(d.clone());
                }
            }
            DworkTask { msg: TaskMsg::new(t.name.clone(), t.payload.encode_body()), deps }
        })
        .collect())
}

/// Render the dwork lowering as a dquery-style script (human-facing
/// `workflow lower --coordinator dwork` output).
pub fn render_dwork(tasks: &[DworkTask]) -> String {
    let mut out = String::new();
    for t in tasks {
        if t.deps.is_empty() {
            out.push_str(&format!("dwork create --name {}\n", t.msg.name));
        } else {
            out.push_str(&format!(
                "dwork create --name {} --dep {}\n",
                t.msg.name,
                t.deps.join(",")
            ));
        }
    }
    out
}

/// mpi-list lowering: a static bulk-synchronous execution plan.  Phase k
/// runs topological level k; within a phase each rank executes the
/// contiguous block of tasks [`block_range`] assigns it, then all ranks
/// barrier — no other synchronization exists, the paper's third archetype.
#[derive(Clone, Debug)]
pub struct MpiListPlan {
    pub workflow: String,
    pub procs: usize,
    /// task indices (into `WorkflowGraph::tasks`) per phase
    pub levels: Vec<Vec<usize>>,
}

impl MpiListPlan {
    /// The slice of `levels[level]` rank `rank` executes.
    pub fn rank_tasks(&self, level: usize, rank: usize) -> &[usize] {
        let l = &self.levels[level];
        let (start, count) = block_range(rank, self.procs, l.len() as u64);
        &l[start as usize..(start + count) as usize]
    }

    pub fn total_tasks(&self) -> usize {
        self.levels.iter().map(Vec::len).sum()
    }

    /// Human-facing plan table.
    pub fn render(&self, g: &WorkflowGraph) -> String {
        let mut out = format!(
            "mpi-list plan for {:?}: {} tasks, {} phases, {} ranks\n",
            self.workflow,
            self.total_tasks(),
            self.levels.len(),
            self.procs
        );
        for (li, level) in self.levels.iter().enumerate() {
            out.push_str(&format!("phase {li} ({} tasks):\n", level.len()));
            for rank in 0..self.procs {
                let mine = self.rank_tasks(li, rank);
                if mine.is_empty() {
                    continue;
                }
                let names: Vec<&str> =
                    mine.iter().map(|&i| g.tasks()[i].name.as_str()).collect();
                out.push_str(&format!("  rank {rank}: {}\n", names.join(" ")));
            }
        }
        out
    }
}

/// Lower to the static rank assignment.
pub fn to_mpilist(g: &WorkflowGraph, procs: usize) -> Result<MpiListPlan> {
    if procs == 0 {
        bail!("mpi-list lowering needs at least one rank");
    }
    g.check_integrity()?;
    let preds = g.preds_vec();
    let order = g.topo_order_from(&preds)?;
    Ok(MpiListPlan {
        workflow: g.name.clone(),
        procs,
        levels: WorkflowGraph::levels_from(&preds, &order),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pmake::{parse_rules, parse_targets, Dag};
    use crate::workflow::graph::TaskSpec;
    use std::path::Path;

    fn pipeline() -> WorkflowGraph {
        let mut g = WorkflowGraph::new("pipe");
        g.add_task(
            TaskSpec::command("gen", "echo 1 > data.txt").outputs(&["data.txt"]).est(5.0),
        )
        .unwrap();
        g.add_task(TaskSpec::kernel("crunch", "atb_64", 3).after(&["gen"]).est(2.0))
            .unwrap();
        g.add_task(
            TaskSpec::command("sum", "cat data.txt > sum.txt")
                .outputs(&["sum.txt"])
                .after(&["gen", "crunch"])
                .est(1.0),
        )
        .unwrap();
        g
    }

    #[test]
    fn pmake_lowering_parses_and_builds_dag() {
        let g = pipeline();
        let low = to_pmake(&g, "/tmp/campaign").unwrap();
        let rules = parse_rules(&low.rules_yaml).unwrap();
        assert_eq!(rules.len(), 3);
        let targets = parse_targets(&low.targets_yaml).unwrap();
        assert_eq!(targets.len(), 1);
        assert_eq!(targets[0].dirname, "/tmp/campaign");
        // no file exists -> full graph instantiates
        let dag =
            Dag::build(&rules, &targets[0], &|_: &Path| false, &|_| String::new()).unwrap();
        assert_eq!(dag.tasks.len(), 3);
        assert!(dag.is_topologically_valid());
        // sum waits on both gen's file and crunch's stamp
        let sum = dag.producer("sum.txt").unwrap();
        assert_eq!(dag.tasks[sum].deps.len(), 2);
        let crunch = dag.producer("crunch.done").unwrap();
        assert!(dag.tasks[crunch].script.contains("#kernel atb_64 3"));
        assert!(dag.tasks[crunch].script.contains("touch crunch.done"));
    }

    #[test]
    fn pmake_scripts_escape_braces() {
        let mut g = WorkflowGraph::new("braces");
        g.add_task(TaskSpec::command("b", "echo ${HOME} {literal}")).unwrap();
        let low = to_pmake(&g, ".").unwrap();
        let rules = parse_rules(&low.rules_yaml).unwrap();
        let targets = parse_targets(&low.targets_yaml).unwrap();
        let dag =
            Dag::build(&rules, &targets[0], &|_: &Path| false, &|_| String::new()).unwrap();
        // substitution round-trips the braces back to the original text
        assert!(dag.tasks[0].script.contains("echo ${HOME} {literal}"));
    }

    #[test]
    fn dwork_lowering_orders_deps_first() {
        let g = pipeline();
        let tasks = to_dwork(&g).unwrap();
        assert_eq!(tasks.len(), 3);
        let pos = |n: &str| tasks.iter().position(|t| t.msg.name == n).unwrap();
        assert!(pos("gen") < pos("crunch"));
        assert!(pos("crunch") < pos("sum"));
        assert_eq!(tasks[pos("sum")].deps, vec!["gen", "crunch"]);
        // bodies decode back to the payloads
        let body = Payload::decode_body(&tasks[pos("crunch")].msg.body).unwrap();
        assert_eq!(body, Payload::Kernel { artifact: "atb_64".into(), seed: 3 });
        let script = render_dwork(&tasks);
        assert!(script.contains("--name sum --dep gen,crunch"));
    }

    #[test]
    fn mpilist_plan_partitions_each_level() {
        let mut g = WorkflowGraph::new("map");
        for i in 0..10 {
            g.add_task(TaskSpec::kernel(format!("k{i}"), "atb_64", i)).unwrap();
        }
        let plan = to_mpilist(&g, 3).unwrap();
        assert_eq!(plan.levels.len(), 1);
        assert_eq!(plan.total_tasks(), 10);
        // every task executed exactly once across ranks
        let mut seen = vec![0usize; 10];
        for rank in 0..3 {
            for &t in plan.rank_tasks(0, rank) {
                seen[t] += 1;
            }
        }
        assert!(seen.iter().all(|&c| c == 1), "{seen:?}");
        // block sizes follow the paper's formula: 4,3,3
        assert_eq!(plan.rank_tasks(0, 0).len(), 4);
        assert_eq!(plan.rank_tasks(0, 1).len(), 3);
        assert_eq!(plan.rank_tasks(0, 2).len(), 3);
    }

    #[test]
    fn in_place_update_does_not_self_cycle_under_pmake() {
        // a task that reads AND writes ckpt.dat (in-place update) must
        // not lower to a rule depending on its own output
        let mut g = WorkflowGraph::new("inplace");
        let mut t = TaskSpec::command("upd", "touch ckpt.dat").outputs(&["ckpt.dat"]);
        t.inputs = vec!["ckpt.dat".into()];
        g.add_task(t).unwrap();
        let low = to_pmake(&g, ".").unwrap();
        let rules = parse_rules(&low.rules_yaml).unwrap();
        let targets = parse_targets(&low.targets_yaml).unwrap();
        let dag =
            Dag::build(&rules, &targets[0], &|_: &Path| false, &|_| String::new()).unwrap();
        assert_eq!(dag.tasks.len(), 1);
        assert!(dag.tasks[0].deps.is_empty());
    }

    #[test]
    fn stamp_named_input_rejected() {
        let mut g = WorkflowGraph::new("stampinput");
        g.add_task(TaskSpec::new("a")).unwrap();
        let mut b = TaskSpec::command("b", "cat a.done");
        b.inputs = vec!["a.done".into()];
        g.add_task(b).unwrap();
        for r in [to_pmake(&g, ".").err(), to_dwork(&g).err(), to_mpilist(&g, 2).err()] {
            let err = r.expect("stamp-named input must fail every lowering");
            assert!(err.to_string().contains("after"), "{err}");
        }
    }

    #[test]
    fn nested_outputs_get_mkdir_in_pmake_script() {
        let mut g = WorkflowGraph::new("mkdirs");
        g.add_task(TaskSpec::kernel("k", "atb_16", 0).outputs(&["out/deep/k.dat"])).unwrap();
        let low = to_pmake(&g, ".").unwrap();
        let rules = parse_rules(&low.rules_yaml).unwrap();
        let script = &rules[0].script;
        assert!(script.contains("mkdir -p out/deep"), "{script}");
        assert!(script.contains("touch out/deep/k.dat"), "{script}");
    }

    #[test]
    fn file_implied_edges_reach_every_lowering() {
        let mut g = WorkflowGraph::new("implicit");
        g.add_task(TaskSpec::command("producer", "echo > d.txt").outputs(&["d.txt"])).unwrap();
        let mut c = TaskSpec::command("consumer", "cat d.txt");
        c.inputs = vec!["d.txt".into()];
        g.add_task(c).unwrap();
        // dwork: the edge appears even though `after` is empty
        let tasks = to_dwork(&g).unwrap();
        let consumer = tasks.iter().find(|t| t.msg.name == "consumer").unwrap();
        assert_eq!(consumer.deps, vec!["producer"]);
        // mpi-list: two phases, not one
        assert_eq!(to_mpilist(&g, 2).unwrap().levels.len(), 2);
    }

    #[test]
    fn mpilist_levels_respect_dependencies() {
        let g = pipeline();
        let plan = to_mpilist(&g, 2).unwrap();
        assert_eq!(plan.levels.len(), 3);
        // level of every dep strictly precedes the task's level
        let level_of = |name: &str| {
            let idx = g.index_of(name).unwrap();
            plan.levels.iter().position(|l| l.contains(&idx)).unwrap()
        };
        assert!(level_of("gen") < level_of("crunch"));
        assert!(level_of("crunch") < level_of("sum"));
    }

    #[test]
    fn empty_and_zero_rank_rejected() {
        let g = WorkflowGraph::new("empty");
        assert!(to_pmake(&g, ".").is_err());
        assert!(to_mpilist(&g, 0).is_err());
        assert!(to_dwork(&g).unwrap().is_empty());
    }

    #[test]
    fn hostile_dirname_rejected_but_odd_paths_allowed() {
        let g = pipeline();
        for bad in ["", "/tmp/my\"dir", "/tmp/a\nb"] {
            assert!(to_pmake(&g, bad).is_err(), "dirname {bad:?} must be rejected");
        }
        // legal unix paths survive the quoted-scalar round-trip
        for odd in ["/tmp/spaced dir", "/tmp/run#3", "/tmp/br{ace}"] {
            let low = to_pmake(&g, odd).unwrap();
            let targets = parse_targets(&low.targets_yaml).unwrap();
            assert_eq!(targets[0].dirname, odd, "round-trip of {odd:?}");
        }
    }
}
