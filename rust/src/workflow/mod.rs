//! Unified workflow IR + adaptive scheduler selection.
//!
//! The paper ships three schedulers and leaves the user to pick one and
//! hand-encode their campaign three different ways (rules files, dquery
//! calls, SPMD scripts).  This subsystem closes that gap with a single
//! front-end, the architecture Balsam-style workflow systems use — one
//! workflow graph, many execution back-ends:
//!
//! * [`graph`] — the IR: a [`WorkflowGraph`](graph::WorkflowGraph) of
//!   [`TaskSpec`](graph::TaskSpec) nodes (command/kernel payloads, file
//!   inputs/outputs, dependencies, duration estimates, resource hints)
//!   with cycle detection, topological levels, and critical-path/width
//!   analysis;
//! * [`spec`] — the YAML front-end (`workflow.yaml`), on
//!   [`crate::substrate::yaml`]; parse errors carry source line
//!   numbers, and the `_loose` variants skip graph validation so
//!   [`crate::analyze`] can report every defect at once;
//! * [`lower`] — three lowerings: pmake `rules.yaml`/`targets.yaml`
//!   text, a dwork task list with dependency edges, and an mpi-list
//!   static bulk-synchronous rank plan;
//! * [`select`] — the adaptive selector: graph shape (depth, width,
//!   uniformity, file-sync) × the Table-4-calibrated METG cost model
//!   picks the coordinator whose overhead disappears at the workload's
//!   task granularity;
//! * [`session`] — **the execution API**: one builder-style
//!   [`Session`](session::Session) owns the graph, the
//!   [`Backend`](session::Backend) (typed execution mode — remote dwork
//!   is data, not a separate function family), the tracer, the
//!   calibration profile, and the polling knobs, and exposes
//!   `plan()` / `lower()` / `run()` / `submit()`.  Results come back as
//!   a typed [`RunOutcome`](session::RunOutcome): the common
//!   [`RunSummary`](run::RunSummary) view, the
//!   [`Plan`](session::Plan) that chose the back-end, and per-backend
//!   detail (pmake `RunReport`s, dwork server counters, mpi-list rank
//!   stats).  [`WorkerPool`](session::WorkerPool) is the library form
//!   of `threesched dhub worker`;
//! * [`run`] — the drivers behind the session (payload execution, the
//!   in-proc hub/worker fabric, the remote submit/await loop).
//!
//! Each coordinator module also gains a `from_workflow` ingestion API
//! ([`crate::coordinator::pmake::from_workflow`],
//! [`crate::coordinator::dwork::SchedState::from_workflow`],
//! [`crate::coordinator::mpilist::from_workflow`]) so external tooling
//! can feed graphs straight in without the text round-trip.
//!
//! # Migrating from the pre-`Session` entry points
//!
//! The free-function API (`run_pmake`, `run_dwork`, `dispatch`,
//! `run_auto`, the remote triplet, `RemoteOpts`) completed its
//! one-release `#[deprecated]` window and was removed.  The mapping,
//! for code migrating across that release boundary:
//!
//! | removed entry point | builder call |
//! |---|---|
//! | `run_pmake(g, dir, n)` | `Session::new(g).backend(Backend::Pmake).parallelism(n).dir(dir).run()` |
//! | `run_dwork(g, dir, w, pf)` | `Session::new(g).backend(Backend::Dwork { remote: None, session: None }).parallelism(w).prefetch(pf).dir(dir).run()` |
//! | `run_mpilist(g, dir, p)` | `Session::new(g).backend(Backend::MpiList).parallelism(p).dir(dir).run()` |
//! | `run_*_traced(…, tracer)` | same builder chain + `.tracer(tracer.clone())` |
//! | `dispatch(g, tool, p, dir)` | `Session::new(g).backend(Backend::from_tool(tool)).parallelism(p).dir(dir).run()` |
//! | `run_auto(g, m, p, dir)` | `Session::new(g).cost_model(m.clone()).parallelism(p).dir(dir).run()` — the verdict is `outcome.plan.recommendation` |
//! | `submit_dwork_remote(g, addr, opts)` | `Session::new(g).backend(Backend::Dwork { remote: Some(addr.into()), session: None }).polling(cfg).submit()` |
//! | `await_dwork_remote(addr, sub, opts)` | `Submission::wait()` on the value `submit()` returned |
//! | `run_dwork_remote(g, addr, opts)` | the same dwork-remote builder chain + `.run()` |
//! | `RemoteOpts { poll, connect_timeout }` | `PollCfg { poll, connect_timeout }` via `.polling(..)` |

pub mod graph;
pub mod lower;
pub mod run;
pub mod select;
pub mod session;
pub mod spec;

pub use graph::{GraphStats, Payload, TaskSpec, WorkflowGraph};
pub use lower::{to_dwork, to_dwork_delta, to_mpilist, to_pmake, DworkTask, LoweredPmake, MpiListPlan};
pub use run::{RemoteSubmission, RunSummary};
pub use select::{select, Assessment, Recommendation};
pub use session::{
    Backend, BackendDetail, Lowered, Plan, PollCfg, PoolStats, RankStats, RemoteTarget,
    RunOutcome, Session, Submission, WorkerPool,
};
pub use spec::{
    parse_workflow, parse_workflow_file, parse_workflow_file_loose, parse_workflow_loose, to_yaml,
};
