//! Unified workflow IR + adaptive scheduler selection.
//!
//! The paper ships three schedulers and leaves the user to pick one and
//! hand-encode their campaign three different ways (rules files, dquery
//! calls, SPMD scripts).  This subsystem closes that gap with a single
//! front-end, the architecture Balsam-style workflow systems use — one
//! workflow graph, many execution back-ends:
//!
//! * [`graph`] — the IR: a [`WorkflowGraph`](graph::WorkflowGraph) of
//!   [`TaskSpec`](graph::TaskSpec) nodes (command/kernel payloads, file
//!   inputs/outputs, dependencies, duration estimates, resource hints)
//!   with cycle detection, topological levels, and critical-path/width
//!   analysis;
//! * [`spec`] — the YAML front-end (`workflow.yaml`), on
//!   [`crate::substrate::yaml`];
//! * [`lower`] — three lowerings: pmake `rules.yaml`/`targets.yaml`
//!   text, a dwork task list with dependency edges, and an mpi-list
//!   static bulk-synchronous rank plan;
//! * [`select`] — the adaptive selector: graph shape (depth, width,
//!   uniformity, file-sync) × the Table-4-calibrated METG cost model
//!   picks the coordinator whose overhead disappears at the workload's
//!   task granularity;
//! * [`run`] — drivers that execute the same graph to completion on any
//!   back-end (`threesched workflow run --coordinator auto`), including
//!   the distributed path: [`run::run_dwork_remote`] feeds a long-lived
//!   TCP dhub (`threesched dhub serve`) drained by independently
//!   launched worker processes (`threesched dhub worker`).
//!
//! Each coordinator module also gains a `from_workflow` ingestion API
//! ([`crate::coordinator::pmake::from_workflow`],
//! [`crate::coordinator::dwork::SchedState::from_workflow`],
//! [`crate::coordinator::mpilist::from_workflow`]) so external tooling
//! can feed graphs straight in without the text round-trip.

pub mod graph;
pub mod lower;
pub mod run;
pub mod select;
pub mod spec;

pub use graph::{GraphStats, Payload, TaskSpec, WorkflowGraph};
pub use lower::{to_dwork, to_mpilist, to_pmake, DworkTask, LoweredPmake, MpiListPlan};
pub use run::{
    await_dwork_remote, dispatch, dispatch_traced, run_auto, run_auto_traced, run_dwork,
    run_dwork_remote, run_dwork_traced, run_mpilist, run_mpilist_traced, run_pmake,
    run_pmake_traced, submit_dwork_remote, RemoteOpts, RemoteSubmission, RunSummary,
};
pub use select::{select, Assessment, Recommendation};
pub use spec::{parse_workflow, parse_workflow_file, to_yaml};
