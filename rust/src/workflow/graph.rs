//! The workflow IR: a [`WorkflowGraph`] of [`TaskSpec`] nodes.
//!
//! One graph, three executions: every coordinator consumes this IR
//! through a lowering (see [`super::lower`]), so users describe a
//! campaign once and pick — or let [`super::select`] pick — the
//! synchronization mechanism later.  The graph/scheduler separation
//! follows `substantic/rain` (graph object distinct from the reactive
//! scheduler) and the `DAGSchedulerBase` shape in sched_sim_rust.
//!
//! Node identity is the task *name* (stable across lowerings: it becomes
//! the pmake rule name, the dwork task name, and the mpi-list element
//! label), so names are restricted to a filesystem/YAML-safe alphabet.

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::substrate::cluster::ResourceSet;

/// What a task actually does when executed.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// A shell script (runs under `/bin/sh` in the campaign directory).
    Command { script: String },
    /// An AOT kernel artifact executed with deterministic seeded inputs.
    Kernel { artifact: String, seed: u64 },
    /// Pure synchronization point (no work).
    Noop,
}

impl Payload {
    /// Payload kind discriminant (used by shape analysis: a "uniform"
    /// level runs one kind of payload).
    pub fn kind(&self) -> &'static str {
        match self {
            Payload::Command { .. } => "command",
            Payload::Kernel { .. } => "kernel",
            Payload::Noop => "noop",
        }
    }

    /// Encode for the dwork task body (scheduler-opaque bytes).
    pub fn encode_body(&self) -> Vec<u8> {
        match self {
            Payload::Command { script } => format!("sh\n{script}").into_bytes(),
            Payload::Kernel { artifact, seed } => {
                format!("kernel\n{artifact} {seed}").into_bytes()
            }
            Payload::Noop => b"noop\n".to_vec(),
        }
    }

    /// Decode a dwork task body written by [`Payload::encode_body`].
    pub fn decode_body(body: &[u8]) -> Result<Payload> {
        let text = std::str::from_utf8(body).map_err(|_| anyhow::anyhow!("non-utf8 body"))?;
        let (kind, rest) = text.split_once('\n').unwrap_or((text, ""));
        match kind {
            "sh" => Ok(Payload::Command { script: rest.to_string() }),
            "kernel" => {
                let (artifact, seed) = rest
                    .trim_end()
                    .split_once(' ')
                    .ok_or_else(|| anyhow::anyhow!("bad kernel body {rest:?}"))?;
                Ok(Payload::Kernel {
                    artifact: artifact.to_string(),
                    seed: seed.parse().map_err(|_| anyhow::anyhow!("bad seed {seed:?}"))?,
                })
            }
            "noop" => Ok(Payload::Noop),
            other => bail!("unknown payload kind {other:?}"),
        }
    }
}

/// One node of the workflow graph.
#[derive(Clone, Debug)]
pub struct TaskSpec {
    pub name: String,
    pub payload: Payload,
    /// source files this task reads (must pre-exist; file-based lowerings
    /// verify presence, the others treat them as documentation)
    pub inputs: Vec<String>,
    /// files this task produces (its synchronization tokens under pmake)
    pub outputs: Vec<String>,
    /// names of tasks that must complete first
    pub after: Vec<String>,
    /// estimated duration in seconds (drives selection + priorities)
    pub est_s: f64,
    /// resource hints (pmake lowering emits them as the rule's resources)
    pub resources: ResourceSet,
}

impl TaskSpec {
    /// A task with defaults: Noop payload, 1 s estimate, 1-cpu resources.
    pub fn new(name: impl Into<String>) -> TaskSpec {
        TaskSpec {
            name: name.into(),
            payload: Payload::Noop,
            inputs: Vec::new(),
            outputs: Vec::new(),
            after: Vec::new(),
            est_s: 1.0,
            resources: ResourceSet::default(),
        }
    }

    pub fn command(name: impl Into<String>, script: impl Into<String>) -> TaskSpec {
        let mut t = TaskSpec::new(name);
        t.payload = Payload::Command { script: script.into() };
        t
    }

    pub fn kernel(name: impl Into<String>, artifact: impl Into<String>, seed: u64) -> TaskSpec {
        let mut t = TaskSpec::new(name);
        t.payload = Payload::Kernel { artifact: artifact.into(), seed };
        t
    }

    pub fn after<S: AsRef<str>>(mut self, deps: &[S]) -> TaskSpec {
        self.after = deps.iter().map(|s| s.as_ref().to_string()).collect();
        self
    }

    pub fn outputs<S: AsRef<str>>(mut self, files: &[S]) -> TaskSpec {
        self.outputs = files.iter().map(|s| s.as_ref().to_string()).collect();
        self
    }

    pub fn est(mut self, seconds: f64) -> TaskSpec {
        self.est_s = seconds;
        self
    }

    /// The files downstream tasks wait on under a file-based lowering:
    /// declared outputs, or a synthesized stamp when there are none.
    pub fn sync_files(&self) -> Vec<String> {
        if self.outputs.is_empty() {
            vec![format!("{}.done", self.name)]
        } else {
            self.outputs.clone()
        }
    }
}

/// Shape analysis of a graph (what the adaptive selector consumes).
#[derive(Clone, Debug)]
pub struct GraphStats {
    pub tasks: usize,
    pub edges: usize,
    /// number of topological levels (1 = flat map)
    pub depth: usize,
    /// size of the widest level
    pub width: usize,
    /// Σ est_s over all tasks
    pub total_work_s: f64,
    /// longest est_s path source→sink
    pub critical_path_s: f64,
    pub mean_task_s: f64,
    /// coefficient of variation of est_s (0 = perfectly uniform)
    pub cv_task_s: f64,
    /// total_work / critical_path: the graph's inherent parallelism
    pub max_parallelism: f64,
    /// any task declares file outputs (file presence can synchronize)
    pub file_sync: bool,
    /// all payloads are the same kind
    pub uniform_payload: bool,
}

/// The workflow IR: named tasks + dependency edges.  Insertion order is
/// preserved (it seeds deterministic topological orders).
#[derive(Clone, Debug, Default)]
pub struct WorkflowGraph {
    pub name: String,
    tasks: Vec<TaskSpec>,
    index: HashMap<String, usize>,
    /// declared output file -> producing task (uniqueness + fast lookup)
    by_output: HashMap<String, usize>,
}

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && !s.starts_with('-')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || "_-.".contains(c))
}

fn valid_file(s: &str) -> bool {
    !s.is_empty()
        && !s.starts_with('/')
        && !s.contains("..")
        && s.chars().all(|c| c.is_ascii_alphanumeric() || "_-./".contains(c))
}

impl WorkflowGraph {
    pub fn new(name: impl Into<String>) -> WorkflowGraph {
        WorkflowGraph {
            name: name.into(),
            tasks: Vec::new(),
            index: HashMap::new(),
            by_output: HashMap::new(),
        }
    }

    /// Which task produces a declared output file, if any.
    pub fn producer_of(&self, file: &str) -> Option<&TaskSpec> {
        self.by_output.get(file).map(|&i| &self.tasks[i])
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn tasks(&self) -> &[TaskSpec] {
        &self.tasks
    }

    pub fn get(&self, name: &str) -> Option<&TaskSpec> {
        self.index.get(name).map(|&i| &self.tasks[i])
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.index.get(name).copied()
    }

    /// Add a task.  Name/file hygiene and duplicate detection happen here
    /// so every lowering can assume a well-formed node; dangling `after`
    /// references are legal until [`WorkflowGraph::validate`] (tasks may
    /// be added in any order).
    pub fn add_task(&mut self, task: TaskSpec) -> Result<()> {
        if !valid_name(&task.name) {
            bail!(
                "task name {:?} invalid (use [A-Za-z0-9_.-], no leading '-')",
                task.name
            );
        }
        if self.index.contains_key(&task.name) {
            bail!("duplicate task name {:?}", task.name);
        }
        if task.after.iter().any(|d| d == &task.name) {
            bail!("task {:?} depends on itself", task.name);
        }
        for f in task.inputs.iter().chain(&task.outputs) {
            if !valid_file(f) {
                bail!(
                    "task {:?}: file {f:?} invalid (relative paths over [A-Za-z0-9_.-/])",
                    task.name
                );
            }
        }
        if !(task.est_s.is_finite() && task.est_s >= 0.0) {
            bail!("task {:?}: est_s must be finite and >= 0", task.name);
        }
        // kernel artifact names travel unescaped through the pmake
        // `#kernel` marker and the dwork body codec: same alphabet as
        // task names (no braces, no spaces)
        if let Payload::Kernel { artifact, .. } = &task.payload {
            if !valid_name(artifact) {
                bail!(
                    "task {:?}: kernel artifact {artifact:?} invalid (use [A-Za-z0-9_.-])",
                    task.name
                );
            }
        }
        let id = self.tasks.len();
        // duplicate declared outputs are ADMITTED here (first producer
        // wins in `by_output`, keeping `producer_of` and implied edges
        // deterministic) so the analyzer can see the whole graph and
        // report every collision at once (E010/E011); `validate()`
        // still hard-errors on them before anything runs
        for out in &task.outputs {
            self.by_output.entry(out.clone()).or_insert(id);
        }
        self.index.insert(task.name.clone(), id);
        self.tasks.push(task);
        Ok(())
    }

    /// Check referential integrity, acyclicity, and file-race freedom.
    /// A thin bail-on-first wrapper over the collect-all analyzer
    /// ([`crate::analyze::error_diagnostics`]): the first Error-severity
    /// diagnostic becomes the `Err`, with the historical message text.
    /// Every analysis and lowering entry point calls at least
    /// [`WorkflowGraph::check_integrity`]; the spec parser and the
    /// `Session` pre-flight gate call this.
    pub fn validate(&self) -> Result<()> {
        crate::analyze::first_error(crate::analyze::error_diagnostics(self))
    }

    /// Non-topological integrity: dependency names resolve, and no
    /// declared output collides with another task's synthesized
    /// `<name>.done` stamp (the pmake lowering would emit two rules for
    /// one file and silently drop a task).  Bail-on-first wrapper over
    /// [`crate::analyze::races::integrity`]; deliberately does NOT
    /// include the race checks, so `Session::allow_lint_errors(true)`
    /// can still lower a duplicate-output graph (first producer wins,
    /// deterministically).
    pub(crate) fn check_integrity(&self) -> Result<()> {
        crate::analyze::first_error(crate::analyze::races::integrity(self))
    }

    /// Dependencies of task `i`: explicit `after` edges plus *implicit*
    /// producer edges — a declared input file that another task declares
    /// as an output orders the producer first.  Every lowering uses this
    /// (not raw `after`), so file-implied ordering means the same thing
    /// under pmake, dwork and mpi-list alike.
    pub fn deps_of(&self, i: usize) -> Vec<usize> {
        let t = &self.tasks[i];
        let mut deps: Vec<usize> =
            t.after.iter().filter_map(|d| self.index_of(d)).collect();
        for f in &t.inputs {
            if let Some(&p) = self.by_output.get(f) {
                if p != i {
                    deps.push(p);
                }
            }
        }
        deps.sort_unstable();
        deps.dedup();
        deps
    }

    /// Dependency edges as (from, to) index pairs (from must finish
    /// first), explicit and file-implied.
    pub fn edges(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        for i in 0..self.tasks.len() {
            for j in self.deps_of(i) {
                out.push((j, i));
            }
        }
        out
    }

    /// All dependency lists at once — ONE adjacency build that the
    /// analysis passes below thread through instead of re-deriving.
    pub(crate) fn preds_vec(&self) -> Vec<Vec<usize>> {
        (0..self.tasks.len()).map(|i| self.deps_of(i)).collect()
    }

    /// Kahn topological order over a prebuilt adjacency, deterministic
    /// for a given graph (sources in insertion order, then BFS discovery
    /// order as tasks unblock).  Errors name one task on a cycle.
    pub(crate) fn topo_order_from(&self, preds: &[Vec<usize>]) -> Result<Vec<usize>> {
        let n = self.tasks.len();
        let mut indegree: Vec<usize> = preds.iter().map(Vec::len).collect();
        let mut successors: Vec<Vec<usize>> = vec![Vec::new(); n];
        for (i, ps) in preds.iter().enumerate() {
            for &p in ps {
                successors[p].push(i);
            }
        }
        let mut ready: Vec<usize> =
            (0..n).filter(|&i| indegree[i] == 0).collect();
        let mut order = Vec::with_capacity(n);
        let mut cursor = 0;
        while cursor < ready.len() {
            let i = ready[cursor];
            cursor += 1;
            order.push(i);
            for &s in &successors[i] {
                indegree[s] -= 1;
                if indegree[s] == 0 {
                    ready.push(s);
                }
            }
        }
        if order.len() != n {
            let stuck = (0..n)
                .find(|&i| indegree[i] > 0)
                .map(|i| self.tasks[i].name.clone())
                .unwrap_or_default();
            bail!("workflow {:?} has a dependency cycle (through {stuck:?})", self.name);
        }
        Ok(order)
    }

    /// Kahn topological order (see [`WorkflowGraph::topo_order_from`]).
    pub fn topo_order(&self) -> Result<Vec<usize>> {
        self.topo_order_from(&self.preds_vec())
    }

    /// Level assignment over a prebuilt adjacency + topo order.
    pub(crate) fn levels_from(preds: &[Vec<usize>], order: &[usize]) -> Vec<Vec<usize>> {
        let mut level = vec![0usize; preds.len()];
        let mut max_level = 0usize;
        for &i in order {
            let l = preds[i].iter().map(|&j| level[j] + 1).max().unwrap_or(0);
            level[i] = l;
            max_level = max_level.max(l);
        }
        let mut out: Vec<Vec<usize>> = vec![Vec::new(); max_level + 1];
        for &i in order {
            out[level[i]].push(i);
        }
        out
    }

    /// Topological levels: level(t) = 1 + max level of its dependencies.
    /// Level k holds the tasks that *could* start in bulk-synchronous
    /// phase k — the mpi-list lowering's phase structure.
    pub fn levels(&self) -> Result<Vec<Vec<usize>>> {
        let preds = self.preds_vec();
        let order = self.topo_order_from(&preds)?;
        Ok(Self::levels_from(&preds, &order))
    }

    /// Critical path DP over a prebuilt adjacency + topo order.
    fn critical_path_from(&self, preds: &[Vec<usize>], order: &[usize]) -> f64 {
        let mut finish = vec![0f64; self.tasks.len()];
        let mut best = 0f64;
        for &i in order {
            let start = preds[i].iter().map(|&j| finish[j]).fold(0f64, f64::max);
            finish[i] = start + self.tasks[i].est_s;
            best = best.max(finish[i]);
        }
        best
    }

    /// Critical path length in estimated seconds.
    pub fn critical_path_s(&self) -> Result<f64> {
        let preds = self.preds_vec();
        let order = self.topo_order_from(&preds)?;
        Ok(self.critical_path_from(&preds, &order))
    }

    /// Full shape analysis (one integrity pass, one adjacency build).
    pub fn stats(&self) -> Result<GraphStats> {
        Ok(self.analyze()?.0)
    }

    /// Stats + topological levels from a single integrity/adjacency
    /// pass — what the selector consumes (it needs both).
    pub fn analyze(&self) -> Result<(GraphStats, Vec<Vec<usize>>)> {
        self.check_integrity()?;
        let preds = self.preds_vec();
        let order = self.topo_order_from(&preds)?;
        let levels = Self::levels_from(&preds, &order);
        let n = self.tasks.len();
        let total: f64 = self.tasks.iter().map(|t| t.est_s).sum();
        let mean = if n == 0 { 0.0 } else { total / n as f64 };
        let var = if n == 0 {
            0.0
        } else {
            self.tasks.iter().map(|t| (t.est_s - mean).powi(2)).sum::<f64>() / n as f64
        };
        let cv = if mean > 0.0 { var.sqrt() / mean } else { 0.0 };
        let cp = self.critical_path_from(&preds, &order);
        let first_kind = self.tasks.first().map(|t| t.payload.kind());
        let stats = GraphStats {
            tasks: n,
            edges: preds.iter().map(Vec::len).sum(),
            depth: levels.len(),
            width: levels.iter().map(Vec::len).max().unwrap_or(0),
            total_work_s: total,
            critical_path_s: cp,
            mean_task_s: mean,
            cv_task_s: cv,
            max_parallelism: if cp > 0.0 { total / cp } else { n as f64 },
            file_sync: self.tasks.iter().any(|t| !t.outputs.is_empty()),
            uniform_payload: self
                .tasks
                .iter()
                .all(|t| Some(t.payload.kind()) == first_kind),
        };
        Ok((stats, levels))
    }

    /// Sink tasks (no successors) — the targets of a file-based lowering.
    pub fn sinks(&self) -> Vec<usize> {
        let mut has_succ = vec![false; self.tasks.len()];
        for (from, _) in self.edges() {
            has_succ[from] = true;
        }
        (0..self.tasks.len()).filter(|&i| !has_succ[i]).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diamond() -> WorkflowGraph {
        let mut g = WorkflowGraph::new("diamond");
        g.add_task(TaskSpec::new("root").est(2.0)).unwrap();
        g.add_task(TaskSpec::new("l").after(&["root"]).est(3.0)).unwrap();
        g.add_task(TaskSpec::new("r").after(&["root"]).est(1.0)).unwrap();
        g.add_task(TaskSpec::new("join").after(&["l", "r"]).est(1.0)).unwrap();
        g
    }

    #[test]
    fn topo_and_levels() {
        let g = diamond();
        let order = g.topo_order().unwrap();
        assert_eq!(order[0], 0);
        assert_eq!(*order.last().unwrap(), 3);
        let levels = g.levels().unwrap();
        assert_eq!(levels.len(), 3);
        assert_eq!(levels[0], vec![0]);
        assert_eq!(levels[1], vec![1, 2]);
        assert_eq!(levels[2], vec![3]);
    }

    #[test]
    fn stats_shape() {
        let g = diamond();
        let s = g.stats().unwrap();
        assert_eq!(s.tasks, 4);
        assert_eq!(s.edges, 4);
        assert_eq!(s.depth, 3);
        assert_eq!(s.width, 2);
        assert!((s.total_work_s - 7.0).abs() < 1e-12);
        // critical path: root(2) -> l(3) -> join(1) = 6
        assert!((s.critical_path_s - 6.0).abs() < 1e-12);
        assert!(!s.file_sync);
        assert!(s.uniform_payload);
    }

    #[test]
    fn cycle_detected() {
        let mut g = WorkflowGraph::new("cyc");
        g.add_task(TaskSpec::new("a").after(&["b"])).unwrap();
        g.add_task(TaskSpec::new("b").after(&["a"])).unwrap();
        let err = g.validate().unwrap_err();
        assert!(err.to_string().contains("cycle"), "{err}");
    }

    #[test]
    fn unknown_dep_detected() {
        let mut g = WorkflowGraph::new("dangling");
        g.add_task(TaskSpec::new("a").after(&["ghost"])).unwrap();
        assert!(g.validate().is_err());
    }

    #[test]
    fn hygiene_rejected() {
        let mut g = WorkflowGraph::new("bad");
        assert!(g.add_task(TaskSpec::new("has space")).is_err());
        assert!(g.add_task(TaskSpec::new("brace{x}")).is_err());
        assert!(g.add_task(TaskSpec::new("")).is_err());
        g.add_task(TaskSpec::new("ok")).unwrap();
        assert!(g.add_task(TaskSpec::new("ok")).is_err(), "duplicate");
        assert!(g.add_task(TaskSpec::new("self").after(&["self"])).is_err());
        assert!(g
            .add_task(TaskSpec::command("abs", "x").outputs(&["/etc/passwd"]))
            .is_err());
        let mut nan = TaskSpec::new("nan");
        nan.est_s = f64::NAN;
        assert!(g.add_task(nan).is_err());
        // kernel artifact names share the task-name alphabet
        assert!(g.add_task(TaskSpec::kernel("kbad", "atb_{rule}", 0)).is_err());
        assert!(g.add_task(TaskSpec::kernel("kbad2", "atb 64", 0)).is_err());
        assert!(g.add_task(TaskSpec::kernel("kok", "atb_64", 0)).is_ok());
    }

    #[test]
    fn stamp_collision_rejected() {
        // task 'a' has no outputs, so its pmake stamp is 'a.done'; a task
        // declaring that very file as an output would alias two rules
        let mut g = WorkflowGraph::new("stamp");
        g.add_task(TaskSpec::new("a")).unwrap();
        g.add_task(TaskSpec::command("b", "touch a.done").outputs(&["a.done"])).unwrap();
        let err = g.validate().unwrap_err();
        assert!(err.to_string().contains("stamp"), "{err}");
        assert!(g.stats().is_err(), "stats performs the same integrity check");
    }

    #[test]
    fn duplicate_outputs_rejected_by_validate() {
        // admitted at insert time (the analyzer needs to see the whole
        // graph to report every collision), hard error before running;
        // `producer_of` stays deterministic: the first producer wins
        let mut g = WorkflowGraph::new("dup");
        g.add_task(TaskSpec::command("a", "touch x").outputs(&["x.out"])).unwrap();
        g.add_task(TaskSpec::command("b", "touch x").outputs(&["x.out"])).unwrap();
        let err = g.validate().unwrap_err();
        assert!(err.to_string().contains("both declare"), "{err}");
        assert_eq!(g.producer_of("x.out").unwrap().name, "a");
        assert!(g.check_integrity().is_ok(), "integrity alone admits it (escape hatch)");
    }

    #[test]
    fn sync_files_stamp_fallback() {
        let t = TaskSpec::new("plain");
        assert_eq!(t.sync_files(), vec!["plain.done"]);
        let t = TaskSpec::new("filey").outputs(&["a.txt", "b.txt"]);
        assert_eq!(t.sync_files(), vec!["a.txt", "b.txt"]);
    }

    #[test]
    fn payload_body_roundtrip() {
        for p in [
            Payload::Command { script: "echo hi\ntouch x".into() },
            Payload::Kernel { artifact: "atb_64".into(), seed: 7 },
            Payload::Noop,
        ] {
            assert_eq!(Payload::decode_body(&p.encode_body()).unwrap(), p);
        }
        assert!(Payload::decode_body(b"warp\n?").is_err());
    }

    #[test]
    fn sinks_of_diamond() {
        assert_eq!(diamond().sinks(), vec![3]);
    }

    #[test]
    fn declared_inputs_imply_producer_edges() {
        // B never says `after: [A]` but reads A's declared output: the
        // edge must exist for EVERY lowering, not just pmake's file walk
        let mut g = WorkflowGraph::new("implicit");
        g.add_task(TaskSpec::command("a", "echo > data.txt").outputs(&["data.txt"])).unwrap();
        let mut b = TaskSpec::command("b", "cat data.txt");
        b.inputs = vec!["data.txt".into()];
        g.add_task(b).unwrap();
        assert_eq!(g.deps_of(1), vec![0]);
        assert_eq!(g.edges(), vec![(0, 1)]);
        let levels = g.levels().unwrap();
        assert_eq!(levels.len(), 2, "file-implied edge creates a level");
        // and a file cycle is still a cycle
        let mut g = WorkflowGraph::new("filecycle");
        let mut a = TaskSpec::command("a", "x").outputs(&["a.out"]);
        a.inputs = vec!["b.out".into()];
        let mut b = TaskSpec::command("b", "x").outputs(&["b.out"]);
        b.inputs = vec!["a.out".into()];
        g.add_task(a).unwrap();
        g.add_task(b).unwrap();
        assert!(g.validate().is_err());
    }

    #[test]
    fn flat_map_stats() {
        let mut g = WorkflowGraph::new("map");
        for i in 0..32 {
            g.add_task(TaskSpec::kernel(format!("k{i}"), "atb_64", i).est(0.5)).unwrap();
        }
        let s = g.stats().unwrap();
        assert_eq!(s.depth, 1);
        assert_eq!(s.width, 32);
        assert_eq!(s.edges, 0);
        assert!(s.cv_task_s < 1e-12);
        assert!(s.uniform_payload);
        assert!(!s.file_sync);
    }
}
