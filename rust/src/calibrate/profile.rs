//! The persisted artifact of a calibration run: a versioned, field-wise
//! set of [`CostModel`] overrides, serialized as TOML (hand-rolled —
//! serde is unavailable offline, and the format is ten numeric keys).
//!
//! A profile never stores a *whole* cost model: parameters a trace
//! cannot constrain (python import scaling, connection-storm slopes at
//! rank counts nobody traced) stay `None` and fall back to the Table-4
//! defaults, so loading a profile fitted from one backend's traces
//! leaves the other components exactly as the paper calibrated them.

use std::path::Path;

use anyhow::{bail, Context as _, Result};

use crate::substrate::cluster::costs::{CostModel, CostOverrides};

/// Bump on any change to the on-disk format.
pub const PROFILE_VERSION: u32 = 1;

/// A versioned calibration profile: provenance plus field-wise cost
/// model overrides.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CalibrationProfile {
    pub version: u32,
    /// free-text provenance ("fitted from 3 traces by threesched calibrate")
    pub source: String,
    pub overrides: CostOverrides,
}

impl CalibrationProfile {
    pub fn new(source: impl Into<String>) -> CalibrationProfile {
        CalibrationProfile {
            version: PROFILE_VERSION,
            source: source.into(),
            overrides: CostOverrides::default(),
        }
    }

    /// No field is overridden (fitting found nothing usable).
    pub fn is_empty(&self) -> bool {
        self.overrides.fields().iter().all(|(_, v)| v.is_none())
    }

    /// The cost model this profile denotes: Table-4 defaults with the
    /// fitted fields swapped in.
    pub fn model(&self) -> CostModel {
        CostModel::from_profile(&self.overrides)
    }

    // ------------------------------------------------------------ TOML

    /// Serialize to TOML.  `f64` values print via Rust's shortest
    /// round-trip formatting, so parse(to_toml(p)) == p exactly.
    pub fn to_toml(&self) -> String {
        let mut out = String::from("# threesched calibration profile\n");
        out.push_str(&format!("version = {}\n", self.version));
        out.push_str(&format!("source = \"{}\"\n", toml_escape(&self.source)));
        out.push_str("\n[cost_model]\n");
        for (name, v) in self.overrides.fields() {
            if let Some(x) = v {
                out.push_str(&format!("{name} = {}\n", fmt_f64(x)));
            }
        }
        out
    }

    /// Parse the TOML emitted by [`CalibrationProfile::to_toml`].
    /// Unknown keys are an error (a typo'd override silently falling
    /// back to the default would defeat the whole subsystem).
    pub fn parse_toml(text: &str) -> Result<CalibrationProfile> {
        let mut p = CalibrationProfile { version: 0, ..CalibrationProfile::default() };
        let mut section = String::new();
        for (n, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let Some(name) = rest.strip_suffix(']') else {
                    bail!("line {}: malformed section header {line:?}", n + 1);
                };
                section = name.trim().to_string();
                if section != "cost_model" {
                    bail!("line {}: unknown section [{section}]", n + 1);
                }
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected key = value, got {line:?}", n + 1);
            };
            let (key, value) = (key.trim(), value.trim());
            match (section.as_str(), key) {
                ("", "version") => {
                    p.version = value
                        .parse()
                        .with_context(|| format!("line {}: bad version {value:?}", n + 1))?;
                }
                ("", "source") => {
                    let inner = value
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .with_context(|| format!("line {}: source must be quoted", n + 1))?;
                    p.source = toml_unescape(inner)?;
                }
                ("cost_model", _) => {
                    let x: f64 = value
                        .parse()
                        .with_context(|| format!("line {}: bad number {value:?}", n + 1))?;
                    if !x.is_finite() {
                        bail!("line {}: {key} must be finite, got {value:?}", n + 1);
                    }
                    if !p.overrides.set(key, x) {
                        bail!("line {}: unknown cost_model field {key:?}", n + 1);
                    }
                }
                _ => bail!("line {}: unknown key {key:?}", n + 1),
            }
        }
        if p.version != PROFILE_VERSION {
            bail!(
                "unsupported calibration profile version {} (want {PROFILE_VERSION})",
                p.version
            );
        }
        Ok(p)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent().filter(|d| !d.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent).with_context(|| format!("creating {parent:?}"))?;
        }
        std::fs::write(path, self.to_toml()).with_context(|| format!("writing {path:?}"))
    }

    pub fn load(path: &Path) -> Result<CalibrationProfile> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Self::parse_toml(&text).with_context(|| format!("parsing {path:?}"))
    }
}

/// Shortest round-trip float formatting that stays valid TOML (TOML
/// floats require a decimal point or exponent; Rust prints `1` for 1.0).
fn fmt_f64(x: f64) -> String {
    let s = format!("{x}");
    if s.contains('.') || s.contains('e') || s.contains('E') {
        s
    } else {
        format!("{s}.0")
    }
}

fn toml_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c => out.push(c),
        }
    }
    out
}

fn toml_unescape(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('n') => out.push('\n'),
            Some('t') => out.push('\t'),
            Some('r') => out.push('\r'),
            other => bail!("bad escape \\{other:?} in source string"),
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::prop::check;

    fn sample() -> CalibrationProfile {
        let mut p = CalibrationProfile::new("unit test");
        p.overrides.steal_rtt = Some(17.5e-6);
        p.overrides.jsrun_a = Some(-0.25);
        p.overrides.gumbel_beta_per_task = Some(1.0625e-4);
        p
    }

    #[test]
    fn toml_roundtrip_exact() {
        let p = sample();
        let text = p.to_toml();
        let q = CalibrationProfile::parse_toml(&text).unwrap();
        assert_eq!(p, q, "{text}");
    }

    #[test]
    fn empty_profile_roundtrips() {
        let p = CalibrationProfile::new("");
        assert!(p.is_empty());
        let q = CalibrationProfile::parse_toml(&p.to_toml()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn model_applies_only_overridden_fields() {
        let p = sample();
        let base = CostModel::paper();
        let m = p.model();
        assert_eq!(m.steal_rtt, 17.5e-6);
        assert_eq!(m.jsrun_a, -0.25);
        assert_eq!(m.alloc, base.alloc, "untouched field keeps the default");
        assert_eq!(m.conn_b, base.conn_b);
    }

    #[test]
    fn unknown_field_rejected() {
        let text = "version = 1\nsource = \"x\"\n[cost_model]\nwarp_drive = 9.0\n";
        assert!(CalibrationProfile::parse_toml(text).is_err());
    }

    #[test]
    fn unknown_section_rejected() {
        assert!(CalibrationProfile::parse_toml("version = 1\n[mystery]\nx = 1.0\n").is_err());
    }

    #[test]
    fn wrong_version_rejected() {
        let text = "version = 99\nsource = \"x\"\n";
        let err = CalibrationProfile::parse_toml(text).unwrap_err();
        assert!(err.to_string().contains("version 99"), "{err}");
    }

    #[test]
    fn missing_version_rejected() {
        assert!(CalibrationProfile::parse_toml("source = \"x\"\n").is_err());
    }

    #[test]
    fn non_finite_rejected() {
        let text = "version = 1\n[cost_model]\nsteal_rtt = NaN\n";
        assert!(CalibrationProfile::parse_toml(text).is_err());
        let text = "version = 1\n[cost_model]\nsteal_rtt = inf\n";
        assert!(CalibrationProfile::parse_toml(text).is_err());
    }

    #[test]
    fn source_escaping_roundtrips() {
        let mut p = CalibrationProfile::new("quo\"te\\slash\nnewline\ttab");
        p.overrides.alloc = Some(2.0);
        let q = CalibrationProfile::parse_toml(&p.to_toml()).unwrap();
        assert_eq!(p, q);
    }

    #[test]
    fn prop_serialize_deserialize_identity() {
        // the satellite property test: arbitrary finite values in every
        // field (including negatives, subnormal-ish magnitudes, and the
        // None pattern) survive the TOML round-trip bit-for-bit
        check("profile toml roundtrip", 200, |g| {
            let mut p = CalibrationProfile::new("prop");
            let names: Vec<&'static str> =
                p.overrides.fields().iter().map(|&(n, _)| n).collect();
            for name in names {
                if g.bool(0.7) {
                    let mag = g.f64(-30.0, 30.0);
                    let x = g.f64(-1.0, 1.0) * 10f64.powf(mag);
                    assert!(p.overrides.set(name, x), "unknown field {name}");
                }
            }
            let q = CalibrationProfile::parse_toml(&p.to_toml())
                .unwrap_or_else(|e| panic!("reparse failed: {e}\n{}", p.to_toml()));
            assert_eq!(p, q, "{}", p.to_toml());
        });
    }
}
