//! Per-backend parameter estimators: measured traces in, a
//! [`CalibrationProfile`] of fitted [`CostModel`] constants out.
//!
//! Each backend's METG law exposes one trace-measurable signature:
//!
//! * **pmake** — every job step pays `jsrun(P) + alloc` between
//!   `Launched` and `Started`; the per-trace median launch window,
//!   regressed against `log2 P` across traces (Theil–Sen), recovers the
//!   launch law.  With a single rank count the slope is unidentifiable,
//!   so the default `jsrun_b` is kept and only the intercept refits.
//! * **dwork** — a saturated task server serializes steals, so the gaps
//!   between consecutive `Launched` events cluster at exactly one
//!   steal/complete RTT; the MAD-inlier mean of the pooled gaps is the
//!   estimate (idle-period gaps are the outliers being rejected).
//! * **mpi-list** — straggler spread comes from per-task Gumbel noise
//!   with scale `gumbel_beta_per_task`; the interdecile range of the
//!   compute durations estimates the scale with the per-task base
//!   duration cancelling (uniform calibration workloads make this
//!   exact; heterogeneous ones inflate it, which the report's CI shows).
//!
//! A hub trace that records worker `Connected` events additionally
//! constrains the **connection storm** law: attaches serialize at the
//! hub, so consecutive attach-time gaps cluster at the per-attach cost
//! `conn_b` (the slope of `conn(P) = conn_a + conn_b·P`; the intercept
//! is not separable from one storm and keeps its default).
//!
//! Parameters no lifecycle trace constrains (python imports) are left
//! at their Table-4 defaults — the profile simply does not mention
//! them.

use anyhow::{bail, Result};

use crate::metg::simmodels::Tool;
use crate::substrate::cluster::costs::CostModel;
use crate::trace::compare::tool_of_source;
use crate::trace::samples::PhaseSamples;
use crate::trace::{EventKind, TaskEvent};

use super::profile::CalibrationProfile;
use super::robust::{self, Estimate};

/// Fewest pooled launch gaps worth fitting an RTT from.
const MIN_GAPS: usize = 8;
/// Fewest pooled attach gaps worth fitting a per-attach cost from
/// (a storm of nine workers or more).
const MIN_ATTACH_GAPS: usize = 8;
/// Fewest launch-window samples for a per-trace pmake point.
const MIN_LAUNCH: usize = 3;
/// MAD multiplier for inlier filtering.
const OUTLIER_K: f64 = 3.5;

/// One input trace, classified and pre-digested for fitting.
#[derive(Clone, Debug)]
pub struct ClassifiedTrace {
    pub source: String,
    pub tool: Tool,
    /// parallelism the trace ran at (explicit override or inferred)
    pub ranks: usize,
    pub samples: PhaseSamples,
    pub makespan_s: f64,
    pub events: Vec<TaskEvent>,
}

/// Classify a trace by its source label and infer its parallelism
/// (worker labels, else peak in-flight tasks) unless overridden.
pub fn classify_trace(
    source: &str,
    events: Vec<TaskEvent>,
    ranks_override: Option<usize>,
) -> Result<ClassifiedTrace> {
    let Some(tool) = tool_of_source(source) else {
        bail!(
            "trace source {source:?} does not name a backend \
             (want pmake, dwork, or mpi-list in the label)"
        );
    };
    let samples = PhaseSamples::from_events(&events);
    let ranks = ranks_override.unwrap_or_else(|| samples.inferred_parallelism(&events)).max(1);
    Ok(ClassifiedTrace {
        source: source.to_string(),
        tool,
        ranks,
        makespan_s: samples.makespan_s,
        samples,
        events,
    })
}

/// One fitted parameter with its provenance.
#[derive(Clone, Debug)]
pub struct ParamEstimate {
    /// `CostOverrides` field name
    pub param: &'static str,
    /// backend whose traces produced it
    pub tool: Tool,
    /// the Table-4 default it replaces
    pub default: f64,
    pub estimate: Estimate,
}

/// Everything a fitting pass produced.
#[derive(Clone, Debug, Default)]
pub struct Calibration {
    pub profile: CalibrationProfile,
    pub estimates: Vec<ParamEstimate>,
    /// human-readable notes on what could NOT be fitted, and why
    pub notes: Vec<String>,
}

/// Fit a calibration profile from classified traces against `base`
/// (normally [`CostModel::paper`]).  Backends with no usable traces
/// contribute nothing; an entirely unusable input set is an error.
pub fn fit_traces(traces: &[ClassifiedTrace], base: &CostModel) -> Result<Calibration> {
    if traces.is_empty() {
        bail!("no traces to fit");
    }
    let mut cal = Calibration {
        profile: CalibrationProfile::new(format!(
            "fitted by threesched calibrate from {} trace(s)",
            traces.len()
        )),
        ..Calibration::default()
    };
    fit_dwork(traces, base, &mut cal);
    fit_attach(traces, base, &mut cal);
    fit_mpilist(traces, base, &mut cal);
    fit_pmake(traces, base, &mut cal);
    if cal.profile.is_empty() {
        bail!(
            "no parameter could be fitted from the supplied traces:\n  {}",
            cal.notes.join("\n  ")
        );
    }
    Ok(cal)
}

fn fit_dwork(traces: &[ClassifiedTrace], base: &CostModel, cal: &mut Calibration) {
    let mut gaps: Vec<f64> = Vec::new();
    let mut n_traces = 0usize;
    for t in traces.iter().filter(|t| t.tool == Tool::Dwork) {
        gaps.extend(t.samples.launch_gaps());
        n_traces += 1;
    }
    if n_traces == 0 {
        cal.notes.push("steal_rtt: no dwork traces supplied".into());
        return;
    }
    if gaps.len() < MIN_GAPS {
        cal.notes.push(format!(
            "steal_rtt: only {} launch gap(s) across {n_traces} dwork trace(s) \
             (need >= {MIN_GAPS}; run a finer-grained calibration workload)",
            gaps.len()
        ));
        return;
    }
    let Some(est) = robust::robust_mean(&gaps, OUTLIER_K) else {
        return;
    };
    if !(est.value.is_finite() && est.value > 0.0) {
        cal.notes.push(format!("steal_rtt: degenerate estimate {}", est.value));
        return;
    }
    cal.profile.overrides.steal_rtt = Some(est.value);
    cal.estimates.push(ParamEstimate {
        param: "steal_rtt",
        tool: Tool::Dwork,
        default: base.steal_rtt,
        estimate: est,
    });
}

fn fit_attach(traces: &[ClassifiedTrace], base: &CostModel, cal: &mut Calibration) {
    // a storm of workers joining a fresh hub serializes in the accept
    // loop: consecutive Connected-event gaps cluster at the per-attach
    // cost, which is the slope conn_b of conn(P) = conn_a + conn_b·P.
    // Only real hub traces carry Connected events (the DES never emits
    // them), so a purely simulated input set simply leaves conn_b alone.
    let mut gaps: Vec<f64> = Vec::new();
    let mut n_traces = 0usize;
    let mut with_conn = 0usize;
    for t in traces.iter().filter(|t| t.tool == Tool::Dwork) {
        n_traces += 1;
        let mut ts: Vec<f64> = t
            .events
            .iter()
            .filter(|e| e.kind == EventKind::Connected)
            .map(|e| e.t)
            .collect();
        if ts.len() < 2 {
            continue;
        }
        with_conn += 1;
        ts.sort_by(f64::total_cmp);
        gaps.extend(ts.windows(2).map(|w| w[1] - w[0]));
    }
    if n_traces == 0 {
        // the steal_rtt pass already noted the absence of dwork traces
        return;
    }
    if gaps.len() < MIN_ATTACH_GAPS {
        cal.notes.push(format!(
            "conn_b: only {} attach gap(s) across {with_conn} dwork trace(s) with \
             Connected events (need >= {MIN_ATTACH_GAPS}; trace a hub while a \
             larger worker storm joins)",
            gaps.len()
        ));
        return;
    }
    let Some(est) = robust::robust_mean(&gaps, OUTLIER_K) else {
        return;
    };
    if !(est.value.is_finite() && est.value > 0.0) {
        cal.notes.push(format!("conn_b: degenerate estimate {}", est.value));
        return;
    }
    cal.profile.overrides.conn_b = Some(est.value);
    cal.estimates.push(ParamEstimate {
        param: "conn_b",
        tool: Tool::Dwork,
        default: base.conn_b,
        estimate: est,
    });
}

fn fit_mpilist(traces: &[ClassifiedTrace], base: &CostModel, cal: &mut Calibration) {
    // per-trace scale estimates (pooling across traces would mix base
    // durations and wreck the location-cancelling interdecile)
    let mut per: Vec<Estimate> = Vec::new();
    let mut n_traces = 0usize;
    for t in traces.iter().filter(|t| t.tool == Tool::MpiList) {
        n_traces += 1;
        match robust::gumbel_scale(&t.samples.compute) {
            Some(e) if e.value.is_finite() && e.value > 0.0 => per.push(e),
            _ => cal.notes.push(format!(
                "gumbel_beta_per_task: trace {:?} has too few or degenerate \
                 compute samples ({})",
                t.source,
                t.samples.compute.len()
            )),
        }
    }
    if n_traces == 0 {
        cal.notes.push("gumbel_beta_per_task: no mpi-list traces supplied".into());
        return;
    }
    if per.is_empty() {
        return;
    }
    // combine: sample-count-weighted mean, conservative CI
    let wsum: f64 = per.iter().map(|e| e.n as f64).sum();
    let value = per.iter().map(|e| e.value * e.n as f64).sum::<f64>() / wsum;
    let ci95 = per.iter().map(|e| e.ci95).fold(0.0, f64::max);
    let n = per.iter().map(|e| e.n).sum();
    cal.profile.overrides.gumbel_beta_per_task = Some(value);
    cal.estimates.push(ParamEstimate {
        param: "gumbel_beta_per_task",
        tool: Tool::MpiList,
        default: base.gumbel_beta_per_task,
        estimate: Estimate { value, ci95, n, rejected: 0 },
    });
}

fn fit_pmake(traces: &[ClassifiedTrace], base: &CostModel, cal: &mut Calibration) {
    // one (log2 ranks, median launch window, CI) point per pmake trace
    let mut points: Vec<(f64, f64, Estimate)> = Vec::new();
    let mut n_traces = 0usize;
    for t in traces.iter().filter(|t| t.tool == Tool::Pmake) {
        n_traces += 1;
        if t.samples.launch.len() < MIN_LAUNCH {
            cal.notes.push(format!(
                "pmake launch law: trace {:?} has only {} launch sample(s) \
                 (need >= {MIN_LAUNCH})",
                t.source,
                t.samples.launch.len()
            ));
            continue;
        }
        if let Some(e) = robust::robust_mean(&t.samples.launch, OUTLIER_K) {
            points.push(((t.ranks as f64).log2(), e.value, e));
        }
    }
    if n_traces == 0 {
        cal.notes.push("pmake launch law: no pmake traces supplied".into());
        return;
    }
    if points.is_empty() {
        return;
    }
    let ci95 = points.iter().map(|&(_, _, e)| e.ci95).fold(0.0, f64::max);
    let n: usize = points.iter().map(|&(_, _, e)| e.n).sum();
    let rejected: usize = points.iter().map(|&(_, _, e)| e.rejected).sum();
    let mut distinct: Vec<f64> = points.iter().map(|&(x, _, _)| x).collect();
    distinct.sort_by(f64::total_cmp);
    distinct.dedup_by(|a, b| (*a - *b).abs() < 1e-9);

    // the launch window is jsrun(P) + alloc; alloc and the jsrun
    // intercept are not separable from launch data, so alloc keeps its
    // default and the intercept absorbs the difference
    let (jsrun_b, slope_fitted) = if distinct.len() >= 2 {
        let xs: Vec<f64> = points.iter().map(|&(x, _, _)| x).collect();
        let ys: Vec<f64> = points.iter().map(|&(_, y, _)| y).collect();
        match robust::theil_sen(&xs, &ys) {
            Some((_, b)) if b >= 0.0 => (b, true),
            _ => {
                cal.notes.push(
                    "pmake launch law: cross-rank slope unusable (negative or \
                     degenerate); keeping the default jsrun_b"
                        .into(),
                );
                (base.jsrun_b, false)
            }
        }
    } else {
        (base.jsrun_b, false)
    };
    // intercept: weighted mean of per-trace (launch − b·log2 P) − alloc
    let wsum: f64 = points.iter().map(|&(_, _, e)| e.n as f64).sum();
    let jsrun_a = points
        .iter()
        .map(|&(x, y, e)| (y - jsrun_b * x) * e.n as f64)
        .sum::<f64>()
        / wsum
        - base.alloc;

    if !jsrun_a.is_finite() || !jsrun_b.is_finite() {
        cal.notes.push("pmake launch law: non-finite fit discarded".into());
        return;
    }
    cal.profile.overrides.jsrun_a = Some(jsrun_a);
    cal.estimates.push(ParamEstimate {
        param: "jsrun_a",
        tool: Tool::Pmake,
        default: base.jsrun_a,
        estimate: Estimate { value: jsrun_a, ci95, n, rejected },
    });
    if slope_fitted {
        cal.profile.overrides.jsrun_b = Some(jsrun_b);
        cal.estimates.push(ParamEstimate {
            param: "jsrun_b",
            tool: Tool::Pmake,
            default: base.jsrun_b,
            estimate: Estimate { value: jsrun_b, ci95, n, rejected },
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::calibrate::workloads;
    use crate::substrate::cluster::costs::CostOverrides;

    fn perturbed() -> CostModel {
        workloads::perturbed_model()
    }

    fn classified(m: &CostModel) -> Vec<ClassifiedTrace> {
        workloads::standard()
            .iter()
            .map(|run| {
                let (source, events) = workloads::simulate(run, m, 42).unwrap();
                classify_trace(&source, events, None).unwrap()
            })
            .collect()
    }

    #[test]
    fn classify_requires_backend_in_source() {
        assert!(classify_trace("mystery", Vec::new(), None).is_err());
        let t = classify_trace("des:dwork", Vec::new(), Some(8)).unwrap();
        assert_eq!(t.tool, Tool::Dwork);
        assert_eq!(t.ranks, 8);
    }

    #[test]
    fn fit_recovers_injected_constants() {
        let inj = perturbed();
        let base = CostModel::paper();
        let traces = classified(&inj);
        let cal = fit_traces(&traces, &base).unwrap();
        let fitted = cal.profile.model();
        let rel = |a: f64, b: f64| (a - b).abs() / b.abs();
        assert!(
            rel(fitted.steal_rtt, inj.steal_rtt) < 0.10,
            "steal_rtt {} vs injected {}",
            fitted.steal_rtt,
            inj.steal_rtt
        );
        assert!(
            rel(fitted.gumbel_beta_per_task, inj.gumbel_beta_per_task) < 0.10,
            "beta {} vs injected {}",
            fitted.gumbel_beta_per_task,
            inj.gumbel_beta_per_task
        );
        // the chain ran at 1 rank: the fitted launch law must match there
        assert!(
            rel(fitted.metg_pmake(1), inj.metg_pmake(1)) < 0.10,
            "metg_pmake(1) {} vs injected {}",
            fitted.metg_pmake(1),
            inj.metg_pmake(1)
        );
    }

    #[test]
    fn fit_is_deterministic() {
        let inj = perturbed();
        let base = CostModel::paper();
        let a = fit_traces(&classified(&inj), &base).unwrap();
        let b = fit_traces(&classified(&inj), &base).unwrap();
        assert_eq!(a.profile, b.profile);
    }

    #[test]
    fn missing_backends_noted_not_fatal() {
        let inj = perturbed();
        let run = &workloads::standard()[1]; // the dwork farm
        assert_eq!(run.tool, Tool::Dwork);
        let (source, events) = workloads::simulate(run, &inj, 7).unwrap();
        let traces = vec![classify_trace(&source, events, None).unwrap()];
        let cal = fit_traces(&traces, &CostModel::paper()).unwrap();
        assert!(cal.profile.overrides.steal_rtt.is_some());
        assert!(cal.profile.overrides.jsrun_a.is_none());
        assert!(cal.profile.overrides.gumbel_beta_per_task.is_none());
        assert!(cal.notes.iter().any(|n| n.contains("no pmake traces")));
        assert!(cal.notes.iter().any(|n| n.contains("no mpi-list traces")));
    }

    #[test]
    fn empty_input_is_an_error() {
        assert!(fit_traces(&[], &CostModel::paper()).is_err());
    }

    #[test]
    fn unusable_traces_are_an_error_with_notes() {
        // a dwork trace with a single task has no launch gaps at all
        let g = workloads::dwork_fine_farm(1, 0.01);
        let run = workloads::CalibrationRun { tool: Tool::Dwork, graph: g, ranks: 2 };
        let (source, events) = workloads::simulate(&run, &CostModel::paper(), 1).unwrap();
        let traces = vec![classify_trace(&source, events, None).unwrap()];
        let err = fit_traces(&traces, &CostModel::paper()).unwrap_err();
        assert!(err.to_string().contains("launch gap"), "{err:#}");
    }

    /// Append a synthetic attach storm to a trace: `n` workers joining
    /// serially with gaps cycling 2.9/3.0/3.1 ms (mean exactly 3 ms).
    /// The DES never emits `Connected`, so tests synthesize the storm
    /// the way a real hub trace records it.
    fn push_storm(events: &mut Vec<TaskEvent>, n: usize) {
        let mut t = 0.0;
        for i in 0..n {
            events.push(TaskEvent {
                task: String::new(),
                kind: EventKind::Connected,
                t,
                who: format!("w{i}"),
                seq: 0,
                session: String::new(),
            });
            t += 0.003 + ((i % 3) as f64 - 1.0) * 1e-4;
        }
    }

    #[test]
    fn attach_storm_fits_conn_b() {
        let base = CostModel::paper();
        let run = &workloads::standard()[1]; // the dwork farm
        assert_eq!(run.tool, Tool::Dwork);
        let (source, mut events) = workloads::simulate(run, &base, 5).unwrap();
        push_storm(&mut events, 19);
        // one straggler two seconds later: an idle-period gap the MAD
        // filter must reject rather than fold into the storm law
        events.push(TaskEvent {
            task: String::new(),
            kind: EventKind::Connected,
            t: 2.0,
            who: "late".into(),
            seq: 0,
            session: String::new(),
        });
        let traces = vec![classify_trace(&source, events, None).unwrap()];
        let cal = fit_traces(&traces, &base).unwrap();
        let got = cal.profile.overrides.conn_b.expect("conn_b fitted");
        assert!((got - 0.003).abs() / 0.003 < 0.05, "conn_b {got}");
        let est = cal.estimates.iter().find(|e| e.param == "conn_b").unwrap();
        assert_eq!(est.tool, Tool::Dwork);
        assert!(est.estimate.rejected >= 1, "straggler gap kept: {:?}", est.estimate);
    }

    #[test]
    fn too_few_attach_gaps_noted_not_fitted() {
        let base = CostModel::paper();
        let run = &workloads::standard()[1];
        let (source, mut events) = workloads::simulate(run, &base, 5).unwrap();
        push_storm(&mut events, 3); // two gaps < MIN_ATTACH_GAPS
        let traces = vec![classify_trace(&source, events, None).unwrap()];
        let cal = fit_traces(&traces, &base).unwrap();
        assert!(cal.profile.overrides.conn_b.is_none());
        assert!(cal.notes.iter().any(|n| n.contains("attach gap")), "{:?}", cal.notes);
    }

    #[test]
    fn multi_rank_pmake_traces_fit_the_slope() {
        // farms wide enough to saturate the allocation at two rank
        // counts give the regression a usable cross-rank slope
        let mut inj = CostModel::paper();
        inj.jsrun_b *= 1.5;
        inj.jsrun_a *= 1.3;
        let base = CostModel::paper();
        let mut traces = Vec::new();
        for ranks in [4usize, 32] {
            let g = workloads::pmake_wave_farm(ranks * 3, 5.0);
            let run = workloads::CalibrationRun { tool: Tool::Pmake, graph: g, ranks };
            let (source, events) = workloads::simulate(&run, &inj, 11).unwrap();
            traces.push(classify_trace(&source, events, None).unwrap());
        }
        assert_eq!(traces[0].ranks, 4, "peak-concurrency inference");
        assert_eq!(traces[1].ranks, 32);
        let cal = fit_traces(&traces, &base).unwrap();
        let fitted = cal.profile.model();
        for ranks in [4usize, 32] {
            let rel = (fitted.metg_pmake(ranks) - inj.metg_pmake(ranks)).abs()
                / inj.metg_pmake(ranks);
            assert!(rel < 0.10, "metg_pmake({ranks}) off by {:.1}%", rel * 100.0);
        }
        assert!(cal.profile.overrides.jsrun_b.is_some());
    }

    #[test]
    fn profile_only_overrides_constrained_fields() {
        let traces = classified(&perturbed());
        let cal = fit_traces(&traces, &CostModel::paper()).unwrap();
        let o: CostOverrides = cal.profile.overrides;
        assert!(o.py_alloc.is_none());
        assert!(o.imp_a.is_none());
        assert!(o.conn_a.is_none());
        assert!(o.alloc.is_none(), "alloc is not separable from jsrun_a");
    }
}
