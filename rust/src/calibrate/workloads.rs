//! Canonical calibration workloads: one graph per backend, shaped so
//! that backend's cost constant dominates its trace and the fitters in
//! [`super::fit`] see a clean signal.
//!
//! * the **pmake chain** is strictly serial, so every hop pays the full
//!   `jsrun + alloc` launch window with no queueing ambiguity;
//! * the **dwork farm** is thousands of sub-millisecond tasks, enough
//!   demand to saturate the serialized server so consecutive launches
//!   are exactly one steal RTT apart;
//! * the **mpi-list map** is a flat uniform bulk-synchronous level, so
//!   compute-duration dispersion is pure straggler (Gumbel) noise.
//!
//! The same graphs serve three callers: the CI golden-model regression
//! (simulate with *known* perturbed constants, fit, assert recovery),
//! the `calibrate_roundtrip` example, and users producing real
//! calibration traces with `workflow run --trace`.

use anyhow::Result;

use crate::metg::simmodels::Tool;
use crate::substrate::cluster::costs::CostModel;
use crate::trace::sim::simulate_workflow;
use crate::trace::{TaskEvent, Tracer};
use crate::workflow::{TaskSpec, WorkflowGraph};

/// One calibration workload: a graph plus the scale to run it at.
#[derive(Clone, Debug)]
pub struct CalibrationRun {
    pub tool: Tool,
    pub graph: WorkflowGraph,
    pub ranks: usize,
}

/// Strictly serial chain of coarse tasks (`seg0 -> seg1 -> …`).
pub fn pmake_chain(len: usize, est_s: f64) -> WorkflowGraph {
    let mut g = WorkflowGraph::new("calibrate-pmake-chain");
    for i in 0..len {
        let mut t = TaskSpec::new(format!("seg{i}")).est(est_s);
        if i > 0 {
            t = t.after(&[&format!("seg{}", i - 1)]);
        }
        g.add_task(t).expect("chain task");
    }
    g
}

/// Wide flat farm of coarse tasks — the multi-rank variant for fitting
/// the launch law's slope (several of these at different rank counts).
pub fn pmake_wave_farm(n: usize, est_s: f64) -> WorkflowGraph {
    let mut g = WorkflowGraph::new("calibrate-pmake-farm");
    for i in 0..n {
        g.add_task(TaskSpec::new(format!("job{i}")).est(est_s)).expect("farm task");
    }
    g
}

/// Flat farm of tiny independent tasks (server-saturating).
pub fn dwork_fine_farm(n: usize, est_s: f64) -> WorkflowGraph {
    let mut g = WorkflowGraph::new("calibrate-dwork-farm");
    for i in 0..n {
        g.add_task(TaskSpec::new(format!("t{i}")).est(est_s)).expect("farm task");
    }
    g
}

/// Flat uniform bulk-synchronous map.
pub fn mpilist_uniform_map(n: usize, est_s: f64) -> WorkflowGraph {
    let mut g = WorkflowGraph::new("calibrate-mpilist-map");
    for i in 0..n {
        g.add_task(TaskSpec::new(format!("k{i}")).est(est_s)).expect("map task");
    }
    g
}

/// The standard three-workload calibration suite, in [`Tool::ALL`]
/// order: pmake chain (serial, 16×5 s), dwork farm (1536×0.5 ms at 64
/// workers), mpi-list map (4096×0.1 s at 16 ranks).
pub fn standard() -> Vec<CalibrationRun> {
    vec![
        CalibrationRun { tool: Tool::Pmake, graph: pmake_chain(16, 5.0), ranks: 1 },
        CalibrationRun { tool: Tool::Dwork, graph: dwork_fine_farm(1536, 5e-4), ranks: 64 },
        CalibrationRun { tool: Tool::MpiList, graph: mpilist_uniform_map(4096, 0.1), ranks: 16 },
    ]
}

/// The golden-model ground truth: Table-4 constants deliberately warped
/// (a stand-in for "your cluster").  One definition shared by the CI
/// `calibration-regression` job, the `calibrate_roundtrip` example, and
/// the unit tests, so every golden check asserts the same truth.
pub fn perturbed_model() -> CostModel {
    let mut m = CostModel::paper();
    m.jsrun_a *= 1.7;
    m.alloc *= 1.4;
    m.steal_rtt *= 2.2;
    m.gumbel_beta_per_task *= 2.5;
    m
}

/// DES-simulate one calibration run under `m` and return the trace as
/// (source label, events) — exactly what `trace::write_trace` persists
/// and `threesched calibrate` reads back.
pub fn simulate(run: &CalibrationRun, m: &CostModel, seed: u64) -> Result<(String, Vec<TaskEvent>)> {
    let tracer = Tracer::memory();
    simulate_workflow(run.tool, &run.graph, m, run.ranks, seed, &tracer)?;
    Ok((format!("des:{}", run.tool.name()), tracer.drain()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::validate;

    #[test]
    fn standard_suite_covers_all_backends_in_order() {
        let runs = standard();
        assert_eq!(runs.len(), 3);
        for (run, tool) in runs.iter().zip(Tool::ALL) {
            assert_eq!(run.tool, tool);
            run.graph.validate().unwrap();
            assert!(run.ranks >= 1);
        }
    }

    #[test]
    fn simulated_traces_are_wellformed_and_labeled() {
        let m = CostModel::paper();
        for run in standard() {
            let (source, events) = simulate(&run, &m, 3).unwrap();
            assert!(source.starts_with("des:"));
            validate(&events).unwrap_or_else(|e| panic!("{source}: {e}"));
            assert!(!events.is_empty());
        }
    }

    #[test]
    fn chain_is_serial() {
        let g = pmake_chain(5, 1.0);
        let (stats, _) = g.analyze().unwrap();
        assert_eq!(stats.depth, 5);
        assert_eq!(stats.width, 1);
    }
}
