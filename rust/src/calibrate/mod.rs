//! Trace-driven cost-model auto-calibration: close the
//! predict → measure → refit loop.
//!
//! The adaptive selector ([`crate::workflow::select`]) prices every
//! backend with a [`CostModel`] whose constants were hand-transcribed
//! from the paper's Table 4, and `trace compare` (PR 3) *measures* how
//! wrong those predictions are without doing anything about it.  This
//! subsystem is the missing arrow back: fit the constants from measured
//! JSONL lifecycle traces, persist them as a versioned
//! [`CalibrationProfile`], and let `workflow plan|run --calibration`
//! and `trace compare --calibration` price workloads with *your*
//! cluster's numbers instead of the paper's.
//!
//! The moving parts:
//!
//! * [`robust`] — median/MAD outlier rejection, interdecile Gumbel
//!   scale, Theil–Sen regression, confidence intervals;
//! * [`fit`] — per-backend estimators over
//!   [`PhaseSamples`](crate::trace::samples::PhaseSamples): launch
//!   windows → pmake's `jsrun+alloc` law, saturated launch gaps →
//!   dwork's steal RTT, compute-duration dispersion → mpi-list's
//!   straggler scale;
//! * [`profile`] — the persisted TOML artifact (field-wise
//!   [`CostOverrides`](crate::substrate::cluster::costs::CostOverrides),
//!   unconstrained parameters keep their Table-4 defaults);
//! * [`workloads`] — canonical per-backend calibration graphs, shared
//!   by the CI golden-model regression and real calibration runs;
//! * [`validate_profile`] — the honesty gate: re-simulate each trace's
//!   reconstructed workload under the default and the fitted model
//!   (the same DES behind
//!   [`compare_backends`](crate::trace::compare_backends)) and compare
//!   both against the measured makespan; `threesched calibrate`
//!   refuses to emit a profile that does not lower the mean error.

pub mod fit;
pub mod profile;
pub mod robust;
pub mod workloads;

use anyhow::{bail, Context as _, Result};

use crate::metg::harness::TextTable;
use crate::metg::simmodels::Tool;
use crate::substrate::cluster::costs::CostModel;
use crate::trace::samples::graph_from_trace;
use crate::trace::sim::simulate_workflow;
use crate::trace::Tracer;

pub use fit::{classify_trace, fit_traces, Calibration, ClassifiedTrace, ParamEstimate};
pub use profile::{CalibrationProfile, PROFILE_VERSION};
pub use robust::Estimate;

/// One trace's prediction error under the default and fitted models.
#[derive(Clone, Debug)]
pub struct ValidationRow {
    pub source: String,
    pub tool: Tool,
    pub ranks: usize,
    pub measured_s: f64,
    /// |DES(default) − measured| / measured
    pub err_default: f64,
    /// |DES(fitted) − measured| / measured
    pub err_fitted: f64,
}

/// Before/after cross-validation of a fitted profile.
#[derive(Clone, Debug)]
pub struct Validation {
    pub rows: Vec<ValidationRow>,
    pub mean_err_default: f64,
    pub mean_err_fitted: f64,
}

impl Validation {
    /// The fitted model predicts the measured traces strictly better.
    pub fn improved(&self) -> bool {
        self.mean_err_fitted < self.mean_err_default
    }
}

/// Cross-validate `profile` against the traces it was fitted from (or
/// any other classified traces): reconstruct each trace's workload
/// ([`graph_from_trace`]), DES-simulate it under the default and the
/// fitted model at the trace's own parallelism, and score each model by
/// relative makespan error against the measured trace.  `seed` drives
/// the validation DES noise and should differ from any generation seed.
pub fn validate_profile(
    traces: &[ClassifiedTrace],
    base: &CostModel,
    profile: &CalibrationProfile,
    seed: u64,
) -> Result<Validation> {
    if traces.is_empty() {
        bail!("no traces to validate against");
    }
    let fitted = base.clone().with_overrides(&profile.overrides);
    let mut rows = Vec::with_capacity(traces.len());
    for t in traces {
        if !(t.makespan_s.is_finite() && t.makespan_s > 0.0) {
            bail!("trace {:?} has no usable makespan ({})", t.source, t.makespan_s);
        }
        let g = graph_from_trace(&t.source, &t.events)
            .with_context(|| format!("reconstructing workload of {:?}", t.source))?;
        if g.is_empty() {
            bail!("trace {:?} contains no finished tasks to validate against", t.source);
        }
        // only the trace's own backend matters here, so simulate it
        // directly (the same DES `trace compare` runs for all three)
        let err_of = |m: &CostModel| -> Result<f64> {
            let sim = simulate_workflow(t.tool, &g, m, t.ranks, seed, &Tracer::disabled())
                .with_context(|| format!("simulating {:?} under a candidate model", t.source))?;
            Ok((sim.makespan - t.makespan_s).abs() / t.makespan_s)
        };
        rows.push(ValidationRow {
            source: t.source.clone(),
            tool: t.tool,
            ranks: t.ranks,
            measured_s: t.makespan_s,
            err_default: err_of(base)?,
            err_fitted: err_of(&fitted)?,
        });
    }
    let n = rows.len() as f64;
    Ok(Validation {
        mean_err_default: rows.iter().map(|r| r.err_default).sum::<f64>() / n,
        mean_err_fitted: rows.iter().map(|r| r.err_fitted).sum::<f64>() / n,
        rows,
    })
}

/// Signed adaptive time/value formatting (fitted constants span
/// microseconds to seconds; slopes and intercepts may be negative).
fn fmt_val(v: f64) -> String {
    let (sign, a) = if v < 0.0 { ("-", -v) } else { ("", v) };
    let body = if a == 0.0 {
        "0".to_string()
    } else if a >= 1.0 {
        format!("{a:.3}s")
    } else if a >= 1e-3 {
        format!("{:.3}ms", a * 1e3)
    } else {
        format!("{:.2}us", a * 1e6)
    };
    format!("{sign}{body}")
}

/// Human-facing fit report (the `threesched calibrate` body).
pub fn render_calibration(cal: &Calibration) -> String {
    let mut t = TextTable::new(&[
        "parameter",
        "backend",
        "default",
        "fitted",
        "change",
        "+-95%",
        "samples",
        "rejected",
    ]);
    for e in &cal.estimates {
        let change = if e.default.abs() > 0.0 {
            format!("{:+.1}%", 100.0 * (e.estimate.value - e.default) / e.default.abs())
        } else {
            "-".into()
        };
        t.row(vec![
            e.param.into(),
            e.tool.name().into(),
            fmt_val(e.default),
            fmt_val(e.estimate.value),
            change,
            fmt_val(e.estimate.ci95),
            e.estimate.n.to_string(),
            e.estimate.rejected.to_string(),
        ]);
    }
    let mut out = format!("calibration fit ({})\n{}", cal.profile.source, t.render());
    if !cal.notes.is_empty() {
        out.push_str("notes:\n");
        for n in &cal.notes {
            out.push_str(&format!("  - {n}\n"));
        }
    }
    out
}

/// Human-facing before/after table (the `calibrate --report` body).
pub fn render_validation(v: &Validation) -> String {
    let mut t = TextTable::new(&[
        "trace",
        "backend",
        "ranks",
        "measured",
        "err(default)",
        "err(fitted)",
    ]);
    for r in &v.rows {
        t.row(vec![
            r.source.clone(),
            r.tool.name().into(),
            r.ranks.to_string(),
            fmt_val(r.measured_s),
            format!("{:.2}%", 100.0 * r.err_default),
            format!("{:.2}%", 100.0 * r.err_fitted),
        ]);
    }
    format!(
        "cross-validation: DES under each model vs measured makespan\n{}\
         mean relative makespan error: default {:.2}% -> fitted {:.2}%  [{}]\n",
        t.render(),
        100.0 * v.mean_err_default,
        100.0 * v.mean_err_fitted,
        if v.improved() { "improved" } else { "NOT improved" }
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn perturbed() -> CostModel {
        workloads::perturbed_model()
    }

    fn golden_traces(m: &CostModel, seed: u64) -> Vec<ClassifiedTrace> {
        workloads::standard()
            .iter()
            .map(|run| {
                let (source, events) = workloads::simulate(run, m, seed).unwrap();
                classify_trace(&source, events, None).unwrap()
            })
            .collect()
    }

    #[test]
    fn fitted_profile_validates_better_than_defaults() {
        let base = CostModel::paper();
        let traces = golden_traces(&perturbed(), 42);
        let cal = fit_traces(&traces, &base).unwrap();
        let v = validate_profile(&traces, &base, &cal.profile, 1234).unwrap();
        assert!(
            v.improved(),
            "mean err default {:.3}% vs fitted {:.3}%\n{}",
            100.0 * v.mean_err_default,
            100.0 * v.mean_err_fitted,
            render_validation(&v)
        );
        // the perturbation-dominated backends must improve individually
        for tool in [Tool::Pmake, Tool::Dwork] {
            let r = v.rows.iter().find(|r| r.tool == tool).unwrap();
            assert!(
                r.err_fitted < r.err_default,
                "{}: fitted {:.3}% vs default {:.3}%",
                tool.name(),
                100.0 * r.err_fitted,
                100.0 * r.err_default
            );
        }
    }

    #[test]
    fn unperturbed_traces_validate_near_zero_either_way() {
        // fitting traces generated by the default model must not make
        // things worse: the profile reproduces the defaults
        let base = CostModel::paper();
        let traces = golden_traces(&base, 7);
        let cal = fit_traces(&traces, &base).unwrap();
        let fitted = cal.profile.model();
        assert!((fitted.steal_rtt - base.steal_rtt).abs() / base.steal_rtt < 0.1);
        let v = validate_profile(&traces, &base, &cal.profile, 99).unwrap();
        assert!(v.mean_err_fitted < 0.10, "{}", render_validation(&v));
    }

    #[test]
    fn renders_mention_every_fitted_param() {
        let base = CostModel::paper();
        let traces = golden_traces(&perturbed(), 5);
        let cal = fit_traces(&traces, &base).unwrap();
        let txt = render_calibration(&cal);
        for p in ["steal_rtt", "gumbel_beta_per_task", "jsrun_a"] {
            assert!(txt.contains(p), "missing {p} in:\n{txt}");
        }
        let v = validate_profile(&traces, &base, &cal.profile, 11).unwrap();
        let txt = render_validation(&v);
        assert!(txt.contains("mean relative makespan error"), "{txt}");
        for tool in Tool::ALL {
            assert!(txt.contains(tool.name()), "{txt}");
        }
    }

    #[test]
    fn validation_rejects_empty_and_degenerate_input() {
        let base = CostModel::paper();
        assert!(validate_profile(&[], &base, &CalibrationProfile::new(""), 1).is_err());
        let t = classify_trace("des:dwork", Vec::new(), Some(4)).unwrap();
        assert!(validate_profile(&[t], &base, &CalibrationProfile::new(""), 1).is_err());
    }

    #[test]
    fn fmt_val_covers_ranges_and_sign() {
        assert_eq!(fmt_val(0.0), "0");
        assert_eq!(fmt_val(2.5), "2.500s");
        assert_eq!(fmt_val(-0.002), "-2.000ms");
        assert_eq!(fmt_val(23e-6), "23.00us");
    }
}
