//! Robust estimation primitives for trace fitting: median/MAD outlier
//! rejection, quantile-based scale estimation, Theil–Sen line fitting,
//! and a mean-with-confidence-interval summary.
//!
//! Trace samples are contaminated by design — a dwork launch-gap stream
//! mixes server-serialized steals (the signal) with idle-period think
//! time (arbitrarily large), a wall-clock trace picks up GC pauses and
//! scheduler noise — so every fitter in [`super::fit`] goes through
//! these instead of raw moments.

/// Median of a sample set (copies + sorts; empty input is a caller bug).
pub fn median(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty(), "median of empty sample set");
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Median absolute deviation around `center`.
pub fn mad(xs: &[f64], center: f64) -> f64 {
    let devs: Vec<f64> = xs.iter().map(|&x| (x - center).abs()).collect();
    median(&devs)
}

/// Quantile by linear interpolation on the sorted sample; `q` in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty sample set");
    assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0, 1]");
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    v[lo] * (1.0 - frac) + v[hi] * frac
}

/// Keep samples within `k` MADs of the median (the classical robust
/// inlier filter; `k = 3.5` is the usual default).  A zero MAD — every
/// deterministic DES stream lands here — degenerates to keeping only
/// samples (numerically) equal to the median, which is exactly right:
/// the majority value IS the signal.
pub fn inliers(xs: &[f64], k: f64) -> Vec<f64> {
    if xs.is_empty() {
        return Vec::new();
    }
    let med = median(xs);
    let spread = mad(xs, med);
    let tol = if spread > 0.0 { k * spread } else { 1e-9 * med.abs().max(f64::MIN_POSITIVE) };
    xs.iter().copied().filter(|&x| (x - med).abs() <= tol).collect()
}

/// A robustly estimated parameter: the value, a 95% confidence
/// half-width, and the sample accounting behind it.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Estimate {
    pub value: f64,
    /// 95% confidence half-width (0 when n < 2)
    pub ci95: f64,
    /// inlier samples the value rests on
    pub n: usize,
    /// samples rejected as outliers
    pub rejected: usize,
}

/// Mean of the MAD-inliers with a normal-theory 95% CI.
pub fn robust_mean(xs: &[f64], k: f64) -> Option<Estimate> {
    if xs.is_empty() {
        return None;
    }
    let kept = inliers(xs, k);
    let n = kept.len();
    let mean = kept.iter().sum::<f64>() / n as f64;
    let ci95 = if n >= 2 {
        let var = kept.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (n - 1) as f64;
        1.96 * (var / n as f64).sqrt()
    } else {
        0.0
    };
    Some(Estimate { value: mean, ci95, n, rejected: xs.len() - n })
}

/// Gumbel scale from the interdecile range: for `X ~ Gumbel(mu, beta)`,
/// `Q(0.9) − Q(0.1) = beta · (ln(−ln 0.1) − ln(−ln 0.9))` ≈ 3.0844·beta,
/// independent of `mu` — so a constant location shift (the task's true
/// duration) cancels, and the extreme 10% on both sides never enter.
/// The CI comes from chunked re-estimation (split into `m` blocks,
/// spread of the per-block values).
pub fn gumbel_scale(xs: &[f64]) -> Option<Estimate> {
    const MIN_SAMPLES: usize = 20;
    if xs.len() < MIN_SAMPLES {
        return None;
    }
    let idr_factor = (-(0.1f64.ln())).ln() - (-(0.9f64.ln())).ln(); // ≈ 3.0844
    let scale = |s: &[f64]| (quantile(s, 0.9) - quantile(s, 0.1)) / idr_factor;
    let value = scale(xs);
    let chunks = (xs.len() / MIN_SAMPLES).clamp(1, 8);
    let ci95 = if chunks >= 2 {
        let per: Vec<f64> = xs.chunks(xs.len().div_ceil(chunks)).map(scale).collect();
        let m = per.len() as f64;
        let mean = per.iter().sum::<f64>() / m;
        let var = per.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / (m - 1.0);
        1.96 * (var / m).sqrt()
    } else {
        0.0
    };
    Some(Estimate { value, ci95, n: xs.len(), rejected: 0 })
}

/// Theil–Sen line fit `y = a + b·x`: slope is the median of all
/// pairwise slopes, intercept the median of `y − b·x`.  Breakdown point
/// ~29%, no leverage-point blowup — the right tool for regressing a
/// handful of per-trace medians against log-ranks.
pub fn theil_sen(xs: &[f64], ys: &[f64]) -> Option<(f64, f64)> {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return None;
    }
    let mut slopes = Vec::new();
    for i in 0..xs.len() {
        for j in (i + 1)..xs.len() {
            let dx = xs[j] - xs[i];
            if dx.abs() > 1e-12 {
                slopes.push((ys[j] - ys[i]) / dx);
            }
        }
    }
    if slopes.is_empty() {
        return None; // all x equal: no slope information
    }
    let b = median(&slopes);
    let residuals: Vec<f64> = xs.iter().zip(ys).map(|(&x, &y)| y - b * x).collect();
    Some((median(&residuals), b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::substrate::rng::Rng;

    #[test]
    fn median_odd_even() {
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn mad_of_symmetric_set() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(mad(&xs, 3.0), 1.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [0.0, 1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 0.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.0).abs() < 1e-12);
        assert!((quantile(&xs, 0.25) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn inliers_reject_gross_outliers() {
        let mut xs = vec![1.0; 20];
        for (i, x) in xs.iter_mut().enumerate() {
            *x += (i as f64 - 10.0) * 1e-3;
        }
        xs.push(50.0);
        xs.push(-30.0);
        let kept = inliers(&xs, 3.5);
        assert_eq!(kept.len(), 20);
        assert!(kept.iter().all(|&x| (x - 1.0).abs() < 0.1));
    }

    #[test]
    fn inliers_degenerate_spread_keeps_majority_value() {
        // deterministic DES stream: >half the gaps are exactly the RTT
        let mut xs = vec![23e-6; 30];
        xs.extend([1.0, 2.0, 0.5]);
        let kept = inliers(&xs, 3.5);
        assert_eq!(kept.len(), 30);
        assert!(kept.iter().all(|&x| x == 23e-6));
    }

    #[test]
    fn robust_mean_recovers_center_with_ci() {
        let mut xs: Vec<f64> = (0..100).map(|i| 5.0 + ((i % 7) as f64 - 3.0) * 0.01).collect();
        xs.push(1e6);
        let e = robust_mean(&xs, 3.5).unwrap();
        assert!((e.value - 5.0).abs() < 0.02, "{e:?}");
        assert_eq!(e.rejected, 1);
        assert!(e.ci95 > 0.0 && e.ci95 < 0.01);
    }

    #[test]
    fn gumbel_scale_recovers_beta() {
        let mut rng = Rng::new(7);
        let beta = 0.02;
        // location shifts (the per-task base duration) must cancel
        let xs: Vec<f64> = (0..4000).map(|_| 1.5 + rng.gumbel(0.0, beta)).collect();
        let e = gumbel_scale(&xs).unwrap();
        assert!(
            (e.value - beta).abs() / beta < 0.08,
            "beta {} vs true {beta}",
            e.value
        );
        assert!(e.ci95 > 0.0);
    }

    #[test]
    fn gumbel_scale_needs_samples() {
        assert!(gumbel_scale(&[1.0; 10]).is_none());
    }

    #[test]
    fn theil_sen_exact_line_with_outlier() {
        let xs: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let mut ys: Vec<f64> = xs.iter().map(|x| 2.0 + 0.5 * x).collect();
        ys[4] = 100.0; // one wrecked point must not move the fit
        let (a, b) = theil_sen(&xs, &ys).unwrap();
        assert!((b - 0.5).abs() < 1e-9, "b={b}");
        assert!((a - 2.0).abs() < 1e-9, "a={a}");
    }

    #[test]
    fn theil_sen_degenerate_x() {
        assert!(theil_sen(&[1.0, 1.0], &[2.0, 3.0]).is_none());
        assert!(theil_sen(&[1.0], &[2.0]).is_none());
    }
}
