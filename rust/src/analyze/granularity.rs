//! METG granularity lints: the selector's silent shape/METG reasoning
//! as explainable diagnostics.
//!
//! W101 warns when the workload's mean task duration sits below the
//! target backend's METG at the planned rank count (estimated
//! efficiency t̄/(t̄+METG) under the selector's 50% floor), W102 when a
//! static mpi-list plan would idle ranks behind stragglers (duration
//! cv over the flat-map tolerance), W103 when command/kernel tasks
//! carry a zero estimate and would sail through both checks as "free".

use super::{codes, AnalyzeOpts, Diagnostic};
use crate::workflow::graph::{Payload, WorkflowGraph};
use crate::workflow::select::{self, EFF_FLOOR, UNIFORM_CV};

use crate::metg::simmodels::Tool;

fn sample(names: &[&str]) -> String {
    if names.len() > 8 {
        format!("{}, …", names[..8].join(", "))
    } else {
        names.join(", ")
    }
}

/// W101/W102/W103.  Callers run this only on graphs with no
/// Error-severity findings (efficiency over an unrunnable graph is
/// noise); a selector failure or an empty graph yields no lints.
pub fn lint(g: &WorkflowGraph, opts: &AnalyzeOpts) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    // W103: zero estimates on real payloads.  Noop barriers are exempt
    // (zero is the truth for them), and so is the whole METG arithmetic
    // below, which such tasks would drag toward "free".
    let zero: Vec<&str> = g
        .tasks()
        .iter()
        .filter(|t| !matches!(t.payload, Payload::Noop) && t.est_s <= 0.0)
        .map(|t| t.name.as_str())
        .collect();
    if !zero.is_empty() {
        out.push(
            Diagnostic::warning(
                codes::ZERO_EST,
                zero.iter().map(|s| s.to_string()).collect(),
                format!(
                    "{} task(s) carry a zero duration estimate ({}): the METG check and \
                     the selector treat them as free",
                    zero.len(),
                    sample(&zero)
                ),
            )
            .suggest("set `est:` to the measured or expected seconds"),
        );
    }

    if g.is_empty() {
        return out;
    }
    let Ok(rec) = select::select(g, &opts.model, opts.ranks) else {
        return out;
    };
    let target = opts.target.unwrap_or(rec.choice);
    let a = rec.assessment(target);
    let t_mean = rec.stats.mean_task_s;

    // W101: sub-METG granularity at the target backend and scale.
    if t_mean > 0.0 && a.efficiency < EFF_FLOOR {
        let best = rec
            .assessments
            .iter()
            .max_by(|x, y| x.efficiency.total_cmp(&y.efficiency))
            .expect("all tools assessed");
        let suggestion = if best.tool != target && best.efficiency >= EFF_FLOOR {
            format!(
                "batch more work per task, or run on {} (estimated {:.0}% efficient)",
                best.tool.name(),
                best.efficiency * 100.0
            )
        } else {
            format!(
                "batch more work per task ({} apiece or more), or lower --ranks",
                select::fmt_t(a.metg_s)
            )
        };
        out.push(
            Diagnostic::warning(
                codes::SUB_METG,
                Vec::new(),
                format!(
                    "mean task duration {} is below {}'s METG {} at {} ranks: estimated \
                     efficiency {:.0}% (floor {:.0}%)",
                    select::fmt_t(t_mean),
                    target.name(),
                    select::fmt_t(a.metg_s),
                    rec.ranks,
                    a.efficiency * 100.0,
                    EFF_FLOOR * 100.0
                ),
            )
            .suggest(suggestion),
        );
    }

    // W102: duration spread under a static rank plan.
    if target == Tool::MpiList && rec.stats.cv_task_s > UNIFORM_CV {
        out.push(
            Diagnostic::warning(
                codes::DURATION_CV,
                Vec::new(),
                format!(
                    "task duration cv {:.2} exceeds {UNIFORM_CV} for a static mpi-list \
                     plan: ranks idle behind stragglers every phase",
                    rec.stats.cv_task_s
                ),
            )
            .suggest("split the long tasks, or use dwork's dynamic pulling"),
        );
    }
    out
}
