//! Collect-all static analysis over the workflow IR (`workflow lint`).
//!
//! The paper's two silent failure modes both surface only *after* an
//! allocation has burned.  A campaign whose tasks sit below the chosen
//! scheduler's METG wastes the machine (paper §6), and a graph whose
//! file outputs collide executes differently under pmake (file presence
//! synchronizes) than under dwork/mpi-list (nothing watches the files).
//! This module proves both properties before a single task launches,
//! and reports *every* finding at once instead of bailing on the first:
//!
//! * [`races`] — the file-race detector: bitset transitive
//!   reachability ([`reach`]) flags unordered write-write conflicts
//!   (`E010`), shadowed duplicate outputs (`E011`), read-write hazards
//!   (`E012`), and orphan inputs (`I201`);
//! * [`granularity`] — the METG lints: estimated efficiency
//!   t̄/(t̄+METG) at the planned rank count against the Table-4 (or a
//!   fitted `--calibration`) cost model (`W101`), mpi-list duration-cv
//!   violations (`W102`), zero estimates on real payloads (`W103`);
//! * [`structure`] — structural hygiene: transitively-redundant
//!   `after` edges (`W104`), dead zero-duration no-ops (`I202`);
//! * referential integrity (`E001`–`E004`) — the checks
//!   [`WorkflowGraph::validate`] has always enforced, re-expressed as
//!   diagnostics.  `validate()` is now a thin first-error wrapper over
//!   this engine (see [`first_error`]), preserving its error text.
//!
//! Surfaces: `threesched workflow lint` on the CLI,
//! [`Session::analyze`](crate::workflow::Session::analyze) in the
//! library, and the `Session::plan()`/`run()` pre-flight gate that
//! refuses Error-severity diagnostics.
//!
//! # Worked example
//!
//! ```
//! use threesched::analyze::{analyze_graph, AnalyzeOpts, Severity};
//! use threesched::workflow::{TaskSpec, WorkflowGraph};
//!
//! # fn main() -> anyhow::Result<()> {
//! let mut g = WorkflowGraph::new("racy");
//! g.add_task(TaskSpec::command("sim-a", "run > result.dat").outputs(&["result.dat"]))?;
//! // a second, unordered writer of result.dat: a write-write race
//! g.add_task(TaskSpec::command("sim-b", "run > result.dat").outputs(&["result.dat"]))?;
//!
//! let report = analyze_graph(&g, &AnalyzeOpts::default());
//! assert_eq!(report.errors(), 1);
//! let d = &report.diagnostics[0];
//! assert_eq!((d.code, d.severity), ("E010", Severity::Error));
//! assert!(d.message.contains("both declare output"));
//! print!("{}", report.render()); // or report.to_json()
//! # Ok(()) }
//! ```

pub mod granularity;
pub mod races;
pub mod reach;
pub mod structure;

use anyhow::Result;

use crate::metg::simmodels::Tool;
use crate::substrate::cluster::costs::CostModel;
use crate::workflow::graph::WorkflowGraph;

use reach::Reach;

/// How bad a [`Diagnostic`] is.  `Error`s make the graph unrunnable
/// (the `Session` pre-flight gate and `validate()` refuse them);
/// `Warning`s burn the machine but execute; `Info`s are advisory.
/// Ordered most-severe-first so reports sort naturally.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Severity {
    Error,
    Warning,
    Info,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Error => "error",
            Severity::Warning => "warning",
            Severity::Info => "info",
        }
    }
}

/// Diagnostic code registry.  `E0xx` = graph is wrong (unrunnable),
/// `W1xx` = graph is wasteful, `I2xx` = advisory.  [`CODE_TABLE`] holds
/// the one-line description for each.
pub mod codes {
    /// `after` names a task that does not exist.
    pub const UNKNOWN_DEP: &str = "E001";
    /// Dependency cycle.
    pub const CYCLE: &str = "E002";
    /// A declared output collides with another task's `<name>.done` stamp.
    pub const STAMP_COLLISION: &str = "E003";
    /// An input names another task's internal synchronization stamp.
    pub const STAMP_INPUT: &str = "E004";
    /// Two tasks write the same output with no ordering path: a race.
    pub const WRITE_WRITE_RACE: &str = "E010";
    /// Two ordered tasks write the same output: the producer is ambiguous.
    pub const DUPLICATE_OUTPUT: &str = "E011";
    /// A task reads a file an unordered task writes.
    pub const READ_WRITE_HAZARD: &str = "E012";
    /// Mean task duration is below the target backend's METG.
    pub const SUB_METG: &str = "W101";
    /// Duration spread too wide for a static mpi-list rank plan.
    pub const DURATION_CV: &str = "W102";
    /// Zero duration estimate on a command/kernel task.
    pub const ZERO_EST: &str = "W103";
    /// An explicit `after` edge is transitively implied already.
    pub const REDUNDANT_EDGE: &str = "W104";
    /// An input no task produces (must pre-exist on disk).
    pub const ORPHAN_INPUT: &str = "I201";
    /// A zero-duration no-op nothing depends on.
    pub const DEAD_TASK: &str = "I202";
}

/// Every code the analyzer can emit: (code, severity, description).
/// The README's lint table and `workflow lint` docs derive from this.
pub const CODE_TABLE: &[(&str, Severity, &str)] = &[
    (codes::UNKNOWN_DEP, Severity::Error, "`after` names a task that does not exist"),
    (codes::CYCLE, Severity::Error, "dependency cycle"),
    (codes::STAMP_COLLISION, Severity::Error, "output collides with a task's `<name>.done` stamp"),
    (codes::STAMP_INPUT, Severity::Error, "input names another task's internal stamp"),
    (codes::WRITE_WRITE_RACE, Severity::Error, "two unordered tasks write the same output"),
    (codes::DUPLICATE_OUTPUT, Severity::Error, "two ordered tasks write the same output"),
    (codes::READ_WRITE_HAZARD, Severity::Error, "a task reads a file an unordered task writes"),
    (codes::SUB_METG, Severity::Warning, "mean task duration below the backend's METG"),
    (codes::DURATION_CV, Severity::Warning, "duration spread idles ranks under a static plan"),
    (codes::ZERO_EST, Severity::Warning, "zero duration estimate on a real payload"),
    (codes::REDUNDANT_EDGE, Severity::Warning, "explicit `after` edge is transitively implied"),
    (codes::ORPHAN_INPUT, Severity::Info, "input no task produces (must pre-exist)"),
    (codes::DEAD_TASK, Severity::Info, "zero-duration no-op nothing depends on"),
];

/// One finding: a stable code, a severity, the tasks involved (subject
/// first), a human message, and an optional fix suggestion.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub code: &'static str,
    pub severity: Severity,
    pub tasks: Vec<String>,
    pub message: String,
    pub suggestion: Option<String>,
}

impl Diagnostic {
    pub fn error(code: &'static str, tasks: Vec<String>, message: String) -> Diagnostic {
        Diagnostic { code, severity: Severity::Error, tasks, message, suggestion: None }
    }

    pub fn warning(code: &'static str, tasks: Vec<String>, message: String) -> Diagnostic {
        Diagnostic { code, severity: Severity::Warning, tasks, message, suggestion: None }
    }

    pub fn info(code: &'static str, tasks: Vec<String>, message: String) -> Diagnostic {
        Diagnostic { code, severity: Severity::Info, tasks, message, suggestion: None }
    }

    pub fn suggest(mut self, s: impl Into<String>) -> Diagnostic {
        self.suggestion = Some(s.into());
        self
    }

    /// `severity[code]: message` — the first line of the text rendering.
    pub fn headline(&self) -> String {
        format!("{}[{}]: {}", self.severity.name(), self.code, self.message)
    }
}

/// Knobs for [`analyze_graph`].
#[derive(Clone, Debug)]
pub struct AnalyzeOpts {
    /// Target scale for the METG lints (the selector's rank count).
    pub ranks: usize,
    /// Cost model pricing the granularity lints: Table-4 defaults or a
    /// fitted [`CalibrationProfile`](crate::calibrate::CalibrationProfile).
    pub model: CostModel,
    /// Lint granularity against this backend; `None` lints the
    /// selector's own choice (nothing to warn about if the selector
    /// would route around the problem).
    pub target: Option<Tool>,
}

impl Default for AnalyzeOpts {
    fn default() -> Self {
        AnalyzeOpts { ranks: 864, model: CostModel::paper(), target: None }
    }
}

/// The collect-all result of [`analyze_graph`], sorted most-severe
/// first (stable within a severity: discovery order).
#[derive(Clone, Debug)]
pub struct AnalysisReport {
    pub workflow: String,
    /// rank count the granularity lints were evaluated at
    pub ranks: usize,
    /// number of tasks checked
    pub tasks: usize,
    pub diagnostics: Vec<Diagnostic>,
}

impl AnalysisReport {
    pub fn count(&self, severity: Severity) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == severity).count()
    }

    pub fn errors(&self) -> usize {
        self.count(Severity::Error)
    }

    pub fn warnings(&self) -> usize {
        self.count(Severity::Warning)
    }

    pub fn infos(&self) -> usize {
        self.count(Severity::Info)
    }

    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// All diagnostics with a given code.
    pub fn by_code<'a>(&'a self, code: &'a str) -> impl Iterator<Item = &'a Diagnostic> {
        self.diagnostics.iter().filter(move |d| d.code == code)
    }

    /// Human-facing text report (the `workflow lint` body).
    pub fn render(&self) -> String {
        let mut out = String::new();
        for d in &self.diagnostics {
            out.push_str(&d.headline());
            out.push('\n');
            if let Some(s) = &d.suggestion {
                out.push_str(&format!("  help: {s}\n"));
            }
        }
        if self.is_clean() {
            out.push_str(&format!(
                "workflow {:?}: clean ({} tasks checked at {} ranks)\n",
                self.workflow, self.tasks, self.ranks
            ));
        } else {
            out.push_str(&format!(
                "workflow {:?}: {} error(s), {} warning(s), {} info ({} tasks checked at {} ranks)\n",
                self.workflow,
                self.errors(),
                self.warnings(),
                self.infos(),
                self.tasks,
                self.ranks
            ));
        }
        out
    }

    /// Machine-readable report (one JSON object, `workflow lint --json`).
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"workflow\":\"{}\",\"ranks\":{},\"tasks\":{},\"errors\":{},\"warnings\":{},\"infos\":{},\"diagnostics\":[",
            esc(&self.workflow),
            self.ranks,
            self.tasks,
            self.errors(),
            self.warnings(),
            self.infos()
        );
        for (i, d) in self.diagnostics.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            let tasks = d
                .tasks
                .iter()
                .map(|t| format!("\"{}\"", esc(t)))
                .collect::<Vec<_>>()
                .join(",");
            let suggestion = match &d.suggestion {
                Some(s) => format!("\"{}\"", esc(s)),
                None => "null".to_string(),
            };
            out.push_str(&format!(
                "{{\"code\":\"{}\",\"severity\":\"{}\",\"tasks\":[{tasks}],\"message\":\"{}\",\"suggestion\":{suggestion}}}",
                d.code,
                d.severity.name(),
                esc(&d.message)
            ));
        }
        out.push_str("]}");
        out
    }

    /// Turn severities into an exit verdict: errors always fail;
    /// `deny_warnings` promotes warnings (the `--deny warnings` flag).
    pub fn deny(&self, deny_warnings: bool) -> Result<()> {
        let (e, w) = (self.errors(), self.warnings());
        if e > 0 {
            anyhow::bail!("workflow {:?}: {e} lint error(s)", self.workflow);
        }
        if deny_warnings && w > 0 {
            anyhow::bail!("workflow {:?}: {w} warning(s) denied (--deny warnings)", self.workflow);
        }
        Ok(())
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Run every pass over `g` and collect all diagnostics.  Infallible:
/// a broken graph *is* the result, not an error.  Granularity lints are
/// skipped while the graph has Error-severity findings (efficiency
/// numbers over a graph that cannot run would be noise).
pub fn analyze_graph(g: &WorkflowGraph, opts: &AnalyzeOpts) -> AnalysisReport {
    let mut diags = races::integrity(g);
    let preds = g.preds_vec();
    match g.topo_order_from(&preds) {
        Ok(order) => {
            let reach = Reach::ancestors(g.len(), &preds, &order);
            diags.extend(races::races(g, Some(&reach)));
            diags.extend(structure::lint(g, &preds, &reach));
        }
        Err(e) => {
            diags.extend(races::races(g, None));
            diags.push(Diagnostic::error(codes::CYCLE, Vec::new(), e.to_string()).suggest(
                "break the cycle: some `after` edge or input/output pair points backwards",
            ));
        }
    }
    if !diags.iter().any(|d| d.severity == Severity::Error) {
        diags.extend(granularity::lint(g, opts));
    }
    diags.sort_by_key(|d| d.severity);
    AnalysisReport { workflow: g.name.clone(), ranks: opts.ranks, tasks: g.len(), diagnostics: diags }
}

/// The cheap errors-only subset (no cost model, no structural lints):
/// what `WorkflowGraph::validate` and the `Session` pre-flight gate
/// consume.  May include Info-severity findings from the race pass;
/// callers filter by severity.
pub fn error_diagnostics(g: &WorkflowGraph) -> Vec<Diagnostic> {
    let mut diags = races::integrity(g);
    let preds = g.preds_vec();
    match g.topo_order_from(&preds) {
        Ok(order) => {
            let reach = Reach::ancestors(g.len(), &preds, &order);
            diags.extend(races::races(g, Some(&reach)));
        }
        Err(e) => {
            diags.extend(races::races(g, None));
            diags.push(Diagnostic::error(codes::CYCLE, Vec::new(), e.to_string()));
        }
    }
    diags
}

/// Bail-on-first compatibility shim: the first Error-severity
/// diagnostic becomes the `Err`, preserving the pre-analyzer
/// `validate()`/`check_integrity()` message text exactly.
pub fn first_error(diags: Vec<Diagnostic>) -> Result<()> {
    match diags.into_iter().find(|d| d.severity == Severity::Error) {
        Some(d) => Err(anyhow::anyhow!(d.message)),
        None => Ok(()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::graph::TaskSpec;

    fn opts() -> AnalyzeOpts {
        AnalyzeOpts::default()
    }

    #[test]
    fn clean_graph_reports_clean() {
        let mut g = WorkflowGraph::new("ok");
        g.add_task(TaskSpec::command("a", "echo > a.out").outputs(&["a.out"]).est(60.0))
            .unwrap();
        g.add_task(TaskSpec::command("b", "cat a.out").after(&["a"]).est(60.0)).unwrap();
        let r = analyze_graph(&g, &opts());
        assert!(r.is_clean(), "{}", r.render());
        assert!(r.render().contains("clean"));
    }

    #[test]
    fn every_emitted_code_is_documented() {
        // kitchen-sink graph: one defect per class that can coexist
        let mut g = WorkflowGraph::new("sink");
        g.add_task(TaskSpec::command("w1", "x").outputs(&["f.out"]).est(60.0)).unwrap();
        g.add_task(TaskSpec::command("w2", "x").outputs(&["f.out"]).est(60.0)).unwrap();
        let mut reader = TaskSpec::command("r", "cat f.out").est(60.0);
        reader.inputs = vec!["f.out".into(), "nowhere.dat".into()];
        g.add_task(reader).unwrap();
        g.add_task(TaskSpec::new("ghostly").after(&["ghost"])).unwrap();
        g.add_task(TaskSpec::new("dead").est(0.0)).unwrap();
        let r = analyze_graph(&g, &opts());
        assert!(!r.is_clean());
        for d in &r.diagnostics {
            let row = CODE_TABLE.iter().find(|(c, ..)| *c == d.code);
            let (_, sev, _) = row.unwrap_or_else(|| panic!("{} undocumented", d.code));
            assert_eq!(*sev, d.severity, "{}", d.code);
        }
        // sorted most-severe first
        let sevs: Vec<Severity> = r.diagnostics.iter().map(|d| d.severity).collect();
        let mut sorted = sevs.clone();
        sorted.sort();
        assert_eq!(sevs, sorted);
    }

    #[test]
    fn json_is_escaped_and_structured() {
        let mut g = WorkflowGraph::new("json \"quoted\"");
        g.add_task(TaskSpec::command("a", "x").outputs(&["f.out"]).est(60.0)).unwrap();
        g.add_task(TaskSpec::command("b", "x").outputs(&["f.out"]).est(60.0)).unwrap();
        let j = analyze_graph(&g, &opts()).to_json();
        assert!(j.starts_with('{') && j.ends_with('}'), "{j}");
        assert!(j.contains("\"workflow\":\"json \\\"quoted\\\"\""), "{j}");
        assert!(j.contains("\"code\":\"E010\""), "{j}");
        assert!(j.contains("\"errors\":1"), "{j}");
    }

    #[test]
    fn deny_promotes_warnings() {
        let mut g = WorkflowGraph::new("warny");
        // sub-METG: microsecond tasks at paper scale
        for i in 0..8 {
            g.add_task(TaskSpec::kernel(format!("k{i}"), "atb_64", i).est(1e-6)).unwrap();
        }
        let r = analyze_graph(&g, &opts());
        assert_eq!(r.errors(), 0);
        assert!(r.warnings() > 0, "{}", r.render());
        assert!(r.deny(false).is_ok());
        assert!(r.deny(true).is_err());
    }

    #[test]
    fn first_error_preserves_message_text() {
        let mut g = WorkflowGraph::new("legacy");
        g.add_task(TaskSpec::new("a").after(&["ghost"])).unwrap();
        let err = first_error(error_diagnostics(&g)).unwrap_err();
        assert_eq!(err.to_string(), "task \"a\" depends on unknown task \"ghost\"");
    }
}
