//! Referential integrity and the file-race detector.
//!
//! [`integrity`] re-expresses the historical `check_integrity` checks
//! (unknown deps, stamp collisions, stamp-named inputs) as collected
//! diagnostics, preserving their message text so the bail-on-first
//! wrappers stay byte-compatible.  [`races`] is new analysis: with
//! every producer of every file in hand (not just the first-wins
//! `by_output` entry) and ancestor bitsets from [`super::reach`], it
//! flags unordered duplicate writers (E010), ordered-but-shadowed
//! duplicates (E011), readers unordered against a writer of their
//! input (E012), and inputs nothing produces (I201).

use std::collections::HashMap;

use super::reach::Reach;
use super::{codes, Diagnostic};
use crate::workflow::graph::WorkflowGraph;

/// E001/E003/E004: dependency names resolve, no output collides with a
/// `<name>.done` stamp, no input names another task's internal stamp.
/// Same per-task check order and message text as the pre-analyzer
/// `check_integrity`, so [`super::first_error`] reproduces it exactly.
pub fn integrity(g: &WorkflowGraph) -> Vec<Diagnostic> {
    let mut out = Vec::new();
    for t in g.tasks() {
        for d in &t.after {
            if g.index_of(d).is_none() {
                out.push(
                    Diagnostic::error(
                        codes::UNKNOWN_DEP,
                        vec![t.name.clone()],
                        format!("task {:?} depends on unknown task {d:?}", t.name),
                    )
                    .suggest(format!("declare task {d:?}, or drop the `after` entry")),
                );
            }
        }
        if t.outputs.is_empty() {
            let stamp = format!("{}.done", t.name);
            if let Some(p) = g.producer_of(&stamp) {
                out.push(
                    Diagnostic::error(
                        codes::STAMP_COLLISION,
                        vec![t.name.clone(), p.name.clone()],
                        format!(
                            "task {:?}'s synchronization stamp {stamp:?} collides with an \
                             output declared by task {:?}",
                            t.name, p.name
                        ),
                    )
                    .suggest(format!(
                        "rename task {:?}'s output, or give task {:?} explicit outputs",
                        p.name, t.name
                    )),
                );
            }
        }
        // an input naming another task's *internal* pmake stamp would
        // order the tasks under pmake only (the stamp file never exists
        // on the other back-ends): insist on an explicit edge
        for f in &t.inputs {
            if g.producer_of(f).is_some() {
                continue;
            }
            if let Some(stem) = f.strip_suffix(".done") {
                if let Some(p) = g.get(stem) {
                    if p.outputs.is_empty() {
                        out.push(
                            Diagnostic::error(
                                codes::STAMP_INPUT,
                                vec![t.name.clone(), p.name.clone()],
                                format!(
                                    "task {:?} input {f:?} names task {stem:?}'s internal \
                                     synchronization stamp; use `after: [{stem}]` instead",
                                    t.name
                                ),
                            )
                            .suggest(format!("replace the input with `after: [{stem}]`")),
                        );
                    }
                }
            }
        }
    }
    out
}

/// E010/E011/E012/I201: the file-race pass.  `reach` is `None` only
/// when the graph is cyclic (no topological order exists); duplicate
/// writers are then reported without an ordering verdict.
pub fn races(g: &WorkflowGraph, reach: Option<&Reach>) -> Vec<Diagnostic> {
    // writers/readers per file, files kept in first-mention order so
    // the report is stable (HashMap iteration order is not)
    let mut files: HashMap<&str, (Vec<usize>, Vec<usize>)> = HashMap::new();
    let mut order: Vec<&str> = Vec::new();
    for (i, t) in g.tasks().iter().enumerate() {
        for f in &t.outputs {
            let entry = files.entry(f).or_insert_with(|| {
                order.push(f);
                Default::default()
            });
            if entry.0.last() != Some(&i) {
                entry.0.push(i); // a task listing a file twice is one writer
            }
        }
    }
    for (i, t) in g.tasks().iter().enumerate() {
        for f in &t.inputs {
            let entry = files.entry(f).or_insert_with(|| {
                order.push(f);
                Default::default()
            });
            if entry.1.last() != Some(&i) {
                entry.1.push(i);
            }
        }
    }

    let name = |i: usize| g.tasks()[i].name.clone();
    let mut out = Vec::new();
    for f in order {
        let (writers, readers) = &files[f];
        // every duplicate-writer pair is wrong; reachability decides how
        for (ai, &a) in writers.iter().enumerate() {
            for &b in &writers[ai + 1..] {
                let (na, nb) = (name(a), name(b));
                match reach.map(|r| r.ordered(a, b)) {
                    Some(false) => out.push(
                        Diagnostic::error(
                            codes::WRITE_WRITE_RACE,
                            vec![na.clone(), nb.clone()],
                            format!(
                                "tasks {na:?} and {nb:?} both declare output {f:?} with no \
                                 ordering path between them: the writes race under dwork \
                                 and mpi-list, and pmake keeps whichever rule fires last"
                            ),
                        )
                        .suggest(
                            "add an `after:` edge ordering one write, or write distinct files",
                        ),
                    ),
                    _ => out.push(
                        Diagnostic::error(
                            codes::DUPLICATE_OUTPUT,
                            vec![na.clone(), nb.clone()],
                            format!(
                                "tasks {na:?} and {nb:?} both declare output {f:?}: implied \
                                 producer edges resolve to {na:?} only, and the later write \
                                 shadows it"
                            ),
                        )
                        .suggest("give each task a distinct output file"),
                    ),
                }
            }
        }
        // a reader must be ordered against EVERY writer of its input;
        // implied edges only order it after the first-declared producer
        if let Some(r) = reach {
            for &rd in readers {
                for &w in writers {
                    if w != rd && !r.ordered(w, rd) {
                        let (nr, nw) = (name(rd), name(w));
                        out.push(
                            Diagnostic::error(
                                codes::READ_WRITE_HAZARD,
                                vec![nr.clone(), nw.clone()],
                                format!(
                                    "task {nr:?} reads {f:?} but has no ordering path to \
                                     task {nw:?}, which also writes it: works only by \
                                     accident under pmake, races under dwork and mpi-list"
                                ),
                            )
                            .suggest(format!("add `after: [{nw}]` to task {nr:?}")),
                        );
                    }
                }
            }
        }
        if writers.is_empty() {
            let mut names: Vec<String> = readers.iter().map(|&i| name(i)).collect();
            names.dedup();
            let shown = if names.len() > 5 {
                format!("{}, …", names[..5].join(", "))
            } else {
                names.join(", ")
            };
            out.push(
                Diagnostic::info(
                    codes::ORPHAN_INPUT,
                    names,
                    format!(
                        "input {f:?} is produced by no task (read by {shown}): the file \
                         must already exist in the campaign directory"
                    ),
                )
                .suggest("declare it as some task's output if the workflow should create it"),
            );
        }
    }
    out
}
