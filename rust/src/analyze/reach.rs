//! Bitset transitive reachability over a DAG.
//!
//! One flat `Vec<u64>` of n ancestor rows, filled in a single
//! topological sweep: `anc(i) = ⋃ over preds p of anc(p) ∪ {p}`.
//! O(E·n/64) time and n²/8 bytes — ~12 MB and a few milliseconds for a
//! 10k-task graph, which is what lets the race detector check every
//! producer pair instead of running a DFS per pair.

/// Ancestor bitsets for every node of a DAG.
pub struct Reach {
    words: usize,
    bits: Vec<u64>,
}

impl Reach {
    /// Build ancestor sets from predecessor lists and a topological
    /// order (callers get both from `WorkflowGraph::preds_vec` /
    /// `topo_order_from`).
    pub fn ancestors(n: usize, preds: &[Vec<usize>], order: &[usize]) -> Reach {
        let words = n.div_ceil(64);
        let mut bits = vec![0u64; n * words];
        for &i in order {
            for &p in &preds[i] {
                bits[i * words + p / 64] |= 1 << (p % 64);
                for w in 0..words {
                    let row_p = bits[p * words + w];
                    bits[i * words + w] |= row_p;
                }
            }
        }
        Reach { words, bits }
    }

    /// Is `a` a strict ancestor of `d` (some path a → … → d)?
    pub fn is_ancestor(&self, a: usize, d: usize) -> bool {
        (self.bits[d * self.words + a / 64] >> (a % 64)) & 1 == 1
    }

    /// Is there an ordering path between the two, in either direction?
    pub fn ordered(&self, a: usize, b: usize) -> bool {
        self.is_ancestor(a, b) || self.is_ancestor(b, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diamond_ancestry() {
        // 0 → {1, 2} → 3
        let preds = vec![vec![], vec![0], vec![0], vec![1, 2]];
        let order = vec![0, 1, 2, 3];
        let r = Reach::ancestors(4, &preds, &order);
        assert!(r.is_ancestor(0, 1));
        assert!(r.is_ancestor(0, 3));
        assert!(!r.is_ancestor(1, 2), "siblings are unordered");
        assert!(!r.is_ancestor(3, 0), "strict: no reverse edges");
        assert!(!r.is_ancestor(0, 0), "strict: not its own ancestor");
        assert!(r.ordered(0, 3) && r.ordered(3, 0));
        assert!(!r.ordered(1, 2));
    }

    #[test]
    fn wide_graph_crosses_word_boundaries() {
        // chain of 130 nodes: everything reaches everything downstream
        let n = 130;
        let preds: Vec<Vec<usize>> = (0..n).map(|i| if i == 0 { vec![] } else { vec![i - 1] }).collect();
        let order: Vec<usize> = (0..n).collect();
        let r = Reach::ancestors(n, &preds, &order);
        for i in 0..n {
            for j in 0..n {
                assert_eq!(r.is_ancestor(i, j), i < j, "({i},{j})");
            }
        }
    }
}
