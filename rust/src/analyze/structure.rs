//! Structural hygiene lints: transitively-redundant explicit `after`
//! edges (W104) and dead zero-duration no-ops (I202).

use super::reach::Reach;
use super::{codes, Diagnostic};
use crate::workflow::graph::{Payload, WorkflowGraph};

/// W104/I202 over a prebuilt adjacency + ancestor bitsets.
pub fn lint(g: &WorkflowGraph, preds: &[Vec<usize>], reach: &Reach) -> Vec<Diagnostic> {
    let mut out = Vec::new();

    // W104: an explicit `after: [p]` on task i is redundant when some
    // OTHER predecessor q already has p among its ancestors — the edge
    // adds no ordering, only noise (and hides the real critical path).
    // Implied producer edges are never flagged: they carry data.
    for (i, t) in g.tasks().iter().enumerate() {
        for dep in &t.after {
            let Some(p) = g.index_of(dep) else { continue }; // E001's problem
            if p == i {
                continue;
            }
            if let Some(&q) = preds[i].iter().find(|&&q| q != p && reach.is_ancestor(p, q)) {
                out.push(
                    Diagnostic::warning(
                        codes::REDUNDANT_EDGE,
                        vec![t.name.clone(), dep.clone()],
                        format!(
                            "`after: [{dep:?}]` on task {:?} is transitively redundant: \
                             {dep:?} already precedes it through {:?}",
                            t.name,
                            g.tasks()[q].name
                        ),
                    )
                    .suggest("drop the redundant edge"),
                );
            }
        }
    }

    // I202: a zero-duration no-op with no outputs that nothing depends
    // on synchronizes nothing — deleting it changes no backend's run.
    // (Noop barriers with dependents, and est-bearing placeholders the
    // selector should price, are NOT flagged.)
    let mut has_succ = vec![false; g.len()];
    for ps in preds {
        for &p in ps {
            has_succ[p] = true;
        }
    }
    for (i, t) in g.tasks().iter().enumerate() {
        if matches!(t.payload, Payload::Noop)
            && t.est_s == 0.0
            && t.outputs.is_empty()
            && !has_succ[i]
        {
            out.push(
                Diagnostic::info(
                    codes::DEAD_TASK,
                    vec![t.name.clone()],
                    format!(
                        "task {:?} is dead: a zero-duration no-op with no outputs that no \
                         task depends on",
                        t.name
                    ),
                )
                .suggest("delete it, or give it work / an estimate / dependents"),
            );
        }
    }
    out
}
