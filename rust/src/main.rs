//! threesched CLI: leader entrypoint for the three schedulers.
//!
//! Subcommands:
//!   pmake    — run a rules.yaml/targets.yaml campaign on this host
//!   dhub     — serve | worker | top | status: a persistent TCP task
//!              server + workflow workers that execute task-body
//!              payloads (the remote deployment `workflow run
//!              --connect` submits to), plus live metrics views of a
//!              running hub
//!   dwork    — serve | worker | create | status | drain  (TCP deployment)
//!   task     — execute one AOT artifact through PJRT (the job-step body
//!              that pmake scripts launch, and a smoke-check for the
//!              runtime path)
//!   metg     — print the paper-scale METG sweep (DES)
//!   workflow — plan | lower | lint | run | submit: one workflow.yaml,
//!              three lowerings, METG-based adaptive coordinator
//!              selection, collect-all static analysis — every verb is
//!              a thin veneer over `workflow::Session` / `analyze`
//!   trace    — report | compare: Fig-5-style breakdowns over lifecycle
//!              traces, and selector-vs-DES-vs-measured cross-validation
//!   calibrate — fit the CostModel from measured traces into a profile
//!              that workflow plan|run and trace compare load with
//!              --calibration in place of the Table-4 defaults
//!
//! Run with no args for usage.

use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context as _, Result};

use threesched::analyze::{analyze_graph, AnalyzeOpts};
use threesched::calibrate::{self, CalibrationProfile};
use threesched::coordinator::dwork::{self, Client, CreateItem, SubmitOutcome, TaskMsg};
use threesched::coordinator::pmake;
use threesched::metg::harness::{metg_sweep, render_metg, PAPER_RANKS};
use threesched::metrics::{self, MetricsSnapshot, Registry};
use threesched::metg::simmodels::Tool;
use threesched::metg::Workload;
use threesched::workflow;
use threesched::runtime::service::RuntimeService;
use threesched::runtime::{default_artifacts_dir, fill_f32, HostBuf};
use threesched::substrate::cli::{parse, Flag};
use threesched::substrate::cluster::costs::CostModel;
use threesched::substrate::cluster::Machine;
use threesched::substrate::kvstore::KvStore;
use threesched::substrate::transport::tcp::TcpClient;
use threesched::substrate::transport::TransportCfg;
use threesched::trace::{self, Tracer};

const USAGE: &str = "\
threesched — three practical workflow schedulers (pmake, dwork, mpi-list)

usage: threesched <command> [flags]

commands:
  pmake   --rules rules.yaml --targets targets.yaml [--nodes N] [--fifo]
  dhub serve    --bind addr:port [--store dir] [--snapshot-every N]
                [--shards N]                   (ready-queue shards, default 1)
                [--trace out.jsonl]            (hub-side lifecycle trace)
                [--metrics-addr host:port]     (Prometheus text exposition)
  dhub worker   --connect addr:port [--workers N] [--prefetch K] [--dir D]
                [--batch N]   (completions per report frame, default 1)
                [--name base] [--linger] [--trace out.jsonl]
                [--idle-floor-us U] [--idle-ceiling-ms M]
  dhub top      --connect addr:port [--interval-ms MS] [--iters N]
                (refreshing full-screen hub view: queue depth, workers,
                 tasks/sec, steal-latency quantiles)
  dhub status   --connect addr:port [--watch] [--interval-ms MS] [--iters N]
  dhub tail     --connect addr:port [--follow] [--task PREFIX] [--json]
                [--interval-ms MS]
                (live lifecycle event stream; without --follow, prints one
                 poll interval's worth of events and exits)
  dwork serve   --bind addr:port [--db dir] [--snapshot-every N]
  dwork worker  --connect addr:port [--name w0] [--prefetch N] [--artifacts-dir D]
  dwork create  --connect addr:port --name task [--dep t1,t2]
  dwork status  --connect addr:port
  dwork drain   --connect addr:port    (no-op worker: waits for + completes tasks)
  task    --artifact atb_128 [--seed S] [--out file] [--artifacts-dir D]
  metg    [--rtt-us X]
  workflow plan   --file wf.yaml [--ranks N] [--calibration profile.toml]
                  (stats + selector verdict)
  workflow lower  --file wf.yaml --coordinator auto|pmake|dwork|mpilist
                  [--out dir] [--ranks N]
  workflow lint   [wf.yaml] [--file wf.yaml] [--json] [--deny warnings]
                  [--ranks N] [--coordinator auto|pmake|dwork|mpilist]
                  [--calibration profile.toml] [--standard]
                  (collect-all static analysis: file races, METG
                   granularity lints, structural hygiene)
  workflow run    --file wf.yaml [--coordinator auto|pmake|dwork|mpilist]
                  [--procs N] [--dir D] [--trace out.jsonl]
                  [--connect addr:port] [--poll-ms MS] [--batch N]
                  [--session NAME] [--calibration profile.toml]
  workflow submit --file wf.yaml --connect addr:port [--batch N]
                  [--session NAME]  (scope the campaign to a hub session:
                   per-session accounting on a shared hub)
                  (ingest + detach; N tasks per wire frame, default 64)
  trace report    --file trace.jsonl      (Fig-5-style time breakdown)
  trace profile   [trace.jsonl] [--file trace.jsonl] [--json]
                  [--chrome out.json]
                  (makespan attribution: the realized critical path with
                   per-task blame, queue/launch/compute/drain phases,
                   straggler flags; --chrome writes a chrome://tracing /
                   Perfetto-loadable trace-event file)
  trace compare   --file wf.yaml [--ranks N] [--seed S] [--trace t.jsonl]
                  [--calibration profile.toml]
                  (selector-predicted vs DES-simulated vs measured makespan)
  calibrate <trace.jsonl...> [--out profile.toml] [--report] [--ranks N]
                  [--seed S]
                  (fit the cost model from measured lifecycle traces;
                   --out refuses a profile that cross-validates worse
                   than the Table-4 defaults)
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first().map(String::as_str) else {
        print!("{USAGE}");
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd {
        "pmake" => cmd_pmake(rest),
        "dhub" => cmd_dhub(rest),
        "dwork" => cmd_dwork(rest),
        "task" => cmd_task(rest),
        "metg" => cmd_metg(rest),
        "workflow" => cmd_workflow(rest),
        "trace" => cmd_trace(rest),
        "calibrate" => cmd_calibrate(rest),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{USAGE}"),
    }
}

// ------------------------------------------------------------------- pmake

fn cmd_pmake(argv: &[String]) -> Result<()> {
    let spec = [
        Flag { name: "rules", help: "rules.yaml path", takes_value: true, default: Some("rules.yaml") },
        Flag { name: "targets", help: "targets.yaml path", takes_value: true, default: Some("targets.yaml") },
        Flag { name: "nodes", help: "allocation size (nodes)", takes_value: true, default: Some("1") },
        Flag { name: "fifo", help: "disable priority scheduling", takes_value: false, default: None },
    ];
    let args = parse(argv, &spec)?;
    let nodes = args.get_usize("nodes", 1)?;
    let cfg = pmake::SchedConfig {
        nodes,
        machine: Machine::summit(nodes.max(1)),
        fifo: args.has("fifo"),
    };
    let reports = pmake::make(
        Path::new(args.get("rules").unwrap()),
        Path::new(args.get("targets").unwrap()),
        &pmake::ShellExecutor::default(),
        &cfg,
    )?;
    for (i, r) in reports.iter().enumerate() {
        println!(
            "target {i}: {} ok, {} failed, {} poisoned, makespan {:.2}s (launch overhead {:.3}s)",
            r.succeeded.len(),
            r.failed.len(),
            r.poisoned.len(),
            r.makespan_s,
            r.total_launch_s
        );
    }
    if reports.iter().any(|r| !r.all_ok()) {
        bail!("campaign had failures");
    }
    Ok(())
}

// -------------------------------------------------------------------- dhub

/// Shared body of `dhub serve` and the legacy `dwork serve` verb: run a
/// persistent TCP dhub in the foreground until killed.  With `trace`,
/// every lifecycle transition streams to the JSONL file as it happens
/// (flushed per event, so a ctrl-c loses at most one line).
fn serve_hub(
    bind: &str,
    store: Option<&str>,
    snapshot_every: u64,
    shards: usize,
    trace_path: Option<&str>,
    metrics_addr: Option<&str>,
) -> Result<()> {
    let mut state = match store {
        Some(dir) => {
            dwork::SchedState::with_store_sharded(KvStore::open(Path::new(dir))?, shards)
        }
        None => dwork::SchedState::with_shards(shards),
    };
    if let Some(p) = trace_path {
        state.set_tracer(Tracer::to_file(Path::new(p), "dwork")?);
        println!("tracing lifecycle events to {p}");
    }
    // a served hub always counts: the whole point of a persistent server
    // is that `dhub top` and remote Metrics requests can look at it, and
    // the per-request cost is a handful of relaxed atomic adds
    let reg = Registry::enabled();
    if let Some(maddr) = metrics_addr {
        let (maddr, _scraper) = metrics::serve_exposition(reg.clone(), maddr)?;
        println!("metrics exposition on {maddr} (Prometheus text format)");
    }
    let cfg = dwork::ServerConfig { snapshot_every, metrics: reg, ..dwork::ServerConfig::default() };
    let (addr, _guard, handle) = dwork::spawn_tcp(state, cfg, bind)?;
    println!("dhub serving on {addr} (ctrl-c to stop)");
    let _ = handle.join();
    Ok(())
}

/// The remote-deployment front half: one long-lived task server many
/// launch configurations can feed (the paper's Summit motivation), plus
/// workflow-aware workers that decode task bodies as payloads.
fn cmd_dhub(argv: &[String]) -> Result<()> {
    let Some(verb) = argv.first().map(String::as_str) else {
        bail!("dhub needs a verb: serve | worker | top | status | tail\n{USAGE}");
    };
    let rest = &argv[1..];
    match verb {
        "serve" => {
            let spec = [
                Flag { name: "bind", help: "listen address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "store", help: "persistence directory (restartable hub)", takes_value: true, default: None },
                Flag { name: "snapshot-every", help: "mutations between auto-snapshots (0 = never)", takes_value: true, default: Some("0") },
                Flag { name: "shards", help: "ready-queue shards (task-name hashed; 1 = the classic single deque)", takes_value: true, default: Some("1") },
                Flag { name: "trace", help: "stream lifecycle events to this JSONL file", takes_value: true, default: None },
                Flag { name: "metrics-addr", help: "serve Prometheus text exposition on this address", takes_value: true, default: None },
            ];
            let args = parse(rest, &spec)?;
            serve_hub(
                args.get("bind").unwrap(),
                args.get("store"),
                args.get_usize("snapshot-every", 0)? as u64,
                args.get_usize("shards", 1)?,
                args.get("trace"),
                args.get("metrics-addr"),
            )
        }
        "worker" => {
            let spec = [
                Flag { name: "connect", help: "server address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "workers", help: "pulling threads in this process", takes_value: true, default: Some("1") },
                Flag { name: "prefetch", help: "tasks to buffer per thread", takes_value: true, default: Some("1") },
                Flag { name: "batch", help: "completions to buffer per thread before one batched report", takes_value: true, default: Some("1") },
                Flag { name: "dir", help: "campaign working directory", takes_value: true, default: Some(".") },
                Flag { name: "name", help: "worker name prefix", takes_value: true, default: None },
                Flag { name: "linger", help: "survive campaign boundaries: rejoin after the hub drains", takes_value: false, default: None },
                Flag { name: "trace", help: "stream worker-side lifecycle events to this JSONL file", takes_value: true, default: None },
                Flag { name: "idle-floor-us", help: "idle-backoff floor, microseconds", takes_value: true, default: Some("200") },
                Flag { name: "idle-ceiling-ms", help: "idle-backoff ceiling, milliseconds", takes_value: true, default: Some("100") },
            ];
            let args = parse(rest, &spec)?;
            let tracer = match args.get("trace") {
                // standalone worker trace: this process owns its stream,
                // so it records terminals too (the hub's trace is elsewhere)
                Some(p) => Tracer::to_file(Path::new(p), "dwork-worker")?,
                None => Tracer::default(),
            };
            // the whole pull loop (rejoin backoff, linger semantics,
            // exit-on-drop, payload decode) lives in workflow::WorkerPool
            let mut pool = workflow::WorkerPool::new(args.get("connect").unwrap())
                .threads(args.get_usize("workers", 1)?)
                .prefetch(args.get_usize("prefetch", 1)? as u32)
                .batch(args.get_usize("batch", 1)?)
                .dir(args.get("dir").unwrap())
                .linger(args.has("linger"))
                .idle_backoff(
                    Duration::from_micros(args.get_usize("idle-floor-us", 200)? as u64),
                    Duration::from_millis(args.get_usize("idle-ceiling-ms", 100)? as u64),
                )
                .tracer(tracer);
            if let Some(name) = args.get("name") {
                pool = pool.name(name);
            }
            let stats = pool.run()?;
            println!(
                "{}: {} threads ran {} tasks ({} failed), compute {:.2}s, comm {:.2}s",
                stats.name,
                stats.threads,
                stats.tasks_run,
                stats.tasks_failed,
                stats.compute_s,
                stats.comm_s
            );
            Ok(())
        }
        "top" => {
            let spec = [
                Flag { name: "connect", help: "hub address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "interval-ms", help: "refresh interval, milliseconds", takes_value: true, default: Some("1000") },
                Flag { name: "iters", help: "stop after N refreshes (0 = until the hub drains)", takes_value: true, default: Some("0") },
            ];
            let args = parse(rest, &spec)?;
            watch_hub(
                args.get("connect").unwrap(),
                Duration::from_millis(args.get_usize("interval-ms", 1000)? as u64),
                args.get_usize("iters", 0)?,
                true,
            )
        }
        "status" => {
            let spec = [
                Flag { name: "connect", help: "hub address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "watch", help: "keep refreshing, one line per interval, until drained", takes_value: false, default: None },
                Flag { name: "interval-ms", help: "refresh interval, milliseconds", takes_value: true, default: Some("1000") },
                Flag { name: "iters", help: "stop after N refreshes (0 = until the hub drains)", takes_value: true, default: Some("0") },
            ];
            let args = parse(rest, &spec)?;
            let addr = args.get("connect").unwrap();
            if args.has("watch") {
                watch_hub(
                    addr,
                    Duration::from_millis(args.get_usize("interval-ms", 1000)? as u64),
                    args.get_usize("iters", 0)?,
                    false,
                )
            } else {
                let conn = TcpClient::connect(addr)?;
                let mut c = Client::new(Box::new(conn), "dtop");
                let st = c.status()?;
                let m = c.metrics().ok().filter(|m| m.version != 0);
                println!("{}", hub_line(&st, m.as_ref(), None));
                Ok(())
            }
        }
        "tail" => {
            let spec = [
                Flag { name: "connect", help: "hub address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "follow", help: "keep polling until the hub drains (ctrl-c to stop)", takes_value: false, default: None },
                Flag { name: "task", help: "only events whose task name starts with this prefix", takes_value: true, default: None },
                Flag { name: "json", help: "one trace-JSONL event object per line", takes_value: false, default: None },
                Flag { name: "interval-ms", help: "poll interval, milliseconds", takes_value: true, default: Some("100") },
            ];
            let args = parse(rest, &spec)?;
            tail_hub(
                args.get("connect").unwrap(),
                args.get("task").unwrap_or(""),
                args.has("follow"),
                args.has("json"),
                Duration::from_millis(args.get_usize("interval-ms", 100)? as u64),
            )
        }
        other => bail!("unknown dhub verb {other:?} (serve | worker | top | status | tail)"),
    }
}

/// `dhub tail`: attach a live-event subscription to a running hub and
/// print lifecycle events as they happen.  The subscription registers on
/// the first long-poll, so only events after attach appear.  Without
/// `--follow` one poll interval's worth of events is printed (a sample
/// window for scripting); with it, polling continues until the hub
/// reports the graph drained.  Server-side overflow (this tail polling
/// too slowly for the event rate) surfaces as a stderr warning with the
/// dropped count — the hub never blocks on us.
fn tail_hub(
    addr: &str,
    prefix: &str,
    follow: bool,
    json: bool,
    interval: Duration,
) -> Result<()> {
    let conn = TcpClient::connect(addr)?;
    let name = format!("tail-{}", std::process::id());
    // exit_on_drop detaches the subscription when we leave
    let mut c = Client::new(Box::new(conn), name).exit_on_drop(true);
    let first = c.subscribe(prefix, 0)?;
    if first.done && !follow {
        return Ok(()); // drained hub: nothing will ever arrive
    }
    loop {
        std::thread::sleep(interval);
        let batch = c.subscribe(prefix, 0)?;
        if batch.dropped > 0 {
            eprintln!(
                "warning: {} events dropped server-side (tail polling too slowly)",
                batch.dropped
            );
        }
        for ev in &batch.events {
            if json {
                println!("{}", trace::event_line(ev));
            } else {
                println!(
                    "{:>14.6}s  {:<9} {:<32} {}",
                    ev.t,
                    ev.kind.name(),
                    ev.task,
                    ev.who
                );
            }
        }
        if !follow || batch.done {
            return Ok(());
        }
    }
}

/// Shared loop of `dhub top` (full-screen) and `dhub status --watch`
/// (one line per refresh): a Status + Metrics round-trip pair per
/// interval, tasks/sec from completed-count deltas.  Stops after
/// `iters` refreshes when nonzero (the scripting/CI escape hatch), or
/// once a non-empty hub drains.
fn watch_hub(addr: &str, interval: Duration, iters: usize, screen: bool) -> Result<()> {
    let conn = TcpClient::connect(addr)?;
    let mut c = Client::new(Box::new(conn), "dtop");
    let mut last: Option<(Instant, u64)> = None;
    let mut done = 0usize;
    loop {
        let st = c.status()?;
        // best-effort: an old hub answers Err for the Metrics request
        // kind and a metrics-disabled hub answers version 0 — the view
        // degrades to Status-only rather than failing
        let m = c.metrics().ok().filter(|m| m.version != 0);
        let now = Instant::now();
        let rate = last.map(|(t0, done0)| {
            st.completed.saturating_sub(done0) as f64
                / now.duration_since(t0).as_secs_f64().max(1e-9)
        });
        last = Some((now, st.completed));
        done += 1;
        if screen {
            print!("\x1b[2J\x1b[H{}", render_top(addr, &st, m.as_ref(), rate));
            std::io::Write::flush(&mut std::io::stdout())?;
        } else {
            println!("{}", hub_line(&st, m.as_ref(), rate));
        }
        if (iters > 0 && done >= iters) || (st.total > 0 && st.is_drained()) {
            return Ok(());
        }
        std::thread::sleep(interval);
    }
}

/// The `dwork status` line, extended with rate and steal-latency fields
/// when the hub exposes metrics.
fn hub_line(st: &dwork::StatusInfo, m: Option<&MetricsSnapshot>, rate: Option<f64>) -> String {
    let mut line = format!(
        "total={} ready={} waiting={} assigned={} completed={} errored={} failed={} \
         workers={} drained={}",
        st.total,
        st.ready,
        st.waiting,
        st.assigned,
        st.completed,
        st.errored,
        st.failed,
        st.workers,
        st.is_drained()
    );
    if !st.sessions.is_empty() {
        line.push_str(&format!(" sessions={}", st.sessions.len()));
    }
    // a zero-worker refresh (pool not joined yet, or all exited
    // mid-campaign) must still render: clamp any non-finite rate
    match rate {
        Some(r) if r.is_finite() => line.push_str(&format!(" tasks/s={r:.1}")),
        Some(_) => line.push_str(" tasks/s=-"),
        None => {}
    }
    if let Some(m) = m {
        line.push_str(&format!(
            " steals={}/{} steal_p99={}",
            m.counter("steals_served"),
            m.counter("steals_served") + m.counter("steals_empty"),
            fmt_q(m, "service_steal", 0.99),
        ));
    }
    line
}

/// The `dhub top` dashboard body (everything below the ANSI clear).
fn render_top(
    addr: &str,
    st: &dwork::StatusInfo,
    m: Option<&MetricsSnapshot>,
    rate: Option<f64>,
) -> String {
    let up = m.map_or_else(|| "-".into(), |m| format!("{:.0}s", m.uptime_s));
    let mut out = format!("dhub {addr} — up {up}\n\n");
    out.push_str(&format!(
        "  tasks    total {:>8}  ready {:>8}  waiting {:>7}  assigned {:>6}\n",
        st.total, st.ready, st.waiting, st.assigned
    ));
    out.push_str(&format!(
        "           completed {:>4}  errored {:>6}  failed-at-a-worker {:>4}\n",
        st.completed, st.errored, st.failed
    ));
    match rate {
        // a stalled zero-worker hub reports 0.0/s, never NaN/inf junk
        Some(r) if r.is_finite() => out.push_str(&format!(
            "  rate     {r:>14.1} tasks/s (completed, since last refresh)\n"
        )),
        _ => out.push_str("  rate     (needs a second refresh)\n"),
    }
    if !st.sessions.is_empty() {
        out.push_str("\n  session                    live  completed    errored     failed\n");
        for s in &st.sessions {
            out.push_str(&format!(
                "    {:<24} {:>6} {:>10} {:>10} {:>10}\n",
                s.name, s.live(), s.completed, s.errored, s.failed
            ));
        }
    }
    let Some(m) = m else {
        out.push_str(&format!("  workers  {:>8} connected\n", st.workers));
        out.push_str("\n  (hub answered without metrics: old server or metrics disabled)\n");
        return out;
    };
    out.push_str(&format!(
        "  workers  {:>8} connected  attached-ever {:>3}  exited {:>8}\n",
        m.gauge("workers_connected"),
        m.counter("workers_attached"),
        m.counter("workers_exited"),
    ));
    out.push_str(&format!(
        "  queue    depth {:>8}  inflight {:>5}  requeued {:>8}\n",
        m.gauge("queue_depth"),
        m.gauge("tasks_inflight"),
        m.counter("tasks_requeued"),
    ));
    out.push_str(&format!(
        "  steals   served {:>7}  empty {:>8}  malformed-reqs {:>4}\n",
        m.counter("steals_served"),
        m.counter("steals_empty"),
        m.counter("requests_malformed"),
    ));
    out.push_str("\n  hub service time        p50        p90        p99      count\n");
    for name in ["service_steal", "service_create", "service_complete", "service_status"] {
        // an untouched series (zero workers joined yet, submit-only hub)
        // renders a placeholder row — skipping it left a bare header and
        // a jumping layout between refreshes
        match m.hist(name) {
            Some(h) if h.count > 0 => out.push_str(&format!(
                "    {:<16} {:>10} {:>10} {:>10} {:>10}\n",
                name.trim_start_matches("service_"),
                fmt_s(h.quantile(0.5)),
                fmt_s(h.quantile(0.9)),
                fmt_s(h.quantile(0.99)),
                h.count,
            )),
            _ => out.push_str(&format!(
                "    {:<16} {:>10} {:>10} {:>10} {:>10}\n",
                name.trim_start_matches("service_"),
                "-", "-", "-", 0,
            )),
        }
    }
    out
}

/// Human duration: sub-millisecond in µs, sub-second in ms, else seconds.
fn fmt_s(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.0}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

fn fmt_q(m: &MetricsSnapshot, series: &str, q: f64) -> String {
    match m.hist(series) {
        Some(h) if h.count > 0 => fmt_s(h.quantile(q)),
        _ => "-".into(),
    }
}

// ------------------------------------------------------------------- dwork

fn cmd_dwork(argv: &[String]) -> Result<()> {
    let Some(verb) = argv.first().map(String::as_str) else {
        bail!("dwork needs a verb: serve | worker | create | status | drain\n{USAGE}");
    };
    let rest = &argv[1..];
    match verb {
        "serve" => {
            let spec = [
                Flag { name: "bind", help: "listen address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "db", help: "persistence directory", takes_value: true, default: None },
                Flag { name: "snapshot-every", help: "mutations between snapshots", takes_value: true, default: Some("0") },
            ];
            let args = parse(rest, &spec)?;
            serve_hub(
                args.get("bind").unwrap(),
                args.get("db"),
                args.get_usize("snapshot-every", 0)? as u64,
                1,
                None,
                None,
            )
        }
        "worker" => {
            let spec = [
                Flag { name: "connect", help: "server address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "name", help: "worker name", takes_value: true, default: None },
                Flag { name: "prefetch", help: "tasks to buffer", takes_value: true, default: Some("1") },
                Flag { name: "artifacts-dir", help: "artifact directory", takes_value: true, default: None },
            ];
            let args = parse(rest, &spec)?;
            let name = args
                .get("name")
                .map(str::to_string)
                .unwrap_or_else(|| format!("worker-{}", std::process::id()));
            let conn = TcpClient::connect(args.get("connect").unwrap())?;
            let mut c = Client::new(Box::new(conn), name.clone());
            let dir = artifacts_dir(args.get("artifacts-dir"));
            let svc = RuntimeService::start(&dir)?;
            let h = svc.handle();
            let prefetch = args.get_usize("prefetch", 1)? as u32;
            // task body convention: task name "<artifact>@<seed>" runs the
            // artifact with deterministic inputs; anything else is a no-op
            let stats = dwork::run_worker(&mut c, prefetch, |t| {
                if let Some((artifact, seed)) = t.name.split_once('@') {
                    let seed: u64 = seed.parse().unwrap_or(0);
                    run_artifact(&h, &dir, artifact, seed, None)?;
                }
                Ok(())
            })?;
            println!(
                "{name}: ran {} tasks ({} failed), compute {:.2}s, comm {:.2}s",
                stats.tasks_run, stats.tasks_failed, stats.compute_s, stats.comm_s
            );
            Ok(())
        }
        "create" => {
            let spec = [
                Flag { name: "connect", help: "server address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "name", help: "task name", takes_value: true, default: None },
                Flag { name: "dep", help: "dependencies, comma separated", takes_value: true, default: None },
            ];
            let args = parse(rest, &spec)?;
            let name = args.get("name").context("--name is required")?;
            let deps: Vec<String> = args
                .get("dep")
                .map(|d| d.split(',').map(str::to_string).collect())
                .unwrap_or_default();
            let conn = TcpClient::connect(args.get("connect").unwrap())?;
            let mut c = Client::new(Box::new(conn), "dquery");
            let out = c.submit(&[CreateItem::new(TaskMsg::new(name, vec![]), deps.clone())])?;
            match out.into_iter().next() {
                Some(SubmitOutcome::Created) => {
                    println!("created {name} (deps: {deps:?})");
                    Ok(())
                }
                Some(SubmitOutcome::Refused(e)) => bail!("hub refused {name}: {e}"),
                None => bail!("hub returned no outcome for {name}"),
            }
        }
        "status" => {
            let spec = [Flag {
                name: "connect",
                help: "server address",
                takes_value: true,
                default: Some("127.0.0.1:7117"),
            }];
            let args = parse(rest, &spec)?;
            let conn = TcpClient::connect(args.get("connect").unwrap())?;
            let mut c = Client::new(Box::new(conn), "dquery");
            let st = c.status()?;
            println!(
                "total={} ready={} waiting={} assigned={} completed={} errored={} \
                 failed={} workers={} drained={}",
                st.total,
                st.ready,
                st.waiting,
                st.assigned,
                st.completed,
                st.errored,
                st.failed,
                st.workers,
                st.is_drained()
            );
            Ok(())
        }
        "drain" => {
            let spec = [Flag {
                name: "connect",
                help: "server address",
                takes_value: true,
                default: Some("127.0.0.1:7117"),
            }];
            let args = parse(rest, &spec)?;
            let conn = TcpClient::connect(args.get("connect").unwrap())?;
            let mut c = Client::new(Box::new(conn), format!("drain-{}", std::process::id()));
            let stats = dwork::run_worker(&mut c, 4, |_| Ok(()))?;
            println!("drained {} tasks", stats.tasks_run);
            Ok(())
        }
        other => bail!("unknown dwork verb {other:?}"),
    }
}

// -------------------------------------------------------------------- task

fn artifacts_dir(flag: Option<&str>) -> PathBuf {
    flag.map(PathBuf::from).unwrap_or_else(default_artifacts_dir)
}

/// Execute one artifact with deterministic seeded inputs; optionally dump
/// |outputs| to a file (one value per line) so downstream pmake rules can
/// consume them.
fn run_artifact(
    h: &threesched::runtime::service::RuntimeHandle,
    artifacts_dir: &Path,
    artifact: &str,
    seed: u64,
    out: Option<&Path>,
) -> Result<f64> {
    // build inputs from the manifest shapes
    let manifest =
        threesched::runtime::registry::Manifest::load(&artifacts_dir.join("manifest.tsv"))?;
    let spec = manifest
        .get(artifact)
        .with_context(|| format!("unknown artifact {artifact:?}"))?;
    let mut inputs = Vec::new();
    for (i, shape) in spec.inputs.iter().enumerate() {
        match shape.dtype {
            threesched::runtime::registry::Dtype::F32 => {
                inputs.push(HostBuf::F32(fill_f32(shape.elems(), seed * 31 + i as u64)));
            }
            threesched::runtime::registry::Dtype::I32 => {
                inputs.push(HostBuf::I32(vec![seed as i32; shape.elems()]));
            }
        }
    }
    let (outs, dt) = h.execute(artifact, inputs)?;
    if let Some(path) = out {
        let mut text = String::new();
        if let Ok(vals) = outs[0].as_f32() {
            for v in vals.iter().take(256) {
                text.push_str(&format!("{}\n", v.abs()));
            }
        }
        std::fs::write(path, text).with_context(|| format!("writing {path:?}"))?;
    }
    Ok(dt)
}

fn cmd_task(argv: &[String]) -> Result<()> {
    let spec = [
        Flag { name: "artifact", help: "artifact name (see artifacts/manifest.tsv)", takes_value: true, default: Some("atb_128") },
        Flag { name: "seed", help: "input seed", takes_value: true, default: Some("0") },
        Flag { name: "out", help: "write |outputs| here (one/line)", takes_value: true, default: None },
        Flag { name: "artifacts-dir", help: "artifact directory", takes_value: true, default: None },
    ];
    let args = parse(argv, &spec)?;
    let dir = artifacts_dir(args.get("artifacts-dir"));
    let svc = RuntimeService::start(&dir)?;
    let h = svc.handle();
    let artifact = args.get("artifact").unwrap();
    let seed = args.get_usize("seed", 0)? as u64;
    let dt = run_artifact(&h, &dir, artifact, seed, args.get("out").map(Path::new))?;
    println!("{artifact} seed={seed}: executed in {:.3}ms", dt * 1e3);
    Ok(())
}

// ---------------------------------------------------------------- workflow

/// The cost model a `--calibration profile.toml` flag denotes: Table-4
/// defaults when absent, the profile's fitted overrides otherwise.
fn load_model(calibration: Option<&str>) -> Result<CostModel> {
    match calibration {
        None => Ok(CostModel::paper()),
        Some(p) => {
            let prof = CalibrationProfile::load(Path::new(p))?;
            println!("calibration: {p} ({})", prof.source);
            Ok(prof.model())
        }
    }
}

fn cmd_workflow(argv: &[String]) -> Result<()> {
    let Some(verb) = argv.first().map(String::as_str) else {
        bail!("workflow needs a verb: plan | lower | lint | run | submit\n{USAGE}");
    };
    let rest = &argv[1..];
    match verb {
        "plan" => {
            let spec = [
                Flag { name: "file", help: "workflow yaml", takes_value: true, default: Some("workflow.yaml") },
                Flag { name: "ranks", help: "target scale for the selector", takes_value: true, default: Some("864") },
                Flag { name: "calibration", help: "fitted cost-model profile (from `threesched calibrate`)", takes_value: true, default: None },
            ];
            let args = parse(rest, &spec)?;
            let g = workflow::parse_workflow_file(Path::new(args.get("file").unwrap()))?;
            let plan = workflow::Session::new(&g)
                .parallelism(args.get_usize("ranks", 864)?)
                .cost_model(load_model(args.get("calibration"))?)
                .plan()?;
            print!("workflow {:?}\n{}", g.name, plan.render());
            Ok(())
        }
        "lower" => {
            let spec = [
                Flag { name: "file", help: "workflow yaml", takes_value: true, default: Some("workflow.yaml") },
                Flag { name: "coordinator", help: "auto | pmake | dwork | mpilist", takes_value: true, default: Some("pmake") },
                Flag { name: "out", help: "write lowered files here (pmake only; default: print)", takes_value: true, default: None },
                Flag { name: "ranks", help: "rank count for the mpilist plan and the auto selector", takes_value: true, default: Some("4") },
            ];
            let args = parse(rest, &spec)?;
            let g = workflow::parse_workflow_file(Path::new(args.get("file").unwrap()))?;
            let coordinator = args.get("coordinator").unwrap();
            let Some(backend) = workflow::Backend::from_name(coordinator) else {
                bail!("unknown coordinator {coordinator:?} (auto | pmake | dwork | mpilist)")
            };
            let auto = backend == workflow::Backend::Auto;
            let mut session = workflow::Session::new(&g)
                .backend(backend)
                .parallelism(args.get_usize("ranks", 4)?)
                .dir(args.get("out").unwrap_or("."));
            if auto {
                // never silently disagree with `workflow plan`: name the
                // verdict and the scale it was made at (--ranks here
                // defaults to 4, plan's selector defaults to 864) — then
                // pin the resolved backend so lower() doesn't re-select
                let plan = session.plan()?;
                eprintln!(
                    "auto-selected coordinator: {} (selector at {} ranks; pass --ranks to \
                     match your `workflow plan` scale)",
                    plan.tool.name(),
                    plan.parallelism
                );
                session = session.backend(workflow::Backend::from_tool(plan.tool));
            }
            let lowered = session.lower()?;
            match lowered {
                workflow::Lowered::Pmake(low) => match args.get("out") {
                    Some(dir) => {
                        std::fs::create_dir_all(dir)?;
                        std::fs::write(Path::new(dir).join("rules.yaml"), &low.rules_yaml)?;
                        std::fs::write(Path::new(dir).join("targets.yaml"), &low.targets_yaml)?;
                        println!("wrote {dir}/rules.yaml and {dir}/targets.yaml");
                    }
                    None => print!(
                        "# rules.yaml\n{}\n# targets.yaml\n{}",
                        low.rules_yaml, low.targets_yaml
                    ),
                },
                workflow::Lowered::Dwork(tasks) => {
                    print!("{}", workflow::lower::render_dwork(&tasks));
                }
                workflow::Lowered::MpiList(plan) => print!("{}", plan.render(&g)),
            }
            Ok(())
        }
        "lint" => {
            let spec = [
                Flag { name: "file", help: "workflow yaml", takes_value: true, default: Some("workflow.yaml") },
                Flag { name: "json", help: "emit one JSON object per report", takes_value: false, default: None },
                Flag { name: "deny", help: "treat this severity as fatal (only `warnings`)", takes_value: true, default: None },
                Flag { name: "ranks", help: "target scale for the METG lints", takes_value: true, default: Some("864") },
                Flag { name: "coordinator", help: "lint granularity against this backend (auto = the selector's own choice)", takes_value: true, default: Some("auto") },
                Flag { name: "calibration", help: "fitted cost-model profile (from `threesched calibrate`)", takes_value: true, default: None },
                Flag { name: "standard", help: "lint the calibrate::workloads::standard() suite instead of a file", takes_value: false, default: None },
            ];
            let args = parse(rest, &spec)?;
            let deny_warnings = match args.get("deny") {
                None => false,
                Some("warnings") => true,
                Some(other) => bail!("--deny accepts only `warnings`, got {other:?}"),
            };
            let target = match args.get("coordinator").unwrap() {
                "auto" => None,
                "pmake" => Some(Tool::Pmake),
                "dwork" => Some(Tool::Dwork),
                "mpilist" | "mpi-list" => Some(Tool::MpiList),
                other => bail!("unknown coordinator {other:?} (auto | pmake | dwork | mpilist)"),
            };
            let model = load_model(args.get("calibration"))?;
            let mut reports = Vec::new();
            if args.has("standard") {
                // each calibration workload lints at its own scale
                for run in calibrate::workloads::standard() {
                    let opts =
                        AnalyzeOpts { ranks: run.ranks, model: model.clone(), target };
                    reports.push(analyze_graph(&run.graph, &opts));
                }
            } else {
                // positional form (`workflow lint wf.yaml`) wins over --file
                let file = match args.positional.first() {
                    Some(p) => p.as_str(),
                    None => args.get("file").unwrap(),
                };
                // the loose parse admits defective graphs so every finding
                // lands in one report instead of a bail on the first
                let g = workflow::parse_workflow_file_loose(Path::new(file))?;
                let opts = AnalyzeOpts { ranks: args.get_usize("ranks", 864)?, model, target };
                reports.push(analyze_graph(&g, &opts));
            }
            let mut verdict = Ok(());
            for r in &reports {
                if args.has("json") {
                    println!("{}", r.to_json());
                } else {
                    print!("{}", r.render());
                }
                if verdict.is_ok() {
                    verdict = r.deny(deny_warnings);
                }
            }
            verdict
        }
        "submit" => {
            let spec = [
                Flag { name: "file", help: "workflow yaml", takes_value: true, default: Some("workflow.yaml") },
                Flag { name: "connect", help: "remote dhub address", takes_value: true, default: Some("127.0.0.1:7117") },
                Flag { name: "batch", help: "tasks per batched Create frame (1 = per-task round-trips)", takes_value: true, default: Some("64") },
                Flag { name: "session", help: "hub session to scope the campaign to (shared-hub isolation)", takes_value: true, default: None },
            ];
            let args = parse(rest, &spec)?;
            let g = workflow::parse_workflow_file(Path::new(args.get("file").unwrap()))?;
            let addr = args.get("connect").unwrap();
            let session_name = args.get("session").map(str::to_string);
            let sub = workflow::Session::new(&g)
                .backend(workflow::Backend::Dwork {
                    remote: Some(addr.into()),
                    session: session_name.clone(),
                })
                .polling(workflow::PollCfg {
                    transport: TransportCfg::default()
                        .with_batch(args.get_usize("batch", 64)?),
                    ..workflow::PollCfg::default()
                })
                .submit()?;
            match (&session_name, &sub.accounting.session) {
                (Some(s), Some(_)) => println!("session {s:?} opened on {addr}"),
                (Some(s), None) => eprintln!(
                    "warning: hub at {addr} predates sessions; {s:?} degraded to the \
                     anonymous namespace"
                ),
                (None, _) => {}
            }
            println!(
                "submitted {} tasks of workflow {:?} to dhub {addr} (detached; \
                 poll with `threesched dwork status --connect {addr}`)",
                sub.accounting.submitted, g.name
            );
            if sub.accounting.skipped_at_submit > 0 {
                println!(
                    "note: {} tasks skipped at submit (an upstream dependency had \
                     already failed)",
                    sub.accounting.skipped_at_submit
                );
            }
            Ok(())
        }
        "run" => {
            let spec = [
                Flag { name: "file", help: "workflow yaml", takes_value: true, default: Some("workflow.yaml") },
                Flag { name: "coordinator", help: "auto | pmake | dwork | mpilist", takes_value: true, default: Some("auto") },
                Flag { name: "procs", help: "parallelism (nodes/workers/ranks)", takes_value: true, default: None },
                Flag { name: "dir", help: "campaign working directory", takes_value: true, default: Some(".") },
                Flag { name: "connect", help: "remote dhub address (implies dwork; workers join separately)", takes_value: true, default: None },
                Flag { name: "poll-ms", help: "status poll interval with --connect, milliseconds", takes_value: true, default: Some("50") },
                Flag { name: "batch", help: "tasks per batched Create frame with --connect (1 = per-task)", takes_value: true, default: Some("64") },
                Flag { name: "trace", help: "write a lifecycle trace (JSONL) after the run", takes_value: true, default: None },
                Flag { name: "calibration", help: "fitted cost-model profile for the auto selector", takes_value: true, default: None },
                Flag { name: "session", help: "hub session to scope the campaign to (--connect only)", takes_value: true, default: None },
            ];
            let args = parse(rest, &spec)?;
            let g = workflow::parse_workflow_file(Path::new(args.get("file").unwrap()))?;
            let trace_path = args.get("trace").map(PathBuf::from);
            let tracer =
                if trace_path.is_some() { Tracer::memory() } else { Tracer::default() };
            if args.get("calibration").is_some()
                && (args.get("connect").is_some() || args.get("coordinator") != Some("auto"))
            {
                eprintln!(
                    "warning: --calibration only affects the auto selector; ignored here"
                );
            }
            // one session carries every knob; the default parallelism is
            // the machine's available parallelism, so --procs only needs
            // forwarding when the user actually passed it
            let mut session = workflow::Session::new(&g)
                .dir(args.get("dir").unwrap())
                .tracer(tracer.clone());
            if args.get("procs").is_some() {
                session = session.parallelism(args.get_usize("procs", 2)?);
            }
            let outcome = match (args.get("connect"), args.get("coordinator").unwrap()) {
                (Some(addr), "dwork" | "auto") => {
                    // execution happens wherever the worker pools run:
                    // local-driver knobs do not travel over the wire
                    if args.get("procs").is_some() {
                        eprintln!("warning: --procs is ignored with --connect \
                                   (parallelism = whatever worker pools joined the hub)");
                    }
                    if args.get("dir") != Some(".") {
                        eprintln!("warning: --dir is ignored with --connect \
                                   (workers use their own `dhub worker --dir`)");
                    }
                    if trace_path.is_some() {
                        // a remote campaign is traced by subscribing to
                        // the hub's live event stream while we await the
                        // drain; the local tracer fills from that feed
                        println!(
                            "tracing remote campaign via live hub subscription \
                             (server-side timestamps)"
                        );
                    }
                    println!(
                        "feeding remote dhub {addr} (join workers with \
                         `threesched dhub worker --connect {addr}`)"
                    );
                    session
                        .backend(workflow::Backend::Dwork {
                            remote: Some(addr.into()),
                            session: args.get("session").map(str::to_string),
                        })
                        .polling(workflow::PollCfg {
                            poll: Duration::from_millis(args.get_usize("poll-ms", 50)? as u64),
                            transport: TransportCfg::default()
                                .with_batch(args.get_usize("batch", 64)?),
                            ..workflow::PollCfg::default()
                        })
                        .run()?
                }
                (Some(_), other) => {
                    bail!("--connect is a dwork deployment (got --coordinator {other})")
                }
                (None, name) => {
                    if args.get("session").is_some() {
                        eprintln!("warning: --session only applies with --connect \
                                   (an in-process hub is single-campaign); ignored");
                    }
                    let Some(backend) = workflow::Backend::from_name(name) else {
                        bail!("unknown coordinator {name:?} (auto | pmake | dwork | mpilist)")
                    };
                    if backend == workflow::Backend::Auto {
                        session = session.cost_model(load_model(args.get("calibration"))?);
                    }
                    let outcome = session.backend(backend).run()?;
                    // the selector's table, exactly as `workflow plan` prints it
                    if let Some(rec) = &outcome.plan.recommendation {
                        print!("{}", rec.render());
                    }
                    outcome
                }
            };
            let summary = &outcome.summary;
            if let Some(path) = &trace_path {
                let events = tracer.drain();
                trace::write_trace(path, summary.coordinator.name(), &events)?;
                println!(
                    "trace: {} events -> {} (inspect with `threesched trace report --file {}`)",
                    events.len(),
                    path.display(),
                    path.display()
                );
            }
            println!(
                "{}: {} tasks run, {} failed, {} skipped, makespan {:.3}s",
                summary.coordinator.name(),
                summary.tasks_run,
                summary.tasks_failed,
                summary.tasks_skipped,
                summary.makespan_s
            );
            if !summary.all_ok() {
                bail!("workflow had failures");
            }
            Ok(())
        }
        other => bail!("unknown workflow verb {other:?}"),
    }
}

// ------------------------------------------------------------------- trace

fn cmd_trace(argv: &[String]) -> Result<()> {
    let Some(verb) = argv.first().map(String::as_str) else {
        bail!("trace needs a verb: report | profile | compare\n{USAGE}");
    };
    let rest = &argv[1..];
    match verb {
        "report" => {
            let spec = [Flag {
                name: "file",
                help: "trace JSONL path (from `workflow run --trace`, `dhub serve --trace`, …)",
                takes_value: true,
                default: Some("trace.jsonl"),
            }];
            let args = parse(rest, &spec)?;
            let path = Path::new(args.get("file").unwrap());
            let (source, events, samples) = trace::read_trace_full(path)?;
            // a trace cut short (ctrl-c'd hub, killed worker) is exactly
            // what the flush-per-event streaming sink exists to preserve:
            // report it anyway, flagging the incompleteness
            if let Err(e) = trace::validate(&events) {
                eprintln!("warning: trace {path:?} is incomplete or malformed ({e}); \
                           reporting the events present");
            }
            print!("{}", trace::TraceReport::from_events(&events).render(&source));
            print!("{}", trace::render_metrics(&samples));
            Ok(())
        }
        "profile" => {
            let spec = [
                Flag { name: "file", help: "trace JSONL path", takes_value: true, default: Some("trace.jsonl") },
                Flag { name: "json", help: "emit the profile as one JSON object", takes_value: false, default: None },
                Flag { name: "chrome", help: "also write a Chrome trace-event file (chrome://tracing, ui.perfetto.dev)", takes_value: true, default: None },
            ];
            let args = parse(rest, &spec)?;
            // positional form (`trace profile t.jsonl`) wins over --file
            let path = match args.positional.first() {
                Some(p) => Path::new(p.as_str()),
                None => Path::new(args.get("file").unwrap()),
            };
            let (source, events, _samples) = trace::read_trace_full(path)?;
            if let Err(e) = trace::validate(&events) {
                eprintln!(
                    "warning: trace {path:?} is incomplete or malformed ({e}); \
                     profiling the events present"
                );
            }
            let profile = trace::TraceProfile::from_events(&events);
            if let Some(out) = args.get("chrome") {
                std::fs::write(out, trace::chrome_trace(&events, &profile))
                    .with_context(|| format!("writing {out:?}"))?;
                // stderr so `--json > profile.json` stays clean JSON
                eprintln!("chrome trace: {out} (load in chrome://tracing or ui.perfetto.dev)");
            }
            if args.has("json") {
                println!("{}", profile.to_json(&source));
            } else {
                print!("{}", profile.render(&source));
            }
            Ok(())
        }
        "compare" => {
            let spec = [
                Flag { name: "file", help: "workflow yaml", takes_value: true, default: Some("workflow.yaml") },
                Flag { name: "ranks", help: "parallelism for prediction + simulation", takes_value: true, default: Some("864") },
                Flag { name: "seed", help: "DES noise seed", takes_value: true, default: Some("42") },
                Flag { name: "trace", help: "measured trace JSONL to lay alongside (optional)", takes_value: true, default: None },
                Flag { name: "calibration", help: "fitted cost-model profile (from `threesched calibrate`)", takes_value: true, default: None },
            ];
            let args = parse(rest, &spec)?;
            let g = workflow::parse_workflow_file(Path::new(args.get("file").unwrap()))?;
            let ranks = args.get_usize("ranks", 864)?;
            let seed = args.get_usize("seed", 42)? as u64;
            let mut measured = Vec::new();
            if let Some(p) = args.get("trace") {
                let (source, events) = trace::read_trace(Path::new(p))?;
                // an interrupted trace still yields a (lower-bound)
                // measured makespan; flag it rather than refusing
                if let Err(e) = trace::validate(&events) {
                    eprintln!("warning: trace {p:?} is incomplete or malformed ({e}); \
                               its makespan is a lower bound");
                }
                measured.push((source, trace::makespan(&events)));
            }
            let m = load_model(args.get("calibration"))?;
            let rows = trace::compare_backends(&g, &m, ranks, seed, &measured)?;
            print!("{}", trace::render_comparison(&g.name, ranks, &rows));
            Ok(())
        }
        other => bail!("unknown trace verb {other:?} (report | profile | compare)"),
    }
}

// --------------------------------------------------------------- calibrate

fn cmd_calibrate(argv: &[String]) -> Result<()> {
    let spec = [
        Flag { name: "out", help: "write the fitted profile here (TOML)", takes_value: true, default: None },
        Flag { name: "report", help: "print the full before/after cross-validation table", takes_value: false, default: None },
        Flag { name: "ranks", help: "force the per-trace parallelism instead of inferring it", takes_value: true, default: None },
        Flag { name: "seed", help: "DES seed for cross-validation", takes_value: true, default: Some("1234") },
    ];
    let args = parse(argv, &spec)?;
    if args.positional.is_empty() {
        bail!("calibrate needs at least one trace JSONL file\n{USAGE}");
    }
    let ranks_override = match args.get("ranks") {
        Some(_) => Some(args.get_usize("ranks", 0)?.max(1)),
        None => None,
    };
    let base = CostModel::paper();
    let mut traces = Vec::new();
    for p in &args.positional {
        let (source, events) = trace::read_trace(Path::new(p))?;
        // an interrupted trace still carries usable samples; fit what is
        // there and let the CIs reflect the thinner evidence
        if let Err(e) = trace::validate(&events) {
            eprintln!(
                "warning: trace {p:?} is incomplete or malformed ({e}); \
                 fitting the events present"
            );
        }
        traces.push(
            calibrate::classify_trace(&source, events, ranks_override)
                .with_context(|| format!("classifying {p:?}"))?,
        );
    }
    let cal = calibrate::fit_traces(&traces, &base)?;
    print!("{}", calibrate::render_calibration(&cal));
    let seed = args.get_usize("seed", 1234)? as u64;
    let v = calibrate::validate_profile(&traces, &base, &cal.profile, seed)?;
    if args.has("report") {
        print!("{}", calibrate::render_validation(&v));
    } else {
        println!(
            "mean relative makespan error: default {:.2}% -> fitted {:.2}% \
             (--report for the per-trace table)",
            100.0 * v.mean_err_default,
            100.0 * v.mean_err_fitted
        );
    }
    if let Some(out) = args.get("out") {
        if !v.improved() {
            bail!(
                "refusing to write {out:?}: the fitted profile does not lower the mean \
                 prediction error on these traces (default {:.2}%, fitted {:.2}%) — \
                 record longer or cleaner calibration runs and refit",
                100.0 * v.mean_err_default,
                100.0 * v.mean_err_fitted
            );
        }
        cal.profile.save(Path::new(out))?;
        println!(
            "wrote {out} (use with `threesched workflow plan --calibration {out}` or \
             `trace compare --calibration {out}`)"
        );
    }
    Ok(())
}

// -------------------------------------------------------------------- metg

fn cmd_metg(argv: &[String]) -> Result<()> {
    let spec = [Flag {
        name: "rtt-us",
        help: "override server RTT (microseconds)",
        takes_value: true,
        default: None,
    }];
    let args = parse(argv, &spec)?;
    let mut m = CostModel::paper();
    if let Some(rtt) = args.get("rtt-us") {
        let us: f64 = rtt.parse().context("--rtt-us expects a number")?;
        m = m.with_measured_rtt(us * 1e-6);
    }
    let w = Workload::paper();
    println!("{}", render_metg(&metg_sweep(&m, &w, &PAPER_RANKS)));
    Ok(())
}
