//! mpi-list: bulk-synchronous distributed lists (paper sec. 2.3).
//!
//! Exactly two classes, like the Python original: a [`Context`] holding
//! the communicator, and a [`DFM`] (distributed free monoid) holding the
//! list elements local to each rank.  The global list is logically
//! ordered, with a contiguous ascending subset on each rank; because all
//! ranks execute the same operations on their local portion, *no
//! synchronization at all* is needed for local operations — the paper's
//! third synchronization archetype.

pub mod dfm;

pub use dfm::DFM;

use crate::substrate::comm::{Comm, CommWorld};

/// Execution context: rank/size plus the collectives DFM ops need.
pub struct Context {
    pub comm: Comm,
}

impl Context {
    pub fn new(comm: Comm) -> Context {
        Context { comm }
    }

    /// This rank (paper: `C.rank`).
    pub fn rank(&self) -> usize {
        self.comm.rank()
    }

    /// Total ranks (paper: `C.procs`).
    pub fn procs(&self) -> usize {
        self.comm.size()
    }

    /// Create a distributed list of the integers `0..n`
    /// (paper: `Context.iterates(N)`).
    pub fn iterates(&self, n: u64) -> DFM<u64> {
        let (start, count) = block_range(self.rank(), self.procs(), n);
        DFM::from_local((start..start + count).collect())
    }

    /// Run an SPMD closure on `procs` in-process ranks and collect each
    /// rank's result — the `mpirun python my_script.py` of this world.
    pub fn run<T: Send>(procs: usize, f: impl Fn(&mut Context) -> T + Sync) -> Vec<T> {
        CommWorld::run(procs, |comm| {
            let mut ctx = Context::new(comm);
            f(&mut ctx)
        })
    }
}

/// Workflow-IR ingestion: compile a [`WorkflowGraph`] into the static
/// bulk-synchronous plan this coordinator executes (topological phases,
/// each block-distributed with [`block_range`]).  Drive it with a
/// [`crate::workflow::Session`] on the mpi-list backend or a custom
/// SPMD loop.
pub fn from_workflow(
    g: &crate::workflow::WorkflowGraph,
    procs: usize,
) -> anyhow::Result<crate::workflow::lower::MpiListPlan> {
    crate::workflow::lower::to_mpilist(g, procs)
}

/// Block distribution (paper sec. 2.3): rank p of P stores the
/// subsequence starting at `p*floor(N/P) + min(p, N mod P)`.
pub fn block_range(p: usize, procs: usize, n: u64) -> (u64, u64) {
    let p = p as u64;
    let procs = procs as u64;
    let base = n / procs;
    let rem = n % procs;
    let start = p * base + p.min(rem);
    let count = base + if p < rem { 1 } else { 0 };
    (start, count)
}

/// Which rank owns global index `i` under the block distribution.
pub fn block_owner(i: u64, procs: usize, n: u64) -> usize {
    let procs_u = procs as u64;
    let base = n / procs_u;
    let rem = n % procs_u;
    let cut = rem * (base + 1); // first `rem` ranks hold base+1 each
    if base == 0 {
        // fewer elements than ranks: element i lives on rank i
        return i as usize;
    }
    if i < cut {
        (i / (base + 1)) as usize
    } else {
        (rem + (i - cut) / base) as usize
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_range_paper_formula() {
        // N=10, P=3 -> 4,3,3 starting at 0,4,7
        assert_eq!(block_range(0, 3, 10), (0, 4));
        assert_eq!(block_range(1, 3, 10), (4, 3));
        assert_eq!(block_range(2, 3, 10), (7, 3));
        // exact division
        assert_eq!(block_range(1, 4, 8), (2, 2));
        // fewer elements than ranks
        assert_eq!(block_range(0, 4, 2), (0, 1));
        assert_eq!(block_range(1, 4, 2), (1, 1));
        assert_eq!(block_range(2, 4, 2), (2, 0));
    }

    #[test]
    fn ranges_partition_exactly() {
        for (p, n) in [(1usize, 10u64), (3, 10), (4, 2), (7, 100), (5, 5)] {
            let mut next = 0u64;
            for r in 0..p {
                let (start, count) = block_range(r, p, n);
                assert_eq!(start, next, "P={p} N={n} rank={r}");
                next += count;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn owner_matches_range() {
        for (p, n) in [(3usize, 10u64), (4, 2), (7, 100), (1, 5)] {
            for i in 0..n {
                let owner = block_owner(i, p, n);
                let (start, count) = block_range(owner, p, n);
                assert!(
                    (start..start + count).contains(&i),
                    "P={p} N={n} i={i} owner={owner} range=({start},{count})"
                );
            }
        }
    }

    #[test]
    fn iterates_distributes() {
        let out = Context::run(3, |ctx| ctx.iterates(10).into_local());
        assert_eq!(out[0], (0..4).collect::<Vec<u64>>());
        assert_eq!(out[1], (4..7).collect::<Vec<u64>>());
        assert_eq!(out[2], (7..10).collect::<Vec<u64>>());
    }

    #[test]
    fn rank_and_procs() {
        let out = Context::run(4, |ctx| (ctx.rank(), ctx.procs()));
        for (r, (rank, procs)) in out.iter().enumerate() {
            assert_eq!(*rank, r);
            assert_eq!(*procs, 4);
        }
    }
}
