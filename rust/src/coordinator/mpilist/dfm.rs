//! DFM: the distributed free monoid — mpi-list's list type.
//!
//! Stores only the elements local to this rank; the global list is the
//! rank-ordered concatenation.  Local operations (`map`, `flat_map`,
//! `filter`) involve no communication at all; `len`, `reduce`, `scan`,
//! `collect`, `head` are collectives; `repartition` and `group` move data
//! between ranks with the paper's three-function protocol.

use super::{block_owner, block_range, Context};

/// A distributed list: this rank's contiguous slice of the global list.
#[derive(Clone, Debug, PartialEq)]
pub struct DFM<T> {
    local: Vec<T>,
}

impl<T: Send + 'static> DFM<T> {
    pub fn from_local(local: Vec<T>) -> DFM<T> {
        DFM { local }
    }

    pub fn local(&self) -> &[T] {
        &self.local
    }

    pub fn into_local(self) -> Vec<T> {
        self.local
    }

    pub fn local_len(&self) -> usize {
        self.local.len()
    }

    // ---------------------------------------------------------- local ops

    /// Apply `f` to every element (paper: `DFM.map(f)`). No communication.
    pub fn map<U: Send + 'static>(self, f: impl FnMut(T) -> U) -> DFM<U> {
        DFM { local: self.local.into_iter().map(f).collect() }
    }

    /// Map to zero-or-more elements (paper: `DFM.flatMap`).
    pub fn flat_map<U: Send + 'static, I: IntoIterator<Item = U>>(
        self,
        f: impl FnMut(T) -> I,
    ) -> DFM<U> {
        DFM { local: self.local.into_iter().flat_map(f).collect() }
    }

    /// Keep elements satisfying the predicate.
    pub fn filter(self, f: impl FnMut(&T) -> bool) -> DFM<T> {
        DFM { local: self.local.into_iter().filter(f).collect() }
    }

    // --------------------------------------------------------- collectives

    /// Global element count (paper: `DFM.len()`).
    pub fn len(&self, ctx: &mut Context) -> u64 {
        ctx.comm.allreduce(self.local.len() as u64, |a, b| a + b)
    }

    pub fn is_empty(&self, ctx: &mut Context) -> bool {
        self.len(ctx) == 0
    }

    /// Full reduction with `op` over the global list, seeded with `init`
    /// on each rank; the result is broadcast to all ranks (paper:
    /// `DFM.reduce(op, init)` as used in Fig 3's histogram sum).
    pub fn reduce(&self, ctx: &mut Context, init: T, op: impl Fn(T, T) -> T) -> T
    where
        T: Clone,
    {
        let local = self.local.iter().cloned().fold(init, &op);
        ctx.comm.allreduce(local, op)
    }

    /// Parallel exclusive prefix scan: element `i` of the result is the
    /// fold of all global elements before `i` (paper's prefix-scan
    /// reduction).
    pub fn exscan(&self, ctx: &mut Context, init: T, op: impl Fn(T, T) -> T) -> DFM<T>
    where
        T: Clone,
    {
        let local_total = self.local.iter().cloned().fold(init.clone(), &op);
        let carry = ctx.comm.exscan(local_total, init, &op);
        let mut out = Vec::with_capacity(self.local.len());
        let mut acc = carry;
        for x in &self.local {
            out.push(acc.clone());
            acc = op(acc, x.clone());
        }
        DFM { local: out }
    }

    /// Gather the whole list to rank 0, in global order (paper:
    /// `DFM.collect()` as used in Fig 3 for the stats dataframe).
    pub fn collect(self, ctx: &mut Context) -> Option<Vec<T>> {
        ctx.comm
            .gather(0, self.local)
            .map(|parts| parts.into_iter().flatten().collect())
    }

    /// First `n` global elements, delivered to every rank.
    pub fn head(&self, ctx: &mut Context, n: usize) -> Vec<T>
    where
        T: Clone,
    {
        let mine: Vec<T> = self.local.iter().take(n).cloned().collect();
        let gathered = ctx.comm.gather(0, mine);
        let out = gathered.map(|parts| {
            parts.into_iter().flatten().take(n).collect::<Vec<T>>()
        });
        ctx.comm.bcast(0, out)
    }

    // ------------------------------------------------------- data movement

    /// Rebalance so every rank holds a contiguous, near-equal share of the
    /// global list (element granularity).
    pub fn rebalance(self, ctx: &mut Context) -> DFM<T> {
        let p = ctx.procs();
        let my_count = self.local.len() as u64;
        let start = ctx.comm.exscan(my_count, 0u64, |a, b| a + b);
        let total = ctx.comm.allreduce(my_count, |a, b| a + b);
        let mut buckets: Vec<Vec<(u64, T)>> = (0..p).map(|_| Vec::new()).collect();
        for (i, x) in self.local.into_iter().enumerate() {
            let gi = start + i as u64;
            buckets[block_owner(gi, p, total)].push((gi, x));
        }
        let received = ctx.comm.alltoallv(buckets);
        let mut flat: Vec<(u64, T)> = received.into_iter().flatten().collect();
        flat.sort_by_key(|(gi, _)| *gi);
        DFM { local: flat.into_iter().map(|(_, x)| x).collect() }
    }

    /// The paper's `repartition`: each element is a *container* of
    /// records (numpy array / DataFrame in Python; anything here).  Takes
    /// the three-function protocol — `length` reports records per
    /// container, `split` cuts a container into chunks of given sizes,
    /// `combine` fuses chunks — and redistributes so every rank ends up
    /// with one container holding a contiguous, near-equal share of the
    /// global records.
    pub fn repartition(
        self,
        ctx: &mut Context,
        length: impl Fn(&T) -> usize,
        split: impl Fn(T, &[usize]) -> Vec<T>,
        combine: impl Fn(Vec<T>) -> T,
    ) -> DFM<T> {
        let p = ctx.procs();
        let my_records: u64 = self.local.iter().map(|t| length(t) as u64).sum();
        let my_start = ctx.comm.exscan(my_records, 0u64, |a, b| a + b);
        let total = ctx.comm.allreduce(my_records, |a, b| a + b);

        // slice every container into per-destination chunks
        let mut buckets: Vec<Vec<(u64, T)>> = (0..p).map(|_| Vec::new()).collect();
        let mut cursor = my_start;
        for container in self.local {
            let n = length(&container) as u64;
            if n == 0 {
                continue;
            }
            // destination segments of [cursor, cursor+n)
            let mut sizes: Vec<usize> = Vec::new();
            let mut dests: Vec<usize> = Vec::new();
            let mut pos = cursor;
            let end = cursor + n;
            while pos < end {
                let owner = block_owner(pos, p, total);
                let (ostart, ocount) = block_range(owner, p, total);
                let oend = ostart + ocount;
                let take = (end.min(oend) - pos) as usize;
                sizes.push(take);
                dests.push(owner);
                pos += take as u64;
            }
            let chunks = split(container, &sizes);
            assert_eq!(
                chunks.len(),
                sizes.len(),
                "split() must return exactly one chunk per requested size"
            );
            let mut off = cursor;
            for (chunk, (dest, sz)) in chunks.into_iter().zip(dests.iter().zip(&sizes)) {
                buckets[*dest].push((off, chunk));
                off += *sz as u64;
            }
            cursor = end;
        }
        let received = ctx.comm.alltoallv(buckets);
        let mut flat: Vec<(u64, T)> = received.into_iter().flatten().collect();
        flat.sort_by_key(|(gi, _)| *gi);
        let chunks: Vec<T> = flat.into_iter().map(|(_, c)| c).collect();
        let local = if chunks.is_empty() { Vec::new() } else { vec![combine(chunks)] };
        DFM { local }
    }

    /// The paper's `group`: `disperse` turns each element into (destination
    /// list index, item) pairs; items are moved to the rank owning each
    /// index (round-robin ownership) and `combine` is called once per new
    /// index to form the output elements, kept in ascending index order.
    pub fn group<U: Send + 'static, V: Send + 'static>(
        self,
        ctx: &mut Context,
        disperse: impl Fn(T) -> Vec<(u64, U)>,
        combine: impl Fn(u64, Vec<U>) -> V,
    ) -> DFM<V> {
        let p = ctx.procs();
        let mut buckets: Vec<Vec<(u64, U)>> = (0..p).map(|_| Vec::new()).collect();
        for element in self.local {
            for (idx, item) in disperse(element) {
                buckets[(idx % p as u64) as usize].push((idx, item));
            }
        }
        let received = ctx.comm.alltoallv(buckets);
        let mut by_index: std::collections::BTreeMap<u64, Vec<U>> = Default::default();
        for (idx, item) in received.into_iter().flatten() {
            by_index.entry(idx).or_default().push(item);
        }
        DFM { local: by_index.into_iter().map(|(i, items)| combine(i, items)).collect() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_filter_flatmap_local() {
        let out = Context::run(3, |ctx| {
            ctx.iterates(9)
                .map(|x| x * 2)
                .filter(|x| x % 3 != 0)
                .flat_map(|x| vec![x, x + 1])
                .into_local()
        });
        let global: Vec<u64> = out.into_iter().flatten().collect();
        let want: Vec<u64> = (0..9u64)
            .map(|x| x * 2)
            .filter(|x| x % 3 != 0)
            .flat_map(|x| vec![x, x + 1])
            .collect();
        assert_eq!(global, want);
    }

    #[test]
    fn len_and_reduce() {
        let out = Context::run(4, |ctx| {
            let dfm = ctx.iterates(100);
            let len = dfm.len(ctx);
            let sum = dfm.reduce(ctx, 0u64, |a, b| a + b);
            (len, sum)
        });
        for (len, sum) in out {
            assert_eq!(len, 100);
            assert_eq!(sum, 4950);
        }
    }

    #[test]
    fn reduce_on_empty_ranks() {
        // N < P: some ranks hold nothing; reduce must still agree
        let out = Context::run(5, |ctx| ctx.iterates(2).reduce(ctx, 0u64, |a, b| a + b));
        assert_eq!(out, vec![1; 5]);
    }

    #[test]
    fn exscan_prefix() {
        let out = Context::run(3, |ctx| {
            ctx.iterates(7).exscan(ctx, 0u64, |a, b| a + b).into_local()
        });
        let global: Vec<u64> = out.into_iter().flatten().collect();
        // exclusive prefix sums of 0..7
        assert_eq!(global, vec![0, 0, 1, 3, 6, 10, 15]);
    }

    #[test]
    fn collect_in_order() {
        let out = Context::run(4, |ctx| ctx.iterates(11).map(|x| x * x).collect(ctx));
        assert_eq!(
            out[0].as_ref().unwrap(),
            &(0..11u64).map(|x| x * x).collect::<Vec<_>>()
        );
        assert!(out[1..].iter().all(Option::is_none));
    }

    #[test]
    fn head_broadcast() {
        let out = Context::run(3, |ctx| ctx.iterates(10).head(ctx, 4));
        for h in out {
            assert_eq!(h, vec![0, 1, 2, 3]);
        }
    }

    #[test]
    fn rebalance_after_skewed_flatmap() {
        let out = Context::run(3, |ctx| {
            // rank 0's elements explode 5x, others stay single
            let dfm = ctx
                .iterates(6)
                .flat_map(|x| if x < 2 { vec![x; 5] } else { vec![x] });
            let re = dfm.rebalance(ctx);
            re.into_local()
        });
        let counts: Vec<usize> = out.iter().map(Vec::len).collect();
        let total: usize = counts.iter().sum();
        assert_eq!(total, 14); // 2*5 + 4
        assert!(counts.iter().all(|&c| c == 4 || c == 5), "{counts:?}");
        // order preserved globally
        let global: Vec<u64> = out.into_iter().flatten().collect();
        let want: Vec<u64> =
            (0..6u64).flat_map(|x| if x < 2 { vec![x; 5] } else { vec![x] }).collect();
        assert_eq!(global, want);
    }

    #[test]
    fn repartition_vec_containers() {
        // containers of varying record counts -> one balanced container/rank
        let out = Context::run(3, |ctx| {
            let local: Vec<Vec<u64>> = match ctx.rank() {
                0 => vec![(0..8).collect()],                 // 8 records
                1 => vec![vec![8], vec![9, 10]],             // 3 records
                _ => vec![(11..13).collect()],               // 2 records
            };
            let dfm = DFM::from_local(local);
            let re = dfm.repartition(
                ctx,
                |v| v.len(),
                |v, sizes| {
                    let mut out = Vec::new();
                    let mut it = v.into_iter();
                    for &s in sizes {
                        out.push(it.by_ref().take(s).collect::<Vec<u64>>());
                    }
                    out
                },
                |chunks| chunks.into_iter().flatten().collect(),
            );
            re.into_local()
        });
        // 13 records over 3 ranks: 5,4,4
        assert_eq!(out[0], vec![(0..5).collect::<Vec<u64>>()]);
        assert_eq!(out[1], vec![(5..9).collect::<Vec<u64>>()]);
        assert_eq!(out[2], vec![(9..13).collect::<Vec<u64>>()]);
    }

    #[test]
    fn repartition_empty_containers_ok() {
        let out = Context::run(2, |ctx| {
            let local: Vec<Vec<u64>> = if ctx.rank() == 0 {
                vec![vec![], vec![1, 2, 3, 4]]
            } else {
                vec![]
            };
            DFM::from_local(local)
                .repartition(
                    ctx,
                    |v| v.len(),
                    |v, sizes| {
                        let mut out = Vec::new();
                        let mut it = v.into_iter();
                        for &s in sizes {
                            out.push(it.by_ref().take(s).collect::<Vec<u64>>());
                        }
                        out
                    },
                    |chunks| chunks.into_iter().flatten().collect(),
                )
                .into_local()
        });
        assert_eq!(out[0], vec![vec![1, 2]]);
        assert_eq!(out[1], vec![vec![3, 4]]);
    }

    #[test]
    fn group_by_key() {
        // histogram-style: route each value to index (value % 4), combine
        // counts the items per destination index
        let out = Context::run(3, |ctx| {
            ctx.iterates(20)
                .group(
                    ctx,
                    |x| vec![(x % 4, x)],
                    |idx, items| (idx, items.len()),
                )
                .into_local()
        });
        let global: Vec<(u64, usize)> = out.into_iter().flatten().collect();
        // indices 0..4, each receiving 5 of the 20 values
        let mut sorted = global.clone();
        sorted.sort();
        assert_eq!(sorted, vec![(0, 5), (1, 5), (2, 5), (3, 5)]);
    }

    #[test]
    fn group_ownership_round_robin() {
        let out = Context::run(2, |ctx| {
            ctx.iterates(8)
                .group(ctx, |x| vec![(x, x)], |idx, _| idx)
                .into_local()
        });
        // rank 0 owns even indices, rank 1 odd, each ascending
        assert_eq!(out[0], vec![0, 2, 4, 6]);
        assert_eq!(out[1], vec![1, 3, 5, 7]);
    }

    #[test]
    fn single_rank_degenerate() {
        let out = Context::run(1, |ctx| {
            let dfm = ctx.iterates(5).map(|x| x + 1);
            let sum = dfm.reduce(ctx, 0, |a, b| a + b);
            let all = dfm.collect(ctx).unwrap();
            (sum, all)
        });
        assert_eq!(out[0].0, 15);
        assert_eq!(out[0].1, vec![1, 2, 3, 4, 5]);
    }
}
