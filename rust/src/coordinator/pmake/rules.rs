//! pmake input files: `rules.yaml` + `targets.yaml` (paper Fig 1).
//!
//! A rule has a resource set, named input/output file templates, a setup
//! script, and a job script; a target names a working directory, the
//! top-level files to build, and an optional loop directive that stamps
//! out a file per iteration value.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::substrate::cluster::ResourceSet;
use crate::substrate::yaml::{self, Yaml};

use super::subst;

/// One rule from rules.yaml.
#[derive(Clone, Debug)]
pub struct Rule {
    pub name: String,
    pub resources: ResourceSet,
    /// named input templates ("param" -> "{n}.param")
    pub inputs: BTreeMap<String, String>,
    /// loop-generated inputs: (var, iterable-spec, template)
    pub input_loops: Vec<(String, String, String)>,
    /// named output templates ("trj" -> "{n}.trj")
    pub outputs: BTreeMap<String, String>,
    pub setup: String,
    pub script: String,
}

/// One target from targets.yaml.
#[derive(Clone, Debug)]
pub struct Target {
    pub name: String,
    pub dirname: String,
    /// plain top-level files to build
    pub out: BTreeMap<String, String>,
    /// loop directive: (var, iterable-spec)
    pub loop_var: Option<(String, String)>,
    /// per-iteration file templates (rendered once per loop value)
    pub tgt: BTreeMap<String, String>,
    /// every other member: substitution variables available to rules
    pub vars: BTreeMap<String, String>,
}

impl Target {
    /// Expand the target to the concrete list of files to build
    /// (paths relative to `dirname`).
    pub fn requested_files(&self) -> Result<Vec<String>> {
        let mut files: Vec<String> = Vec::new();
        let mut base = subst::Ctx::new();
        for (k, v) in &self.vars {
            base.set(k.clone(), v.clone());
        }
        for tpl in self.out.values() {
            files.push(subst::render(tpl, &base).with_context(|| format!("target {}", self.name))?);
        }
        if let Some((var, spec)) = &self.loop_var {
            for value in subst::parse_iterable(spec)? {
                let mut ctx = base.clone();
                ctx.set(var.clone(), value);
                for tpl in self.tgt.values() {
                    files.push(
                        subst::render(tpl, &ctx).with_context(|| format!("target {}", self.name))?,
                    );
                }
            }
        } else if !self.tgt.is_empty() {
            bail!("target {} has tgt: but no loop:", self.name);
        }
        Ok(files)
    }
}

fn yaml_string_map(y: &Yaml, what: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let Some(m) = y.as_map() else {
        bail!("{what} must be a mapping")
    };
    for (k, v) in m {
        let t = v
            .as_text()
            .ok_or_else(|| anyhow!("{what}.{k} must be a scalar"))?;
        out.insert(k.clone(), t);
    }
    Ok(out)
}

fn parse_resources(y: Option<&Yaml>) -> Result<ResourceSet> {
    let mut rs = ResourceSet::default();
    let Some(y) = y else { return Ok(rs) };
    let Some(m) = y.as_map() else {
        bail!("resources must be a mapping like {{time: 10, nrs: 1, cpu: 1}}")
    };
    for (k, v) in m {
        let num = v
            .as_f64()
            .ok_or_else(|| anyhow!("resources.{k} must be numeric"))?;
        match k.as_str() {
            "time" => rs.time_min = num,
            "nrs" => rs.nrs = num as usize,
            "cpu" => rs.cpu = num as usize,
            "gpu" => rs.gpu = num as usize,
            "ranks" => rs.ranks_per_rs = (num as usize).max(1),
            other => bail!("unknown resource key {other:?}"),
        }
    }
    Ok(rs)
}

/// Parse rules.yaml text.  Rule order is preserved (search order).
pub fn parse_rules(src: &str) -> Result<Vec<Rule>> {
    let doc = yaml::parse(src)?;
    let Some(entries) = doc.as_map() else {
        bail!("rules.yaml must be a mapping of rule names")
    };
    let mut rules = Vec::new();
    for (name, body) in entries {
        let mut inputs = BTreeMap::new();
        let mut input_loops = Vec::new();
        if let Some(inp) = body.get("inp") {
            let Some(m) = inp.as_map() else {
                bail!("rule {name}: inp must be a mapping")
            };
            for (k, v) in m {
                if k == "loop" {
                    // loop: {var: n, over: "range(0,4)", tpl: "part_{n}.dat"}
                    let var = v
                        .get("var")
                        .and_then(Yaml::as_str)
                        .ok_or_else(|| anyhow!("rule {name}: inp.loop needs var"))?;
                    let over = v
                        .get("over")
                        .and_then(|y| y.as_text())
                        .ok_or_else(|| anyhow!("rule {name}: inp.loop needs over"))?;
                    let tpl = v
                        .get("tpl")
                        .and_then(Yaml::as_str)
                        .ok_or_else(|| anyhow!("rule {name}: inp.loop needs tpl"))?;
                    input_loops.push((var.to_string(), over, tpl.to_string()));
                } else {
                    let t = v
                        .as_text()
                        .ok_or_else(|| anyhow!("rule {name}: inp.{k} must be a scalar"))?;
                    inputs.insert(k.clone(), t);
                }
            }
        }
        let outputs = match body.get("out") {
            Some(o) => yaml_string_map(o, &format!("rule {name}: out"))?,
            None => bail!("rule {name} has no out section (rules are file-directed)"),
        };
        if outputs.is_empty() {
            bail!("rule {name}: out section is empty");
        }
        // at most one distinct template variable across outputs (paper:
        // "one variable is allowed ... defined by matching on names in
        // the out section")
        let mut out_vars: Vec<String> = Vec::new();
        for tpl in outputs.values() {
            if let Some(v) = template_single_var(tpl)? {
                if !out_vars.contains(&v) {
                    out_vars.push(v);
                }
            }
        }
        if out_vars.len() > 1 {
            bail!("rule {name}: outputs use more than one variable: {out_vars:?}");
        }
        rules.push(Rule {
            name: name.clone(),
            resources: parse_resources(body.get("resources"))?,
            inputs,
            input_loops,
            outputs,
            setup: body
                .get("setup")
                .and_then(|y| y.as_text())
                .unwrap_or_default(),
            script: body
                .get("script")
                .and_then(|y| y.as_text())
                .ok_or_else(|| anyhow!("rule {name} has no script"))?,
        });
    }
    Ok(rules)
}

/// The single template variable used in a template, if any.
/// (Indexed refs like {inp[x]} and {mpirun} don't count: they are not
/// matchable output variables.)
fn template_single_var(tpl: &str) -> Result<Option<String>> {
    // cheap scan: find {ident} chunks
    let mut var = None;
    let mut rest = tpl;
    while let Some(i) = rest.find('{') {
        if rest[i + 1..].starts_with('{') {
            rest = &rest[i + 2..];
            continue;
        }
        let Some(j) = rest[i..].find('}') else {
            bail!("unclosed brace in template {tpl:?}")
        };
        let body = &rest[i + 1..i + j];
        if !body.contains('[') && body != "mpirun" {
            match &var {
                None => var = Some(body.to_string()),
                Some(v) if v == body => {}
                Some(v) => bail!("template {tpl:?} mixes variables {v:?} and {body:?}"),
            }
        }
        rest = &rest[i + j + 1..];
    }
    Ok(var)
}

/// Parse targets.yaml text.
pub fn parse_targets(src: &str) -> Result<Vec<Target>> {
    let doc = yaml::parse(src)?;
    let Some(entries) = doc.as_map() else {
        bail!("targets.yaml must be a mapping of target names")
    };
    let mut targets = Vec::new();
    for (name, body) in entries {
        let mut out = BTreeMap::new();
        let mut tgt = BTreeMap::new();
        let mut loop_var = None;
        let mut vars = BTreeMap::new();
        let Some(members) = body.as_map() else {
            bail!("target {name} must be a mapping")
        };
        let mut dirname = String::from(".");
        for (k, v) in members {
            match k.as_str() {
                "dirname" => {
                    dirname = v
                        .as_text()
                        .ok_or_else(|| anyhow!("target {name}: dirname must be a string"))?
                }
                "out" => out = yaml_string_map(v, &format!("target {name}: out"))?,
                "tgt" => tgt = yaml_string_map(v, &format!("target {name}: tgt"))?,
                "loop" => {
                    let Some(m) = v.as_map() else {
                        bail!("target {name}: loop must be a mapping")
                    };
                    if m.len() != 1 {
                        bail!("target {name}: loop must have exactly one variable");
                    }
                    let (var, spec) = &m[0];
                    loop_var = Some((
                        var.clone(),
                        spec.as_text()
                            .ok_or_else(|| anyhow!("target {name}: loop.{var} must be a scalar"))?,
                    ));
                }
                _ => {
                    if let Some(t) = v.as_text() {
                        vars.insert(k.clone(), t);
                    }
                }
            }
        }
        targets.push(Target { name: name.clone(), dirname, out, loop_var, tgt, vars });
    }
    Ok(targets)
}

pub fn parse_rules_file(path: &std::path::Path) -> Result<Vec<Rule>> {
    parse_rules(&std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?)
}

pub fn parse_targets_file(path: &std::path::Path) -> Result<Vec<Target>> {
    parse_targets(&std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?)
}

#[cfg(test)]
mod tests {
    use super::*;

    const FIG1_RULES: &str = r#"
simulate:
  resources: {time: 120, nrs: 10, cpu: 42, gpu: 6}
  inp:
    param: "{n}.param"
  out:
    trj: "{n}.trj"
  setup: module load cuda
  script: |
    {mpirun} simulate {inp[param]} {out[trj]}
analyze:
  resources: {time: 10, nrs: 1, cpu: 1}
  inp:
    trj: "{n}.trj"
  out:
    npy: "an_{n}.npy"
  setup: module load Python/3
  script: |
    {mpirun} python compute_averages.py {inp[trj]} {out[npy]}
"#;

    const FIG1_TARGETS: &str = r#"
sim1:
  dirname: System1
  out:
    npy: "an_0.npy"
  loop:
    n: "range(1,11)"
  tgt:
    npy: "an_{n}.npy"
"#;

    #[test]
    fn parse_fig1_rules() {
        let rules = parse_rules(FIG1_RULES).unwrap();
        assert_eq!(rules.len(), 2);
        let sim = &rules[0];
        assert_eq!(sim.name, "simulate");
        assert_eq!(sim.resources.nrs, 10);
        assert_eq!(sim.resources.gpu, 6);
        assert!((sim.resources.time_min - 120.0).abs() < 1e-12);
        assert_eq!(sim.inputs["param"], "{n}.param");
        assert_eq!(sim.outputs["trj"], "{n}.trj");
        assert_eq!(sim.setup, "module load cuda");
        assert!(sim.script.contains("{mpirun} simulate"));
        let ana = &rules[1];
        assert_eq!(ana.outputs["npy"], "an_{n}.npy");
    }

    #[test]
    fn parse_fig1_targets() {
        let targets = parse_targets(FIG1_TARGETS).unwrap();
        assert_eq!(targets.len(), 1);
        let t = &targets[0];
        assert_eq!(t.dirname, "System1");
        assert_eq!(t.out["npy"], "an_0.npy");
        let files = t.requested_files().unwrap();
        assert_eq!(files.len(), 11); // an_0 + an_1..an_10
        assert!(files.contains(&"an_0.npy".to_string()));
        assert!(files.contains(&"an_10.npy".to_string()));
    }

    #[test]
    fn rule_without_out_rejected() {
        assert!(parse_rules("r:\n  script: echo\n").is_err());
    }

    #[test]
    fn rule_without_script_rejected() {
        assert!(parse_rules("r:\n  out:\n    f: x.txt\n").is_err());
    }

    #[test]
    fn rule_with_two_out_vars_rejected() {
        let src = "r:\n  out:\n    a: \"{x}.a\"\n    b: \"{y}.b\"\n  script: echo\n";
        assert!(parse_rules(src).is_err());
    }

    #[test]
    fn rule_same_var_in_two_outputs_ok() {
        let src = "r:\n  out:\n    a: \"{x}.a\"\n    b: \"{x}.b\"\n  script: echo\n";
        let rules = parse_rules(src).unwrap();
        assert_eq!(rules[0].outputs.len(), 2);
    }

    #[test]
    fn input_loop_directive() {
        let src = r#"
gather:
  inp:
    loop:
      var: i
      over: "range(0,3)"
      tpl: "part_{i}.dat"
  out:
    all: "combined.dat"
  script: cat part_*.dat > combined.dat
"#;
        let rules = parse_rules(src).unwrap();
        assert_eq!(rules[0].input_loops.len(), 1);
        let (var, over, tpl) = &rules[0].input_loops[0];
        assert_eq!(var, "i");
        assert_eq!(over, "range(0,3)");
        assert_eq!(tpl, "part_{i}.dat");
    }

    #[test]
    fn target_vars_available() {
        let src = "t:\n  dirname: D\n  temperature: 300\n  out:\n    f: \"res_{temperature}.txt\"\n";
        let targets = parse_targets(src).unwrap();
        assert_eq!(targets[0].vars["temperature"], "300");
        assert_eq!(targets[0].requested_files().unwrap(), vec!["res_300.txt"]);
    }

    #[test]
    fn target_default_dirname() {
        let src = "t:\n  out:\n    f: a.txt\n";
        assert_eq!(parse_targets(src).unwrap()[0].dirname, ".");
    }

    #[test]
    fn tgt_without_loop_rejected() {
        let src = "t:\n  tgt:\n    f: \"a_{n}.txt\"\n";
        let targets = parse_targets(src).unwrap();
        assert!(targets[0].requested_files().is_err());
    }

    #[test]
    fn resources_default_and_ranks() {
        let src = "r:\n  resources: {time: 5, nrs: 2, cpu: 4, gpu: 1, ranks: 3}\n  out:\n    f: x\n  script: echo\n";
        let rules = parse_rules(src).unwrap();
        assert_eq!(rules[0].resources.ranks_per_rs, 3);
        assert_eq!(rules[0].resources.total_ranks(), 6);
        let src2 = "r:\n  out:\n    f: x\n  script: echo\n";
        let rules2 = parse_rules(src2).unwrap();
        assert_eq!(rules2[0].resources.nrs, 1); // defaults
    }

    #[test]
    fn unknown_resource_key_rejected() {
        let src = "r:\n  resources: {walltime: 5}\n  out:\n    f: x\n  script: echo\n";
        assert!(parse_rules(src).is_err());
    }
}
