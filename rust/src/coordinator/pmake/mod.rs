//! pmake: file-based parallel make (paper sec. 2.1).
//!
//! Every task corresponds to one or more output files; presence of the
//! file is the synchronization mechanism.  A single managing process
//! parses `rules.yaml` + `targets.yaml`, constructs the task graph from
//! file presence, assigns node-hours-based earliest-finish priorities,
//! and pushes job scripts onto the allocation until the nodes run out.

pub mod dag;
pub mod exec;
pub mod rules;
pub mod sched;
pub mod subst;

pub use dag::{Dag, TaskInstance};
pub use exec::{Executor, LaunchReport, ShellExecutor};
pub use rules::{parse_rules, parse_rules_file, parse_targets, parse_targets_file, Rule, Target};
pub use sched::{run, run_traced, RunReport, SchedConfig};

use anyhow::Result;
use std::path::Path;

use crate::substrate::cluster::ResourceSet;

/// Default `{mpirun}` expansion: our stand-in for srun/jsrun selection.
/// On a real Slurm/LSF system this would emit `srun -n ...`/`jsrun -n ...`;
/// here tasks run locally, so it expands to the empty prefix (commands run
/// directly), keeping scripts identical in shape to the paper's.
pub fn default_mpirun(rs: &ResourceSet) -> String {
    let _ = rs;
    String::new()
}

/// Workflow-IR ingestion: lower a [`WorkflowGraph`] to pmake rule/target
/// documents rooted at `dirname` and parse them back into typed rules +
/// targets.  Going through the text form keeps the invariant that every
/// ingested workflow is also expressible as standalone `rules.yaml` /
/// `targets.yaml` files a user could run by hand.
pub fn from_workflow(
    g: &crate::workflow::WorkflowGraph,
    dirname: &str,
) -> Result<(Vec<Rule>, Vec<Target>)> {
    let lowered = crate::workflow::lower::to_pmake(g, dirname)?;
    Ok((parse_rules(&lowered.rules_yaml)?, parse_targets(&lowered.targets_yaml)?))
}

/// End-to-end convenience: parse rule/target files, build DAGs (one per
/// target), and run them on the executor.
pub fn make(
    rules_path: &Path,
    targets_path: &Path,
    exec: &dyn Executor,
    cfg: &SchedConfig,
) -> Result<Vec<RunReport>> {
    let rules = parse_rules_file(rules_path)?;
    let targets = parse_targets_file(targets_path)?;
    let mut reports = Vec::new();
    for target in &targets {
        let dag = Dag::build(&rules, target, &|p: &Path| p.exists(), &|rs| default_mpirun(rs))?;
        reports.push(run(&dag, exec, cfg)?);
    }
    Ok(reports)
}
