//! pmake job-script execution: the popen-equivalent.
//!
//! For each launched task pmake concatenates `set -e`, a `cd` into the
//! target's dirname, the rule's setup script and job script, writes the
//! result to `<stem>.sh`, executes it with /bin/sh, and stores combined
//! stdout/stderr in `<stem>.log` (paper sec. 2.1).  Exit status 0 means
//! the task's outputs must now exist; a zero exit with missing outputs is
//! reported as a failure (the file *is* the synchronization mechanism).

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{Context, Result};

use super::dag::TaskInstance;

/// Where a task's launch time went — pmake's METG components.
#[derive(Clone, Copy, Debug, Default)]
pub struct LaunchReport {
    pub success: bool,
    /// time to set up + spawn the job step ("jsrun" cost)
    pub launch_s: f64,
    /// script wall time
    pub run_s: f64,
}

/// Task launcher abstraction: the scheduler drives this; production uses
/// [`ShellExecutor`], tests/benches may use a virtual executor.
pub trait Executor: Sync {
    fn launch(&self, task: &TaskInstance) -> LaunchReport;
}

/// Runs tasks as real /bin/sh subprocesses.
pub struct ShellExecutor {
    /// prepend to every launch, e.g. simulated jsrun startup (seconds)
    pub launch_overhead_s: f64,
    /// verify declared outputs exist after a zero exit
    pub check_outputs: bool,
    /// where scripts + logs go (usually the target's dirname)
    pub script_dir: Option<PathBuf>,
}

impl Default for ShellExecutor {
    fn default() -> Self {
        ShellExecutor { launch_overhead_s: 0.0, check_outputs: true, script_dir: None }
    }
}

impl ShellExecutor {
    /// Compose the shell script text for a task (paper: `set -e` + cd +
    /// setup + script).
    pub fn script_text(task: &TaskInstance) -> String {
        let mut s = String::from("set -e\n");
        s.push_str(&format!("cd {}\n", shell_quote(&task.dir.to_string_lossy())));
        if !task.setup.trim().is_empty() {
            s.push_str(task.setup.trim_end());
            s.push('\n');
        }
        s.push_str(task.script.trim_end());
        s.push('\n');
        s
    }

    fn run(&self, task: &TaskInstance) -> Result<LaunchReport> {
        let t_launch = Instant::now();
        if self.launch_overhead_s > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(self.launch_overhead_s));
        }
        let dir = self.script_dir.clone().unwrap_or_else(|| task.dir.clone());
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
        let stem = task.stem();
        let script_path = dir.join(format!("{stem}.sh"));
        let log_path = dir.join(format!("{stem}.log"));
        std::fs::write(&script_path, Self::script_text(task))
            .with_context(|| format!("writing {script_path:?}"))?;
        let log = std::fs::File::create(&log_path)
            .with_context(|| format!("creating {log_path:?}"))?;
        let log2 = log.try_clone()?;
        let mut child = std::process::Command::new("/bin/sh")
            .arg(&script_path)
            .stdout(log)
            .stderr(log2)
            .stdin(std::process::Stdio::null())
            .spawn()
            .with_context(|| format!("spawning /bin/sh {script_path:?}"))?;
        let launch_s = t_launch.elapsed().as_secs_f64();
        let t_run = Instant::now();
        let status = child.wait().context("waiting for job script")?;
        let run_s = t_run.elapsed().as_secs_f64();
        let mut success = status.success();
        if success && self.check_outputs {
            for out in task.outputs.values() {
                if !task.dir.join(out).exists() {
                    success = false; // exited 0 but lied about its outputs
                    break;
                }
            }
        }
        Ok(LaunchReport { success, launch_s, run_s })
    }
}

impl Executor for ShellExecutor {
    fn launch(&self, task: &TaskInstance) -> LaunchReport {
        match self.run(task) {
            Ok(r) => r,
            Err(_) => LaunchReport { success: false, ..Default::default() },
        }
    }
}

fn shell_quote(s: &str) -> String {
    if s.chars().all(|c| c.is_ascii_alphanumeric() || "_-./".contains(c)) {
        s.to_string()
    } else {
        format!("'{}'", s.replace('\'', r"'\''"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::BTreeMap;
    use std::path::Path;

    fn tmp(name: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("threesched-exec-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn task(dir: &Path, script: &str, outputs: &[(&str, &str)]) -> TaskInstance {
        TaskInstance {
            id: 0,
            rule: "r".into(),
            binding: Some(("n".into(), "1".into())),
            dir: dir.to_path_buf(),
            inputs: vec![],
            outputs: outputs
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect::<BTreeMap<_, _>>(),
            setup: String::new(),
            script: script.to_string(),
            resources: Default::default(),
            deps: vec![],
            priority: 0.0,
        }
    }

    #[test]
    fn runs_and_logs() {
        let dir = tmp("runs");
        let t = task(&dir, "echo hello-from-task\ntouch out.txt", &[("f", "out.txt")]);
        let r = ShellExecutor::default().launch(&t);
        assert!(r.success);
        assert!(dir.join("out.txt").exists());
        assert!(dir.join("r.1.sh").exists());
        let log = std::fs::read_to_string(dir.join("r.1.log")).unwrap();
        assert!(log.contains("hello-from-task"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn nonzero_exit_fails() {
        let dir = tmp("fail");
        let t = task(&dir, "exit 3", &[]);
        assert!(!ShellExecutor::default().launch(&t).success);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn set_e_aborts_on_first_error() {
        let dir = tmp("sete");
        let t = task(&dir, "false\ntouch should-not-exist.txt", &[]);
        let r = ShellExecutor::default().launch(&t);
        assert!(!r.success);
        assert!(!dir.join("should-not-exist.txt").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_declared_output_fails() {
        let dir = tmp("liar");
        let t = task(&dir, "echo did nothing", &[("f", "promised.txt")]);
        assert!(!ShellExecutor::default().launch(&t).success);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn setup_runs_before_script() {
        let dir = tmp("setup");
        let mut t = task(&dir, "cat from-setup.txt > out.txt", &[("f", "out.txt")]);
        t.setup = "echo prepared > from-setup.txt".into();
        let r = ShellExecutor::default().launch(&t);
        assert!(r.success);
        assert_eq!(
            std::fs::read_to_string(dir.join("out.txt")).unwrap().trim(),
            "prepared"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn launch_overhead_injected() {
        let dir = tmp("overhead");
        let t = task(&dir, "touch o.txt", &[("f", "o.txt")]);
        let ex = ShellExecutor { launch_overhead_s: 0.05, ..Default::default() };
        let r = ex.launch(&t);
        assert!(r.success);
        assert!(r.launch_s >= 0.05, "launch_s={}", r.launch_s);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn quoting() {
        assert_eq!(shell_quote("plain/path.txt"), "plain/path.txt");
        assert_eq!(shell_quote("has space"), "'has space'");
        assert_eq!(shell_quote("it's"), r"'it'\''s'");
    }
}
