//! pmake scheduler: push tasks onto the allocation, highest priority
//! first, until the nodes run out (paper sec. 2.1).
//!
//! Greedy loop: among tasks whose dependencies are satisfied, launch the
//! highest-priority one that fits the free nodes; when a running script
//! exits 0, its nodes free up and waiting rules trigger.  A failed task
//! poisons its transitive dependents but the rest of the campaign
//! continues (make -k semantics — the paper's production pipelines keep
//! going and report at the end).

use std::collections::HashSet;
use std::sync::mpsc;

use anyhow::{bail, Result};

use crate::substrate::cluster::Machine;
use crate::trace::{EventKind, Tracer};

use super::dag::{Dag, TaskInstance};
use super::exec::{Executor, LaunchReport};

/// Outcome of a campaign run.
#[derive(Clone, Debug, Default)]
pub struct RunReport {
    pub succeeded: Vec<usize>,
    pub failed: Vec<usize>,
    /// tasks skipped because a transitive dependency failed
    pub poisoned: Vec<usize>,
    /// wall time of the whole campaign
    pub makespan_s: f64,
    /// summed per-task launch overhead (the jsrun+alloc METG component)
    pub total_launch_s: f64,
    /// summed script run time
    pub total_run_s: f64,
    /// launch order (task ids), for policy inspection
    pub launch_order: Vec<usize>,
}

impl RunReport {
    pub fn all_ok(&self) -> bool {
        self.failed.is_empty() && self.poisoned.is_empty()
    }
}

/// Scheduler configuration.
#[derive(Clone, Debug)]
pub struct SchedConfig {
    /// nodes in the allocation
    pub nodes: usize,
    /// machine model used for node arithmetic
    pub machine: Machine,
    /// launch FIFO instead of by priority (ablation knob)
    pub fifo: bool,
}

impl Default for SchedConfig {
    fn default() -> Self {
        SchedConfig { nodes: 1, machine: Machine::summit(1), fifo: false }
    }
}

/// Run the DAG to completion on the executor.
pub fn run(dag: &Dag, exec: &dyn Executor, cfg: &SchedConfig) -> Result<RunReport> {
    run_traced(dag, exec, cfg, &Tracer::default())
}

/// [`run`] with a lifecycle tracer.  Task identity in the trace is the
/// instance stem (rule + binding).  `Started` is reconstructed from the
/// launch report's run time (the executor runs in its own thread), so
/// the queue-wait / launch / compute split matches Fig 5's components.
pub fn run_traced(
    dag: &Dag,
    exec: &dyn Executor,
    cfg: &SchedConfig,
    tracer: &Tracer,
) -> Result<RunReport> {
    // static feasibility check: every task must fit the allocation
    for t in &dag.tasks {
        let need = t.resources.nodes_needed(&cfg.machine);
        if need > cfg.nodes {
            bail!(
                "task {} needs {} nodes but the allocation has {}",
                t.stem(),
                need,
                cfg.nodes
            );
        }
    }
    let t_start = std::time::Instant::now();
    let n = dag.tasks.len();
    for t in &dag.tasks {
        tracer.record(&t.stem(), EventKind::Created, "");
    }
    let mut ready_traced = vec![false; n];
    let mut launched_at = vec![0f64; n];
    let mut report = RunReport::default();
    let mut done: HashSet<usize> = HashSet::new();
    let mut failed: HashSet<usize> = HashSet::new();
    let mut launched: HashSet<usize> = HashSet::new();
    let mut free_nodes = cfg.nodes;
    let (done_tx, done_rx) = mpsc::channel::<(usize, LaunchReport)>();

    std::thread::scope(|scope| -> Result<()> {
        let mut running = 0usize;
        loop {
            // poison pass: tasks with a failed dependency can never run
            for t in &dag.tasks {
                if !launched.contains(&t.id)
                    && !report.poisoned.contains(&t.id)
                    && t.deps.iter().any(|d| failed.contains(d) || report.poisoned.contains(d))
                {
                    // abandoned without an attempt: terminal Failed with
                    // no Launched marks it skipped in trace accounting
                    tracer.record(&t.stem(), EventKind::Failed, "");
                    report.poisoned.push(t.id);
                    launched.insert(t.id); // never launch
                }
            }
            // ready pass: deps done, not yet launched/poisoned
            if tracer.enabled() {
                for t in &dag.tasks {
                    if !ready_traced[t.id]
                        && !launched.contains(&t.id)
                        && t.deps.iter().all(|d| done.contains(d))
                    {
                        ready_traced[t.id] = true;
                        tracer.record(&t.stem(), EventKind::Ready, "");
                    }
                }
            }
            // launch pass: runnable = deps done, not launched, fits nodes
            loop {
                let mut best: Option<&TaskInstance> = None;
                for t in &dag.tasks {
                    if launched.contains(&t.id) || !t.deps.iter().all(|d| done.contains(d)) {
                        continue;
                    }
                    if t.resources.nodes_needed(&cfg.machine) > free_nodes {
                        continue;
                    }
                    let better = match best {
                        None => true,
                        Some(b) => {
                            if cfg.fifo {
                                t.id < b.id
                            } else {
                                t.priority > b.priority
                                    || (t.priority == b.priority && t.id < b.id)
                            }
                        }
                    };
                    if better {
                        best = Some(t);
                    }
                }
                let Some(task) = best else { break };
                launched.insert(task.id);
                launched_at[task.id] = tracer.now();
                tracer.record(&task.stem(), EventKind::Launched, "pmake");
                report.launch_order.push(task.id);
                free_nodes -= task.resources.nodes_needed(&cfg.machine);
                running += 1;
                let tx = done_tx.clone();
                scope.spawn(move || {
                    let r = exec.launch(task);
                    let _ = tx.send((task.id, r));
                });
            }
            if running == 0 {
                break;
            }
            // wait for one completion
            let (id, r) = done_rx.recv().expect("running task vanished");
            running -= 1;
            if tracer.enabled() {
                let t_done = tracer.now();
                // the script ran for r.run_s ending ~now; clamp to the
                // launch time so per-task order survives timer jitter
                let started = (t_done - r.run_s).max(launched_at[id]);
                let stem = dag.tasks[id].stem();
                tracer.record_at(started, &stem, EventKind::Started, "pmake");
                tracer.record_at(
                    t_done,
                    &stem,
                    if r.success { EventKind::Finished } else { EventKind::Failed },
                    "pmake",
                );
            }
            free_nodes += dag.tasks[id].resources.nodes_needed(&cfg.machine);
            report.total_launch_s += r.launch_s;
            report.total_run_s += r.run_s;
            if r.success {
                done.insert(id);
                report.succeeded.push(id);
            } else {
                failed.insert(id);
                report.failed.push(id);
            }
            if done.len() + failed.len() + report.poisoned.len() == n {
                // everything resolved; drain any stragglers next loop
            }
        }
        Ok(())
    })?;
    report.makespan_s = t_start.elapsed().as_secs_f64();
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pmake::dag::Dag;
    use crate::coordinator::pmake::rules::{parse_rules, parse_targets};
    use std::path::Path;
    use std::sync::Mutex;

    /// Virtual executor: records launch order, simulates file creation.
    struct VirtualExec {
        fail: HashSet<usize>,
        order: Mutex<Vec<usize>>,
    }

    impl VirtualExec {
        fn new() -> Self {
            VirtualExec { fail: HashSet::new(), order: Mutex::new(vec![]) }
        }

        fn failing(ids: &[usize]) -> Self {
            VirtualExec { fail: ids.iter().copied().collect(), order: Mutex::new(vec![]) }
        }
    }

    impl Executor for VirtualExec {
        fn launch(&self, task: &TaskInstance) -> LaunchReport {
            self.order.lock().unwrap().push(task.id);
            LaunchReport {
                success: !self.fail.contains(&task.id),
                launch_s: 0.001,
                run_s: 0.001,
            }
        }
    }

    fn chain_dag() -> Dag {
        // a -> b -> c (each 1 node)
        let rules = parse_rules(
            r#"
a:
  out:
    f: "a.out"
  script: one
b:
  inp:
    f: "a.out"
  out:
    f: "b.out"
  script: two
c:
  inp:
    f: "b.out"
  out:
    f: "c.out"
  script: three
"#,
        )
        .unwrap();
        let targets = parse_targets("t:\n  out:\n    f: c.out\n").unwrap();
        Dag::build(&rules, &targets[0], &|_: &Path| false, &|_| String::new()).unwrap()
    }

    #[test]
    fn chain_runs_in_dep_order() {
        let dag = chain_dag();
        let ex = VirtualExec::new();
        let cfg = SchedConfig { nodes: 4, ..Default::default() };
        let r = run(&dag, &ex, &cfg).unwrap();
        assert!(r.all_ok());
        assert_eq!(r.succeeded.len(), 3);
        let order = ex.order.lock().unwrap().clone();
        let a = dag.producer("a.out").unwrap();
        let b = dag.producer("b.out").unwrap();
        let c = dag.producer("c.out").unwrap();
        assert_eq!(order, vec![a, b, c]);
    }

    #[test]
    fn failure_poisons_dependents() {
        let dag = chain_dag();
        let a = dag.producer("a.out").unwrap();
        let ex = VirtualExec::failing(&[a]);
        let cfg = SchedConfig { nodes: 4, ..Default::default() };
        let r = run(&dag, &ex, &cfg).unwrap();
        assert_eq!(r.failed, vec![a]);
        assert_eq!(r.poisoned.len(), 2);
        assert!(r.succeeded.is_empty());
        assert!(!r.all_ok());
    }

    fn fan_dag(n: usize) -> Dag {
        // n independent single-node tasks with different priorities via a
        // heavy dependent on task 0's output
        let mut rules = String::new();
        for i in 0..n {
            rules.push_str(&format!("r{i}:\n  out:\n    f: \"{i}.out\"\n  script: echo\n"));
        }
        rules.push_str(
            "heavy:\n  resources: {time: 600, nrs: 1, cpu: 42}\n  inp:\n    f: \"0.out\"\n  out:\n    f: h.out\n  script: echo\n",
        );
        let mut tgts = String::from("t:\n  out:\n    h: h.out\n");
        for i in 1..n {
            tgts.push_str(&format!("    f{i}: \"{i}.out\"\n"));
        }
        let rules = parse_rules(&rules).unwrap();
        let targets = parse_targets(&tgts).unwrap();
        Dag::build(&rules, &targets[0], &|_: &Path| false, &|_| String::new()).unwrap()
    }

    #[test]
    fn priority_launches_critical_path_first() {
        let dag = fan_dag(4);
        // task producing 0.out has the heavy dependent: highest priority
        let ex = VirtualExec::new();
        let cfg = SchedConfig { nodes: 1, ..Default::default() }; // serialize
        let r = run(&dag, &ex, &cfg).unwrap();
        assert!(r.all_ok());
        let first = ex.order.lock().unwrap()[0];
        assert_eq!(first, dag.producer("0.out").unwrap());
    }

    #[test]
    fn fifo_ablation_launches_in_id_order() {
        let dag = fan_dag(4);
        let ex = VirtualExec::new();
        let cfg = SchedConfig { nodes: 1, fifo: true, ..Default::default() };
        run(&dag, &ex, &cfg).unwrap();
        let order = ex.order.lock().unwrap().clone();
        let mut runnable_first: Vec<usize> =
            dag.tasks.iter().filter(|t| t.deps.is_empty()).map(|t| t.id).collect();
        runnable_first.sort_unstable();
        assert_eq!(order[0], runnable_first[0]);
    }

    #[test]
    fn capacity_limits_parallelism() {
        // with 2 nodes and 4 single-node tasks the launch order interleaves
        // but everything completes
        let dag = fan_dag(4);
        let ex = VirtualExec::new();
        let cfg = SchedConfig { nodes: 2, ..Default::default() };
        let r = run(&dag, &ex, &cfg).unwrap();
        assert!(r.all_ok());
        assert_eq!(r.succeeded.len(), dag.tasks.len());
    }

    #[test]
    fn oversize_task_rejected() {
        let rules = parse_rules(
            "big:\n  resources: {time: 1, nrs: 20, cpu: 42}\n  out:\n    f: b.out\n  script: echo\n",
        )
        .unwrap();
        let targets = parse_targets("t:\n  out:\n    f: b.out\n").unwrap();
        let dag =
            Dag::build(&rules, &targets[0], &|_: &Path| false, &|_| String::new()).unwrap();
        let cfg = SchedConfig { nodes: 4, machine: Machine::summit(4), ..Default::default() };
        assert!(run(&dag, &VirtualExec::new(), &cfg).is_err());
    }

    #[test]
    fn empty_dag_is_fine() {
        let dag = Dag::default();
        let r = run(&dag, &VirtualExec::new(), &SchedConfig::default()).unwrap();
        assert!(r.all_ok());
        assert!(r.succeeded.is_empty());
    }
}
