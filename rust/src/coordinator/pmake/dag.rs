//! pmake task-graph construction: file-directed, make-like.
//!
//! Starting from the target's requested files, walk backwards: a file
//! that exists on disk is a source ("like make, pmake stops searching for
//! rules when it finds all the files needed"); otherwise the first rule
//! whose output template matches produces it, binding the rule's single
//! template variable.  Rule instances deduplicate by (rule, binding), and
//! instance inputs recurse.
//!
//! Priorities implement the paper's earliest-finish-time heuristic: each
//! task's priority is its own node-hours plus the node-hours of all its
//! *distinct* transitive successors — work that cannot start until this
//! task finishes.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::substrate::cluster::{Machine, ResourceSet};

use super::rules::{Rule, Target};
use super::subst::{self, Ctx};

/// One concrete task (a rule instance bound to a variable value).
#[derive(Clone, Debug)]
pub struct TaskInstance {
    pub id: usize,
    pub rule: String,
    /// (var name, value) when the rule's outputs are templated
    pub binding: Option<(String, String)>,
    /// working directory (the target's dirname)
    pub dir: PathBuf,
    /// input files, relative to dir
    pub inputs: Vec<String>,
    /// output files, relative to dir, keyed by the rule's out names
    pub outputs: BTreeMap<String, String>,
    pub setup: String,
    /// fully rendered job script (mpirun expanded)
    pub script: String,
    pub resources: ResourceSet,
    /// producer tasks this instance waits for
    pub deps: Vec<usize>,
    /// node-hours based priority (filled by [`Dag::assign_priorities`])
    pub priority: f64,
}

impl TaskInstance {
    /// Script/log file stem: `rulename.n` or `rulename` (paper sec. 2.1).
    pub fn stem(&self) -> String {
        match &self.binding {
            Some((_, v)) => format!("{}.{}", self.rule, v),
            None => self.rule.clone(),
        }
    }
}

/// The built DAG.
#[derive(Debug, Default)]
pub struct Dag {
    pub tasks: Vec<TaskInstance>,
    /// rendered output path -> producing task
    by_output: HashMap<String, usize>,
}

/// How `{mpirun}` is expanded for a rule's resource set.
pub type MpirunFn<'a> = dyn Fn(&ResourceSet) -> String + 'a;

impl Dag {
    /// Build the graph for one target.  `exists` abstracts the filesystem
    /// (tests inject virtual file sets; production passes a closure over
    /// `Path::exists`).
    pub fn build(
        rules: &[Rule],
        target: &Target,
        exists: &dyn Fn(&Path) -> bool,
        mpirun: &MpirunFn,
    ) -> Result<Dag> {
        let mut dag = Dag::default();
        let dir = PathBuf::from(&target.dirname);
        let mut resolving: HashSet<String> = HashSet::new();
        for file in target.requested_files()? {
            dag.need(&file, rules, target, &dir, exists, mpirun, &mut resolving)?;
        }
        dag.assign_priorities();
        Ok(dag)
    }

    #[allow(clippy::too_many_arguments)]
    fn need(
        &mut self,
        file: &str,
        rules: &[Rule],
        target: &Target,
        dir: &Path,
        exists: &dyn Fn(&Path) -> bool,
        mpirun: &MpirunFn,
        resolving: &mut HashSet<String>,
    ) -> Result<Option<usize>> {
        if let Some(&id) = self.by_output.get(file) {
            return Ok(Some(id));
        }
        if exists(&dir.join(file)) {
            return Ok(None); // source file: satisfied
        }
        if !resolving.insert(file.to_string()) {
            bail!("cyclic rule dependency while resolving {file:?}");
        }
        // first matching rule wins (rule order is search order)
        let mut found: Option<(usize, Option<(String, String)>)> = None;
        'rules: for (ri, rule) in rules.iter().enumerate() {
            for tpl in rule.outputs.values() {
                // render target-level vars into the template first so
                // literal parts like {temperature} resolve before matching
                let mut tctx = Ctx::new();
                for (k, v) in &target.vars {
                    tctx.set(k.clone(), v.clone());
                }
                let tpl = subst::render_partial(tpl, &tctx)?;
                if let Some((var, value)) = subst::match_template(&tpl, file) {
                    let binding = if var.is_empty() { None } else { Some((var, value)) };
                    found = Some((ri, binding));
                    break 'rules;
                }
            }
        }
        let Some((ri, binding)) = found else {
            resolving.remove(file);
            bail!(
                "no rule builds {file:?} and it does not exist in {:?}",
                dir.display()
            );
        };
        let rule = &rules[ri];

        // substitution context, in the paper's layering order:
        // target members -> loop/template variable -> rule members
        let mut ctx = Ctx::new();
        for (k, v) in &target.vars {
            ctx.set(k.clone(), v.clone());
        }
        if let Some((var, value)) = &binding {
            ctx.set(var.clone(), value.clone());
        }
        ctx.set("rule", rule.name.clone());
        ctx.set("dirname", target.dirname.clone());

        // render outputs; dedup instance if another requested file already
        // instantiated this (rule, binding)
        let mut outputs = BTreeMap::new();
        for (k, tpl) in &rule.outputs {
            outputs.insert(k.clone(), subst::render(tpl, &ctx).with_context(|| {
                format!("rendering out.{k} of rule {}", rule.name)
            })?);
        }
        if let Some(&id) = outputs.values().find_map(|o| self.by_output.get(o)) {
            resolving.remove(file);
            return Ok(Some(id));
        }

        // render inputs (incl. loop-generated)
        let mut inputs = Vec::new();
        for (k, tpl) in &rule.inputs {
            inputs.push(subst::render(tpl, &ctx).with_context(|| {
                format!("rendering inp.{k} of rule {}", rule.name)
            })?);
        }
        for (var, over, tpl) in &rule.input_loops {
            let spec = subst::render(over, &ctx)?;
            for value in subst::parse_iterable(&spec)? {
                let mut lctx = ctx.clone();
                lctx.set(var.clone(), value);
                inputs.push(subst::render(tpl, &lctx)?);
            }
        }

        // script rendering: inp/out maps + mpirun available now
        let mut inp_map = BTreeMap::new();
        for (k, tpl) in &rule.inputs {
            inp_map.insert(k.clone(), subst::render(tpl, &ctx)?);
        }
        let mut sctx = ctx.clone();
        sctx.set_map("inp", inp_map);
        sctx.set_map("out", outputs.clone());
        sctx.set("mpirun", mpirun(&rule.resources));
        let script = subst::render(&rule.script, &sctx)
            .with_context(|| format!("rendering script of rule {}", rule.name))?;
        let setup = subst::render_partial(&rule.setup, &sctx)?;

        // recurse into inputs to find producer deps
        let mut deps = Vec::new();
        for inp in &inputs {
            if let Some(dep) =
                self.need(inp, rules, target, dir, exists, mpirun, resolving)?
            {
                deps.push(dep);
            }
        }
        deps.sort_unstable();
        deps.dedup();

        let id = self.tasks.len();
        for out in outputs.values() {
            self.by_output.insert(out.clone(), id);
        }
        self.tasks.push(TaskInstance {
            id,
            rule: rule.name.clone(),
            binding,
            dir: dir.to_path_buf(),
            inputs,
            outputs,
            setup,
            script,
            resources: rule.resources,
            deps,
            priority: 0.0,
        });
        resolving.remove(file);
        Ok(Some(id))
    }

    /// Producer of a (rendered) output path, if any.
    pub fn producer(&self, file: &str) -> Option<usize> {
        self.by_output.get(file).copied()
    }

    /// Paper priority: own node-hours + node-hours of all distinct
    /// transitive successors.
    pub fn assign_priorities(&mut self) {
        let m = Machine::summit(4608); // node-hour arithmetic only
        let n = self.tasks.len();
        let mut successors: Vec<Vec<usize>> = vec![Vec::new(); n];
        for t in &self.tasks {
            for &d in &t.deps {
                successors[d].push(t.id);
            }
        }
        let nh: Vec<f64> = self.tasks.iter().map(|t| t.resources.node_hours(&m)).collect();
        for id in 0..n {
            let mut seen = HashSet::new();
            let mut stack: Vec<usize> = successors[id].clone();
            let mut total = nh[id];
            while let Some(s) = stack.pop() {
                if seen.insert(s) {
                    total += nh[s];
                    stack.extend(successors[s].iter().copied());
                }
            }
            self.tasks[id].priority = total;
        }
    }

    /// Topological order sanity check (deps before dependents).
    pub fn is_topologically_valid(&self) -> bool {
        self.tasks.iter().all(|t| t.deps.iter().all(|&d| d < t.id))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::pmake::rules::{parse_rules, parse_targets};

    const FIG1_RULES: &str = r#"
simulate:
  resources: {time: 120, nrs: 10, cpu: 42, gpu: 6}
  inp:
    param: "{n}.param"
  out:
    trj: "{n}.trj"
  setup: module load cuda
  script: |
    {mpirun} simulate {inp[param]} {out[trj]}
analyze:
  resources: {time: 10, nrs: 1, cpu: 1}
  inp:
    trj: "{n}.trj"
  out:
    npy: "an_{n}.npy"
  script: |
    {mpirun} python compute_averages.py {inp[trj]} {out[npy]}
"#;

    const FIG1_TARGETS: &str = r#"
sim1:
  dirname: System1
  loop:
    n: "range(1,4)"
  tgt:
    npy: "an_{n}.npy"
"#;

    fn build_fig1(existing: &[&str]) -> Dag {
        let rules = parse_rules(FIG1_RULES).unwrap();
        let targets = parse_targets(FIG1_TARGETS).unwrap();
        let existing: HashSet<PathBuf> =
            existing.iter().map(|f| PathBuf::from("System1").join(f)).collect();
        Dag::build(
            &rules,
            &targets[0],
            &|p| existing.contains(p),
            &|rs| format!("jsrun -n {}", rs.nrs),
        )
        .unwrap()
    }

    #[test]
    fn fig1_full_graph() {
        // params exist on disk; 3 simulate + 3 analyze tasks
        let dag = build_fig1(&["1.param", "2.param", "3.param"]);
        assert_eq!(dag.tasks.len(), 6);
        assert!(dag.is_topologically_valid());
        // each analyze depends on its simulate
        for n in 1..=3 {
            let sim = dag.producer(&format!("{n}.trj")).unwrap();
            let ana = dag.producer(&format!("an_{n}.npy")).unwrap();
            assert_eq!(dag.tasks[ana].deps, vec![sim]);
            assert!(dag.tasks[ana].script.contains(&format!("{n}.trj")));
            assert!(dag.tasks[sim].script.starts_with("jsrun -n 10 simulate"));
        }
    }

    #[test]
    fn existing_intermediate_skips_producer() {
        // 2.trj already exists: no simulate task for n=2
        let dag = build_fig1(&["1.param", "2.trj", "3.param"]);
        assert_eq!(dag.tasks.len(), 5);
        assert!(dag.producer("2.trj").is_none());
        let ana2 = dag.producer("an_2.npy").unwrap();
        assert!(dag.tasks[ana2].deps.is_empty());
    }

    #[test]
    fn missing_source_is_error() {
        let rules = parse_rules(FIG1_RULES).unwrap();
        let targets = parse_targets(FIG1_TARGETS).unwrap();
        let err = Dag::build(&rules, &targets[0], &|_| false, &|_| String::new()).unwrap_err();
        assert!(err.to_string().contains("no rule builds"), "{err}");
    }

    #[test]
    fn shared_dep_dedup() {
        // two analyze variants reading the same trj -> one simulate task
        let rules_src = r#"
simulate:
  inp:
    param: "p.param"
  out:
    trj: "x.trj"
  script: sim
a1:
  inp:
    trj: "x.trj"
  out:
    f: "a1.out"
  script: one
a2:
  inp:
    trj: "x.trj"
  out:
    f: "a2.out"
  script: two
"#;
        let rules = parse_rules(rules_src).unwrap();
        let targets = parse_targets("t:\n  out:\n    a: a1.out\n    b: a2.out\n").unwrap();
        let exists = |p: &Path| p.ends_with("p.param");
        let dag = Dag::build(&rules, &targets[0], &exists, &|_| String::new()).unwrap();
        assert_eq!(dag.tasks.len(), 3);
        let sim = dag.producer("x.trj").unwrap();
        for out in ["a1.out", "a2.out"] {
            assert_eq!(dag.tasks[dag.producer(out).unwrap()].deps, vec![sim]);
        }
    }

    #[test]
    fn priority_prefers_long_chains() {
        // simulate (20 node-hours) + analyze (0.17): simulate priority must
        // include its dependent analyze; leaves have the lowest priority.
        let dag = build_fig1(&["1.param", "2.param", "3.param"]);
        for n in 1..=3 {
            let sim = dag.producer(&format!("{n}.trj")).unwrap();
            let ana = dag.producer(&format!("an_{n}.npy")).unwrap();
            assert!(dag.tasks[sim].priority > dag.tasks[ana].priority);
            // sim priority = own 20 + analyze ~0.167
            assert!((dag.tasks[sim].priority - 20.1666).abs() < 0.01);
        }
    }

    #[test]
    fn input_loop_expands() {
        let rules_src = r#"
combine:
  inp:
    loop:
      var: i
      over: "range(0,3)"
      tpl: "part_{i}.dat"
  out:
    all: "combined.dat"
  script: cat
"#;
        let rules = parse_rules(rules_src).unwrap();
        let targets = parse_targets("t:\n  out:\n    f: combined.dat\n").unwrap();
        let exists = |p: &Path| p.to_string_lossy().contains("part_");
        let dag = Dag::build(&rules, &targets[0], &exists, &|_| String::new()).unwrap();
        assert_eq!(dag.tasks.len(), 1);
        assert_eq!(dag.tasks[0].inputs, vec!["part_0.dat", "part_1.dat", "part_2.dat"]);
    }

    #[test]
    fn cycle_detected() {
        let rules_src = r#"
a:
  inp:
    x: "b.out"
  out:
    f: "a.out"
  script: one
b:
  inp:
    x: "a.out"
  out:
    f: "b.out"
  script: two
"#;
        let rules = parse_rules(rules_src).unwrap();
        let targets = parse_targets("t:\n  out:\n    f: a.out\n").unwrap();
        let err = Dag::build(&rules, &targets[0], &|_| false, &|_| String::new()).unwrap_err();
        assert!(err.to_string().contains("cyclic"), "{err}");
    }

    #[test]
    fn stem_naming() {
        let dag = build_fig1(&["1.param", "2.param", "3.param"]);
        let sim1 = dag.producer("1.trj").unwrap();
        assert_eq!(dag.tasks[sim1].stem(), "simulate.1");
    }

    #[test]
    fn target_vars_flow_into_match_and_script() {
        let rules_src = r#"
run:
  out:
    f: "res_{T}_{n}.txt"
  script: "echo {T} {n} > {out[f]}"
"#;
        // hmm: res_{T}_{n} has two vars — rejected at parse?  T comes from
        // the target, so after partial render the template has one var.
        let rules = parse_rules(rules_src);
        // parse-time check sees two vars in the raw template: must reject
        assert!(rules.is_err());
    }
}
