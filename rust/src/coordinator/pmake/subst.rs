//! Template substitution: pmake's Python-`format()` work-alike.
//!
//! The paper splices values into rules with Python's `format()`:
//! `{n}`, `{inp[param]}`, `{out[trj]}`, `{mpirun}`, with literal braces
//! escaped as `{{`/`}}`.  Substitution is layered (target members → loop
//! variable → rule members → script), so later layers may reference
//! earlier ones.
//!
//! Also here: reverse matching — given the template `an_{n}.npy` and the
//! concrete file `an_3.npy`, recover `n = 3` (how pmake discovers which
//! rule instance builds a requested output).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Layered substitution context.
#[derive(Clone, Debug, Default)]
pub struct Ctx {
    vars: BTreeMap<String, String>,
    /// indexed namespaces: inp[...], out[...], tgt[...]
    maps: BTreeMap<String, BTreeMap<String, String>>,
}

impl Ctx {
    pub fn new() -> Ctx {
        Ctx::default()
    }

    pub fn set(&mut self, k: impl Into<String>, v: impl Into<String>) -> &mut Self {
        self.vars.insert(k.into(), v.into());
        self
    }

    pub fn set_map(&mut self, ns: impl Into<String>, m: BTreeMap<String, String>) -> &mut Self {
        self.maps.insert(ns.into(), m);
        self
    }

    pub fn get(&self, k: &str) -> Option<&str> {
        self.vars.get(k).map(String::as_str)
    }

    pub fn get_indexed(&self, ns: &str, key: &str) -> Option<&str> {
        self.maps.get(ns)?.get(key).map(String::as_str)
    }

    /// Merge `other`'s entries over this context (later layer wins).
    pub fn overlay(&mut self, other: &Ctx) {
        for (k, v) in &other.vars {
            self.vars.insert(k.clone(), v.clone());
        }
        for (ns, m) in &other.maps {
            self.maps.entry(ns.clone()).or_default().extend(m.clone());
        }
    }
}

/// One parsed template chunk.
#[derive(Debug, PartialEq)]
enum Chunk<'a> {
    Lit(&'a str),
    /// `{name}`
    Var(&'a str),
    /// `{ns[key]}`
    Indexed(&'a str, &'a str),
    /// escaped `{{` or `}}`
    Brace(char),
}

fn parse_chunks(tpl: &str) -> Result<Vec<Chunk<'_>>> {
    let mut out = Vec::new();
    let bytes = tpl.as_bytes();
    let mut pos = 0;
    while pos < bytes.len() {
        match bytes[pos] {
            b'{' if pos + 1 < bytes.len() && bytes[pos + 1] == b'{' => {
                out.push(Chunk::Brace('{'));
                pos += 2;
            }
            b'}' if pos + 1 < bytes.len() && bytes[pos + 1] == b'}' => {
                out.push(Chunk::Brace('}'));
                pos += 2;
            }
            b'{' => {
                let close = tpl[pos..]
                    .find('}')
                    .map(|i| pos + i)
                    .ok_or_else(|| anyhow::anyhow!("unclosed '{{' in template {tpl:?}"))?;
                let body = &tpl[pos + 1..close];
                if body.is_empty() {
                    bail!("empty substitution in template {tpl:?}");
                }
                if let Some(open) = body.find('[') {
                    if !body.ends_with(']') {
                        bail!("bad indexed substitution {body:?} in {tpl:?}");
                    }
                    out.push(Chunk::Indexed(&body[..open], &body[open + 1..body.len() - 1]));
                } else {
                    out.push(Chunk::Var(body));
                }
                pos = close + 1;
            }
            b'}' => bail!("stray '}}' in template {tpl:?} (escape as '}}}}')"),
            _ => {
                let start = pos;
                while pos < bytes.len() && bytes[pos] != b'{' && bytes[pos] != b'}' {
                    pos += 1;
                }
                out.push(Chunk::Lit(&tpl[start..pos]));
            }
        }
    }
    Ok(out)
}

/// Render a template against a context.  Unknown variables are an error —
/// silent empty substitution hides real workflow bugs.
pub fn render(tpl: &str, ctx: &Ctx) -> Result<String> {
    let mut out = String::with_capacity(tpl.len());
    for chunk in parse_chunks(tpl)? {
        match chunk {
            Chunk::Lit(s) => out.push_str(s),
            Chunk::Brace(c) => out.push(c),
            Chunk::Var(name) => match ctx.get(name) {
                Some(v) => out.push_str(v),
                None => bail!("undefined variable {{{name}}} in template {tpl:?}"),
            },
            Chunk::Indexed(ns, key) => match ctx.get_indexed(ns, key) {
                Some(v) => out.push_str(v),
                None => bail!("undefined {{{ns}[{key}]}} in template {tpl:?}"),
            },
        }
    }
    Ok(out)
}

/// Render, leaving *unknown* variables untouched (used for the staged
/// layering: early layers render what they can; later layers finish).
pub fn render_partial(tpl: &str, ctx: &Ctx) -> Result<String> {
    let mut out = String::with_capacity(tpl.len());
    for chunk in parse_chunks(tpl)? {
        match chunk {
            Chunk::Lit(s) => out.push_str(s),
            // keep escapes escaped so a later render() pass sees them intact
            Chunk::Brace(c) => {
                out.push(c);
                out.push(c);
            }
            Chunk::Var(name) => match ctx.get(name) {
                Some(v) => out.push_str(v),
                None => {
                    out.push('{');
                    out.push_str(name);
                    out.push('}');
                }
            },
            Chunk::Indexed(ns, key) => match ctx.get_indexed(ns, key) {
                Some(v) => out.push_str(v),
                None => {
                    out.push('{');
                    out.push_str(ns);
                    out.push('[');
                    out.push_str(key);
                    out.push_str("]}");
                }
            },
        }
    }
    Ok(out)
}

/// Match a concrete string against a template with at most one variable;
/// returns Some((var_name, value)) or Some(("", "")) for an exact literal
/// match, None on mismatch.
///
/// pmake rules "for rules that can make multiple output files, one
/// variable is allowed, and is defined by matching on names in the out
/// section" (paper sec 2.1).
pub fn match_template(tpl: &str, concrete: &str) -> Option<(String, String)> {
    let chunks = parse_chunks(tpl).ok()?;
    // flatten to (prefix, var, suffix)
    let mut lit = String::new();
    let mut var: Option<(&str, usize)> = None; // (name, position in lit)
    for c in &chunks {
        match c {
            Chunk::Lit(s) => lit.push_str(s),
            Chunk::Brace(ch) => lit.push(*ch),
            Chunk::Var(name) => {
                if var.is_some() {
                    return None; // more than one variable: not matchable
                }
                var = Some((name, lit.len()));
            }
            Chunk::Indexed(..) => return None,
        }
    }
    match var {
        None => (lit == concrete).then(|| (String::new(), String::new())),
        Some((name, pos)) => {
            let prefix = &lit[..pos];
            let suffix = &lit[pos..];
            if concrete.len() < prefix.len() + suffix.len() {
                return None;
            }
            if !concrete.starts_with(prefix) || !concrete.ends_with(suffix) {
                return None;
            }
            let value = &concrete[prefix.len()..concrete.len() - suffix.len()];
            if value.is_empty() {
                return None; // a variable must match something
            }
            Some((name.to_string(), value.to_string()))
        }
    }
}

/// Parse the paper's loop iterables: `range(a,b)`, `range(a,b,step)`, or
/// a comma-separated literal list `x, y, z`.
pub fn parse_iterable(spec: &str) -> Result<Vec<String>> {
    let s = spec.trim();
    if let Some(body) = s.strip_prefix("range(").and_then(|r| r.strip_suffix(')')) {
        let parts: Vec<i64> = body
            .split(',')
            .map(|p| p.trim().parse::<i64>())
            .collect::<std::result::Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad range {s:?}: {e}"))?;
        let (start, stop, step) = match parts.as_slice() {
            [stop] => (0, *stop, 1),
            [start, stop] => (*start, *stop, 1),
            [start, stop, step] => (*start, *stop, *step),
            _ => bail!("range() takes 1-3 arguments: {s:?}"),
        };
        if step == 0 {
            bail!("range() step must be nonzero");
        }
        let mut out = Vec::new();
        let mut i = start;
        while (step > 0 && i < stop) || (step < 0 && i > stop) {
            out.push(i.to_string());
            i += step;
        }
        Ok(out)
    } else {
        Ok(s.split(',').map(|p| p.trim().to_string()).filter(|p| !p.is_empty()).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> Ctx {
        let mut c = Ctx::new();
        c.set("n", "3").set("mpirun", "jsrun -n 10");
        let mut inp = BTreeMap::new();
        inp.insert("param".to_string(), "3.param".to_string());
        c.set_map("inp", inp);
        let mut out = BTreeMap::new();
        out.insert("trj".to_string(), "3.trj".to_string());
        c.set_map("out", out);
        c
    }

    #[test]
    fn simple_vars() {
        assert_eq!(render("{n}.trj", &ctx()).unwrap(), "3.trj");
        assert_eq!(render("an_{n}.npy", &ctx()).unwrap(), "an_3.npy");
    }

    #[test]
    fn indexed_vars() {
        assert_eq!(
            render("{mpirun} simulate {inp[param]} {out[trj]}", &ctx()).unwrap(),
            "jsrun -n 10 simulate 3.param 3.trj"
        );
    }

    #[test]
    fn escaped_braces() {
        assert_eq!(render("awk '{{print $1}}'", &ctx()).unwrap(), "awk '{print $1}'");
        assert_eq!(render("{{{n}}}", &ctx()).unwrap(), "{3}");
    }

    #[test]
    fn unknown_var_is_error() {
        assert!(render("{missing}", &ctx()).is_err());
        assert!(render("{inp[missing]}", &ctx()).is_err());
        assert!(render("{missing[k]}", &ctx()).is_err());
    }

    #[test]
    fn syntax_errors() {
        assert!(render("{unclosed", &ctx()).is_err());
        assert!(render("stray } here", &ctx()).is_err());
        assert!(render("{}", &ctx()).is_err());
    }

    #[test]
    fn partial_render_keeps_unknowns() {
        let mut c = Ctx::new();
        c.set("n", "7");
        assert_eq!(
            render_partial("{mpirun} f {n} {inp[x]}", &c).unwrap(),
            "{mpirun} f 7 {inp[x]}"
        );
        // escapes survive a partial pass for the final render
        let partial = render_partial("{{literal}} {n}", &c).unwrap();
        assert_eq!(partial, "{{literal}} 7");
        assert_eq!(render(&partial, &c).unwrap(), "{literal} 7");
    }

    #[test]
    fn template_matching() {
        assert_eq!(
            match_template("an_{n}.npy", "an_3.npy").unwrap(),
            ("n".to_string(), "3".to_string())
        );
        assert_eq!(
            match_template("{n}.trj", "system-A.trj").unwrap(),
            ("n".to_string(), "system-A".to_string())
        );
        assert_eq!(match_template("fixed.txt", "fixed.txt").unwrap(), (String::new(), String::new()));
        assert!(match_template("an_{n}.npy", "an_.npy").is_none()); // empty match
        assert!(match_template("an_{n}.npy", "bn_3.npy").is_none());
        assert!(match_template("an_{n}.npy", "an_3.txt").is_none());
        assert!(match_template("{a}_{b}.npy", "x_y.npy").is_none()); // two vars
    }

    #[test]
    fn iterables() {
        assert_eq!(parse_iterable("range(1,4)").unwrap(), vec!["1", "2", "3"]);
        assert_eq!(parse_iterable("range(3)").unwrap(), vec!["0", "1", "2"]);
        assert_eq!(parse_iterable("range(0,10,5)").unwrap(), vec!["0", "5"]);
        assert_eq!(parse_iterable("range(3,0,-1)").unwrap(), vec!["3", "2", "1"]);
        assert_eq!(parse_iterable("a, b, c").unwrap(), vec!["a", "b", "c"]);
        assert!(parse_iterable("range(1,2,0)").is_err());
        assert!(parse_iterable("range(x)").is_err());
    }

    #[test]
    fn paper_fig1_range() {
        // targets.yaml: n: "range(1,11)" -> files an_1.npy .. an_10.npy
        let ns = parse_iterable("range(1,11)").unwrap();
        assert_eq!(ns.len(), 10);
        assert_eq!(ns.first().unwrap(), "1");
        assert_eq!(ns.last().unwrap(), "10");
    }

    #[test]
    fn overlay_layering() {
        let mut base = Ctx::new();
        base.set("n", "1").set("keep", "yes");
        let mut top = Ctx::new();
        top.set("n", "2");
        base.overlay(&top);
        assert_eq!(base.get("n"), Some("2"));
        assert_eq!(base.get("keep"), Some("yes"));
    }
}
