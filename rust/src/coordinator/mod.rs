//! The paper's three schedulers.
pub mod dwork;
pub mod mpilist;
pub mod pmake;
