//! dhub: the dwork task server event loop.
//!
//! Transport-agnostic: consumes the [`Request`](crate::substrate::transport::Request)
//! stream produced by either the in-proc hub or the TCP front-end, decodes
//! wire messages, applies them to [`SchedState`], and replies.  A single
//! loop serializes all mutations — the paper's single-task-server design
//! whose dispatch rate bounds dwork's METG.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::substrate::transport::RequestRx;

use super::messages::{Request, Response};
use super::state::SchedState;

/// Counters the server publishes for benches/monitoring.
#[derive(Default, Debug)]
pub struct ServerCounters {
    pub requests: AtomicU64,
    pub steals_served: AtomicU64,
    pub not_found: AtomicU64,
    pub exits_sent: AtomicU64,
}

/// Configuration knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Auto-snapshot the store every N mutations (0 = never).
    pub snapshot_every: u64,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { snapshot_every: 0 }
    }
}

/// Run the server loop until every client connector is dropped.
/// Returns the final state (for inspection by tests/benches).
pub fn serve(mut state: SchedState, rx: RequestRx, cfg: ServerConfig) -> SchedState {
    serve_with_counters(&mut state, rx, cfg, &ServerCounters::default());
    state
}

/// Like [`serve`] but with externally visible counters.
pub fn serve_with_counters(
    state: &mut SchedState,
    rx: RequestRx,
    cfg: ServerConfig,
    counters: &ServerCounters,
) {
    let mut mutations = 0u64;
    for req in rx {
        counters.requests.fetch_add(1, Ordering::Relaxed);
        let resp = match Request::decode(&req.payload) {
            Err(e) => Response::Err(format!("bad request: {e}")),
            Ok(Request::Create { task, deps }) => {
                mutations += 1;
                match state.create(task, &deps) {
                    Ok(()) => Response::Ok,
                    Err(e) => Response::Err(e.to_string()),
                }
            }
            Ok(Request::Steal { worker }) => {
                mutations += 1;
                let mut got = state.steal(&worker, 1);
                match got.pop() {
                    Some(t) => {
                        counters.steals_served.fetch_add(1, Ordering::Relaxed);
                        Response::Task(t)
                    }
                    None if state.all_done() => {
                        counters.exits_sent.fetch_add(1, Ordering::Relaxed);
                        Response::Exit
                    }
                    None => {
                        counters.not_found.fetch_add(1, Ordering::Relaxed);
                        Response::NotFound
                    }
                }
            }
            Ok(Request::StealN { worker, n }) => {
                mutations += 1;
                let got = state.steal(&worker, n);
                if got.is_empty() && state.all_done() {
                    counters.exits_sent.fetch_add(1, Ordering::Relaxed);
                    Response::Exit
                } else {
                    counters
                        .steals_served
                        .fetch_add(got.len() as u64, Ordering::Relaxed);
                    Response::Tasks(got)
                }
            }
            Ok(Request::Complete { worker, task, success }) => {
                mutations += 1;
                match state.complete(&worker, &task, success) {
                    Ok(()) => Response::Ok,
                    Err(e) => Response::Err(e.to_string()),
                }
            }
            Ok(Request::Transfer { worker, task, new_deps }) => {
                mutations += 1;
                match state.transfer(&worker, &task, &new_deps) {
                    Ok(()) => Response::Ok,
                    Err(e) => Response::Err(e.to_string()),
                }
            }
            Ok(Request::Exit { worker }) => {
                mutations += 1;
                state.exit_worker(&worker);
                Response::Ok
            }
            Ok(Request::Status) => Response::Status(state.status()),
            Ok(Request::Save) => match state.save() {
                Ok(()) => Response::Ok,
                Err(e) => Response::Err(e.to_string()),
            },
        };
        if cfg.snapshot_every > 0 && mutations % cfg.snapshot_every == 0 {
            let _ = state.save();
        }
        req.reply(resp.encode());
    }
}

/// Spawn the server on its own thread over an in-proc hub; returns the
/// connector + join handle.  The server stops when every connector clone
/// is dropped.
pub fn spawn_inproc(
    state: SchedState,
    cfg: ServerConfig,
) -> (
    crate::substrate::transport::inproc::Connector,
    std::thread::JoinHandle<SchedState>,
) {
    let (rx, connector) = crate::substrate::transport::inproc::hub();
    let handle = std::thread::Builder::new()
        .name("dhub".into())
        .spawn(move || serve(state, rx, cfg))
        .expect("spawn dhub");
    (connector, handle)
}

/// Spawn the server over TCP; returns (bound address, server guard, join
/// handle).  Dropping the guard stops accepting; the loop exits when all
/// connection threads are gone.  NOTE: the acceptor holds a request-sender
/// clone, so drop the guard *before* joining the handle.
pub fn spawn_tcp(
    state: SchedState,
    cfg: ServerConfig,
    bind: &str,
) -> anyhow::Result<(
    std::net::SocketAddr,
    crate::substrate::transport::tcp::TcpServer,
    std::thread::JoinHandle<SchedState>,
)> {
    let (server, rx) = crate::substrate::transport::tcp::TcpServer::bind(bind)?;
    let addr = server.addr;
    let handle = std::thread::Builder::new()
        .name("dhub-tcp".into())
        .spawn(move || serve(state, rx, cfg))
        .expect("spawn dhub");
    Ok((addr, server, handle))
}

/// Arc-wrapped counters helper for sharing with benches.
pub fn counters() -> Arc<ServerCounters> {
    Arc::new(ServerCounters::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dwork::client::Client;
    use crate::coordinator::dwork::messages::TaskMsg;
    use crate::substrate::transport::ClientConn;

    #[test]
    fn inproc_end_to_end() {
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        c.create(TaskMsg::new("a", vec![1]), &[]).unwrap();
        c.create(TaskMsg::new("b", vec![2]), &["a".to_string()]).unwrap();
        let t = c.steal().unwrap().unwrap();
        assert_eq!(t.name, "a");
        c.complete(&t.name, true).unwrap();
        let t = c.steal().unwrap().unwrap();
        assert_eq!(t.name, "b");
        c.complete(&t.name, true).unwrap();
        assert!(c.steal().unwrap().is_none(), "all done => Exit");
        drop(c);
        drop(connector);
        let state = handle.join().unwrap();
        assert!(state.all_done());
    }

    #[test]
    fn malformed_request_gets_err_reply() {
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut raw = connector.connect();
        let reply = raw.request(&[0xde, 0xad]).unwrap();
        match super::super::messages::Response::decode(&reply).unwrap() {
            super::super::messages::Response::Err(_) => {}
            other => panic!("expected Err, got {other:?}"),
        }
        drop(raw);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn tcp_end_to_end() {
        let (addr, _guard, _handle) =
            spawn_tcp(SchedState::new(), ServerConfig::default(), "127.0.0.1:0").unwrap();
        let conn =
            crate::substrate::transport::tcp::TcpClient::connect(&addr.to_string()).unwrap();
        let mut c = Client::new(Box::new(conn), "w0");
        c.create(TaskMsg::new("t1", b"payload".to_vec()), &[]).unwrap();
        let t = c.steal().unwrap().unwrap();
        assert_eq!(t.body, b"payload");
        c.complete(&t.name, true).unwrap();
        let st = c.status().unwrap();
        assert_eq!(st.completed, 1);
    }
}
