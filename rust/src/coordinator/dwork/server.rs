//! dhub: the dwork task server event loop.
//!
//! Transport-agnostic: consumes the [`Request`](crate::substrate::transport::Request)
//! stream produced by either the in-proc hub or the TCP front-end, decodes
//! wire messages, applies them to [`SchedState`], and replies.  A single
//! loop serializes all mutations — the paper's single-task-server design
//! whose dispatch rate bounds dwork's METG.

use std::collections::HashSet;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::metrics::{Counter, Gauge, Registry, Series};
use crate::substrate::transport::RequestRx;

use super::messages::{BatchItem, Request, Response};
use super::state::SchedState;

/// Counters the server publishes for benches/monitoring.
#[derive(Default, Debug)]
pub struct ServerCounters {
    pub requests: AtomicU64,
    pub steals_served: AtomicU64,
    pub not_found: AtomicU64,
    pub exits_sent: AtomicU64,
}

/// Configuration knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Auto-snapshot the store every N mutations (0 = never).
    pub snapshot_every: u64,
    /// Live-metrics registry.  The disabled default costs one branch
    /// per update; pass [`Registry::enabled`] to get per-request-kind
    /// counts, service-time histograms, queue/inflight gauges, and the
    /// `Request::Metrics` snapshot (the serve loop shares this registry
    /// with the state machine via `SchedState::set_metrics`).
    pub metrics: Registry,
    /// Test shim: answer the session request kinds
    /// (OpenSession/CloseSession/SubmitDelta) with the whole-frame
    /// `Err` a pre-session hub would produce, so the client degrade
    /// path can be pinned against a current build (mixed-version test).
    /// Never set in production servers.
    pub compat_pre_sessions: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            snapshot_every: 0,
            metrics: Registry::default(),
            compat_pre_sessions: false,
        }
    }
}

/// Run the server loop until every client connector is dropped.
/// Returns the final state (for inspection by tests/benches).
pub fn serve(mut state: SchedState, rx: RequestRx, cfg: ServerConfig) -> SchedState {
    serve_with_counters(&mut state, rx, cfg, &ServerCounters::default());
    state
}

/// Like [`serve`] but with externally visible counters.
pub fn serve_with_counters(
    state: &mut SchedState,
    rx: RequestRx,
    cfg: ServerConfig,
    counters: &ServerCounters,
) {
    let metrics = cfg.metrics.clone();
    // one registry, shared: the state machine updates task-lifecycle
    // counters and the queue/inflight gauges; this loop adds per-kind
    // request counts, service times, and worker-population series
    state.set_metrics(metrics.clone());
    // worker names seen stealing since the last Exit — the hub-side
    // notion of "attached".  Only maintained when metrics are on.
    let mut attached: HashSet<String> = HashSet::new();
    let mut mutations = 0u64;
    for req in rx {
        counters.requests.fetch_add(1, Ordering::Relaxed);
        let t0 = Instant::now();
        let decoded = Request::decode(&req.payload);
        // per-kind arrival counter + the service-time series this
        // request will observe into once handled
        let (kind_counter, service) = match &decoded {
            Err(_) => (Counter::ReqMalformed, None),
            Ok(Request::Create { .. }) => (Counter::ReqCreate, Some(Series::ServiceCreate)),
            Ok(Request::Steal { .. }) => (Counter::ReqSteal, Some(Series::ServiceSteal)),
            Ok(Request::StealN { .. }) => (Counter::ReqStealN, Some(Series::ServiceSteal)),
            Ok(Request::Complete { .. }) => {
                (Counter::ReqComplete, Some(Series::ServiceComplete))
            }
            Ok(Request::Transfer { .. }) => {
                (Counter::ReqTransfer, Some(Series::ServiceTransfer))
            }
            Ok(Request::Exit { .. }) => (Counter::ReqExit, Some(Series::ServiceExit)),
            Ok(Request::Status) => (Counter::ReqStatus, Some(Series::ServiceStatus)),
            Ok(Request::Save) => (Counter::ReqSave, Some(Series::ServiceSave)),
            Ok(Request::Metrics) => (Counter::ReqMetrics, Some(Series::ServiceMetrics)),
            Ok(Request::Subscribe { .. }) => {
                (Counter::ReqSubscribe, Some(Series::ServiceSubscribe))
            }
            Ok(Request::CreateBatch { .. }) => {
                (Counter::ReqCreateBatch, Some(Series::ServiceCreateBatch))
            }
            Ok(Request::CompleteBatch { .. }) => {
                (Counter::ReqCompleteBatch, Some(Series::ServiceCompleteBatch))
            }
            Ok(Request::OpenSession { .. }) => {
                (Counter::ReqOpenSession, Some(Series::ServiceOpenSession))
            }
            Ok(Request::CloseSession { .. }) => {
                (Counter::ReqCloseSession, Some(Series::ServiceCloseSession))
            }
            Ok(Request::SubmitDelta { .. }) => {
                (Counter::ReqSubmitDelta, Some(Series::ServiceSubmitDelta))
            }
        };
        metrics.inc(kind_counter);
        if metrics.is_enabled() {
            // first steal from a name = attach; Exit = detach
            match &decoded {
                Ok(Request::Steal { worker }) | Ok(Request::StealN { worker, .. }) => {
                    if attached.insert(worker.clone()) {
                        metrics.inc(Counter::WorkersAttached);
                        metrics.gauge_add(Gauge::WorkersConnected, 1);
                    }
                }
                Ok(Request::Exit { worker }) => {
                    if attached.remove(worker) {
                        metrics.inc(Counter::WorkersExited);
                        metrics.gauge_add(Gauge::WorkersConnected, -1);
                    }
                }
                _ => {}
            }
        }
        // set only when THIS request changed scheduler state: the
        // auto-snapshot gate must not fire on reads, malformed frames, or
        // no-op steals sitting at a counter multiple (and never before
        // the first mutation)
        let mut mutated = false;
        let resp = match decoded {
            Err(e) => Response::err(format!("bad request: {e}")),
            Ok(Request::Create { task, deps }) => match state.create(task, &deps) {
                Ok(()) => {
                    mutated = true;
                    Response::Ok
                }
                // typed refusal: the code rides next to the marker text
                Err(e) => Response::Err { msg: e.to_string(), code: Some(e.code) },
            },
            Ok(Request::Steal { worker }) => {
                let mut got = state.steal(&worker, 1);
                match got.pop() {
                    Some(t) => {
                        mutated = true;
                        counters.steals_served.fetch_add(1, Ordering::Relaxed);
                        metrics.inc(Counter::StealsServed);
                        Response::Task(t)
                    }
                    // an empty hub parks the worker instead of dismissing
                    // it: a freshly served dhub is fed by submitters that
                    // may not have connected yet
                    None if !state.is_empty() && state.all_done() => {
                        counters.exits_sent.fetch_add(1, Ordering::Relaxed);
                        metrics.inc(Counter::StealsEmpty);
                        Response::Exit
                    }
                    None => {
                        counters.not_found.fetch_add(1, Ordering::Relaxed);
                        metrics.inc(Counter::StealsEmpty);
                        Response::NotFound
                    }
                }
            }
            Ok(Request::StealN { worker, n }) => {
                let got = state.steal(&worker, n);
                if got.is_empty() && !state.is_empty() && state.all_done() {
                    counters.exits_sent.fetch_add(1, Ordering::Relaxed);
                    metrics.inc(Counter::StealsEmpty);
                    Response::Exit
                } else {
                    mutated = !got.is_empty();
                    counters
                        .steals_served
                        .fetch_add(got.len() as u64, Ordering::Relaxed);
                    if got.is_empty() {
                        metrics.inc(Counter::StealsEmpty);
                    } else {
                        metrics.add(Counter::StealsServed, got.len() as u64);
                    }
                    Response::Tasks(got)
                }
            }
            Ok(Request::Complete { worker, task, success }) => {
                match state.complete(&worker, &task, success) {
                    Ok(()) => {
                        mutated = true;
                        Response::Ok
                    }
                    Err(e) => Response::err(e.to_string()),
                }
            }
            Ok(Request::Transfer { worker, task, new_deps }) => {
                match state.transfer(&worker, &task, &new_deps) {
                    Ok(()) => {
                        mutated = true;
                        Response::Ok
                    }
                    Err(e) => Response::err(e.to_string()),
                }
            }
            Ok(Request::Exit { worker }) => {
                mutated = state.exit_worker(&worker) > 0;
                // a departing tail also drops its event subscription
                state.unsubscribe(&worker);
                Response::Ok
            }
            Ok(Request::Status) => Response::Status(state.status()),
            Ok(Request::Save) => match state.save() {
                Ok(()) => Response::Ok,
                Err(e) => Response::err(e.to_string()),
            },
            // a snapshot of this very registry; version 0 (empty) when
            // the hub was served without --metrics-addr and no enabled
            // registry was passed in
            Ok(Request::Metrics) => Response::Metrics(metrics.snapshot()),
            // long-poll: drain whatever is queued for this subscriber
            // (registering it on first contact); `done` tells the tail
            // the graph has fully drained so --follow can stop
            Ok(Request::Subscribe { worker, prefix, max }) => {
                let (events, dropped) = state.subscribe_poll(&worker, &prefix, max as usize);
                Response::Events { events, dropped, done: !state.is_empty() && state.all_done() }
            }
            // batched wire ops: one frame, one reply, per-item results.
            // Refusals/errors stay per-item (the whole frame never turns
            // into Response::Err — that reply is reserved for pre-batch
            // hubs, whose "unknown request kind" Err is the client's
            // degrade-to-per-task signal).  The snapshot gate and the
            // service-time observation run once per wire message, not
            // once per task.
            Ok(Request::CreateBatch { items }) => {
                let mut results = Vec::with_capacity(items.len());
                for item in items {
                    match state.create(item.task, &item.deps) {
                        Ok(()) => {
                            mutated = true;
                            results.push(BatchItem::Ok);
                        }
                        Err(e) => results
                            .push(BatchItem::Err { msg: e.to_string(), code: Some(e.code) }),
                    }
                }
                Response::Batch(results)
            }
            Ok(Request::CompleteBatch { worker, completions }) => {
                let mut results = Vec::with_capacity(completions.len());
                for c in completions {
                    match state.complete(&worker, &c.task, c.success) {
                        Ok(()) => {
                            mutated = true;
                            results.push(BatchItem::Ok);
                        }
                        Err(e) => results.push(BatchItem::Err { msg: e.to_string(), code: None }),
                    }
                }
                Response::Batch(results)
            }
            // session verbs.  With `compat_pre_sessions` the hub replays
            // the exact reply a PR-9 hub produces for these kinds — the
            // whole-frame Err whose decode path is `bad request: unknown
            // request kind {13,14,15}` — pinning the client degrade.
            Ok(Request::OpenSession { session }) => {
                if cfg.compat_pre_sessions {
                    Response::err("bad request: unknown request kind 13")
                } else {
                    match state.open_session(&session) {
                        Ok(newly) => {
                            mutated = newly;
                            Response::Session { session, cancelled: 0 }
                        }
                        Err(e) => Response::err(e.to_string()),
                    }
                }
            }
            Ok(Request::CloseSession { session }) => {
                if cfg.compat_pre_sessions {
                    Response::err("bad request: unknown request kind 14")
                } else {
                    let was_open = state.session_is_open(&session);
                    match state.close_session(&session) {
                        Ok(cancelled) => {
                            mutated = was_open;
                            Response::Session { session, cancelled }
                        }
                        Err(e) => Response::err(e.to_string()),
                    }
                }
            }
            // one delta frame: completions applied FIRST, then creates,
            // so a same-frame create may depend on a task this very
            // frame completed (task-spawns-task reports).  Per-item
            // results align completions-then-creates; like the batch
            // kinds, a current hub never answers whole-frame Err here —
            // that reply is reserved for pre-session hubs and is the
            // client's degrade signal.
            Ok(Request::SubmitDelta { session, worker, creates, completions }) => {
                if cfg.compat_pre_sessions {
                    Response::err("bad request: unknown request kind 15")
                } else {
                    let mut results = Vec::with_capacity(completions.len() + creates.len());
                    for c in completions {
                        match state.complete(&worker, &c.task, c.success) {
                            Ok(()) => {
                                mutated = true;
                                results.push(BatchItem::Ok);
                            }
                            Err(e) => {
                                results.push(BatchItem::Err { msg: e.to_string(), code: None })
                            }
                        }
                    }
                    for item in creates {
                        match state.create_in_session(&session, item.task, &item.deps) {
                            Ok(()) => {
                                mutated = true;
                                results.push(BatchItem::Ok);
                            }
                            Err(e) => results
                                .push(BatchItem::Err { msg: e.to_string(), code: Some(e.code) }),
                        }
                    }
                    Response::Batch(results)
                }
            }
        };
        if mutated {
            mutations += 1;
            if cfg.snapshot_every > 0 && mutations % cfg.snapshot_every == 0 {
                let _ = state.save();
            }
        }
        if let Some(series) = service {
            metrics.observe(series, t0.elapsed());
        }
        req.reply(resp.encode());
    }
}

/// Spawn the server on its own thread over an in-proc hub; returns the
/// connector + join handle.  The server stops when every connector clone
/// is dropped.
pub fn spawn_inproc(
    state: SchedState,
    cfg: ServerConfig,
) -> (
    crate::substrate::transport::inproc::Connector,
    std::thread::JoinHandle<SchedState>,
) {
    let (rx, connector) = crate::substrate::transport::inproc::hub();
    let handle = std::thread::Builder::new()
        .name("dhub".into())
        .spawn(move || serve(state, rx, cfg))
        .expect("spawn dhub");
    (connector, handle)
}

/// Spawn the server over TCP; returns (bound address, server guard, join
/// handle).  Dropping the guard stops accepting; the loop exits when all
/// connection threads are gone.  NOTE: the acceptor holds a request-sender
/// clone, so drop the guard *before* joining the handle.
pub fn spawn_tcp(
    state: SchedState,
    cfg: ServerConfig,
    bind: &str,
) -> anyhow::Result<(
    std::net::SocketAddr,
    crate::substrate::transport::tcp::TcpServer,
    std::thread::JoinHandle<SchedState>,
)> {
    let (server, rx) = crate::substrate::transport::tcp::TcpServer::bind(bind)?;
    let addr = server.addr;
    let handle = std::thread::Builder::new()
        .name("dhub-tcp".into())
        .spawn(move || serve(state, rx, cfg))
        .expect("spawn dhub");
    Ok((addr, server, handle))
}

/// Arc-wrapped counters helper for sharing with benches.
pub fn counters() -> Arc<ServerCounters> {
    Arc::new(ServerCounters::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dwork::client::{Client, StealBatch};
    use crate::coordinator::dwork::messages::{Completion, CreateItem, TaskMsg};
    use crate::substrate::transport::ClientConn;

    /// Acquire exactly one task, asserting the hub still has work.
    fn take_one(c: &mut Client) -> TaskMsg {
        match c.acquire(1).unwrap() {
            StealBatch::Tasks(mut ts) if ts.len() == 1 => ts.pop().unwrap(),
            other => panic!("expected one task, got {other:?}"),
        }
    }

    #[test]
    fn inproc_end_to_end() {
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let out = c
            .submit(&[
                CreateItem::new(TaskMsg::new("a", vec![1]), vec![]),
                CreateItem::new(TaskMsg::new("b", vec![2]), vec!["a".to_string()]),
            ])
            .unwrap();
        assert_eq!(out.len(), 2);
        assert!(out.iter().all(|o| o.is_created()));
        let t = take_one(&mut c);
        assert_eq!(t.name, "a");
        c.report(&[Completion::ok(&t.name)]).unwrap();
        let t = take_one(&mut c);
        assert_eq!(t.name, "b");
        c.report(&[Completion::ok(&t.name)]).unwrap();
        assert!(matches!(c.acquire(1).unwrap(), StealBatch::AllDone), "all done => Exit");
        drop(c);
        drop(connector);
        let state = handle.join().unwrap();
        assert!(state.all_done());
    }

    #[test]
    fn malformed_request_gets_err_reply() {
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut raw = connector.connect();
        let reply = raw.request(&[0xde, 0xad]).unwrap();
        match super::super::messages::Response::decode(&reply).unwrap() {
            super::super::messages::Response::Err { code, .. } => assert!(code.is_none()),
            other => panic!("expected Err, got {other:?}"),
        }
        drop(raw);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn snapshot_fires_only_on_actual_mutation() {
        // regression: the auto-snapshot gate used to evaluate
        // `mutations % snapshot_every == 0` on EVERY request, so
        // non-mutating traffic (Status, malformed frames) re-triggered
        // state.save() whenever the counter sat at a multiple — including
        // at mutations == 0, before anything had happened
        use crate::substrate::kvstore::KvStore;
        let dir = std::env::temp_dir()
            .join(format!("threesched-dwork-snap-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let kv = KvStore::open(&dir).unwrap();
        let state = SchedState::with_store(kv);
        let snap = dir.join("snapshot.kv");
        let (connector, handle) = spawn_inproc(
            state,
            ServerConfig { snapshot_every: 2, ..ServerConfig::default() },
        );
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        // reads and failed steals at mutations == 0 must not snapshot
        for _ in 0..3 {
            c.status().unwrap();
        }
        assert!(matches!(c.acquire(1).unwrap(), StealBatch::Tasks(ts) if ts.is_empty()));
        assert!(!snap.exists(), "non-mutating requests triggered the auto-snapshot");
        // one single-item batch frame = one mutation against the gate
        c.submit(&[CreateItem::new(TaskMsg::new("a", vec![]), vec![])]).unwrap(); // mutation 1
        c.status().unwrap();
        assert!(!snap.exists(), "snapshot fired before the interval elapsed");
        c.submit(&[CreateItem::new(TaskMsg::new("b", vec![]), vec![])]).unwrap(); // mutation 2 -> snapshot
        c.status().unwrap(); // round-trip: snapshot already written when this returns
        assert!(snap.exists(), "snapshot missing after snapshot_every mutations");
        // with the counter parked at a multiple, reads must not re-save
        std::fs::remove_file(&snap).unwrap();
        for _ in 0..3 {
            c.status().unwrap();
        }
        assert!(!snap.exists(), "reads at a counter multiple re-triggered the snapshot");
        drop(c);
        drop(connector);
        handle.join().unwrap();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn empty_hub_parks_workers_instead_of_dismissing() {
        // a worker that joins a freshly served hub (no submissions yet)
        // must be told "nothing ready yet", not "all done, go away"
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "early-bird");
        match c.acquire(4).unwrap() {
            StealBatch::Tasks(ts) => assert!(ts.is_empty()),
            other => panic!("empty hub dismissed the worker: {other:?}"),
        }
        // once fed and drained, the hub does dismiss
        c.submit(&[CreateItem::new(TaskMsg::new("only", vec![]), vec![])]).unwrap();
        let t = take_one(&mut c);
        c.report(&[Completion::ok(&t.name)]).unwrap();
        assert!(matches!(c.acquire(1).unwrap(), StealBatch::AllDone));
        drop(c);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn metrics_request_snapshots_live_hub_counters() {
        let metrics = crate::metrics::Registry::enabled();
        let cfg = ServerConfig { metrics: metrics.clone(), ..ServerConfig::default() };
        let (connector, handle) = spawn_inproc(SchedState::new(), cfg);
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        c.submit(&[
            CreateItem::new(TaskMsg::new("a", vec![]), vec![]),
            CreateItem::new(TaskMsg::new("b", vec![]), vec!["a".to_string()]),
        ])
        .unwrap();
        let t = take_one(&mut c);
        c.report(&[Completion::ok(&t.name)]).unwrap();
        let snap = c.metrics().unwrap();
        assert_eq!(snap.version, crate::metrics::MetricsSnapshot::VERSION);
        // the batched surface costs ONE create frame for the whole
        // submission and one complete frame per report
        assert_eq!(snap.counter("requests_create_batch"), 1);
        assert_eq!(snap.counter("requests_complete_batch"), 1);
        assert_eq!(snap.counter("requests_steal_n"), 1);
        assert_eq!(snap.counter("tasks_created"), 2);
        assert_eq!(snap.counter("tasks_completed"), 1);
        assert_eq!(snap.counter("steals_served"), 1);
        assert_eq!(snap.counter("workers_attached"), 1);
        assert_eq!(snap.gauge("workers_connected"), 1);
        assert_eq!(snap.gauge("queue_depth"), 1, "b became ready when a completed");
        assert_eq!(snap.gauge("tasks_inflight"), 0);
        let svc = snap.hist("service_create_batch").expect("create-batch service histogram");
        assert_eq!(svc.count, 1, "service time observed per wire message, not per task");
        // worker exit flips the population series
        let t = take_one(&mut c);
        c.report(&[Completion::ok(&t.name)]).unwrap();
        assert!(matches!(c.acquire(1).unwrap(), StealBatch::AllDone), "all done => Exit");
        c.exit().unwrap();
        drop(c);
        drop(connector);
        handle.join().unwrap();
        let snap = metrics.snapshot();
        assert_eq!(snap.counter("workers_exited"), 1);
        assert_eq!(snap.gauge("workers_connected"), 0);
        assert_eq!(snap.counter("tasks_completed"), 2);
    }

    #[test]
    fn disabled_metrics_request_answers_version_zero() {
        // a hub served without an enabled registry still answers the
        // Metrics request — with the version-0 "disabled" sentinel —
        // so `dhub top` can say "metrics off" instead of erroring
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let snap = c.metrics().unwrap();
        assert_eq!(snap.version, 0);
        assert!(snap.counters.is_empty());
        drop(c);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn subscribe_long_poll_streams_lifecycle() {
        use crate::trace::EventKind;
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut tail = Client::new(Box::new(connector.connect()), "tail0");
        // first poll registers the subscriber; nothing is retroactive
        let b = tail.subscribe("", 0).unwrap();
        assert!(b.events.is_empty());
        assert!(!b.done, "empty hub is not 'done'");
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        c.submit(&[CreateItem::new(TaskMsg::new("a", vec![]), vec![])]).unwrap();
        let t = take_one(&mut c);
        c.report(&[Completion::ok(&t.name)]).unwrap();
        let b = tail.subscribe("", 0).unwrap();
        assert_eq!(b.dropped, 0);
        let kinds: Vec<EventKind> = b.events.iter().map(|e| e.kind).collect();
        assert_eq!(
            kinds,
            vec![
                EventKind::Created,
                EventKind::Ready,
                EventKind::Launched,
                EventKind::Finished
            ]
        );
        assert!(b.events.iter().all(|e| e.task == "a"));
        assert!(b.done, "graph fully drained");
        // Exit detaches the subscription server-side
        tail.exit().unwrap();
        drop(tail);
        drop(c);
        drop(connector);
        let state = handle.join().unwrap();
        assert_eq!(state.subscriber_count(), 0);
    }

    #[test]
    fn tcp_end_to_end() {
        let (addr, _guard, _handle) =
            spawn_tcp(SchedState::new(), ServerConfig::default(), "127.0.0.1:0").unwrap();
        let conn =
            crate::substrate::transport::tcp::TcpClient::connect(&addr.to_string()).unwrap();
        let mut c = Client::new(Box::new(conn), "w0");
        c.submit(&[CreateItem::new(TaskMsg::new("t1", b"payload".to_vec()), vec![])]).unwrap();
        let t = take_one(&mut c);
        assert_eq!(t.body, b"payload");
        c.report(&[Completion::ok(&t.name)]).unwrap();
        let st = c.status().unwrap();
        assert_eq!(st.completed, 1);
    }

    #[test]
    fn single_shot_kinds_still_served() {
        // wire compatibility: an old client speaking per-task Create /
        // Steal / Complete must keep working against the batch-era hub
        use super::super::messages::{Request, Response};
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut raw = connector.connect();
        let rt = |raw: &mut dyn ClientConn, req: &Request| {
            Response::decode(&raw.request(&req.encode()).unwrap()).unwrap()
        };
        let r = rt(
            &mut raw,
            &Request::Create { task: TaskMsg::new("solo", vec![7]), deps: vec![] },
        );
        assert!(matches!(r, Response::Ok), "{r:?}");
        let r = rt(&mut raw, &Request::Steal { worker: "old-worker".into() });
        let Response::Task(t) = r else { panic!("expected Task, got {r:?}") };
        assert_eq!(t.name, "solo");
        assert_eq!(t.body, vec![7]);
        let r = rt(
            &mut raw,
            &Request::Complete { worker: "old-worker".into(), task: "solo".into(), success: true },
        );
        assert!(matches!(r, Response::Ok), "{r:?}");
        drop(raw);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn batch_frame_never_answers_whole_frame_err() {
        // the degrade contract: clients treat a whole-frame Err to a
        // batch kind as "pre-batch hub".  A current hub must therefore
        // answer Response::Batch even when EVERY item is refused.
        use super::super::messages::{BatchItem, Request, Response};
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut raw = connector.connect();
        let req = Request::CreateBatch {
            items: vec![
                CreateItem::new(TaskMsg::new("x", vec![]), vec!["ghost".into()]),
                CreateItem::new(TaskMsg::new("y", vec![]), vec!["ghost".into()]),
            ],
        };
        let r = Response::decode(&raw.request(&req.encode()).unwrap()).unwrap();
        let Response::Batch(items) = r else { panic!("expected Batch, got {r:?}") };
        assert_eq!(items.len(), 2);
        assert!(items.iter().all(|i| !i.is_ok()));
        assert!(items.iter().all(|i| matches!(i, BatchItem::Err { .. })));
        drop(raw);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn session_delta_completions_apply_before_creates() {
        // one SubmitDelta frame both reports a finished task and hangs
        // new work off it — the hub must apply completions first so the
        // same-frame dependency resolves
        use super::super::messages::{Request, Response};
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut raw = connector.connect();
        let rt = |raw: &mut dyn ClientConn, req: &Request| {
            Response::decode(&raw.request(&req.encode()).unwrap()).unwrap()
        };
        let r = rt(&mut raw, &Request::OpenSession { session: "gen".into() });
        assert!(
            matches!(&r, Response::Session { session, cancelled: 0 } if session == "gen"),
            "{r:?}"
        );
        let r = rt(
            &mut raw,
            &Request::SubmitDelta {
                session: "gen".into(),
                worker: "w0".into(),
                creates: vec![CreateItem::new(TaskMsg::new("root", vec![]), vec![])],
                completions: vec![],
            },
        );
        let Response::Batch(items) = r else { panic!("expected Batch, got {r:?}") };
        assert!(items.iter().all(|i| i.is_ok()));
        // steal the qualified task like any worker would
        let r = rt(&mut raw, &Request::StealN { worker: "w0".into(), n: 1 });
        let Response::Tasks(ts) = r else { panic!("expected Tasks, got {r:?}") };
        assert_eq!(ts[0].session(), "gen");
        assert_eq!(ts[0].short_name(), "root");
        // the completion report spawns a child depending on the task it
        // just completed — one frame, completion applied first
        let r = rt(
            &mut raw,
            &Request::SubmitDelta {
                session: "gen".into(),
                worker: "w0".into(),
                creates: vec![CreateItem::new(
                    TaskMsg::new("child", vec![]),
                    vec!["root".into()],
                )],
                completions: vec![Completion::ok(&ts[0].name)],
            },
        );
        let Response::Batch(items) = r else { panic!("expected Batch, got {r:?}") };
        assert_eq!(items.len(), 2, "completion result + create result");
        assert!(items.iter().all(|i| i.is_ok()), "{items:?}");
        let r = rt(&mut raw, &Request::StealN { worker: "w0".into(), n: 1 });
        let Response::Tasks(ts) = r else { panic!("expected Tasks, got {r:?}") };
        assert_eq!(ts[0].short_name(), "child", "same-frame dependency resolved");
        drop(raw);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn compat_shim_answers_session_kinds_like_a_pre_session_hub() {
        use super::super::messages::{Request, Response};
        let cfg = ServerConfig { compat_pre_sessions: true, ..ServerConfig::default() };
        let (connector, handle) = spawn_inproc(SchedState::new(), cfg);
        let mut raw = connector.connect();
        for req in [
            Request::OpenSession { session: "s".into() },
            Request::CloseSession { session: "s".into() },
            Request::SubmitDelta {
                session: "s".into(),
                worker: String::new(),
                creates: vec![],
                completions: vec![],
            },
        ] {
            let r = Response::decode(&raw.request(&req.encode()).unwrap()).unwrap();
            match r {
                Response::Err { msg, code } => {
                    assert!(msg.contains("unknown request kind"), "{msg}");
                    assert!(code.is_none());
                }
                other => panic!("compat hub must whole-frame Err, got {other:?}"),
            }
        }
        // non-session traffic is served normally by the same hub
        let r = Response::decode(
            &raw.request(&Request::Create { task: TaskMsg::new("a", vec![]), deps: vec![] }.encode())
                .unwrap(),
        )
        .unwrap();
        assert!(matches!(r, Response::Ok), "{r:?}");
        drop(raw);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn sharded_hub_serves_batches_end_to_end() {
        let (connector, handle) =
            spawn_inproc(SchedState::with_shards(4), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "submitter");
        let items: Vec<CreateItem> = (0..64)
            .map(|i| CreateItem::new(TaskMsg::new(format!("t{i}"), vec![]), vec![]))
            .collect();
        let out = c.submit(&items).unwrap();
        assert!(out.iter().all(|o| o.is_created()));
        let mut done = 0;
        loop {
            match c.acquire(8).unwrap() {
                StealBatch::Tasks(ts) if ts.is_empty() => break,
                StealBatch::AllDone => break,
                StealBatch::Tasks(ts) => {
                    let report: Vec<Completion> =
                        ts.iter().map(|t| Completion::ok(&t.name)).collect();
                    done += report.len();
                    c.report(&report).unwrap();
                }
            }
        }
        assert_eq!(done, 64);
        drop(c);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }
}
