//! Per-session namespaces for the dwork hub (the Balsam-style
//! "continuously fed, multi-user task server" the ROADMAP calls for).
//!
//! A *session* is a named campaign sharing one hub with other
//! campaigns.  Internally a session task's key in the scheduler tables
//! is its short name qualified with the session prefix —
//! `"<session>\u{1f}<name>"` (see
//! [`super::messages::SESSION_SEP`]) — so two sessions can reuse the
//! same task names without colliding, failure propagation stays inside
//! one session (qualified dependencies can only name same-session
//! keys), and teardown can sweep exactly one campaign's rows.  The
//! *anonymous* session is the empty name: its task keys are the raw
//! task names, byte-identical to every pre-session hub, which is what
//! keeps the single-client serve order and snapshot bytes unchanged.
//!
//! This module owns the registry bookkeeping (per-session counters,
//! [`StatusInfo`](super::messages::StatusInfo) rows, KV persistence
//! records); the scheduler-table mutations live in
//! [`SchedState`](super::state::SchedState) next door because they need
//! the task/queue tables.
//!
//! Wire-format note: one known (and accepted) collision remains — an
//! anonymous task literally named `"alpha\u{1f}x"` shares a key with
//! session `alpha`'s task `x` and will be refused as a duplicate if
//! both exist.  `U+001F` is a C0 control character; no real task
//! namespace uses it, and session names themselves reject it.

use std::collections::HashMap;

use anyhow::{bail, Result};

use super::messages::{SessionRow, SESSION_SEP};
use crate::substrate::wire::{self, Reader, Writer};

/// KV key prefix for persisted session records (`s/<name>`), sibling to
/// the `t/` task table.
pub(crate) const SESSION_KEY_PREFIX: &str = "s/";

/// KV key for the snapshot format marker.  Absent on pre-session
/// snapshots; written (as [`FORMAT_SESSIONS`]) the first time a session
/// opens.  Older hubs only scan `t/` and ignore both this and the `s/`
/// rows, so the bump is backward- *and* forward-compatible.
pub(crate) const FORMAT_KEY: &[u8] = b"meta/format";
pub(crate) const FORMAT_SESSIONS: &[u8] = b"2";

/// The scheduler-table key for `name` inside `session` (the raw name
/// when the session is anonymous).
pub(crate) fn qualify(session: &str, name: &str) -> String {
    if session.is_empty() {
        name.to_string()
    } else {
        let mut key = String::with_capacity(session.len() + 1 + name.len());
        key.push_str(session);
        key.push(SESSION_SEP);
        key.push_str(name);
        key
    }
}

/// The short (user-facing) half of a possibly-qualified key.
pub(crate) fn short_of(key: &str) -> &str {
    match key.split_once(SESSION_SEP) {
        Some((_, short)) => short,
        None => key,
    }
}

/// Validate a session name at `OpenSession` time: non-empty, no
/// reserved separator, and no characters that would corrupt the
/// Prometheus label or the JSONL trace field (`"`/`\`/control chars).
pub(crate) fn validate_session_name(name: &str) -> Result<()> {
    if name.is_empty() {
        bail!("session name must not be empty (empty means the anonymous session)");
    }
    if name.contains(SESSION_SEP) {
        bail!("session name {name:?} contains the reserved separator U+001F");
    }
    if name.chars().any(|c| c.is_control() || c == '"' || c == '\\') {
        bail!("session name {name:?} contains a control or quoting character");
    }
    Ok(())
}

/// Live accounting for one open session.  `total` counts every create
/// accepted into the session; completed/errored/failed mirror the
/// global [`SchedState`](super::state::SchedState) counters scoped to
/// this namespace, so `total - completed - errored` is the session's
/// live population.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub(crate) struct SessionCounters {
    pub total: u64,
    pub completed: u64,
    pub errored: u64,
    pub failed: u64,
}

impl SessionCounters {
    pub fn live(&self) -> u64 {
        self.total.saturating_sub(self.completed + self.errored)
    }
}

/// The hub's open-session table: name → counters.  Purely bookkeeping —
/// every mutation is driven by `SchedState`, which owns the actual task
/// rows.
#[derive(Debug, Default)]
pub(crate) struct SessionRegistry {
    map: HashMap<String, SessionCounters>,
}

impl SessionRegistry {
    /// Open (or re-open) `name`; `true` if it was not already open.
    pub fn open(&mut self, name: &str) -> bool {
        if self.map.contains_key(name) {
            return false;
        }
        self.map.insert(name.to_string(), SessionCounters::default());
        true
    }

    pub fn remove(&mut self, name: &str) -> Option<SessionCounters> {
        self.map.remove(name)
    }

    pub fn is_open(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// Counters for `name`, opening it implicitly if needed (rebuild
    /// path: task rows may be scanned before their session record).
    pub fn ensure(&mut self, name: &str) -> &mut SessionCounters {
        self.map.entry(name.to_string()).or_default()
    }

    /// Counters for an already-open session; panics on a name the
    /// caller did not open (every `SchedState` path opens first).
    pub fn counters_mut(&mut self, name: &str) -> &mut SessionCounters {
        self.map.get_mut(name).expect("session counters for an unopened session")
    }

    pub fn counters(&self, name: &str) -> Option<&SessionCounters> {
        self.map.get(name)
    }

    /// Status rows, sorted by session name for a stable wire order.
    pub fn rows(&self) -> Vec<SessionRow> {
        let mut rows: Vec<SessionRow> = self
            .map
            .iter()
            .map(|(name, c)| SessionRow {
                name: name.clone(),
                total: c.total,
                completed: c.completed,
                errored: c.errored,
                failed: c.failed,
            })
            .collect();
        rows.sort_by(|a, b| a.name.cmp(&b.name));
        rows
    }

    pub fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.map.keys().cloned().collect();
        names.sort();
        names
    }
}

/// Persisted form of one `s/<name>` row.  Counters are *not* stored —
/// they are rebuilt from the task table on load, exactly like the ready
/// queue — so the record only pins the session's existence (a session
/// with zero live rows must survive a restart as "open").
pub(crate) fn encode_session_record(name: &str) -> Vec<u8> {
    let mut w = Writer::new();
    w.string(1, name);
    w.into_bytes()
}

pub(crate) fn decode_session_record(bytes: &[u8]) -> Result<String> {
    let fields = Reader::new(bytes).fields()?;
    Ok(wire::get_str(&fields, 1)?.to_string())
}

#[cfg(test)]
mod tests {
    use super::super::state::{SchedState, TaskState};
    use super::super::messages::{RefusalCode, TaskMsg};
    use super::*;
    use crate::metrics::{Counter, Gauge, Registry};
    use crate::substrate::kvstore::KvStore;
    use crate::trace::{EventKind, Tracer};

    fn t(name: &str) -> TaskMsg {
        TaskMsg::new(name, vec![])
    }

    fn deps(names: &[&str]) -> Vec<String> {
        names.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn qualify_and_short_roundtrip() {
        assert_eq!(qualify("", "a"), "a");
        let key = qualify("alpha", "a");
        assert_eq!(key, format!("alpha{SESSION_SEP}a"));
        assert_eq!(short_of(&key), "a");
        assert_eq!(short_of("plain"), "plain");
    }

    #[test]
    fn session_name_validation() {
        assert!(validate_session_name("alpha-1").is_ok());
        assert!(validate_session_name("").is_err());
        assert!(validate_session_name(&format!("a{SESSION_SEP}b")).is_err());
        assert!(validate_session_name("a\"b").is_err());
        assert!(validate_session_name("a\\b").is_err());
        assert!(validate_session_name("a\nb").is_err());
    }

    #[test]
    fn same_task_name_in_two_sessions_is_not_a_duplicate() {
        let mut s = SchedState::new();
        s.open_session("alpha").unwrap();
        s.open_session("beta").unwrap();
        s.create_in_session("alpha", t("a"), &[]).unwrap();
        s.create_in_session("beta", t("a"), &[]).unwrap();
        // ...but within one session it still is
        let err = s.create_in_session("alpha", t("a"), &[]).unwrap_err();
        assert_eq!(err.code, RefusalCode::Duplicate);
        assert_eq!(s.len(), 2);
        let rows = s.status().sessions;
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.total == 1 && r.live() == 1));
    }

    #[test]
    fn incremental_deltas_depend_on_done_and_inflight_tasks() {
        let mut s = SchedState::new();
        s.create_in_session("inc", t("done"), &[]).unwrap();
        s.create_in_session("inc", t("flight"), &[]).unwrap();
        let got = s.steal("w0", 2);
        assert_eq!(got.len(), 2);
        s.complete("w0", &got[0].name, true).unwrap();
        // new work may hang off an already-finished task (join counts as
        // satisfied immediately) or an in-flight one (normal waiting)
        s.create_in_session("inc", t("after-done"), &deps(&["done"])).unwrap();
        s.create_in_session("inc", t("after-flight"), &deps(&["flight"])).unwrap();
        assert_eq!(s.get(&qualify("inc", "after-done")).unwrap().state, TaskState::Ready);
        assert_eq!(s.get(&qualify("inc", "after-flight")).unwrap().state, TaskState::Waiting);
        s.complete("w0", &got[1].name, true).unwrap();
        assert_eq!(s.get(&qualify("inc", "after-flight")).unwrap().state, TaskState::Ready);
    }

    #[test]
    fn failure_propagation_stays_inside_the_session() {
        let metrics = Registry::enabled();
        let mut s = SchedState::new();
        s.set_metrics(metrics.clone());
        s.create_in_session("bad", t("root"), &[]).unwrap();
        s.create_in_session("bad", t("child"), &deps(&["root"])).unwrap();
        s.create_in_session("good", t("root"), &[]).unwrap();
        let got = s.steal("w0", 8);
        assert_eq!(got.len(), 2, "one ready root per session");
        for msg in &got {
            let ok = msg.session() != "bad";
            s.complete("w0", &msg.name, ok).unwrap();
        }
        let status = s.status();
        let bad = status.sessions.iter().find(|r| r.name == "bad").unwrap();
        let good = status.sessions.iter().find(|r| r.name == "good").unwrap();
        assert_eq!((bad.errored, bad.failed, bad.live()), (2, 1, 0));
        assert_eq!((good.completed, good.errored, good.live()), (1, 0, 0));
        assert_eq!(metrics.session_gauge("bad"), Some(0));
        assert_eq!(metrics.session_gauge("good"), Some(0));
    }

    #[test]
    fn close_session_sweeps_only_its_own_rows() {
        let metrics = Registry::enabled();
        let tracer = Tracer::memory();
        let mut s = SchedState::new();
        s.set_metrics(metrics.clone());
        s.set_tracer(tracer.clone());
        // session "doomed": one done, one assigned, one ready, one waiting
        s.create_in_session("doomed", t("d0"), &[]).unwrap();
        s.create_in_session("doomed", t("d1"), &[]).unwrap();
        s.create_in_session("doomed", t("d2"), &[]).unwrap();
        s.create_in_session("doomed", t("d3"), &deps(&["d2"])).unwrap();
        // session "alive" plus an anonymous task
        s.create_in_session("alive", t("a0"), &[]).unwrap();
        s.create(t("anon"), &[]).unwrap();
        let got = s.steal("w0", 2); // d0, d1 (FIFO)
        assert_eq!(got.len(), 2);
        s.complete("w0", &got[0].name, true).unwrap();
        assert_eq!(s.ready_len(), 3); // d2, a0, anon

        let cancelled = s.close_session("doomed").unwrap();
        assert_eq!(cancelled, 3, "assigned d1 + ready d2 + waiting d3");
        assert_eq!(s.len(), 2, "alive/a0 and anon remain");
        assert_eq!(s.ready_len(), 2);
        assert!(s.status().sessions.iter().all(|r| r.name == "alive"));
        assert_eq!(metrics.counter(Counter::TasksCancelled), 3);
        assert_eq!(metrics.gauge(Gauge::SessionsOpen), 1);
        assert_eq!(metrics.gauge(Gauge::Inflight), 0, "swept assigned task left inflight");
        assert_eq!(metrics.session_gauge("doomed"), None);
        // closing again is a no-op
        assert_eq!(s.close_session("doomed").unwrap(), 0);
        // the straggler completion for swept-while-assigned d1 is
        // silently absorbed, not an error and not double-counted
        s.complete("w0", &got[1].name, true).unwrap();
        assert_eq!(s.status().completed, 0, "doomed's terminal counts were subtracted");
        // the other campaign drains normally
        let rest = s.steal("w0", 8);
        assert_eq!(rest.len(), 2);
        for m in &rest {
            s.complete("w0", &m.name, true).unwrap();
        }
        assert!(s.all_done());
        // cancelled tasks got terminal Failed events, so the trace of the
        // swept session is still well-formed
        let events = tracer.drain();
        let d2_failed = events.iter().any(|e| {
            e.session == "doomed" && e.task == "d2" && e.kind == EventKind::Failed
        });
        assert!(d2_failed, "swept ready task traced a terminal event");
    }

    #[test]
    fn sessions_persist_and_counters_rebuild() {
        let path =
            std::env::temp_dir().join(format!("threesched-sessions-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        {
            let kv = KvStore::open(&path).unwrap();
            let mut s = SchedState::with_store(kv);
            s.open_session("idle").unwrap();
            s.create_in_session("work", t("a"), &[]).unwrap();
            s.create_in_session("work", t("b"), &deps(&["a"])).unwrap();
            let got = s.steal("w0", 1);
            s.complete("w0", &got[0].name, true).unwrap();
            s.save().unwrap();
        }
        let kv = KvStore::open(&path).unwrap();
        let mut s = SchedState::with_store(kv);
        assert!(s.session_is_open("idle"), "empty session survives restart");
        assert!(s.session_is_open("work"));
        let rows = s.status().sessions;
        let work = rows.iter().find(|r| r.name == "work").unwrap();
        assert_eq!((work.total, work.completed, work.live()), (2, 1, 1));
        let idle = rows.iter().find(|r| r.name == "idle").unwrap();
        assert_eq!(idle.total, 0);
        // the rebuilt hub serves the surviving task under its session key
        let got = s.steal("w1", 4);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].session(), "work");
        assert_eq!(got[0].short_name(), "b");
        s.complete("w1", &got[0].name, true).unwrap();
        assert!(s.all_done());
        let _ = std::fs::remove_dir_all(&path);
    }

    #[test]
    fn pre_session_snapshot_loads_as_all_anonymous() {
        let path =
            std::env::temp_dir().join(format!("threesched-presess-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&path);
        {
            let kv = KvStore::open(&path).unwrap();
            let mut s = SchedState::with_store(kv);
            s.create(t("x"), &[]).unwrap();
            s.save().unwrap();
            // pre-session snapshots have no s/ rows and no format marker —
            // this one is indistinguishable from one written by PR 9
        }
        let kv = KvStore::open(&path).unwrap();
        let mut s = SchedState::with_store(kv);
        assert_eq!(s.status().sessions.len(), 0);
        let got = s.steal("w0", 1);
        assert_eq!(got[0].name, "x");
        assert_eq!(got[0].session(), "");
        let _ = std::fs::remove_dir_all(&path);
    }

    #[test]
    fn session_record_roundtrip() {
        let rec = encode_session_record("α-campaign");
        assert_eq!(decode_session_record(&rec).unwrap(), "α-campaign");
        assert!(decode_session_record(b"\xff\xff").is_err());
    }

    #[test]
    fn create_in_session_refuses_bad_session_names() {
        let mut s = SchedState::new();
        let err = s
            .create_in_session(&format!("a{SESSION_SEP}b"), t("x"), &[])
            .unwrap_err();
        assert_eq!(err.code, RefusalCode::BadSession);
        assert_eq!(s.len(), 0);
    }
}
