//! dwork: a client/server bag-of-tasks scheduler (paper sec. 2.2).
//!
//! A single server (dhub) owns the task graph; workers pull named tasks
//! over a request/reply transport.  The synchronization contract is the
//! server's: a task is served only after every dependency completed.
//! FIFO double-ended queue, front re-insertion on Transfer, fault
//! tolerance via Exit, persistence via the KV-store tables, and the two
//! scalability extensions the paper names: Steal-n batching and the
//! rack-leader forwarding tree.

pub mod client;
pub mod forwarder;
pub mod messages;
pub mod server;
pub mod sessions;
pub mod state;

pub use client::{
    run_worker, run_worker_opts, Client, EventBatch, ServerError, StealBatch, SubmitOutcome,
    WorkerOpts, WorkerStats,
};
pub use messages::{
    BatchItem, Completion, CreateItem, RefusalCode, Request, Response, SessionRow, StatusInfo,
    TaskMsg,
};
pub use server::{serve, spawn_inproc, spawn_tcp, ServerConfig};
pub use state::{CreateError, SchedState, TaskState};
