//! dwork scheduler state: the task graph tables + double-ended ready queue.
//!
//! Mirrors the paper's dhub internals (sec. 2.2):
//!
//! * two tables — join counters + successors per task, and task metadata —
//!   persisted write-through into the TKRZW-substitute [`KvStore`];
//! * "other run-time information, such as the list of tasks ready to run,
//!   can be generated from these tables on startup" — exactly what
//!   [`SchedState::rebuild`] does;
//! * FIFO assignment with *front* re-insertion for transferred tasks: "the
//!   same double-ended queue setup used for work-stealing" — optionally
//!   split into N hash-keyed shards ([`SchedState::with_shards`]) with
//!   cross-shard stealing on miss, so hundreds of concurrent workers stop
//!   serializing on one deque; N = 1 (the default) is today's behavior;
//! * the server never serves a task whose dependencies are incomplete;
//! * `Exit` moves a dead worker's assignments back into the ready pool.

use std::collections::{HashMap, HashSet, VecDeque};
use std::time::Instant;

use anyhow::{bail, Result};

use crate::metrics::{Counter, Gauge, Registry};
use crate::substrate::kvstore::KvStore;
use crate::substrate::wire::{self, Reader, Writer};
use crate::trace::{EventKind, TaskEvent, Tracer};

use super::messages::{RefusalCode, StatusInfo, TaskMsg, SESSION_SEP};
use super::sessions::{
    decode_session_record, encode_session_record, qualify, short_of, validate_session_name,
    SessionRegistry, FORMAT_KEY, FORMAT_SESSIONS, SESSION_KEY_PREFIX,
};

/// The legacy marker phrases pre-code clients used to substring-match
/// in Create refusal messages.  The typed-refusal protocol
/// ([`RefusalCode`] on the wire) is the only classification now: the
/// submitter-side string fallback went first (PR 4), and the
/// server-side embedding of these phrases followed once its
/// compatibility window elapsed — refusal text is free-form again.
/// Kept crate-private solely for the pinning tests, which assert the
/// server no longer relies on (or emits) them.
#[allow(dead_code)] // referenced only from the pinning tests
pub(crate) const ERR_MARKER_DUPLICATE: &str = "already exists";
#[allow(dead_code)] // referenced only from the pinning tests
pub(crate) const ERR_MARKER_DEP_ERRORED: &str = "error state";

/// A refused Create: the typed classification plus a free-form
/// human-readable message.
#[derive(Debug)]
pub struct CreateError {
    pub code: RefusalCode,
    msg: String,
}

impl CreateError {
    fn new(code: RefusalCode, msg: String) -> CreateError {
        CreateError { code, msg }
    }
}

impl std::fmt::Display for CreateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for CreateError {}

/// Lifecycle of a task (paper Fig 2 semantics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TaskState {
    /// has unfinished dependencies
    Waiting,
    /// ready to be stolen
    Ready,
    /// assigned to a worker
    Assigned,
    /// completed successfully
    Done,
    /// failed, or depends (transitively) on a failed task
    Error,
}

impl TaskState {
    fn to_u8(self) -> u8 {
        match self {
            TaskState::Waiting => 0,
            TaskState::Ready => 1,
            TaskState::Assigned => 2,
            TaskState::Done => 3,
            TaskState::Error => 4,
        }
    }

    fn from_u8(v: u8) -> TaskState {
        match v {
            0 => TaskState::Waiting,
            1 => TaskState::Ready,
            2 => TaskState::Assigned,
            3 => TaskState::Done,
            _ => TaskState::Error,
        }
    }
}

/// One task's full record (both paper tables merged per key).
#[derive(Clone, Debug)]
pub struct TaskEntry {
    pub msg: TaskMsg,
    pub state: TaskState,
    /// unfinished-dependency count; serve only when 0
    pub join: u32,
    /// tasks to notify on completion
    pub successors: Vec<String>,
    /// creation sequence — FIFO order survives restart through this
    pub seq: u64,
    /// front-of-queue flag for transferred (re-inserted) tasks
    pub reinserted: bool,
    /// a worker attempted this task and reported failure (distinguishes
    /// it from successors errored by propagation, which never ran)
    pub failed: bool,
    /// owning session name; empty for the anonymous session.  Redundant
    /// with the `SESSION_SEP` prefix of `msg.name` for well-formed keys,
    /// but authoritative: it is what teardown sweeps and counters key on.
    pub session: String,
}

impl TaskEntry {
    fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(64);
        w.string(1, &self.msg.name);
        w.bytes(2, &self.msg.body);
        w.string(3, &self.msg.originator);
        w.uint(4, self.state.to_u8() as u64);
        w.uint(5, self.join as u64);
        w.strings(6, self.successors.iter().map(String::as_str));
        w.uint(7, self.seq);
        w.uint(8, self.reinserted as u64);
        w.uint(9, self.failed as u64);
        // snapshot format 2: omitted for anonymous tasks, which keeps
        // pre-session snapshots byte-identical
        if !self.session.is_empty() {
            w.string(10, &self.session);
        }
        w.into_bytes()
    }

    fn decode(bytes: &[u8]) -> Result<TaskEntry> {
        let fields = Reader::new(bytes).fields()?;
        Ok(TaskEntry {
            msg: TaskMsg {
                name: wire::get_str(&fields, 1)?.to_string(),
                body: fields
                    .iter()
                    .find(|(f, _)| *f == 2)
                    .and_then(|(_, v)| v.as_bytes())
                    .unwrap_or_default()
                    .to_vec(),
                originator: wire::get_str(&fields, 3).unwrap_or_default().to_string(),
            },
            state: TaskState::from_u8(wire::get_u64(&fields, 4)? as u8),
            join: wire::get_u64(&fields, 5)? as u32,
            successors: wire::get_strs(&fields, 6).into_iter().map(str::to_string).collect(),
            seq: wire::get_u64(&fields, 7)?,
            reinserted: wire::get_u64(&fields, 8).unwrap_or(0) != 0,
            failed: wire::get_u64(&fields, 9).unwrap_or(0) != 0,
            // absent on pre-session (format 1) records: anonymous
            session: wire::get_str(&fields, 10).unwrap_or_default().to_string(),
        })
    }
}

/// Per-subscriber queue cap: a tail that stops draining loses the
/// *oldest* events (drop-oldest) and learns how many via the `dropped`
/// count in every [`super::messages::Response::Events`] reply — the
/// serve loop never blocks on a slow consumer.
pub(crate) const SUB_QUEUE_CAP: usize = 8192;

/// Events handed out per Subscribe long-poll when the client asks for
/// `max == 0` ("server default").
pub(crate) const SUB_BATCH_DEFAULT: usize = 1024;

/// One live subscriber's pending events plus its task-name filter.
struct SubQueue {
    q: VecDeque<TaskEvent>,
    prefix: String,
    dropped: u64,
}

/// Fan-out side of live event streaming (`dhub tail`).  Plain fields,
/// no atomics: the hub serve loop is single-threaded, and the long-poll
/// protocol means subscribers only ever touch this through requests the
/// same loop serves.  With no subscribers attached, the only cost per
/// lifecycle event is one `is_empty` branch — zero allocations.
#[derive(Default)]
struct EventHub {
    subs: HashMap<String, SubQueue>,
    /// hub-stamped monotone sequence across all fanned-out events
    seq: u64,
    /// timestamp epoch when no tracer is attached; set lazily at the
    /// first subscribe so idle hubs never read the clock
    epoch: Option<Instant>,
}

/// FNV-1a over a name: a stable, dependency-free hash so shard
/// assignment is identical across runs, platforms, and restarts
/// (`DefaultHasher` guarantees none of that).
pub(crate) fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// The ready pool, split into N shards keyed by task-name hash.  Each
/// shard is the same double-ended queue the paper describes (FIFO
/// `push_back`, front re-insertion for transferred/requeued tasks); a
/// steal drains the worker's home shard first and work-steals from the
/// other shards on miss, so concurrent workers mostly touch disjoint
/// deques.  `N = 1` collapses to exactly the single-deque behavior the
/// pre-shard tests pin.
struct ReadyQueue {
    shards: Vec<VecDeque<String>>,
}

impl ReadyQueue {
    fn new(shards: usize) -> ReadyQueue {
        ReadyQueue { shards: vec![VecDeque::new(); shards.max(1)] }
    }

    fn shard_of(&self, name: &str) -> usize {
        if self.shards.len() == 1 {
            0
        } else {
            (fnv1a(name) % self.shards.len() as u64) as usize
        }
    }

    /// A worker's preferred shard — same hash family as the tasks, so
    /// distinct workers spread across shards.
    fn home(&self, worker: &str) -> usize {
        if self.shards.len() == 1 {
            0
        } else {
            (fnv1a(worker) % self.shards.len() as u64) as usize
        }
    }

    fn len(&self) -> usize {
        self.shards.iter().map(VecDeque::len).sum()
    }

    fn push_back(&mut self, name: String) {
        let i = self.shard_of(&name);
        self.shards[i].push_back(name);
    }

    fn push_front(&mut self, name: String) {
        let i = self.shard_of(&name);
        self.shards[i].push_front(name);
    }

    /// Pop one ready task for a worker whose home shard is `home`: the
    /// home shard first, then the others in wrap-around order
    /// (work-stealing on miss).
    fn pop_for(&mut self, home: usize) -> Option<String> {
        let n = self.shards.len();
        for k in 0..n {
            if let Some(name) = self.shards[(home + k) % n].pop_front() {
                return Some(name);
            }
        }
        None
    }

    /// Targeted removal (error propagation): only the owning shard is
    /// scanned.
    fn remove(&mut self, name: &str) {
        let i = self.shard_of(name);
        self.shards[i].retain(|r| r != name);
    }
}

/// The scheduler state machine.
pub struct SchedState {
    tasks: HashMap<String, TaskEntry>,
    ready: ReadyQueue,
    /// worker -> assigned task names
    assigned: HashMap<String, HashSet<String>>,
    kv: KvStore,
    seq: u64,
    completed: u64,
    errored: u64,
    /// subset of `errored` that a worker actually attempted
    failed: u64,
    /// lifecycle event recorder (no-op unless [`SchedState::set_tracer`])
    tracer: Tracer,
    /// live counters/gauges (no-op unless [`SchedState::set_metrics`])
    metrics: Registry,
    /// live event fan-out to `Subscribe` long-pollers (`dhub tail`)
    hub: EventHub,
    /// open-session registry (per-campaign namespaces and counters)
    sessions: SessionRegistry,
    /// task keys swept by [`SchedState::close_session`] while assigned:
    /// the worker still holds them and will report a completion the hub
    /// must absorb silently (once) instead of erroring the worker out
    orphaned: HashSet<String>,
}

impl SchedState {
    /// Fresh volatile state (single ready-queue shard).
    pub fn new() -> SchedState {
        SchedState::with_store(KvStore::in_memory())
    }

    /// Fresh volatile state with an `n`-sharded ready queue (`n = 1`
    /// reproduces [`SchedState::new`] exactly; 0 is clamped to 1).
    pub fn with_shards(n: usize) -> SchedState {
        SchedState::with_store_sharded(KvStore::in_memory(), n)
    }

    /// Ready-queue shard count this state was built with.
    pub fn shard_count(&self) -> usize {
        self.ready.shards.len()
    }

    /// Workflow-IR ingestion: a fresh volatile state pre-loaded with the
    /// graph's tasks (payloads in the task bodies, dependencies as join
    /// edges), ready for workers to drain.
    pub fn from_workflow(g: &crate::workflow::WorkflowGraph) -> Result<SchedState> {
        let mut s = SchedState::new();
        s.ingest_workflow(g)?;
        Ok(s)
    }

    /// Add every task of `g` to this state (topological creation order,
    /// as the Create API requires).  Composable: an already-running dhub
    /// can absorb a workflow next to hand-created tasks.
    pub fn ingest_workflow(&mut self, g: &crate::workflow::WorkflowGraph) -> Result<()> {
        for t in crate::workflow::lower::to_dwork(g)? {
            self.create(t.msg, &t.deps)?;
        }
        Ok(())
    }

    /// State backed by a persistent store; replays any existing records.
    pub fn with_store(kv: KvStore) -> SchedState {
        SchedState::with_store_sharded(kv, 1)
    }

    /// Persistent state with an `n`-sharded ready queue.  Shard count is
    /// run-time configuration, not persisted state: a restart may pick a
    /// different `n` and [`SchedState::rebuild`] redistributes.
    pub fn with_store_sharded(kv: KvStore, n: usize) -> SchedState {
        let mut s = SchedState {
            tasks: HashMap::new(),
            ready: ReadyQueue::new(n),
            assigned: HashMap::new(),
            kv,
            seq: 0,
            completed: 0,
            errored: 0,
            failed: 0,
            tracer: Tracer::default(),
            metrics: Registry::default(),
            hub: EventHub::default(),
            sessions: SessionRegistry::default(),
            orphaned: HashSet::new(),
        };
        s.rebuild();
        s
    }

    /// Attach a tracer: every lifecycle transition this state machine
    /// performs (Created/Ready/Launched/Finished/Failed/Requeued) is
    /// recorded from the server's vantage point.  Worker-side `Started`
    /// events come from [`super::client::run_worker_opts`] when the same
    /// tracer (or a clone) is handed to the workers.
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    /// Attach a live-metrics registry: task lifecycle counters
    /// (created/completed/failed/skipped/requeued) and the queue-depth /
    /// inflight gauges update at every transition this state machine
    /// performs.  The gauges are synced immediately so a registry
    /// attached to a rebuilt (restarted) hub starts truthful.
    pub fn set_metrics(&mut self, metrics: Registry) {
        self.metrics = metrics;
        self.metrics.gauge_set(Gauge::QueueDepth, self.ready.len() as i64);
        let inflight = self
            .tasks
            .values()
            .filter(|e| e.state == TaskState::Assigned)
            .count();
        self.metrics.gauge_set(Gauge::Inflight, inflight as i64);
        self.metrics.gauge_set(Gauge::SessionsOpen, self.sessions.len() as i64);
        for name in self.sessions.names() {
            self.sync_session_gauge(&name);
        }
    }

    /// Tasks in the ready deque right now — O(1), unlike the full
    /// [`SchedState::status`] scan, so monitors can poll it freely.
    pub fn ready_len(&self) -> usize {
        self.ready.len()
    }

    fn sync_queue_gauge(&self) {
        self.metrics.gauge_set(Gauge::QueueDepth, self.ready.len() as i64);
    }

    /// Refresh one session's labeled live-task gauge
    /// (`session_tasks_live{session="<name>"}`) from its counters.
    fn sync_session_gauge(&self, session: &str) {
        if !self.metrics.is_enabled() {
            return;
        }
        if let Some(c) = self.sessions.counters(session) {
            self.metrics.session_gauge_set(session, c.live() as i64);
        }
    }

    /// Record one lifecycle event: into the tracer (if attached) and
    /// into every live subscriber queue whose prefix matches.  Events
    /// carry the task's *short* name plus its session tag — never the
    /// `SESSION_SEP`-qualified internal key — so anonymous traces stay
    /// byte-identical to pre-session hubs.  With neither tracer nor
    /// subscribers this is two branches — no clock read, no allocation
    /// (pinned by `benches/trace_profile`).
    fn emit(&mut self, task: &str, kind: EventKind, who: &str) {
        let no_subs = self.hub.subs.is_empty();
        if !self.tracer.enabled() && no_subs {
            return;
        }
        // entry.session is authoritative; anonymous keys pass through
        // verbatim (including pathological names containing SESSION_SEP)
        let (session, short): (&str, &str) = match self.tasks.get(task) {
            Some(e) if !e.session.is_empty() => {
                (e.session.as_str(), &task[e.session.len() + SESSION_SEP.len_utf8()..])
            }
            _ => ("", task),
        };
        self.tracer.record_in_session(session, short, kind, who);
        if no_subs {
            return;
        }
        let t = if self.tracer.enabled() {
            self.tracer.now()
        } else {
            // epoch is set when the first subscriber attached
            self.hub.epoch.map_or(0.0, |e| e.elapsed().as_secs_f64())
        };
        let seq = self.hub.seq;
        self.hub.seq += 1;
        let ev = TaskEvent {
            task: short.to_string(),
            kind,
            t,
            who: who.to_string(),
            seq,
            session: session.to_string(),
        };
        for sub in self.hub.subs.values_mut() {
            if !ev.task.starts_with(sub.prefix.as_str()) {
                continue;
            }
            if sub.q.len() >= SUB_QUEUE_CAP {
                sub.q.pop_front();
                sub.dropped += 1;
                self.metrics.inc(Counter::SubscribeDropped);
            }
            sub.q.push_back(ev.clone());
        }
    }

    /// One Subscribe long-poll from `worker`: register it (first call)
    /// or update its filter, then hand back up to `max` queued events
    /// (0 = [`SUB_BATCH_DEFAULT`]) plus the number dropped since the
    /// last poll.  Only events emitted *after* registration are seen.
    pub fn subscribe_poll(
        &mut self,
        worker: &str,
        prefix: &str,
        max: usize,
    ) -> (Vec<TaskEvent>, u64) {
        if self.hub.epoch.is_none() {
            self.hub.epoch = Some(Instant::now());
        }
        // lookup-then-insert instead of entry(): an idle long-poll from a
        // registered subscriber must not allocate (the key clone entry()
        // requires would) — the parked-tail serve path is benched at zero
        if !self.hub.subs.contains_key(worker) {
            self.hub.subs.insert(
                worker.to_string(),
                SubQueue { q: VecDeque::new(), prefix: String::new(), dropped: 0 },
            );
        }
        let sub = self.hub.subs.get_mut(worker).expect("just inserted");
        if sub.prefix != prefix {
            sub.prefix = prefix.to_string();
        }
        let max = if max == 0 { SUB_BATCH_DEFAULT } else { max };
        let n = sub.q.len().min(max);
        let events: Vec<TaskEvent> = sub.q.drain(..n).collect();
        let dropped = std::mem::take(&mut sub.dropped);
        (events, dropped)
    }

    /// Drop `worker`'s subscription (its Exit, or a vanished tail).
    pub fn unsubscribe(&mut self, worker: &str) {
        self.hub.subs.remove(worker);
    }

    /// Live subscriber count (monitoring/tests).
    pub fn subscriber_count(&self) -> usize {
        self.hub.subs.len()
    }

    /// Regenerate run-time structures from the persisted tables (paper:
    /// rebuildable-on-startup design).  Assigned tasks return to ready:
    /// their workers did not survive the restart.
    fn rebuild(&mut self) {
        let mut entries: Vec<TaskEntry> = self
            .kv
            .scan_prefix(b"t/")
            .filter_map(|(_, v)| TaskEntry::decode(v).ok())
            .collect();
        entries.sort_by_key(|e| e.seq);
        // transferred tasks are persisted as front-of-queue re-insertions
        // (paper: "the same double-ended queue setup used for
        // work-stealing"); a restart must not silently demote them
        let mut front: Vec<String> = Vec::new();
        for mut e in entries {
            self.seq = self.seq.max(e.seq + 1);
            match e.state {
                TaskState::Done => self.completed += 1,
                TaskState::Error => {
                    self.errored += 1;
                    if e.failed {
                        self.failed += 1;
                    }
                }
                TaskState::Ready | TaskState::Assigned => {
                    // Assigned: worker is gone, back to the pool
                    e.state = TaskState::Ready;
                    if e.reinserted {
                        front.push(e.msg.name.clone());
                    } else {
                        self.ready.push_back(e.msg.name.clone());
                    }
                }
                TaskState::Waiting => {}
            }
            // per-session counters are derived state, regenerated from
            // the task rows exactly like the ready queue
            if !e.session.is_empty() {
                let c = self.sessions.ensure(&e.session);
                c.total += 1;
                match e.state {
                    TaskState::Done => c.completed += 1,
                    TaskState::Error => {
                        c.errored += 1;
                        if e.failed {
                            c.failed += 1;
                        }
                    }
                    _ => {}
                }
            }
            self.tasks.insert(e.msg.name.clone(), e);
        }
        // oldest re-inserted task ends up at the very front
        for name in front.into_iter().rev() {
            self.ready.push_front(name);
        }
        // re-open sessions persisted with zero surviving rows (an idle
        // but open campaign must not vanish across a restart)
        let names: Vec<String> = self
            .kv
            .scan_prefix(SESSION_KEY_PREFIX.as_bytes())
            .filter_map(|(_, v)| decode_session_record(v).ok())
            .collect();
        for name in names {
            self.sessions.ensure(&name);
        }
    }

    fn persist(&mut self, name: &str) {
        if let Some(e) = self.tasks.get(name) {
            let bytes = e.encode();
            let key = format!("t/{name}");
            let _ = self.kv.set(key.as_bytes(), &bytes);
        }
    }

    /// Ask the store to write a compact snapshot.
    pub fn save(&mut self) -> Result<()> {
        self.kv.save()
    }

    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    pub fn get(&self, name: &str) -> Option<&TaskEntry> {
        self.tasks.get(name)
    }

    /// Everything finished (done or error)?  Drives the Exit reply.
    pub fn all_done(&self) -> bool {
        self.completed + self.errored == self.tasks.len() as u64
    }

    pub fn status(&self) -> StatusInfo {
        let mut waiting = 0;
        let mut assigned = 0;
        for e in self.tasks.values() {
            match e.state {
                TaskState::Waiting => waiting += 1,
                TaskState::Assigned => assigned += 1,
                _ => {}
            }
        }
        StatusInfo {
            total: self.tasks.len() as u64,
            ready: self.ready.len() as u64,
            waiting,
            assigned,
            completed: self.completed,
            errored: self.errored,
            failed: self.failed,
            workers: self.assigned.iter().filter(|(_, t)| !t.is_empty()).count() as u64,
            sessions: self.sessions.rows(),
        }
    }

    /// Create a task with dependencies (paper Fig 2 `Create`) in the
    /// anonymous session.  Refusals are typed ([`CreateError::code`]) so
    /// the server can put the classification on the wire instead of
    /// leaving clients to parse message text.
    pub fn create(&mut self, msg: TaskMsg, deps: &[String]) -> Result<(), CreateError> {
        self.create_qualified(String::new(), msg, deps)
    }

    /// [`SchedState::create`] inside a named session: the task name and
    /// every dependency are qualified into the session's namespace, so
    /// deltas can only hang off same-session (or, with an empty session,
    /// anonymous) tasks.  Opens the session implicitly — a `SubmitDelta`
    /// is one round-trip, not open-then-submit.
    pub fn create_in_session(
        &mut self,
        session: &str,
        msg: TaskMsg,
        deps: &[String],
    ) -> Result<(), CreateError> {
        if session.is_empty() {
            return self.create_qualified(String::new(), msg, deps);
        }
        if let Err(e) = self.open_session(session) {
            return Err(CreateError::new(RefusalCode::BadSession, e.to_string()));
        }
        let mut msg = msg;
        msg.name = qualify(session, &msg.name);
        let deps: Vec<String> = deps.iter().map(|d| qualify(session, d)).collect();
        self.create_qualified(session.to_string(), msg, &deps)
    }

    /// The shared create core: `msg.name` and `deps` are already
    /// session-qualified keys and `session` is open (or empty).
    /// Refusal messages use the short names — the qualified form is an
    /// internal detail no user typed.
    fn create_qualified(
        &mut self,
        session: String,
        msg: TaskMsg,
        deps: &[String],
    ) -> Result<(), CreateError> {
        if self.tasks.contains_key(&msg.name) {
            return Err(CreateError::new(
                RefusalCode::Duplicate,
                format!("refusing duplicate create of task {:?}", short_of(&msg.name)),
            ));
        }
        let mut join = 0u32;
        for d in deps {
            match self.tasks.get(d) {
                None => {
                    return Err(CreateError::new(
                        RefusalCode::DepMissing,
                        format!("dependency {:?} does not exist", short_of(d)),
                    ))
                }
                Some(e) if e.state == TaskState::Error => {
                    return Err(CreateError::new(
                        RefusalCode::DepErrored,
                        format!(
                            "dependency {:?} failed earlier; the new task could never run",
                            short_of(d)
                        ),
                    ))
                }
                Some(e) if e.state == TaskState::Done => {}
                Some(_) => join += 1,
            }
        }
        let name = msg.name.clone();
        let entry = TaskEntry {
            msg,
            state: if join == 0 { TaskState::Ready } else { TaskState::Waiting },
            join,
            successors: Vec::new(),
            seq: self.seq,
            reinserted: false,
            failed: false,
            session: session.clone(),
        };
        self.seq += 1;
        self.tasks.insert(name.clone(), entry);
        // register as successor of each unfinished dependency
        let mut touched = Vec::new();
        for d in deps {
            let e = self.tasks.get_mut(d).unwrap();
            if e.state != TaskState::Done {
                e.successors.push(name.clone());
                touched.push(d.clone());
            }
        }
        self.emit(&name, EventKind::Created, "");
        self.metrics.inc(Counter::TasksCreated);
        if join == 0 {
            self.emit(&name, EventKind::Ready, "");
            self.ready.push_back(name.clone());
            self.sync_queue_gauge();
        }
        self.persist(&name);
        for d in touched {
            self.persist(&d);
        }
        if !session.is_empty() {
            self.sessions.counters_mut(&session).total += 1;
            self.sync_session_gauge(&session);
        }
        Ok(())
    }

    /// Pop up to `n` ready tasks for `worker` (paper `Steal`).  Returns an
    /// empty Vec when nothing is ready — the caller distinguishes
    /// NotFound/Exit via [`SchedState::all_done`].
    pub fn steal(&mut self, worker: &str, n: u32) -> Vec<TaskMsg> {
        let home = self.ready.home(worker);
        let mut out = Vec::new();
        for _ in 0..n {
            let Some(name) = self.ready.pop_for(home) else { break };
            let e = self.tasks.get_mut(&name).expect("ready task must exist");
            debug_assert_eq!(e.state, TaskState::Ready);
            e.state = TaskState::Assigned;
            out.push(e.msg.clone());
            self.emit(&name, EventKind::Launched, worker);
            self.assigned.entry(worker.to_string()).or_default().insert(name.clone());
            self.persist(&name);
        }
        if !out.is_empty() {
            self.metrics.gauge_add(Gauge::Inflight, out.len() as i64);
            self.sync_queue_gauge();
        }
        out
    }

    /// Mark `task` complete (paper `Complete`); on success, decrement
    /// successor join counters and promote them when they hit zero.  On
    /// failure, the task and (recursively) every transitive successor go
    /// to the error state — they can never run.
    pub fn complete(&mut self, worker: &str, task: &str, success: bool) -> Result<()> {
        // a report for a task swept by close_session while this worker
        // held it: absorb silently (once) — the worker did nothing wrong
        if self.orphaned.remove(task) {
            return Ok(());
        }
        let Some(e) = self.tasks.get(task) else {
            bail!("complete of unknown task {task:?}")
        };
        if e.state != TaskState::Assigned {
            bail!("complete of task {task:?} in state {:?}", e.state);
        }
        if let Some(set) = self.assigned.get_mut(worker) {
            set.remove(task);
        }
        self.metrics.gauge_add(Gauge::Inflight, -1);
        if success {
            let (succs, session) = {
                let e = self.tasks.get_mut(task).unwrap();
                e.state = TaskState::Done;
                (e.successors.clone(), e.session.clone())
            };
            self.completed += 1;
            if !session.is_empty() {
                self.sessions.counters_mut(&session).completed += 1;
                self.sync_session_gauge(&session);
            }
            self.metrics.inc(Counter::TasksCompleted);
            self.emit(task, EventKind::Finished, worker);
            self.persist(task);
            for s in succs {
                let promote = {
                    let se = self.tasks.get_mut(&s).expect("successor must exist");
                    se.join = se.join.saturating_sub(1);
                    se.join == 0 && se.state == TaskState::Waiting
                };
                if promote {
                    let front = {
                        let se = self.tasks.get_mut(&s).unwrap();
                        se.state = TaskState::Ready;
                        se.reinserted
                    };
                    self.emit(&s, EventKind::Ready, "");
                    // paper: re-inserted tasks go to the FRONT of the deque
                    if front {
                        self.ready.push_front(s.clone());
                    } else {
                        self.ready.push_back(s.clone());
                    }
                }
                self.persist(&s);
            }
            self.sync_queue_gauge();
        } else {
            // the root of the failure ran and failed; its successors are
            // errored by propagation without ever being attempted
            let e = self.tasks.get_mut(task).expect("checked above");
            e.failed = true;
            let session = e.session.clone();
            self.failed += 1;
            if !session.is_empty() {
                self.sessions.counters_mut(&session).failed += 1;
            }
            self.metrics.inc(Counter::TasksFailed);
            self.error_recursive(task, worker);
        }
        Ok(())
    }

    fn error_recursive(&mut self, task: &str, worker: &str) {
        let mut stack = vec![task.to_string()];
        while let Some(name) = stack.pop() {
            let (succs, session) = {
                let Some(e) = self.tasks.get_mut(&name) else { continue };
                if e.state == TaskState::Error {
                    continue;
                }
                if e.state == TaskState::Done {
                    continue; // already finished before the failure propagated
                }
                if e.state == TaskState::Ready {
                    // remove from the ready queue (owning shard only)
                    self.ready.remove(&name);
                }
                e.state = TaskState::Error;
                (e.successors.clone(), e.session.clone())
            };
            self.errored += 1;
            // qualified dependencies keep propagation inside one session,
            // so attributing per-task is bookkeeping, not a fan-out
            if !session.is_empty() {
                self.sessions.counters_mut(&session).errored += 1;
                self.sync_session_gauge(&session);
            }
            // the root was attempted by `worker`; propagated successors
            // never reached anyone
            let who = if name == task { worker } else { "" };
            if name != task {
                self.metrics.inc(Counter::TasksSkipped);
            }
            self.emit(&name, EventKind::Failed, who);
            stack.extend(succs);
            self.persist(&name);
        }
        self.sync_queue_gauge();
    }

    /// Replace a running task, adding new dependencies (paper `Transfer`).
    /// The task leaves its worker; when its new dependencies are complete
    /// it re-enters the queue at the *front*.
    pub fn transfer(&mut self, worker: &str, task: &str, new_deps: &[String]) -> Result<()> {
        let Some(e) = self.tasks.get(task) else {
            bail!("transfer of unknown task {task:?}")
        };
        if e.state != TaskState::Assigned {
            bail!("transfer of task {task:?} in state {:?}", e.state);
        }
        // cycle guard (user error per the paper — we detect instead of
        // deadlocking): reject a new dep that transitively depends on task
        for d in new_deps {
            if self.reaches(task, d) {
                bail!("transfer would create a cycle: {d:?} depends on {task:?}");
            }
        }
        if let Some(set) = self.assigned.get_mut(worker) {
            set.remove(task);
        }
        let mut join = 0u32;
        let mut touched = Vec::new();
        for d in new_deps {
            match self.tasks.get_mut(d) {
                None => bail!("new dependency {d:?} does not exist"),
                Some(de) if de.state == TaskState::Done => {}
                Some(de) => {
                    de.successors.push(task.to_string());
                    join += 1;
                    touched.push(d.clone());
                }
            }
        }
        let now_ready = {
            let e = self.tasks.get_mut(task).unwrap();
            e.join += join;
            e.reinserted = true;
            let now_ready = e.join == 0;
            e.state = if now_ready { TaskState::Ready } else { TaskState::Waiting };
            now_ready
        };
        self.emit(task, EventKind::Requeued, worker);
        self.metrics.inc(Counter::TasksRequeued);
        self.metrics.gauge_add(Gauge::Inflight, -1);
        if now_ready {
            self.emit(task, EventKind::Ready, "");
            self.ready.push_front(task.to_string());
        }
        self.sync_queue_gauge();
        self.persist(task);
        for d in touched {
            self.persist(&d);
        }
        Ok(())
    }

    /// Does `from`'s successor closure contain `to`?
    fn reaches(&self, from: &str, to: &str) -> bool {
        if from == to {
            return true;
        }
        let mut seen = HashSet::new();
        let mut stack = vec![from];
        while let Some(n) = stack.pop() {
            if !seen.insert(n.to_string()) {
                continue;
            }
            if let Some(e) = self.tasks.get(n) {
                for s in &e.successors {
                    if s == to {
                        return true;
                    }
                    stack.push(s.as_str());
                }
            }
        }
        false
    }

    /// A worker died or left (paper `Exit`): its assignments go back to
    /// the front of the ready pool (they are the oldest work in flight).
    /// Returns how many tasks were re-queued.
    pub fn exit_worker(&mut self, worker: &str) -> usize {
        let Some(tasks) = self.assigned.remove(worker) else { return 0 };
        let mut names: Vec<String> = tasks.into_iter().collect();
        // deterministic order: oldest first at the very front
        names.sort_by_key(|n| self.tasks.get(n).map(|e| e.seq).unwrap_or(u64::MAX));
        let mut requeued = 0;
        for name in names.into_iter().rev() {
            let was_assigned = self.tasks.get_mut(&name).is_some_and(|e| {
                if e.state == TaskState::Assigned {
                    e.state = TaskState::Ready;
                    true
                } else {
                    false
                }
            });
            if was_assigned {
                self.emit(&name, EventKind::Requeued, worker);
                self.emit(&name, EventKind::Ready, "");
                self.ready.push_front(name.clone());
                self.persist(&name);
                requeued += 1;
            }
        }
        if requeued > 0 {
            self.metrics.add(Counter::TasksRequeued, requeued as u64);
            self.metrics.gauge_add(Gauge::Inflight, -(requeued as i64));
            self.sync_queue_gauge();
        }
        requeued
    }

    /// Open (or re-open) a named session.  Idempotent: `Ok(true)` only
    /// when the session was not already open.  Persists an `s/<name>`
    /// row and stamps the snapshot format marker, so an idle session
    /// survives a restart.
    pub fn open_session(&mut self, session: &str) -> Result<bool> {
        validate_session_name(session)?;
        if !self.sessions.open(session) {
            return Ok(false);
        }
        let _ = self.kv.set(FORMAT_KEY, FORMAT_SESSIONS);
        let key = format!("{SESSION_KEY_PREFIX}{session}");
        let _ = self.kv.set(key.as_bytes(), &encode_session_record(session));
        self.metrics.inc(Counter::SessionsOpened);
        self.metrics.gauge_add(Gauge::SessionsOpen, 1);
        self.metrics.session_gauge_set(session, 0);
        Ok(true)
    }

    /// Tear a session down: cancel and forget every one of its tasks —
    /// live rows get a terminal `Failed` trace event so the session's
    /// trace stays well-formed, terminal rows just leave (their counts
    /// come off the global totals, since the rows back those totals).
    /// Other campaigns (and the anonymous namespace) are untouched.
    /// Idempotent: closing an unknown session is `Ok(0)`.  Returns the
    /// number of live (waiting/ready/assigned) tasks cancelled.
    pub fn close_session(&mut self, session: &str) -> Result<u64> {
        if session.is_empty() {
            bail!("the anonymous session cannot be closed");
        }
        if !self.sessions.is_open(session) {
            return Ok(0);
        }
        // deterministic sweep order: creation sequence, like a replay
        let mut keys: Vec<(u64, String)> = self
            .tasks
            .iter()
            .filter(|(_, e)| e.session == session)
            .map(|(k, e)| (e.seq, k.clone()))
            .collect();
        keys.sort();
        let mut cancelled = 0u64;
        for (_, key) in &keys {
            let (state, failed) = {
                let e = &self.tasks[key];
                (e.state, e.failed)
            };
            match state {
                TaskState::Done => self.completed -= 1,
                TaskState::Error => {
                    self.errored -= 1;
                    if failed {
                        self.failed -= 1;
                    }
                }
                TaskState::Ready => {
                    self.ready.remove(key);
                    self.emit(key, EventKind::Failed, "");
                    cancelled += 1;
                }
                TaskState::Assigned => {
                    for set in self.assigned.values_mut() {
                        set.remove(key);
                    }
                    // the worker still holds it and will report in;
                    // absorb that one report instead of erroring it out
                    self.orphaned.insert(key.clone());
                    self.metrics.gauge_add(Gauge::Inflight, -1);
                    self.emit(key, EventKind::Failed, "");
                    cancelled += 1;
                }
                TaskState::Waiting => {
                    self.emit(key, EventKind::Failed, "");
                    cancelled += 1;
                }
            }
            self.tasks.remove(key);
            let _ = self.kv.remove(format!("t/{key}").as_bytes());
        }
        self.sessions.remove(session);
        let _ = self.kv.remove(format!("{SESSION_KEY_PREFIX}{session}").as_bytes());
        self.metrics.add(Counter::TasksCancelled, cancelled);
        self.metrics.inc(Counter::SessionsClosed);
        self.metrics.gauge_add(Gauge::SessionsOpen, -1);
        self.metrics.session_gauge_remove(session);
        self.sync_queue_gauge();
        Ok(cancelled)
    }

    /// Is `session` currently open?
    pub fn session_is_open(&self, session: &str) -> bool {
        self.sessions.is_open(session)
    }

    /// Number of currently open named sessions.
    pub fn open_session_count(&self) -> usize {
        self.sessions.len()
    }
}

impl Default for SchedState {
    fn default() -> Self {
        SchedState::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(name: &str) -> TaskMsg {
        TaskMsg::new(name, vec![])
    }

    #[test]
    fn fifo_order() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &[]).unwrap();
        s.create(t("c"), &[]).unwrap();
        let got: Vec<String> = s.steal("w", 3).into_iter().map(|m| m.name).collect();
        assert_eq!(got, vec!["a", "b", "c"]);
    }

    #[test]
    fn dependencies_gate_serving() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        // only a is ready
        let got = s.steal("w", 10);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].name, "a");
        assert!(s.steal("w", 1).is_empty());
        s.complete("w", "a", true).unwrap();
        let got = s.steal("w", 1);
        assert_eq!(got[0].name, "b");
        s.complete("w", "b", true).unwrap();
        assert!(s.all_done());
    }

    #[test]
    fn diamond_dag() {
        let mut s = SchedState::new();
        s.create(t("root"), &[]).unwrap();
        s.create(t("l"), &["root".into()]).unwrap();
        s.create(t("r"), &["root".into()]).unwrap();
        s.create(t("join"), &["l".into(), "r".into()]).unwrap();
        assert_eq!(s.steal("w", 9)[0].name, "root");
        s.complete("w", "root", true).unwrap();
        let two = s.steal("w", 9);
        assert_eq!(two.len(), 2);
        s.complete("w", "l", true).unwrap();
        assert!(s.steal("w", 1).is_empty(), "join still waits on r");
        s.complete("w", "r", true).unwrap();
        assert_eq!(s.steal("w", 1)[0].name, "join");
        s.complete("w", "join", true).unwrap();
        assert!(s.all_done());
    }

    #[test]
    fn dep_on_done_task_is_free() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.steal("w", 1);
        s.complete("w", "a", true).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        assert_eq!(s.steal("w", 1)[0].name, "b");
    }

    #[test]
    fn unknown_dep_rejected() {
        let mut s = SchedState::new();
        let err = s.create(t("x"), &["ghost".into()]).unwrap_err();
        assert_eq!(err.code, RefusalCode::DepMissing);
    }

    #[test]
    fn duplicate_create_rejected() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        let err = s.create(t("a"), &[]).unwrap_err();
        assert_eq!(err.code, RefusalCode::Duplicate);
        // the pre-code compatibility window has elapsed: classification
        // is the typed code alone, and the message no longer embeds the
        // legacy marker phrase old clients substring-matched
        assert!(!err.to_string().contains(ERR_MARKER_DUPLICATE), "{err}");
        assert!(err.to_string().contains("\"a\""), "message still names the task: {err}");
    }

    #[test]
    fn errored_dep_create_message() {
        let mut s = SchedState::new();
        s.create(t("bad"), &[]).unwrap();
        s.steal("w", 1);
        s.complete("w", "bad", false).unwrap();
        let err = s.create(t("late"), &["bad".into()]).unwrap_err();
        assert_eq!(err.code, RefusalCode::DepErrored);
        // the pre-code compatibility window has elapsed: classification
        // is the typed code alone, and the message no longer embeds the
        // legacy marker phrase old clients substring-matched
        assert!(!err.to_string().contains(ERR_MARKER_DEP_ERRORED), "{err}");
        assert!(err.to_string().contains("\"bad\""), "message still names the dep: {err}");
    }

    #[test]
    fn traced_lifecycle_is_wellformed() {
        use crate::trace;
        let tracer = Tracer::memory();
        let mut s = SchedState::new();
        s.set_tracer(tracer.clone());
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        s.create(t("boom"), &[]).unwrap();
        s.create(t("child"), &["boom".into()]).unwrap();
        let got = s.steal("w1", 2); // a, boom
        assert_eq!(got.len(), 2);
        s.complete("w1", "a", true).unwrap();
        s.complete("w1", "boom", false).unwrap();
        let got = s.steal("w2", 2); // b
        assert_eq!(got.len(), 1);
        // w2 dies holding b; a survivor picks it up
        s.exit_worker("w2");
        s.steal("w3", 1);
        s.complete("w3", "b", true).unwrap();
        let evs = tracer.drain();
        trace::validate(&evs).unwrap();
        let c = trace::counts(&evs);
        assert_eq!(c.completed, 2);
        assert_eq!(c.failed, 1, "boom was attempted");
        assert_eq!(c.skipped, 1, "child never launched");
        // b's requeue cycle is visible
        let b_kinds: Vec<EventKind> =
            evs.iter().filter(|e| e.task == "b").map(|e| e.kind).collect();
        assert!(b_kinds.contains(&EventKind::Requeued));
    }

    #[test]
    fn error_propagates_to_successors() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        s.create(t("c"), &["b".into()]).unwrap();
        s.create(t("free"), &[]).unwrap();
        s.steal("w", 1);
        s.complete("w", "a", false).unwrap(); // fail a
        assert_eq!(s.get("b").unwrap().state, TaskState::Error);
        assert_eq!(s.get("c").unwrap().state, TaskState::Error);
        // free is unaffected and still served
        assert_eq!(s.steal("w", 2).len(), 1);
        s.complete("w", "free", true).unwrap();
        assert!(s.all_done(), "errored graph still terminates");
    }

    #[test]
    fn transfer_reinserts_at_front() {
        let mut s = SchedState::new();
        s.create(t("x"), &[]).unwrap();
        s.create(t("y"), &[]).unwrap();
        s.create(t("z"), &[]).unwrap();
        let first = s.steal("w", 1);
        assert_eq!(first[0].name, "x");
        // x decides it needs nothing more but wants requeueing
        s.transfer("w", "x", &[]).unwrap();
        // x must come back BEFORE y and z (front of deque)
        assert_eq!(s.steal("w", 1)[0].name, "x");
    }

    #[test]
    fn transfer_with_new_deps_waits_then_fronts() {
        let mut s = SchedState::new();
        s.create(t("x"), &[]).unwrap();
        s.create(t("other"), &[]).unwrap();
        s.steal("w1", 1); // x assigned
        s.create(t("pre"), &[]).unwrap();
        s.transfer("w1", "x", &["pre".into()]).unwrap();
        assert_eq!(s.get("x").unwrap().state, TaskState::Waiting);
        // queue now: other, pre
        let batch = s.steal("w2", 2);
        assert_eq!(batch.len(), 2);
        s.complete("w2", "pre", true).unwrap();
        // x becomes ready and lands at the FRONT
        assert_eq!(s.steal("w2", 1)[0].name, "x");
    }

    #[test]
    fn transfer_cycle_detected() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        s.steal("w", 1); // a assigned
        // a transferring to depend on b would deadlock (b waits on a)
        let err = s.transfer("w", "a", &["b".into()]).unwrap_err();
        assert!(err.to_string().contains("cycle"), "{err}");
    }

    #[test]
    fn exit_requeues_assignments() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &[]).unwrap();
        s.create(t("c"), &[]).unwrap();
        let got = s.steal("w1", 2); // a, b assigned to w1
        assert_eq!(got.len(), 2);
        s.exit_worker("w1");
        // a and b return to the FRONT in seq order, ahead of c
        let got: Vec<String> = s.steal("w2", 3).into_iter().map(|m| m.name).collect();
        assert_eq!(got, vec!["a", "b", "c"]);
    }

    #[test]
    fn exit_unknown_worker_is_noop() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.exit_worker("ghost");
        assert_eq!(s.steal("w", 1).len(), 1);
    }

    #[test]
    fn complete_wrong_state_rejected() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        assert!(s.complete("w", "a", true).is_err()); // not assigned
        assert!(s.complete("w", "ghost", true).is_err());
    }

    #[test]
    fn status_counters() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        s.create(t("c"), &[]).unwrap();
        s.steal("w", 1);
        let st = s.status();
        assert_eq!(st.total, 3);
        assert_eq!(st.ready, 1); // c
        assert_eq!(st.waiting, 1); // b
        assert_eq!(st.assigned, 1); // a
        assert_eq!(st.workers, 1);
    }

    #[test]
    fn persistence_survives_restart() {
        let dir = std::env::temp_dir().join(format!("threesched-dwork-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store(kv);
            s.create(t("a"), &[]).unwrap();
            s.create(t("b"), &["a".into()]).unwrap();
            s.create(t("c"), &[]).unwrap();
            let got = s.steal("w", 1); // a assigned
            assert_eq!(got[0].name, "a");
        } // server "crashes"
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store(kv);
            // a was assigned -> back to ready; c ready; b still waiting
            let st = s.status();
            assert_eq!(st.total, 3);
            assert_eq!(st.ready, 2);
            assert_eq!(st.waiting, 1);
            // FIFO order by creation seq survives
            let got: Vec<String> = s.steal("w", 2).into_iter().map(|m| m.name).collect();
            assert_eq!(got, vec!["a", "c"]);
            s.complete("w", "a", true).unwrap();
            assert_eq!(s.steal("w", 1)[0].name, "b");
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn rebuild_honors_front_reinsertion() {
        // regression: rebuild used to push every recovered task
        // push_back, silently demoting transferred (re-inserted) tasks
        // that are persisted as front-of-queue entries
        let dir = std::env::temp_dir()
            .join(format!("threesched-dwork-reins-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store(kv);
            s.create(t("x"), &[]).unwrap();
            s.create(t("y"), &[]).unwrap();
            s.create(t("z"), &[]).unwrap();
            let got = s.steal("w1", 3); // x, y, z assigned
            assert_eq!(got.len(), 3);
            s.transfer("w1", "z", &[]).unwrap(); // z re-inserted at the FRONT
            s.complete("w1", "x", true).unwrap();
            // y stays assigned; queue is [z]
        } // server "crashes"
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store(kv);
            // z (re-inserted, seq 2) must come back BEFORE y (assigned ->
            // ready, seq 1) even though seq order says otherwise
            let got: Vec<String> = s.steal("w2", 2).into_iter().map(|m| m.name).collect();
            assert_eq!(got, vec!["z", "y"]);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn failed_vs_skipped_counters() {
        let mut s = SchedState::new();
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        s.create(t("c"), &["b".into()]).unwrap();
        s.steal("w", 1);
        s.complete("w", "a", false).unwrap();
        let st = s.status();
        assert_eq!(st.errored, 3);
        assert_eq!(st.failed, 1, "only the attempted root counts as failed");
        assert_eq!(st.skipped(), 2);
        assert!(st.is_drained());
    }

    #[test]
    fn failed_counter_survives_restart() {
        let dir = std::env::temp_dir()
            .join(format!("threesched-dwork-failed-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store(kv);
            s.create(t("a"), &[]).unwrap();
            s.create(t("b"), &["a".into()]).unwrap();
            s.steal("w", 1);
            s.complete("w", "a", false).unwrap();
        }
        {
            let kv = KvStore::open(&dir).unwrap();
            let s = SchedState::with_store(kv);
            let st = s.status();
            assert_eq!(st.errored, 2);
            assert_eq!(st.failed, 1);
            assert_eq!(st.skipped(), 1);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn metrics_counters_track_lifecycle() {
        let r = Registry::enabled();
        let mut s = SchedState::new();
        s.set_metrics(r.clone());
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        s.create(t("boom"), &[]).unwrap();
        s.create(t("child"), &["boom".into()]).unwrap();
        assert_eq!(r.counter(Counter::TasksCreated), 4);
        assert_eq!(r.gauge(Gauge::QueueDepth), 2, "a and boom ready");
        let got = s.steal("w1", 2);
        assert_eq!(got.len(), 2);
        assert_eq!(r.gauge(Gauge::QueueDepth), 0);
        assert_eq!(r.gauge(Gauge::Inflight), 2);
        s.complete("w1", "a", true).unwrap();
        assert_eq!(r.counter(Counter::TasksCompleted), 1);
        assert_eq!(r.gauge(Gauge::QueueDepth), 1, "b promoted");
        s.complete("w1", "boom", false).unwrap();
        assert_eq!(r.counter(Counter::TasksFailed), 1, "attempted root");
        assert_eq!(r.counter(Counter::TasksSkipped), 1, "child errored by propagation");
        assert_eq!(r.gauge(Gauge::Inflight), 0);
        // w2 takes b then dies: the requeue shows up in counters + gauges
        s.steal("w2", 1);
        assert_eq!(r.gauge(Gauge::Inflight), 1);
        s.exit_worker("w2");
        assert_eq!(r.counter(Counter::TasksRequeued), 1);
        assert_eq!(r.gauge(Gauge::Inflight), 0);
        assert_eq!(r.gauge(Gauge::QueueDepth), 1);
        // accounting identity the property suite pins at the session
        // level: created == completed + failed + skipped + still-live
        let live = r.counter(Counter::TasksCreated)
            - r.counter(Counter::TasksCompleted)
            - r.counter(Counter::TasksFailed)
            - r.counter(Counter::TasksSkipped);
        assert_eq!(live, 1, "only b is unfinished");
    }

    #[test]
    fn set_metrics_on_rebuilt_state_syncs_gauges() {
        let dir = std::env::temp_dir()
            .join(format!("threesched-dwork-metrics-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store(kv);
            s.create(t("a"), &[]).unwrap();
            s.create(t("b"), &[]).unwrap();
            s.steal("w", 1);
        } // crash holding a assigned
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store(kv);
            let r = Registry::enabled();
            s.set_metrics(r.clone());
            // rebuild returned the assigned task to ready: gauges truthful
            assert_eq!(r.gauge(Gauge::QueueDepth), 2);
            assert_eq!(r.gauge(Gauge::Inflight), 0);
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn million_task_create_and_drain() {
        // paper sec. 6: "can create and deque one million tasks in about a
        // minute".  Here we just prove the state machine handles 100k
        // without pathological behavior (full million exercised in bench).
        let mut s = SchedState::new();
        for i in 0..100_000 {
            s.create(t(&format!("t{i}")), &[]).unwrap();
        }
        let mut n = 0;
        loop {
            let batch = s.steal("w", 64);
            if batch.is_empty() {
                break;
            }
            for m in &batch {
                s.complete("w", &m.name, true).unwrap();
            }
            n += batch.len();
        }
        assert_eq!(n, 100_000);
        assert!(s.all_done());
    }

    /// Drive the same op sequence through two states and assert the
    /// steal order matches step for step.
    fn assert_same_order(a: &mut SchedState, b: &mut SchedState) {
        for i in 0..24 {
            a.create(t(&format!("task{i}")), &[]).unwrap();
            b.create(t(&format!("task{i}")), &[]).unwrap();
        }
        let (sa, sb) = (a.steal("w1", 5), b.steal("w1", 5));
        assert_eq!(sa.iter().map(|m| &m.name).collect::<Vec<_>>(),
                   sb.iter().map(|m| &m.name).collect::<Vec<_>>());
        // a transfer (front re-insert) and a worker death in the middle
        a.transfer("w1", &sa[2].name, &[]).unwrap();
        b.transfer("w1", &sb[2].name, &[]).unwrap();
        a.complete("w1", &sa[0].name, true).unwrap();
        b.complete("w1", &sb[0].name, true).unwrap();
        a.exit_worker("w1");
        b.exit_worker("w1");
        loop {
            let (na, nb) = (a.steal("w2", 3), b.steal("w2", 3));
            assert_eq!(na.iter().map(|m| &m.name).collect::<Vec<_>>(),
                       nb.iter().map(|m| &m.name).collect::<Vec<_>>());
            if na.is_empty() {
                break;
            }
            for m in na {
                a.complete("w2", &m.name, true).unwrap();
                b.complete("w2", &m.name, true).unwrap();
            }
        }
        assert!(a.all_done() && b.all_done());
    }

    #[test]
    fn one_shard_matches_unsharded_exactly() {
        // the N=1 pin: with_shards(1) must reproduce the single-deque
        // scheduling order through creates, transfers, and a worker death
        let mut a = SchedState::new();
        let mut b = SchedState::with_shards(1);
        assert_eq!(b.shard_count(), 1);
        assert_same_order(&mut a, &mut b);
    }

    #[test]
    fn zero_shards_clamps_to_one() {
        let mut a = SchedState::new();
        let mut b = SchedState::with_shards(0);
        assert_eq!(b.shard_count(), 1);
        assert_same_order(&mut a, &mut b);
    }

    #[test]
    fn sharded_steal_crosses_shards_on_miss() {
        // one worker must still drain everything: its home shard first,
        // then work-stealing from the other shards
        let mut s = SchedState::with_shards(4);
        assert_eq!(s.shard_count(), 4);
        for i in 0..32 {
            s.create(t(&format!("task{i}")), &[]).unwrap();
        }
        let got = s.steal("lone-worker", 32);
        assert_eq!(got.len(), 32, "a miss on the home shard steals elsewhere");
        for m in &got {
            s.complete("lone-worker", &m.name, true).unwrap();
        }
        assert!(s.all_done());
    }

    #[test]
    fn sharded_preserves_per_shard_fifo_and_dependencies() {
        let mut s = SchedState::with_shards(3);
        s.create(t("a"), &[]).unwrap();
        s.create(t("b"), &["a".into()]).unwrap();
        // dependency gating is shard-independent
        let got = s.steal("w", 10);
        assert_eq!(got.len(), 1);
        assert_eq!(got[0].name, "a");
        s.complete("w", "a", true).unwrap();
        assert_eq!(s.steal("w", 1)[0].name, "b");
        s.complete("w", "b", true).unwrap();
        assert!(s.all_done());
    }

    #[test]
    fn sharded_error_propagation_leaves_other_shards_intact() {
        let mut s = SchedState::with_shards(4);
        s.create(t("boom"), &[]).unwrap();
        s.create(t("child"), &["boom".into()]).unwrap();
        // boom is the only ready task, so any shard scan must yield it
        assert_eq!(s.steal("w", 1)[0].name, "boom");
        for i in 0..8 {
            s.create(t(&format!("ok{i}")), &[]).unwrap();
        }
        s.complete("w", "boom", false).unwrap();
        assert_eq!(s.get("child").unwrap().state, TaskState::Error);
        // the 8 independent tasks are untouched and fully drainable
        let mut n = 0;
        loop {
            let batch = s.steal("w", 3);
            if batch.is_empty() {
                break;
            }
            for m in &batch {
                s.complete("w", &m.name, true).unwrap();
            }
            n += batch.len();
        }
        assert_eq!(n, 8);
        assert!(s.all_done());
    }

    #[test]
    fn partial_batch_death_requeues_only_unreported() {
        // regression (batched completion): a worker that stole a batch,
        // reported part of it, then died must put back ONLY the
        // unreported remainder — at the front, in seq order per shard
        let mut s = SchedState::with_shards(4);
        for name in ["a", "b", "c", "d"] {
            s.create(t(name), &[]).unwrap();
        }
        let got = s.steal("doomed", 4);
        assert_eq!(got.len(), 4);
        s.complete("doomed", "a", true).unwrap();
        s.complete("doomed", "c", true).unwrap();
        let requeued = s.exit_worker("doomed");
        assert_eq!(requeued, 2, "only the unreported half returns");
        let back: Vec<String> = s.steal("w2", 4).into_iter().map(|m| m.name).collect();
        assert_eq!(back.len(), 2);
        assert!(back.contains(&"b".to_string()) && back.contains(&"d".to_string()));
        for name in back {
            s.complete("w2", &name, true).unwrap();
        }
        assert!(s.all_done());
    }

    #[test]
    fn sharded_exit_requeue_fronts_per_shard() {
        // a dead worker's tasks re-enter at the front OF THEIR SHARD in
        // seq order, ahead of that shard's never-assigned tasks
        let n = 4usize;
        let mut s = SchedState::with_shards(n);
        let names: Vec<String> = (0..16).map(|i| format!("task{i}")).collect();
        for nm in &names {
            s.create(t(nm), &[]).unwrap();
        }
        let stolen: Vec<String> = s.steal("w1", 6).into_iter().map(|m| m.name).collect();
        assert_eq!(stolen.len(), 6);
        s.exit_worker("w1");
        let order: Vec<String> = s.steal("w2", 16).into_iter().map(|m| m.name).collect();
        assert_eq!(order.len(), 16);
        let shard_of = |nm: &str| (fnv1a(nm) % n as u64) as usize;
        let idx_of = |nm: &str| names.iter().position(|x| x == nm).unwrap();
        let mut per_shard: std::collections::HashMap<usize, Vec<&String>> =
            std::collections::HashMap::new();
        for nm in &order {
            per_shard.entry(shard_of(nm)).or_default().push(nm);
        }
        for (_, drained) in per_shard {
            // within a shard: the requeued block first (seq order), then
            // the fresh block (seq order)
            let k = drained.iter().take_while(|nm| stolen.contains(**nm)).count();
            assert!(
                drained[k..].iter().all(|nm| !stolen.contains(*nm)),
                "requeued tasks must precede fresh ones in-shard: {drained:?}"
            );
            assert!(drained[..k].windows(2).all(|w| idx_of(w[0]) < idx_of(w[1])));
            assert!(drained[k..].windows(2).all(|w| idx_of(w[0]) < idx_of(w[1])));
        }
    }

    #[test]
    fn sharded_state_survives_restart_with_different_shard_count() {
        // shard count is runtime config: a hub restarted with a
        // different N redistributes the rebuilt queue and still honors
        // front re-insertion within each shard
        let dir = std::env::temp_dir()
            .join(format!("threesched-dwork-shards-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store_sharded(kv, 4);
            for i in 0..8 {
                s.create(t(&format!("task{i}")), &[]).unwrap();
            }
            let got = s.steal("w1", 2);
            assert_eq!(got.len(), 2);
            s.transfer("w1", &got[0].name, &[]).unwrap(); // reinserted
        } // crash
        {
            let kv = KvStore::open(&dir).unwrap();
            let mut s = SchedState::with_store_sharded(kv, 2);
            assert_eq!(s.shard_count(), 2);
            let mut drained = 0;
            loop {
                let batch = s.steal("w2", 3);
                if batch.is_empty() {
                    break;
                }
                for m in &batch {
                    s.complete("w2", &m.name, true).unwrap();
                }
                drained += batch.len();
            }
            assert_eq!(drained, 8);
            assert!(s.all_done());
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn subscriber_sees_lifecycle_events_after_attach() {
        let mut s = SchedState::new();
        s.create(t("before"), &[]).unwrap(); // emitted pre-attach: invisible
        let (evs, dropped) = s.subscribe_poll("tail", "", 0);
        assert!(evs.is_empty(), "attach returns nothing retroactively");
        assert_eq!(dropped, 0);
        s.create(t("a"), &[]).unwrap();
        s.steal("w", 2); // before, a
        s.complete("w", "a", true).unwrap();
        let (evs, dropped) = s.subscribe_poll("tail", "", 0);
        assert_eq!(dropped, 0);
        let kinds: Vec<(String, EventKind)> =
            evs.iter().map(|e| (e.task.clone(), e.kind)).collect();
        assert_eq!(
            kinds,
            vec![
                ("a".to_string(), EventKind::Created),
                ("a".to_string(), EventKind::Ready),
                ("before".to_string(), EventKind::Launched),
                ("a".to_string(), EventKind::Launched),
                ("a".to_string(), EventKind::Finished),
            ]
        );
        // hub-stamped seq is monotone, timestamps never go backwards
        for w in evs.windows(2) {
            assert!(w[0].seq < w[1].seq);
            assert!(w[0].t <= w[1].t);
        }
        // queue drained: next poll is empty
        assert!(s.subscribe_poll("tail", "", 0).0.is_empty());
    }

    #[test]
    fn subscriber_prefix_filters_and_unsubscribe_detaches() {
        let mut s = SchedState::new();
        s.subscribe_poll("tail", "app/", 0);
        s.create(t("app/x"), &[]).unwrap();
        s.create(t("sys/y"), &[]).unwrap();
        let (evs, _) = s.subscribe_poll("tail", "app/", 0);
        assert!(!evs.is_empty());
        assert!(evs.iter().all(|e| e.task.starts_with("app/")), "{evs:?}");
        assert_eq!(s.subscriber_count(), 1);
        s.unsubscribe("tail");
        assert_eq!(s.subscriber_count(), 0);
        // further events don't accumulate anywhere
        s.create(t("app/z"), &[]).unwrap();
        let (evs, _) = s.subscribe_poll("tail", "app/", 0);
        assert!(evs.is_empty(), "re-attach starts fresh");
    }

    #[test]
    fn slow_subscriber_drops_oldest_and_counts() {
        let r = Registry::enabled();
        let mut s = SchedState::new();
        s.set_metrics(r.clone());
        s.subscribe_poll("tail", "", 0);
        // each create emits Created+Ready: overflow the cap
        let creates = SUB_QUEUE_CAP / 2 + 10;
        for i in 0..creates {
            s.create(t(&format!("t{i}")), &[]).unwrap();
        }
        let expect_dropped = (creates * 2 - SUB_QUEUE_CAP) as u64;
        // drain fully in bounded batches
        let mut got = 0usize;
        let mut dropped = 0u64;
        loop {
            let (evs, d) = s.subscribe_poll("tail", "", 4096);
            dropped += d;
            if evs.is_empty() {
                break;
            }
            got += evs.len();
        }
        assert_eq!(got, SUB_QUEUE_CAP, "queue holds exactly the cap");
        assert_eq!(dropped, expect_dropped);
        assert_eq!(r.counter(Counter::SubscribeDropped), expect_dropped);
        // the oldest events went first: the survivor stream starts late
        let (evs, _) = s.subscribe_poll("tail", "", 1);
        assert!(evs.is_empty());
    }

    #[test]
    fn subscribe_batch_size_is_respected() {
        let mut s = SchedState::new();
        s.subscribe_poll("tail", "", 0);
        for i in 0..10 {
            s.create(t(&format!("t{i}")), &[]).unwrap();
        }
        let (evs, _) = s.subscribe_poll("tail", "", 3);
        assert_eq!(evs.len(), 3);
        let (evs, _) = s.subscribe_poll("tail", "", 0);
        assert_eq!(evs.len(), 17, "default batch takes the rest (20 total)");
    }
}
