//! Message forwarding tree: the paper's rack-leader fan-in.
//!
//! Paper sec. 4: "I have used a 2-level forwarding tree, where each rack
//! of 18 Summit nodes communicates with a rack-leader.  The rack leaders
//! forward all messages to a single task server running on the job's
//! launch node."  This keeps the task server's open-connection count at
//! the number of racks instead of the number of ranks (sec. 6, feature 2:
//! "forwarding of messages to maintain constant open connections per
//! rank").
//!
//! A forwarder is itself a tiny server: it accepts requests on its own
//! hub and relays each one upstream over a single connection, returning
//! the upstream reply.  Forwarders compose, so deeper trees are possible.

use std::thread::JoinHandle;

use crate::substrate::transport::{inproc, ClientConn, RequestRx};

/// Run a forwarder loop: every request from `rx` is relayed through
/// `upstream`, and the reply is sent back to the original requester.
/// Exits when all downstream connectors are dropped.
pub fn forward(rx: RequestRx, mut upstream: Box<dyn ClientConn>) {
    for req in rx {
        match upstream.request(&req.payload) {
            Ok(reply) => req.reply(reply),
            Err(_) => {
                // upstream is gone: drop the request; the client will
                // surface a transport error and can re-resolve.
                return;
            }
        }
    }
}

/// Spawn an in-proc forwarder in front of `upstream`; returns the
/// downstream connector workers should use.
pub fn spawn(upstream: Box<dyn ClientConn>) -> (inproc::Connector, JoinHandle<()>) {
    let (rx, connector) = inproc::hub();
    let handle = std::thread::Builder::new()
        .name("dwork-forwarder".into())
        .spawn(move || forward(rx, upstream))
        .expect("spawn forwarder");
    (connector, handle)
}

/// Build a two-level tree over an in-proc server connector: `racks`
/// forwarders, each to be shared by the ranks of one rack.  Returns one
/// downstream connector per rack (plus the forwarder join handles).
pub fn rack_tree(
    server: &inproc::Connector,
    racks: usize,
) -> (Vec<inproc::Connector>, Vec<JoinHandle<()>>) {
    let mut connectors = Vec::with_capacity(racks);
    let mut handles = Vec::with_capacity(racks);
    for _ in 0..racks {
        let (c, h) = spawn(Box::new(server.connect()));
        connectors.push(c);
        handles.push(h);
    }
    (connectors, handles)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dwork::client::{run_worker, Client};
    use crate::coordinator::dwork::messages::TaskMsg;
    use crate::coordinator::dwork::server::{spawn_inproc, ServerConfig};
    use crate::coordinator::dwork::state::SchedState;
    use crate::substrate::cluster::Machine;

    #[test]
    fn one_hop_forwarding_transparent() {
        let mut s = SchedState::new();
        for i in 0..20 {
            s.create(TaskMsg::new(format!("t{i}"), vec![]), &[]).unwrap();
        }
        let (server_conn, server_handle) = spawn_inproc(s, ServerConfig::default());
        let (fwd_conn, _fwd_handle) = spawn(Box::new(server_conn.connect()));
        let mut c = Client::new(Box::new(fwd_conn.connect()), "w0");
        let stats = run_worker(&mut c, 1, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 20);
        drop(c);
        drop(fwd_conn);
        drop(server_conn);
        assert!(server_handle.join().unwrap().all_done());
    }

    #[test]
    fn rack_tree_summit_topology() {
        // 6 nodes -> 36 ranks over 1 rack; 36 nodes -> 2 racks
        let m = Machine::summit(36);
        assert_eq!(m.racks(), 2);
        let mut s = SchedState::new();
        for i in 0..100 {
            s.create(TaskMsg::new(format!("t{i}"), vec![]), &[]).unwrap();
        }
        let (server_conn, server_handle) = spawn_inproc(s, ServerConfig::default());
        let (racks, _handles) = rack_tree(&server_conn, m.racks());
        assert_eq!(racks.len(), 2);
        // 8 workers spread over the 2 rack leaders by topology
        let totals: Vec<u64> = std::thread::scope(|scope| {
            let handles: Vec<_> = (0..8)
                .map(|w| {
                    let rack = w % 2;
                    let conn = racks[rack].connect();
                    scope.spawn(move || {
                        let mut c = Client::new(Box::new(conn), format!("w{w}"));
                        run_worker(&mut c, 1, |_| Ok(())).unwrap().tasks_run
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(totals.iter().sum::<u64>(), 100);
        drop(racks);
        drop(server_conn);
        assert!(server_handle.join().unwrap().all_done());
    }

    #[test]
    fn two_level_tree_composes() {
        let mut s = SchedState::new();
        for i in 0..10 {
            s.create(TaskMsg::new(format!("t{i}"), vec![]), &[]).unwrap();
        }
        let (server_conn, server_handle) = spawn_inproc(s, ServerConfig::default());
        let (mid, _h1) = spawn(Box::new(server_conn.connect()));
        let (leaf, _h2) = spawn(Box::new(mid.connect()));
        let mut c = Client::new(Box::new(leaf.connect()), "w");
        let stats = run_worker(&mut c, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 10);
        drop(c);
        drop(leaf);
        drop(mid);
        drop(server_conn);
        assert!(server_handle.join().unwrap().all_done());
    }
}
