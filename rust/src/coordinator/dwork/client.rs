//! dwork client: the worker-side API + the worker main loop.
//!
//! [`Client`] is a thin typed wrapper over one connection (the paper's
//! dquery CLI and user programs sit at this level).  [`run_worker`] is the
//! paper Fig 2 client loop:
//!
//! ```text
//! while server responds with task do
//!     copy-in task inputs; execute task; inform server of completion
//! end; inform server of Exit
//! ```
//!
//! with the paper's compute/communication overlap implemented as a
//! prefetch buffer: while a task executes, the next Steal has already
//! been issued (depth configurable; sec. 5's "Steal n" batching).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::metrics::{Counter, MetricsSnapshot, Registry, Series};
use crate::substrate::transport::ClientConn;
use crate::trace::{EventKind, TaskEvent, Tracer};

use super::messages::{
    BatchItem, Completion, CreateItem, RefusalCode, Request, Response, StatusInfo, TaskMsg,
};

/// A server-side error surfaced through the typed client.  Downcast the
/// `anyhow::Error` chain to this type to reach the machine-readable
/// refusal `code`; it is absent for non-Create errors and on replies
/// from pre-code hubs (which current submitters no longer accommodate —
/// the marker-string fallback, and since this release the server-side
/// marker embedding too, are gone after their compatibility windows).
#[derive(Debug)]
pub struct ServerError {
    pub code: Option<RefusalCode>,
    pub msg: String,
}

impl std::fmt::Display for ServerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "server error: {}", self.msg)
    }
}

impl std::error::Error for ServerError {}

/// Per-item result of a [`Client::submit`] batch: either the task was
/// created, or the hub refused it (duplicate, missing/errored dep — the
/// typed [`RefusalCode`] rides inside).  Transport-level failures abort
/// the whole call instead of appearing here.
#[derive(Debug)]
pub enum SubmitOutcome {
    Created,
    Refused(ServerError),
}

impl SubmitOutcome {
    pub fn is_created(&self) -> bool {
        matches!(self, SubmitOutcome::Created)
    }

    /// The typed refusal code, when this item was refused with one.
    pub fn code(&self) -> Option<RefusalCode> {
        match self {
            SubmitOutcome::Created => None,
            SubmitOutcome::Refused(e) => e.code,
        }
    }
}

/// Whether the connected hub speaks the batched wire kinds.  Probed
/// lazily on the first [`Client::submit`]/[`Client::report`]: a current
/// hub answers `Response::Batch` (never a whole-frame `Err`, even when
/// every item is refused), while a pre-batch hub answers `Err` for the
/// unknown request kind — the degrade signal that pins this to
/// `PerTask` for the rest of the connection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum BatchSupport {
    Unknown,
    Native,
    PerTask,
}

/// Whether the connected hub speaks the session wire kinds
/// (`OpenSession`/`CloseSession`/`SubmitDelta`).  Probed lazily like
/// [`BatchSupport`]: the first session verb against a pre-session hub
/// gets a whole-frame `Err` for the unknown request kind, which pins
/// `Unsupported` for the rest of the connection — the client then
/// behaves as one anonymous single-session submitter
/// ([`Client::submit_delta`] routes completions through
/// [`Client::report`] and creates through [`Client::submit`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum SessionSupport {
    Unknown,
    Native,
    Unsupported,
}

/// Typed request/reply client.
pub struct Client {
    conn: Box<dyn ClientConn>,
    worker: String,
    exit_on_drop: bool,
    batch: BatchSupport,
    session: SessionSupport,
}

impl Client {
    pub fn new(conn: Box<dyn ClientConn>, worker: impl Into<String>) -> Client {
        Client {
            conn,
            worker: worker.into(),
            exit_on_drop: false,
            batch: BatchSupport::Unknown,
            session: SessionSupport::Unknown,
        }
    }

    /// Announce departure (`Exit`) when this client is dropped, so a
    /// worker that dies mid-campaign — panic unwinding included — hands
    /// its assigned tasks back to the hub.  Best-effort: a vanished
    /// server is ignored.  Harmless after a clean shutdown (an `Exit`
    /// for a worker with no assignments is a no-op server-side).
    pub fn exit_on_drop(mut self, yes: bool) -> Client {
        self.exit_on_drop = yes;
        self
    }

    pub fn worker(&self) -> &str {
        &self.worker
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        let reply = self.conn.request(&req.encode())?;
        Response::decode(&reply)
    }

    fn expect_ok(&mut self, req: &Request) -> Result<()> {
        match self.roundtrip(req)? {
            Response::Ok => Ok(()),
            Response::Err { msg, code } => Err(ServerError { code, msg }.into()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Submit a batch of tasks in one round-trip, returning one
    /// [`SubmitOutcome`] per item in order.  Against a pre-batch hub the
    /// first call detects the unknown request kind and transparently
    /// degrades to per-task `Create` round-trips (same outcomes, more
    /// RTTs) for the rest of the connection.
    pub fn submit(&mut self, items: &[CreateItem]) -> Result<Vec<SubmitOutcome>> {
        if items.is_empty() {
            return Ok(Vec::new());
        }
        if self.batch != BatchSupport::PerTask {
            let req = Request::CreateBatch { items: items.to_vec() };
            match self.roundtrip(&req)? {
                Response::Batch(results) => {
                    self.batch = BatchSupport::Native;
                    if results.len() != items.len() {
                        bail!(
                            "batch reply carries {} results for {} items",
                            results.len(),
                            items.len()
                        );
                    }
                    return Ok(results
                        .into_iter()
                        .map(|r| match r {
                            BatchItem::Ok => SubmitOutcome::Created,
                            BatchItem::Err { msg, code } => {
                                SubmitOutcome::Refused(ServerError { code, msg })
                            }
                        })
                        .collect());
                }
                // a whole-frame Err to a batch kind only comes from a
                // pre-batch hub ("bad request: unknown request kind"):
                // degrade to per-task mode for good
                Response::Err { .. } => self.batch = BatchSupport::PerTask,
                other => bail!("unexpected reply {other:?}"),
            }
        }
        let mut out = Vec::with_capacity(items.len());
        for item in items {
            match self.create_impl(item.task.clone(), &item.deps) {
                Ok(()) => out.push(SubmitOutcome::Created),
                Err(e) => match e.downcast::<ServerError>() {
                    Ok(se) => out.push(SubmitOutcome::Refused(se)),
                    Err(e) => return Err(e),
                },
            }
        }
        Ok(out)
    }

    /// Acquire up to `n` ready tasks in one round-trip (the paper's
    /// "Steal n" batching — the batch-first name for it).
    pub fn acquire(&mut self, n: u32) -> Result<StealBatch> {
        self.steal_n_impl(n)
    }

    /// Report a batch of completions in one round-trip (the symmetric
    /// twin of [`Client::acquire`]).  Per-item failures (unknown task,
    /// wrong state) surface as the first [`ServerError`]; against a
    /// pre-batch hub this degrades to per-task `Complete` round-trips
    /// like [`Client::submit`].
    pub fn report(&mut self, completions: &[Completion]) -> Result<()> {
        if completions.is_empty() {
            return Ok(());
        }
        if self.batch != BatchSupport::PerTask {
            let req = Request::CompleteBatch {
                worker: self.worker.clone(),
                completions: completions.to_vec(),
            };
            match self.roundtrip(&req)? {
                Response::Batch(results) => {
                    self.batch = BatchSupport::Native;
                    for r in results {
                        if let BatchItem::Err { msg, code } = r {
                            return Err(ServerError { code, msg }.into());
                        }
                    }
                    return Ok(());
                }
                Response::Err { .. } => self.batch = BatchSupport::PerTask,
                other => bail!("unexpected reply {other:?}"),
            }
        }
        for c in completions {
            self.complete_impl(&c.task, c.success)?;
        }
        Ok(())
    }

    /// Did the probed hub speak the batched wire kinds?  `None` until
    /// the first [`Client::submit`]/[`Client::report`] ran.
    pub fn uses_batch_wire(&self) -> Option<bool> {
        match self.batch {
            BatchSupport::Unknown => None,
            BatchSupport::Native => Some(true),
            BatchSupport::PerTask => Some(false),
        }
    }

    /// Did the probed hub speak the session wire kinds?  `None` until
    /// the first session verb ran.
    pub fn uses_session_wire(&self) -> Option<bool> {
        match self.session {
            SessionSupport::Unknown => None,
            SessionSupport::Native => Some(true),
            SessionSupport::Unsupported => Some(false),
        }
    }

    /// A whole-frame `Err` answering a session kind only comes from a
    /// pre-session hub (its decoder refuses the unknown request kind);
    /// a current hub answers `Response::Session`, or a typed/whole-frame
    /// error that does not carry the unknown-kind marker.
    fn is_pre_session_err(code: Option<RefusalCode>, msg: &str) -> bool {
        code.is_none() && msg.contains("unknown request kind")
    }

    /// Open (or idempotently re-open) a named session on the hub.
    /// Returns `Ok(true)` when the hub speaks sessions and the session
    /// is live, `Ok(false)` when a pre-session hub refused the kind —
    /// the client pins the degrade and every later
    /// [`Client::submit_delta`] lands its creates in the anonymous
    /// namespace instead.
    pub fn open_session(&mut self, session: &str) -> Result<bool> {
        if self.session == SessionSupport::Unsupported {
            return Ok(false);
        }
        match self.roundtrip(&Request::OpenSession { session: session.to_string() })? {
            Response::Session { .. } => {
                self.session = SessionSupport::Native;
                Ok(true)
            }
            Response::Err { msg, code } if Self::is_pre_session_err(code, &msg) => {
                self.session = SessionSupport::Unsupported;
                Ok(false)
            }
            Response::Err { msg, code } => Err(ServerError { code, msg }.into()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Tear down a session: the hub forgets its finished tasks and
    /// cancels its waiting/ready/in-flight ones, leaving every other
    /// session untouched.  Returns the number of live tasks cancelled
    /// (0 against a pre-session hub, which has no session to close).
    pub fn close_session(&mut self, session: &str) -> Result<u64> {
        if self.session == SessionSupport::Unsupported {
            return Ok(0);
        }
        match self.roundtrip(&Request::CloseSession { session: session.to_string() })? {
            Response::Session { cancelled, .. } => {
                self.session = SessionSupport::Native;
                Ok(cancelled)
            }
            Response::Err { msg, code } if Self::is_pre_session_err(code, &msg) => {
                self.session = SessionSupport::Unsupported;
                Ok(0)
            }
            Response::Err { msg, code } => Err(ServerError { code, msg }.into()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// One incremental-delta round-trip: report `completions` (global
    /// task keys, any session), then create `creates` inside `session`
    /// (empty = anonymous).  The hub applies completions first, so a
    /// same-frame create may depend on a task completed by this very
    /// frame — the task-spawns-task primitive.  Opening the session is
    /// implicit (`OpenSession` is only needed for an *empty* session to
    /// exist).  Returns one [`SubmitOutcome`] per create, in order; a
    /// completion refusal aborts with the first [`ServerError`].
    ///
    /// Against a pre-session hub this degrades to [`Client::report`] +
    /// [`Client::submit`]: same tasks, anonymous namespace, two legacy
    /// round-trips instead of one.
    pub fn submit_delta(
        &mut self,
        session: &str,
        completions: &[Completion],
        creates: &[CreateItem],
    ) -> Result<Vec<SubmitOutcome>> {
        if completions.is_empty() && creates.is_empty() {
            return Ok(Vec::new());
        }
        if self.session != SessionSupport::Unsupported {
            let req = Request::SubmitDelta {
                session: session.to_string(),
                worker: self.worker.clone(),
                completions: completions.to_vec(),
                creates: creates.to_vec(),
            };
            match self.roundtrip(&req)? {
                Response::Batch(results) => {
                    self.session = SessionSupport::Native;
                    if results.len() != completions.len() + creates.len() {
                        bail!(
                            "delta reply carries {} results for {} completions + {} creates",
                            results.len(),
                            completions.len(),
                            creates.len()
                        );
                    }
                    let mut results = results.into_iter();
                    for r in results.by_ref().take(completions.len()) {
                        if let BatchItem::Err { msg, code } = r {
                            return Err(ServerError { code, msg }.into());
                        }
                    }
                    return Ok(results
                        .map(|r| match r {
                            BatchItem::Ok => SubmitOutcome::Created,
                            BatchItem::Err { msg, code } => {
                                SubmitOutcome::Refused(ServerError { code, msg })
                            }
                        })
                        .collect());
                }
                Response::Err { msg, code } if Self::is_pre_session_err(code, &msg) => {
                    self.session = SessionSupport::Unsupported;
                }
                Response::Err { msg, code } => return Err(ServerError { code, msg }.into()),
                other => bail!("unexpected reply {other:?}"),
            }
        }
        self.report(completions)?;
        self.submit(creates)
    }

    /// Per-task `Create` round-trip: [`Client::submit`]'s degrade path
    /// against a pre-batch hub.  The deprecated single-shot verbs that
    /// used to wrap these `_impl`s (`create`/`steal`/`steal_n`/
    /// `steal_poll`/`complete`) are gone — their compatibility window
    /// closed; the wire kinds themselves are still served for old
    /// binaries.
    fn create_impl(&mut self, task: TaskMsg, deps: &[String]) -> Result<()> {
        self.expect_ok(&Request::Create { task, deps: deps.to_vec() })
    }

    fn steal_n_impl(&mut self, n: u32) -> Result<StealBatch> {
        match self.roundtrip(&Request::StealN { worker: self.worker.clone(), n })? {
            Response::Tasks(ts) => Ok(StealBatch::Tasks(ts)),
            Response::Exit => Ok(StealBatch::AllDone),
            Response::Err { msg, code } => Err(ServerError { code, msg }.into()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Per-task `Complete` round-trip: [`Client::report`]'s degrade path.
    fn complete_impl(&mut self, task: &str, success: bool) -> Result<()> {
        self.expect_ok(&Request::Complete {
            worker: self.worker.clone(),
            task: task.to_string(),
            success,
        })
    }

    /// Replace a running task adding new dependencies (dynamic rewrite).
    pub fn transfer(&mut self, task: &str, new_deps: &[String]) -> Result<()> {
        self.expect_ok(&Request::Transfer {
            worker: self.worker.clone(),
            task: task.to_string(),
            new_deps: new_deps.to_vec(),
        })
    }

    pub fn exit(&mut self) -> Result<()> {
        self.expect_ok(&Request::Exit { worker: self.worker.clone() })
    }

    /// Announce departure on behalf of another worker — the paper's
    /// user-driven recovery for a worker that died without sending Exit
    /// (its connection just vanished): its assignments re-enter the
    /// ready queue at the front.
    pub fn exit_for(&mut self, worker: &str) -> Result<()> {
        self.expect_ok(&Request::Exit { worker: worker.to_string() })
    }

    pub fn status(&mut self) -> Result<StatusInfo> {
        match self.roundtrip(&Request::Status)? {
            Response::Status(s) => Ok(s),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn save(&mut self) -> Result<()> {
        self.expect_ok(&Request::Save)
    }

    /// Fetch the hub's live [`MetricsSnapshot`].  A hub running without
    /// an enabled registry answers with the version-0 sentinel (all
    /// fields empty); a pre-metrics hub answers `Err` for the unknown
    /// request kind, surfaced here as [`ServerError`].
    pub fn metrics(&mut self) -> Result<MetricsSnapshot> {
        match self.roundtrip(&Request::Metrics)? {
            Response::Metrics(m) => Ok(m),
            Response::Err { msg, code } => Err(ServerError { code, msg }.into()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// One live-event long-poll (`dhub tail`): registers this client's
    /// worker name as a subscriber on first contact, then drains up to
    /// `max` queued lifecycle events (0 = server default batch).  Only
    /// events emitted *after* registration are seen; `prefix` filters
    /// by task-name prefix server-side.  A pre-streaming hub answers
    /// `Err` for the unknown request kind, surfaced as [`ServerError`].
    pub fn subscribe(&mut self, prefix: &str, max: u32) -> Result<EventBatch> {
        match self.roundtrip(&Request::Subscribe {
            worker: self.worker.clone(),
            prefix: prefix.to_string(),
            max,
        })? {
            Response::Events { events, dropped, done } => {
                Ok(EventBatch { events, dropped, done })
            }
            Response::Err { msg, code } => Err(ServerError { code, msg }.into()),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Completion query: poll `Status` every `poll` until everything the
    /// hub has accepted is finished (done or errored), then return the
    /// final counters.  This is how a remote submitter awaits a campaign
    /// it cannot join() — the server-side drain signal.
    pub fn await_drained(&mut self, poll: Duration) -> Result<StatusInfo> {
        loop {
            let st = self.status()?;
            if st.is_drained() {
                return Ok(st);
            }
            std::thread::sleep(poll);
        }
    }
}

impl Drop for Client {
    fn drop(&mut self) {
        if self.exit_on_drop {
            let req = Request::Exit { worker: self.worker.clone() };
            let _ = self.conn.request(&req.encode());
        }
    }
}

/// Idle backoff while the hub has nothing ready: starts at the in-proc
/// RTT scale (200 µs, so a briefly empty queue costs nothing) and doubles
/// to a 100 ms ceiling, because "parked on an idle hub waiting for the
/// first submission" is a normal long-lived state in the remote
/// deployment — thousands of steal round-trips per second against an
/// empty queue would be pure hub load.  Reset on every served task.
struct IdleBackoff {
    current: Duration,
    floor: Duration,
    ceiling: Duration,
}

impl IdleBackoff {
    const FLOOR: Duration = Duration::from_micros(200);
    const CEILING: Duration = Duration::from_millis(100);

    /// Custom bounds (the `dhub worker` CLI exposes these); a zero floor
    /// is clamped to 1 µs and the ceiling never drops below the floor.
    fn with_bounds(floor: Duration, ceiling: Duration) -> IdleBackoff {
        let floor = floor.max(Duration::from_micros(1));
        IdleBackoff { current: floor, floor, ceiling: ceiling.max(floor) }
    }

    /// Sleep the current interval, then lengthen it.  Returns the time
    /// actually slept (for idle accounting).
    fn sleep(&mut self) -> f64 {
        let t0 = Instant::now();
        std::thread::sleep(self.current);
        self.current = (self.current * 2).min(self.ceiling);
        t0.elapsed().as_secs_f64()
    }

    fn reset(&mut self) {
        self.current = self.floor;
    }
}

/// StealN outcome.
#[derive(Debug)]
pub enum StealBatch {
    Tasks(Vec<TaskMsg>),
    AllDone,
}

/// One [`Client::subscribe`] long-poll's yield.
#[derive(Debug)]
pub struct EventBatch {
    pub events: Vec<TaskEvent>,
    /// events lost to the bounded server-side queue since the last poll
    pub dropped: u64,
    /// the hub's graph is non-empty and fully drained — a following
    /// tail can stop polling
    pub done: bool,
}

/// Per-worker accounting returned by [`run_worker`]: the Fig 5 breakdown
/// inputs (compute vs communication time).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub tasks_run: u64,
    pub tasks_failed: u64,
    pub compute_s: f64,
    /// time spent blocked on the server (steal + complete round-trips)
    pub comm_s: f64,
    /// time spent idle on NotFound backoff
    pub idle_s: f64,
}

/// Knobs for the worker main loop.  Defaults reproduce the historical
/// constants exactly: prefetch 1, idle backoff 200 µs → 100 ms, no
/// tracing.
#[derive(Clone, Debug)]
pub struct WorkerOpts {
    /// tasks to keep buffered locally (0 = strict steal→run→complete)
    pub prefetch: u32,
    /// idle-backoff bounds while the hub has nothing ready
    pub idle_floor: Duration,
    pub idle_ceiling: Duration,
    /// worker-side lifecycle recorder: `Connected` once at attach (the
    /// raw material for observing connection storms), then `Started`
    /// before each payload
    pub tracer: Tracer,
    /// record Finished/Failed here too.  Leave off when the tracer is
    /// shared with a traced [`SchedState`](super::state::SchedState) —
    /// the server owns the terminal events then; turn on for standalone
    /// worker traces (`dhub worker --trace`), whose hub stream lives in
    /// another process.
    pub trace_terminals: bool,
    /// worker-side live counters: poll/backoff/park transitions,
    /// steal-RTT and task-compute histograms.  Disabled (no-op) by
    /// default; share one enabled registry across a pool to aggregate.
    pub metrics: Registry,
    /// completions to buffer locally before one batched
    /// [`Client::report`] round-trip.  1 (the default) reports after
    /// every task — the historical behavior; larger values amortize the
    /// completion RTT across `report_batch` tasks.  The buffer is always
    /// flushed before parking (buffered completions may be gating
    /// successors) and before the loop returns, so a worker that exits —
    /// or dies and lets `exit_on_drop` fire — never strands reported
    /// work; 0 is clamped to 1.
    pub report_batch: usize,
}

impl Default for WorkerOpts {
    fn default() -> Self {
        WorkerOpts {
            prefetch: 1,
            idle_floor: IdleBackoff::FLOOR,
            idle_ceiling: IdleBackoff::CEILING,
            tracer: Tracer::default(),
            trace_terminals: false,
            metrics: Registry::default(),
            report_batch: 1,
        }
    }
}

/// Worker main loop with a prefetch buffer of `prefetch` tasks.
///
/// `exec` runs one task and returns Ok to report success.  With
/// `prefetch >= 1` the next task is already local when the current one
/// finishes, hiding the steal round-trip behind compute — the paper's
/// overlap strategy.  `prefetch == 0` degenerates to strict
/// steal→execute→complete (used to *measure* the unhidden RTT).
pub fn run_worker(
    client: &mut Client,
    prefetch: u32,
    exec: impl FnMut(&TaskMsg) -> Result<()>,
) -> Result<WorkerStats> {
    run_worker_opts(client, &WorkerOpts { prefetch, ..WorkerOpts::default() }, exec)
}

/// [`run_worker`] with every knob exposed (backoff bounds, tracing).
pub fn run_worker_opts(
    client: &mut Client,
    opts: &WorkerOpts,
    mut exec: impl FnMut(&TaskMsg) -> Result<()>,
) -> Result<WorkerStats> {
    // worker-scoped attach marker (task field empty): a lingering pool
    // re-entering this loop after a campaign boundary records one per
    // attach, which is exactly what makes connection storms observable
    opts.tracer.record("", EventKind::Connected, client.worker());
    let mut stats = WorkerStats::default();
    let mut buffer: VecDeque<TaskMsg> = VecDeque::new();
    let batch = opts.prefetch.max(1);
    let report_batch = opts.report_batch.max(1);
    // completions finished locally but not yet reported to the hub
    let mut pending: Vec<Completion> = Vec::new();
    let mut backoff = IdleBackoff::with_bounds(opts.idle_floor, opts.idle_ceiling);
    // one batched report round-trip for everything buffered
    fn flush(client: &mut Client, pending: &mut Vec<Completion>, stats: &mut WorkerStats) -> Result<()> {
        if pending.is_empty() {
            return Ok(());
        }
        let t0 = Instant::now();
        let r = client.report(pending);
        stats.comm_s += t0.elapsed().as_secs_f64();
        pending.clear();
        r
    }
    // park tracking: one WorkerParks per *episode* of consecutive empty
    // polls, not per backoff sleep — the metric counts transitions into
    // the idle state, matching the hub's view of a parked worker
    let mut parked = false;
    'outer: loop {
        // refill: keep `batch` tasks in hand
        while (buffer.len() as u32) < batch {
            let t0 = Instant::now();
            opts.metrics.inc(Counter::WorkerPolls);
            let outcome = client.acquire(batch - buffer.len() as u32)?;
            let rtt = t0.elapsed();
            opts.metrics.observe(Series::StealRtt, rtt);
            stats.comm_s += rtt.as_secs_f64();
            match outcome {
                StealBatch::Tasks(ts) if ts.is_empty() => {
                    if buffer.is_empty() {
                        // our own unreported completions may be gating
                        // successors — flush, then retry before parking
                        if !pending.is_empty() {
                            flush(client, &mut pending, &mut stats)?;
                            continue 'outer;
                        }
                        // nothing in hand and nothing ready: back off
                        if !parked {
                            parked = true;
                            opts.metrics.inc(Counter::WorkerParks);
                        }
                        opts.metrics.inc(Counter::WorkerBackoffs);
                        stats.idle_s += backoff.sleep();
                        continue 'outer;
                    }
                    break; // run what we have
                }
                StealBatch::Tasks(ts) => {
                    parked = false;
                    backoff.reset();
                    buffer.extend(ts);
                }
                StealBatch::AllDone => {
                    if buffer.is_empty() {
                        break 'outer;
                    }
                    break;
                }
            }
        }
        let Some(task) = buffer.pop_front() else { continue };
        // session-qualified names split for the trace (`alpha<US>x` is
        // recorded as task `x` in session `alpha`); completions keep the
        // full qualified key — that is the global handle the hub knows
        opts.tracer.record_in_session(
            task.session(),
            task.short_name(),
            EventKind::Started,
            client.worker(),
        );
        let t0 = Instant::now();
        let ok = exec(&task).is_ok();
        let compute = t0.elapsed();
        opts.metrics.observe(Series::TaskCompute, compute);
        stats.compute_s += compute.as_secs_f64();
        stats.tasks_run += 1;
        if !ok {
            stats.tasks_failed += 1;
        }
        if opts.trace_terminals {
            let kind = if ok { EventKind::Finished } else { EventKind::Failed };
            opts.tracer.record_in_session(task.session(), task.short_name(), kind, client.worker());
        }
        pending.push(Completion { task: task.name.clone(), success: ok });
        if pending.len() >= report_batch {
            flush(client, &mut pending, &mut stats)?;
        }
    }
    flush(client, &mut pending, &mut stats)?;
    Ok(stats)
}

/// Self-diagnostic hook from the paper's client loop: on failure the
/// worker informs the server of Exit so its tasks are re-queued.
pub fn run_worker_with_diagnostic(
    client: &mut Client,
    prefetch: u32,
    exec: impl FnMut(&TaskMsg) -> Result<()>,
    mut healthy: impl FnMut() -> bool,
) -> Result<WorkerStats> {
    if !healthy() {
        client.exit()?;
        return Err(anyhow!("worker failed self-diagnostic before starting"));
    }
    run_worker(client, prefetch, exec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dwork::server::{spawn_inproc, ServerConfig};
    use crate::coordinator::dwork::state::SchedState;

    fn farm(n_tasks: usize) -> SchedState {
        let mut s = SchedState::new();
        for i in 0..n_tasks {
            s.create(TaskMsg::new(format!("t{i}"), vec![]), &[]).unwrap();
        }
        s
    }

    #[test]
    fn single_worker_drains_farm() {
        let (connector, handle) = spawn_inproc(farm(50), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let stats = run_worker(&mut c, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 50);
        assert_eq!(stats.tasks_failed, 0);
        drop(c);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn many_workers_share_farm() {
        let (connector, handle) = spawn_inproc(farm(200), ServerConfig::default());
        let totals: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|i| {
                    let conn = connector.connect();
                    s.spawn(move || {
                        let mut c = Client::new(Box::new(conn), format!("w{i}"));
                        run_worker(&mut c, 2, |_| Ok(())).unwrap().tasks_run
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(totals.iter().sum::<u64>(), 200);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn prefetch_overlap_still_completes_everything() {
        for prefetch in [0, 1, 4, 16] {
            let (connector, handle) = spawn_inproc(farm(64), ServerConfig::default());
            let mut c = Client::new(Box::new(connector.connect()), "w0");
            let stats = run_worker(&mut c, prefetch, |_| Ok(())).unwrap();
            assert_eq!(stats.tasks_run, 64, "prefetch={prefetch}");
            drop(c);
            drop(connector);
            assert!(handle.join().unwrap().all_done());
        }
    }

    #[test]
    fn failing_tasks_error_out_dependents() {
        let mut s = SchedState::new();
        s.create(TaskMsg::new("bad", vec![]), &[]).unwrap();
        s.create(TaskMsg::new("child", vec![]), &["bad".to_string()]).unwrap();
        s.create(TaskMsg::new("good", vec![]), &[]).unwrap();
        let (connector, handle) = spawn_inproc(s, ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let stats = run_worker(&mut c, 0, |t| {
            if t.name == "bad" {
                anyhow::bail!("task exploded")
            }
            Ok(())
        })
        .unwrap();
        // bad + good ran; child never served
        assert_eq!(stats.tasks_run, 2);
        assert_eq!(stats.tasks_failed, 1);
        drop(c);
        drop(connector);
        let state = handle.join().unwrap();
        assert!(state.all_done());
        assert_eq!(
            state.get("child").unwrap().state,
            crate::coordinator::dwork::state::TaskState::Error
        );
    }

    #[test]
    fn dynamic_task_insertion_from_worker() {
        // a worker that, on finding "expand", creates two children
        let (connector, handle) = spawn_inproc(farm(0), ServerConfig::default());
        {
            let mut seed = Client::new(Box::new(connector.connect()), "user");
            let out =
                seed.submit(&[CreateItem::new(TaskMsg::new("expand", vec![]), vec![])]).unwrap();
            assert!(out.iter().all(SubmitOutcome::is_created));
        }
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let conn2 = connector.connect();
        let mut creator = Client::new(Box::new(conn2), "w0-creator");
        let stats = run_worker(&mut c, 0, |t| {
            if t.name == "expand" {
                let out = creator
                    .submit(&[
                        CreateItem::new(TaskMsg::new("child-1", vec![]), vec![]),
                        CreateItem::new(TaskMsg::new("child-2", vec![]), vec![]),
                    ])
                    .unwrap();
                assert!(out.iter().all(SubmitOutcome::is_created));
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(stats.tasks_run, 3);
        drop(c);
        drop(creator);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn exit_on_drop_requeues_assignments() {
        let (connector, handle) = spawn_inproc(farm(3), ServerConfig::default());
        {
            let mut dying =
                Client::new(Box::new(connector.connect()), "dying").exit_on_drop(true);
            match dying.acquire(2).unwrap() {
                StealBatch::Tasks(ts) => assert_eq!(ts.len(), 2),
                other => panic!("expected a batch, got {other:?}"),
            }
        } // dropped holding 2 assigned tasks: Exit hands them back
        let mut c = Client::new(Box::new(connector.connect()), "survivor");
        let stats = run_worker(&mut c, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 3, "re-queued tasks must reach the survivor");
        drop(c);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn worker_metrics_count_polls_and_compute() {
        let metrics = Registry::enabled();
        let (connector, handle) = spawn_inproc(farm(8), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let opts = WorkerOpts { metrics: metrics.clone(), ..WorkerOpts::default() };
        let stats = run_worker_opts(&mut c, &opts, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 8);
        let snap = metrics.snapshot();
        assert!(snap.counter("worker_polls") >= 8, "one poll per task at minimum");
        let compute = snap.hist("task_compute").expect("task_compute histogram");
        assert_eq!(compute.count, 8);
        let rtt = snap.hist("steal_rtt").expect("steal_rtt histogram");
        assert_eq!(rtt.count, snap.counter("worker_polls"));
        // the farm never emptied mid-run, so parks only happen if a poll
        // raced the drain — and then an episode is one park, not many
        assert!(snap.counter("worker_parks") <= snap.counter("worker_backoffs").max(1));
        drop(c);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn submit_reports_per_item_outcomes_in_order() {
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "user");
        assert_eq!(c.uses_batch_wire(), None, "unprobed before the first batch call");
        let out = c
            .submit(&[
                CreateItem::new(TaskMsg::new("a", vec![]), vec![]),
                CreateItem::new(TaskMsg::new("a", vec![]), vec![]), // duplicate
                CreateItem::new(TaskMsg::new("b", vec![]), vec!["ghost".into()]), // missing dep
                CreateItem::new(TaskMsg::new("c", vec![]), vec!["a".into()]),
            ])
            .unwrap();
        assert_eq!(c.uses_batch_wire(), Some(true), "current hub speaks batch kinds");
        assert_eq!(out.len(), 4);
        assert!(out[0].is_created());
        assert_eq!(out[1].code(), Some(RefusalCode::Duplicate));
        assert_eq!(out[2].code(), Some(RefusalCode::DepMissing));
        assert!(out[3].is_created());
        // a refusal inside the batch never poisoned the frame: the
        // accepted items are live and drainable
        let mut w = Client::new(Box::new(connector.connect()), "w0");
        let stats = run_worker(&mut w, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 2);
        drop(c);
        drop(w);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn session_verbs_round_trip_and_pin_native() {
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "user");
        assert_eq!(c.uses_session_wire(), None, "unprobed before the first session verb");
        assert!(c.open_session("alpha").unwrap());
        assert_eq!(c.uses_session_wire(), Some(true));
        let out = c
            .submit_delta("alpha", &[], &[CreateItem::new(TaskMsg::new("a", vec![]), vec![])])
            .unwrap();
        assert!(out.iter().all(SubmitOutcome::is_created));
        let mut w = Client::new(Box::new(connector.connect()), "w0");
        let stats = run_worker(&mut w, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 1);
        let st = c.status().unwrap();
        let row = st.sessions.iter().find(|r| r.name == "alpha").expect("session row");
        assert_eq!(row.completed, 1);
        assert_eq!(c.close_session("alpha").unwrap(), 0, "drained session: nothing to cancel");
        assert!(c.status().unwrap().sessions.is_empty());
        drop(c);
        drop(w);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn submit_delta_reports_and_creates_in_one_frame() {
        let (connector, handle) = spawn_inproc(SchedState::new(), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        c.submit_delta("gen", &[], &[CreateItem::new(TaskMsg::new("root", vec![]), vec![])])
            .unwrap();
        let ts = match c.acquire(1).unwrap() {
            StealBatch::Tasks(ts) => ts,
            other => panic!("expected tasks, got {other:?}"),
        };
        assert_eq!(ts[0].session(), "gen");
        // complete root and hang a child off it in the same frame
        let out = c
            .submit_delta(
                "gen",
                &[Completion::ok(&ts[0].name)],
                &[CreateItem::new(TaskMsg::new("child", vec![]), vec!["root".into()])],
            )
            .unwrap();
        assert_eq!(out.len(), 1, "one outcome per create; clean completions are not echoed");
        assert!(out[0].is_created());
        let ts = match c.acquire(1).unwrap() {
            StealBatch::Tasks(ts) => ts,
            other => panic!("expected tasks, got {other:?}"),
        };
        assert_eq!(ts[0].short_name(), "child", "same-frame dependency resolved");
        c.report(&[Completion::ok(&ts[0].name)]).unwrap();
        drop(c);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn session_verbs_degrade_against_pre_session_hub() {
        let cfg = ServerConfig { compat_pre_sessions: true, ..ServerConfig::default() };
        let (connector, handle) = spawn_inproc(SchedState::new(), cfg);
        let mut c = Client::new(Box::new(connector.connect()), "user");
        assert!(!c.open_session("alpha").unwrap(), "pre-session hub: no session namespace");
        assert_eq!(c.uses_session_wire(), Some(false));
        // creates land anonymous through the legacy submit path
        let out = c
            .submit_delta("alpha", &[], &[CreateItem::new(TaskMsg::new("a", vec![]), vec![])])
            .unwrap();
        assert!(out[0].is_created());
        let mut w = Client::new(Box::new(connector.connect()), "w0");
        let stats = run_worker(&mut w, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 1);
        let st = c.status().unwrap();
        assert!(st.sessions.is_empty(), "anonymous tasks never grow session rows");
        assert_eq!(st.completed, 1);
        assert_eq!(c.close_session("alpha").unwrap(), 0);
        drop(c);
        drop(w);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn report_flags_bad_completions() {
        let (connector, handle) = spawn_inproc(farm(2), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let ts = match c.acquire(2).unwrap() {
            StealBatch::Tasks(ts) => ts,
            other => panic!("expected tasks, got {other:?}"),
        };
        assert_eq!(ts.len(), 2);
        // one good completion + one for a task we never stole
        let err = c
            .report(&[Completion::ok(&ts[0].name), Completion::ok("never-stolen")])
            .unwrap_err();
        let se = err.downcast::<ServerError>().expect("typed server error");
        assert!(se.msg.contains("never-stolen"), "{se}");
        // the good half of the batch landed
        c.report(&[Completion::ok(&ts[1].name)]).unwrap();
        let st = c.status().unwrap();
        assert_eq!(st.completed, 2);
        drop(c);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn batched_reporting_drains_dependency_chains() {
        // report_batch > 1 buffers completions locally; the flush-before-
        // park rule must kick in when buffered completions gate the only
        // remaining successors, or this chain would deadlock
        let mut s = SchedState::new();
        s.create(TaskMsg::new("c0", vec![]), &[]).unwrap();
        for i in 1..6 {
            s.create(TaskMsg::new(format!("c{i}"), vec![]), &[format!("c{}", i - 1)]).unwrap();
        }
        let (connector, handle) = spawn_inproc(s, ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let opts = WorkerOpts { report_batch: 4, ..WorkerOpts::default() };
        let stats = run_worker_opts(&mut c, &opts, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 6);
        drop(c);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn report_batch_sizes_all_drain_farm() {
        for report_batch in [1, 3, 16, 64] {
            let (connector, handle) = spawn_inproc(farm(40), ServerConfig::default());
            let mut c = Client::new(Box::new(connector.connect()), "w0");
            let opts = WorkerOpts { prefetch: 4, report_batch, ..WorkerOpts::default() };
            let stats = run_worker_opts(&mut c, &opts, |_| Ok(())).unwrap();
            assert_eq!(stats.tasks_run, 40, "report_batch={report_batch}");
            drop(c);
            drop(connector);
            assert!(handle.join().unwrap().all_done());
        }
    }

    #[test]
    fn await_drained_returns_final_counters() {
        let (connector, handle) = spawn_inproc(farm(5), ServerConfig::default());
        let connector2 = connector.clone();
        let watcher = std::thread::spawn(move || {
            let mut c = Client::new(Box::new(connector2.connect()), "watcher");
            let st = c.await_drained(Duration::from_millis(1)).unwrap();
            drop(c);
            st
        });
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        run_worker(&mut c, 1, |_| Ok(())).unwrap();
        let st = watcher.join().unwrap();
        assert!(st.is_drained());
        assert_eq!(st.completed, 5);
        assert_eq!(st.failed, 0);
        drop(c);
        drop(connector);
        handle.join().unwrap();
    }

    #[test]
    fn diagnostic_failure_exits_cleanly() {
        let (connector, handle) = spawn_inproc(farm(3), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "sick");
        let r = run_worker_with_diagnostic(&mut c, 0, |_| Ok(()), || false);
        assert!(r.is_err());
        // the farm is still fully drainable by a healthy worker
        let mut c2 = Client::new(Box::new(connector.connect()), "healthy");
        let stats = run_worker(&mut c2, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 3);
        drop(c);
        drop(c2);
        drop(connector);
        handle.join().unwrap();
    }
}
