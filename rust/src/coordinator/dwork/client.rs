//! dwork client: the worker-side API + the worker main loop.
//!
//! [`Client`] is a thin typed wrapper over one connection (the paper's
//! dquery CLI and user programs sit at this level).  [`run_worker`] is the
//! paper Fig 2 client loop:
//!
//! ```text
//! while server responds with task do
//!     copy-in task inputs; execute task; inform server of completion
//! end; inform server of Exit
//! ```
//!
//! with the paper's compute/communication overlap implemented as a
//! prefetch buffer: while a task executes, the next Steal has already
//! been issued (depth configurable; sec. 5's "Steal n" batching).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::substrate::transport::ClientConn;

use super::messages::{Request, Response, StatusInfo, TaskMsg};

/// Typed request/reply client.
pub struct Client {
    conn: Box<dyn ClientConn>,
    worker: String,
}

impl Client {
    pub fn new(conn: Box<dyn ClientConn>, worker: impl Into<String>) -> Client {
        Client { conn, worker: worker.into() }
    }

    pub fn worker(&self) -> &str {
        &self.worker
    }

    fn roundtrip(&mut self, req: &Request) -> Result<Response> {
        let reply = self.conn.request(&req.encode())?;
        Response::decode(&reply)
    }

    fn expect_ok(&mut self, req: &Request) -> Result<()> {
        match self.roundtrip(req)? {
            Response::Ok => Ok(()),
            Response::Err(e) => bail!("server error: {e}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Create a task with dependencies.
    pub fn create(&mut self, task: TaskMsg, deps: &[String]) -> Result<()> {
        self.expect_ok(&Request::Create { task, deps: deps.to_vec() })
    }

    /// Steal one task.  Ok(None) = everything complete (server said Exit).
    /// NotFound (nothing ready *yet*) is surfaced as `StealOutcome` via
    /// [`Client::steal_poll`]; this convenience blocks through it.
    pub fn steal(&mut self) -> Result<Option<TaskMsg>> {
        loop {
            match self.steal_poll()? {
                StealOutcome::Task(t) => return Ok(Some(t)),
                StealOutcome::AllDone => return Ok(None),
                StealOutcome::NotReady => {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
        }
    }

    /// Non-blocking steal: one round-trip, three-way outcome.
    pub fn steal_poll(&mut self) -> Result<StealOutcome> {
        match self.roundtrip(&Request::Steal { worker: self.worker.clone() })? {
            Response::Task(t) => Ok(StealOutcome::Task(t)),
            Response::NotFound => Ok(StealOutcome::NotReady),
            Response::Exit => Ok(StealOutcome::AllDone),
            Response::Err(e) => bail!("server error: {e}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    /// Steal up to n tasks (batching extension).
    pub fn steal_n(&mut self, n: u32) -> Result<StealBatch> {
        match self.roundtrip(&Request::StealN { worker: self.worker.clone(), n })? {
            Response::Tasks(ts) => Ok(StealBatch::Tasks(ts)),
            Response::Exit => Ok(StealBatch::AllDone),
            Response::Err(e) => bail!("server error: {e}"),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn complete(&mut self, task: &str, success: bool) -> Result<()> {
        self.expect_ok(&Request::Complete {
            worker: self.worker.clone(),
            task: task.to_string(),
            success,
        })
    }

    /// Replace a running task adding new dependencies (dynamic rewrite).
    pub fn transfer(&mut self, task: &str, new_deps: &[String]) -> Result<()> {
        self.expect_ok(&Request::Transfer {
            worker: self.worker.clone(),
            task: task.to_string(),
            new_deps: new_deps.to_vec(),
        })
    }

    pub fn exit(&mut self) -> Result<()> {
        self.expect_ok(&Request::Exit { worker: self.worker.clone() })
    }

    pub fn status(&mut self) -> Result<StatusInfo> {
        match self.roundtrip(&Request::Status)? {
            Response::Status(s) => Ok(s),
            other => bail!("unexpected reply {other:?}"),
        }
    }

    pub fn save(&mut self) -> Result<()> {
        self.expect_ok(&Request::Save)
    }
}

/// Three-way steal outcome.
#[derive(Debug)]
pub enum StealOutcome {
    Task(TaskMsg),
    NotReady,
    AllDone,
}

/// StealN outcome.
#[derive(Debug)]
pub enum StealBatch {
    Tasks(Vec<TaskMsg>),
    AllDone,
}

/// Per-worker accounting returned by [`run_worker`]: the Fig 5 breakdown
/// inputs (compute vs communication time).
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub tasks_run: u64,
    pub tasks_failed: u64,
    pub compute_s: f64,
    /// time spent blocked on the server (steal + complete round-trips)
    pub comm_s: f64,
    /// time spent idle on NotFound backoff
    pub idle_s: f64,
}

/// Worker main loop with a prefetch buffer of `prefetch` tasks.
///
/// `exec` runs one task and returns Ok to report success.  With
/// `prefetch >= 1` the next task is already local when the current one
/// finishes, hiding the steal round-trip behind compute — the paper's
/// overlap strategy.  `prefetch == 0` degenerates to strict
/// steal→execute→complete (used to *measure* the unhidden RTT).
pub fn run_worker(
    client: &mut Client,
    prefetch: u32,
    mut exec: impl FnMut(&TaskMsg) -> Result<()>,
) -> Result<WorkerStats> {
    let mut stats = WorkerStats::default();
    let mut buffer: VecDeque<TaskMsg> = VecDeque::new();
    let batch = prefetch.max(1);
    'outer: loop {
        // refill: keep `batch` tasks in hand
        while (buffer.len() as u32) < batch {
            let t0 = Instant::now();
            let outcome = client.steal_n(batch - buffer.len() as u32)?;
            stats.comm_s += t0.elapsed().as_secs_f64();
            match outcome {
                StealBatch::Tasks(ts) if ts.is_empty() => {
                    if buffer.is_empty() {
                        // nothing in hand and nothing ready: back off
                        let t0 = Instant::now();
                        std::thread::sleep(Duration::from_micros(200));
                        stats.idle_s += t0.elapsed().as_secs_f64();
                        continue 'outer;
                    }
                    break; // run what we have
                }
                StealBatch::Tasks(ts) => buffer.extend(ts),
                StealBatch::AllDone => {
                    if buffer.is_empty() {
                        break 'outer;
                    }
                    break;
                }
            }
        }
        let Some(task) = buffer.pop_front() else { continue };
        let t0 = Instant::now();
        let ok = exec(&task).is_ok();
        stats.compute_s += t0.elapsed().as_secs_f64();
        stats.tasks_run += 1;
        if !ok {
            stats.tasks_failed += 1;
        }
        let t0 = Instant::now();
        client.complete(&task.name, ok)?;
        stats.comm_s += t0.elapsed().as_secs_f64();
    }
    Ok(stats)
}

/// Self-diagnostic hook from the paper's client loop: on failure the
/// worker informs the server of Exit so its tasks are re-queued.
pub fn run_worker_with_diagnostic(
    client: &mut Client,
    prefetch: u32,
    exec: impl FnMut(&TaskMsg) -> Result<()>,
    mut healthy: impl FnMut() -> bool,
) -> Result<WorkerStats> {
    if !healthy() {
        client.exit()?;
        return Err(anyhow!("worker failed self-diagnostic before starting"));
    }
    run_worker(client, prefetch, exec)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::dwork::server::{spawn_inproc, ServerConfig};
    use crate::coordinator::dwork::state::SchedState;

    fn farm(n_tasks: usize) -> SchedState {
        let mut s = SchedState::new();
        for i in 0..n_tasks {
            s.create(TaskMsg::new(format!("t{i}"), vec![]), &[]).unwrap();
        }
        s
    }

    #[test]
    fn single_worker_drains_farm() {
        let (connector, handle) = spawn_inproc(farm(50), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let stats = run_worker(&mut c, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 50);
        assert_eq!(stats.tasks_failed, 0);
        drop(c);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn many_workers_share_farm() {
        let (connector, handle) = spawn_inproc(farm(200), ServerConfig::default());
        let totals: Vec<u64> = std::thread::scope(|s| {
            let handles: Vec<_> = (0..4)
                .map(|i| {
                    let conn = connector.connect();
                    s.spawn(move || {
                        let mut c = Client::new(Box::new(conn), format!("w{i}"));
                        run_worker(&mut c, 2, |_| Ok(())).unwrap().tasks_run
                    })
                })
                .collect();
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        });
        assert_eq!(totals.iter().sum::<u64>(), 200);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn prefetch_overlap_still_completes_everything() {
        for prefetch in [0, 1, 4, 16] {
            let (connector, handle) = spawn_inproc(farm(64), ServerConfig::default());
            let mut c = Client::new(Box::new(connector.connect()), "w0");
            let stats = run_worker(&mut c, prefetch, |_| Ok(())).unwrap();
            assert_eq!(stats.tasks_run, 64, "prefetch={prefetch}");
            drop(c);
            drop(connector);
            assert!(handle.join().unwrap().all_done());
        }
    }

    #[test]
    fn failing_tasks_error_out_dependents() {
        let mut s = SchedState::new();
        s.create(TaskMsg::new("bad", vec![]), &[]).unwrap();
        s.create(TaskMsg::new("child", vec![]), &["bad".to_string()]).unwrap();
        s.create(TaskMsg::new("good", vec![]), &[]).unwrap();
        let (connector, handle) = spawn_inproc(s, ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let stats = run_worker(&mut c, 0, |t| {
            if t.name == "bad" {
                anyhow::bail!("task exploded")
            }
            Ok(())
        })
        .unwrap();
        // bad + good ran; child never served
        assert_eq!(stats.tasks_run, 2);
        assert_eq!(stats.tasks_failed, 1);
        drop(c);
        drop(connector);
        let state = handle.join().unwrap();
        assert!(state.all_done());
        assert_eq!(
            state.get("child").unwrap().state,
            crate::coordinator::dwork::state::TaskState::Error
        );
    }

    #[test]
    fn dynamic_task_insertion_from_worker() {
        // a worker that, on finding "expand", creates two children
        let (connector, handle) = spawn_inproc(farm(0), ServerConfig::default());
        {
            let mut seed = Client::new(Box::new(connector.connect()), "user");
            seed.create(TaskMsg::new("expand", vec![]), &[]).unwrap();
        }
        let mut c = Client::new(Box::new(connector.connect()), "w0");
        let conn2 = connector.connect();
        let mut creator = Client::new(Box::new(conn2), "w0-creator");
        let stats = run_worker(&mut c, 0, |t| {
            if t.name == "expand" {
                creator.create(TaskMsg::new("child-1", vec![]), &[]).unwrap();
                creator.create(TaskMsg::new("child-2", vec![]), &[]).unwrap();
            }
            Ok(())
        })
        .unwrap();
        assert_eq!(stats.tasks_run, 3);
        drop(c);
        drop(creator);
        drop(connector);
        assert!(handle.join().unwrap().all_done());
    }

    #[test]
    fn diagnostic_failure_exits_cleanly() {
        let (connector, handle) = spawn_inproc(farm(3), ServerConfig::default());
        let mut c = Client::new(Box::new(connector.connect()), "sick");
        let r = run_worker_with_diagnostic(&mut c, 0, |_| Ok(()), || false);
        assert!(r.is_err());
        // the farm is still fully drainable by a healthy worker
        let mut c2 = Client::new(Box::new(connector.connect()), "healthy");
        let stats = run_worker(&mut c2, 0, |_| Ok(())).unwrap();
        assert_eq!(stats.tasks_run, 3);
        drop(c);
        drop(c2);
        drop(connector);
        handle.join().unwrap();
    }
}
