//! dwork message API (paper Table 2) on the wire codec.
//!
//! | Query    | Parameter      | Response        |
//! |----------|----------------|-----------------|
//! | Create   | Task, [Task]   | Ok              |
//! | Steal    | Worker         | Task? | Exit    |
//! | StealN   | Worker, n      | Tasks | Exit    | (sec. 5 batching extension)
//! | Complete | Worker, Task   | Ok              | (+ success flag)
//! | Transfer | Worker, [Task] | Ok              |
//! | Exit     | Worker         | Ok              |
//! | Status   | –              | Status          | (dquery support)
//! | Metrics  | –              | Metrics         | (live-metrics extension)
//! | Subscribe| Worker, pfx, n | Events          | (lifecycle tail extension)
//! | CreateBatch   | [Task, [Task]]   | Batch    | (throughput extension)
//! | CompleteBatch | Worker, [(Task, ok)] | Batch| (throughput extension)
//! | OpenSession   | Session          | Session  | (multi-client extension)
//! | CloseSession  | Session          | Session  | (multi-client extension)
//! | SubmitDelta   | Session, Worker, [(Task, ok)], [Task, [Task]] | Batch |
//!
//! Workers are strings; Tasks are messages carrying arbitrary metadata —
//! exactly the paper's protobuf choice, here via `substrate::wire`.
//!
//! `Subscribe` is a *long-poll*: the transport is strict request/reply,
//! so a tail client calls it repeatedly and each reply drains whatever
//! the hub buffered for that subscriber since the previous call (bounded
//! queue, drop-oldest — a slow tail can never stall the serve loop).
//!
//! The session kinds give a shared hub per-client namespaces (Rain's
//! session-scoped server, Balsam's multi-user service): `SubmitDelta`
//! carries completions *and* creates in one frame — the task-spawns-task
//! path — and is answered with per-item [`Response::Batch`] results,
//! completions first.  Pre-session hubs answer the unknown kinds with a
//! whole-frame `Err`, the client's signal to degrade to the anonymous
//! single-session behavior.

use anyhow::{anyhow, bail, Result};

use crate::metrics::{HistSnapshot, MetricsSnapshot};
use crate::substrate::wire::{self, Reader, Value, Writer};
use crate::trace::{EventKind, TaskEvent};

/// Task payload crossing the wire: name + opaque body + originator.
///
/// The body is scheduler-opaque (the paper: "tasks are software anyway");
/// our workloads encode the artifact name + input seed in it.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TaskMsg {
    pub name: String,
    pub body: Vec<u8>,
    pub originator: String,
}

/// Separator between a session name and a task name inside a
/// session-qualified task id (`"<session>\u{1f}<task>"`).  The hub keys
/// every named-session task this way, so sessions are disjoint
/// namespaces (two campaigns may both submit a task called `t0`) and
/// teardown can sweep exactly one session's tasks.  Anonymous-session
/// ids carry no separator — they are the raw task name, byte-identical
/// to the pre-session wire.  Session names themselves may not contain
/// the separator.
pub const SESSION_SEP: char = '\u{1f}';

impl TaskMsg {
    pub fn new(name: impl Into<String>, body: Vec<u8>) -> TaskMsg {
        TaskMsg { name: name.into(), body, originator: String::new() }
    }

    /// The session component of a session-qualified task id (empty for
    /// anonymous-session tasks).
    pub fn session(&self) -> &str {
        self.name.split_once(SESSION_SEP).map(|(s, _)| s).unwrap_or("")
    }

    /// The task name without its session qualifier — what the submitter
    /// called the task, and what traces record (with the session riding
    /// in the event's own `session` field).
    pub fn short_name(&self) -> &str {
        self.name.split_once(SESSION_SEP).map(|(_, n)| n).unwrap_or(&self.name)
    }

    fn encode_into(&self, w: &mut Writer, field: u32) {
        let mut t = Writer::new();
        t.string(1, &self.name);
        t.bytes(2, &self.body);
        t.string(3, &self.originator);
        w.message(field, &t);
    }

    fn decode(bytes: &[u8]) -> Result<TaskMsg> {
        let fields = Reader::new(bytes).fields()?;
        Ok(TaskMsg {
            name: wire::get_str(&fields, 1)?.to_string(),
            body: fields
                .iter()
                .find(|(f, _)| *f == 2)
                .and_then(|(_, v)| v.as_bytes())
                .unwrap_or_default()
                .to_vec(),
            originator: wire::get_str(&fields, 3).unwrap_or_default().to_string(),
        })
    }
}

/// One task of a batched Create: the task plus its dependency names —
/// the payload of one classic `Create` request, batchable.
#[derive(Clone, Debug, PartialEq)]
pub struct CreateItem {
    pub task: TaskMsg,
    pub deps: Vec<String>,
}

impl CreateItem {
    pub fn new(task: TaskMsg, deps: Vec<String>) -> CreateItem {
        CreateItem { task, deps }
    }
}

/// One completion report inside a batched Complete.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Completion {
    pub task: String,
    pub success: bool,
}

impl Completion {
    pub fn ok(task: impl Into<String>) -> Completion {
        Completion { task: task.into(), success: true }
    }

    pub fn failed(task: impl Into<String>) -> Completion {
        Completion { task: task.into(), success: false }
    }
}

/// Requests a client can send to dhub.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Create a task with dependencies (dep task names).
    Create { task: TaskMsg, deps: Vec<String> },
    /// Deque (steal) one ready task for `worker`.
    Steal { worker: String },
    /// Steal up to `n` ready tasks (batching extension, paper sec. 5).
    StealN { worker: String, n: u32 },
    /// Report completion; `success=false` marks the task errored.
    Complete { worker: String, task: String, success: bool },
    /// Replace a running task, adding new dependencies (paper's rewrite).
    Transfer { worker: String, task: String, new_deps: Vec<String> },
    /// Worker (or user, for a dead worker) announces departure.
    Exit { worker: String },
    /// Queue introspection (dquery).
    Status,
    /// Ask the server to persist a snapshot now.
    Save,
    /// Live-metrics snapshot (counters/gauges/histograms).  `Status`
    /// is untouched, so this is wire-compatible with old servers: they
    /// answer the unknown kind with `Response::Err`.
    Metrics,
    /// Long-poll the hub's lifecycle event stream.  The first call from
    /// a `worker` name registers the subscriber (with an optional task
    /// name `prefix` filter); every call drains up to `max` buffered
    /// events (0 = server default).  Old servers answer the unknown
    /// kind with `Response::Err`, so tail clients degrade cleanly.
    Subscribe { worker: String, prefix: String, max: u32 },
    /// Batched Create: every item is one classic Create, applied in
    /// request order, answered with per-item results
    /// ([`Response::Batch`]) so refusals keep their classification.
    /// Old hubs answer the unknown kind with a whole-frame
    /// `Response::Err`, which tells the client to degrade to per-task
    /// mode — the submit side of the throughput extension.
    CreateBatch { items: Vec<CreateItem> },
    /// Batched Complete, the `StealN`-symmetric completion path: one
    /// worker reports many finished tasks in one round trip.  Same
    /// per-item `Batch` reply and same old-hub degrade signal as
    /// `CreateBatch`.
    CompleteBatch { worker: String, completions: Vec<Completion> },
    /// Open (or idempotently re-open) a named session namespace on the
    /// hub.  Answered with [`Response::Session`]; a pre-session hub
    /// answers the unknown kind with a whole-frame `Err` — the client's
    /// degrade probe.
    OpenSession { session: String },
    /// Tear the session down: cancel/forget every task it owns (ready
    /// tasks leave the queue, in-flight completions are ignored) while
    /// other sessions keep draining.  Answered with
    /// [`Response::Session`] carrying the cancelled-task count.
    CloseSession { session: String },
    /// Incremental graph delta into a session: `completions` are applied
    /// first (a completion report may carry the delta — the
    /// task-spawns-task path), then `creates`, so a same-frame create
    /// may depend on a just-completed task or an earlier create in the
    /// same delta.  Answered with per-item [`Response::Batch`] results,
    /// completions first, then creates.  An empty session targets the
    /// anonymous namespace (exact `CreateBatch`+`CompleteBatch`
    /// semantics in one frame).
    SubmitDelta {
        session: String,
        worker: String,
        creates: Vec<CreateItem>,
        completions: Vec<Completion>,
    },
}

const REQ_CREATE: u64 = 1;
const REQ_STEAL: u64 = 2;
const REQ_STEAL_N: u64 = 3;
const REQ_COMPLETE: u64 = 4;
const REQ_TRANSFER: u64 = 5;
const REQ_EXIT: u64 = 6;
const REQ_STATUS: u64 = 7;
const REQ_SAVE: u64 = 8;
const REQ_METRICS: u64 = 9;
const REQ_SUBSCRIBE: u64 = 10;
const REQ_CREATE_BATCH: u64 = 11;
const REQ_COMPLETE_BATCH: u64 = 12;
const REQ_OPEN_SESSION: u64 = 13;
const REQ_CLOSE_SESSION: u64 = 14;
const REQ_SUBMIT_DELTA: u64 = 15;

impl Request {
    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(64);
        match self {
            Request::Create { task, deps } => {
                w.uint(1, REQ_CREATE);
                task.encode_into(&mut w, 2);
                w.strings(3, deps.iter().map(String::as_str));
            }
            Request::Steal { worker } => {
                w.uint(1, REQ_STEAL);
                w.string(4, worker);
            }
            Request::StealN { worker, n } => {
                w.uint(1, REQ_STEAL_N);
                w.string(4, worker);
                w.uint(5, *n as u64);
            }
            Request::Complete { worker, task, success } => {
                w.uint(1, REQ_COMPLETE);
                w.string(4, worker);
                w.string(6, task);
                w.uint(7, *success as u64);
            }
            Request::Transfer { worker, task, new_deps } => {
                w.uint(1, REQ_TRANSFER);
                w.string(4, worker);
                w.string(6, task);
                w.strings(3, new_deps.iter().map(String::as_str));
            }
            Request::Exit { worker } => {
                w.uint(1, REQ_EXIT);
                w.string(4, worker);
            }
            Request::Status => {
                w.uint(1, REQ_STATUS);
            }
            Request::Save => {
                w.uint(1, REQ_SAVE);
            }
            Request::Metrics => {
                w.uint(1, REQ_METRICS);
            }
            Request::Subscribe { worker, prefix, max } => {
                w.uint(1, REQ_SUBSCRIBE);
                w.string(4, worker);
                if !prefix.is_empty() {
                    w.string(6, prefix);
                }
                if *max != 0 {
                    w.uint(5, *max as u64);
                }
            }
            Request::CreateBatch { items } => {
                w.uint(1, REQ_CREATE_BATCH);
                // repeated item submessages (field 8), each reusing the
                // classic Create's inner layout: 2 = task, 3 = deps
                for item in items {
                    let mut iw = Writer::new();
                    item.task.encode_into(&mut iw, 2);
                    iw.strings(3, item.deps.iter().map(String::as_str));
                    w.message(8, &iw);
                }
            }
            Request::CompleteBatch { worker, completions } => {
                w.uint(1, REQ_COMPLETE_BATCH);
                w.string(4, worker);
                // repeated completion submessages (field 8), each
                // reusing Complete's layout: 6 = task name, 7 = success
                for c in completions {
                    let mut cw = Writer::new();
                    cw.string(6, &c.task);
                    cw.uint(7, c.success as u64);
                    w.message(8, &cw);
                }
            }
            Request::OpenSession { session } => {
                w.uint(1, REQ_OPEN_SESSION);
                w.string(6, session);
            }
            Request::CloseSession { session } => {
                w.uint(1, REQ_CLOSE_SESSION);
                w.string(6, session);
            }
            Request::SubmitDelta { session, worker, creates, completions } => {
                w.uint(1, REQ_SUBMIT_DELTA);
                // 6 = session (omitted = anonymous), 4 = worker,
                // 9 = repeated completion submessages (Complete layout),
                // 8 = repeated create submessages (CreateBatch layout)
                if !session.is_empty() {
                    w.string(6, session);
                }
                if !worker.is_empty() {
                    w.string(4, worker);
                }
                for c in completions {
                    let mut cw = Writer::new();
                    cw.string(6, &c.task);
                    cw.uint(7, c.success as u64);
                    w.message(9, &cw);
                }
                for item in creates {
                    let mut iw = Writer::new();
                    item.task.encode_into(&mut iw, 2);
                    iw.strings(3, item.deps.iter().map(String::as_str));
                    w.message(8, &iw);
                }
            }
        }
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Request> {
        let fields = Reader::new(bytes).fields()?;
        let kind = wire::get_u64(&fields, 1)?;
        let worker = || wire::get_str(&fields, 4).map(str::to_string);
        let task_name = || wire::get_str(&fields, 6).map(str::to_string);
        let deps = || -> Vec<String> {
            wire::get_strs(&fields, 3).into_iter().map(str::to_string).collect()
        };
        Ok(match kind {
            REQ_CREATE => {
                let tb = fields
                    .iter()
                    .find(|(f, _)| *f == 2)
                    .and_then(|(_, v)| v.as_bytes())
                    .ok_or_else(|| anyhow!("Create missing task"))?;
                Request::Create { task: TaskMsg::decode(tb)?, deps: deps() }
            }
            REQ_STEAL => Request::Steal { worker: worker()? },
            REQ_STEAL_N => Request::StealN {
                worker: worker()?,
                n: wire::get_u64(&fields, 5)? as u32,
            },
            REQ_COMPLETE => Request::Complete {
                worker: worker()?,
                task: task_name()?,
                success: wire::get_u64(&fields, 7).unwrap_or(1) != 0,
            },
            REQ_TRANSFER => Request::Transfer {
                worker: worker()?,
                task: task_name()?,
                new_deps: deps(),
            },
            REQ_EXIT => Request::Exit { worker: worker()? },
            REQ_STATUS => Request::Status,
            REQ_SAVE => Request::Save,
            REQ_METRICS => Request::Metrics,
            REQ_SUBSCRIBE => Request::Subscribe {
                worker: worker()?,
                prefix: wire::get_str(&fields, 6).unwrap_or_default().to_string(),
                max: wire::get_u64(&fields, 5).unwrap_or(0) as u32,
            },
            REQ_CREATE_BATCH => Request::CreateBatch { items: decode_create_items(&fields, 8)? },
            REQ_COMPLETE_BATCH => Request::CompleteBatch {
                worker: worker()?,
                completions: decode_completions(&fields, 8)?,
            },
            REQ_OPEN_SESSION => Request::OpenSession { session: task_name()? },
            REQ_CLOSE_SESSION => Request::CloseSession { session: task_name()? },
            REQ_SUBMIT_DELTA => Request::SubmitDelta {
                session: wire::get_str(&fields, 6).unwrap_or_default().to_string(),
                worker: wire::get_str(&fields, 4).unwrap_or_default().to_string(),
                creates: decode_create_items(&fields, 8)?,
                completions: decode_completions(&fields, 9)?,
            },
            other => bail!("unknown request kind {other}"),
        })
    }
}

/// Decode the repeated create submessages of a batch/delta frame
/// (CreateBatch layout: 2 = task, 3 = deps) at the given field number.
fn decode_create_items(fields: &[(u32, Value)], field: u32) -> Result<Vec<CreateItem>> {
    fields
        .iter()
        .filter(|(f, _)| *f == field)
        .map(|(_, v)| -> Result<CreateItem> {
            let bytes = v.as_bytes().ok_or_else(|| anyhow!("batch item has wrong wire type"))?;
            let sub = Reader::new(bytes).fields()?;
            let tb = sub
                .iter()
                .find(|(f, _)| *f == 2)
                .and_then(|(_, v)| v.as_bytes())
                .ok_or_else(|| anyhow!("create item missing task"))?;
            Ok(CreateItem {
                task: TaskMsg::decode(tb)?,
                deps: wire::get_strs(&sub, 3).into_iter().map(str::to_string).collect(),
            })
        })
        .collect()
}

/// Decode the repeated completion submessages of a batch/delta frame
/// (Complete layout: 6 = task, 7 = success) at the given field number.
fn decode_completions(fields: &[(u32, Value)], field: u32) -> Result<Vec<Completion>> {
    fields
        .iter()
        .filter(|(f, _)| *f == field)
        .map(|(_, v)| -> Result<Completion> {
            let bytes = v.as_bytes().ok_or_else(|| anyhow!("batch item has wrong wire type"))?;
            let sub = Reader::new(bytes).fields()?;
            Ok(Completion {
                task: wire::get_str(&sub, 6)?.to_string(),
                success: wire::get_u64(&sub, 7).unwrap_or(1) != 0,
            })
        })
        .collect()
}

/// Machine-readable classification of a Create refusal.  Travels as an
/// optional field on [`Response::Err`] (same wire kind), so pre-code
/// servers simply omit it — the version-proof replacement for
/// substring-matching marker strings in the message text.  Both halves
/// of that legacy protocol are gone now: the submitter-side string
/// fallback (PR 4) and the server-side marker embedding (this release,
/// after its compatibility window) — refusal message text is free-form
/// and the code is the only classification.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefusalCode {
    /// the task already exists (a replayed Create — the refusal IS the ack)
    Duplicate,
    /// a named dependency has not been created
    DepMissing,
    /// a named dependency is in the error state: the task can never run
    DepErrored,
    /// the named session is invalid (empty, or contains the reserved
    /// separator / quoting characters) — `SubmitDelta` creates only
    BadSession,
}

impl RefusalCode {
    fn to_u64(self) -> u64 {
        match self {
            RefusalCode::Duplicate => 1,
            RefusalCode::DepMissing => 2,
            RefusalCode::DepErrored => 3,
            RefusalCode::BadSession => 4,
        }
    }

    fn from_u64(v: u64) -> Option<RefusalCode> {
        match v {
            1 => Some(RefusalCode::Duplicate),
            2 => Some(RefusalCode::DepMissing),
            3 => Some(RefusalCode::DepErrored),
            4 => Some(RefusalCode::BadSession),
            _ => None,
        }
    }
}

/// Per-session counters inside a [`StatusInfo`] reply: one row per open
/// named session (the anonymous session stays in the global counters
/// only).  Old clients skip the unknown wire field; old servers simply
/// send no rows.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct SessionRow {
    pub name: String,
    pub total: u64,
    pub completed: u64,
    /// errored = failed + transitively-skipped successors
    pub errored: u64,
    /// subset of `errored` that a worker actually attempted
    pub failed: u64,
}

impl SessionRow {
    /// Tasks the session still owes the hub (waiting, ready, or running).
    pub fn live(&self) -> u64 {
        self.total.saturating_sub(self.completed + self.errored)
    }

    /// Every task this session has submitted is finished.
    pub fn is_drained(&self) -> bool {
        self.completed + self.errored == self.total
    }
}

/// Queue counters exposed through Status.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct StatusInfo {
    pub total: u64,
    pub ready: u64,
    pub waiting: u64,
    pub assigned: u64,
    pub completed: u64,
    /// errored = failed + transitively-skipped successors
    pub errored: u64,
    /// tasks a worker actually attempted and reported `success=false`
    /// (subset of `errored`; the rest never reached a worker)
    pub failed: u64,
    pub workers: u64,
    /// one row per open named session, sorted by name (empty against
    /// pre-session hubs and when no session is open)
    pub sessions: Vec<SessionRow>,
}

impl StatusInfo {
    /// Completion query: every task the hub has ever accepted is finished
    /// (done or errored).  This is what a remote submitter polls — the
    /// server-side analogue of the in-proc driver joining its workers.
    pub fn is_drained(&self) -> bool {
        self.completed + self.errored == self.total
    }

    /// Tasks that finished in the error state without ever being
    /// attempted: dependents of a failure (the workflow "skipped" set).
    pub fn skipped(&self) -> u64 {
        self.errored.saturating_sub(self.failed)
    }
}

/// Server replies.
#[derive(Clone, Debug, PartialEq)]
pub enum Response {
    /// A task to run (Steal success).
    Task(TaskMsg),
    /// A batch of tasks (StealN success; may be shorter than requested).
    Tasks(Vec<TaskMsg>),
    /// No task ready right now, but the graph is not finished: poll again.
    NotFound,
    /// Everything is complete: worker should shut down.
    Exit,
    /// Mutation acknowledged.
    Ok,
    /// Request failed server-side.  `code` classifies Create refusals for
    /// programmatic callers; absent on other errors and on frames from
    /// pre-code servers.
    Err { msg: String, code: Option<RefusalCode> },
    Status(StatusInfo),
    /// Live-metrics reply: a versioned name-addressed snapshot.
    Metrics(MetricsSnapshot),
    /// Subscribe reply: buffered lifecycle events since the last poll,
    /// the subscriber's cumulative drop-oldest count, and whether the
    /// hub has drained (so a non-follow tail knows when to stop).
    Events { events: Vec<TaskEvent>, dropped: u64, done: bool },
    /// Per-item batch results, order-aligned with the request's items.
    /// The only reply a current hub sends for `CreateBatch` /
    /// `CompleteBatch` / `SubmitDelta` — a whole-frame `Err` to one of
    /// those kinds therefore always means the hub predates them.
    Batch(Vec<BatchItem>),
    /// Session acknowledgement (`OpenSession` / `CloseSession`):
    /// `cancelled` is the number of live tasks the teardown swept
    /// (always 0 for an open).
    Session { session: String, cancelled: u64 },
}

/// Outcome of one item inside a batched request.
#[derive(Clone, Debug, PartialEq)]
pub enum BatchItem {
    Ok,
    /// This item failed server-side; `code` classifies Create refusals
    /// exactly like the single-shot [`Response::Err`] does.
    Err { msg: String, code: Option<RefusalCode> },
}

impl BatchItem {
    pub fn is_ok(&self) -> bool {
        matches!(self, BatchItem::Ok)
    }

    /// The refusal classification, if this item was refused with one.
    pub fn code(&self) -> Option<RefusalCode> {
        match self {
            BatchItem::Ok => None,
            BatchItem::Err { code, .. } => *code,
        }
    }
}

const RESP_TASK: u64 = 1;
const RESP_TASKS: u64 = 2;
const RESP_NOT_FOUND: u64 = 3;
const RESP_EXIT: u64 = 4;
const RESP_OK: u64 = 5;
const RESP_ERR: u64 = 6;
const RESP_STATUS: u64 = 7;
const RESP_METRICS: u64 = 8;
const RESP_EVENTS: u64 = 9;
const RESP_BATCH: u64 = 10;
const RESP_SESSION: u64 = 11;

// TaskEvent wire layout (repeated sub-message, field 30 of an Events
// frame): {1: task, 2: kind name, 3: t as f64 bits (uint — same float
// convention as the metrics snapshot), 4: who, 5: seq, 6: session
// (omitted for the anonymous session; old decoders skip it)}.  The kind
// travels as its schema name so the wire stays aligned with the JSONL
// vocabulary (an unknown kind is a decode error, not silence).
fn encode_event_into(w: &mut Writer, field: u32, ev: &TaskEvent) {
    let mut e = Writer::new();
    e.string(1, &ev.task);
    e.string(2, ev.kind.name());
    e.uint(3, ev.t.to_bits());
    if !ev.who.is_empty() {
        e.string(4, &ev.who);
    }
    if ev.seq != 0 {
        e.uint(5, ev.seq);
    }
    if !ev.session.is_empty() {
        e.string(6, &ev.session);
    }
    w.message(field, &e);
}

fn decode_event(bytes: &[u8]) -> Result<TaskEvent> {
    let sub = Reader::new(bytes).fields()?;
    let kind_name = wire::get_str(&sub, 2)?;
    Ok(TaskEvent {
        task: wire::get_str(&sub, 1).unwrap_or_default().to_string(),
        kind: EventKind::from_name(kind_name)
            .ok_or_else(|| anyhow!("unknown event kind {kind_name:?}"))?,
        t: f64::from_bits(wire::get_u64(&sub, 3).unwrap_or(0)),
        who: wire::get_str(&sub, 4).unwrap_or_default().to_string(),
        seq: wire::get_u64(&sub, 5).unwrap_or(0),
        session: wire::get_str(&sub, 6).unwrap_or_default().to_string(),
    })
}

// MetricsSnapshot wire layout (all inside the Response frame):
//   field 20: snapshot version (uint)
//   field 21: uptime seconds as f64 bits (uint — the codec has no
//             float wire type, so floats travel as `f64::to_bits`)
//   field 22: repeated counter submessage  {1: name, 2: value}
//   field 23: repeated gauge submessage    {1: name, 2: value as u64
//             two's complement}
//   field 24: repeated histogram submessage {1: name, 2: repeated
//             bucket count in index order (trailing zeros trimmed),
//             3: sum seconds as f64 bits, 4: observation count}
// Name-addressed series (not positional arrays) keep the snapshot
// forward compatible: decoders ignore names they don't know.
fn encode_metrics_into(w: &mut Writer, m: &MetricsSnapshot) {
    w.uint(20, m.version as u64);
    w.uint(21, m.uptime_s.to_bits());
    for (name, v) in &m.counters {
        let mut c = Writer::new();
        c.string(1, name);
        c.uint(2, *v);
        w.message(22, &c);
    }
    for (name, v) in &m.gauges {
        let mut g = Writer::new();
        g.string(1, name);
        g.uint(2, *v as u64);
        w.message(23, &g);
    }
    for h in &m.hists {
        let mut hw = Writer::new();
        hw.string(1, &h.name);
        for b in &h.buckets {
            hw.uint(2, *b);
        }
        hw.uint(3, h.sum_s.to_bits());
        hw.uint(4, h.count);
        w.message(24, &hw);
    }
}

fn decode_metrics(fields: &[(u32, Value)]) -> Result<MetricsSnapshot> {
    let mut m = MetricsSnapshot {
        version: wire::get_u64(fields, 20).unwrap_or(0) as u32,
        uptime_s: f64::from_bits(wire::get_u64(fields, 21).unwrap_or(0)),
        ..MetricsSnapshot::default()
    };
    for (f, v) in fields {
        let Some(bytes) = v.as_bytes() else { continue };
        match f {
            22 => {
                let sub = Reader::new(bytes).fields()?;
                m.counters
                    .push((wire::get_str(&sub, 1)?.to_string(), wire::get_u64(&sub, 2)?));
            }
            23 => {
                let sub = Reader::new(bytes).fields()?;
                m.gauges
                    .push((wire::get_str(&sub, 1)?.to_string(), wire::get_u64(&sub, 2)? as i64));
            }
            24 => {
                let sub = Reader::new(bytes).fields()?;
                m.hists.push(HistSnapshot {
                    name: wire::get_str(&sub, 1)?.to_string(),
                    buckets: sub
                        .iter()
                        .filter(|(f, _)| *f == 2)
                        .filter_map(|(_, v)| v.as_u64())
                        .collect(),
                    sum_s: f64::from_bits(wire::get_u64(&sub, 3).unwrap_or(0)),
                    count: wire::get_u64(&sub, 4).unwrap_or(0),
                });
            }
            _ => {}
        }
    }
    Ok(m)
}

impl Response {
    /// An error reply with no refusal classification.
    pub fn err(msg: impl Into<String>) -> Response {
        Response::Err { msg: msg.into(), code: None }
    }

    pub fn encode(&self) -> Vec<u8> {
        let mut w = Writer::with_capacity(32);
        match self {
            Response::Task(t) => {
                w.uint(1, RESP_TASK);
                t.encode_into(&mut w, 2);
            }
            Response::Tasks(ts) => {
                w.uint(1, RESP_TASKS);
                for t in ts {
                    t.encode_into(&mut w, 2);
                }
            }
            Response::NotFound => {
                w.uint(1, RESP_NOT_FOUND);
            }
            Response::Exit => {
                w.uint(1, RESP_EXIT);
            }
            Response::Ok => {
                w.uint(1, RESP_OK);
            }
            Response::Err { msg, code } => {
                w.uint(1, RESP_ERR);
                w.string(3, msg);
                if let Some(c) = code {
                    w.uint(4, c.to_u64());
                }
            }
            Response::Status(s) => {
                w.uint(1, RESP_STATUS);
                w.uint(10, s.total);
                w.uint(11, s.ready);
                w.uint(12, s.waiting);
                w.uint(13, s.assigned);
                w.uint(14, s.completed);
                w.uint(15, s.errored);
                w.uint(16, s.workers);
                w.uint(17, s.failed);
                // repeated session-row submessages (field 18):
                // {1: name, 2: total, 3: completed, 4: errored, 5: failed}
                // — pre-session decoders skip the unknown field
                for row in &s.sessions {
                    let mut rw = Writer::new();
                    rw.string(1, &row.name);
                    rw.uint(2, row.total);
                    rw.uint(3, row.completed);
                    rw.uint(4, row.errored);
                    rw.uint(5, row.failed);
                    w.message(18, &rw);
                }
            }
            Response::Metrics(m) => {
                w.uint(1, RESP_METRICS);
                encode_metrics_into(&mut w, m);
            }
            Response::Events { events, dropped, done } => {
                w.uint(1, RESP_EVENTS);
                for ev in events {
                    encode_event_into(&mut w, 30, ev);
                }
                if *dropped != 0 {
                    w.uint(31, *dropped);
                }
                w.uint(32, *done as u64);
            }
            Response::Batch(results) => {
                w.uint(1, RESP_BATCH);
                // repeated result submessages (field 40):
                // {1: err flag, 2: msg, 3: refusal code}
                for r in results {
                    let mut rw = Writer::new();
                    match r {
                        BatchItem::Ok => rw.uint(1, 0),
                        BatchItem::Err { msg, code } => {
                            rw.uint(1, 1);
                            rw.string(2, msg);
                            if let Some(c) = code {
                                rw.uint(3, c.to_u64());
                            }
                        }
                    }
                    w.message(40, &rw);
                }
            }
            Response::Session { session, cancelled } => {
                w.uint(1, RESP_SESSION);
                w.string(50, session);
                if *cancelled != 0 {
                    w.uint(51, *cancelled);
                }
            }
        }
        w.into_bytes()
    }

    pub fn decode(bytes: &[u8]) -> Result<Response> {
        let fields = Reader::new(bytes).fields()?;
        let kind = wire::get_u64(&fields, 1)?;
        let tasks = || -> Result<Vec<TaskMsg>> {
            fields
                .iter()
                .filter(|(f, _)| *f == 2)
                .map(|(_, v)| match v {
                    Value::Bytes(b) => TaskMsg::decode(b),
                    _ => bail!("task field has wrong wire type"),
                })
                .collect()
        };
        Ok(match kind {
            RESP_TASK => {
                let mut ts = tasks()?;
                Response::Task(ts.pop().ok_or_else(|| anyhow!("Task reply without task"))?)
            }
            RESP_TASKS => Response::Tasks(tasks()?),
            RESP_NOT_FOUND => Response::NotFound,
            RESP_EXIT => Response::Exit,
            RESP_OK => Response::Ok,
            RESP_ERR => Response::Err {
                msg: wire::get_str(&fields, 3).unwrap_or("?").to_string(),
                // absent on frames from pre-code servers
                code: wire::get_u64(&fields, 4).ok().and_then(RefusalCode::from_u64),
            },
            RESP_STATUS => Response::Status(StatusInfo {
                total: wire::get_u64(&fields, 10)?,
                ready: wire::get_u64(&fields, 11)?,
                waiting: wire::get_u64(&fields, 12)?,
                assigned: wire::get_u64(&fields, 13)?,
                completed: wire::get_u64(&fields, 14)?,
                errored: wire::get_u64(&fields, 15)?,
                workers: wire::get_u64(&fields, 16)?,
                // absent on frames from pre-`failed` servers
                failed: wire::get_u64(&fields, 17).unwrap_or(0),
                // absent on frames from pre-session servers
                sessions: fields
                    .iter()
                    .filter(|(f, _)| *f == 18)
                    .map(|(_, v)| -> Result<SessionRow> {
                        let bytes = v
                            .as_bytes()
                            .ok_or_else(|| anyhow!("session row has wrong wire type"))?;
                        let sub = Reader::new(bytes).fields()?;
                        Ok(SessionRow {
                            name: wire::get_str(&sub, 1)?.to_string(),
                            total: wire::get_u64(&sub, 2).unwrap_or(0),
                            completed: wire::get_u64(&sub, 3).unwrap_or(0),
                            errored: wire::get_u64(&sub, 4).unwrap_or(0),
                            failed: wire::get_u64(&sub, 5).unwrap_or(0),
                        })
                    })
                    .collect::<Result<Vec<SessionRow>>>()?,
            }),
            RESP_METRICS => Response::Metrics(decode_metrics(&fields)?),
            RESP_EVENTS => Response::Events {
                events: fields
                    .iter()
                    .filter(|(f, _)| *f == 30)
                    .map(|(_, v)| match v {
                        Value::Bytes(b) => decode_event(b),
                        _ => bail!("event field has wrong wire type"),
                    })
                    .collect::<Result<Vec<TaskEvent>>>()?,
                dropped: wire::get_u64(&fields, 31).unwrap_or(0),
                done: wire::get_u64(&fields, 32).unwrap_or(0) != 0,
            },
            RESP_BATCH => Response::Batch(
                fields
                    .iter()
                    .filter(|(f, _)| *f == 40)
                    .map(|(_, v)| -> Result<BatchItem> {
                        let bytes = v
                            .as_bytes()
                            .ok_or_else(|| anyhow!("batch result has wrong wire type"))?;
                        let sub = Reader::new(bytes).fields()?;
                        Ok(if wire::get_u64(&sub, 1).unwrap_or(0) == 0 {
                            BatchItem::Ok
                        } else {
                            BatchItem::Err {
                                msg: wire::get_str(&sub, 2).unwrap_or("?").to_string(),
                                code: wire::get_u64(&sub, 3)
                                    .ok()
                                    .and_then(RefusalCode::from_u64),
                            }
                        })
                    })
                    .collect::<Result<Vec<BatchItem>>>()?,
            ),
            RESP_SESSION => Response::Session {
                session: wire::get_str(&fields, 50).unwrap_or_default().to_string(),
                cancelled: wire::get_u64(&fields, 51).unwrap_or(0),
            },
            other => bail!("unknown response kind {other}"),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_req(r: Request) {
        assert_eq!(Request::decode(&r.encode()).unwrap(), r);
    }

    fn roundtrip_resp(r: Response) {
        assert_eq!(Response::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn all_requests_roundtrip() {
        roundtrip_req(Request::Create {
            task: TaskMsg {
                name: "dock-42".into(),
                body: vec![1, 2, 3],
                originator: "user".into(),
            },
            deps: vec!["prep-42".into(), "prep-43".into()],
        });
        roundtrip_req(Request::Steal { worker: "w-001".into() });
        roundtrip_req(Request::StealN { worker: "w".into(), n: 16 });
        roundtrip_req(Request::Complete { worker: "w".into(), task: "t".into(), success: true });
        roundtrip_req(Request::Complete { worker: "w".into(), task: "t".into(), success: false });
        roundtrip_req(Request::Transfer {
            worker: "w".into(),
            task: "t".into(),
            new_deps: vec!["d1".into()],
        });
        roundtrip_req(Request::Exit { worker: "w".into() });
        roundtrip_req(Request::Status);
        roundtrip_req(Request::Save);
        roundtrip_req(Request::Metrics);
        roundtrip_req(Request::Subscribe {
            worker: "tail-1".into(),
            prefix: String::new(),
            max: 0,
        });
        roundtrip_req(Request::Subscribe {
            worker: "tail-1".into(),
            prefix: "dock-".into(),
            max: 512,
        });
    }

    #[test]
    fn all_responses_roundtrip() {
        roundtrip_resp(Response::Task(TaskMsg::new("t1", vec![9, 9])));
        roundtrip_resp(Response::Tasks(vec![
            TaskMsg::new("a", vec![]),
            TaskMsg::new("b", vec![1]),
        ]));
        roundtrip_resp(Response::Tasks(vec![]));
        roundtrip_resp(Response::NotFound);
        roundtrip_resp(Response::Exit);
        roundtrip_resp(Response::Ok);
        roundtrip_resp(Response::err("boom"));
        roundtrip_resp(Response::Err {
            msg: "task \"a\" already exists".into(),
            code: Some(RefusalCode::Duplicate),
        });
        roundtrip_resp(Response::Err {
            msg: "dependency gone".into(),
            code: Some(RefusalCode::DepErrored),
        });
        roundtrip_resp(Response::Status(StatusInfo {
            total: 100,
            ready: 5,
            waiting: 10,
            assigned: 3,
            completed: 80,
            errored: 2,
            failed: 1,
            workers: 7,
            sessions: vec![],
        }));
        roundtrip_resp(Response::Status(StatusInfo {
            total: 100,
            completed: 80,
            sessions: vec![
                SessionRow { name: "alpha".into(), total: 60, completed: 50, errored: 2, failed: 1 },
                SessionRow { name: "beta".into(), total: 40, completed: 30, errored: 0, failed: 0 },
            ],
            ..StatusInfo::default()
        }));
        roundtrip_resp(Response::Session { session: "alpha".into(), cancelled: 0 });
        roundtrip_resp(Response::Session { session: "beta".into(), cancelled: 17 });
    }

    #[test]
    fn metrics_snapshot_roundtrips() {
        // a realistic populated snapshot: counters, gauges (including a
        // negative value to pin the two's-complement path), and a
        // histogram with interior zero buckets
        roundtrip_resp(Response::Metrics(MetricsSnapshot {
            version: MetricsSnapshot::VERSION,
            uptime_s: 12.75,
            counters: vec![
                ("tasks_created".into(), 100),
                ("steals_served".into(), 42),
                ("a_series_this_decoder_never_heard_of".into(), u64::MAX),
            ],
            gauges: vec![("queue_depth".into(), 7), ("drift".into(), -3)],
            hists: vec![
                HistSnapshot {
                    name: "service_steal".into(),
                    buckets: vec![0, 2, 0, 0, 5],
                    sum_s: 0.0625,
                    count: 7,
                },
                HistSnapshot { name: "empty".into(), buckets: vec![], sum_s: 0.0, count: 0 },
            ],
        }));
        // the disabled-registry snapshot (version 0, all empty)
        roundtrip_resp(Response::Metrics(MetricsSnapshot::default()));
    }

    #[test]
    fn events_responses_roundtrip() {
        let ev = |task: &str, kind: EventKind, t: f64, who: &str, seq: u64| TaskEvent {
            task: task.into(),
            kind,
            t,
            who: who.into(),
            seq,
            session: String::new(),
        };
        roundtrip_resp(Response::Events { events: vec![], dropped: 0, done: false });
        roundtrip_resp(Response::Events { events: vec![], dropped: 7, done: true });
        roundtrip_resp(Response::Events {
            events: vec![
                ev("dock-1", EventKind::Created, 0.0, "", 0),
                ev("dock-1", EventKind::Ready, 1.5e-3, "", 1),
                ev("dock-1", EventKind::Launched, 2.5e-3, "w0", 2),
                ev("dock-1", EventKind::Finished, 0.25, "w0", 3),
                ev("", EventKind::Connected, 0.1, "w1", 4),
            ],
            dropped: 0,
            done: false,
        });
        // negative / huge timestamps survive the f64-bits convention
        roundtrip_resp(Response::Events {
            events: vec![ev("t", EventKind::Failed, 1.0e9 + 0.125, "rank3", u64::MAX)],
            dropped: u64::MAX,
            done: true,
        });
        // the /5 session tag rides event field 6 (omitted when empty)
        roundtrip_resp(Response::Events {
            events: vec![TaskEvent {
                session: "alpha".into(),
                ..ev("t0", EventKind::Finished, 0.5, "w0", 2)
            }],
            dropped: 0,
            done: false,
        });
    }

    #[test]
    fn batch_requests_roundtrip() {
        roundtrip_req(Request::CreateBatch { items: vec![] });
        roundtrip_req(Request::CreateBatch {
            items: vec![
                CreateItem::new(TaskMsg::new("prep", vec![]), vec![]),
                CreateItem::new(
                    TaskMsg {
                        name: "dock-7".into(),
                        body: vec![1, 2, 3],
                        originator: "user".into(),
                    },
                    vec!["prep".into()],
                ),
                CreateItem::new(TaskMsg::new("タスク-α", vec![0xf0]), vec!["dock-7".into()]),
            ],
        });
        roundtrip_req(Request::CompleteBatch {
            worker: "w-001".into(),
            completions: vec![],
        });
        roundtrip_req(Request::CompleteBatch {
            worker: "w".into(),
            completions: vec![
                Completion::ok("a"),
                Completion::failed("b"),
                Completion { task: "依存-β".into(), success: true },
            ],
        });
    }

    #[test]
    fn batch_responses_roundtrip() {
        roundtrip_resp(Response::Batch(vec![]));
        roundtrip_resp(Response::Batch(vec![
            BatchItem::Ok,
            BatchItem::Err { msg: "task \"a\" already exists".into(), code: Some(RefusalCode::Duplicate) },
            BatchItem::Err { msg: "dep gone".into(), code: Some(RefusalCode::DepErrored) },
            BatchItem::Err { msg: "not assigned".into(), code: None },
            BatchItem::Ok,
        ]));
    }

    #[test]
    fn batch_kinds_are_fresh() {
        // kinds 11 and 12 (requests) and 10 (response), the next free
        // slots: a current server decodes them, while a pre-batch hub
        // answers the unknown request kind with Err — the degrade
        // signal batch clients fall back on
        let req = Request::CreateBatch {
            items: vec![CreateItem::new(TaskMsg::new("t", vec![]), vec![])],
        };
        let bytes = req.encode();
        assert_eq!(Request::decode(&bytes).unwrap(), req);
        let fields = crate::substrate::wire::Reader::new(&bytes).fields().unwrap();
        assert_eq!(wire::get_u64(&fields, 1).unwrap(), 11);

        let req = Request::CompleteBatch {
            worker: "w".into(),
            completions: vec![Completion::ok("t")],
        };
        let bytes = req.encode();
        assert_eq!(Request::decode(&bytes).unwrap(), req);
        let fields = crate::substrate::wire::Reader::new(&bytes).fields().unwrap();
        assert_eq!(wire::get_u64(&fields, 1).unwrap(), 12);

        let resp = Response::Batch(vec![BatchItem::Ok]);
        let bytes = resp.encode();
        assert_eq!(Response::decode(&bytes).unwrap(), resp);
        let fields = crate::substrate::wire::Reader::new(&bytes).fields().unwrap();
        assert_eq!(wire::get_u64(&fields, 1).unwrap(), 10);
    }

    #[test]
    fn session_requests_roundtrip() {
        roundtrip_req(Request::OpenSession { session: "alpha".into() });
        roundtrip_req(Request::CloseSession { session: "キャンペーン".into() });
        roundtrip_req(Request::SubmitDelta {
            session: "alpha".into(),
            worker: "w0".into(),
            creates: vec![
                CreateItem::new(TaskMsg::new("child", vec![1]), vec!["gen".into()]),
                CreateItem::new(TaskMsg::new("leaf", vec![]), vec!["child".into()]),
            ],
            completions: vec![Completion::ok("gen"), Completion::failed("other")],
        });
        // anonymous delta: empty session + empty worker both omitted
        roundtrip_req(Request::SubmitDelta {
            session: String::new(),
            worker: String::new(),
            creates: vec![CreateItem::new(TaskMsg::new("t", vec![]), vec![])],
            completions: vec![],
        });
        // completion-only delta (a bare task-spawns-nothing report)
        roundtrip_req(Request::SubmitDelta {
            session: "beta".into(),
            worker: "w1".into(),
            creates: vec![],
            completions: vec![Completion::ok("a")],
        });
    }

    #[test]
    fn session_kinds_are_fresh() {
        // request kinds 13/14/15 and response kind 11, the next free
        // slots after the batch kinds: a pre-session hub answers the
        // unknown request kind with a whole-frame Err — the client's
        // degrade-to-anonymous signal
        let pin = |req: &Request, want: u64| {
            let bytes = req.encode();
            assert_eq!(&Request::decode(&bytes).unwrap(), req);
            let fields = crate::substrate::wire::Reader::new(&bytes).fields().unwrap();
            assert_eq!(wire::get_u64(&fields, 1).unwrap(), want);
        };
        pin(&Request::OpenSession { session: "s".into() }, 13);
        pin(&Request::CloseSession { session: "s".into() }, 14);
        pin(
            &Request::SubmitDelta {
                session: "s".into(),
                worker: "w".into(),
                creates: vec![],
                completions: vec![],
            },
            15,
        );
        let resp = Response::Session { session: "s".into(), cancelled: 3 };
        let bytes = resp.encode();
        assert_eq!(Response::decode(&bytes).unwrap(), resp);
        let fields = crate::substrate::wire::Reader::new(&bytes).fields().unwrap();
        assert_eq!(wire::get_u64(&fields, 1).unwrap(), 11);
    }

    #[test]
    fn pre_session_status_frame_decodes_with_no_rows() {
        // a pre-session hub's Status frame has no field-18 rows
        let mut w = Writer::new();
        w.uint(1, 7); // RESP_STATUS
        for f in 10..=16 {
            w.uint(f, 1);
        }
        match Response::decode(w.as_bytes()).unwrap() {
            Response::Status(st) => {
                assert!(st.sessions.is_empty());
                assert_eq!(st.failed, 0, "pre-failed frames default to 0");
            }
            other => panic!("expected Status, got {other:?}"),
        }
    }

    #[test]
    fn session_qualified_task_ids_split() {
        let anon = TaskMsg::new("t0", vec![]);
        assert_eq!(anon.session(), "");
        assert_eq!(anon.short_name(), "t0");
        let qualified = TaskMsg::new(format!("alpha{SESSION_SEP}t0"), vec![]);
        assert_eq!(qualified.session(), "alpha");
        assert_eq!(qualified.short_name(), "t0");
    }

    #[test]
    fn subscribe_request_is_a_fresh_kind() {
        // kind 10, the next free slot after Metrics (9): a current server
        // decodes it; a pre-subscribe server answers Err for the unknown
        // kind, which the tail client surfaces as ServerError
        let req =
            Request::Subscribe { worker: "tail".into(), prefix: String::new(), max: 0 };
        let bytes = req.encode();
        assert_eq!(Request::decode(&bytes).unwrap(), req);
        let fields = crate::substrate::wire::Reader::new(&bytes).fields().unwrap();
        assert_eq!(wire::get_u64(&fields, 1).unwrap(), 10);
    }

    #[test]
    fn metrics_request_is_a_fresh_kind() {
        // the new request must not collide with any pre-existing kind:
        // decoding its frame on a current server yields Metrics, and the
        // frame is a single kind field (payload-less, like Status)
        let bytes = Request::Metrics.encode();
        assert_eq!(Request::decode(&bytes).unwrap(), Request::Metrics);
        let fields = crate::substrate::wire::Reader::new(&bytes).fields().unwrap();
        assert_eq!(fields.len(), 1);
        assert_eq!(wire::get_u64(&fields, 1).unwrap(), 9);
    }

    #[test]
    fn pre_code_err_frame_decodes_with_no_code() {
        assert_eq!(RefusalCode::from_u64(99), None);
        // a pre-code server's Err frame has no code field: decode to None
        let mut w = Writer::new();
        w.uint(1, 6); // RESP_ERR
        w.string(3, "boom");
        match Response::decode(w.as_bytes()).unwrap() {
            Response::Err { msg, code } => {
                assert_eq!(msg, "boom");
                assert!(code.is_none());
            }
            other => panic!("expected Err, got {other:?}"),
        }
    }

    #[test]
    fn empty_deps_and_body() {
        roundtrip_req(Request::Create { task: TaskMsg::new("t", vec![]), deps: vec![] });
    }

    #[test]
    fn garbage_rejected() {
        assert!(Request::decode(&[0xff, 0xff]).is_err());
        assert!(Response::decode(&[]).is_err());
        // valid wire, unknown kind
        let mut w = Writer::new();
        w.uint(1, 999);
        assert!(Request::decode(w.as_bytes()).is_err());
    }

    #[test]
    fn drained_and_skipped_queries() {
        let st = StatusInfo {
            total: 10,
            completed: 6,
            errored: 4,
            failed: 1,
            ..StatusInfo::default()
        };
        assert!(st.is_drained());
        assert_eq!(st.skipped(), 3);
        let running = StatusInfo { total: 10, completed: 6, ..StatusInfo::default() };
        assert!(!running.is_drained());
    }

    #[test]
    fn unicode_names() {
        roundtrip_req(Request::Create {
            task: TaskMsg::new("タスク-α", vec![0xf0]),
            deps: vec!["依存-β".into()],
        });
    }
}
