//! Live metrics: lock-cheap counters, gauges, and log-bucketed
//! histograms with Prometheus text exposition.
//!
//! Same handle discipline as [`crate::trace::Tracer`] (the one
//! `benches/trace_overhead.rs` pins): a disabled [`Registry`] — the
//! `Default` — is a single `Option` branch per update, zero allocation,
//! zero atomics, so instrumentation stays unconditionally compiled into
//! the hub serve loop and the worker pull loop.  An enabled registry is
//! one `Arc` of fixed-size atomic arrays: every update is a relaxed
//! atomic op or two, no locks, no allocation on the hot path
//! (`benches/metrics_overhead.rs` pins both properties).
//!
//! Where the post-hoc JSONL tracer answers "what happened", this module
//! answers "what is the hub doing right now": it feeds the
//! `Request::Metrics` wire query, the `dhub serve --metrics-addr`
//! Prometheus endpoint ([`serve_exposition`]), and the `dhub top`
//! terminal view.  Snapshots ([`Registry::snapshot`]) carry name–value
//! pairs rather than indexed arrays, so the wire form stays forward
//! compatible: a newer hub can add series without breaking an older
//! `dhub top`.

use std::fmt;
use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Monotonically increasing event counts.  `name()` is the stable
/// identifier used in snapshots and Prometheus exposition (which
/// appends `_total`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Counter {
    /// per-`Request`-kind arrival counts (hub serve loop)
    ReqCreate,
    ReqSteal,
    ReqStealN,
    ReqComplete,
    ReqTransfer,
    ReqExit,
    ReqStatus,
    ReqSave,
    ReqMetrics,
    /// frames that failed to decode
    ReqMalformed,
    /// task lifecycle (scheduler state machine)
    TasksCreated,
    TasksCompleted,
    /// attempted by a worker and reported `success=false`
    TasksFailed,
    /// errored by propagation without ever being attempted
    TasksSkipped,
    /// handed back to the ready queue (Transfer or worker Exit)
    TasksRequeued,
    /// steal outcomes (hub side)
    StealsServed,
    StealsEmpty,
    /// worker population churn (hub side: first steal / Exit request)
    WorkersAttached,
    WorkersExited,
    /// worker pull loop (client side)
    WorkerPolls,
    WorkerBackoffs,
    /// transitions into the idle/backoff state (not each sleep)
    WorkerParks,
    /// local pmake/mpi-list driver lifecycle
    DriverTasksLaunched,
    DriverTasksCompleted,
    DriverTasksFailed,
    /// live event streaming (`Request::Subscribe` long-polls)
    ReqSubscribe,
    /// events discarded because a subscriber queue hit its cap
    SubscribeDropped,
    /// batched wire ops (`Request::CreateBatch` / `CompleteBatch`)
    ReqCreateBatch,
    ReqCompleteBatch,
    /// session wire ops (`Request::OpenSession` / `CloseSession` /
    /// `SubmitDelta`)
    ReqOpenSession,
    ReqCloseSession,
    ReqSubmitDelta,
    /// session registry churn (hub side)
    SessionsOpened,
    SessionsClosed,
    /// live tasks swept by `CloseSession` teardown (never attempted to
    /// completion; distinct from `TasksFailed`/`TasksSkipped`)
    TasksCancelled,
}

impl Counter {
    pub const ALL: [Counter; 35] = [
        Counter::ReqCreate,
        Counter::ReqSteal,
        Counter::ReqStealN,
        Counter::ReqComplete,
        Counter::ReqTransfer,
        Counter::ReqExit,
        Counter::ReqStatus,
        Counter::ReqSave,
        Counter::ReqMetrics,
        Counter::ReqMalformed,
        Counter::TasksCreated,
        Counter::TasksCompleted,
        Counter::TasksFailed,
        Counter::TasksSkipped,
        Counter::TasksRequeued,
        Counter::StealsServed,
        Counter::StealsEmpty,
        Counter::WorkersAttached,
        Counter::WorkersExited,
        Counter::WorkerPolls,
        Counter::WorkerBackoffs,
        Counter::WorkerParks,
        Counter::DriverTasksLaunched,
        Counter::DriverTasksCompleted,
        Counter::DriverTasksFailed,
        Counter::ReqSubscribe,
        Counter::SubscribeDropped,
        Counter::ReqCreateBatch,
        Counter::ReqCompleteBatch,
        Counter::ReqOpenSession,
        Counter::ReqCloseSession,
        Counter::ReqSubmitDelta,
        Counter::SessionsOpened,
        Counter::SessionsClosed,
        Counter::TasksCancelled,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Counter::ReqCreate => "requests_create",
            Counter::ReqSteal => "requests_steal",
            Counter::ReqStealN => "requests_steal_n",
            Counter::ReqComplete => "requests_complete",
            Counter::ReqTransfer => "requests_transfer",
            Counter::ReqExit => "requests_exit",
            Counter::ReqStatus => "requests_status",
            Counter::ReqSave => "requests_save",
            Counter::ReqMetrics => "requests_metrics",
            Counter::ReqMalformed => "requests_malformed",
            Counter::TasksCreated => "tasks_created",
            Counter::TasksCompleted => "tasks_completed",
            Counter::TasksFailed => "tasks_failed",
            Counter::TasksSkipped => "tasks_skipped",
            Counter::TasksRequeued => "tasks_requeued",
            Counter::StealsServed => "steals_served",
            Counter::StealsEmpty => "steals_empty",
            Counter::WorkersAttached => "workers_attached",
            Counter::WorkersExited => "workers_exited",
            Counter::WorkerPolls => "worker_polls",
            Counter::WorkerBackoffs => "worker_backoffs",
            Counter::WorkerParks => "worker_parks",
            Counter::DriverTasksLaunched => "driver_tasks_launched",
            Counter::DriverTasksCompleted => "driver_tasks_completed",
            Counter::DriverTasksFailed => "driver_tasks_failed",
            Counter::ReqSubscribe => "requests_subscribe",
            Counter::SubscribeDropped => "subscribe_dropped",
            Counter::ReqCreateBatch => "requests_create_batch",
            Counter::ReqCompleteBatch => "requests_complete_batch",
            Counter::ReqOpenSession => "requests_open_session",
            Counter::ReqCloseSession => "requests_close_session",
            Counter::ReqSubmitDelta => "requests_submit_delta",
            Counter::SessionsOpened => "sessions_opened",
            Counter::SessionsClosed => "sessions_closed",
            Counter::TasksCancelled => "tasks_cancelled",
        }
    }
}

/// Instantaneous levels (can go up and down).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Gauge {
    /// tasks in the ready deque right now
    QueueDepth,
    /// tasks assigned to a worker right now
    Inflight,
    /// workers the hub believes are attached
    WorkersConnected,
    /// sessions currently open in the hub's registry
    SessionsOpen,
}

impl Gauge {
    pub const ALL: [Gauge; 4] =
        [Gauge::QueueDepth, Gauge::Inflight, Gauge::WorkersConnected, Gauge::SessionsOpen];

    pub fn name(self) -> &'static str {
        match self {
            Gauge::QueueDepth => "queue_depth",
            Gauge::Inflight => "tasks_inflight",
            Gauge::WorkersConnected => "workers_connected",
            Gauge::SessionsOpen => "sessions_open",
        }
    }
}

/// Duration series, recorded into log2-bucketed histograms over
/// nanoseconds: bucket `i` covers `[2^(i-1), 2^i)` ns, bucket 0 holds
/// zero-length observations.  40 buckets reach ~9 minutes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Series {
    /// hub-side service time per request kind (decode→reply)
    ServiceCreate,
    ServiceSteal,
    ServiceComplete,
    ServiceTransfer,
    ServiceExit,
    ServiceStatus,
    ServiceSave,
    ServiceMetrics,
    /// worker-observed steal round-trip (request→batch in hand)
    StealRtt,
    /// worker-side payload execution time
    TaskCompute,
    /// hub-side service time for Subscribe long-polls
    ServiceSubscribe,
    /// hub-side service time per whole batch frame
    ServiceCreateBatch,
    ServiceCompleteBatch,
    /// hub-side service time for the session verbs
    ServiceOpenSession,
    ServiceCloseSession,
    ServiceSubmitDelta,
}

impl Series {
    pub const ALL: [Series; 16] = [
        Series::ServiceCreate,
        Series::ServiceSteal,
        Series::ServiceComplete,
        Series::ServiceTransfer,
        Series::ServiceExit,
        Series::ServiceStatus,
        Series::ServiceSave,
        Series::ServiceMetrics,
        Series::StealRtt,
        Series::TaskCompute,
        Series::ServiceSubscribe,
        Series::ServiceCreateBatch,
        Series::ServiceCompleteBatch,
        Series::ServiceOpenSession,
        Series::ServiceCloseSession,
        Series::ServiceSubmitDelta,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Series::ServiceCreate => "service_create",
            Series::ServiceSteal => "service_steal",
            Series::ServiceComplete => "service_complete",
            Series::ServiceTransfer => "service_transfer",
            Series::ServiceExit => "service_exit",
            Series::ServiceStatus => "service_status",
            Series::ServiceSave => "service_save",
            Series::ServiceMetrics => "service_metrics",
            Series::StealRtt => "steal_rtt",
            Series::TaskCompute => "task_compute",
            Series::ServiceSubscribe => "service_subscribe",
            Series::ServiceCreateBatch => "service_create_batch",
            Series::ServiceCompleteBatch => "service_complete_batch",
            Series::ServiceOpenSession => "service_open_session",
            Series::ServiceCloseSession => "service_close_session",
            Series::ServiceSubmitDelta => "service_submit_delta",
        }
    }
}

/// Bucket count per histogram: log2 over ns, so 40 buckets span
/// 1 ns .. 2^39 ns ≈ 550 s — beyond any per-request latency we serve.
pub const HIST_BUCKETS: usize = 40;

struct HistCell {
    buckets: [AtomicU64; HIST_BUCKETS],
    sum_ns: AtomicU64,
    count: AtomicU64,
}

impl HistCell {
    fn new() -> HistCell {
        HistCell {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum_ns: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    #[inline]
    fn observe_ns(&self, ns: u64) {
        let idx = if ns == 0 {
            0
        } else {
            ((64 - ns.leading_zeros()) as usize).min(HIST_BUCKETS - 1)
        };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_ns.fetch_add(ns, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }
}

struct Inner {
    epoch: Instant,
    counters: [AtomicU64; Counter::ALL.len()],
    gauges: [AtomicI64; Gauge::ALL.len()],
    hists: [HistCell; Series::ALL.len()],
    /// per-session live-task levels, keyed by session name.  This is the
    /// one labeled family; it rides the name-addressed snapshot wire as
    /// composite gauge names `session_tasks_live{session="<name>"}`, so
    /// older `dhub top` builds simply see gauges they don't chart.
    /// Mutex (not atomics) is fine: it is touched on session lifecycle
    /// mutations, never on the steal/complete hot path.
    session_live: std::sync::Mutex<std::collections::BTreeMap<String, i64>>,
}

/// A cheap-to-clone metrics handle.  `Registry::default()` is disabled:
/// every update is one branch and nothing else.  [`Registry::enabled`]
/// allocates the shared atomic store; clones observe into the same
/// store, so the hub serve loop, the scheduler state machine, and any
/// exposition threads can share one registry.
#[derive(Clone, Default)]
pub struct Registry(Option<Arc<Inner>>);

impl fmt::Debug for Registry {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match &self.0 {
            None => write!(f, "Registry(disabled)"),
            Some(_) => write!(f, "Registry(enabled)"),
        }
    }
}

impl Registry {
    /// An active registry (disabled is the `Default`).
    pub fn enabled() -> Registry {
        Registry(Some(Arc::new(Inner {
            epoch: Instant::now(),
            counters: std::array::from_fn(|_| AtomicU64::new(0)),
            gauges: std::array::from_fn(|_| AtomicI64::new(0)),
            hists: std::array::from_fn(|_| HistCell::new()),
            session_live: std::sync::Mutex::new(std::collections::BTreeMap::new()),
        })))
    }

    pub fn is_enabled(&self) -> bool {
        self.0.is_some()
    }

    #[inline]
    pub fn inc(&self, c: Counter) {
        self.add(c, 1);
    }

    #[inline]
    pub fn add(&self, c: Counter, n: u64) {
        if let Some(inner) = &self.0 {
            inner.counters[c as usize].fetch_add(n, Ordering::Relaxed);
        }
    }

    pub fn counter(&self, c: Counter) -> u64 {
        match &self.0 {
            Some(inner) => inner.counters[c as usize].load(Ordering::Relaxed),
            None => 0,
        }
    }

    #[inline]
    pub fn gauge_add(&self, g: Gauge, delta: i64) {
        if let Some(inner) = &self.0 {
            inner.gauges[g as usize].fetch_add(delta, Ordering::Relaxed);
        }
    }

    #[inline]
    pub fn gauge_set(&self, g: Gauge, v: i64) {
        if let Some(inner) = &self.0 {
            inner.gauges[g as usize].store(v, Ordering::Relaxed);
        }
    }

    pub fn gauge(&self, g: Gauge) -> i64 {
        match &self.0 {
            Some(inner) => inner.gauges[g as usize].load(Ordering::Relaxed),
            None => 0,
        }
    }

    /// Set the live-task level for one session (labeled gauge
    /// `session_tasks_live{session="<name>"}`).
    pub fn session_gauge_set(&self, session: &str, v: i64) {
        if let Some(inner) = &self.0 {
            let mut map = inner.session_live.lock().unwrap();
            map.insert(session.to_string(), v);
        }
    }

    /// Forget a closed session's labeled gauge entirely (the exposition
    /// stops listing it rather than pinning a stale zero forever).
    pub fn session_gauge_remove(&self, session: &str) {
        if let Some(inner) = &self.0 {
            inner.session_live.lock().unwrap().remove(session);
        }
    }

    /// Current labeled level for one session; `None` when the registry
    /// is disabled or the session is not tracked.
    pub fn session_gauge(&self, session: &str) -> Option<i64> {
        self.0.as_ref().and_then(|inner| inner.session_live.lock().unwrap().get(session).copied())
    }

    /// Record one duration observation.
    #[inline]
    pub fn observe(&self, s: Series, d: Duration) {
        if let Some(inner) = &self.0 {
            inner.hists[s as usize].observe_ns(d.as_nanos().min(u64::MAX as u128) as u64);
        }
    }

    /// [`Registry::observe`] from fractional seconds (driver-side code
    /// that already accounts in f64).
    #[inline]
    pub fn observe_s(&self, s: Series, seconds: f64) {
        if let Some(inner) = &self.0 {
            inner.hists[s as usize].observe_ns((seconds.max(0.0) * 1e9) as u64);
        }
    }

    /// Materialize every series into a wire-friendly snapshot.  A
    /// disabled registry yields the empty `MetricsSnapshot::default()`
    /// (version 0) — callers can distinguish "metrics off" that way.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let Some(inner) = &self.0 else {
            return MetricsSnapshot::default();
        };
        let counters = Counter::ALL
            .iter()
            .map(|&c| {
                (c.name().to_string(), inner.counters[c as usize].load(Ordering::Relaxed))
            })
            .collect();
        let mut gauges: Vec<(String, i64)> = Gauge::ALL
            .iter()
            .map(|&g| (g.name().to_string(), inner.gauges[g as usize].load(Ordering::Relaxed)))
            .collect();
        // labeled per-session levels ride the same name-addressed list;
        // BTreeMap keeps the exposition order deterministic
        for (session, v) in inner.session_live.lock().unwrap().iter() {
            gauges.push((session_gauge_name(session), *v));
        }
        let hists = Series::ALL
            .iter()
            .map(|&s| {
                let cell = &inner.hists[s as usize];
                let mut buckets: Vec<u64> =
                    cell.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
                while buckets.last() == Some(&0) {
                    buckets.pop();
                }
                HistSnapshot {
                    name: s.name().to_string(),
                    buckets,
                    sum_s: cell.sum_ns.load(Ordering::Relaxed) as f64 * 1e-9,
                    count: cell.count.load(Ordering::Relaxed),
                }
            })
            .collect();
        MetricsSnapshot {
            version: MetricsSnapshot::VERSION,
            uptime_s: inner.epoch.elapsed().as_secs_f64(),
            counters,
            gauges,
            hists,
        }
    }
}

/// The composite snapshot/exposition name for one session's live-task
/// gauge: `session_tasks_live{session="<name>"}`.  Session names are
/// validated at `OpenSession` time to exclude quotes and control
/// characters, so no escaping is needed here.
pub fn session_gauge_name(session: &str) -> String {
    format!("session_tasks_live{{session=\"{session}\"}}")
}

/// One histogram, frozen: per-bucket counts (trailing zero buckets
/// trimmed), total observed time, and observation count.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct HistSnapshot {
    pub name: String,
    /// bucket `i` counts observations in `[2^(i-1), 2^i)` ns
    pub buckets: Vec<u64>,
    pub sum_s: f64,
    pub count: u64,
}

impl HistSnapshot {
    /// Upper bound of bucket `i`, in seconds.
    pub fn bucket_le_s(i: usize) -> f64 {
        (1u128 << i) as f64 * 1e-9
    }

    /// Approximate quantile (0..=1): linearly interpolated within the
    /// log2 bucket the rank falls in.  Assuming observations spread
    /// uniformly inside a bucket this is far tighter than the bucket's
    /// upper bound (which alone can overestimate by 2x).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            let before = cum;
            cum += b;
            if cum >= rank {
                let lo = if i == 0 {
                    0.0
                } else {
                    HistSnapshot::bucket_le_s(i - 1)
                };
                let hi = HistSnapshot::bucket_le_s(i);
                let frac = (rank - before) as f64 / b as f64;
                return lo + frac * (hi - lo);
            }
        }
        HistSnapshot::bucket_le_s(self.buckets.len().saturating_sub(1))
    }

    pub fn mean_s(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_s / self.count as f64
        }
    }
}

/// A versioned, name-addressed view of every metric at one instant.
/// This is what crosses the wire (`Response::Metrics`), lands in
/// `RunOutcome`, and renders to Prometheus text.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// snapshot schema version ([`MetricsSnapshot::VERSION`]); 0 means
    /// "metrics disabled" (the `Default`)
    pub version: u32,
    /// seconds since the registry was enabled
    pub uptime_s: f64,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, i64)>,
    pub hists: Vec<HistSnapshot>,
}

impl MetricsSnapshot {
    pub const VERSION: u32 = 1;

    /// Counter by name; 0 when absent (older hub, disabled registry).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    /// Gauge by name; 0 when absent.
    pub fn gauge(&self, name: &str) -> i64 {
        self.gauges.iter().find(|(n, _)| n == name).map(|(_, v)| *v).unwrap_or(0)
    }

    /// Every per-session live-task gauge in this snapshot as
    /// `(session, live)` pairs, parsed back out of the composite
    /// `session_tasks_live{session="<name>"}` names.  Empty on older
    /// hubs that never labeled a gauge.
    pub fn session_gauges(&self) -> Vec<(String, i64)> {
        self.gauges
            .iter()
            .filter_map(|(n, v)| {
                let rest = n.strip_prefix("session_tasks_live{session=\"")?;
                let session = rest.strip_suffix("\"}")?;
                Some((session.to_string(), *v))
            })
            .collect()
    }

    pub fn hist(&self, name: &str) -> Option<&HistSnapshot> {
        self.hists.iter().find(|h| h.name == name)
    }

    /// Render in the Prometheus text exposition format (0.0.4): every
    /// series prefixed `threesched_`, counters suffixed `_total`,
    /// histograms as cumulative `_bucket{le=...}` + `_sum` + `_count`.
    pub fn to_prometheus(&self) -> String {
        let mut out = String::with_capacity(4096);
        out.push_str("# TYPE threesched_uptime_seconds gauge\n");
        out.push_str(&format!("threesched_uptime_seconds {}\n", self.uptime_s));
        for (name, v) in &self.counters {
            out.push_str(&format!("# TYPE threesched_{name}_total counter\n"));
            out.push_str(&format!("threesched_{name}_total {v}\n"));
        }
        let mut typed: Vec<&str> = Vec::new();
        for (name, v) in &self.gauges {
            // labeled gauges (`base{label=...}`) share one TYPE line per
            // base family, emitted before the family's first sample
            let base = name.split('{').next().unwrap_or(name);
            if !typed.contains(&base) {
                typed.push(base);
                out.push_str(&format!("# TYPE threesched_{base} gauge\n"));
            }
            out.push_str(&format!("threesched_{name} {v}\n"));
        }
        for h in &self.hists {
            let name = &h.name;
            out.push_str(&format!("# TYPE threesched_{name}_seconds histogram\n"));
            let mut cum = 0u64;
            for (i, &b) in h.buckets.iter().enumerate() {
                cum += b;
                out.push_str(&format!(
                    "threesched_{name}_seconds_bucket{{le=\"{le:e}\"}} {cum}\n",
                    le = HistSnapshot::bucket_le_s(i)
                ));
            }
            out.push_str(&format!(
                "threesched_{name}_seconds_bucket{{le=\"+Inf\"}} {}\n",
                h.count
            ));
            out.push_str(&format!("threesched_{name}_seconds_sum {}\n", h.sum_s));
            out.push_str(&format!("threesched_{name}_seconds_count {}\n", h.count));
        }
        out
    }
}

/// Serve `registry` as Prometheus text over plain TCP: a minimal
/// HTTP/1.1 responder (every request path gets the exposition — scrape
/// configs point at `/metrics` by convention).  Returns the bound
/// address and the acceptor thread's handle; the thread runs until the
/// process exits, which is exactly the lifetime of the `dhub serve`
/// foreground loop it fronts.
pub fn serve_exposition(
    registry: Registry,
    bind: &str,
) -> anyhow::Result<(SocketAddr, std::thread::JoinHandle<()>)> {
    use anyhow::Context as _;
    let listener =
        TcpListener::bind(bind).with_context(|| format!("binding metrics endpoint {bind}"))?;
    let addr = listener.local_addr()?;
    let handle = std::thread::Builder::new()
        .name("metrics-http".into())
        .spawn(move || {
            for stream in listener.incoming() {
                let Ok(mut s) = stream else { continue };
                let _ = s.set_read_timeout(Some(Duration::from_secs(2)));
                // drain the request line + headers (bounded); the reply
                // is the same regardless of path or method
                let mut buf = [0u8; 1024];
                let mut seen: Vec<u8> = Vec::new();
                loop {
                    match s.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            seen.extend_from_slice(&buf[..n]);
                            if seen.windows(4).any(|w| w == b"\r\n\r\n") || seen.len() > 8192 {
                                break;
                            }
                        }
                    }
                }
                let body = registry.snapshot().to_prometheus();
                let resp = format!(
                    "HTTP/1.1 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
                     Content-Length: {}\r\nConnection: close\r\n\r\n{}",
                    body.len(),
                    body
                );
                let _ = s.write_all(resp.as_bytes());
            }
        })
        .expect("spawn metrics responder");
    Ok((addr, handle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_is_inert() {
        let r = Registry::default();
        assert!(!r.is_enabled());
        r.inc(Counter::TasksCreated);
        r.gauge_add(Gauge::QueueDepth, 5);
        r.observe(Series::StealRtt, Duration::from_micros(3));
        assert_eq!(r.counter(Counter::TasksCreated), 0);
        assert_eq!(r.gauge(Gauge::QueueDepth), 0);
        let snap = r.snapshot();
        assert_eq!(snap, MetricsSnapshot::default());
        assert_eq!(snap.version, 0, "disabled snapshot is distinguishable");
    }

    #[test]
    fn counters_and_gauges_accumulate_across_clones() {
        let r = Registry::enabled();
        let r2 = r.clone();
        r.inc(Counter::StealsServed);
        r2.add(Counter::StealsServed, 4);
        r.gauge_add(Gauge::QueueDepth, 7);
        r2.gauge_add(Gauge::QueueDepth, -2);
        r.gauge_set(Gauge::WorkersConnected, 3);
        assert_eq!(r.counter(Counter::StealsServed), 5);
        assert_eq!(r2.gauge(Gauge::QueueDepth), 5);
        let snap = r.snapshot();
        assert_eq!(snap.version, MetricsSnapshot::VERSION);
        assert_eq!(snap.counter("steals_served"), 5);
        assert_eq!(snap.gauge("queue_depth"), 5);
        assert_eq!(snap.gauge("workers_connected"), 3);
        assert_eq!(snap.counter("no_such_counter"), 0);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let r = Registry::enabled();
        // 10 fast observations (~1 µs) and one slow outlier (~10 ms)
        for _ in 0..10 {
            r.observe(Series::StealRtt, Duration::from_micros(1));
        }
        r.observe(Series::StealRtt, Duration::from_millis(10));
        let snap = r.snapshot();
        let h = snap.hist("steal_rtt").expect("series present");
        assert_eq!(h.count, 11);
        assert!(h.sum_s > 0.009 && h.sum_s < 0.012, "sum {}", h.sum_s);
        // p50 sits in the microsecond buckets, p99 in the millisecond one
        let p50 = h.quantile(0.5);
        let p99 = h.quantile(0.99);
        assert!(p50 >= 0.5e-6 && p50 <= 4e-6, "p50 {p50}");
        assert!(p99 >= 0.005 && p99 <= 0.04, "p99 {p99}");
        assert!(p50 <= p99);
        // bucket invariant: per-bucket counts sum to count
        assert_eq!(h.buckets.iter().sum::<u64>(), h.count);
    }

    #[test]
    fn zero_and_huge_observations_stay_in_range() {
        let r = Registry::enabled();
        r.observe(Series::TaskCompute, Duration::ZERO);
        r.observe(Series::TaskCompute, Duration::from_secs(3600));
        let snap = r.snapshot();
        let h = snap.hist("task_compute").unwrap();
        assert_eq!(h.count, 2);
        assert_eq!(h.buckets[0], 1, "zero lands in bucket 0");
        assert_eq!(*h.buckets.last().unwrap(), 1, "overflow clamps to the last bucket");
        assert!(h.buckets.len() <= HIST_BUCKETS);
    }

    #[test]
    fn observe_s_matches_duration_path() {
        let r = Registry::enabled();
        r.observe_s(Series::TaskCompute, 1e-6);
        r.observe(Series::TaskCompute, Duration::from_micros(1));
        let h = r.snapshot().hist("task_compute").unwrap().clone();
        assert_eq!(h.count, 2);
        // both land in the same bucket
        assert_eq!(h.buckets.iter().filter(|&&b| b > 0).count(), 1);
        // negative seconds clamp to zero rather than wrapping
        r.observe_s(Series::TaskCompute, -5.0);
        let h = r.snapshot().hist("task_compute").unwrap().clone();
        assert_eq!(h.buckets[0], 1);
    }

    #[test]
    fn prometheus_exposition_shape() {
        let r = Registry::enabled();
        r.add(Counter::TasksCompleted, 42);
        r.gauge_set(Gauge::QueueDepth, 3);
        r.observe(Series::ServiceSteal, Duration::from_micros(7));
        r.observe(Series::ServiceSteal, Duration::from_micros(9));
        let text = r.snapshot().to_prometheus();
        assert!(text.contains("# TYPE threesched_tasks_completed_total counter"));
        assert!(text.contains("threesched_tasks_completed_total 42"));
        assert!(text.contains("# TYPE threesched_queue_depth gauge"));
        assert!(text.contains("threesched_queue_depth 3"));
        assert!(text.contains("# TYPE threesched_service_steal_seconds histogram"));
        assert!(text.contains("threesched_service_steal_seconds_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("threesched_service_steal_seconds_count 2"));
        // cumulative buckets never decrease
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("threesched_service_steal_seconds_bucket"))
        {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "non-monotone bucket line: {line}");
            last = v;
        }
    }

    #[test]
    fn exposition_endpoint_serves_scrapes() {
        use std::net::TcpStream;
        let r = Registry::enabled();
        r.add(Counter::StealsServed, 9);
        let (addr, _handle) = serve_exposition(r.clone(), "127.0.0.1:0").unwrap();
        // two scrapes: the responder must survive more than one connection
        for _ in 0..2 {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut text = String::new();
            s.read_to_string(&mut text).unwrap();
            assert!(text.starts_with("HTTP/1.1 200 OK"), "{text}");
            assert!(text.contains("text/plain; version=0.0.4"));
            assert!(text.contains("threesched_steals_served_total 9"), "{text}");
        }
    }

    #[test]
    fn session_labeled_gauges_snapshot_and_render() {
        let r = Registry::enabled();
        r.session_gauge_set("alpha", 3);
        r.session_gauge_set("beta", 0);
        let snap = r.snapshot();
        assert_eq!(snap.gauge("session_tasks_live{session=\"alpha\"}"), 3);
        assert_eq!(
            snap.session_gauges(),
            vec![("alpha".to_string(), 3), ("beta".to_string(), 0)]
        );
        let text = snap.to_prometheus();
        assert!(text.contains("threesched_session_tasks_live{session=\"alpha\"} 3"), "{text}");
        assert!(text.contains("threesched_session_tasks_live{session=\"beta\"} 0"), "{text}");
        // one TYPE line for the whole labeled family, none per sample
        let type_lines = text
            .lines()
            .filter(|l| *l == "# TYPE threesched_session_tasks_live gauge")
            .count();
        assert_eq!(type_lines, 1, "{text}");
        // closing a session drops its label from the next snapshot
        r.session_gauge_remove("alpha");
        assert_eq!(r.session_gauge("alpha"), None);
        assert_eq!(r.snapshot().session_gauges(), vec![("beta".to_string(), 0)]);
        // disabled registries stay inert
        let off = Registry::default();
        off.session_gauge_set("x", 9);
        assert_eq!(off.session_gauge("x"), None);
        assert!(off.snapshot().session_gauges().is_empty());
    }

    #[test]
    fn quantile_edge_cases() {
        let h = HistSnapshot::default();
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram");
        assert_eq!(h.mean_s(), 0.0);
        let r = Registry::enabled();
        r.observe(Series::StealRtt, Duration::from_micros(100));
        let snap = r.snapshot();
        let h = snap.hist("steal_rtt").unwrap();
        // all quantiles of a single observation agree
        assert_eq!(h.quantile(0.0), h.quantile(1.0));
        assert!(h.mean_s() > 0.0);
    }

    #[test]
    fn quantile_interpolates_within_a_bucket() {
        // 512 observations of 512..1024 ns all land in the same log2
        // bucket ([512, 1024) ns, index 10).  Before interpolation every
        // quantile collapsed to the bucket's upper bound (1024 ns ≈ 2x
        // the true median); interpolation spreads the mass uniformly.
        let r = Registry::enabled();
        for ns in 512..1024u64 {
            r.observe(Series::TaskCompute, Duration::from_nanos(ns));
        }
        let snap = r.snapshot();
        let h = snap.hist("task_compute").unwrap();
        assert_eq!(h.count, 512);
        // rank(q) = ceil(q * 512); lo = 512 ns, hi = 1024 ns, so
        // quantile(q) = (512 + rank(q)) ns exactly.
        for &(q, rank) in &[(0.25, 128u64), (0.5, 256), (0.75, 384), (0.99, 507)] {
            let want = (512 + rank) as f64 * 1e-9;
            let got = h.quantile(q);
            assert!(
                (got - want).abs() < 1e-12,
                "q={q}: got {got:e}, want {want:e}"
            );
        }
        // strictly increasing across distinct ranks, and never the old
        // flat upper bound for mid-bucket quantiles
        assert!(h.quantile(0.25) < h.quantile(0.5));
        assert!(h.quantile(0.5) < h.quantile(0.75));
        assert!(h.quantile(0.5) < HistSnapshot::bucket_le_s(10));
    }
}
