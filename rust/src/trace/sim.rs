//! Graph-aware DES models: run one [`WorkflowGraph`] through each
//! coordinator's scheduling logic in *virtual* time.
//!
//! [`crate::metg::simmodels`] simulates the paper's weak-scaling
//! benchmark workload; this module simulates an arbitrary workflow IR
//! graph instead, against the same Table-4 cost model — the missing
//! middle rung between the selector's closed-form makespan estimate and
//! a measured run.  Every model emits the standard lifecycle trace
//! ([`super::TaskEvent`]) with virtual timestamps, so `trace report`
//! and the wellformedness validator apply to simulated runs unchanged.

use anyhow::Result;

use crate::metg::simmodels::{Breakdown, SimRun, Tool};
use crate::substrate::cluster::costs::CostModel;
use crate::substrate::des::{key, Sim};
use crate::substrate::rng::Rng;
use crate::workflow::WorkflowGraph;

use super::{EventKind, Tracer};

/// Sampled task duration: the estimate plus small Gumbel execution
/// jitter (heavy right tail, like the calibrated models), floored so a
/// task never takes less than half its estimate.
fn noisy(rng: &mut Rng, est: f64, beta: f64) -> f64 {
    if est <= 0.0 {
        return 0.0;
    }
    (est + rng.gumbel(0.0, beta)).max(est * 0.5)
}

/// Dependency scaffolding shared by the queue-driven models: successor
/// lists, join (unfinished-dependency) counts, and the t=0 ready queue —
/// with the Created/Ready trace seeding done once.
fn seed_graph(
    g: &WorkflowGraph,
    tracer: &Tracer,
) -> (Vec<Vec<usize>>, Vec<usize>, std::collections::VecDeque<usize>) {
    let preds = (0..g.len()).map(|i| g.deps_of(i)).collect::<Vec<_>>();
    let mut succs: Vec<Vec<usize>> = vec![Vec::new(); g.len()];
    let mut join: Vec<usize> = preds.iter().map(Vec::len).collect();
    for (i, ps) in preds.iter().enumerate() {
        for &p in ps {
            succs[p].push(i);
        }
    }
    let mut ready: std::collections::VecDeque<usize> = Default::default();
    for (i, t) in g.tasks().iter().enumerate() {
        tracer.record_at(0.0, &t.name, EventKind::Created, "");
        if join[i] == 0 {
            tracer.record_at(0.0, &t.name, EventKind::Ready, "");
            ready.push_back(i);
        }
    }
    (succs, join, ready)
}

/// Simulate `g` on `tool` at `ranks` parallelism.  Deterministic for a
/// given seed.  The tracer (virtual timestamps) may be disabled.
pub fn simulate_workflow(
    tool: Tool,
    g: &WorkflowGraph,
    m: &CostModel,
    ranks: usize,
    seed: u64,
    tracer: &Tracer,
) -> Result<SimRun> {
    g.validate()?;
    let ranks = ranks.max(1);
    match tool {
        Tool::Pmake => sim_wf_pmake(g, m, ranks, seed, tracer),
        Tool::Dwork => sim_wf_dwork(g, m, ranks, seed, tracer),
        Tool::MpiList => sim_wf_mpilist(g, m, ranks, seed, tracer),
    }
}

// ------------------------------------------------------------------ pmake

/// pmake: every task is a job step pushed onto an allocation of `ranks`
/// slots; each launch pays jsrun + alloc before compute begins.
fn sim_wf_pmake(
    g: &WorkflowGraph,
    m: &CostModel,
    ranks: usize,
    seed: u64,
    tracer: &Tracer,
) -> Result<SimRun> {
    const DONE: u16 = 1;
    let mut rng = Rng::new(seed);
    let (succs, mut join, mut ready) = seed_graph(g, tracer);
    let launch = m.metg_pmake(ranks); // jsrun + alloc per job step
    let mut bd = Breakdown::default();
    let mut free = ranks;
    let mut makespan = 0.0f64;
    let mut sim = Sim::new();
    // launch pass shared by t=0 and every completion
    let dispatch = |sim: &mut Sim,
                    ready: &mut std::collections::VecDeque<usize>,
                    free: &mut usize,
                    bd: &mut Breakdown,
                    rng: &mut Rng| {
        while *free > 0 {
            let Some(i) = ready.pop_front() else { break };
            *free -= 1;
            let t = &g.tasks()[i];
            let now = sim.now();
            tracer.record_at(now, &t.name, EventKind::Launched, "pmake");
            tracer.record_at(now + launch, &t.name, EventKind::Started, "pmake");
            let dur = noisy(rng, t.est_s, m.gumbel_beta_per_task);
            bd.jsrun += m.jsrun(ranks);
            bd.alloc += m.alloc;
            bd.compute += dur;
            sim.after(launch + dur, key::pack(DONE, i as u64));
        }
    };
    dispatch(&mut sim, &mut ready, &mut free, &mut bd, &mut rng);
    while let Some(ev) = sim.next() {
        let i = key::index(ev.key) as usize;
        let now = sim.now();
        makespan = makespan.max(now);
        tracer.record_at(now, &g.tasks()[i].name, EventKind::Finished, "pmake");
        free += 1;
        for &s in &succs[i] {
            join[s] -= 1;
            if join[s] == 0 {
                tracer.record_at(now, &g.tasks()[s].name, EventKind::Ready, "");
                ready.push_back(s);
            }
        }
        dispatch(&mut sim, &mut ready, &mut free, &mut bd, &mut rng);
    }
    Ok(SimRun { makespan, breakdown: bd })
}

// ------------------------------------------------------------------ dwork

/// dwork: `ranks` pulling workers against one serialized server; each
/// Steal/Complete pair occupies the server for `steal_rtt`.
fn sim_wf_dwork(
    g: &WorkflowGraph,
    m: &CostModel,
    ranks: usize,
    seed: u64,
    tracer: &Tracer,
) -> Result<SimRun> {
    const REQ: u16 = 1; // worker joins the server queue
    const GRANT: u16 = 2; // server finished serving the head request
    const DONE: u16 = 3; // worker finished a task (index = task<<20 | worker)
    const WBITS: u64 = 20;
    anyhow::ensure!(
        ranks < (1 << WBITS) && g.len() < (1 << (48 - WBITS)),
        "dwork workflow sim limits: ranks < 2^20, tasks < 2^28"
    );

    let mut rng = Rng::new(seed);
    let (succs, mut join, mut ready) = seed_graph(g, tracer);
    let workers = ranks.min(g.len().max(1));
    let mut server_q: std::collections::VecDeque<usize> = Default::default();
    let mut parked: Vec<usize> = Vec::new(); // workers granted while nothing was ready
    let mut server_busy = false;
    let mut req_at = vec![0.0f64; workers];
    let mut assigned = 0usize;
    let mut finished = 0usize;
    let mut bd = Breakdown::default();
    let mut makespan = 0.0f64;
    let mut sim = Sim::new();
    for w in 0..workers {
        sim.at(0.0, key::pack(REQ, w as u64));
    }
    while let Some(ev) = sim.next() {
        let now = sim.now();
        match key::kind(ev.key) {
            REQ => {
                let w = key::index(ev.key) as usize;
                req_at[w] = now;
                server_q.push_back(w);
                if !server_busy {
                    server_busy = true;
                    sim.after(m.steal_rtt, key::pack(GRANT, 0));
                }
            }
            GRANT => {
                let w = server_q.pop_front().expect("grant with empty queue");
                bd.communication += now - req_at[w];
                match ready.pop_front() {
                    Some(i) => {
                        assigned += 1;
                        let name = &g.tasks()[i].name;
                        let who = format!("w{w}");
                        tracer.record_at(now, name, EventKind::Launched, &who);
                        tracer.record_at(now, name, EventKind::Started, &who);
                        let est = g.tasks()[i].est_s;
                        let dur = noisy(&mut rng, est, 0.02 * est);
                        bd.compute += dur;
                        sim.after(dur, key::pack(DONE, ((i as u64) << WBITS) | w as u64));
                    }
                    // nothing ready: the worker parks until a completion
                    // promotes a successor (the NotFound path)
                    None => parked.push(w),
                }
                if server_q.is_empty() {
                    server_busy = false;
                } else {
                    sim.after(m.steal_rtt, key::pack(GRANT, 0));
                }
            }
            DONE => {
                let idx = key::index(ev.key);
                let (i, w) = ((idx >> WBITS) as usize, (idx & ((1 << WBITS) - 1)) as usize);
                makespan = makespan.max(now);
                tracer.record_at(now, &g.tasks()[i].name, EventKind::Finished, &format!("w{w}"));
                finished += 1;
                for &s in &succs[i] {
                    join[s] -= 1;
                    if join[s] == 0 {
                        tracer.record_at(now, &g.tasks()[s].name, EventKind::Ready, "");
                        ready.push_back(s);
                        // wake one parked worker per newly ready task
                        if let Some(pw) = parked.pop() {
                            sim.at(now, key::pack(REQ, pw as u64));
                        }
                    }
                }
                if assigned < g.len() {
                    sim.at(now, key::pack(REQ, w as u64));
                }
            }
            _ => unreachable!(),
        }
    }
    debug_assert_eq!(finished, g.len());
    // residual idle: aggregate worker time minus compute and server wait
    bd.sync = (workers as f64 * makespan - bd.compute - bd.communication).max(0.0);
    Ok(SimRun { makespan, breakdown: bd })
}

// --------------------------------------------------------------- mpi-list

/// mpi-list: the static plan — per topological level, each rank runs its
/// contiguous block sequentially, then everyone barriers.
fn sim_wf_mpilist(
    g: &WorkflowGraph,
    m: &CostModel,
    ranks: usize,
    seed: u64,
    tracer: &Tracer,
) -> Result<SimRun> {
    use crate::coordinator::mpilist::block_range;
    let mut rng = Rng::new(seed);
    let levels = g.levels()?;
    for t in g.tasks() {
        tracer.record_at(0.0, &t.name, EventKind::Created, "");
    }
    let mut bd = Breakdown::default();
    let mut phase_start = 0.0f64;
    for level in &levels {
        let mut phase_end = phase_start;
        let mut busy_total = 0.0f64;
        for r in 0..ranks {
            let (start, count) = block_range(r, ranks, level.len() as u64);
            let mut cursor = phase_start;
            let who = format!("rank{r}");
            for k in start..start + count {
                let t = &g.tasks()[level[k as usize]];
                tracer.record_at(phase_start, &t.name, EventKind::Ready, "");
                tracer.record_at(cursor, &t.name, EventKind::Launched, &who);
                tracer.record_at(cursor, &t.name, EventKind::Started, &who);
                let dur = noisy(&mut rng, t.est_s, m.gumbel_beta_per_task);
                cursor += dur;
                bd.compute += dur;
                tracer.record_at(cursor, &t.name, EventKind::Finished, &who);
            }
            busy_total += cursor - phase_start;
            phase_end = phase_end.max(cursor);
        }
        // aggregate idle at the phase barrier (stragglers)
        bd.sync += (phase_end - phase_start) * ranks as f64 - busy_total;
        phase_start = phase_end;
    }
    Ok(SimRun { makespan: phase_start, breakdown: bd })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{counts, validate};
    use crate::workflow::TaskSpec;

    fn model() -> CostModel {
        CostModel::paper()
    }

    fn diamond() -> WorkflowGraph {
        let mut g = WorkflowGraph::new("diamond");
        g.add_task(TaskSpec::new("root").est(2.0)).unwrap();
        g.add_task(TaskSpec::new("l").after(&["root"]).est(3.0)).unwrap();
        g.add_task(TaskSpec::new("r").after(&["root"]).est(1.0)).unwrap();
        g.add_task(TaskSpec::new("join").after(&["l", "r"]).est(1.0)).unwrap();
        g
    }

    #[test]
    fn all_three_sims_emit_wellformed_traces() {
        let g = diamond();
        for tool in Tool::ALL {
            let tracer = Tracer::memory();
            let run = simulate_workflow(tool, &g, &model(), 4, 7, &tracer).unwrap();
            let evs = tracer.drain();
            validate(&evs).unwrap_or_else(|e| panic!("{}: {e}", tool.name()));
            let c = counts(&evs);
            assert_eq!(c.completed, 4, "{}", tool.name());
            assert_eq!(c.failed + c.skipped, 0, "{}", tool.name());
            assert!(run.makespan > 0.0, "{}", tool.name());
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let g = diamond();
        for tool in Tool::ALL {
            let a = simulate_workflow(tool, &g, &model(), 4, 9, &Tracer::disabled()).unwrap();
            let b = simulate_workflow(tool, &g, &model(), 4, 9, &Tracer::disabled()).unwrap();
            assert_eq!(a.makespan, b.makespan, "{}", tool.name());
        }
    }

    #[test]
    fn makespan_respects_critical_path_and_overheads() {
        let g = diamond(); // critical path 6s
        let m = model();
        // dwork/mpi-list add tiny per-task overheads: makespan ~ critical path
        for tool in [Tool::Dwork, Tool::MpiList] {
            let run = simulate_workflow(tool, &g, &m, 4, 1, &Tracer::disabled()).unwrap();
            assert!(
                (5.0..12.0).contains(&run.makespan),
                "{}: {}",
                tool.name(),
                run.makespan
            );
        }
        // pmake pays 3 levels of jsrun+alloc (~4.2s each) on the path
        let run = simulate_workflow(Tool::Pmake, &g, &m, 4, 1, &Tracer::disabled()).unwrap();
        assert!(
            run.makespan > 6.0 + 2.5 * m.metg_pmake(4),
            "pmake makespan {} must carry launch overhead",
            run.makespan
        );
    }

    #[test]
    fn dwork_sim_serializes_on_the_server_for_tiny_tasks() {
        // 512 zero-ish tasks: makespan floor = n * rtt (server bound)
        let mut g = WorkflowGraph::new("tiny");
        for i in 0..512 {
            g.add_task(TaskSpec::new(format!("t{i}")).est(0.0)).unwrap();
        }
        let m = model();
        let run = simulate_workflow(Tool::Dwork, &g, &m, 64, 3, &Tracer::disabled()).unwrap();
        let floor = 512.0 * m.steal_rtt;
        assert!(
            run.makespan >= floor * 0.9,
            "makespan {} vs server floor {floor}",
            run.makespan
        );
    }

    #[test]
    fn parallelism_speeds_up_flat_maps() {
        let mut g = WorkflowGraph::new("map");
        for i in 0..64 {
            g.add_task(TaskSpec::new(format!("k{i}")).est(1.0)).unwrap();
        }
        for tool in Tool::ALL {
            let slow = simulate_workflow(tool, &g, &model(), 1, 5, &Tracer::disabled()).unwrap();
            let fast = simulate_workflow(tool, &g, &model(), 32, 5, &Tracer::disabled()).unwrap();
            assert!(
                slow.makespan > fast.makespan * 4.0,
                "{}: {} vs {}",
                tool.name(),
                slow.makespan,
                fast.makespan
            );
        }
    }

    #[test]
    fn empty_graph_is_zero() {
        let g = WorkflowGraph::new("void");
        for tool in Tool::ALL {
            let run = simulate_workflow(tool, &g, &model(), 4, 1, &Tracer::disabled()).unwrap();
            assert_eq!(run.makespan, 0.0, "{}", tool.name());
        }
    }
}
