//! Per-task phase samples extracted from a lifecycle trace — the raw
//! material the calibration subsystem ([`crate::calibrate`]) fits the
//! [`CostModel`](crate::substrate::cluster::costs::CostModel) against.
//!
//! A trace is a flat stream of [`TaskEvent`]s; a fitter wants *samples*:
//! every `Ready → Launched` queue wait, every `Launched → Started` launch
//! window (pmake's jsrun+alloc lives here), every `Started → terminal`
//! compute duration (the mpi-list straggler noise lives in its
//! dispersion), the gaps between consecutive `Launched` events (a
//! saturated dwork server serializes these at exactly one steal RTT),
//! and the observed parallelism.  This module does the extraction; it
//! deliberately knows nothing about cost models.

use std::collections::HashMap;

use super::{makespan, EventKind, TaskEvent};
use crate::workflow::{TaskSpec, WorkflowGraph};

/// Interval samples pulled from one trace.  All values in seconds; one
/// entry per task *attempt* (a requeue restarts the attempt, exactly as
/// in [`super::report::TraceReport`]).
#[derive(Clone, Debug, Default)]
pub struct PhaseSamples {
    /// `Ready → Launched` per attempt
    pub queue_wait: Vec<f64>,
    /// `Launched → Started` per attempt (pmake: the job-step launch)
    pub launch: Vec<f64>,
    /// `Started → Finished/Failed` per attempt (falls back to
    /// `Launched → terminal` for server-only traces with no `Started`)
    pub compute: Vec<f64>,
    /// `Created → Started` first-attempt round-trip per task
    pub create_to_start: Vec<f64>,
    /// every `Launched` timestamp, in stream order (NOT sorted: DES
    /// producers may emit future-dated events early)
    pub launched_at: Vec<f64>,
    /// distinct non-empty `who` labels on Launched/Started/terminal
    pub workers: usize,
    /// distinct task names
    pub tasks: usize,
    /// latest event time
    pub makespan_s: f64,
}

impl PhaseSamples {
    /// Extract samples from an event stream (any producer).
    pub fn from_events(events: &[TaskEvent]) -> PhaseSamples {
        #[derive(Default)]
        struct Cursor {
            created: Option<f64>,
            ready: Option<f64>,
            launched: Option<f64>,
            started: Option<f64>,
            saw_start: bool,
        }
        let mut out = PhaseSamples { makespan_s: makespan(events), ..PhaseSamples::default() };
        let mut cursors: HashMap<&str, Cursor> = HashMap::new();
        let mut whos: std::collections::HashSet<&str> = Default::default();
        for ev in events {
            // worker attach, not a task: skip before the cursor map
            // sees its empty task name
            if ev.kind == EventKind::Connected {
                continue;
            }
            if !ev.who.is_empty()
                && matches!(
                    ev.kind,
                    EventKind::Launched
                        | EventKind::Started
                        | EventKind::Finished
                        | EventKind::Failed
                )
            {
                whos.insert(&ev.who);
            }
            let c = cursors.entry(&ev.task).or_default();
            match ev.kind {
                EventKind::Connected => unreachable!("filtered above"),
                EventKind::Created => c.created = Some(ev.t),
                EventKind::Ready => c.ready = Some(ev.t),
                EventKind::Launched => {
                    c.launched = Some(ev.t);
                    out.launched_at.push(ev.t);
                    if let Some(r) = c.ready {
                        out.queue_wait.push(ev.t - r);
                    }
                }
                EventKind::Started => {
                    c.started = Some(ev.t);
                    if let Some(l) = c.launched {
                        out.launch.push(ev.t - l);
                    }
                    if let (Some(cr), false) = (c.created, c.saw_start) {
                        out.create_to_start.push(ev.t - cr);
                    }
                    c.saw_start = true;
                }
                EventKind::Finished | EventKind::Failed => {
                    if let Some(s) = c.started.or(c.launched) {
                        out.compute.push(ev.t - s);
                    }
                }
                EventKind::Requeued => {
                    let created = c.created;
                    let saw_start = c.saw_start;
                    *c = Cursor { created, saw_start, ..Cursor::default() };
                }
            }
        }
        out.tasks = cursors.len();
        out.workers = whos.len();
        out
    }

    /// Positive gaps between consecutive `Launched` events in time order.
    /// On a saturated dwork server these are the steal/complete RTT; on
    /// an idle one they include think time, which is why fitters apply
    /// outlier rejection on top.
    pub fn launch_gaps(&self) -> Vec<f64> {
        let mut ts = self.launched_at.clone();
        ts.sort_by(f64::total_cmp);
        ts.windows(2).map(|w| w[1] - w[0]).filter(|&g| g > 0.0).collect()
    }

    /// The parallelism this trace ran at.  Per-worker `who` labels
    /// ("w3", "rank7") count directly; producers that label everything
    /// with one name (pmake's single managing process) fall back to the
    /// peak number of simultaneously in-flight tasks.
    pub fn inferred_parallelism(&self, events: &[TaskEvent]) -> usize {
        if self.workers > 1 {
            return self.workers;
        }
        peak_in_flight(events).max(1)
    }
}

/// Peak number of tasks simultaneously between `Launched` and their
/// terminal event (a sweep over interval endpoints).
fn peak_in_flight(events: &[TaskEvent]) -> usize {
    #[derive(Default)]
    struct Span {
        start: Option<f64>,
        end: Option<f64>,
    }
    let mut spans: HashMap<&str, Span> = HashMap::new();
    for ev in events {
        let s = spans.entry(&ev.task).or_default();
        match ev.kind {
            EventKind::Launched => {
                if s.start.is_none() {
                    s.start = Some(ev.t);
                }
            }
            EventKind::Finished | EventKind::Failed => s.end = Some(ev.t),
            _ => {}
        }
    }
    // +1 at each start, -1 at each end; ends sort before starts at equal
    // times so back-to-back serial tasks don't read as concurrent
    let mut deltas: Vec<(f64, i32)> = Vec::new();
    for s in spans.values() {
        if let (Some(a), Some(b)) = (s.start, s.end) {
            deltas.push((a, 1));
            deltas.push((b, -1));
        }
    }
    deltas.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.cmp(&y.1)));
    let mut cur = 0i32;
    let mut peak = 0i32;
    for (_, d) in deltas {
        cur += d;
        peak = peak.max(cur);
    }
    peak.max(0) as usize
}

/// Reconstruct a workload graph from a trace: one task per traced task
/// with its *measured* compute duration as the estimate, and dependency
/// edges inferred from timing — a task whose `Ready` coincides with
/// another task's `Finished` is taken to depend on it.  Exact for DES
/// traces (a successor becomes Ready at the virtual instant its last
/// dependency finishes); a heuristic for wall-clock traces.  Tasks that
/// never reached a terminal event are dropped.
///
/// This is what lets `threesched calibrate` cross-validate a fitted
/// cost model against the very traces it was fitted from, without
/// requiring the original `workflow.yaml`.
pub fn graph_from_trace(name: &str, events: &[TaskEvent]) -> anyhow::Result<WorkflowGraph> {
    #[derive(Clone, Default)]
    struct Obs {
        ready: Option<f64>,
        launched: Option<f64>,
        started: Option<f64>,
        finish: Option<f64>,
        dur: f64,
    }
    let mut obs: HashMap<String, Obs> = HashMap::new();
    let mut order: Vec<String> = Vec::new();
    for ev in events {
        // worker attach, not a task: never becomes a workload node
        if ev.kind == EventKind::Connected {
            continue;
        }
        if !obs.contains_key(&ev.task) {
            order.push(ev.task.clone());
        }
        let o = obs.entry(ev.task.clone()).or_default();
        match ev.kind {
            EventKind::Connected => unreachable!("filtered above"),
            EventKind::Created => {}
            EventKind::Ready => o.ready = Some(o.ready.unwrap_or(ev.t)),
            EventKind::Launched => o.launched = Some(ev.t),
            EventKind::Started => o.started = Some(ev.t),
            EventKind::Finished | EventKind::Failed => {
                o.finish = Some(ev.t);
                if let Some(s) = o.started.or(o.launched) {
                    o.dur = (ev.t - s).max(0.0);
                }
            }
            EventKind::Requeued => {
                o.launched = None;
                o.started = None;
            }
        }
    }
    // insertion order: by first-ready time, then finish, then name —
    // guarantees every inferred dependency precedes its dependent
    let mut done: Vec<(String, Obs)> = order
        .into_iter()
        .filter_map(|n| {
            let o = obs[&n].clone();
            o.finish.map(|_| (n, o))
        })
        .collect();
    done.sort_by(|a, b| {
        let ka = (a.1.ready.unwrap_or(0.0), a.1.finish.unwrap_or(0.0));
        let kb = (b.1.ready.unwrap_or(0.0), b.1.finish.unwrap_or(0.0));
        ka.0.total_cmp(&kb.0).then(ka.1.total_cmp(&kb.1)).then(a.0.cmp(&b.0))
    });
    // traced names may use characters the IR forbids ("atb_64@3"):
    // sanitize uniformly, deduplicating collisions deterministically
    let mut seen: std::collections::HashSet<String> = Default::default();
    let safe: Vec<String> = done
        .iter()
        .map(|(task, _)| {
            let mut s: String = task
                .chars()
                .map(|c| if c.is_ascii_alphanumeric() || "_-.".contains(c) { c } else { '_' })
                .collect();
            if s.is_empty() || s.starts_with('-') {
                s = format!("t{s}");
            }
            let mut unique = s.clone();
            let mut n = 1;
            while !seen.insert(unique.clone()) {
                unique = format!("{s}-{n}");
                n += 1;
            }
            unique
        })
        .collect();
    // finish-time index for dependency lookup (binary search instead of
    // an O(n²) scan: campaign traces reach 10^5 tasks)
    let mut by_finish: Vec<(f64, usize)> = done
        .iter()
        .enumerate()
        .map(|(j, (_, o))| (o.finish.expect("filtered to finished"), j))
        .collect();
    by_finish.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut g = WorkflowGraph::new(name);
    for (i, (_, o)) in done.iter().enumerate() {
        let mut spec = TaskSpec::new(safe[i].clone()).est(o.dur);
        if let Some(r) = o.ready.filter(|&r| r > 0.0) {
            let eps = 1e-9 * r.abs().max(1.0);
            let lo = by_finish.partition_point(|&(f, _)| f < r - eps);
            let deps: Vec<&str> = by_finish[lo..]
                .iter()
                .take_while(|&&(f, _)| f <= r + eps)
                .filter(|&&(_, j)| j < i)
                .map(|&(_, j)| safe[j].as_str())
                .collect();
            if !deps.is_empty() {
                spec = spec.after(&deps);
            }
        }
        g.add_task(spec)?;
    }
    Ok(g)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(task: &str, kind: EventKind, t: f64, who: &str) -> TaskEvent {
        TaskEvent { task: task.into(), kind, t, who: who.into(), seq: 0, session: String::new() }
    }

    fn lifecycle(task: &str, t0: f64, who: &str) -> Vec<TaskEvent> {
        vec![
            ev(task, EventKind::Created, 0.0, ""),
            ev(task, EventKind::Ready, t0, ""),
            ev(task, EventKind::Launched, t0 + 0.1, who),
            ev(task, EventKind::Started, t0 + 0.3, who),
            ev(task, EventKind::Finished, t0 + 1.3, who),
        ]
    }

    #[test]
    fn intervals_extracted_per_phase() {
        let mut evs = lifecycle("a", 0.0, "w0");
        evs.extend(lifecycle("b", 2.0, "w1"));
        let s = PhaseSamples::from_events(&evs);
        assert_eq!(s.tasks, 2);
        assert_eq!(s.workers, 2);
        assert_eq!(s.queue_wait, vec![0.1, 0.1]);
        assert_eq!(s.launch, vec![0.2, 0.2]);
        assert_eq!(s.compute, vec![1.0, 1.0]);
        assert_eq!(s.create_to_start, vec![0.3, 2.3]);
        assert!((s.makespan_s - 3.3).abs() < 1e-12);
    }

    #[test]
    fn launch_gaps_sorted_and_positive() {
        // stream order deliberately scrambled (DES future-dating)
        let evs = vec![
            ev("a", EventKind::Launched, 0.5, "w0"),
            ev("b", EventKind::Launched, 0.1, "w0"),
            ev("c", EventKind::Launched, 0.1, "w1"),
            ev("d", EventKind::Launched, 0.9, "w1"),
        ];
        let s = PhaseSamples::from_events(&evs);
        // gaps: 0.1->0.5 and 0.5->0.9 (the zero gap is dropped)
        assert_eq!(s.launch_gaps(), vec![0.4, 0.4]);
    }

    #[test]
    fn requeue_restarts_the_attempt() {
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Ready, 0.0, ""),
            ev("a", EventKind::Launched, 0.2, "w0"),
            ev("a", EventKind::Requeued, 1.0, "w0"),
            ev("a", EventKind::Ready, 1.0, ""),
            ev("a", EventKind::Launched, 1.5, "w1"),
            ev("a", EventKind::Started, 1.6, "w1"),
            ev("a", EventKind::Finished, 2.6, "w1"),
        ];
        let s = PhaseSamples::from_events(&evs);
        assert_eq!(s.queue_wait, vec![0.2, 0.5]);
        assert_eq!(s.compute, vec![1.0]);
        // Created -> first Started, once
        assert_eq!(s.create_to_start, vec![1.6]);
    }

    #[test]
    fn parallelism_from_workers_else_peak_overlap() {
        let mut evs = lifecycle("a", 0.0, "w0");
        evs.extend(lifecycle("b", 0.0, "w1"));
        let s = PhaseSamples::from_events(&evs);
        assert_eq!(s.inferred_parallelism(&evs), 2);

        // single label ("pmake"): fall back to overlap counting —
        // a+b overlap, c runs after both
        let mut evs = lifecycle("a", 0.0, "p");
        evs.extend(lifecycle("b", 0.0, "p"));
        evs.extend(lifecycle("c", 5.0, "p"));
        let s = PhaseSamples::from_events(&evs);
        assert_eq!(s.inferred_parallelism(&evs), 2);
    }

    #[test]
    fn serial_chain_has_parallelism_one() {
        let mut evs = lifecycle("a", 0.0, "p");
        // b launches exactly when a finishes: must not read as overlap
        evs.extend(vec![
            ev("b", EventKind::Created, 0.0, ""),
            ev("b", EventKind::Ready, 1.3, ""),
            ev("b", EventKind::Launched, 1.3, "p"),
            ev("b", EventKind::Started, 1.4, "p"),
            ev("b", EventKind::Finished, 2.4, "p"),
        ]);
        let s = PhaseSamples::from_events(&evs);
        assert_eq!(s.inferred_parallelism(&evs), 1);
    }

    #[test]
    fn graph_reconstruction_recovers_chain_and_durations() {
        // a -> b: b becomes Ready the instant a finishes
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Ready, 0.0, ""),
            ev("a", EventKind::Launched, 0.0, "p"),
            ev("a", EventKind::Started, 0.5, "p"),
            ev("a", EventKind::Finished, 2.5, "p"),
            ev("b", EventKind::Created, 0.0, ""),
            ev("b", EventKind::Ready, 2.5, ""),
            ev("b", EventKind::Launched, 2.5, "p"),
            ev("b", EventKind::Started, 3.0, "p"),
            ev("b", EventKind::Finished, 6.0, "p"),
        ];
        let g = graph_from_trace("rt", &evs).unwrap();
        assert_eq!(g.len(), 2);
        let a = g.get("a").unwrap();
        let b = g.get("b").unwrap();
        assert!((a.est_s - 2.0).abs() < 1e-12);
        assert!((b.est_s - 3.0).abs() < 1e-12);
        assert_eq!(b.after, vec!["a".to_string()]);
        assert!(a.after.is_empty());
    }

    #[test]
    fn graph_reconstruction_flat_map_has_no_edges() {
        let mut evs = Vec::new();
        for i in 0..4 {
            evs.extend(lifecycle(&format!("t{i}"), 0.0, "w0"));
        }
        let g = graph_from_trace("flat", &evs).unwrap();
        assert_eq!(g.len(), 4);
        assert!(g.tasks().iter().all(|t| t.after.is_empty()));
    }

    #[test]
    fn unfinished_tasks_dropped_from_reconstruction() {
        let mut evs = lifecycle("done", 0.0, "w0");
        evs.push(ev("hung", EventKind::Created, 0.0, ""));
        evs.push(ev("hung", EventKind::Launched, 0.1, "w1"));
        let g = graph_from_trace("partial", &evs).unwrap();
        assert_eq!(g.len(), 1);
        assert!(g.get("done").is_some());
    }
}
