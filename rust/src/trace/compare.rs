//! Cross-validation: selector-predicted vs DES-simulated vs measured
//! makespan, per back-end.
//!
//! The adaptive selector ([`crate::workflow::select`]) picks a
//! coordinator from closed-form makespan/efficiency estimates; the DES
//! ([`super::sim`]) runs the same graph through each scheduler's actual
//! queueing logic in virtual time; a trace file holds what a real run
//! did.  Laying the three side by side — with relative errors — is how
//! the cost model earns (or loses) trust, and the hook a future
//! auto-calibration pass will close the loop on.

use anyhow::Result;

use crate::metg::harness::TextTable;
use crate::metg::simmodels::Tool;
use crate::substrate::cluster::costs::CostModel;
use crate::workflow::{select, WorkflowGraph};

use super::report::fmt_t;
use super::sim::simulate_workflow;
use super::Tracer;

/// One back-end's predicted / simulated / measured triple.
#[derive(Clone, Debug)]
pub struct BackendComparison {
    pub tool: Tool,
    /// the selector's closed-form makespan estimate
    pub predicted_s: f64,
    /// DES makespan of the same graph on this back-end
    pub simulated_s: f64,
    /// makespan of a supplied measured trace, when one names this tool
    pub measured_s: Option<f64>,
    /// the selector would run this back-end
    pub selected: bool,
}

impl BackendComparison {
    /// |predicted − simulated| / simulated.
    pub fn rel_err_pred_vs_sim(&self) -> f64 {
        rel_err(self.predicted_s, self.simulated_s)
    }

    /// |simulated − measured| / measured, when a measurement exists.
    pub fn rel_err_sim_vs_measured(&self) -> Option<f64> {
        self.measured_s.map(|m| rel_err(self.simulated_s, m))
    }
}

fn rel_err(a: f64, b: f64) -> f64 {
    if b.abs() <= f64::MIN_POSITIVE {
        if a.abs() <= f64::MIN_POSITIVE {
            0.0
        } else {
            f64::INFINITY
        }
    } else {
        (a - b).abs() / b.abs()
    }
}

/// Match a trace source label ("pmake", "des:dwork", "workflow/mpi-list")
/// to the tool it describes.
pub fn tool_of_source(source: &str) -> Option<Tool> {
    // longest name first so "mpi-list" is never shadowed by a substring
    let mut tools = Tool::ALL;
    tools.sort_by_key(|t| std::cmp::Reverse(t.name().len()));
    tools.into_iter().find(|t| source.contains(t.name()))
}

/// Run the three-way comparison for `g` at `ranks` parallelism.
/// `measured` pairs a trace's source label with its makespan (from
/// `trace::read_trace` + `trace::makespan`); traces whose source does
/// not name a back-end are ignored.
pub fn compare_backends(
    g: &WorkflowGraph,
    m: &CostModel,
    ranks: usize,
    seed: u64,
    measured: &[(String, f64)],
) -> Result<Vec<BackendComparison>> {
    let rec = select(g, m, ranks)?;
    let mut out = Vec::with_capacity(3);
    for tool in Tool::ALL {
        let sim = simulate_workflow(tool, g, m, ranks, seed, &Tracer::disabled())?;
        let measured_s = measured
            .iter()
            .find(|(src, _)| tool_of_source(src) == Some(tool))
            .map(|&(_, mk)| mk);
        out.push(BackendComparison {
            tool,
            predicted_s: rec.assessment(tool).est_makespan_s,
            simulated_s: sim.makespan,
            measured_s,
            selected: rec.choice == tool,
        });
    }
    Ok(out)
}

/// Human-facing comparison table (the `trace compare` body).
pub fn render_comparison(name: &str, ranks: usize, rows: &[BackendComparison]) -> String {
    let mut t = TextTable::new(&[
        "backend",
        "predicted",
        "simulated",
        "|pred-sim|/sim",
        "measured",
        "|sim-meas|/meas",
        "",
    ]);
    for r in rows {
        t.row(vec![
            r.tool.name().into(),
            fmt_t(r.predicted_s),
            fmt_t(r.simulated_s),
            format!("{:.1}%", 100.0 * r.rel_err_pred_vs_sim()),
            r.measured_s.map(fmt_t).unwrap_or_else(|| "-".into()),
            r.rel_err_sim_vs_measured()
                .map(|e| format!("{:.1}%", 100.0 * e))
                .unwrap_or_else(|| "-".into()),
            if r.selected { "<- selected" } else { "" }.into(),
        ]);
    }
    format!(
        "predicted (selector) vs simulated (DES) vs measured makespan \
         for {name:?} at {ranks} ranks\n{}",
        t.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workflow::TaskSpec;

    fn model() -> CostModel {
        CostModel::paper()
    }

    fn farm(n: usize, est: f64) -> WorkflowGraph {
        let mut g = WorkflowGraph::new("farm");
        for i in 0..n {
            g.add_task(TaskSpec::new(format!("t{i}")).est(est)).unwrap();
        }
        g
    }

    #[test]
    fn covers_all_backends_and_marks_selection() {
        let rows = compare_backends(&farm(64, 1.0), &model(), 8, 1, &[]).unwrap();
        assert_eq!(rows.len(), 3);
        assert_eq!(rows.iter().filter(|r| r.selected).count(), 1);
        for r in &rows {
            assert!(r.predicted_s > 0.0, "{}", r.tool.name());
            assert!(r.simulated_s > 0.0, "{}", r.tool.name());
            assert!(r.measured_s.is_none());
        }
        let txt = render_comparison("farm", 8, &rows);
        for tool in Tool::ALL {
            assert!(txt.contains(tool.name()), "{txt}");
        }
        assert!(txt.contains("<- selected"));
    }

    #[test]
    fn predictions_track_simulation_for_coarse_flat_maps() {
        // coarse uniform work is the regime every model agrees on: the
        // selector's estimate and the DES should land within ~50%
        let rows = compare_backends(&farm(64, 10.0), &model(), 8, 2, &[]).unwrap();
        for r in &rows {
            assert!(
                r.rel_err_pred_vs_sim() < 0.5,
                "{}: pred {} vs sim {}",
                r.tool.name(),
                r.predicted_s,
                r.simulated_s
            );
        }
    }

    #[test]
    fn measured_trace_attaches_to_its_backend() {
        let measured = vec![("dwork".to_string(), 3.5), ("des:mpi-list".to_string(), 9.9)];
        let rows = compare_backends(&farm(8, 1.0), &model(), 4, 1, &measured).unwrap();
        let by = |t: Tool| rows.iter().find(|r| r.tool == t).unwrap();
        assert_eq!(by(Tool::Dwork).measured_s, Some(3.5));
        assert_eq!(by(Tool::MpiList).measured_s, Some(9.9));
        assert_eq!(by(Tool::Pmake).measured_s, None);
        assert!(by(Tool::Dwork).rel_err_sim_vs_measured().is_some());
    }

    #[test]
    fn source_labels_resolve() {
        assert_eq!(tool_of_source("pmake"), Some(Tool::Pmake));
        assert_eq!(tool_of_source("des:dwork"), Some(Tool::Dwork));
        assert_eq!(tool_of_source("workflow/mpi-list"), Some(Tool::MpiList));
        assert_eq!(tool_of_source("mystery"), None);
    }
}
