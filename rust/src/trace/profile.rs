//! Makespan attribution: the *realized* critical path of a finished run.
//!
//! `trace report` answers "where did the aggregate time go"; this module
//! answers the sharper question every perf PR needs — *which chain of
//! tasks and scheduler phases actually bounded the makespan*.  From any
//! lifecycle trace (real or DES) it reconstructs a dependency-respecting
//! chain of `Created→Ready→Launched→Started→Finished` intervals whose
//! spans telescope to exactly the measured makespan, attributes each
//! link to the Fig-5 phases (queue wait / launch / compute) plus a drain
//! residual, and reports per-link blame percentages, finish-slack
//! statistics for off-path tasks, and MAD-based straggler flags.
//!
//! Traces carry no dependency edges, so the walk uses the standard
//! realized-path reconstruction: walking backward from the last
//! finisher, a task's binding predecessor is either the latest task to
//! finish at-or-before its `Ready` (the dependency that released it) or
//! the latest same-worker task to finish at-or-before its `Launched`
//! (the task that held its executor) — whichever finished *later* is
//! the constraint that actually gated it.  On DES traces this is exact
//! (a dependency's `Finished` and its successor's `Ready` share one
//! virtual instant); on wall-clock traces it is the tightest
//! reconstruction the event stream supports.
//!
//! [`chrome_trace`] renders the same picture as Chrome trace-event JSON
//! (`chrome://tracing` / Perfetto): one row per worker, phase-colored
//! slices, the critical path chained with flow arrows.

use std::collections::{HashMap, HashSet};

use super::{json_escape, EventKind, TaskEvent};

/// Per-task observation: the final attempt's lifecycle timestamps.
#[derive(Clone, Debug, Default)]
struct Obs {
    created: Option<f64>,
    ready: Option<f64>,
    launched: Option<f64>,
    started: Option<f64>,
    finish: Option<f64>,
    failed: bool,
    who: String,
}

/// How a link joined the critical path.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkVia {
    /// chain root: nothing observable gated this task
    Root,
    /// released by a dependency finishing (latest finish at its `Ready`)
    Dep,
    /// gated by its worker finishing a previous task
    Worker,
}

impl LinkVia {
    pub fn name(&self) -> &'static str {
        match self {
            LinkVia::Root => "root",
            LinkVia::Dep => "dep",
            LinkVia::Worker => "worker",
        }
    }
}

/// One link of the realized critical path.  The link covers
/// `[start_s, finish_s]` where `start_s` is the previous link's finish
/// (0 for the root), so link spans telescope to the last finish time.
#[derive(Clone, Debug)]
pub struct PathLink {
    pub task: String,
    pub who: String,
    pub via: LinkVia,
    /// span start: previous link's finish (0 for the root link)
    pub start_s: f64,
    pub finish_s: f64,
    /// span portion before the executor had the task (start → Launched)
    pub queue_s: f64,
    /// Launched → Started
    pub launch_s: f64,
    /// Started (or Launched when the trace has no Started) → terminal
    pub compute_s: f64,
    /// this link's share of the makespan, in percent
    pub blame_pct: f64,
}

impl PathLink {
    pub fn span_s(&self) -> f64 {
        self.finish_s - self.start_s
    }
}

/// A task whose compute duration is a MAD outlier.
#[derive(Clone, Debug)]
pub struct Straggler {
    pub task: String,
    pub who: String,
    pub compute_s: f64,
    /// median + 3.5 robust sigmas at the time of flagging
    pub threshold_s: f64,
}

/// The profiler output: critical path + phase attribution + off-path
/// slack + stragglers.  Invariant (tested): the link spans plus
/// `drain_s` sum to exactly `makespan_s`.
#[derive(Clone, Debug, Default)]
pub struct TraceProfile {
    pub makespan_s: f64,
    /// tasks observed with a terminal event
    pub tasks: usize,
    /// chronological (root first)
    pub path: Vec<PathLink>,
    /// makespan minus the last link's finish: run teardown the path
    /// cannot see (worker exits, final bookkeeping)
    pub drain_s: f64,
    /// phase totals over the path links
    pub queue_s: f64,
    pub launch_s: f64,
    pub compute_s: f64,
    /// per-task finish slack (makespan − finish) for tasks *off* the
    /// path, sorted ascending
    pub off_path_slack_s: Vec<f64>,
    pub stragglers: Vec<Straggler>,
}

/// Fold the stream into per-task final-attempt observations.  `Requeued`
/// resets the attempt (the final attempt wins, matching the report
/// module's cursor discipline).
fn collect(events: &[TaskEvent]) -> (HashMap<&str, Obs>, f64) {
    let mut obs: HashMap<&str, Obs> = HashMap::new();
    let mut makespan = 0.0f64;
    for ev in events {
        makespan = makespan.max(ev.t);
        if ev.kind == EventKind::Connected {
            continue;
        }
        let o = obs.entry(&ev.task).or_default();
        match ev.kind {
            EventKind::Created => o.created = Some(ev.t),
            EventKind::Ready => o.ready = Some(ev.t),
            EventKind::Launched => o.launched = Some(ev.t),
            EventKind::Started => o.started = Some(ev.t),
            EventKind::Finished | EventKind::Failed => {
                o.finish = Some(ev.t);
                o.failed = ev.kind == EventKind::Failed;
            }
            EventKind::Requeued => {
                o.ready = None;
                o.launched = None;
                o.started = None;
            }
            EventKind::Connected => unreachable!(),
        }
        if !ev.who.is_empty() && !ev.kind.is_terminal() {
            o.who = ev.who.clone();
        } else if !ev.who.is_empty() && o.who.is_empty() {
            o.who = ev.who.clone();
        }
    }
    (obs, makespan)
}

/// Comparison slop for "finished at the same instant as": DES traces
/// put a dependency's finish and its successor's ready at one virtual
/// time; wall-clock traces are strictly ordered but float formatting
/// wobbles in the last bits.
fn eps_at(t: f64) -> f64 {
    1e-9 * t.abs().max(1.0)
}

impl TraceProfile {
    /// Profile an event stream.  Works on any trace [`super::validate`]
    /// accepts, including partial views (no `Started`, skipped tasks).
    pub fn from_events(events: &[TaskEvent]) -> TraceProfile {
        let (obs, makespan_s) = collect(events);
        // finished tasks, sorted by finish time — the walk's search index
        let mut by_finish: Vec<(&str, &Obs)> = obs
            .iter()
            .filter(|(_, o)| o.finish.is_some())
            .map(|(k, o)| (*k, o))
            .collect();
        by_finish.sort_by(|a, b| a.1.finish.unwrap().total_cmp(&b.1.finish.unwrap()));
        let tasks = by_finish.len();
        let mut profile = TraceProfile { makespan_s, tasks, ..TraceProfile::default() };
        let Some(&(last_task, _)) = by_finish.last() else {
            profile.drain_s = makespan_s;
            return profile;
        };

        // ------------------------------------------------ backward walk
        // latest finisher at-or-before `t`, optionally restricted to one
        // worker, excluding `not` (the task being explained)
        let latest_before = |t: f64, who: Option<&str>, not: &str| -> Option<&str> {
            let hi = by_finish.partition_point(|(_, o)| o.finish.unwrap() <= t + eps_at(t));
            by_finish[..hi]
                .iter()
                .rev()
                .find(|(name, o)| *name != not && who.map_or(true, |w| o.who == w))
                .map(|(name, _)| *name)
        };
        let mut chain: Vec<(&str, LinkVia)> = Vec::new();
        let mut visited: HashSet<&str> = HashSet::new();
        let mut cur = last_task;
        loop {
            visited.insert(cur);
            let o = &obs[cur];
            let fin = o.finish.unwrap();
            // the dependency that released us vs the task that held our
            // worker: the LATER finisher is the binding constraint
            let dep = o.ready.and_then(|r| latest_before(r, None, cur));
            let wrk = (!o.who.is_empty())
                .then(|| o.launched.and_then(|l| latest_before(l, Some(&o.who), cur)))
                .flatten();
            let fin_of = |name: &str| obs[name].finish.unwrap();
            let next = match (dep, wrk) {
                (Some(d), Some(w)) => {
                    if fin_of(w) > fin_of(d) {
                        Some((w, LinkVia::Worker))
                    } else {
                        Some((d, LinkVia::Dep))
                    }
                }
                (Some(d), None) => Some((d, LinkVia::Dep)),
                (None, Some(w)) => Some((w, LinkVia::Worker)),
                (None, None) => None,
            };
            match next {
                // causality guard: a "blocker" finishing at-or-after us is
                // noise (a parallel finisher at one instant), not a cause;
                // `via` labels how *cur* was gated, so an accepted blocker
                // stamps cur before the walk moves on
                Some((n, v)) if !visited.contains(n) && fin_of(n) < fin - eps_at(fin) => {
                    chain.push((cur, v));
                    cur = n;
                }
                _ => {
                    chain.push((cur, LinkVia::Root));
                    break;
                }
            }
        }
        chain.reverse(); // chronological: root first

        // ------------------------------------- telescoping links + phases
        let mut lo = 0.0f64;
        for (name, via) in &chain {
            let o = &obs[*name];
            let fin = o.finish.unwrap();
            // clamp the lifecycle marks into [lo, fin]: a mark before the
            // previous link's finish is time already attributed upstream
            let a = o.launched.unwrap_or(lo).clamp(lo, fin);
            let b = o.started.unwrap_or(a).clamp(a, fin);
            profile.path.push(PathLink {
                task: (*name).to_string(),
                who: o.who.clone(),
                via: *via,
                start_s: lo,
                finish_s: fin,
                queue_s: a - lo,
                launch_s: b - a,
                compute_s: fin - b,
                blame_pct: if makespan_s > 0.0 { 100.0 * (fin - lo) / makespan_s } else { 0.0 },
            });
            lo = fin;
        }
        profile.drain_s = makespan_s - lo;
        for l in &profile.path {
            profile.queue_s += l.queue_s;
            profile.launch_s += l.launch_s;
            profile.compute_s += l.compute_s;
        }

        // ------------------------------------------------ off-path slack
        let on_path: HashSet<&str> = chain.iter().map(|(n, _)| *n).collect();
        profile.off_path_slack_s = by_finish
            .iter()
            .filter(|(name, _)| !on_path.contains(name))
            .map(|(_, o)| makespan_s - o.finish.unwrap())
            .collect();
        profile.off_path_slack_s.sort_by(f64::total_cmp);

        // ------------------------------------------------ MAD stragglers
        let mut computes: Vec<(&str, &Obs, f64)> = by_finish
            .iter()
            .filter_map(|(name, o)| {
                o.started.map(|s| (*name, *o, o.finish.unwrap() - s))
            })
            .collect();
        if computes.len() >= 4 {
            let mut xs: Vec<f64> = computes.iter().map(|(_, _, c)| *c).collect();
            xs.sort_by(f64::total_cmp);
            let med = xs[xs.len() / 2];
            let mut dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
            dev.sort_by(f64::total_cmp);
            let mad = dev[dev.len() / 2];
            if mad > 0.0 {
                let threshold = med + 3.5 * 1.4826 * mad;
                computes.sort_by(|a, b| b.2.total_cmp(&a.2));
                for (name, o, c) in computes {
                    if c <= threshold {
                        break;
                    }
                    profile.stragglers.push(Straggler {
                        task: name.to_string(),
                        who: o.who.clone(),
                        compute_s: c,
                        threshold_s: threshold,
                    });
                }
            }
        }
        profile
    }

    /// Sum of link spans plus the drain residual — equal to
    /// [`TraceProfile::makespan_s`] by construction (the tested
    /// invariant).
    pub fn critical_path_s(&self) -> f64 {
        self.path.iter().map(|l| l.span_s()).sum::<f64>() + self.drain_s
    }

    /// drain's share of the makespan, in percent.
    pub fn drain_pct(&self) -> f64 {
        if self.makespan_s > 0.0 {
            100.0 * self.drain_s / self.makespan_s
        } else {
            0.0
        }
    }

    fn slack_quantile(&self, q: f64) -> f64 {
        let s = &self.off_path_slack_s;
        if s.is_empty() {
            return 0.0;
        }
        let i = (q.clamp(0.0, 1.0) * (s.len() - 1) as f64).round() as usize;
        s[i]
    }

    /// Human-facing report (the `trace profile` body).
    pub fn render(&self, source: &str) -> String {
        use super::report::fmt_t;
        let mut out = format!(
            "profile: source {source}, {} finished task(s), makespan {}, \
             critical path {} link(s) + drain {} ({:.1}%)\n",
            self.tasks,
            fmt_t(self.makespan_s),
            self.path.len(),
            fmt_t(self.drain_s),
            self.drain_pct()
        );
        if self.path.is_empty() {
            return out;
        }
        out.push_str(
            "  #   task                     worker        via     span      queue     launch    compute   blame\n",
        );
        for (i, l) in self.path.iter().enumerate() {
            out.push_str(&format!(
                "  {:<3} {:<24} {:<12}  {:<6} {:>9} {:>9} {:>9} {:>9}  {:>5.1}%\n",
                i + 1,
                truncate(&l.task, 24),
                truncate(if l.who.is_empty() { "-" } else { &l.who }, 12),
                l.via.name(),
                fmt_t(l.span_s()),
                fmt_t(l.queue_s),
                fmt_t(l.launch_s),
                fmt_t(l.compute_s),
                l.blame_pct
            ));
        }
        let total = self.makespan_s.max(f64::MIN_POSITIVE);
        out.push_str(&format!(
            "  phase totals on path: queue {:.1}%  launch {:.1}%  compute {:.1}%  drain {:.1}%\n",
            100.0 * self.queue_s / total,
            100.0 * self.launch_s / total,
            100.0 * self.compute_s / total,
            self.drain_pct()
        ));
        if !self.off_path_slack_s.is_empty() {
            out.push_str(&format!(
                "  off-path slack ({} task(s)): p50 {}  p90 {}  p99 {}  max {}\n",
                self.off_path_slack_s.len(),
                fmt_t(self.slack_quantile(0.50)),
                fmt_t(self.slack_quantile(0.90)),
                fmt_t(self.slack_quantile(0.99)),
                fmt_t(*self.off_path_slack_s.last().unwrap()),
            ));
            out.push_str(&slack_histogram(&self.off_path_slack_s));
        }
        if !self.stragglers.is_empty() {
            out.push_str("  straggler(s) (> median + 3.5 robust sigmas):\n");
            for s in self.stragglers.iter().take(10) {
                out.push_str(&format!(
                    "    {:<24} {:<12} compute {:>9} (threshold {})\n",
                    truncate(&s.task, 24),
                    truncate(if s.who.is_empty() { "-" } else { &s.who }, 12),
                    fmt_t(s.compute_s),
                    fmt_t(s.threshold_s)
                ));
            }
            if self.stragglers.len() > 10 {
                out.push_str(&format!("    … and {} more\n", self.stragglers.len() - 10));
            }
        }
        out
    }

    /// Machine-facing report (the `trace profile --json` body): one JSON
    /// object, hand-rolled like every other writer in this crate.
    pub fn to_json(&self, source: &str) -> String {
        let mut out = format!(
            "{{\"source\":\"{}\",\"makespan_s\":{:.9},\"tasks\":{},\"critical_path_s\":{:.9},\
             \"drain_s\":{:.9},\"drain_pct\":{:.4},\"queue_s\":{:.9},\"launch_s\":{:.9},\
             \"compute_s\":{:.9},\"path\":[",
            json_escape(source),
            self.makespan_s,
            self.tasks,
            self.critical_path_s(),
            self.drain_s,
            self.drain_pct(),
            self.queue_s,
            self.launch_s,
            self.compute_s
        );
        for (i, l) in self.path.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"task\":\"{}\",\"who\":\"{}\",\"via\":\"{}\",\"start_s\":{:.9},\
                 \"finish_s\":{:.9},\"queue_s\":{:.9},\"launch_s\":{:.9},\"compute_s\":{:.9},\
                 \"blame_pct\":{:.4}}}",
                json_escape(&l.task),
                json_escape(&l.who),
                l.via.name(),
                l.start_s,
                l.finish_s,
                l.queue_s,
                l.launch_s,
                l.compute_s,
                l.blame_pct
            ));
        }
        out.push_str("],\"off_path\":{");
        out.push_str(&format!(
            "\"count\":{},\"slack_p50_s\":{:.9},\"slack_p90_s\":{:.9},\"slack_p99_s\":{:.9},\
             \"slack_max_s\":{:.9}}}",
            self.off_path_slack_s.len(),
            self.slack_quantile(0.50),
            self.slack_quantile(0.90),
            self.slack_quantile(0.99),
            self.off_path_slack_s.last().copied().unwrap_or(0.0)
        ));
        out.push_str(",\"stragglers\":[");
        for (i, s) in self.stragglers.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(&format!(
                "{{\"task\":\"{}\",\"who\":\"{}\",\"compute_s\":{:.9},\"threshold_s\":{:.9}}}",
                json_escape(&s.task),
                json_escape(&s.who),
                s.compute_s,
                s.threshold_s
            ));
        }
        out.push_str("]}");
        out
    }
}

fn truncate(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        let cut: String = s.chars().take(n.saturating_sub(1)).collect();
        format!("{cut}…")
    }
}

/// Eight-bin ASCII histogram of off-path finish slack.
fn slack_histogram(sorted: &[f64]) -> String {
    const BINS: usize = 8;
    let lo = sorted[0];
    let hi = *sorted.last().unwrap();
    let span = (hi - lo).max(f64::MIN_POSITIVE);
    let mut counts = [0usize; BINS];
    for &s in sorted {
        let b = (((s - lo) / span) * BINS as f64).min(BINS as f64 - 1.0) as usize;
        counts[b] += 1;
    }
    let max = counts.iter().copied().max().unwrap_or(1).max(1);
    let mut out = String::new();
    use super::report::fmt_t;
    for (b, &n) in counts.iter().enumerate() {
        let from = lo + span * b as f64 / BINS as f64;
        let to = lo + span * (b + 1) as f64 / BINS as f64;
        let bar = "#".repeat((n * 40).div_ceil(max).min(40).max(usize::from(n > 0)));
        out.push_str(&format!(
            "    [{:>9} .. {:>9}) {:>6} {}\n",
            fmt_t(from),
            fmt_t(to),
            n,
            bar
        ));
    }
    out
}

// ------------------------------------------------------------ chrome export

/// Render an event stream + its profile as Chrome trace-event JSON
/// (loadable in `chrome://tracing` and Perfetto): one thread row per
/// worker (tid 0 = scheduler-side events with an empty `who`), a
/// phase-colored complete (`"ph":"X"`) slice per task — launch window and
/// compute separately — and the critical path as a flow-arrow chain
/// through its compute slices.  Timestamps are microseconds, per the
/// trace-event spec.
pub fn chrome_trace(events: &[TaskEvent], profile: &TraceProfile) -> String {
    let (obs, _) = collect(events);
    // stable worker → tid map: sorted names, tid 1.. (0 = scheduler)
    let mut workers: Vec<&str> =
        obs.values().map(|o| o.who.as_str()).filter(|w| !w.is_empty()).collect();
    workers.sort_unstable();
    workers.dedup();
    let tid_of = |who: &str| -> usize {
        if who.is_empty() {
            0
        } else {
            1 + workers.binary_search(&who).unwrap_or(0)
        }
    };
    let on_path: HashSet<&str> = profile.path.iter().map(|l| l.task.as_str()).collect();
    let us = |t: f64| t * 1e6;
    let mut ev_out: Vec<String> = Vec::new();
    // process/thread metadata rows
    ev_out.push(
        "{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{\"name\":\"threesched\"}}"
            .to_string(),
    );
    ev_out.push(
        "{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":0,\
         \"args\":{\"name\":\"scheduler\"}}"
            .to_string(),
    );
    for &w in &workers {
        ev_out.push(format!(
            "{{\"name\":\"thread_name\",\"ph\":\"M\",\"pid\":1,\"tid\":{},\
             \"args\":{{\"name\":\"{}\"}}}}",
            tid_of(w),
            json_escape(w)
        ));
    }
    // one launch slice (Launched → Started) + one compute slice
    // (Started/Launched → terminal) per finished task
    let mut names: Vec<&str> = obs.keys().copied().collect();
    names.sort_unstable(); // deterministic output
    for name in names {
        let o = &obs[name];
        let Some(fin) = o.finish else { continue };
        let tid = tid_of(&o.who);
        if let (Some(l), Some(s)) = (o.launched, o.started) {
            if s > l {
                ev_out.push(format!(
                    "{{\"name\":\"{}\",\"cat\":\"launch\",\"ph\":\"X\",\"pid\":1,\
                     \"tid\":{tid},\"ts\":{:.3},\"dur\":{:.3},\
                     \"cname\":\"thread_state_runnable\",\"args\":{{\"phase\":\"launch\"}}}}",
                    json_escape(name),
                    us(l),
                    us(s - l)
                ));
            }
        }
        let start = o.started.or(o.launched).or(o.ready).or(o.created).unwrap_or(fin);
        let cname = if o.failed {
            "terrible"
        } else if on_path.contains(name) {
            "bad"
        } else {
            "thread_state_running"
        };
        ev_out.push(format!(
            "{{\"name\":\"{}\",\"cat\":\"task\",\"ph\":\"X\",\"pid\":1,\"tid\":{tid},\
             \"ts\":{:.3},\"dur\":{:.3},\"cname\":\"{cname}\",\
             \"args\":{{\"phase\":\"compute\",\"on_path\":{}}}}}",
            json_escape(name),
            us(start),
            us(fin - start),
            on_path.contains(name)
        ));
    }
    // critical-path flow chain through the compute slices: s → t… → f
    if profile.path.len() >= 2 {
        let n = profile.path.len();
        for (i, l) in profile.path.iter().enumerate() {
            let o = &obs[l.task.as_str()];
            let fin = o.finish.unwrap();
            let start = o.started.or(o.launched).or(o.ready).or(o.created).unwrap_or(fin);
            // bind inside the compute slice (bp "e" = enclosing slice)
            let ts = us(start + (fin - start) * 0.5);
            let (ph, bp) = if i == 0 {
                ("s", "")
            } else if i + 1 == n {
                ("f", ",\"bp\":\"e\"")
            } else {
                ("t", "")
            };
            ev_out.push(format!(
                "{{\"name\":\"critical-path\",\"cat\":\"critical-path\",\"ph\":\"{ph}\"{bp},\
                 \"id\":1,\"pid\":1,\"tid\":{},\"ts\":{ts:.3}}}",
                tid_of(&o.who)
            ));
        }
    }
    format!("{{\"displayTimeUnit\":\"ms\",\"traceEvents\":[{}]}}", ev_out.join(","))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(task: &str, kind: EventKind, t: f64, who: &str) -> TaskEvent {
        TaskEvent { task: task.into(), kind, t, who: who.into(), seq: 0, session: String::new() }
    }

    fn lifecycle(task: &str, ready: f64, launched: f64, fin: f64, who: &str) -> Vec<TaskEvent> {
        vec![
            ev(task, EventKind::Created, 0.0, ""),
            ev(task, EventKind::Ready, ready, ""),
            ev(task, EventKind::Launched, launched, who),
            ev(task, EventKind::Started, launched + 0.01, who),
            ev(task, EventKind::Finished, fin, who),
        ]
    }

    #[test]
    fn empty_trace_profiles_to_nothing() {
        let p = TraceProfile::from_events(&[]);
        assert_eq!(p.tasks, 0);
        assert!(p.path.is_empty());
        assert_eq!(p.makespan_s, 0.0);
        assert_eq!(p.critical_path_s(), 0.0);
    }

    #[test]
    fn chain_follows_dependency_releases() {
        // a → b → c, each ready the instant its parent finishes
        let mut evs = lifecycle("a", 0.0, 0.1, 1.0, "w0");
        evs.extend(lifecycle("b", 1.0, 1.1, 2.0, "w1"));
        evs.extend(lifecycle("c", 2.0, 2.1, 3.0, "w0"));
        let p = TraceProfile::from_events(&evs);
        let names: Vec<&str> = p.path.iter().map(|l| l.task.as_str()).collect();
        assert_eq!(names, vec!["a", "b", "c"]);
        assert_eq!(p.path[0].via, LinkVia::Root);
        assert_eq!(p.path[1].via, LinkVia::Dep);
        assert!((p.critical_path_s() - p.makespan_s).abs() < 1e-9);
        let blame: f64 = p.path.iter().map(|l| l.blame_pct).sum::<f64>() + p.drain_pct();
        assert!((blame - 100.0).abs() < 1e-6, "blame sums to 100%, got {blame}");
    }

    #[test]
    fn worker_contention_is_attributed_to_the_worker() {
        // both ready at t=0, one worker: "second" waits for "first" to
        // free w0 — a worker link, not a dep link
        let mut evs = lifecycle("first", 0.0, 0.0, 1.0, "w0");
        evs.extend(lifecycle("second", 0.0, 1.0, 2.5, "w0"));
        let p = TraceProfile::from_events(&evs);
        let names: Vec<&str> = p.path.iter().map(|l| l.task.as_str()).collect();
        assert_eq!(names, vec!["first", "second"]);
        assert_eq!(p.path[1].via, LinkVia::Worker);
        assert!((p.critical_path_s() - p.makespan_s).abs() < 1e-9);
    }

    #[test]
    fn off_path_tasks_report_finish_slack() {
        let mut evs = lifecycle("long", 0.0, 0.0, 10.0, "w0");
        evs.extend(lifecycle("quick", 0.0, 0.0, 1.0, "w1"));
        let p = TraceProfile::from_events(&evs);
        assert_eq!(p.path.len(), 1, "quick is not on the path");
        assert_eq!(p.off_path_slack_s.len(), 1);
        assert!((p.off_path_slack_s[0] - 9.0).abs() < 1e-9);
    }

    #[test]
    fn phases_are_nonnegative_and_fill_each_span() {
        let mut evs = lifecycle("a", 0.0, 0.4, 1.0, "w0");
        evs.extend(lifecycle("b", 1.0, 1.5, 3.0, "w1"));
        let p = TraceProfile::from_events(&evs);
        for l in &p.path {
            assert!(l.queue_s >= 0.0 && l.launch_s >= 0.0 && l.compute_s >= 0.0);
            let sum = l.queue_s + l.launch_s + l.compute_s;
            assert!((sum - l.span_s()).abs() < 1e-9, "phases fill the span");
        }
    }

    #[test]
    fn requeued_tasks_profile_their_final_attempt() {
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Ready, 0.0, ""),
            ev("a", EventKind::Launched, 0.1, "dead"),
            ev("a", EventKind::Requeued, 0.5, "dead"),
            ev("a", EventKind::Ready, 0.5, ""),
            ev("a", EventKind::Launched, 0.6, "w1"),
            ev("a", EventKind::Started, 0.7, "w1"),
            ev("a", EventKind::Finished, 2.0, "w1"),
        ];
        let p = TraceProfile::from_events(&evs);
        assert_eq!(p.path.len(), 1);
        assert_eq!(p.path[0].who, "w1");
        assert!((p.critical_path_s() - p.makespan_s).abs() < 1e-9);
    }

    #[test]
    fn mad_flags_the_straggler() {
        let mut evs = Vec::new();
        for i in 0..20 {
            let launched = 0.1 * i as f64;
            // computes 0.11 .. 0.129: nonzero spread so the MAD is > 0
            evs.extend(lifecycle(
                &format!("t{i}"),
                0.0,
                launched,
                launched + 0.11 + 0.001 * i as f64,
                "w0",
            ));
        }
        evs.extend(lifecycle("slow", 0.0, 5.0, 9.0, "w1"));
        let p = TraceProfile::from_events(&evs);
        assert_eq!(p.stragglers.len(), 1, "stragglers: {:?}", p.stragglers);
        assert_eq!(p.stragglers[0].task, "slow");
    }

    #[test]
    fn chrome_export_has_one_compute_slice_per_finished_task() {
        let mut evs = lifecycle("a", 0.0, 0.1, 1.0, "w0");
        evs.extend(lifecycle("b", 1.0, 1.1, 2.0, "w1"));
        let p = TraceProfile::from_events(&evs);
        let json = chrome_trace(&evs, &p);
        assert_eq!(json.matches("\"phase\":\"compute\"").count(), 2);
        assert!(json.contains("\"thread_name\""));
        assert!(json.contains("\"ph\":\"s\"") && json.contains("\"ph\":\"f\""));
        assert!(json.starts_with("{\"displayTimeUnit\""));
    }

    #[test]
    fn json_report_is_self_consistent() {
        let mut evs = lifecycle("a", 0.0, 0.1, 1.0, "w0");
        evs.extend(lifecycle("b", 1.0, 1.1, 2.0, "w1"));
        let p = TraceProfile::from_events(&evs);
        let j = p.to_json("dwork");
        assert!(j.contains("\"source\":\"dwork\""));
        assert!(j.contains("\"path\":["));
        assert!(j.contains("\"blame_pct\""));
        // render shouldn't panic on the same profile
        let r = p.render("dwork");
        assert!(r.contains("critical path"));
    }
}
