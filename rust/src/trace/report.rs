//! Fig-5-shaped reporting over a trace: where each task's time went.
//!
//! The paper's Fig 5 splits aggregate rank time into compute and the
//! per-scheduler overheads.  A lifecycle trace supports the same split
//! generically, without knowing which coordinator produced it:
//!
//! * **queue wait** — `Ready → Launched`: the task was eligible but the
//!   scheduler had no capacity (pmake's node limit, dwork's serialized
//!   server, an mpi-list rank still busy with earlier block elements);
//! * **launch** — `Launched → Started`: hand-off overhead (pmake's
//!   jsrun+alloc window, a dwork task sitting in a worker's prefetch
//!   buffer);
//! * **compute** — `Started → Finished/Failed` (falls back to
//!   `Launched → terminal` for server-only traces with no `Started`);
//! * **drain** — per-worker idle tail: makespan minus the worker's last
//!   recorded activity (stragglers leave the rest of the pool idle).
//!
//! Utilization = compute / (workers × makespan), directly comparable to
//! the simulated [`Breakdown::compute_fraction`]
//! (crate::metg::simmodels::Breakdown::compute_fraction).

use std::collections::HashMap;

use super::{makespan, counts, EventKind, TaskEvent, TraceCounts};

/// Aggregate per-component seconds derived from one trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceReport {
    pub counts: TraceCounts,
    pub tasks: usize,
    pub makespan_s: f64,
    /// distinct non-empty `who` labels seen on Launched/Started/terminal
    pub workers: usize,
    pub queue_wait_s: f64,
    pub launch_s: f64,
    pub compute_s: f64,
    pub drain_s: f64,
}

impl TraceReport {
    /// Build the report from an event stream (any producer).
    pub fn from_events(events: &[TaskEvent]) -> TraceReport {
        let mut r = TraceReport {
            counts: counts(events),
            makespan_s: makespan(events),
            ..TraceReport::default()
        };
        // per-task attempt walk: interval starts reset on Requeued
        #[derive(Default)]
        struct Cursor {
            ready: Option<f64>,
            launched: Option<f64>,
            started: Option<f64>,
        }
        let mut cursors: HashMap<&str, Cursor> = HashMap::new();
        let mut last_activity: HashMap<&str, f64> = HashMap::new();
        for ev in events {
            // worker attach, not a task: skip before the cursor map sees
            // its empty task name
            if ev.kind == EventKind::Connected {
                continue;
            }
            if !ev.who.is_empty()
                && matches!(
                    ev.kind,
                    EventKind::Launched
                        | EventKind::Started
                        | EventKind::Finished
                        | EventKind::Failed
                )
            {
                let t = last_activity.entry(&ev.who).or_insert(ev.t);
                *t = t.max(ev.t);
            }
            let c = cursors.entry(&ev.task).or_default();
            match ev.kind {
                EventKind::Connected => unreachable!("filtered above"),
                EventKind::Created => {}
                EventKind::Ready => c.ready = Some(ev.t),
                EventKind::Launched => {
                    c.launched = Some(ev.t);
                    if let Some(rdy) = c.ready {
                        r.queue_wait_s += ev.t - rdy;
                    }
                }
                EventKind::Started => {
                    c.started = Some(ev.t);
                    if let Some(l) = c.launched {
                        r.launch_s += ev.t - l;
                    }
                }
                EventKind::Finished | EventKind::Failed => {
                    if let Some(s) = c.started.or(c.launched) {
                        r.compute_s += ev.t - s;
                    }
                }
                EventKind::Requeued => *c = Cursor::default(),
            }
        }
        r.tasks = cursors.len();
        r.workers = last_activity.len();
        r.drain_s = last_activity
            .values()
            .map(|&t| (r.makespan_s - t).max(0.0))
            .sum();
        r
    }

    /// Fraction of worker-seconds spent computing (0 when unknowable).
    pub fn utilization(&self) -> f64 {
        let denom = self.workers as f64 * self.makespan_s;
        if denom <= 0.0 {
            0.0
        } else {
            (self.compute_s / denom).min(1.0)
        }
    }

    /// Human-facing report (the `trace report` body).
    pub fn render(&self, source: &str) -> String {
        let c = &self.counts;
        let mut out = format!(
            "trace: source {source}, {} tasks ({} completed, {} failed, {} skipped), \
             makespan {}, {} worker(s)\n",
            self.tasks,
            c.completed,
            c.failed,
            c.skipped,
            fmt_t(self.makespan_s),
            self.workers
        );
        let total = (self.queue_wait_s + self.launch_s + self.compute_s + self.drain_s)
            .max(f64::MIN_POSITIVE);
        out.push_str("  component     aggregate    share\n");
        for (name, v) in [
            ("compute", self.compute_s),
            ("queue wait", self.queue_wait_s),
            ("launch", self.launch_s),
            ("drain", self.drain_s),
        ] {
            out.push_str(&format!(
                "  {:<12} {:>10}   {:>5.1}%\n",
                name,
                fmt_t(v),
                100.0 * v / total
            ));
        }
        out.push_str(&format!(
            "  utilization  {:>5.1}% of {} worker(s) x {}\n",
            100.0 * self.utilization(),
            self.workers,
            fmt_t(self.makespan_s)
        ));
        out
    }
}

pub(crate) fn fmt_t(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.3}s")
    } else if t >= 1e-3 {
        format!("{:.3}ms", t * 1e3)
    } else {
        format!("{:.1}us", t * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(task: &str, kind: EventKind, t: f64, who: &str) -> TaskEvent {
        TaskEvent { task: task.into(), kind, t, who: who.into() }
    }

    #[test]
    fn components_add_up() {
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Ready, 0.0, ""),
            ev("a", EventKind::Launched, 0.5, "w0"), // 0.5 queue
            ev("a", EventKind::Started, 0.7, "w0"),  // 0.2 launch
            ev("a", EventKind::Finished, 1.7, "w0"), // 1.0 compute
            ev("b", EventKind::Created, 0.0, ""),
            ev("b", EventKind::Ready, 0.0, ""),
            ev("b", EventKind::Launched, 0.0, "w1"),
            ev("b", EventKind::Started, 0.0, "w1"),
            ev("b", EventKind::Finished, 1.0, "w1"), // 1.0 compute, 0.7 drain
        ];
        let r = TraceReport::from_events(&evs);
        assert_eq!(r.tasks, 2);
        assert_eq!(r.workers, 2);
        assert!((r.queue_wait_s - 0.5).abs() < 1e-12);
        assert!((r.launch_s - 0.2).abs() < 1e-12);
        assert!((r.compute_s - 2.0).abs() < 1e-12);
        assert!((r.drain_s - 0.7).abs() < 1e-12, "{}", r.drain_s);
        assert!((r.makespan_s - 1.7).abs() < 1e-12);
        // utilization = 2.0 / (2 * 1.7)
        assert!((r.utilization() - 2.0 / 3.4).abs() < 1e-12);
        let txt = r.render("test");
        assert!(txt.contains("compute"));
        assert!(txt.contains("utilization"));
    }

    #[test]
    fn server_only_trace_still_reports_compute() {
        // no Started events: Launched→terminal counts as compute
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Launched, 0.1, "w0"),
            ev("a", EventKind::Finished, 1.1, "w0"),
        ];
        let r = TraceReport::from_events(&evs);
        assert!((r.compute_s - 1.0).abs() < 1e-12);
        assert!((r.launch_s - 0.0).abs() < 1e-12);
    }

    #[test]
    fn requeue_resets_attempt_intervals() {
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Ready, 0.0, ""),
            ev("a", EventKind::Launched, 0.1, "w0"),
            ev("a", EventKind::Requeued, 5.0, "w0"),
            ev("a", EventKind::Ready, 5.0, ""),
            ev("a", EventKind::Launched, 5.1, "w1"),
            ev("a", EventKind::Started, 5.2, "w1"),
            ev("a", EventKind::Finished, 6.2, "w1"),
        ];
        let r = TraceReport::from_events(&evs);
        // compute must come from the SECOND attempt only (1.0s), not 6.1
        assert!((r.compute_s - 1.0).abs() < 1e-12, "{}", r.compute_s);
        // queue wait: 0.1 (first) + 0.1 (second)
        assert!((r.queue_wait_s - 0.2).abs() < 1e-9);
    }

    #[test]
    fn empty_trace_renders() {
        let r = TraceReport::from_events(&[]);
        assert_eq!(r.tasks, 0);
        assert_eq!(r.utilization(), 0.0);
        assert!(r.render("x").contains("0 tasks"));
    }
}
