//! Fig-5-shaped reporting over a trace: where each task's time went.
//!
//! The paper's Fig 5 splits aggregate rank time into compute and the
//! per-scheduler overheads.  A lifecycle trace supports the same split
//! generically, without knowing which coordinator produced it:
//!
//! * **queue wait** — `Ready → Launched`: the task was eligible but the
//!   scheduler had no capacity (pmake's node limit, dwork's serialized
//!   server, an mpi-list rank still busy with earlier block elements);
//! * **launch** — `Launched → Started`: hand-off overhead (pmake's
//!   jsrun+alloc window, a dwork task sitting in a worker's prefetch
//!   buffer);
//! * **compute** — `Started → Finished/Failed` (falls back to
//!   `Launched → terminal` for server-only traces with no `Started`);
//! * **drain** — per-worker idle tail: makespan minus the worker's last
//!   recorded activity (stragglers leave the rest of the pool idle).
//!
//! Utilization = compute / (workers × makespan), directly comparable to
//! the simulated [`Breakdown::compute_fraction`]
//! (crate::metg::simmodels::Breakdown::compute_fraction).

use std::collections::HashMap;

use super::{counts, makespan, EventKind, MetricSample, TaskEvent, TraceCounts};

/// Gaps between a worker's consecutive task intervals longer than this
/// count as park episodes: the worker sat in its poll/backoff loop
/// rather than flowing straight into the next task.
const PARK_GAP_S: f64 = 1e-3;

/// Per-worker activity digest (one row of the utilization table).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WorkerRow {
    pub who: String,
    /// terminal events attributed to this worker
    pub tasks: usize,
    /// seconds inside its Started→terminal (or Launched→terminal) spans
    pub busy_s: f64,
    /// idle gaps between consecutive spans longer than [`PARK_GAP_S`]
    pub parks: usize,
}

/// Aggregate per-component seconds derived from one trace.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceReport {
    pub counts: TraceCounts,
    pub tasks: usize,
    pub makespan_s: f64,
    /// distinct non-empty `who` labels seen on Launched/Started/terminal
    pub workers: usize,
    pub queue_wait_s: f64,
    pub launch_s: f64,
    pub compute_s: f64,
    pub drain_s: f64,
    /// who-tagged activity rows, sorted by worker name (empty when the
    /// producer recorded no `who` labels)
    pub per_worker: Vec<WorkerRow>,
}

impl TraceReport {
    /// Build the report from an event stream (any producer).
    pub fn from_events(events: &[TaskEvent]) -> TraceReport {
        let mut r = TraceReport {
            counts: counts(events),
            makespan_s: makespan(events),
            ..TraceReport::default()
        };
        // per-task attempt walk: interval starts reset on Requeued
        #[derive(Default)]
        struct Cursor {
            ready: Option<f64>,
            launched: Option<f64>,
            started: Option<f64>,
        }
        let mut cursors: HashMap<&str, Cursor> = HashMap::new();
        let mut last_activity: HashMap<&str, f64> = HashMap::new();
        let mut spans: HashMap<&str, Vec<(f64, f64)>> = HashMap::new();
        for ev in events {
            // worker attach, not a task: skip before the cursor map sees
            // its empty task name
            if ev.kind == EventKind::Connected {
                continue;
            }
            if !ev.who.is_empty()
                && matches!(
                    ev.kind,
                    EventKind::Launched
                        | EventKind::Started
                        | EventKind::Finished
                        | EventKind::Failed
                )
            {
                let t = last_activity.entry(&ev.who).or_insert(ev.t);
                *t = t.max(ev.t);
            }
            let c = cursors.entry(&ev.task).or_default();
            match ev.kind {
                EventKind::Connected => unreachable!("filtered above"),
                EventKind::Created => {}
                EventKind::Ready => c.ready = Some(ev.t),
                EventKind::Launched => {
                    c.launched = Some(ev.t);
                    if let Some(rdy) = c.ready {
                        r.queue_wait_s += ev.t - rdy;
                    }
                }
                EventKind::Started => {
                    c.started = Some(ev.t);
                    if let Some(l) = c.launched {
                        r.launch_s += ev.t - l;
                    }
                }
                EventKind::Finished | EventKind::Failed => {
                    if let Some(s) = c.started.or(c.launched) {
                        r.compute_s += ev.t - s;
                        if !ev.who.is_empty() {
                            spans.entry(&ev.who).or_default().push((s, ev.t));
                        }
                    }
                }
                EventKind::Requeued => *c = Cursor::default(),
            }
        }
        r.tasks = cursors.len();
        r.workers = last_activity.len();
        r.drain_s = last_activity
            .values()
            .map(|&t| (r.makespan_s - t).max(0.0))
            .sum();
        r.per_worker = spans
            .into_iter()
            .map(|(who, mut iv)| {
                iv.sort_by(|a, b| a.0.total_cmp(&b.0));
                let busy_s = iv.iter().map(|(s, e)| (e - s).max(0.0)).sum();
                let parks = iv.windows(2).filter(|w| w[1].0 - w[0].1 > PARK_GAP_S).count();
                WorkerRow { who: who.to_string(), tasks: iv.len(), busy_s, parks }
            })
            .collect();
        r.per_worker.sort_by(|a, b| a.who.cmp(&b.who));
        r
    }

    /// Fraction of worker-seconds spent computing (0 when unknowable).
    pub fn utilization(&self) -> f64 {
        let denom = self.workers as f64 * self.makespan_s;
        if denom <= 0.0 {
            0.0
        } else {
            (self.compute_s / denom).min(1.0)
        }
    }

    /// Human-facing report (the `trace report` body).
    pub fn render(&self, source: &str) -> String {
        let c = &self.counts;
        let mut out = format!(
            "trace: source {source}, {} tasks ({} completed, {} failed, {} skipped), \
             makespan {}, {} worker(s)\n",
            self.tasks,
            c.completed,
            c.failed,
            c.skipped,
            fmt_t(self.makespan_s),
            self.workers
        );
        let total = (self.queue_wait_s + self.launch_s + self.compute_s + self.drain_s)
            .max(f64::MIN_POSITIVE);
        out.push_str("  component     aggregate    share\n");
        for (name, v) in [
            ("compute", self.compute_s),
            ("queue wait", self.queue_wait_s),
            ("launch", self.launch_s),
            ("drain", self.drain_s),
        ] {
            out.push_str(&format!(
                "  {:<12} {:>10}   {:>5.1}%\n",
                name,
                fmt_t(v),
                100.0 * v / total
            ));
        }
        out.push_str(&format!(
            "  utilization  {:>5.1}% of {} worker(s) x {}\n",
            100.0 * self.utilization(),
            self.workers,
            fmt_t(self.makespan_s)
        ));
        if !self.per_worker.is_empty() {
            out.push_str("  worker            tasks       busy   busy%  parks\n");
            for w in &self.per_worker {
                let frac = if self.makespan_s > 0.0 {
                    (w.busy_s / self.makespan_s).min(1.0)
                } else {
                    0.0
                };
                out.push_str(&format!(
                    "    {:<16} {:>5} {:>10}  {:>5.1}%  {:>5}\n",
                    w.who,
                    w.tasks,
                    fmt_t(w.busy_s),
                    100.0 * frac,
                    w.parks
                ));
            }
        }
        out
    }
}

/// Render the periodic gauge samples a tracer-enabled dwork run folds
/// into its trace (`{"metric":…}` lines): queue depth over time, tasks
/// in flight.  Each series gets a ten-bin time-bucketed mean row, the
/// terminal's answer to Fig 5's queue-depth plots.  Empty input renders
/// to the empty string so `trace report` stays byte-identical for
/// traces without samples.
pub fn render_metrics(samples: &[MetricSample]) -> String {
    if samples.is_empty() {
        return String::new();
    }
    let mut names: Vec<&str> = samples.iter().map(|s| s.name.as_str()).collect();
    names.sort_unstable();
    names.dedup();
    const BINS: usize = 10;
    let mut out = String::from("  sampled gauges (ten time-binned means, first -> last):\n");
    for name in names {
        let pts: Vec<&MetricSample> = samples.iter().filter(|s| s.name == name).collect();
        let t0 = pts.iter().map(|s| s.t).fold(f64::INFINITY, f64::min);
        let t1 = pts.iter().map(|s| s.t).fold(f64::NEG_INFINITY, f64::max);
        let max = pts.iter().map(|s| s.value).fold(f64::NEG_INFINITY, f64::max);
        let mean = pts.iter().map(|s| s.value).sum::<f64>() / pts.len() as f64;
        let span = (t1 - t0).max(f64::MIN_POSITIVE);
        let mut sum = [0.0f64; BINS];
        let mut n = [0usize; BINS];
        for s in &pts {
            let b = (((s.t - t0) / span) * BINS as f64).min(BINS as f64 - 1.0) as usize;
            sum[b] += s.value;
            n[b] += 1;
        }
        let cells: Vec<String> = (0..BINS)
            .map(|b| {
                if n[b] == 0 {
                    "-".into()
                } else {
                    format!("{:.0}", sum[b] / n[b] as f64)
                }
            })
            .collect();
        out.push_str(&format!(
            "    {:<16} {:>4} samples over {:>9}  mean {:.1}  max {:.0}\n      [{}]\n",
            name,
            pts.len(),
            fmt_t(t1 - t0),
            mean,
            max,
            cells.join(" ")
        ));
    }
    out
}

pub(crate) fn fmt_t(t: f64) -> String {
    if t >= 1.0 {
        format!("{t:.3}s")
    } else if t >= 1e-3 {
        format!("{:.3}ms", t * 1e3)
    } else {
        format!("{:.1}us", t * 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(task: &str, kind: EventKind, t: f64, who: &str) -> TaskEvent {
        TaskEvent { task: task.into(), kind, t, who: who.into(), seq: 0, session: String::new() }
    }

    #[test]
    fn components_add_up() {
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Ready, 0.0, ""),
            ev("a", EventKind::Launched, 0.5, "w0"), // 0.5 queue
            ev("a", EventKind::Started, 0.7, "w0"),  // 0.2 launch
            ev("a", EventKind::Finished, 1.7, "w0"), // 1.0 compute
            ev("b", EventKind::Created, 0.0, ""),
            ev("b", EventKind::Ready, 0.0, ""),
            ev("b", EventKind::Launched, 0.0, "w1"),
            ev("b", EventKind::Started, 0.0, "w1"),
            ev("b", EventKind::Finished, 1.0, "w1"), // 1.0 compute, 0.7 drain
        ];
        let r = TraceReport::from_events(&evs);
        assert_eq!(r.tasks, 2);
        assert_eq!(r.workers, 2);
        assert!((r.queue_wait_s - 0.5).abs() < 1e-12);
        assert!((r.launch_s - 0.2).abs() < 1e-12);
        assert!((r.compute_s - 2.0).abs() < 1e-12);
        assert!((r.drain_s - 0.7).abs() < 1e-12, "{}", r.drain_s);
        assert!((r.makespan_s - 1.7).abs() < 1e-12);
        // utilization = 2.0 / (2 * 1.7)
        assert!((r.utilization() - 2.0 / 3.4).abs() < 1e-12);
        let txt = r.render("test");
        assert!(txt.contains("compute"));
        assert!(txt.contains("utilization"));
    }

    #[test]
    fn server_only_trace_still_reports_compute() {
        // no Started events: Launched→terminal counts as compute
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Launched, 0.1, "w0"),
            ev("a", EventKind::Finished, 1.1, "w0"),
        ];
        let r = TraceReport::from_events(&evs);
        assert!((r.compute_s - 1.0).abs() < 1e-12);
        assert!((r.launch_s - 0.0).abs() < 1e-12);
    }

    #[test]
    fn requeue_resets_attempt_intervals() {
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Ready, 0.0, ""),
            ev("a", EventKind::Launched, 0.1, "w0"),
            ev("a", EventKind::Requeued, 5.0, "w0"),
            ev("a", EventKind::Ready, 5.0, ""),
            ev("a", EventKind::Launched, 5.1, "w1"),
            ev("a", EventKind::Started, 5.2, "w1"),
            ev("a", EventKind::Finished, 6.2, "w1"),
        ];
        let r = TraceReport::from_events(&evs);
        // compute must come from the SECOND attempt only (1.0s), not 6.1
        assert!((r.compute_s - 1.0).abs() < 1e-12, "{}", r.compute_s);
        // queue wait: 0.1 (first) + 0.1 (second)
        assert!((r.queue_wait_s - 0.2).abs() < 1e-9);
    }

    #[test]
    fn per_worker_rows_count_tasks_busy_time_and_parks() {
        let evs = vec![
            // w0: two tasks with a 0.3s gap between them (one park), one
            // back-to-back task 10µs later (no park)
            ev("a", EventKind::Launched, 0.0, "w0"),
            ev("a", EventKind::Started, 0.0, "w0"),
            ev("a", EventKind::Finished, 0.2, "w0"),
            ev("b", EventKind::Launched, 0.5, "w0"),
            ev("b", EventKind::Started, 0.5, "w0"),
            ev("b", EventKind::Finished, 0.7, "w0"),
            ev("c", EventKind::Started, 0.70001, "w0"),
            ev("c", EventKind::Finished, 0.9, "w0"),
            // w1: one task
            ev("d", EventKind::Started, 0.1, "w1"),
            ev("d", EventKind::Finished, 0.4, "w1"),
        ];
        let r = TraceReport::from_events(&evs);
        assert_eq!(r.per_worker.len(), 2);
        let w0 = &r.per_worker[0];
        assert_eq!((w0.who.as_str(), w0.tasks, w0.parks), ("w0", 3, 1));
        assert!((w0.busy_s - (0.2 + 0.2 + 0.19999)).abs() < 1e-9, "{}", w0.busy_s);
        let w1 = &r.per_worker[1];
        assert_eq!((w1.who.as_str(), w1.tasks, w1.parks), ("w1", 1, 0));
        let txt = r.render("test");
        assert!(txt.contains("worker"), "{txt}");
        assert!(txt.contains("parks"), "{txt}");
    }

    #[test]
    fn metric_summary_bins_by_time() {
        let samples: Vec<MetricSample> = (0..20)
            .map(|i| MetricSample {
                name: "queue_depth".into(),
                t: i as f64 * 0.1,
                value: if i < 10 { 10.0 } else { 0.0 },
            })
            .collect();
        let txt = render_metrics(&samples);
        assert!(txt.contains("queue_depth"), "{txt}");
        assert!(txt.contains("20 samples"), "{txt}");
        // first bin all-high, last bin all-zero
        assert!(txt.contains("[10 "), "{txt}");
        assert!(txt.trim_end().ends_with("0]"), "{txt}");
        assert_eq!(render_metrics(&[]), "");
    }

    #[test]
    fn empty_trace_renders() {
        let r = TraceReport::from_events(&[]);
        assert_eq!(r.tasks, 0);
        assert_eq!(r.utilization(), 0.0);
        assert!(r.render("x").contains("0 tasks"));
    }
}
