//! Unified trace/telemetry: per-task lifecycle events across every
//! execution layer.
//!
//! The paper's quantitative story — the Fig 5 per-component breakdowns,
//! the Fig 4 efficiency-vs-granularity curves, the METG characterization
//! itself — is built from per-task timing, yet a scheduler run normally
//! surfaces only end-of-run counters.  This module is the missing
//! substrate: a [`Tracer`] handle threaded through all three coordinators
//! (pmake's push loop, the dwork server/state machine and its workers,
//! the mpi-list rank loops) *and* through the discrete-event simulator
//! models, so real runs and simulated runs emit one identical event
//! schema.  On top of the stream sit:
//!
//! * [`report`] — a Fig-5-shaped per-component time breakdown (queue
//!   wait / launch / compute / drain) plus a utilization summary;
//! * [`sim`] — graph-aware DES models of the three back-ends (virtual
//!   time, Table-4 cost model) emitting the same events;
//! * [`compare`] — selector-predicted vs DES-simulated vs measured
//!   makespan per back-end, with relative errors — the cross-validation
//!   loop the adaptive selector's cost model rests on;
//! * [`samples`] — per-task phase samples (queue wait, launch window,
//!   compute duration, launch gaps) plus workload reconstruction, the
//!   extraction layer [`crate::calibrate`] fits the cost model against.
//!
//! Design constraints, in order: the *disabled* tracer must be a true
//! no-op (no allocation, a single branch — tracing rides inside the
//! coordinators' hot paths, including the dwork server loop whose
//! dispatch rate bounds dwork's METG); the enabled path must be
//! lock-cheap (one short mutex hold per event); and the on-disk format
//! must be dumb enough to survive (JSON Lines, one event per line).

pub mod compare;
pub mod profile;
pub mod report;
pub mod samples;
pub mod sim;

use std::io::{Read as _, Write as _};
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context as _, Result};

pub use compare::{compare_backends, render_comparison, BackendComparison};
pub use profile::{chrome_trace, PathLink, TraceProfile};
pub use report::{render_metrics, TraceReport, WorkerRow};
pub use samples::{graph_from_trace, PhaseSamples};
pub use sim::simulate_workflow;

/// Schema marker written in the JSONL header line; bump on any change to
/// the event encoding *or* the event-kind vocabulary, so an old reader
/// fails cleanly at the header ("unsupported trace schema") instead of
/// mid-stream on an event kind it has never heard of.  Real and
/// simulated traces share it byte-for-byte.  `/2` added the
/// worker-scoped `connected` kind; `/3` added interleaved metric-sample
/// lines (`{"metric":…,"t":…,"value":…}`, e.g. periodic queue-depth
/// folds from the live [`crate::metrics`] registry); `/4` added the
/// per-writer monotone `seq` field, so merged multi-writer traces sort
/// stably at equal timestamps (readers default a missing `seq` to 0);
/// `/5` added the optional `session` field tagging events with the hub
/// session that owns the task (omitted — not emitted — for the anonymous
/// session, so session-free traces stay byte-identical to `/4` bodies;
/// readers default a missing `session` to empty); readers accept every
/// schema listed in [`ACCEPTED_SCHEMAS`].
pub const SCHEMA: &str = "threesched-trace/5";

/// Schemas [`parse_jsonl`] accepts: the current one plus every older
/// version whose events are a subset of the current vocabulary.
pub const ACCEPTED_SCHEMAS: [&str; 5] = [
    "threesched-trace/1",
    "threesched-trace/2",
    "threesched-trace/3",
    "threesched-trace/4",
    SCHEMA,
];

/// One step of a task's lifecycle.  The same vocabulary covers all three
/// coordinators and the DES models:
///
/// * `Connected` — a *worker* attached to the scheduler (`who` is the
///   worker, `task` is empty): not part of any task's lifecycle, but the
///   raw material for observing connection storms and startup costs,
///   which per-task events cannot see.  Validators and counters ignore
///   it;
/// * `Created` — the scheduler learned of the task;
/// * `Ready` — every dependency is satisfied, the task is eligible;
/// * `Launched` — the scheduler handed it to an executor (pmake spawned
///   the job step, dwork served the Steal, mpi-list's rank picked it up);
/// * `Started` — the payload itself began executing;
/// * `Finished` / `Failed` — terminal: the task succeeded, or it failed
///   (attempted and errored) / was abandoned (a dependency failed first —
///   distinguishable because such tasks were never `Launched`);
/// * `Requeued` — the task went back to the pool (worker death, Transfer)
///   and its `Ready`/`Launched`/`Started` cycle may repeat.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EventKind {
    Connected,
    Created,
    Ready,
    Launched,
    Started,
    Finished,
    Failed,
    Requeued,
}

impl EventKind {
    pub const ALL: [EventKind; 8] = [
        EventKind::Connected,
        EventKind::Created,
        EventKind::Ready,
        EventKind::Launched,
        EventKind::Started,
        EventKind::Finished,
        EventKind::Failed,
        EventKind::Requeued,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Connected => "connected",
            EventKind::Created => "created",
            EventKind::Ready => "ready",
            EventKind::Launched => "launched",
            EventKind::Started => "started",
            EventKind::Finished => "finished",
            EventKind::Failed => "failed",
            EventKind::Requeued => "requeued",
        }
    }

    pub fn from_name(s: &str) -> Option<EventKind> {
        EventKind::ALL.into_iter().find(|k| k.name() == s)
    }

    /// Terminal events end a task's lifecycle: exactly one per task in a
    /// well-formed trace.
    pub fn is_terminal(&self) -> bool {
        matches!(self, EventKind::Finished | EventKind::Failed)
    }
}

/// One trace record.  `t` is seconds since the trace epoch — wall time
/// for real runs, virtual time for DES runs; the schema does not care.
#[derive(Clone, Debug, PartialEq)]
pub struct TaskEvent {
    pub task: String,
    pub kind: EventKind,
    pub t: f64,
    /// executing party when known ("w0", "rank3", …); empty for
    /// scheduler-side bookkeeping events
    pub who: String,
    /// per-writer monotone sequence number (schema `/4`): breaks ties
    /// between equal timestamps when merging multi-writer traces.  0 for
    /// events loaded from pre-`/4` traces.
    pub seq: u64,
    /// hub session that owns the task (schema `/5`); empty for the
    /// anonymous session and for events loaded from pre-`/5` traces.
    /// Task names are only unique *within* a session — readers that
    /// group by task must key on `(session, task)`.
    pub session: String,
}

/// One scalar metric sample folded into the trace stream (schema `/3`):
/// a named value at an epoch-relative time — the periodic queue-depth /
/// inflight snapshots a metrics-enabled run interleaves with its task
/// events, so `trace report` can plot hub load over time.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricSample {
    pub name: String,
    pub t: f64,
    pub value: f64,
}

// ------------------------------------------------------------------ tracer

enum Sink {
    Memory { events: Vec<TaskEvent>, metrics: Vec<MetricSample> },
    /// streamed JSONL (long-lived hubs must not grow a Vec forever);
    /// line-buffered so a killed process loses at most one event
    File(std::io::BufWriter<std::fs::File>),
}

struct Inner {
    epoch: Instant,
    /// next `seq` to stamp: per-writer monotone, shared across clones
    /// (one writer = one sink), so a merged multi-writer trace sorts
    /// stably by `(t, seq)` within each writer's stream
    seq: std::sync::atomic::AtomicU64,
    sink: Mutex<Sink>,
}

/// Cheap cloneable event recorder.  `Tracer::default()` is disabled:
/// recording through it is a single `Option` branch with no allocation,
/// so every coordinator can take a `&Tracer` unconditionally.  Clones
/// share one sink and one epoch, which is what lets the dwork server
/// thread and its worker threads interleave into a single stream.
#[derive(Clone, Default)]
pub struct Tracer(Option<Arc<Inner>>);

impl std::fmt::Debug for Tracer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(if self.enabled() { "Tracer(enabled)" } else { "Tracer(disabled)" })
    }
}

impl Tracer {
    /// The no-op tracer (same as `Tracer::default()`).
    pub fn disabled() -> Tracer {
        Tracer(None)
    }

    /// Collect events in memory; retrieve with [`Tracer::drain`].
    pub fn memory() -> Tracer {
        Tracer(Some(Arc::new(Inner {
            epoch: Instant::now(),
            seq: std::sync::atomic::AtomicU64::new(0),
            sink: Mutex::new(Sink::Memory { events: Vec::new(), metrics: Vec::new() }),
        })))
    }

    /// Stream events to `path` as JSONL (header line first).  Each event
    /// is flushed as written — tracing a long-lived hub must survive the
    /// operator's ctrl-c.
    pub fn to_file(path: &Path, source: &str) -> Result<Tracer> {
        if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
            std::fs::create_dir_all(parent).with_context(|| format!("creating {parent:?}"))?;
        }
        let f = std::fs::File::create(path).with_context(|| format!("creating {path:?}"))?;
        let mut w = std::io::BufWriter::new(f);
        writeln!(w, "{}", header_line(source)).with_context(|| format!("writing {path:?}"))?;
        w.flush()?;
        Ok(Tracer(Some(Arc::new(Inner {
            epoch: Instant::now(),
            seq: std::sync::atomic::AtomicU64::new(0),
            sink: Mutex::new(Sink::File(w)),
        }))))
    }

    pub fn enabled(&self) -> bool {
        self.0.is_some()
    }

    /// Seconds since the trace epoch (0.0 when disabled).
    pub fn now(&self) -> f64 {
        match &self.0 {
            Some(inner) => inner.epoch.elapsed().as_secs_f64(),
            None => 0.0,
        }
    }

    /// Record an event at the current wall clock.  Disabled: one branch,
    /// no allocation, no time read.
    #[inline]
    pub fn record(&self, task: &str, kind: EventKind, who: &str) {
        self.record_in_session("", task, kind, who);
    }

    /// [`Tracer::record`] with a session tag (schema `/5`): events from a
    /// named hub session carry the session so multi-campaign traces keep
    /// same-named tasks from different sessions apart.  An empty session
    /// is the anonymous session (what [`Tracer::record`] stamps).
    #[inline]
    pub fn record_in_session(&self, session: &str, task: &str, kind: EventKind, who: &str) {
        if let Some(inner) = &self.0 {
            let t = inner.epoch.elapsed().as_secs_f64();
            let seq = inner.seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            Self::push(
                inner,
                TaskEvent {
                    task: task.to_string(),
                    kind,
                    t,
                    who: who.to_string(),
                    seq,
                    session: session.to_string(),
                },
            );
        }
    }

    /// Record an event at an explicit epoch-relative time — the DES path
    /// (virtual timestamps) and post-hoc splits of a measured interval.
    #[inline]
    pub fn record_at(&self, t: f64, task: &str, kind: EventKind, who: &str) {
        self.record_at_in_session(t, "", task, kind, who);
    }

    /// [`Tracer::record_at`] with a session tag (see
    /// [`Tracer::record_in_session`]).
    #[inline]
    pub fn record_at_in_session(
        &self,
        t: f64,
        session: &str,
        task: &str,
        kind: EventKind,
        who: &str,
    ) {
        if let Some(inner) = &self.0 {
            let seq = inner.seq.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            Self::push(
                inner,
                TaskEvent {
                    task: task.to_string(),
                    kind,
                    t,
                    who: who.to_string(),
                    seq,
                    session: session.to_string(),
                },
            );
        }
    }

    /// Fold one scalar metric sample into the stream at the current wall
    /// clock (schema `/3` metric lines).  Disabled: one branch, no
    /// allocation, no time read — same discipline as [`Tracer::record`].
    #[inline]
    pub fn record_metric(&self, name: &str, value: f64) {
        if let Some(inner) = &self.0 {
            let t = inner.epoch.elapsed().as_secs_f64();
            let sample = MetricSample { name: name.to_string(), t, value };
            let mut sink = inner.sink.lock().expect("trace sink poisoned");
            match &mut *sink {
                Sink::Memory { metrics, .. } => metrics.push(sample),
                Sink::File(w) => {
                    let _ = writeln!(w, "{}", metric_line(&sample));
                    let _ = w.flush();
                }
            }
        }
    }

    fn push(inner: &Inner, ev: TaskEvent) {
        let mut sink = inner.sink.lock().expect("trace sink poisoned");
        match &mut *sink {
            Sink::Memory { events, .. } => events.push(ev),
            Sink::File(w) => {
                // best-effort: a full disk must not take the campaign down
                let _ = writeln!(w, "{}", event_line(&ev));
                let _ = w.flush();
            }
        }
    }

    /// Take every event collected so far (memory sinks; a file sink just
    /// flushes and yields nothing — its events are already on disk).
    pub fn drain(&self) -> Vec<TaskEvent> {
        match &self.0 {
            None => Vec::new(),
            Some(inner) => {
                let mut sink = inner.sink.lock().expect("trace sink poisoned");
                match &mut *sink {
                    Sink::Memory { events, .. } => std::mem::take(events),
                    Sink::File(w) => {
                        let _ = w.flush();
                        Vec::new()
                    }
                }
            }
        }
    }

    /// Take every metric sample collected so far (memory sinks only; a
    /// file sink's samples are already on disk).
    pub fn drain_metrics(&self) -> Vec<MetricSample> {
        match &self.0 {
            None => Vec::new(),
            Some(inner) => {
                let mut sink = inner.sink.lock().expect("trace sink poisoned");
                match &mut *sink {
                    Sink::Memory { metrics, .. } => std::mem::take(metrics),
                    Sink::File(w) => {
                        let _ = w.flush();
                        Vec::new()
                    }
                }
            }
        }
    }
}

// ------------------------------------------------------------------- JSONL

pub(crate) fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_unescape(s: &str) -> Result<String> {
    let mut out = String::with_capacity(s.len());
    let mut chars = s.chars();
    while let Some(c) = chars.next() {
        if c != '\\' {
            out.push(c);
            continue;
        }
        match chars.next() {
            Some('"') => out.push('"'),
            Some('\\') => out.push('\\'),
            Some('/') => out.push('/'),
            Some('n') => out.push('\n'),
            Some('r') => out.push('\r'),
            Some('t') => out.push('\t'),
            Some('u') => {
                let hex: String = chars.by_ref().take(4).collect();
                let code = u32::from_str_radix(&hex, 16)
                    .map_err(|_| anyhow::anyhow!("bad \\u escape {hex:?}"))?;
                out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
            }
            other => bail!("bad escape \\{other:?}"),
        }
    }
    Ok(out)
}

/// Extract the raw (still-escaped) string value of `"key":"…"` from a
/// flat one-line JSON object.  Scans for the key pattern outside string
/// context the cheap way: our writer always emits `"key":"` verbatim and
/// escapes embedded quotes, so the first unescaped `"` ends the value.
fn json_str_field(line: &str, key: &str) -> Option<String> {
    let pat = format!("\"{key}\":\"");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let mut end = 0;
    let bytes = rest.as_bytes();
    while end < bytes.len() {
        match bytes[end] {
            b'\\' => end += 2,
            b'"' => return json_unescape(&rest[..end]).ok(),
            _ => end += 1,
        }
    }
    None
}

fn json_num_field(line: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\":");
    let start = line.find(&pat)? + pat.len();
    let rest = &line[start..];
    let end = rest
        .find(|c: char| !(c.is_ascii_digit() || "+-.eE".contains(c)))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

fn header_line(source: &str) -> String {
    format!("{{\"schema\":\"{SCHEMA}\",\"source\":\"{}\"}}", json_escape(source))
}

/// One event as its trace-JSONL line (no trailing newline) — the same
/// encoding [`to_jsonl`] writes, exposed so live consumers (`dhub tail
/// --json`) emit stream-compatible records.
pub fn event_line(ev: &TaskEvent) -> String {
    // the session field is omitted (not emitted empty) for the anonymous
    // session, so session-free trace bodies stay byte-identical to /4
    let session = if ev.session.is_empty() {
        String::new()
    } else {
        format!(",\"session\":\"{}\"", json_escape(&ev.session))
    };
    format!(
        "{{\"task\":\"{}\",\"kind\":\"{}\",\"t\":{:.9},\"who\":\"{}\",\"seq\":{}{}}}",
        json_escape(&ev.task),
        ev.kind.name(),
        ev.t,
        json_escape(&ev.who),
        ev.seq,
        session
    )
}

fn metric_line(s: &MetricSample) -> String {
    format!(
        "{{\"metric\":\"{}\",\"t\":{:.9},\"value\":{}}}",
        json_escape(&s.name),
        s.t,
        s.value
    )
}

/// Serialize a trace (header + events) to a JSONL string.  `source`
/// names the producer: a coordinator (`"pmake"`, `"dwork"`,
/// `"mpi-list"`) or a DES run (`"des:pmake"`, …).
pub fn to_jsonl(source: &str, events: &[TaskEvent]) -> String {
    to_jsonl_full(source, events, &[])
}

/// [`to_jsonl`] with interleaved metric samples appended after the
/// events (readers order by `t`, not line position).
pub fn to_jsonl_full(source: &str, events: &[TaskEvent], metrics: &[MetricSample]) -> String {
    let mut out = header_line(source);
    out.push('\n');
    for ev in events {
        out.push_str(&event_line(ev));
        out.push('\n');
    }
    for s in metrics {
        out.push_str(&metric_line(s));
        out.push('\n');
    }
    out
}

/// Write a trace file in one shot (the post-run path of
/// `workflow run --trace`; streaming sinks write themselves).
pub fn write_trace(path: &Path, source: &str, events: &[TaskEvent]) -> Result<()> {
    write_trace_full(path, source, events, &[])
}

/// [`write_trace`] carrying metric samples too.
pub fn write_trace_full(
    path: &Path,
    source: &str,
    events: &[TaskEvent],
    metrics: &[MetricSample],
) -> Result<()> {
    if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
        std::fs::create_dir_all(parent).with_context(|| format!("creating {parent:?}"))?;
    }
    std::fs::write(path, to_jsonl_full(source, events, metrics))
        .with_context(|| format!("writing {path:?}"))
}

/// Parse a JSONL trace: returns (source, events).  Metric-sample lines
/// are tolerated and skipped — use [`parse_jsonl_full`] to keep them.
/// Tolerates a missing header (source defaults to `"unknown"`) so
/// hand-concatenated traces still load; unknown event kinds are an
/// error, not silently dropped.
pub fn parse_jsonl(text: &str) -> Result<(String, Vec<TaskEvent>)> {
    let (source, events, _) = parse_jsonl_full(text)?;
    Ok((source, events))
}

/// Parse a JSONL trace keeping the schema-`/3` metric samples:
/// returns (source, events, metric samples).
///
/// A truncated *final* line — the file does not end in a newline, so the
/// writer died (or is still writing) mid-record — is skipped with a
/// warning rather than erroring: a killed worker or a live `--follow`
/// race must not make the rest of the trace unreadable.  A malformed
/// line anywhere else is still an error.
pub fn parse_jsonl_full(text: &str) -> Result<(String, Vec<TaskEvent>, Vec<MetricSample>)> {
    let mut source = String::from("unknown");
    let mut events = Vec::new();
    let mut metrics = Vec::new();
    let lines: Vec<&str> = text.lines().collect();
    let unterminated_last = !text.is_empty() && !text.ends_with('\n');
    for (n, raw) in lines.iter().enumerate() {
        let line = raw.trim();
        let truncatable = unterminated_last && n + 1 == lines.len();
        match parse_line(line, &mut source, &mut events, &mut metrics) {
            Ok(()) => {}
            Err(e) if truncatable => {
                eprintln!(
                    "warning: trace line {} is a truncated partial record ({e}); skipping it",
                    n + 1
                );
            }
            Err(e) => return Err(e.context(format!("line {}", n + 1))),
        }
    }
    Ok((source, events, metrics))
}

/// Parse one trace line into whichever of `source`/`events`/`metrics` it
/// belongs to.  Blank lines are a no-op.
fn parse_line(
    line: &str,
    source: &mut String,
    events: &mut Vec<TaskEvent>,
    metrics: &mut Vec<MetricSample>,
) -> Result<()> {
    if line.is_empty() {
        return Ok(());
    }
    if line.contains("\"schema\":") {
        let schema = json_str_field(line, "schema").unwrap_or_default();
        if !ACCEPTED_SCHEMAS.contains(&schema.as_str()) {
            bail!("unsupported trace schema {schema:?} (want {SCHEMA})");
        }
        if let Some(s) = json_str_field(line, "source") {
            *source = s;
        }
        return Ok(());
    }
    // metric lines have no "task"/"kind": route them first
    if let Some(name) = json_str_field(line, "metric") {
        let t = json_num_field(line, "t").context("metric missing \"t\"")?;
        let value = json_num_field(line, "value").context("metric missing \"value\"")?;
        metrics.push(MetricSample { name, t, value });
        return Ok(());
    }
    let task = json_str_field(line, "task").context("missing \"task\"")?;
    let kind_name = json_str_field(line, "kind").context("missing \"kind\"")?;
    let kind = EventKind::from_name(&kind_name)
        .with_context(|| format!("unknown event kind {kind_name:?}"))?;
    let t = json_num_field(line, "t").context("missing \"t\"")?;
    let who = json_str_field(line, "who").unwrap_or_default();
    // pre-/4 traces have no seq: default 0 (stable sorts fall back to
    // stream order for those)
    let seq = json_num_field(line, "seq").map(|s| s.max(0.0) as u64).unwrap_or(0);
    // pre-/5 traces (and anonymous-session events) have no session
    let session = json_str_field(line, "session").unwrap_or_default();
    events.push(TaskEvent { task, kind, t, who, seq, session });
    Ok(())
}

/// Sort a (possibly merged, multi-writer) event stream into a stable
/// global order: by time, then per-writer `seq`, then writer — so equal
/// timestamps from one writer keep their emission order and ties across
/// writers break deterministically.
pub fn sort_events(events: &mut [TaskEvent]) {
    events.sort_by(|a, b| {
        a.t.total_cmp(&b.t).then_with(|| a.seq.cmp(&b.seq)).then_with(|| a.who.cmp(&b.who))
    });
}

/// Load a trace file written by [`write_trace`] or a streaming sink.
pub fn read_trace(path: &Path) -> Result<(String, Vec<TaskEvent>)> {
    let (source, events, _) = read_trace_full(path)?;
    Ok((source, events))
}

/// [`read_trace`] keeping the metric samples.
pub fn read_trace_full(path: &Path) -> Result<(String, Vec<TaskEvent>, Vec<MetricSample>)> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path:?}"))?;
    let mut text = String::new();
    std::io::BufReader::new(f)
        .read_to_string(&mut text)
        .with_context(|| format!("reading {path:?}"))?;
    parse_jsonl_full(&text)
}

// ------------------------------------------------------- wellformedness

/// Lifecycle rank used by the validator: events of one task must appear
/// in strictly increasing rank order between requeues.
fn rank(kind: EventKind) -> u8 {
    match kind {
        EventKind::Created => 0,
        EventKind::Ready => 1,
        EventKind::Launched => 2,
        EventKind::Started => 3,
        EventKind::Finished | EventKind::Failed => 4,
        EventKind::Requeued => u8::MAX,  // handled specially
        EventKind::Connected => u8::MAX, // worker-scoped: filtered before ranking
    }
}

/// Check trace wellformedness:
///
/// * every task has exactly one terminal event, and it is the task's
///   last event;
/// * per-task timestamps are monotone non-decreasing;
/// * the lifecycle order holds: `Created ≤ Ready ≤ Launched ≤ Started ≤
///   Finished/Failed`, with each stage at most once per attempt;
/// * `Requeued` only after `Launched`/`Started`, resetting the attempt
///   (a fresh `Ready → Launched → Started` cycle may follow).
///
/// `Connected` events are worker-scoped, not task-scoped: they are
/// ignored here (a worker may attach any number of times and never run
/// a task).
pub fn validate(events: &[TaskEvent]) -> Result<()> {
    use std::collections::HashMap;
    // group by (session, task), preserving stream order — task names are
    // only unique within a session (schema /5)
    let mut by_task: HashMap<(&str, &str), Vec<&TaskEvent>> = HashMap::new();
    let mut order: Vec<(&str, &str)> = Vec::new();
    for ev in events {
        if ev.kind == EventKind::Connected {
            continue;
        }
        let key = (ev.session.as_str(), ev.task.as_str());
        let slot = by_task.entry(key).or_default();
        if slot.is_empty() {
            order.push(key);
        }
        slot.push(ev);
    }
    for key @ (_, task) in order {
        let evs = &by_task[&key];
        let mut last_t = f64::NEG_INFINITY;
        let mut stage = -1i16; // highest rank seen in the current attempt
        let mut terminals = 0usize;
        for (i, ev) in evs.iter().enumerate() {
            if ev.t < last_t {
                bail!(
                    "task {task:?}: timestamps not monotone ({} at {:.9} after {:.9})",
                    ev.kind.name(),
                    ev.t,
                    last_t
                );
            }
            last_t = ev.t;
            if ev.kind == EventKind::Requeued {
                if stage < rank(EventKind::Launched) as i16 {
                    bail!("task {task:?}: requeued before ever being launched");
                }
                stage = rank(EventKind::Ready) as i16 - 1;
                continue;
            }
            let r = rank(ev.kind) as i16;
            if r <= stage {
                bail!(
                    "task {task:?}: {} out of lifecycle order (or repeated)",
                    ev.kind.name()
                );
            }
            stage = r;
            if ev.kind.is_terminal() {
                terminals += 1;
                if i + 1 != evs.len() {
                    bail!("task {task:?}: events after terminal {}", ev.kind.name());
                }
            }
        }
        if terminals != 1 {
            bail!("task {task:?}: {terminals} terminal events (want exactly 1)");
        }
    }
    Ok(())
}

// ------------------------------------------------------------- summaries

/// Counters derived purely from a trace — comparable against the
/// coordinator's own `RunSummary` (the equivalence the tests pin).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TraceCounts {
    /// tasks with a Finished terminal
    pub completed: usize,
    /// tasks with a Failed terminal that were attempted (Launched/Started)
    pub failed: usize,
    /// tasks with a Failed terminal that never launched — dependents of a
    /// failure, abandoned without an attempt
    pub skipped: usize,
}

impl TraceCounts {
    /// attempted = completed + failed (the `tasks_run` analogue)
    pub fn attempted(&self) -> usize {
        self.completed + self.failed
    }
}

/// Derive [`TraceCounts`] + makespan from an event stream.
pub fn counts(events: &[TaskEvent]) -> TraceCounts {
    use std::collections::HashMap;
    // keyed by (session, task): multi-campaign traces may reuse names
    let mut attempted: HashMap<(&str, &str), bool> = HashMap::new();
    let mut out = TraceCounts::default();
    for ev in events {
        let key = (ev.session.as_str(), ev.task.as_str());
        match ev.kind {
            // worker attach: not a task at all
            EventKind::Connected => {}
            EventKind::Launched | EventKind::Started => {
                attempted.insert(key, true);
            }
            EventKind::Created | EventKind::Ready | EventKind::Requeued => {
                attempted.entry(key).or_insert(false);
            }
            EventKind::Finished => out.completed += 1,
            EventKind::Failed => {
                if attempted.get(&key).copied().unwrap_or(false) {
                    out.failed += 1;
                } else {
                    out.skipped += 1;
                }
            }
        }
    }
    out
}

/// Trace makespan: latest event time (the epoch is the run start).
pub fn makespan(events: &[TaskEvent]) -> f64 {
    events.iter().map(|e| e.t).fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(task: &str, kind: EventKind, t: f64, who: &str) -> TaskEvent {
        TaskEvent { task: task.into(), kind, t, who: who.into(), seq: 0, session: String::new() }
    }

    fn sev(session: &str, task: &str, kind: EventKind, t: f64, who: &str) -> TaskEvent {
        TaskEvent { session: session.into(), ..ev(task, kind, t, who) }
    }

    fn lifecycle(task: &str, t0: f64, ok: bool) -> Vec<TaskEvent> {
        let terminal = if ok { EventKind::Finished } else { EventKind::Failed };
        vec![
            ev(task, EventKind::Created, t0, ""),
            ev(task, EventKind::Ready, t0 + 0.1, ""),
            ev(task, EventKind::Launched, t0 + 0.2, "w0"),
            ev(task, EventKind::Started, t0 + 0.3, "w0"),
            ev(task, terminal, t0 + 0.9, "w0"),
        ]
    }

    #[test]
    fn disabled_tracer_is_inert() {
        let t = Tracer::disabled();
        assert!(!t.enabled());
        t.record("x", EventKind::Created, "");
        t.record_at(1.0, "x", EventKind::Finished, "");
        assert!(t.drain().is_empty());
        assert_eq!(t.now(), 0.0);
    }

    #[test]
    fn memory_tracer_collects_in_order() {
        let t = Tracer::memory();
        t.record("a", EventKind::Created, "");
        t.record("a", EventKind::Started, "w1");
        let evs = t.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[0].kind, EventKind::Created);
        assert!(evs[0].t <= evs[1].t);
        assert_eq!(evs[1].who, "w1");
        assert!(t.drain().is_empty(), "drain takes");
    }

    #[test]
    fn clones_share_one_sink_and_epoch() {
        let t = Tracer::memory();
        let t2 = t.clone();
        t.record("a", EventKind::Created, "");
        t2.record("a", EventKind::Finished, "");
        assert_eq!(t.drain().len(), 2);
    }

    #[test]
    fn jsonl_roundtrip() {
        let events = vec![
            ev("gen", EventKind::Created, 0.0, ""),
            ev("gen", EventKind::Finished, 1.25e-3, "w0"),
            ev("na\"me\\n", EventKind::Failed, 2.0, "rank\t7"),
        ];
        let text = to_jsonl("pmake", &events);
        let (source, parsed) = parse_jsonl(&text).unwrap();
        assert_eq!(source, "pmake");
        assert_eq!(parsed, events);
    }

    #[test]
    fn file_sink_streams_valid_jsonl() {
        let path = std::env::temp_dir()
            .join(format!("threesched-trace-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let t = Tracer::to_file(&path, "dwork").unwrap();
            t.record("a", EventKind::Created, "");
            t.record("a", EventKind::Launched, "w0");
            assert!(t.drain().is_empty(), "file sink holds nothing in memory");
        }
        let (source, evs) = read_trace(&path).unwrap();
        assert_eq!(source, "dwork");
        assert_eq!(evs.len(), 2);
        assert_eq!(evs[1].kind, EventKind::Launched);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unknown_kind_rejected_not_dropped() {
        let text = format!(
            "{}\n{{\"task\":\"a\",\"kind\":\"warped\",\"t\":0.0,\"who\":\"\"}}\n",
            header_line("x")
        );
        assert!(parse_jsonl(&text).is_err());
    }

    #[test]
    fn wrong_schema_rejected() {
        assert!(parse_jsonl("{\"schema\":\"threesched-trace/999\",\"source\":\"x\"}\n").is_err());
    }

    #[test]
    fn older_schema_versions_still_load() {
        // /1 traces (pre-Connected vocabulary) are a strict subset of the
        // current schema: readers must keep accepting them
        let text = "{\"schema\":\"threesched-trace/1\",\"source\":\"dwork\"}\n\
                    {\"task\":\"a\",\"kind\":\"created\",\"t\":0.000000000,\"who\":\"\"}\n";
        let (source, evs) = parse_jsonl(text).unwrap();
        assert_eq!(source, "dwork");
        assert_eq!(evs.len(), 1);
    }

    #[test]
    fn validate_accepts_full_lifecycle() {
        let mut evs = lifecycle("a", 0.0, true);
        evs.extend(lifecycle("b", 0.5, false));
        validate(&evs).unwrap();
    }

    #[test]
    fn validate_accepts_requeue_cycle() {
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Ready, 0.1, ""),
            ev("a", EventKind::Launched, 0.2, "w0"),
            ev("a", EventKind::Requeued, 0.3, "w0"),
            ev("a", EventKind::Ready, 0.3, ""),
            ev("a", EventKind::Launched, 0.4, "w1"),
            ev("a", EventKind::Started, 0.5, "w1"),
            ev("a", EventKind::Finished, 0.6, "w1"),
        ];
        validate(&evs).unwrap();
    }

    #[test]
    fn validate_rejects_double_terminal() {
        let mut evs = lifecycle("a", 0.0, true);
        evs.push(ev("a", EventKind::Failed, 2.0, ""));
        assert!(validate(&evs).is_err());
    }

    #[test]
    fn validate_rejects_missing_terminal() {
        let evs = vec![ev("a", EventKind::Created, 0.0, "")];
        assert!(validate(&evs).is_err());
    }

    #[test]
    fn validate_rejects_time_regression() {
        let evs = vec![
            ev("a", EventKind::Created, 1.0, ""),
            ev("a", EventKind::Finished, 0.5, ""),
        ];
        assert!(validate(&evs).is_err());
    }

    #[test]
    fn validate_rejects_out_of_order_lifecycle() {
        let evs = vec![
            ev("a", EventKind::Started, 0.0, "w0"),
            ev("a", EventKind::Launched, 0.1, "w0"),
            ev("a", EventKind::Finished, 0.2, "w0"),
        ];
        assert!(validate(&evs).is_err());
    }

    #[test]
    fn validate_rejects_requeue_before_launch() {
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Requeued, 0.1, ""),
            ev("a", EventKind::Finished, 0.2, ""),
        ];
        assert!(validate(&evs).is_err());
    }

    #[test]
    fn validate_allows_partial_chains() {
        // a server-only trace has no Started; a skipped task has only
        // Created + Failed — both are legal partial views
        let evs = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Launched, 0.1, "w0"),
            ev("a", EventKind::Failed, 0.2, "w0"),
            ev("b", EventKind::Created, 0.0, ""),
            ev("b", EventKind::Failed, 0.2, ""),
        ];
        validate(&evs).unwrap();
    }

    #[test]
    fn connected_events_are_worker_scoped_and_ignored_by_task_checks() {
        // a worker attaches (twice — e.g. a lingering pool rejoining),
        // runs one task; another attaches and never runs anything.  The
        // validator and the counters must not treat the attaches as a
        // task lifecycle.
        let mut evs = vec![ev("", EventKind::Connected, 0.0, "w0")];
        evs.extend(lifecycle("a", 0.1, true));
        evs.push(ev("", EventKind::Connected, 1.5, "w0"));
        evs.push(ev("", EventKind::Connected, 1.6, "w1"));
        validate(&evs).unwrap();
        let c = counts(&evs);
        assert_eq!(c.completed, 1);
        assert_eq!(c.failed, 0);
        assert_eq!(c.skipped, 0);
        // and the schema round-trips them like any other event
        let text = to_jsonl("dwork-worker", &evs);
        let (_, parsed) = parse_jsonl(&text).unwrap();
        assert_eq!(parsed, evs);
        assert_eq!(EventKind::from_name("connected"), Some(EventKind::Connected));
    }

    #[test]
    fn metric_samples_roundtrip_and_stay_out_of_events() {
        let events = vec![
            ev("a", EventKind::Created, 0.0, ""),
            ev("a", EventKind::Finished, 1.0, "w0"),
        ];
        let metrics = vec![
            MetricSample { name: "queue_depth".into(), t: 0.25, value: 3.0 },
            MetricSample { name: "queue_depth".into(), t: 0.75, value: 0.0 },
            MetricSample { name: "tasks_inflight".into(), t: 0.5, value: 1.0 },
        ];
        let text = to_jsonl_full("dwork", &events, &metrics);
        let (source, evs, ms) = parse_jsonl_full(&text).unwrap();
        assert_eq!(source, "dwork");
        assert_eq!(evs, events);
        assert_eq!(ms, metrics);
        // the event-only reader tolerates (and drops) the metric lines
        let (_, evs_only) = parse_jsonl(&text).unwrap();
        assert_eq!(evs_only, events);
        // and the combined stream still validates as a task trace
        validate(&evs).unwrap();
    }

    #[test]
    fn tracer_folds_metric_samples_into_both_sinks() {
        let t = Tracer::memory();
        t.record("a", EventKind::Created, "");
        t.record_metric("queue_depth", 2.0);
        let ms = t.drain_metrics();
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].name, "queue_depth");
        assert_eq!(ms[0].value, 2.0);
        assert_eq!(t.drain().len(), 1, "events unaffected by metric drain");
        // disabled tracer: inert
        let off = Tracer::disabled();
        off.record_metric("queue_depth", 9.0);
        assert!(off.drain_metrics().is_empty());
        // file sink: metric lines land on disk and read back
        let path = std::env::temp_dir()
            .join(format!("threesched-trace-metrics-{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&path);
        {
            let t = Tracer::to_file(&path, "dwork").unwrap();
            t.record("a", EventKind::Created, "");
            t.record_metric("queue_depth", 5.0);
            t.record("a", EventKind::Finished, "w0");
        }
        let (_, evs, ms) = read_trace_full(&path).unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(ms.len(), 1);
        assert_eq!(ms[0].value, 5.0);
        assert!(ms[0].t >= evs[0].t && ms[0].t <= evs[1].t, "sample between the events");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn seq_is_per_writer_monotone_and_roundtrips() {
        let t = Tracer::memory();
        let t2 = t.clone();
        t.record("a", EventKind::Created, "");
        t2.record("a", EventKind::Ready, "");
        t.record("a", EventKind::Finished, "w0");
        let evs = t.drain();
        assert_eq!(evs.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![0, 1, 2]);
        let text = to_jsonl("dwork", &evs);
        assert!(text.contains("\"seq\":2"));
        let (_, parsed) = parse_jsonl(&text).unwrap();
        assert_eq!(parsed, evs);
    }

    #[test]
    fn truncated_final_line_is_skipped_not_fatal() {
        // a killed writer (or a live --follow race) leaves a partial
        // final record with no trailing newline
        let text = format!(
            "{}\n{}\n{{\"task\":\"b\",\"ki",
            header_line("dwork"),
            event_line(&ev("a", EventKind::Created, 0.0, ""))
        );
        let (source, evs) = parse_jsonl(&text).unwrap();
        assert_eq!(source, "dwork");
        assert_eq!(evs.len(), 1, "the complete record survives");
        // but a malformed line in the MIDDLE is still an error
        let bad = format!(
            "{}\n{{\"task\":\"b\",\"ki\n{}\n",
            header_line("dwork"),
            event_line(&ev("a", EventKind::Created, 0.0, ""))
        );
        assert!(parse_jsonl(&bad).is_err());
        // and so is a newline-terminated garbage final line
        let bad2 = format!("{}\n{{\"task\":\"b\",\"ki\n", header_line("dwork"));
        assert!(parse_jsonl(&bad2).is_err());
    }

    #[test]
    fn sort_events_is_stable_across_merged_writers() {
        // two writers emitted at the same timestamp: per-writer seq keeps
        // each stream's emission order; the writer name breaks cross-
        // writer ties deterministically
        let mut evs = vec![
            TaskEvent { seq: 1, ..ev("x", EventKind::Started, 1.0, "w1") },
            TaskEvent { seq: 0, ..ev("x", EventKind::Launched, 1.0, "w1") },
            TaskEvent { seq: 0, ..ev("y", EventKind::Started, 1.0, "w0") },
            TaskEvent { seq: 9, ..ev("z", EventKind::Created, 0.5, "") },
        ];
        sort_events(&mut evs);
        assert_eq!(evs[0].task, "z");
        assert_eq!((evs[1].kind, evs[1].who.as_str()), (EventKind::Started, "w0"));
        assert_eq!((evs[2].kind, evs[2].who.as_str()), (EventKind::Launched, "w1"));
        assert_eq!((evs[3].kind, evs[3].who.as_str()), (EventKind::Started, "w1"));
    }

    #[test]
    fn pre_seq_schema_defaults_seq_to_zero() {
        let text = "{\"schema\":\"threesched-trace/3\",\"source\":\"dwork\"}\n\
                    {\"task\":\"a\",\"kind\":\"created\",\"t\":0.000000000,\"who\":\"\"}\n";
        let (_, evs) = parse_jsonl(text).unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].seq, 0);
    }

    #[test]
    fn session_tag_roundtrips_and_anonymous_lines_stay_identical() {
        // anonymous events must not emit the field at all: the /5 body is
        // byte-identical to the /4 body for session-free traces
        let anon = ev("a", EventKind::Created, 0.0, "");
        assert_eq!(
            event_line(&anon),
            "{\"task\":\"a\",\"kind\":\"created\",\"t\":0.000000000,\"who\":\"\",\"seq\":0}"
        );
        let tagged = sev("alpha", "a", EventKind::Created, 0.0, "");
        assert!(event_line(&tagged).contains("\"session\":\"alpha\""));
        let text = to_jsonl("dwork", &[anon.clone(), tagged.clone()]);
        let (_, parsed) = parse_jsonl(&text).unwrap();
        assert_eq!(parsed, vec![anon, tagged]);
        // pre-/5 traces load with an empty session
        let old = "{\"schema\":\"threesched-trace/4\",\"source\":\"dwork\"}\n\
                   {\"task\":\"a\",\"kind\":\"created\",\"t\":0.000000000,\"who\":\"\",\"seq\":3}\n";
        let (_, evs) = parse_jsonl(old).unwrap();
        assert_eq!(evs[0].session, "");
        assert_eq!(evs[0].seq, 3);
    }

    #[test]
    fn validate_and_counts_key_on_session_and_task() {
        // two sessions reuse the task name "a": each lifecycle is
        // complete on its own but would look like a double terminal if
        // the validator collapsed them by bare name
        let evs = vec![
            sev("alpha", "a", EventKind::Created, 0.0, ""),
            sev("beta", "a", EventKind::Created, 0.05, ""),
            sev("alpha", "a", EventKind::Launched, 0.1, "w0"),
            sev("alpha", "a", EventKind::Finished, 0.2, "w0"),
            sev("beta", "a", EventKind::Launched, 0.3, "w1"),
            sev("beta", "a", EventKind::Failed, 0.4, "w1"),
        ];
        validate(&evs).unwrap();
        let c = counts(&evs);
        assert_eq!((c.completed, c.failed, c.skipped), (1, 1, 0));
        // tracer session verbs stamp the tag
        let t = Tracer::memory();
        t.record_in_session("alpha", "a", EventKind::Created, "");
        t.record_at_in_session(1.0, "alpha", "a", EventKind::Finished, "w0");
        t.record("b", EventKind::Created, "");
        let evs = t.drain();
        assert_eq!(evs[0].session, "alpha");
        assert_eq!(evs[1].session, "alpha");
        assert_eq!(evs[2].session, "");
    }

    #[test]
    fn counts_distinguish_failed_from_skipped() {
        let evs = vec![
            ev("root", EventKind::Created, 0.0, ""),
            ev("root", EventKind::Launched, 0.1, "w0"),
            ev("root", EventKind::Started, 0.2, "w0"),
            ev("root", EventKind::Failed, 0.3, "w0"),
            ev("child", EventKind::Created, 0.0, ""),
            ev("child", EventKind::Failed, 0.3, ""),
            ev("free", EventKind::Created, 0.0, ""),
            ev("free", EventKind::Launched, 0.1, "w1"),
            ev("free", EventKind::Finished, 0.5, "w1"),
        ];
        let c = counts(&evs);
        assert_eq!(c.completed, 1);
        assert_eq!(c.failed, 1);
        assert_eq!(c.skipped, 1);
        assert_eq!(c.attempted(), 2);
        assert!((makespan(&evs) - 0.5).abs() < 1e-12);
    }
}
