//! Minimal CLI argument parser (clap is unavailable offline).
//!
//! Supports subcommands, `--flag value`, `--flag=value`, boolean switches,
//! positional arguments, and generated usage text.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Declarative flag spec.
#[derive(Clone, Debug)]
pub struct Flag {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// Parsed arguments for one (sub)command.
#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(String::as_str)
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name) || self.flags.contains_key(name)
    }
}

/// Parse `argv` against a flag spec.  Unknown flags are an error.
pub fn parse(argv: &[String], spec: &[Flag]) -> Result<Args> {
    let mut out = Args::default();
    for f in spec {
        if let (true, Some(d)) = (f.takes_value, f.default) {
            out.flags.insert(f.name.to_string(), d.to_string());
        }
    }
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(body) = a.strip_prefix("--") {
            let (name, inline) = match body.split_once('=') {
                Some((n, v)) => (n, Some(v.to_string())),
                None => (body, None),
            };
            let Some(f) = spec.iter().find(|f| f.name == name) else {
                bail!("unknown flag --{name}\n{}", usage(spec));
            };
            if f.takes_value {
                let v = match inline {
                    Some(v) => v,
                    None => {
                        i += 1;
                        if i >= argv.len() {
                            bail!("--{name} expects a value");
                        }
                        argv[i].clone()
                    }
                };
                out.flags.insert(name.to_string(), v);
            } else {
                if inline.is_some() {
                    bail!("--{name} is a switch and takes no value");
                }
                out.switches.push(name.to_string());
            }
        } else {
            out.positional.push(a.clone());
        }
        i += 1;
    }
    Ok(out)
}

/// Render usage text for a flag spec.
pub fn usage(spec: &[Flag]) -> String {
    let mut s = String::from("flags:\n");
    for f in spec {
        let val = if f.takes_value { " <value>" } else { "" };
        let def = f
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        s.push_str(&format!("  --{}{val}\t{}{def}\n", f.name, f.help));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<Flag> {
        vec![
            Flag { name: "ranks", help: "rank count", takes_value: true, default: Some("6") },
            Flag { name: "bind", help: "listen addr", takes_value: true, default: None },
            Flag { name: "verbose", help: "chatty", takes_value: false, default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_override() {
        let a = parse(&sv(&[]), &spec()).unwrap();
        assert_eq!(a.get("ranks"), Some("6"));
        let a = parse(&sv(&["--ranks", "864"]), &spec()).unwrap();
        assert_eq!(a.get_usize("ranks", 0).unwrap(), 864);
    }

    #[test]
    fn equals_form() {
        let a = parse(&sv(&["--ranks=60"]), &spec()).unwrap();
        assert_eq!(a.get("ranks"), Some("60"));
    }

    #[test]
    fn switch_and_positional() {
        let a = parse(&sv(&["--verbose", "target1", "target2"]), &spec()).unwrap();
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["target1", "target2"]);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(parse(&sv(&["--nope"]), &spec()).is_err());
    }

    #[test]
    fn missing_value_errors() {
        assert!(parse(&sv(&["--bind"]), &spec()).is_err());
    }

    #[test]
    fn bad_int_errors() {
        let a = parse(&sv(&["--ranks", "abc"]), &spec()).unwrap();
        assert!(a.get_usize("ranks", 0).is_err());
    }
}
