//! Request/reply transport: the ZeroMQ substitute under dwork.
//!
//! dwork's dhub is a single server that serializes task dispatch: every
//! worker sends a request (Steal/Complete/...) and blocks on one reply.
//! Two interchangeable transports provide that pattern:
//!
//! * [`inproc`] — channel-based, zero-syscall; used by tests, benches and
//!   the in-process "MPI job" harness.  Its measured RTT is this stack's
//!   analogue of the paper's 23 µs per-task latency.
//! * [`tcp`] — `std::net` with u32-length framing; used by the real
//!   multi-process deployment (`threesched dwork serve/worker`).
//!
//! Both deliver requests into a single [`Request`] stream so the server
//! event loop is transport-agnostic — exactly the property the paper uses
//! when it swaps direct connections for the rack-leader forwarding tree.

pub mod inproc;
pub mod tcp;

use std::sync::mpsc;

use anyhow::Result;

/// A client connection capable of blocking request/reply.
pub trait ClientConn: Send {
    fn request(&mut self, msg: &[u8]) -> Result<Vec<u8>>;
}

/// One in-flight request as seen by the server event loop.
pub struct Request {
    pub payload: Vec<u8>,
    reply_tx: mpsc::Sender<Vec<u8>>,
}

impl Request {
    pub fn new(payload: Vec<u8>) -> (Self, mpsc::Receiver<Vec<u8>>) {
        let (tx, rx) = mpsc::channel();
        (Request { payload, reply_tx: tx }, rx)
    }

    /// Send the reply; ignores a vanished client (it may have crashed —
    /// the paper's Exit handling covers the task-state side).
    pub fn reply(self, bytes: Vec<u8>) {
        let _ = self.reply_tx.send(bytes);
    }
}

/// Server-side request source shared by both transports.
pub type RequestRx = mpsc::Receiver<Request>;
pub type RequestTx = mpsc::Sender<Request>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_reply_plumbing() {
        let (req, rx) = Request::new(b"ping".to_vec());
        assert_eq!(req.payload, b"ping");
        req.reply(b"pong".to_vec());
        assert_eq!(rx.recv().unwrap(), b"pong");
    }

    #[test]
    fn reply_to_gone_client_is_silent() {
        let (req, rx) = Request::new(vec![]);
        drop(rx);
        req.reply(b"late".to_vec()); // must not panic
    }
}
