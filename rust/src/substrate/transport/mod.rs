//! Request/reply transport: the ZeroMQ substitute under dwork.
//!
//! dwork's dhub is a single server that serializes task dispatch: every
//! worker sends a request (Steal/Complete/...) and blocks on one reply.
//! Two interchangeable transports provide that pattern:
//!
//! * [`inproc`] — channel-based, zero-syscall; used by tests, benches and
//!   the in-process "MPI job" harness.  Its measured RTT is this stack's
//!   analogue of the paper's 23 µs per-task latency.
//! * [`tcp`] — `std::net` with u32-length framing; used by the real
//!   multi-process deployment (`threesched dwork serve/worker`).
//!
//! Both deliver requests into a single [`Request`] stream so the server
//! event loop is transport-agnostic — exactly the property the paper uses
//! when it swaps direct connections for the rack-leader forwarding tree.

pub mod inproc;
pub mod tcp;

use std::sync::mpsc;
use std::time::Duration;

use anyhow::Result;

/// A client connection capable of blocking request/reply.
pub trait ClientConn: Send {
    fn request(&mut self, msg: &[u8]) -> Result<Vec<u8>>;
}

/// Default batch size for batched wire operations (driver submits,
/// worker completion reports): large enough to amortize the RTT across
/// a burst, small enough to keep frames tiny next to [`tcp`]'s 64 MiB
/// frame cap.
pub const DEFAULT_BATCH: usize = 64;

/// Typed transport knobs — the constants that used to be buried in
/// `tcp.rs` (socket timeout, `connect_retry` backoff) plus the
/// batch-size threshold for the batched wire ops, threaded through
/// `PollCfg`/`Session::polling` and the `--batch` CLI flags.
/// [`TransportCfg::default`] reproduces the historical values exactly.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TransportCfg {
    /// Per-syscall socket timeout (read/write).  Every dwork request
    /// gets an immediate reply, so a read blocked this long means the
    /// hub is wedged or the network black-holed — better to error (and
    /// let `ReconnectConn` redial) than to hang a worker forever.
    pub io_timeout: Duration,
    /// First `connect_retry` redial delay (doubles per attempt).
    pub retry_floor: Duration,
    /// `connect_retry` redial delay ceiling.
    pub retry_ceiling: Duration,
    /// Tasks per batched wire frame (submission chunks, completion
    /// reports).  1 degenerates to per-task round-trips; 0 is treated
    /// as 1 by every consumer.
    pub batch: usize,
}

impl Default for TransportCfg {
    fn default() -> Self {
        TransportCfg {
            io_timeout: Duration::from_secs(30),
            retry_floor: Duration::from_millis(5),
            retry_ceiling: Duration::from_millis(250),
            batch: DEFAULT_BATCH,
        }
    }
}

impl TransportCfg {
    /// Builder-style batch override (the `--batch N` flags land here).
    pub fn with_batch(mut self, batch: usize) -> TransportCfg {
        self.batch = batch.max(1);
        self
    }
}

/// One in-flight request as seen by the server event loop.
pub struct Request {
    pub payload: Vec<u8>,
    reply_tx: mpsc::Sender<Vec<u8>>,
}

impl Request {
    pub fn new(payload: Vec<u8>) -> (Self, mpsc::Receiver<Vec<u8>>) {
        let (tx, rx) = mpsc::channel();
        (Request { payload, reply_tx: tx }, rx)
    }

    /// Send the reply; ignores a vanished client (it may have crashed —
    /// the paper's Exit handling covers the task-state side).
    pub fn reply(self, bytes: Vec<u8>) {
        let _ = self.reply_tx.send(bytes);
    }
}

/// Server-side request source shared by both transports.
pub type RequestRx = mpsc::Receiver<Request>;
pub type RequestTx = mpsc::Sender<Request>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_reply_plumbing() {
        let (req, rx) = Request::new(b"ping".to_vec());
        assert_eq!(req.payload, b"ping");
        req.reply(b"pong".to_vec());
        assert_eq!(rx.recv().unwrap(), b"pong");
    }

    #[test]
    fn reply_to_gone_client_is_silent() {
        let (req, rx) = Request::new(vec![]);
        drop(rx);
        req.reply(b"late".to_vec()); // must not panic
    }

    #[test]
    fn transport_cfg_defaults_match_historical_constants() {
        let cfg = TransportCfg::default();
        assert_eq!(cfg.io_timeout, Duration::from_secs(30));
        assert_eq!(cfg.retry_floor, Duration::from_millis(5));
        assert_eq!(cfg.retry_ceiling, Duration::from_millis(250));
        assert_eq!(cfg.batch, DEFAULT_BATCH);
        assert_eq!(TransportCfg::default().with_batch(0).batch, 1, "0 clamps to per-task");
    }
}
