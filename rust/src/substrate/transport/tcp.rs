//! TCP transport: `std::net` with u32-LE length framing.
//!
//! One acceptor thread + one thread per connection; every decoded request
//! is forwarded into the shared server request stream, so the dwork server
//! event loop is identical for in-proc and TCP deployments.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use super::{ClientConn, Request, RequestRx};

const MAX_FRAME: u32 = 64 * 1024 * 1024;

fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

fn read_frame(r: &mut impl Read) -> std::io::Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; 4];
    match r.read_exact(&mut len4) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len4);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds {MAX_FRAME}"),
        ));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// A running TCP server front-end.  Dropping it stops the acceptor.
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind and start accepting; requests appear on the returned stream.
    pub fn bind(addr: &str) -> Result<(Self, RequestRx)> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Request>();
        let sd = Arc::clone(&shutdown);
        let acceptor = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if sd.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    // PERF: without NODELAY on the *accepted* socket the
                    // reply frames sit in Nagle's buffer waiting for the
                    // client's delayed ACK — measured 44 ms per steal RTT
                    // vs ~60 us with it (EXPERIMENTS.md §Perf).
                    let _ = stream.set_nodelay(true);
                    let tx = tx.clone();
                    let _ = std::thread::Builder::new()
                        .name("tcp-conn".into())
                        .spawn(move || connection_loop(stream, tx));
                }
            })?;
        Ok((TcpServer { addr: local, shutdown, acceptor: Some(acceptor) }, rx))
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock accept() with a dummy connection
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(200));
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

fn connection_loop(stream: TcpStream, tx: mpsc::Sender<Request>) {
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = stream;
    loop {
        let payload = match read_frame(&mut reader) {
            Ok(Some(p)) => p,
            Ok(None) | Err(_) => return, // client went away
        };
        let (req, reply_rx) = Request::new(payload);
        if tx.send(req).is_err() {
            return; // server event loop is gone
        }
        let Ok(reply) = reply_rx.recv() else { return };
        if write_frame(&mut writer, &reply).is_err() {
            return;
        }
    }
}

/// Blocking request/reply client over one TCP connection.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true)?; // latency matters: this RTT is the METG driver
        Ok(TcpClient { stream })
    }
}

impl ClientConn for TcpClient {
    fn request(&mut self, msg: &[u8]) -> Result<Vec<u8>> {
        write_frame(&mut self.stream, msg)?;
        read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow!("server closed connection mid-request"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_echo(rx: RequestRx) -> std::thread::JoinHandle<u64> {
        std::thread::spawn(move || {
            let mut n = 0;
            for req in rx {
                n += 1;
                let mut out = req.payload.clone();
                out.reverse();
                req.reply(out);
            }
            n
        })
    }

    #[test]
    fn tcp_roundtrip() {
        let (server, rx) = TcpServer::bind("127.0.0.1:0").unwrap();
        let handle = spawn_echo(rx);
        let mut c = TcpClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.request(b"hello").unwrap(), b"olleh");
        assert_eq!(c.request(b"").unwrap(), b"");
        drop(c);
        drop(server);
        let _ = handle;
    }

    #[test]
    fn tcp_concurrent_clients() {
        let (server, rx) = TcpServer::bind("127.0.0.1:0").unwrap();
        let _handle = spawn_echo(rx);
        let addr = server.addr.to_string();
        std::thread::scope(|s| {
            for i in 0..6 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = TcpClient::connect(&addr).unwrap();
                    for j in 0..20 {
                        let msg = format!("client{i}-msg{j}");
                        let want: Vec<u8> = msg.bytes().rev().collect();
                        assert_eq!(c.request(msg.as_bytes()).unwrap(), want);
                    }
                });
            }
        });
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf: &[u8] = &[0xff, 0xff, 0xff, 0xff];
        assert!(read_frame(&mut buf).is_err());
    }

    #[test]
    fn connect_to_nothing_errors() {
        assert!(TcpClient::connect("127.0.0.1:1").is_err());
    }
}
