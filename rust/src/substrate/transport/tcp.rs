//! TCP transport: `std::net` with u32-LE length framing.
//!
//! One acceptor thread + one thread per connection; every decoded request
//! is forwarded into the shared server request stream, so the dwork server
//! event loop is identical for in-proc and TCP deployments.

use std::io::{Read, Write};
use std::net::{IpAddr, Ipv4Addr, Ipv6Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use super::{ClientConn, Request, RequestRx, TransportCfg};

const MAX_FRAME: u32 = 64 * 1024 * 1024;

fn write_frame(w: &mut impl Write, payload: &[u8]) -> std::io::Result<()> {
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()
}

fn read_frame(r: &mut impl Read) -> std::io::Result<Option<Vec<u8>>> {
    let mut len4 = [0u8; 4];
    match r.read_exact(&mut len4) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Ok(None),
        Err(e) => return Err(e),
    }
    let len = u32::from_le_bytes(len4);
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds {MAX_FRAME}"),
        ));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(Some(buf))
}

/// A running TCP server front-end.  Dropping it stops the acceptor.
pub struct TcpServer {
    pub addr: std::net::SocketAddr,
    shutdown: Arc<AtomicBool>,
    acceptor: Option<std::thread::JoinHandle<()>>,
}

impl TcpServer {
    /// Bind and start accepting; requests appear on the returned stream.
    pub fn bind(addr: &str) -> Result<(Self, RequestRx)> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel::<Request>();
        let sd = Arc::clone(&shutdown);
        let acceptor = std::thread::Builder::new()
            .name("tcp-accept".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    if sd.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = conn else { continue };
                    // PERF: without NODELAY on the *accepted* socket the
                    // reply frames sit in Nagle's buffer waiting for the
                    // client's delayed ACK — measured 44 ms per steal RTT
                    // vs ~60 us with it (EXPERIMENTS.md §Perf).
                    let _ = stream.set_nodelay(true);
                    let tx = tx.clone();
                    let _ = std::thread::Builder::new()
                        .name("tcp-conn".into())
                        .spawn(move || connection_loop(stream, tx));
                }
            })?;
        Ok((TcpServer { addr: local, shutdown, acceptor: Some(acceptor) }, rx))
    }
}

/// Where the unblock-accept dummy connection must dial: a wildcard bind
/// address (`0.0.0.0` / `::`) is not itself connectable on every
/// platform, so the dial goes to the loopback of the same family with
/// the bound port.
fn dial_addr(bound: SocketAddr) -> SocketAddr {
    if bound.ip().is_unspecified() {
        let loopback: IpAddr = match bound.ip() {
            IpAddr::V4(_) => Ipv4Addr::LOCALHOST.into(),
            IpAddr::V6(_) => Ipv6Addr::LOCALHOST.into(),
        };
        SocketAddr::new(loopback, bound.port())
    } else {
        bound
    }
}

impl Drop for TcpServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // unblock accept() with a dummy connection
        let _ = TcpStream::connect_timeout(&dial_addr(self.addr), Duration::from_millis(200));
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
    }
}

/// Requests a connection may have in flight before its reader half
/// blocks: deep enough to keep the hub's event loop fed by a batching
/// client, bounded so one connection cannot queue unbounded state work.
const PIPELINE_DEPTH: usize = 32;

/// Pipelined per-connection loop.  The reader half decodes the next
/// frame and injects it into the server stream *while* the state
/// operation for the previous request runs; the writer half (this
/// thread) drains the per-request reply channels strictly in arrival
/// order, so the one-reply-per-request wire contract is preserved.
fn connection_loop(stream: TcpStream, tx: mpsc::Sender<Request>) {
    let mut reader = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut writer = stream;
    let (pending_tx, pending_rx) =
        mpsc::sync_channel::<mpsc::Receiver<Vec<u8>>>(PIPELINE_DEPTH);
    let read_half = std::thread::Builder::new().name("tcp-read".into()).spawn(move || {
        loop {
            let payload = match read_frame(&mut reader) {
                Ok(Some(p)) => p,
                Ok(None) | Err(_) => return, // client went away
            };
            let (req, reply_rx) = Request::new(payload);
            if tx.send(req).is_err() {
                return; // server event loop is gone
            }
            if pending_tx.send(reply_rx).is_err() {
                return; // writer half gave up (write error)
            }
        }
    });
    let Ok(read_half) = read_half else { return };
    for reply_rx in pending_rx {
        // recv fails when the server dropped the request without a
        // reply — the event loop is gone, tear the connection down
        let Ok(reply) = reply_rx.recv() else { break };
        if write_frame(&mut writer, &reply).is_err() {
            break;
        }
    }
    // unblock a reader half parked in read_frame (e.g. the server loop
    // died between two client requests) so this thread can reap it
    let _ = writer.shutdown(std::net::Shutdown::Both);
    let _ = read_half.join();
}

/// Blocking request/reply client over one TCP connection.
pub struct TcpClient {
    stream: TcpStream,
}

impl TcpClient {
    /// Connect with the default [`TransportCfg`] (30 s socket timeout —
    /// every dwork request gets an immediate reply, so a read blocked
    /// that long means the hub is wedged or the network black-holed).
    pub fn connect(addr: &str) -> Result<Self> {
        Self::connect_cfg(addr, &TransportCfg::default())
    }

    /// Connect applying `cfg.io_timeout` to both socket directions.
    pub fn connect_cfg(addr: &str, cfg: &TransportCfg) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
        stream.set_nodelay(true)?; // latency matters: this RTT is the METG driver
        stream.set_read_timeout(Some(cfg.io_timeout))?;
        stream.set_write_timeout(Some(cfg.io_timeout))?;
        Ok(TcpClient { stream })
    }

    /// Keep dialing `addr` with exponential backoff until it answers or
    /// `timeout` elapses.  Remote deployments launch hub and workers from
    /// independent job steps, so a worker routinely starts before the hub
    /// has bound its socket; this absorbs that race.
    pub fn connect_retry(addr: &str, timeout: Duration) -> Result<Self> {
        Self::connect_retry_cfg(addr, timeout, &TransportCfg::default())
    }

    /// [`connect_retry`](Self::connect_retry) with explicit backoff knobs:
    /// the first redial waits `cfg.retry_floor`, doubling per attempt up
    /// to `cfg.retry_ceiling`.
    pub fn connect_retry_cfg(addr: &str, timeout: Duration, cfg: &TransportCfg) -> Result<Self> {
        let deadline = Instant::now() + timeout;
        let mut delay = cfg.retry_floor;
        loop {
            match Self::connect_cfg(addr, cfg) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(e.context(format!(
                            "no server reachable at {addr} within {timeout:?}"
                        )));
                    }
                    // never sleep past the deadline: the last dial happens
                    // AT the deadline, not delay-before it
                    std::thread::sleep(delay.min(deadline - now));
                    delay = (delay * 2).min(cfg.retry_ceiling);
                }
            }
        }
    }
}

impl ClientConn for TcpClient {
    fn request(&mut self, msg: &[u8]) -> Result<Vec<u8>> {
        write_frame(&mut self.stream, msg)?;
        read_frame(&mut self.stream)?
            .ok_or_else(|| anyhow!("server closed connection mid-request"))
    }
}

/// A self-healing [`ClientConn`] over TCP: dials lazily on first use, and
/// when a request fails (connection reset, server restart) redials and
/// replays the request up to `max_redials` times before surfacing the
/// error — bounded, so a dead hub fails fast instead of spinning forever.
///
/// Replay caveat: a request the server applied just before the connection
/// died is applied twice.  Every dwork message tolerates this — reads
/// (`Status`, `Steal`) simply re-ask, and a duplicated mutation surfaces
/// as a server-side `Err` the caller already handles (`Create` of an
/// existing task, `Complete` of a finished one).  Use it for control-plane
/// clients (submitters, status pollers); workers prefer a plain
/// [`TcpClient`] so a dead worker's tasks are re-queued rather than
/// replayed.
pub struct ReconnectConn {
    addr: String,
    conn: Option<TcpClient>,
    max_redials: u32,
    connect_timeout: Duration,
}

impl ReconnectConn {
    pub fn new(addr: impl Into<String>) -> ReconnectConn {
        ReconnectConn {
            addr: addr.into(),
            conn: None,
            max_redials: 3,
            connect_timeout: Duration::from_secs(10),
        }
    }

    /// Bound the redial count and the per-dial connect timeout.
    pub fn with_limits(mut self, max_redials: u32, connect_timeout: Duration) -> ReconnectConn {
        self.max_redials = max_redials;
        self.connect_timeout = connect_timeout;
        self
    }
}

impl ClientConn for ReconnectConn {
    fn request(&mut self, msg: &[u8]) -> Result<Vec<u8>> {
        let mut redials = 0u32;
        loop {
            if self.conn.is_none() {
                self.conn = Some(TcpClient::connect_retry(&self.addr, self.connect_timeout)?);
            }
            match self.conn.as_mut().expect("connection just established").request(msg) {
                Ok(reply) => return Ok(reply),
                Err(e) => {
                    self.conn = None; // this connection is dead: redial
                    if redials >= self.max_redials {
                        return Err(e.context(format!(
                            "request to {} failed after {redials} redials",
                            self.addr
                        )));
                    }
                    redials += 1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spawn_echo(rx: RequestRx) -> std::thread::JoinHandle<u64> {
        std::thread::spawn(move || {
            let mut n = 0;
            for req in rx {
                n += 1;
                let mut out = req.payload.clone();
                out.reverse();
                req.reply(out);
            }
            n
        })
    }

    #[test]
    fn tcp_roundtrip() {
        let (server, rx) = TcpServer::bind("127.0.0.1:0").unwrap();
        let handle = spawn_echo(rx);
        let mut c = TcpClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(c.request(b"hello").unwrap(), b"olleh");
        assert_eq!(c.request(b"").unwrap(), b"");
        drop(c);
        drop(server);
        let _ = handle;
    }

    #[test]
    fn tcp_concurrent_clients() {
        let (server, rx) = TcpServer::bind("127.0.0.1:0").unwrap();
        let _handle = spawn_echo(rx);
        let addr = server.addr.to_string();
        std::thread::scope(|s| {
            for i in 0..6 {
                let addr = addr.clone();
                s.spawn(move || {
                    let mut c = TcpClient::connect(&addr).unwrap();
                    for j in 0..20 {
                        let msg = format!("client{i}-msg{j}");
                        let want: Vec<u8> = msg.bytes().rev().collect();
                        assert_eq!(c.request(msg.as_bytes()).unwrap(), want);
                    }
                });
            }
        });
    }

    #[test]
    fn pipelined_requests_reply_in_order() {
        // a raw socket writes a burst of frames before reading anything:
        // the pipelined connection loop must serve them all (reader half
        // keeps decoding while earlier requests are in flight) and the
        // replies must come back strictly in request order
        let (server, rx) = TcpServer::bind("127.0.0.1:0").unwrap();
        let _handle = spawn_echo(rx);
        let mut s = TcpStream::connect(server.addr).unwrap();
        s.set_nodelay(true).unwrap();
        for i in 0..10u8 {
            write_frame(&mut s, &[i, i + 1, i + 2]).unwrap();
        }
        for i in 0..10u8 {
            let reply = read_frame(&mut s).unwrap().expect("reply frame");
            assert_eq!(reply, vec![i + 2, i + 1, i], "reply {i} out of order");
        }
        drop(s);
        drop(server);
    }

    #[test]
    fn connect_cfg_applies_io_timeout() {
        let (server, _rx) = TcpServer::bind("127.0.0.1:0").unwrap();
        let custom = Duration::from_secs(7);
        let cfg = TransportCfg { io_timeout: custom, ..TransportCfg::default() };
        let c = TcpClient::connect_cfg(&server.addr.to_string(), &cfg).unwrap();
        assert_eq!(c.stream.read_timeout().unwrap(), Some(custom));
        assert_eq!(c.stream.write_timeout().unwrap(), Some(custom));
        let d = TcpClient::connect(&server.addr.to_string()).unwrap();
        assert_eq!(d.stream.read_timeout().unwrap(), Some(Duration::from_secs(30)));
        drop(server);
    }

    #[test]
    fn oversized_frame_rejected() {
        let mut buf: &[u8] = &[0xff, 0xff, 0xff, 0xff];
        assert!(read_frame(&mut buf).is_err());
    }

    #[test]
    fn connect_to_nothing_errors() {
        assert!(TcpClient::connect("127.0.0.1:1").is_err());
    }

    #[test]
    fn wildcard_bind_drop_does_not_stall() {
        // regression: the unblock-accept dummy dial used to target the
        // wildcard address verbatim, stalling Drop for the full 200 ms
        // connect timeout on platforms where 0.0.0.0 is not connectable
        let (server, _rx) = TcpServer::bind("0.0.0.0:0").unwrap();
        let t0 = std::time::Instant::now();
        drop(server);
        assert!(
            t0.elapsed() < Duration::from_millis(150),
            "wildcard-bound server drop stalled {:?}",
            t0.elapsed()
        );
    }

    #[test]
    fn dial_addr_maps_wildcard_to_loopback() {
        let v4: SocketAddr = "0.0.0.0:7117".parse().unwrap();
        assert_eq!(dial_addr(v4), "127.0.0.1:7117".parse().unwrap());
        let v6: SocketAddr = "[::]:7117".parse().unwrap();
        assert_eq!(dial_addr(v6), "[::1]:7117".parse().unwrap());
        let concrete: SocketAddr = "10.1.2.3:7117".parse().unwrap();
        assert_eq!(dial_addr(concrete), concrete);
    }

    #[test]
    fn connect_retry_waits_for_late_server() {
        // grab a free port, release it, then bring the server up late
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = probe.local_addr().unwrap();
        drop(probe);
        let addr_s = addr.to_string();
        let server_thread = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            let (server, rx) = TcpServer::bind(&addr.to_string()).unwrap();
            let echo = spawn_echo(rx);
            (server, echo)
        });
        let mut c = TcpClient::connect_retry(&addr_s, Duration::from_secs(5)).unwrap();
        assert_eq!(c.request(b"late").unwrap(), b"etal");
        let (server, _echo) = server_thread.join().unwrap();
        drop(c);
        drop(server);
    }

    #[test]
    fn connect_retry_gives_up_at_deadline() {
        let t0 = std::time::Instant::now();
        let r = TcpClient::connect_retry("127.0.0.1:1", Duration::from_millis(150));
        assert!(r.is_err());
        assert!(t0.elapsed() < Duration::from_secs(5), "retry loop ran away");
    }

    #[test]
    fn reconnect_conn_serves_requests_and_bounds_redials() {
        let (server, rx) = TcpServer::bind("127.0.0.1:0").unwrap();
        // an event loop that dies after one request: the live connection
        // is severed mid-session, exactly the failure ReconnectConn heals
        let one_shot = std::thread::spawn(move || {
            let req = rx.recv().unwrap();
            let mut out = req.payload.clone();
            out.reverse();
            req.reply(out);
            // rx drops here: every later forward fails, connections close
        });
        let mut c = ReconnectConn::new(server.addr.to_string())
            .with_limits(2, Duration::from_millis(200));
        assert_eq!(c.request(b"abc").unwrap(), b"cba");
        one_shot.join().unwrap();
        // redials reconnect fine (the acceptor still runs) but every
        // replay fails: the bounded budget must surface the error quickly
        let t0 = std::time::Instant::now();
        assert!(c.request(b"again").is_err());
        assert!(t0.elapsed() < Duration::from_secs(10), "redial loop ran away");
        drop(server);
    }
}
