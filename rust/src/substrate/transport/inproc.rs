//! In-process transport: channels standing in for ZeroMQ inproc://.

use std::sync::mpsc;

use anyhow::{anyhow, Result};

use super::{ClientConn, Request, RequestRx, RequestTx};

/// Create a hub: returns the server-side request stream and a connector
/// from which any number of clients can be cloned.
pub fn hub() -> (RequestRx, Connector) {
    let (tx, rx) = mpsc::channel();
    (rx, Connector { tx })
}

/// Cheap-to-clone client factory.
#[derive(Clone)]
pub struct Connector {
    tx: RequestTx,
}

impl Connector {
    pub fn connect(&self) -> InprocClient {
        InprocClient { tx: self.tx.clone() }
    }
}

/// Blocking request/reply client over the in-proc hub.
pub struct InprocClient {
    tx: RequestTx,
}

impl ClientConn for InprocClient {
    fn request(&mut self, msg: &[u8]) -> Result<Vec<u8>> {
        let (req, reply_rx) = Request::new(msg.to_vec());
        self.tx
            .send(req)
            .map_err(|_| anyhow!("inproc server is gone"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow!("inproc server dropped the request"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn echo_roundtrip() {
        let (rx, connector) = hub();
        let server = std::thread::spawn(move || {
            for req in rx {
                let mut reply = req.payload.clone();
                reply.reverse();
                req.reply(reply);
            }
        });
        let mut c = connector.connect();
        assert_eq!(c.request(b"abc").unwrap(), b"cba");
        assert_eq!(c.request(b"").unwrap(), b"");
        drop(c);
        drop(connector);
        server.join().unwrap();
    }

    #[test]
    fn many_clients_serialized() {
        let (rx, connector) = hub();
        let server = std::thread::spawn(move || {
            let mut count = 0u64;
            for req in rx {
                count += 1;
                req.reply(count.to_le_bytes().to_vec());
            }
            count
        });
        let clients: Vec<_> = (0..8).map(|_| connector.connect()).collect();
        std::thread::scope(|s| {
            for mut c in clients {
                s.spawn(move || {
                    for _ in 0..50 {
                        let r = c.request(b"x").unwrap();
                        assert_eq!(r.len(), 8);
                    }
                });
            }
        });
        drop(connector);
        assert_eq!(server.join().unwrap(), 400);
    }

    #[test]
    fn request_after_server_gone_errors() {
        let (rx, connector) = hub();
        drop(rx);
        let mut c = connector.connect();
        assert!(c.request(b"hello").is_err());
    }
}
